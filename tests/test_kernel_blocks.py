"""Property tests for the kernel's BlockSpec selection: the chosen tile
always fits the VMEM budget and is MXU/chunk aligned (the paper's 4x4-
layout feasibility question at the VMEM level)."""
from hypothesis import given, settings, strategies as st

from repro.core import packing
from repro.kernels.qmatmul import default_block


@given(m=st.integers(32, 8192), n=st.integers(128, 16384),
       k=st.integers(128, 32768),
       a_bits=st.sampled_from([8, 4, 2]), w_bits=st.sampled_from([8, 4, 2]))
@settings(max_examples=100, deadline=None)
def test_default_block_fits_vmem(m, n, k, a_bits, w_bits):
    budget = 8 * 1024 * 1024
    bm, bn, bk = default_block(m, n, k, a_bits, w_bits, budget)
    pf_a, pf_w = 8 // a_bits, 8 // w_bits
    work = 2 * (bm * (bk // pf_a) + (bk // pf_w) * bn) + 2 * bm * bn * 4
    assert work <= budget
    assert bk % packing.CHUNK == 0
    assert bm >= 32 and bn >= 128
