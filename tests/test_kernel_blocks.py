"""Property tests for the kernels' BlockSpec selection: the chosen tile
always fits the VMEM budget and is MXU/chunk aligned (the paper's 4x4-
layout feasibility question at the VMEM level). Covers both the GEMM
selector (`default_block`) and the fused-conv selector
(`conv_default_block`), whose grid must also cover ragged Ho edges."""
import pytest

from conftest import hypothesis_api

# guarded: property tests skip (not hard-fail) without hypothesis
given, settings, st = hypothesis_api()

from repro.core import packing
from repro.kernels.common import (LANE, SUBLANE_I8, conv_default_block,
                                  conv_working_set, gemm_working_set)
from repro.kernels.qmatmul import default_block

BUDGET = 8 * 1024 * 1024


@given(m=st.integers(32, 8192), n=st.integers(128, 16384),
       k=st.integers(128, 32768),
       a_bits=st.sampled_from([8, 4, 2]), w_bits=st.sampled_from([8, 4, 2]))
@settings(max_examples=100, deadline=None)
def test_default_block_fits_vmem(m, n, k, a_bits, w_bits):
    bm, bn, bk = default_block(m, n, k, a_bits, w_bits, BUDGET)
    assert gemm_working_set(bm, bn, bk, a_bits, w_bits) <= BUDGET
    assert bk % packing.CHUNK == 0
    assert bm >= 32 and bn >= 128


def test_gemm_working_set_counts_double_buffered_copies():
    """Regression: the fit check must count 2x residency for every
    pipelined block (x/w K tiles, out tile, epilogue params), not just
    the operand tiles — the pre-fix formula under-counted by the second
    out-block buffer plus both param-block buffers, so a tile at the
    budget edge could overflow VMEM once double-buffered."""
    bm, bn, bk, a_bits, w_bits = 256, 512, 1024, 8, 8
    work = gemm_working_set(bm, bn, bk, a_bits, w_bits)
    under = (2 * (bm * bk + bk * bn)      # operands only, double-buffered
             + 2 * bm * bn * 4)           # old formula: acc + single out
    assert work > under
    missed = work - under                 # second out buffer + 2x params
    assert missed == bm * bn * 4 + 2 * 3 * bn * 4


def test_default_block_boundary_at_budget():
    """At a budget exactly equal to the chosen tile's working set the
    selector keeps the tile; one byte less forces a strictly smaller tile
    (the fit check is the working set, with no hidden slack)."""
    m, n, k, a_bits, w_bits = 256, 512, 2048, 4, 4
    blk = default_block(m, n, k, a_bits, w_bits, BUDGET)
    exact = gemm_working_set(*blk, a_bits, w_bits)
    assert default_block(m, n, k, a_bits, w_bits, exact) == blk
    smaller = default_block(m, n, k, a_bits, w_bits, exact - 1)
    assert smaller != blk
    assert gemm_working_set(*smaller, a_bits, w_bits) <= exact - 1
    # the floor tile is never shrunk below MXU alignment
    assert smaller[0] >= SUBLANE_I8 and smaller[1] >= LANE
    assert smaller[2] % packing.CHUNK == 0


def _check_conv_block(ho, wo, cout, fh, fw, cin_pad, stride, a_bits, w_bits):
    bho, bn = conv_default_block(1, ho, wo, cout, fh, fw, cin_pad, stride,
                                 a_bits, w_bits, BUDGET)
    # MXU/chunk alignment: lane dim a LANE multiple, per-tap contraction
    # run (and hence every im2col scratch column run) CHUNK-aligned
    assert bn % LANE == 0 and bn >= LANE
    assert cin_pad % packing.CHUNK == 0
    # ragged Ho coverage: ceil(ho/bho) tiles cover every output row with
    # less than one tile of overshoot
    assert 1 <= bho <= ho
    n_tiles = -(-ho // bho)
    assert n_tiles * bho >= ho
    assert n_tiles * bho - ho < bho
    # the working set the wrapper will actually allocate fits the budget
    assert conv_working_set(
        bho, bn, ho=ho, wo=wo, cout=cout, fh=fh, fw=fw, cin_pad=cin_pad,
        stride=stride, a_bits=a_bits, w_bits=w_bits) <= BUDGET
    return bho, bn


@given(ho=st.integers(1, 64), wo=st.integers(1, 64),
       cout=st.integers(1, 1024),
       fh=st.sampled_from([1, 3, 5, 7]), fw=st.sampled_from([1, 3, 5, 7]),
       n_chunks=st.integers(1, 3), stride=st.sampled_from([1, 2]),
       a_bits=st.sampled_from([8, 4, 2]), w_bits=st.sampled_from([8, 4, 2]))
@settings(max_examples=100, deadline=None)
def test_conv_default_block_fits_vmem(ho, wo, cout, fh, fw, n_chunks,
                                      stride, a_bits, w_bits):
    _check_conv_block(ho, wo, cout, fh, fw, n_chunks * packing.CHUNK,
                      stride, a_bits, w_bits)


# deterministic edge cases — these run even without hypothesis installed
@pytest.mark.parametrize("ho,wo", [(1, 1), (7, 5), (33, 1), (1, 63),
                                   (16, 16), (64, 64)])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv_block_ragged_edges(ho, wo, stride):
    bho, bn = _check_conv_block(ho, wo, cout=40, fh=3, fw=3,
                                cin_pad=packing.CHUNK, stride=stride,
                                a_bits=4, w_bits=4)
    assert -(-ho // bho) * bho >= ho


def test_conv_block_paper_layers():
    """The paper's fig.11 layers (16x16x32, 32x32x32 -> 64ch 3x3) pick a
    single-tile block: the whole output in one VMEM-resident pass."""
    for hw in (16, 32):
        bho, bn = _check_conv_block(hw, hw, cout=64, fh=3, fw=3,
                                    cin_pad=packing.CHUNK, stride=1,
                                    a_bits=4, w_bits=4)
        assert bn == LANE


def test_conv_block_rejects_oversized_image():
    """Images whose packed whole-image block cannot fit VMEM must raise
    (callers then use the im2col fallback) rather than return a tile that
    would OOM the kernel."""
    with pytest.raises(ValueError):
        conv_default_block(1, 4096, 4096, 64, 3, 3, 8 * packing.CHUNK,
                           1, 8, 8, BUDGET)
