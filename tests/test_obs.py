"""The observability subsystem (`repro.obs`): spans, software perf
counters, the dispatch decision log, Chrome-trace export, env knobs.

* span nesting/attrs and the null-singleton disabled path;
* counter MAC/byte accounting against hand-computed GEMM/conv costs
  across the {8,4,2}^2 bit grid, recorded at the api entry points;
* one dispatch event per resolution with correct provenance for every
  layer of the order (explicit / plan hint / env / tune-cache / default);
* chrome_trace() round-trips through json and passes the checked-in
  artifact validator (benchmarks/schema.py::check_trace);
* disabled mode records nothing — the backend-parity invariant;
* engine wave-latency percentiles against a deterministic fake clock;
* the shared timer dedupe (tune._time == obs.time_call / 1e6) and the
  env-knob registry (validation, legacy alias, unknown-var warning).
"""
import json
import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:  # `import benchmarks` from any rootdir
    sys.path.insert(0, str(ROOT))

from repro.core import packing
from repro.core.quantize import QuantizedLinearParams
from repro.kernels import api, tune
from repro.obs import counters as obs_counters
from repro.obs import env as obsenv
from repro.obs import trace as obs

BITS = (8, 4, 2)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with empty buffers + disabled state."""
    obs.disable()
    obs.reset()
    obs_counters.reset()
    tune.clear()
    yield
    obs.disable()
    obs.reset()
    obs_counters.reset()
    tune.clear()


# ------------------------------------------------------------- fixtures ---

def _mk_qdot_params(rng, a_bits, w_bits, K=256, N=128):
    lo, hi = packing.int_range(w_bits, True)
    w = rng.integers(lo, hi + 1, size=(K, N)).astype(np.int8)
    wp = packing.pack(jnp.asarray(w), w_bits, axis=0)
    return QuantizedLinearParams(
        w_packed=wp, w_bits=w_bits, a_bits=a_bits, a_signed=False,
        kappa=jnp.asarray(rng.integers(-64, 64, (N,)).astype(np.int32)),
        lam=jnp.asarray(rng.integers(-2**16, 2**16, (N,)).astype(np.int32)),
        m=jnp.asarray(rng.integers(0, 2**15, (N,)).astype(np.int32)),
        d=18, out_bits=8, k_logical=K)


def _mk_acts(rng, a_bits, M=16, K=256):
    lo, hi = packing.int_range(a_bits, False)
    return jnp.asarray(rng.integers(lo, hi + 1, (M, K)).astype(np.int8))


def _mk_conv(rng, a_bits, w_bits, H=8, W=8, cin=24, cout=40):
    from repro.core import (QuantSpec, calibrate_activation,
                            calibrate_weight, quantize)
    from repro.kernels.qconv import quantize_conv

    x = np.maximum(rng.normal(size=(1, H, W, cin)), 0).astype(np.float32)
    w = rng.normal(size=(3, 3, cin, cout)).astype(np.float32) * 0.08
    sw = calibrate_weight(jnp.asarray(w), w_bits)
    sx = calibrate_activation(x, a_bits, 100.0)
    sy = QuantSpec.activation(a_bits, 8.0)
    qp = quantize_conv(jnp.asarray(w), sw,
                       rng.normal(size=(cout,)).astype(np.float32) * .05 + .3,
                       np.zeros((cout,), np.float32), sx, sy, 1, 1)
    return qp, quantize(jnp.asarray(x), sx)


# ----------------------------------------------------------------- spans ---

def test_span_records_attrs_and_nesting():
    with obs.enabled_scope():
        with obs.span("outer", cat="test", depth=0) as sp:
            sp.set(extra="late")
            with obs.span("inner", cat="test", depth=1):
                pass
    evs = obs.spans(cat="test")
    assert [e["name"] for e in evs] == ["inner", "outer"]  # exit order
    inner, outer = evs
    assert outer["args"] == {"depth": 0, "extra": "late"}
    assert inner["args"] == {"depth": 1}
    # inner lies within outer's [ts, ts+dur] window
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_span_records_exception_and_reraises():
    with obs.enabled_scope():
        with pytest.raises(RuntimeError):
            with obs.span("boom", cat="test"):
                raise RuntimeError("x")
    (ev,) = obs.spans(name="boom")
    assert ev["args"]["error"] == "RuntimeError"


def test_counter_accumulates_and_survives_handle_caching():
    with obs.enabled_scope():
        c = obs.counter("hits")
        c.add().add(4)
        assert obs.counter_values() == {"hits": 5}
    # the cached handle is inert once disabled
    c.add(100)
    assert obs.counter_values() == {"hits": 5}


def test_disabled_mode_is_a_noop(rng):
    """The backend-parity invariant: with observability off the api path
    records nothing, and span/counter return the shared null singletons."""
    assert obs.span("a") is obs.span("b")
    assert obs.counter("a") is obs.counter("b")
    params = _mk_qdot_params(rng, 8, 8)
    api.qdot(params, _mk_acts(rng, 8), backend="xla")
    assert obs.events() == []
    assert obs.dispatch_log() == []
    assert obs.counter_values() == {}
    assert obs_counters.snapshot() == {}


# -------------------------------------------------------------- counters ---

@pytest.mark.parametrize("ab", BITS)
@pytest.mark.parametrize("wb", BITS)
def test_qdot_mac_accounting(ab, wb, rng):
    M, K, N = 16, 256, 128
    params = _mk_qdot_params(rng, ab, wb, K=K, N=N)
    x = _mk_acts(rng, ab, M=M, K=K)
    with obs.enabled_scope():
        api.qdot(params, x, backend="xla")
    snap = obs_counters.snapshot()
    k = obs_counters.key("qdot", wb, ab, "xla", "off")
    assert set(snap) == {k}
    b = snap[k]
    assert b["calls"] == 1
    assert b["macs"] == M * K * N
    assert b["logical_bytes"] == M * K + K * N + M * N
    assert b["packed_bytes"] == (M * K // (8 // ab) + K * N // (8 // wb)
                                 + M * N)
    # the kernel span mirrors the same costs in its args
    (ev,) = obs.spans(name="qdot", cat="kernel")
    assert ev["args"]["macs"] == M * K * N
    assert ev["args"]["w_bits"] == wb and ev["args"]["a_bits"] == ab


@pytest.mark.parametrize("ab,wb", [(8, 8), (8, 4), (4, 2)])
def test_qconv_mac_accounting(ab, wb, rng):
    H = W = 8
    cin, cout, fh = 24, 40, 3
    qp, xq = _mk_conv(rng, ab, wb, H=H, W=W, cin=cin, cout=cout)
    with obs.enabled_scope():
        api.qconv(qp, xq, backend="xla")
    snap = obs_counters.snapshot()
    k = obs_counters.key("qconv", wb, ab, "xla", "off")
    assert k in snap
    ho = wo = H  # stride 1, padding 1, 3x3
    assert snap[k]["macs"] == 1 * ho * wo * fh * fh * cin * cout
    assert snap[k]["calls"] == 1


def test_counter_delta_attribution(rng):
    params = _mk_qdot_params(rng, 8, 4)
    x = _mk_acts(rng, 8)
    with obs.enabled_scope():
        api.qdot(params, x, backend="xla")
        before = obs_counters.snapshot()
        api.qdot(params, x, backend="xla")
        api.qdot(params, x, backend="xla")
        d = obs_counters.delta(obs_counters.snapshot(), before)
    k = obs_counters.key("qdot", 4, 8, "xla", "off")
    assert d[k]["calls"] == 2
    assert d[k]["macs"] == 2 * 16 * 256 * 128
    # unchanged buckets are dropped entirely
    assert obs_counters.delta(before, before) == {}


# ---------------------------------------------------------- dispatch log ---

def _one_dispatch(rng, monkeypatch=None, **kw):
    params = _mk_qdot_params(rng, 8, 4)
    x = _mk_acts(rng, 8)
    with obs.enabled_scope():
        api.qdot(params, x, **kw)
    log = obs.dispatch_log()
    assert len(log) == 1
    return log[0]


def test_dispatch_source_explicit(rng):
    ev = _one_dispatch(rng, backend="xla")
    assert ev["backend"] == "xla"
    assert ev["backend_source"] == "explicit"
    assert ev["pipeline_source"] == "default"
    assert ev["tune_cache_hit"] is False
    assert ev["op"] == "qdot" and ev["w_bits"] == 4 and ev["a_bits"] == 8


def test_dispatch_source_plan_hint(rng):
    ev = _one_dispatch(rng, plan_hints={"backend": "xla",
                                        "pipeline": "double_buffer"})
    assert ev["backend_source"] == "plan"
    assert ev["plan_backend"] == "xla"
    assert ev["pipeline"] == "double_buffer"
    assert ev["pipeline_source"] == "plan"


def test_dispatch_source_env(rng, monkeypatch):
    monkeypatch.setenv("REPRO_QBACKEND", "xla")
    monkeypatch.setenv("REPRO_QPIPELINE", "double_buffer")
    ev = _one_dispatch(rng)
    assert ev["backend_source"] == "env"
    assert ev["env_backend"] == "xla"
    assert ev["pipeline_source"] == "env"
    assert ev["env_pipeline"] == "double_buffer"


def test_dispatch_source_default(rng):
    ev = _one_dispatch(rng)
    assert ev["backend_source"] == "default"
    assert ev["backend"] in api.DEFAULT_ORDER
    assert ev["pipeline"] == "off" and ev["pipeline_source"] == "default"


def test_dispatch_source_tune_cache(rng):
    # first resolution reveals the registry's exact shape key ...
    first = _one_dispatch(rng, backend="xla")
    assert first["tune_cache_hit"] is False
    obs.reset()
    # ... which a recorded sweep winner then serves on the next call
    tune.record_block("qdot", first["shape"], 8, 4, "xla",
                      block=(16, 128, 128), pipeline="double_buffer",
                      us=12.5)
    ev = _one_dispatch(rng, backend="xla")
    assert ev["tune_cache_hit"] is True
    assert ev["block_source"] == "tuned"
    assert ev["block"] == (16, 128, 128)
    assert ev["pipeline"] == "double_buffer"
    assert ev["pipeline_source"] == "tuned"
    assert ev["tune_winner"] == {"block": [16, 128, 128],
                                 "pipeline": "double_buffer", "us": 12.5}


def test_dispatch_mirrors_instant_event(rng):
    _one_dispatch(rng, backend="xla")
    instants = [e for e in obs.events() if e["ph"] == "i"]
    assert len(instants) == 1
    assert instants[0]["name"] == "dispatch:qdot"
    assert instants[0]["args"]["backend"] == "xla"


# ---------------------------------------------------------- trace export ---

def test_chrome_trace_roundtrip(rng, tmp_path):
    from benchmarks import schema

    params = _mk_qdot_params(rng, 8, 4)
    x = _mk_acts(rng, 8)
    with obs.enabled_scope():
        api.qdot(params, x, backend="xla")
        path = obs.export_chrome_trace(str(tmp_path / "t.json"))
    doc = json.loads(pathlib.Path(path).read_text())
    schema.check_trace(doc)
    assert doc["repro"]["version"] == obs.TRACE_SCHEMA_VERSION
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"qdot", "dispatch:qdot"} <= names
    assert "qdot|w4a8|xla|off" in doc["repro"]["op_counters"]


def test_export_if_configured(rng, tmp_path, monkeypatch):
    assert obs.export_if_configured(str(tmp_path / "no.json")) is None
    with obs.enabled_scope():
        obs.counter("x").add()
        assert obs.export_if_configured(None) is None
        target = tmp_path / "via_env.json"
        monkeypatch.setenv("REPRO_OBS_TRACE", str(target))
        assert obs.export_if_configured("ignored.json") == str(target)
    assert json.loads(target.read_text())["repro"]["counters"] == {"x": 1}


def test_report_cli_renders_table(rng, tmp_path, capsys):
    from repro.obs import report

    params = _mk_qdot_params(rng, 8, 4)
    x = _mk_acts(rng, 8)
    with obs.enabled_scope():
        api.qdot(params, x, backend="xla")
        path = obs.export_chrome_trace(str(tmp_path / "t.json"))
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert "MAC/us per bit-width" in out
    assert "dispatch decisions" in out
    assert "qdot" in out
    assert report.main([str(tmp_path / "missing.json")]) == 2


def test_ring_buffer_bounds_memory():
    with obs.enabled_scope():
        obs.enable(capacity=8)
        for i in range(50):
            with obs.span(f"s{i}", cat="test"):
                pass
        evs = obs.events()
    assert len(evs) == 8
    assert evs[-1]["name"] == "s49"  # newest survive, oldest fall off
    obs.enable(capacity=obs.DEFAULT_CAPACITY)


# ------------------------------------------------------- engine latency ---

def test_wave_latency_percentiles_fake_clock():
    from repro.serve import engine

    class Stats(engine._WaveStats):
        def __init__(self, batch, dp):
            self.batch, self._dp = batch, dp
            self.wave_stats = []

    st = Stats(batch=4, dp=2)
    ticks = iter([0.0, 0.010, 1.0, 1.020, 2.0, 2.030, 3.0, 3.040])
    st.clock = lambda: next(ticks)
    for n_real, depth in ((4, 3), (4, 1), (3, 0), (1, 0)):
        st._record_wave(n_real, queue_depth=depth)
        w = st._finish_wave()
        assert w["latency_us"] is not None
    rep = st.utilization_report()
    lat = rep["latency_us"]
    want = [10e3, 20e3, 30e3, 40e3]
    assert lat["waves"] == 4
    assert lat["p50"] == pytest.approx(np.percentile(want, 50))
    assert lat["p95"] == pytest.approx(np.percentile(want, 95))
    assert lat["p99"] == pytest.approx(np.percentile(want, 99))
    assert lat["mean"] == pytest.approx(25e3)
    assert lat["max"] == pytest.approx(40e3)
    assert rep["queue_depth"] == {"mean": 1.0, "max": 3}
    assert rep["occupancy_timeline"] == [[1.0, 1.0], [1.0, 1.0],
                                         [1.0, 0.5], [0.5, 0.0]]


def test_wave_counters_bump_when_enabled():
    from repro.serve import engine

    class Stats(engine._WaveStats):
        def __init__(self):
            self.batch, self._dp = 2, 1
            self.wave_stats = []

    st = Stats()
    with obs.enabled_scope():
        st._record_wave(2)
        st._finish_wave()
        st._record_wave(1)
        st._finish_wave()
    assert obs.counter_values() == {"engine.waves": 2,
                                    "engine.requests": 3}


def test_empty_report_has_null_latency():
    from repro.serve import engine

    class Stats(engine._WaveStats):
        def __init__(self):
            self.batch, self._dp = 2, 1
            self.wave_stats = []

    rep = Stats().utilization_report()
    assert rep["latency_us"] is None
    assert rep["queue_depth"] is None
    assert rep["occupancy_timeline"] == []


# ----------------------------------------------------------- shared timer ---

def test_time_call_dedupe():
    """One timer implementation behind tune._time and benchmarks'
    time_call (the PR's dedupe satellite): same semantics, µs vs s."""
    from benchmarks import common

    calls = []
    us = obs.time_call(lambda: calls.append(1), warmup=2, iters=5)
    assert us >= 0 and len(calls) == 7  # warmup + iters
    calls.clear()
    common.time_call(lambda: calls.append(1), warmup=2, iters=5)
    assert len(calls) == 7  # same implementation behind the alias
    s = tune._time(lambda: None, iters=2)
    assert 0 <= s < 1.0  # seconds, not µs


def test_counted_time_call_attributes_per_call(rng):
    from benchmarks import common

    params = _mk_qdot_params(rng, 8, 4)
    x = _mk_acts(rng, 8)
    us, per_call = common.counted_time_call(
        lambda: api.qdot(params, x, backend="xla"), warmup=1, iters=3)
    assert us > 0
    assert per_call["macs"] == pytest.approx(16 * 256 * 128)
    assert per_call["packed_bytes"] == pytest.approx(
        16 * 256 // 1 + 256 * 128 // 2 + 16 * 128)
    # counted_time_call force-enables, then restores the prior state
    assert not obs.enabled()


# -------------------------------------------------------------- env knobs ---

def test_env_get_validates(monkeypatch):
    with pytest.raises(KeyError, match="undeclared env knob"):
        obsenv.get("REPRO_NOT_A_KNOB")
    monkeypatch.setenv("REPRO_QPIPELINE", "triple_buffer")
    with pytest.raises(ValueError, match="choices"):
        obsenv.get("REPRO_QPIPELINE")
    monkeypatch.setenv("REPRO_QPIPELINE", "double_buffer")
    assert obsenv.get("REPRO_QPIPELINE") == "double_buffer"
    monkeypatch.delenv("REPRO_QPIPELINE")
    assert obsenv.get("REPRO_QPIPELINE") is None
    monkeypatch.setenv("REPRO_OBS", "maybe")
    with pytest.raises(ValueError, match="not boolean"):
        obsenv.get_bool("REPRO_OBS")
    monkeypatch.setenv("REPRO_OBS", "yes")
    assert obsenv.get_bool("REPRO_OBS") is True
    monkeypatch.setenv("REPRO_OBS", "0")
    assert obsenv.get_bool("REPRO_OBS") is False


def test_env_legacy_alias_warns(monkeypatch):
    monkeypatch.delenv("REPRO_EXTRA_XLA", raising=False)
    monkeypatch.setenv("_REPRO_EXTRA_XLA", "--flag")
    with pytest.warns(DeprecationWarning, match="_REPRO_EXTRA_XLA"):
        assert obsenv.get("REPRO_EXTRA_XLA") == "--flag"
    # the canonical name wins over the legacy alias
    monkeypatch.setenv("REPRO_EXTRA_XLA", "--new")
    assert obsenv.get("REPRO_EXTRA_XLA") == "--new"


def test_env_warn_unknown(monkeypatch):
    monkeypatch.setenv("REPRO_TYPO_KNOB", "1")
    monkeypatch.setattr(obsenv, "_warned_unknown", False)
    with pytest.warns(UserWarning, match="REPRO_TYPO_KNOB"):
        assert "REPRO_TYPO_KNOB" in obsenv.warn_unknown()
    # second scan still reports, but silently
    assert "REPRO_TYPO_KNOB" in obsenv.warn_unknown()


def test_env_table_covers_every_knob():
    t = obsenv.table()
    for name in obsenv.KNOBS:
        assert f"`{name}`" in t
