"""Training loop + optimizer variants + checkpoint/restart fault tolerance
+ gradient compression (deliverables c, plus runtime features)."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_step, restore,
                                   save)
from repro.configs.base import ShapeConfig
from repro.configs.qwen2p5_3b import smoke_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.api import build
from repro.runtime.trainer import StragglerMonitor, Trainer, TrainerConfig
from repro.train.compress import compress_grads
from repro.train.optimizer import OptConfig
from repro.train.step import TrainStepConfig, make_train_fns


def _setup(state_bits=32, compress=32):
    cfg = smoke_config()
    model = build(cfg)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", 16, 2, "train")
    return cfg, model, make_train_fns(
        model, mesh, shape,
        TrainStepConfig(opt=OptConfig(lr=1e-3, warmup=2, total_steps=30,
                                      state_bits=state_bits),
                        grad_compress_bits=compress))


@pytest.mark.parametrize(
    "state_bits,compress",
    [(32, 32), pytest.param(8, 8, marks=pytest.mark.slow)])
def test_loss_decreases(state_bits, compress):
    cfg, model, (init_fn, step, _) = _setup(state_bits, compress)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    state = init_fn(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    losses = []
    for _ in range(8):
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_compress_grads_error_feedback():
    g = {"a": jnp.linspace(-1, 1, 1000).reshape(10, 100)}
    ef = {"a": jnp.zeros((10, 100), jnp.float32)}
    gq, ef2 = compress_grads(g, ef)
    # quantized + residual reconstructs the input exactly
    np.testing.assert_allclose(np.asarray(gq["a"]) + np.asarray(ef2["a"]),
                               np.asarray(g["a"]), atol=1e-6)
    # error is bounded by one int8 step of the block absmax
    assert float(jnp.max(jnp.abs(ef2["a"]))) <= 1.0 / 127 + 1e-6


def test_checkpoint_roundtrip_and_atomicity():
    tmp = tempfile.mkdtemp()
    try:
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
        save(tmp, 5, tree)
        got, step = restore(tmp)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(tree["a"]))
        # async + gc keeps newest `keep`
        ck = AsyncCheckpointer(tmp, keep=2)
        for s in (6, 7, 8):
            ck.save_async(s, tree)
            ck.wait()
        assert latest_step(tmp) == 8
        from repro.ckpt.checkpoint import list_steps
        assert len(list_steps(tmp)) <= 2
    finally:
        shutil.rmtree(tmp)


@pytest.mark.slow  # full Trainer run + resume; ckpt roundtrip stays fast
def test_trainer_restart_resume():
    cfg, model, (init_fn, step, _) = _setup()
    jstep = jax.jit(step)
    tmp = tempfile.mkdtemp()
    try:
        data = SyntheticLM(cfg.vocab, 2, 16, seed=1)
        tr = Trainer(init_fn, jstep, data, TrainerConfig(
            total_steps=12, ckpt_every=6, ckpt_dir=tmp))
        _, log = tr.run(jax.random.PRNGKey(0))
        assert log[-1]["step"] == 12
        data2 = SyntheticLM(cfg.vocab, 2, 16, seed=1)
        data2.seek(12)
        tr2 = Trainer(init_fn, jstep, data2, TrainerConfig(
            total_steps=18, ckpt_every=6, ckpt_dir=tmp))
        _, log2 = tr2.run(jax.random.PRNGKey(0))
        assert log2[0]["step"] == 13  # resumed, not restarted
    finally:
        shutil.rmtree(tmp)


def test_straggler_monitor():
    m = StragglerMonitor(factor=2.0)
    for _ in range(10):
        assert not m.record(0.1)
    assert m.record(0.5)        # 5x median -> flagged
    assert m.flags == 1
