"""Differential harness for the kernel software-pipeline modes.

The Mac&Load analogue ('double_buffer': packed operands stay in HBM, the
kernel owns two VMEM slots per operand and prefetches the next K tile /
receptive-field tap behind the current dot) must be a pure *scheduling*
change: for both ops, every (a_bits, w_bits) pair, every epilogue, and
ragged-edge grids,

    pipelined == non-pipelined == eager_ref   (bit-exact)

because both modes consume identical packed operands and accumulate in the
same int32 order. Also pins the resolution order (explicit arg -> plan
hint -> REPRO_QPIPELINE env -> tune-cache winner -> 'off') and that the
non-kernel backends accept-and-ignore the knob. Property tests fuzz the
geometry; they skip (not hard-fail) without hypothesis (conftest guard).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import hypothesis_api

given, settings, st = hypothesis_api()

from repro.core import packing
from repro.kernels import api, tune
from repro.kernels.common import PIPELINE_MODES, check_pipeline
from repro.kernels.qconv.kernel import qconv2d_fused
from repro.kernels.qmatmul.kernel import qmatmul_packed

from test_backend_api import _mk_acts, _mk_conv, _mk_qdot_params

BITS = (8, 4, 2)


def _qdot_all_modes(params, x, **kw):
    """api.qdot under every pipeline mode, first result == eager oracle."""
    want = np.asarray(api.qdot(params, x, backend="eager_ref", **kw))
    outs = {p: np.asarray(api.qdot(params, x, backend="pallas_interpret",
                                   pipeline=p, **kw))
            for p in PIPELINE_MODES}
    return want, outs


# ------------------------------------------------------ qdot: bit grid ---

@pytest.mark.parametrize("ab", BITS)
@pytest.mark.parametrize("wb", BITS)
def test_qdot_pipeline_parity_bit_grid(ab, wb, rng):
    params = _mk_qdot_params(rng, ab, wb)
    x = _mk_acts(rng, ab)
    want, outs = _qdot_all_modes(params, x)
    for p, got in outs.items():
        assert np.array_equal(got, want), (p, ab, wb)


@pytest.mark.parametrize("epilogue", ["int", "raw", "dequant"])
def test_qdot_pipeline_parity_epilogues(epilogue, rng):
    params = _mk_qdot_params(rng, 4, 2)
    x = _mk_acts(rng, 4)
    want, outs = _qdot_all_modes(params, x, epilogue=epilogue, scale=0.25)
    for p, got in outs.items():
        if epilogue == "dequant":
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       rtol=1e-2)
        else:
            assert np.array_equal(got, want), (p, epilogue)


@pytest.mark.parametrize("m,k,n,block", [
    (48, 512, 160, (16, 128, 256)),   # ragged M/N, nk=2
    (33, 384, 128, (32, 128, 128)),   # M pads 33 -> 64, nk=3
    (16, 256, 130, (16, 128, 256)),   # ragged N, single K tile
])
def test_qdot_pipeline_ragged_grid(m, k, n, block, rng):
    """Edge tiles (M/N padded to the block) and multi-tile K loops agree
    across modes — the db kernel's fori_loop + warm-up DMA owns the whole
    contraction, so nk > 1 exercises the slot rotation."""
    params = _mk_qdot_params(rng, 4, 4, K=k, N=n)
    x = _mk_acts(rng, 4, M=m, K=k)
    want, outs = _qdot_all_modes(params, x, block=block)
    for p, got in outs.items():
        assert got.shape == (m, n)
        assert np.array_equal(got, want), (p, m, k, n)


def test_qmatmul_packed_direct_db_vs_off(rng):
    """The kernel entry itself (no api padding): both modes bit-exact on
    an exactly-tiled shape with nk=4 slot rotations."""
    m, k, n = 32, 1024, 128
    params = _mk_qdot_params(rng, 2, 8, K=k, N=n)
    xp = packing.pack(_mk_acts(rng, 2, M=m, K=k), 2, axis=-1)
    kw = dict(a_bits=2, a_signed=False, w_bits=8, d=params.d,
              out_bits=params.out_bits, block=(32, 128, 256),
              interpret=True)
    off = qmatmul_packed(xp, params.w_packed, params.kappa, params.lam,
                         params.m, pipeline="off", **kw)
    db = qmatmul_packed(xp, params.w_packed, params.kappa, params.lam,
                        params.m, pipeline="double_buffer", **kw)
    assert np.array_equal(np.asarray(off), np.asarray(db))


# ------------------------------------------------------- qdot: ragged K ---

@pytest.mark.parametrize("k,bk", [
    (640, 256),   # 2 full K tiles + 128-row ragged tail
    (384, 256),   # 1 full + ragged
    (256, 512),   # K < bk: one tile, half of it zero padding
])
def test_qdot_ragged_k_block(k, bk, rng):
    """bk no longer has to divide K: the kernel zero-pads both packed
    operands to the next bk multiple (zero containers hold zero in every
    plane, so the extra MACs are exact no-ops in both pipeline modes)."""
    params = _mk_qdot_params(rng, 4, 4, K=k, N=128)
    x = _mk_acts(rng, 4, M=32, K=k)
    want, outs = _qdot_all_modes(params, x, block=(32, 128, bk))
    for p, got in outs.items():
        assert np.array_equal(got, want), (p, k, bk)


def test_qmatmul_packed_ragged_k_direct(rng):
    """Kernel entry itself: a ragged final K tile matches the divisor-bk
    result bit-for-bit, in both modes."""
    m, k, n = 32, 384, 128
    params = _mk_qdot_params(rng, 8, 2, K=k, N=n)
    xp = packing.pack(_mk_acts(rng, 8, M=m, K=k), 8, axis=-1)
    kw = dict(a_bits=8, a_signed=False, w_bits=2, d=params.d,
              out_bits=params.out_bits, interpret=True)
    want = np.asarray(qmatmul_packed(
        xp, params.w_packed, params.kappa, params.lam, params.m,
        block=(32, 128, 128), pipeline="off", **kw))
    for pipeline in PIPELINE_MODES:
        got = qmatmul_packed(xp, params.w_packed, params.kappa, params.lam,
                             params.m, block=(32, 128, 256),
                             pipeline=pipeline, **kw)
        assert np.array_equal(np.asarray(got), want), pipeline


def test_qdot_candidates_allow_ragged_bk():
    """The tune ladder no longer filters bk to divisors of K — a ragged
    final tile is legal — but never offers a bk that overshoots K by a
    whole tile."""
    cands = tune.qdot_candidates(64, 256, 1280, 8, 8)
    assert cands, "empty candidate ladder"
    assert any(1280 % bk for _, _, bk in cands), \
        "expected at least one non-divisor bk candidate"
    assert all(bk <= 1280 for _, _, bk in cands)
    # every bk the ladder offers must be a legal (CHUNK-aligned) tile —
    # halving 896 naively would give 448, which the kernel rejects
    for k in (384, 640, 896, 1280):
        for _, _, bk in tune.qdot_candidates(64, 256, k, 8, 8):
            assert bk % packing.CHUNK == 0, (k, bk)
    # K smaller than every tile: the CHUNK floor keeps the ladder alive
    small = tune.qdot_candidates(8, 128, 128, 8, 8)
    assert small and all(bk <= max(128, packing.CHUNK) for _, _, bk in small)


# ----------------------------------------------------- qconv: bit grid ---

@pytest.mark.parametrize("ab", BITS)
@pytest.mark.parametrize("wb", BITS)
def test_qconv_pipeline_parity_bit_grid(ab, wb, rng):
    qp, xq = _mk_conv(rng, ab, wb)
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    for p in PIPELINE_MODES:
        got = np.asarray(api.qconv(qp, xq, backend="pallas_interpret",
                                   pipeline=p))
        assert np.array_equal(got, want), (p, ab, wb)


@pytest.mark.parametrize("epilogue", ["int", "raw", "dequant"])
def test_qconv_pipeline_parity_epilogues(epilogue, rng):
    """'int' checks against the eager oracle; 'raw'/'dequant' (which
    eager_ref does not implement for qconv) pin db == off bit-exact —
    the scheduling-only claim."""
    qp, xq = _mk_conv(rng, 4, 4)
    if epilogue == "int":
        want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    else:
        want = np.asarray(api.qconv(qp, xq, epilogue=epilogue, scale=0.25,
                                    backend="pallas_interpret",
                                    pipeline="off"), np.float32)
    for p in PIPELINE_MODES:
        kw = {} if epilogue == "int" else {"epilogue": epilogue,
                                           "scale": 0.25}
        got = np.asarray(api.qconv(qp, xq, backend="pallas_interpret",
                                   pipeline=p, **kw))
        if epilogue != "int":
            got = np.asarray(got, np.float32)
        assert np.array_equal(got, want), (p, epilogue)


@pytest.mark.parametrize("H,W,F,stride,pad", [
    (7, 5, 3, 1, 1),     # ragged Ho vs bho tiles
    (9, 9, 3, 2, 1),     # strided tap gather
    (8, 8, 1, 1, 0),     # 1x1: single tap, no halo
    (11, 11, 5, 1, 2),   # 5x5: 25 tap DMAs per tile
])
def test_qconv_pipeline_ragged_geometry(H, W, F, stride, pad, rng):
    """Tap-loop prefetch across awkward geometries: every tap's strided
    VMEM slice and its halo rows come from the HBM image identically in
    both modes."""
    qp, xq = _mk_conv(rng, 4, 4, H=H, W=W)
    # rebuild with the target filter geometry
    from repro.core import QuantSpec, calibrate_activation, calibrate_weight
    from repro.core.quantize import quantize
    from repro.kernels.qconv import quantize_conv
    cin, cout = 24, 40
    x = np.maximum(rng.normal(size=(2, H, W, cin)), 0).astype(np.float32)
    w = rng.normal(size=(F, F, cin, cout)).astype(np.float32) * 0.08
    sw = calibrate_weight(jnp.asarray(w), 4)
    sx = calibrate_activation(x, 4, 100.0)
    qp = quantize_conv(jnp.asarray(w), sw,
                       rng.normal(size=(cout,)).astype(np.float32) * .05 + .3,
                       np.zeros((cout,), np.float32), sx,
                       QuantSpec.activation(4, 8.0), stride, pad)
    xq = quantize(jnp.asarray(x), sx)
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    for p in PIPELINE_MODES:
        got = np.asarray(api.qconv(qp, xq, backend="pallas_interpret",
                                   pipeline=p))
        assert np.array_equal(got, want), (p, H, W, F, stride, pad)


# ----------------------------------------------------------- resolution ---

def test_pipeline_env_resolution(rng, monkeypatch):
    """REPRO_QPIPELINE selects the mode when no explicit arg/hint is
    given; a bogus value fails loudly at the call site."""
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    monkeypatch.setenv(api.ENV_PIPELINE, "double_buffer")
    got = np.asarray(api.qdot(params, x, backend="pallas_interpret"))
    assert np.array_equal(got, want)
    monkeypatch.setenv(api.ENV_PIPELINE, "bogus")
    # the env-knob registry (repro.obs.env) rejects the value before the
    # pipeline layer even sees it — still a loud ValueError at the call
    with pytest.raises(ValueError, match="not a valid value"):
        api.qdot(params, x, backend="pallas_interpret")


def test_pipeline_plan_hints_and_explicit_precedence(rng, monkeypatch):
    """Explicit arg beats the plan hint; the plan hint beats the env."""
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    monkeypatch.setenv(api.ENV_PIPELINE, "bogus")  # must never be reached
    got = np.asarray(api.qdot(params, x, backend="pallas_interpret",
                              plan_hints={"pipeline": "double_buffer"}))
    assert np.array_equal(got, want)
    got = np.asarray(api.qdot(params, x, backend="pallas_interpret",
                              pipeline="off",
                              plan_hints={"pipeline": "bogus"}))
    assert np.array_equal(got, want)


def test_pipeline_tune_cache_resolution(rng):
    """With no arg/hint/env, the measured tune-cache winner is used (and
    produces the same bits as 'off')."""
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    tune.clear()
    try:
        tune.record_block("qdot", (16, 256, 128), 4, 4, "pallas_interpret",
                          (16, 128, 256), pipeline="double_buffer")
        got = np.asarray(api.qdot(params, x, backend="pallas_interpret"))
        assert np.array_equal(got, want)
    finally:
        tune.clear()


def test_non_kernel_backends_ignore_pipeline(rng):
    """xla/eager_ref have no pipeline concept: the knob is accepted and
    ignored (plans can set it globally without forking per backend)."""
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    for name in ("xla", "eager_ref"):
        got = np.asarray(api.qdot(params, x, backend=name,
                                  pipeline="double_buffer"))
        assert np.array_equal(got, want), name


def test_check_pipeline_rejects_unknown():
    assert check_pipeline("off") == "off"
    assert check_pipeline("double_buffer") == "double_buffer"
    with pytest.raises(ValueError, match="unknown pipeline mode"):
        check_pipeline("triple_buffer")


# ------------------------------------------------------- property fuzz ---

@given(m=st.integers(1, 40), nk=st.integers(1, 4),
       n=st.integers(100, 200),
       ab=st.sampled_from(BITS), wb=st.sampled_from(BITS))
@settings(max_examples=15, deadline=None)
def test_qdot_pipeline_parity_fuzz(m, nk, n, ab, wb):
    rng = np.random.default_rng(m * 1000 + nk * 100 + n + ab * 10 + wb)
    k = nk * packing.CHUNK
    params = _mk_qdot_params(rng, ab, wb, K=k, N=n)
    x = _mk_acts(rng, ab, M=m, K=k)
    want, outs = _qdot_all_modes(params, x,
                                 block=(32, 128, packing.CHUNK))
    for p, got in outs.items():
        assert np.array_equal(got, want), (p, m, k, n, ab, wb)


@given(h=st.integers(4, 12), w=st.integers(4, 12),
       f=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]),
       ab=st.sampled_from(BITS), wb=st.sampled_from(BITS))
@settings(max_examples=10, deadline=None)
def test_qconv_pipeline_parity_fuzz(h, w, f, stride, ab, wb):
    rng = np.random.default_rng(h * 100 + w * 10 + f + stride + ab + wb)
    from repro.core import QuantSpec, calibrate_activation, calibrate_weight
    from repro.core.quantize import quantize
    from repro.kernels.qconv import quantize_conv
    cin, cout = 16, 32
    x = np.maximum(rng.normal(size=(1, h, w, cin)), 0).astype(np.float32)
    wgt = rng.normal(size=(f, f, cin, cout)).astype(np.float32) * 0.1
    sx = calibrate_activation(x, ab, 100.0)
    qp = quantize_conv(jnp.asarray(wgt), calibrate_weight(jnp.asarray(wgt), wb),
                       np.full((cout,), 0.3, np.float32),
                       np.zeros((cout,), np.float32), sx,
                       QuantSpec.activation(ab, 8.0), stride, f // 2)
    xq = quantize(jnp.asarray(x), sx)
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    for p in PIPELINE_MODES:
        got = np.asarray(api.qconv(qp, xq, backend="pallas_interpret",
                                   pipeline=p))
        assert np.array_equal(got, want), (p, h, w, f, stride, ab, wb)
