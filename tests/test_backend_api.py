"""The unified quantized-op backend API (`repro.kernels.api`).

* backend-parity suite: every registered backend per op agrees bit-exactly
  with `eager_ref` across the {8,4,2}^2 bit grid x epilogues;
* registry negative tests: unknown backends raise with the available list,
  supports=False backends are skipped in default resolution;
* resolution order: explicit arg -> REPRO_QBACKEND env -> capability
  default (xla on CPU — the real `pallas` backend asserts a TPU platform);
* deprecation shims: `use_kernel`/`interpret` kwargs, `QuantConfig`, plan
  schema v1 JSON (single warning, correct backend mapping, v2 re-save);
* `_int_matmul`-vs-`xla_int_gemm` dedupe regression (old inline
  implementation pinned here) for the W{8,4,2}A{8,4,2} grid;
* the autotune block cache: JSON round-trip, api lookup, env preload.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantSpec, calibrate_activation, calibrate_weight,
                        packing, quantize)
from repro.core.quantize import QuantizedLinearParams
from repro.kernels import api, tune
from repro.kernels.qconv import quantize_conv, qconv2d_apply
from repro.kernels.qmatmul import qlinear_apply
from repro.nn.layers import QuantConfig, dense_apply, pack_dense_weights

BITS = (8, 4, 2)


# ------------------------------------------------------------- fixtures ---

def _mk_qdot_params(rng, a_bits, w_bits, K=256, N=128):
    lo, hi = packing.int_range(w_bits, True)
    w = rng.integers(lo, hi + 1, size=(K, N)).astype(np.int8)
    wp = packing.pack(jnp.asarray(w), w_bits, axis=0)
    return QuantizedLinearParams(
        w_packed=wp, w_bits=w_bits, a_bits=a_bits, a_signed=False,
        kappa=jnp.asarray(rng.integers(-64, 64, (N,)).astype(np.int32)),
        lam=jnp.asarray(rng.integers(-2**16, 2**16, (N,)).astype(np.int32)),
        m=jnp.asarray(rng.integers(0, 2**15, (N,)).astype(np.int32)),
        d=18, out_bits=8, k_logical=K)


def _mk_acts(rng, a_bits, M=16, K=256):
    lo, hi = packing.int_range(a_bits, False)
    return jnp.asarray(rng.integers(lo, hi + 1, (M, K)).astype(np.int8))


def _mk_conv(rng, a_bits, w_bits, H=8, W=8, cin=24, cout=40):
    x = np.maximum(rng.normal(size=(1, H, W, cin)), 0).astype(np.float32)
    w = rng.normal(size=(3, 3, cin, cout)).astype(np.float32) * 0.08
    sw = calibrate_weight(jnp.asarray(w), w_bits)
    sx = calibrate_activation(x, a_bits, 100.0)
    sy = QuantSpec.activation(a_bits, 8.0)
    qp = quantize_conv(jnp.asarray(w), sw,
                       rng.normal(size=(cout,)).astype(np.float32) * .05 + .3,
                       np.zeros((cout,), np.float32), sx, sy, 1, 1)
    return qp, quantize(jnp.asarray(x), sx)


def _supported(op, shape, a_bits, w_bits):
    plat = api.platform()
    return [n for n in api.backends(op)
            if api.get(op, n).supports(shape, a_bits, w_bits, plat)]


# --------------------------------------------------------- parity: qdot ---

@pytest.mark.parametrize("ab", BITS)
@pytest.mark.parametrize("wb", BITS)
def test_qdot_backend_parity_int(ab, wb, rng):
    """Every runnable backend == eager_ref, bit-exact, per bit pair."""
    params = _mk_qdot_params(rng, ab, wb)
    x = _mk_acts(rng, ab)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    names = _supported("qdot", (16, 256, 128), ab, wb)
    assert "xla" in names and "pallas_interpret" in names
    for name in names:
        got = np.asarray(api.qdot(params, x, backend=name))
        assert np.array_equal(got, want), (name, ab, wb)


@pytest.mark.parametrize("epilogue", ["int", "raw", "dequant"])
def test_qdot_backend_parity_epilogues(epilogue, rng):
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    want = np.asarray(api.qdot(params, x, epilogue=epilogue, scale=0.25,
                               backend="eager_ref"), np.float32)
    for name in _supported("qdot", (16, 256, 128), 4, 4):
        got = np.asarray(api.qdot(params, x, epilogue=epilogue, scale=0.25,
                                  backend=name), np.float32)
        if epilogue == "dequant":
            np.testing.assert_allclose(got, want, rtol=1e-2)
        else:
            assert np.array_equal(got, want), (name, epilogue)


# -------------------------------------------------------- parity: qconv ---

@pytest.mark.parametrize("ab", BITS)
@pytest.mark.parametrize("wb", BITS)
def test_qconv_backend_parity(ab, wb, rng):
    qp, xq = _mk_conv(rng, ab, wb)
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    shape = api._conv_shape(qp, xq)
    names = _supported("qconv", shape, ab, wb)
    assert {"xla", "pallas_interpret"} <= set(names)
    for name in names:
        got = np.asarray(api.qconv(qp, xq, backend=name))
        assert np.array_equal(got, want), (name, ab, wb)


# ------------------------------------------------------------- registry ---

def test_unknown_backend_raises_with_available_list():
    with pytest.raises(KeyError, match="available.*eager_ref"):
        api.get("qdot", "mosaic_gpu")
    params = _mk_qdot_params(np.random.default_rng(0), 8, 8)
    with pytest.raises(KeyError, match="no backend 'nope'"):
        api.qdot_packed(params, _mk_acts(np.random.default_rng(0), 8),
                        backend="nope")
    with pytest.raises(ValueError, match="unknown op"):
        api.register("qpool", "xla", supports=lambda *a: True, run=None)


def test_grouped_conv_rejected_cleanly(rng, monkeypatch):
    """Grouped/depthwise params: no registered qconv backend claims
    support, default resolution raises, and an explicit backend raises
    (instead of silently mis-shaping the ungrouped contraction)."""
    import dataclasses

    monkeypatch.delenv(api.ENV_VAR, raising=False)
    qp, xq = _mk_conv(rng, 8, 8)
    grouped = dataclasses.replace(qp, groups=2)
    shape = api._conv_shape(grouped, xq)
    assert api.conv_shape_groups(shape) == 2
    plat = api.platform()
    for name in api.backends("qconv"):
        assert not api.get("qconv", name).supports(shape, 8, 8, plat), name
    with pytest.raises(RuntimeError, match="no default backend supports"):
        api.qconv(grouped, xq)
    with pytest.raises(ValueError, match="grouped conv"):
        api.qconv(grouped, xq, backend="xla")
    with pytest.raises(ValueError, match="grouped conv"):
        api.qconv(grouped, xq, backend="pallas_interpret")
    # ungrouped params still resolve exactly as before (9- and 10-tuple
    # shape keys are both accepted by the supports helpers)
    assert api.conv_shape_groups(shape[:9]) == 1
    got = np.asarray(api.qconv(qp, xq, backend="xla"))
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    assert np.array_equal(got, want)


def test_grouped_conv_rejected_under_mesh(rng):
    import dataclasses
    import jax

    qp, xq = _mk_conv(rng, 8, 8)
    grouped = dataclasses.replace(qp, groups=2)
    mesh = jax.make_mesh((2, 1), ("data", "model"),
                         devices=jax.devices()[:2])
    with pytest.raises((RuntimeError, ValueError),
                       match="grouped conv|no default backend supports"):
        api.qconv(grouped, xq, mesh=mesh, backend="xla")


def test_default_resolution_skips_unsupported(monkeypatch):
    """supports=False backends are skipped; the capability order falls
    through to the first supporting backend."""
    monkeypatch.delenv(api.ENV_VAR, raising=False)
    api.register("qdot", "_test_never", supports=lambda *a: False, run=None)
    try:
        monkeypatch.setattr(api, "DEFAULT_ORDER", ("_test_never", "xla"))
        spec = api.resolve("qdot", (16, 256, 128), 8, 8)
        assert spec.name == "xla"
    finally:
        api._REGISTRY.pop(("qdot", "_test_never"))


def test_default_resolution_on_cpu_is_xla(monkeypatch):
    if api.platform() == "tpu":
        pytest.skip("CPU-only assertion")
    monkeypatch.delenv(api.ENV_VAR, raising=False)
    # pallas is first in capability order but requires TPU
    assert api.DEFAULT_ORDER[0] == "pallas"
    assert api.resolve("qdot", (16, 256, 128), 8, 8).name == "xla"
    assert api.default_backend("qconv") == "xla"


def test_pallas_backend_asserts_real_tpu(rng):
    if api.platform() == "tpu":
        pytest.skip("CPU-only assertion")
    params = _mk_qdot_params(rng, 8, 8)
    with pytest.raises(RuntimeError, match="requires a real TPU"):
        api.qdot_packed(params, _mk_acts(rng, 8), backend="pallas")
    qp, xq = _mk_conv(rng, 4, 4)
    with pytest.raises(RuntimeError, match="requires a real TPU"):
        api.qconv(qp, xq, backend="pallas")


def test_env_override(monkeypatch, rng):
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    base = np.asarray(api.qdot(params, x))
    monkeypatch.setenv(api.ENV_VAR, "eager_ref")
    spec = api.resolve("qdot", (16, 256, 128), 4, 4)
    assert spec.name == "eager_ref"
    assert np.array_equal(np.asarray(api.qdot(params, x)), base)
    monkeypatch.setenv(api.ENV_VAR, "not_a_backend")
    with pytest.raises(KeyError, match="not_a_backend"):
        api.qdot(params, x)
    # explicit argument beats the env override
    monkeypatch.setenv(api.ENV_VAR, "eager_ref")
    assert api.resolve("qdot", (16, 256, 128), 4, 4,
                       backend="xla").name == "xla"


def test_registry_table_covers_both_ops():
    rows = api.registry_table()
    assert {(op, b) for op, b, _ in rows} >= {
        (op, b) for op in ("qdot", "qconv")
        for b in ("pallas", "pallas_interpret", "xla", "eager_ref")}


# ---------------------------------------------------- deprecation shims ---

def test_qlinear_apply_use_kernel_shim(rng):
    K, N, M = 288, 64, 50
    w = rng.normal(size=(K, N)).astype(np.float32) * 0.05
    x = np.maximum(rng.normal(size=(M, K)), 0).astype(np.float32) * 0.5
    from repro.core import quantize_linear
    sw = calibrate_weight(jnp.asarray(w), 4)
    sx = calibrate_activation(x, 4, 100.0)
    sy = calibrate_activation(np.maximum(x @ w, 0), 4, 100.0)
    qp = quantize_linear(jnp.asarray(w), sw,
                         np.ones((N,), np.float32),
                         np.zeros((N,), np.float32), sx, sy)
    xq = quantize(jnp.asarray(x), sx)
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        y_old = qlinear_apply(qp, xq, use_kernel=True)
    y_new = api.qdot(qp, xq, backend="pallas_interpret")
    assert np.array_equal(np.asarray(y_old), np.asarray(y_new))
    with pytest.warns(DeprecationWarning):
        y_xla = qlinear_apply(qp, xq, use_kernel=False)
    assert np.array_equal(np.asarray(y_xla),
                          np.asarray(api.qdot(qp, xq, backend="xla")))
    with pytest.raises(ValueError, match="not both"):
        qlinear_apply(qp, xq, backend="xla", use_kernel=True)


def test_qconv2d_apply_use_kernel_shim(rng):
    qp, xq = _mk_conv(rng, 4, 4)
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        y_old = qconv2d_apply(qp, xq, use_kernel=True)
    y_new = api.qconv(qp, xq, backend="pallas_interpret")
    assert np.array_equal(np.asarray(y_old), np.asarray(y_new))


def test_quantconfig_use_kernel_shim():
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        cfg = QuantConfig(mode="int", use_kernel=True)
    assert cfg.backend == "pallas_interpret" and cfg.use_kernel is None
    with pytest.warns(DeprecationWarning):
        cfg = QuantConfig(mode="int", use_kernel=False)
    assert cfg.backend == "xla"
    # new field + deprecated boolean together is contradictory — same
    # policy as the qlinear_apply/qconv2d_apply kwarg shims
    with pytest.raises(ValueError, match="not both"):
        QuantConfig(mode="int", backend="eager_ref", use_kernel=True)
    from repro.deploy.policy import PlanRule
    with pytest.raises(ValueError, match="not both"):
        PlanRule("layers/*", 4, backend="xla", use_kernel=True)
    # normalized shim keeps configs hashable/comparable
    import dataclasses
    assert dataclasses.replace(QuantConfig(backend="xla"), w_bits=4) == \
        QuantConfig(w_bits=4, backend="xla")


OLD_PLAN_JSON = json.dumps({
    "version": 1,
    "default": {"w_bits": 8, "a_bits": 8},
    "rules": [
        {"pattern": "layers/mlp/*", "w_bits": 4, "a_bits": 8,
         "use_kernel": True, "a_absmax": 2.5},
        {"pattern": "layers/attn/*", "w_bits": 2, "a_bits": 8,
         "use_kernel": False, "a_absmax": None},
    ],
    "meta": {"arch": "qwen-smoke"},
})


def test_old_plan_json_single_warning_and_backend_mapping(tmp_path):
    from repro.deploy.policy import (PLAN_VERSION, PrecisionPlan, load_plan,
                                     save_plan)
    with pytest.warns(DeprecationWarning, match="schema-v1") as rec:
        plan = PrecisionPlan.from_json(OLD_PLAN_JSON)
    assert len([w for w in rec if issubclass(
        w.category, DeprecationWarning)]) == 1   # one per artifact
    by_pat = {r.pattern: r for r in plan.rules}
    assert by_pat["layers/mlp/*"].backend == "pallas_interpret"
    assert by_pat["layers/attn/*"].backend == "xla"  # explicit pin kept
    assert by_pat["layers/mlp/*"].w_bits == 4      # not dropped
    # re-save upgrades the artifact: v4, backend field, no use_kernel
    f = tmp_path / "plan.json"
    save_plan(plan, f)
    d = json.loads(f.read_text())
    assert d["version"] == PLAN_VERSION == 4
    assert all("use_kernel" not in r for r in d["rules"])
    assert d["rules"][0]["backend"] == "pallas_interpret"
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # round-trip is clean
        again = load_plan(f)
    assert again == plan


def test_plan_resolve_carries_backend():
    from repro.deploy.policy import PlanRule, PrecisionPlan
    plan = PrecisionPlan(rules=(
        PlanRule("layers/mlp/*", 4, backend="xla"),
        PlanRule("layers/attn/*", 8),
    ))
    base = QuantConfig(mode="int", backend="pallas_interpret")
    assert plan.resolve("layers/mlp/wi", base).backend == "xla"
    # rule without backend inherits the base config's
    assert plan.resolve("layers/attn/wq", base).backend == \
        "pallas_interpret"


def test_unsupported_plan_version_raises():
    from repro.deploy.policy import PrecisionPlan
    with pytest.raises(ValueError, match="unsupported plan version"):
        PrecisionPlan.from_json(json.dumps({"version": 99, "rules": []}))


# -------------------------------------------- _int_matmul dedupe pinned ---

def _old_int_matmul(p, x, qcfg):
    """The pre-registry nn/layers implementation, pinned verbatim as the
    regression oracle for the shared xla_int_gemm path."""
    absmax = qcfg.a_absmax or 4.0
    a_max = packing.int_range(qcfg.a_bits, True)[1]
    a_scale = absmax / a_max
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / a_scale), -a_max,
                   a_max).astype(jnp.int8)
    x_q = packing.pad_to_chunk(x_q, axis=-1)
    w_int = packing.unpack(p["w_packed"], qcfg.w_bits, True, axis=0)
    acc = jax.lax.dot_general(
        x_q, w_int, (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    scale = (p["w_scale"] * a_scale).astype(jnp.float32)
    return (acc.astype(jnp.float32) * scale).astype(x.dtype)


@pytest.mark.parametrize("wb", BITS)
@pytest.mark.parametrize("ab", BITS)
def test_dense_int_matmul_matches_old_implementation(ab, wb, rng):
    w = (rng.normal(size=(96, 48)) * 0.1).astype(np.float32)
    x = rng.normal(size=(4, 96)).astype(np.float32)
    packed, scale = pack_dense_weights(jnp.asarray(w), wb)
    p = {"w_packed": packed, "w_scale": scale}
    qcfg = QuantConfig(mode="int", w_bits=wb, a_bits=ab, a_absmax=4.0)
    got = np.asarray(dense_apply(p, jnp.asarray(x), qcfg=qcfg))
    want = np.asarray(_old_int_matmul(p, jnp.asarray(x), qcfg))
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------- tune cache ---

def test_tune_cache_roundtrip(tmp_path):
    tune.clear()
    try:
        tune.record_block("qdot", (64, 256, 256), 4, 4,
                          "pallas_interpret", (32, 128, 128))
        assert tune.get_block("qdot", (64, 256, 256), 4, 4,
                              "pallas_interpret") == (32, 128, 128)
        assert tune.get_block("qdot", (64, 256, 256), 4, 2,
                              "pallas_interpret") is None
        f = tmp_path / "tune.json"
        tune.save(f)
        tune.clear()
        assert tune.get_block("qdot", (64, 256, 256), 4, 4,
                              "pallas_interpret") is None
        tune.merge(tune.load(f))
        assert tune.get_block("qdot", (64, 256, 256), 4, 4,
                              "pallas_interpret") == (32, 128, 128)
        with pytest.raises(ValueError, match="version"):
            tune.TuneCache.from_json('{"version": 42}')
    finally:
        tune.clear()


def test_qdot_uses_cached_block_and_stays_bit_exact(rng):
    """A cached (valid, non-default) block is consumed by api.qdot and the
    result stays bit-exact vs eager_ref."""
    params = _mk_qdot_params(rng, 4, 4, K=512, N=256)
    x = _mk_acts(rng, 4, M=64, K=512)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    tune.clear()
    try:
        tune.record_block("qdot", (64, 512, 256), 4, 4,
                          "pallas_interpret", (32, 128, 256))
        got = np.asarray(api.qdot(params, x, backend="pallas_interpret"))
        assert np.array_equal(got, want)
    finally:
        tune.clear()


@pytest.mark.slow
def test_autotune_qdot_records_best_block(rng):
    tune.clear()
    try:
        params = _mk_qdot_params(rng, 4, 4)
        x2 = packing.pack(_mk_acts(rng, 4, M=32), 4, axis=-1)
        blk, pipe = tune.autotune_qdot(params, x2,
                                       backend="pallas_interpret", iters=1)
        assert tune.get_block("qdot", (32, 256, 128), 4, 4,
                              "pallas_interpret") == blk
        assert tune.get_pipeline("qdot", (32, 256, 128), 4, 4,
                                 "pallas_interpret") == pipe
    finally:
        tune.clear()
