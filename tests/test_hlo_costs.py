"""HLO cost walker: trip-count multiplication and dot flops parsing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_costs import analyze, parse_module


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_dot_flops_exact():
    M, K, N = 64, 128, 32

    def f(a, b):
        return a @ b

    c = _compile(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    mc = analyze(c.as_text())
    assert mc.flops == 2 * M * K * N


def test_scan_trip_count_multiplies():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((8, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mc = analyze(c.as_text())
    assert mc.flops == 7 * 2 * 8 * 64 * 64


def test_parse_module_structure():
    def f(x):
        return x * 2 + 1

    c = _compile(f, jax.ShapeDtypeStruct((16,), jnp.float32))
    comps = parse_module(c.as_text())
    assert any(comp.is_entry for comp in comps.values())
