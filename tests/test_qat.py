"""QAT subsystem: STE gradients, grid bit-exactness, fold losslessness,
task-loss calibration, dataset hermeticity, and the train->deploy loop.

The subsystem's load-bearing invariant is **grid matching**: the fake
quantizers in `repro.qat.fakequant` must land on exactly the grids the
deployment path (`core.quantize.QuantSpec` via `calibrate_weight` /
`quantize_dense_weights`) packs — otherwise "QAT" trains a model for an
arithmetic that never ships. Every numeric test here compares against
the deployment helpers, never against a reimplementation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.calibration import calibrate_weight
from repro.core.quantize import QuantSpec, dequantize, quantize
from repro.nn.layers import quantize_dense_weights
from repro.qat import fakequant as fq
from repro.qat.data import SyntheticDigits, make_dataset
from repro.qat.evaluate import (deploy, edge_agreement, evaluate_fq,
                                evaluate_int, fold_check)
from repro.qat.train import (QATConfig, resolve_layer_quant, train_qat)
from repro.vision.configs import get_vision_config

BITS = (8, 4, 2)


# ------------------------------------------------------------- STE ------

def test_ste_forward_matches_integer_grid(rng):
    t = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    eps = jnp.float32(0.037)
    got = fq.ste_quantize(t, eps, -7, 7)
    want = jnp.clip(jnp.round(t / eps), -7, 7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ste_gradient_is_masked_identity(rng):
    """d/dt [eps * ste(t)] == 1 inside [lo*eps, hi*eps], 0 outside —
    the straight-through contract, checked point by point."""
    eps = 0.1
    t = jnp.asarray(np.linspace(-1.5, 1.5, 61).astype(np.float32))
    g = jax.grad(lambda v: jnp.sum(eps * fq.ste_quantize(v, eps, -7, 7)))(t)
    inside = (np.asarray(t) >= -7 * eps) & (np.asarray(t) <= 7 * eps)
    np.testing.assert_array_equal(np.asarray(g), inside.astype(np.float32))


def test_ste_gradient_vs_finite_difference_of_surrogate(rng):
    """The STE backward equals the finite difference of the *clip
    surrogate* f(t) = clip(t, lo*eps, hi*eps) — the function STE
    pretends the quantizer is. FD of the true staircase would be 0 or
    spikes; the surrogate is what the gradient must track."""
    eps = 0.25
    t = np.asarray(rng.normal(size=(41,)), np.float32)
    # keep probe points away from surrogate kinks and staircase steps
    t = t[np.abs(np.abs(t) - 7 * eps) > 0.05]
    g = jax.grad(
        lambda v: jnp.sum(eps * fq.ste_quantize(v, eps, -7, 7)))(
            jnp.asarray(t))
    h = 1e-3
    fd = (np.clip(t + h, -7 * eps, 7 * eps)
          - np.clip(t - h, -7 * eps, 7 * eps)) / (2 * h)
    np.testing.assert_allclose(np.asarray(g), fd, atol=1e-4)


def test_ste_eps_gets_zero_gradient(rng):
    t = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    g = jax.grad(lambda e: jnp.sum(fq.ste_quantize(t, e, -7, 7)))(
        jnp.float32(0.1))
    assert float(g) == 0.0


# ------------------------------------------- grid bit-exactness ---------

@pytest.mark.parametrize("bits", BITS)
def test_weight_fake_quant_matches_deployed_grid(rng, bits):
    """fake_quant_weight == dequantize(quantize(w, calibrate_weight(w)))
    bit-exact — the per-tensor vision grid."""
    w = jnp.asarray(rng.normal(size=(3, 3, 8, 16)).astype(np.float32))
    got = fq.fake_quant_weight(w, bits)
    spec = calibrate_weight(w, bits)
    want = dequantize(quantize(w, spec), spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", BITS)
def test_weight_fake_quant_per_channel_matches_lm_grid(rng, bits):
    """Per-channel fake-quant vs `quantize_dense_weights` codes, on 2-D
    (K, N) weights (where the two absmax reductions coincide)."""
    w = jnp.asarray(rng.normal(size=(32, 12)).astype(np.float32))
    fq_w = fq.fake_quant_weight(w, bits, per_channel=True)
    codes, scale = quantize_dense_weights(w, bits)
    np.testing.assert_array_equal(
        np.asarray(fq_w),
        np.asarray(codes.astype(jnp.float32) * scale))


@pytest.mark.parametrize("bits", BITS)
def test_act_fake_quant_matches_activation_spec(rng, bits):
    """fake_quant_act lands on QuantSpec.activation's unsigned grid."""
    beta = 1.7
    x = jnp.asarray(rng.uniform(-0.5, 2.5, size=(128,)).astype(np.float32))
    got = fq.fake_quant_act(x, jnp.float32(beta), bits)
    spec = QuantSpec.activation(bits, beta)
    want = dequantize(quantize(x, spec), spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segmented_fake_quant_is_per_run_uniform(rng):
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    runs = ((0, 5, 4), (5, 8, 2))
    got = fq.fake_quant_weight_segmented(w, runs)
    for s, e, b in runs:
        np.testing.assert_array_equal(
            np.asarray(got[..., s:e]),
            np.asarray(fq.fake_quant_weight(w[..., s:e], b)))


def test_weight_absmax_floor_and_stop_gradient():
    z = jnp.zeros((4, 4))
    assert float(fq.weight_absmax(z)) == np.float32(fq.WEIGHT_ABSMAX_FLOOR)
    g = jax.grad(lambda w: jnp.sum(fq.fake_quant_weight(w, 8)))(
        jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                    jnp.float32))
    assert np.isfinite(np.asarray(g)).all()


def test_ema_update_snaps_then_blends():
    prev = jnp.float32(0.0)
    first = fq.ema_update(prev, jnp.float32(2.0), 0.9)
    assert float(first) == 2.0          # zero-init snaps to observation
    second = fq.ema_update(first, jnp.float32(1.0), 0.9)
    np.testing.assert_allclose(float(second), 0.9 * 2.0 + 0.1 * 1.0,
                               rtol=1e-6)


# ------------------------------------------------ dataset hermeticity ---

def test_synthetic_digits_replay_byte_identical():
    d = SyntheticDigits(split="train", seed=3)
    a = list(d.batches(16, 3))
    b = list(d.batches(16, 3))       # same object, fresh generator
    c = list(SyntheticDigits(split="train", seed=3).batches(16, 3))
    for (xa, ya), (xb, yb), (xc, yc) in zip(a, b, c):
        assert xa.tobytes() == xb.tobytes() == xc.tobytes()
        assert ya.tobytes() == yb.tobytes() == yc.tobytes()


def test_synthetic_digits_splits_and_seeds_differ():
    base = next(SyntheticDigits(split="train", seed=0).batches(16, 1))
    other_split = next(SyntheticDigits(split="test", seed=0).batches(16, 1))
    other_seed = next(SyntheticDigits(split="train", seed=1).batches(16, 1))
    assert base[0].tobytes() != other_split[0].tobytes()
    assert base[0].tobytes() != other_seed[0].tobytes()


def test_make_dataset_dispatch():
    d = make_dataset("synthetic", split="train", seed=0)
    x, y = next(d.batches(4, 1))
    assert x.shape == (4, 16, 16, 1) and x.dtype == np.float32
    assert y.shape == (4,) and x.min() >= 0.0 and x.max() <= 1.0
    with pytest.raises(KeyError):
        make_dataset("imagenet", split="train", seed=0)


# ------------------------------------------- task-loss calibration ------

def _trained_smoke(steps=60, w_bits=4):
    cfg = get_vision_config("qat-cnn", smoke=True)
    data = make_dataset("synthetic", split="train", seed=0)
    qc = QATConfig(steps=steps, batch=32, w_bits=w_bits, warmup=5,
                   log_every=max(steps // 2, 1), seed=0)
    return cfg, data, train_qat(cfg, data, qc)


def test_task_loss_calibration_deterministic_and_structured():
    from repro.deploy.calibrate import calibrate_vision

    cfg, data, res = _trained_smoke(steps=30)
    xs, ys = [], []
    for x, y in data.batches(16, 2):
        xs.append(np.asarray(x))
        ys.append(np.asarray(y))
    s1, a1 = calibrate_vision(cfg, res.model_params(), xs,
                              sensitivity="task_loss", labels=ys)
    s2, a2 = calibrate_vision(cfg, res.model_params(), xs,
                              sensitivity="task_loss", labels=ys)
    assert a1 == a2
    for p in s1:
        assert s1[p].sq_ref == 1.0
        for b in BITS:
            assert s1[p].sq_err[b] == s2[p].sq_err[b]       # exact replay
            np.testing.assert_array_equal(s1[p].col_sq_err[b],
                                          s2[p].col_sq_err[b])
            # group sensitivities apportion the layer sensitivity
            np.testing.assert_allclose(float(s1[p].col_sq_err[b].sum()),
                                       s1[p].sq_err[b], rtol=1e-6)
    # task_loss requires labels, and rejects unknown modes
    with pytest.raises(ValueError):
        calibrate_vision(cfg, res.model_params(), xs,
                         sensitivity="task_loss")
    with pytest.raises(ValueError):
        calibrate_vision(cfg, res.model_params(), xs, sensitivity="huh")


# --------------------------------------------- train -> deploy loop -----

def test_qat_smoke_loss_decreases_and_folds():
    """Tier-1 gate: 60 fake-quant steps reduce the loss, the trained
    weights fold bit-exact, and the integer path agrees with training."""
    cfg, data, res = _trained_smoke(steps=60)
    assert res.log[-1]["loss"] < res.log[0]["loss"]
    fold_check(res)                                 # raises on any drift
    qnet = deploy(res)
    test = make_dataset("synthetic", split="test", seed=0)
    iq = evaluate_int(qnet, test.batches(50, 2))
    fqe = evaluate_fq(res, test.batches(50, 2))
    assert iq["n"] == fqe["n"] == 100
    assert abs(iq["accuracy"] - fqe["accuracy"]) <= 0.1


def test_fold_check_rejects_float_results():
    cfg, data, res = _trained_smoke(steps=5, w_bits=None)
    with pytest.raises(ValueError):
        fold_check(res)


def test_edge_agreement_contract():
    cfg, data, res = _trained_smoke(steps=60)
    qnet = deploy(res)
    x, _ = next(make_dataset("synthetic", split="test", seed=0).batches(
        32, 1))
    ea = edge_agreement(res, qnet, x)
    # the honest fold contract: grids identical => codes within a couple
    # LSBs almost everywhere (f32 vs int32 accumulation), decisions agree
    assert ea["within_1lsb"] >= 0.9
    assert ea["argmax_agree"] >= 0.95


def test_planned_training_resolves_segments():
    """A segmented PrecisionPlan reaches the fake-quant forward with the
    deployment's own width resolution (resolve_qcfg), and the deployed
    artifact carries the segmented conv."""
    from repro.deploy.policy import PlanRule, PrecisionPlan
    from repro.vision.layers import QSegmentedConv2D

    # full-size net: c3's 256 channels give a CHUNK-aligned boundary
    # (interior segment edges must sit on packing.CHUNK multiples)
    cfg = get_vision_config("qat-cnn", smoke=False)
    segs = ((0, packing.CHUNK, 8), (packing.CHUNK, 256, 2))
    plan = PrecisionPlan(rules=(
        PlanRule(pattern="c3", w_bits=8, segments=segs),
        PlanRule(pattern="c1", w_bits=2),
    ), default_w_bits=4)
    lquant = resolve_layer_quant(cfg, plan, 4, 8)
    assert lquant["c3"].segments == segs
    assert lquant["c1"].w_bits == 2 and lquant["c2"].w_bits == 4

    data = make_dataset("synthetic", split="train", seed=0)
    qc = QATConfig(steps=10, batch=16, log_every=5, seed=0)
    res = train_qat(cfg, data, qc, plan=plan)
    fold_check(res)                    # segmented runs fold per-run
    qnet = deploy(res)
    seg_layers = [l for l in qnet.qlayers
                  if isinstance(l[1], QSegmentedConv2D)]
    assert len(seg_layers) == 1
    x, _ = next(data.batches(8, 1))
    iq = evaluate_int(qnet, [(x, np.zeros(8, np.int64))])
    assert iq["n"] == 8


@pytest.mark.slow
def test_qat_beats_ptq_at_w2():
    """The subsystem's reason to exist: at W2, fake-quant fine-tuning
    recovers accuracy PTQ cannot (full-size net, the benchmark recipe)."""
    cfg = get_vision_config("qat-cnn", smoke=False)
    data = SyntheticDigits(split="train", seed=0, noise=0.45, jitter=3)
    test = SyntheticDigits(split="test", seed=0, noise=0.45, jitter=3)
    qc_f = QATConfig(steps=400, batch=64, w_bits=None, log_every=200,
                     seed=0)
    res_f = train_qat(cfg, data, qc_f)
    ptq = evaluate_int(deploy(res_f, default_w_bits=2),
                       test.batches(100, 5))
    qc2 = QATConfig(steps=600, batch=64, lr=1e-2, w_bits=2, warmup=30,
                    log_every=300, seed=0)
    res2 = train_qat(cfg, data, qc2, init_params=res_f.params)
    qat = evaluate_int(deploy(res2), test.batches(100, 5))
    assert qat["accuracy"] > ptq["accuracy"] + 0.05, \
        f"QAT {qat['accuracy']} vs PTQ {ptq['accuracy']}"
