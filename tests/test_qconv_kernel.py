"""Fused implicit-GEMM conv kernel vs the direct-convolution numpy oracle.

Bit-exactness of `qconv2d_fused` against `qconv/ref.py` across the full
{a_bits, w_bits} x stride x padding grid, on shapes chosen to stress the
gather: non-square H != W, Cin that is NOT a CHUNK multiple (per-tap
channel padding path), ragged Ho tile edges, and degenerate 1x1 /
non-square filters. The oracle convolves directly (no im2col), so a bug
in the in-kernel gather or the per-tap packed weight layout cannot hide
in a shared code path.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (QuantSpec, quantize, calibrate_weight,
                        calibrate_activation)
from repro.core import packing
from repro.kernels.qconv import (quantize_conv, qconv2d_apply, qconv2d_ref,
                                 qconv2d_fused)


def _quantized_layer(rng, shape_hw, cin, cout, f, a_bits, w_bits, out_bits,
                     stride, padding, n=1, fw=None):
    fh, fw = f, f if fw is None else fw
    h, w_ = shape_hw
    w = rng.normal(size=(fh, fw, cin, cout)).astype(np.float32) * 0.08
    x = np.maximum(rng.normal(size=(n, h, w_, cin)), 0).astype(np.float32)
    bn_s = rng.normal(size=(cout,)).astype(np.float32) * 0.05 + 0.3
    bn_b = rng.normal(size=(cout,)).astype(np.float32) * 0.01
    sw = calibrate_weight(jnp.asarray(w), w_bits)
    sx = calibrate_activation(x, a_bits, 100.0)
    sy = QuantSpec.activation(out_bits, 8.0)
    qp = quantize_conv(jnp.asarray(w), sw, bn_s, bn_b, sx, sy,
                       stride, padding)
    xq = quantize(jnp.asarray(x), sx)
    return qp, xq


def _oracle(qp, xq, out_bits):
    fh, fw, cin, cout = qp.fh, qp.fw, qp.cin, qp.cout
    w_unp = np.asarray(packing.unpack(
        qp.gemm.w_packed, qp.gemm.w_bits, True, axis=0))[: fh * fw * cin]
    return qconv2d_ref(np.asarray(xq), w_unp.reshape(fh, fw, cin, cout),
                       np.asarray(qp.gemm.kappa), np.asarray(qp.gemm.lam),
                       np.asarray(qp.gemm.m), qp.gemm.d, out_bits,
                       qp.stride, qp.padding)


# Cin=24 is deliberately NOT a CHUNK multiple -> per-tap padding path;
# H != W exercises the non-square gather. The layer is quantized once per
# bit pair (stride/padding do not touch the packed artifact) and every
# stride x padding combo of the grid runs against the oracle.
@pytest.mark.parametrize("a_bits", [8, 4, 2])
@pytest.mark.parametrize("w_bits", [8, 4, 2])
def test_fused_bit_exact_grid(a_bits, w_bits, rng):
    import dataclasses
    qp0, xq = _quantized_layer(rng, (7, 5), cin=24, cout=40, f=3,
                               a_bits=a_bits, w_bits=w_bits, out_bits=a_bits,
                               stride=1, padding=0)
    for stride, padding in [(1, 0), (1, 1), (2, 0), (2, 1)]:
        qp = dataclasses.replace(qp0, stride=stride, padding=padding)
        want = _oracle(qp, xq, a_bits)
        got = qconv2d_apply(qp, xq, backend="pallas_interpret")
        assert got.dtype == jnp.int8
        assert np.array_equal(np.asarray(got), want), (
            f"fused conv mismatch a={a_bits} w={w_bits} "
            f"s={stride} p={padding}")


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_fused_matches_im2col_fallback(bits, rng):
    """The two routes of qconv2d_apply are bit-identical."""
    qp, xq = _quantized_layer(rng, (9, 6), cin=24, cout=33, f=3,
                              a_bits=bits, w_bits=bits, out_bits=bits,
                              stride=1, padding=1, n=2)
    got_fused = qconv2d_apply(qp, xq, backend="pallas_interpret")
    got_jnp = qconv2d_apply(qp, xq, backend="xla")
    assert np.array_equal(np.asarray(got_fused), np.asarray(got_jnp))


def test_fused_ragged_ho_tiles(rng):
    """Explicit block whose bho does not divide Ho: the padded rows must
    be gathered in-bounds (zero rows) and sliced off the output."""
    qp, xq = _quantized_layer(rng, (12, 6), cin=24, cout=40, f=3,
                              a_bits=4, w_bits=4, out_bits=4,
                              stride=1, padding=1)
    want = _oracle(qp, xq, 4)
    got = qconv2d_apply(qp, xq, backend="pallas_interpret", block=(5, 128))  # ho=12
    assert np.array_equal(np.asarray(got), want)


def test_fused_cin_chunk_multiple(rng):
    """Cin == CHUNK: no channel padding, pack factor path only."""
    qp, xq = _quantized_layer(rng, (6, 8), cin=packing.CHUNK, cout=40, f=3,
                              a_bits=4, w_bits=4, out_bits=4,
                              stride=1, padding=1, n=1)
    assert qp.cin_pad == packing.CHUNK
    want = _oracle(qp, xq, 4)
    got = qconv2d_apply(qp, xq, backend="pallas_interpret")
    assert np.array_equal(np.asarray(got), want)


def test_fused_multiple_cout_panels(rng):
    """cout spanning several bn panels: the im2col scratch is gathered on
    the first panel only and reused for the rest (j>0 grid steps)."""
    qp, xq = _quantized_layer(rng, (6, 5), cin=24, cout=200, f=3,
                              a_bits=4, w_bits=4, out_bits=4,
                              stride=1, padding=1, n=2)
    want = _oracle(qp, xq, 4)
    got = qconv2d_apply(qp, xq, backend="pallas_interpret", block=(3, 128))
    assert np.array_equal(np.asarray(got), want)


def test_fused_1x1_conv(rng):
    """1x1 filter: the implicit GEMM degenerates to a plain packed GEMM
    over pixels."""
    qp, xq = _quantized_layer(rng, (5, 7), cin=24, cout=40, f=1,
                              a_bits=4, w_bits=2, out_bits=4,
                              stride=1, padding=0, n=1)
    want = _oracle(qp, xq, 4)
    got = qconv2d_apply(qp, xq, backend="pallas_interpret")
    assert np.array_equal(np.asarray(got), want)


def test_fused_non_square_filter(rng):
    qp, xq = _quantized_layer(rng, (8, 6), cin=24, cout=40, f=3, fw=1,
                              a_bits=4, w_bits=4, out_bits=4,
                              stride=1, padding=0, n=1)
    want = _oracle(qp, xq, 4)
    got = qconv2d_apply(qp, xq, backend="pallas_interpret")
    assert np.array_equal(np.asarray(got), want)


def test_fused_stride2_even_dims(rng):
    """stride 2 on even dims + padding: the gather's strided slices must
    stay aligned with the oracle's indexing."""
    qp, xq = _quantized_layer(rng, (8, 10), cin=24, cout=40, f=3,
                              a_bits=2, w_bits=4, out_bits=4,
                              stride=2, padding=1, n=1)
    want = _oracle(qp, xq, 4)
    got = qconv2d_apply(qp, xq, backend="pallas_interpret")
    assert np.array_equal(np.asarray(got), want)


def test_fused_raw_epilogue_matches_int32_accum(rng):
    """epilogue='raw' exposes the int32 accumulators: compare against a
    direct numpy int32 convolution (no BN/requant)."""
    qp, xq = _quantized_layer(rng, (6, 5), cin=24, cout=40, f=3,
                              a_bits=4, w_bits=4, out_bits=4,
                              stride=1, padding=1, n=1)
    g = qp.gemm
    got = qconv2d_fused(
        xq, qp.w_packed_fused, g.kappa, g.lam, g.m,
        fh=qp.fh, fw=qp.fw, stride=qp.stride, padding=qp.padding,
        cin_pad=qp.cin_pad, cout=qp.cout, a_bits=g.a_bits,
        a_signed=g.a_signed, w_bits=g.w_bits, d=g.d, out_bits=g.out_bits,
        epilogue="raw", interpret=True)
    w_unp = np.asarray(packing.unpack(
        g.w_packed, g.w_bits, True, axis=0))[: qp.fh * qp.fw * qp.cin]
    w_unp = w_unp.reshape(qp.fh, qp.fw, qp.cin, qp.cout).astype(np.int32)
    x = np.pad(np.asarray(xq, np.int32),
               ((0, 0), (1, 1), (1, 1), (0, 0)))
    acc = np.zeros(got.shape, np.int64)
    for dy in range(3):
        for dx in range(3):
            patch = x[:, dy:dy + 6, dx:dx + 5]
            acc += np.einsum("nhwc,co->nhwo", patch, w_unp[dy, dx],
                             dtype=np.int64)
    assert np.array_equal(np.asarray(got), acc.astype(np.int32))
