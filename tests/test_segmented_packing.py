"""Property-based fuzz wall for segmented packed containers (core/packing.py).

Random SegmentMaps — random run counts, widths from WIDTHS={8,4,2},
CHUNK-aligned interior boundaries with a ragged final run — checked for:

* pack -> unpack round-trip exactness (per-run and whole-buffer);
* planar-perm consistency: each run's container block is byte-identical
  to what the uniform chunk-planar `pack` produces for those columns, and
  its `unpack_planes` planes land on the `planar_perm` logical order;
* offset-table byte accounting: packed_bytes == sum(run_len * K_pad * b/8),
  seg_offsets deltas match per-run sizes, tile_table covers the buffer;
* loud ValueErrors on malformed maps (gaps, overlaps, empty runs,
  unaligned interior boundaries, unsupported widths).

Properties are driven two ways: hypothesis `@given` when the package is
installed (conftest degrades them to skips otherwise), and a deterministic
seed sweep that always runs so tier-1 keeps the coverage either way.
"""
import numpy as np
import pytest

from conftest import hypothesis_api
from repro.core import packing
from repro.core.packing import CHUNK, WIDTHS, SegmentMap

given, settings, st = hypothesis_api()

N_SWEEP_SEEDS = 25


def random_segmap(rng, *, max_runs=4, ragged=None):
    """Random valid SegmentMap: interior runs are CHUNK multiples wide,
    the final run is ragged with probability ~1/2 (or per ``ragged``)."""
    n_runs = int(rng.integers(1, max_runs + 1))
    runs, pos = [], 0
    for i in range(n_runs):
        last = i == n_runs - 1
        width = int(rng.integers(1, 4)) * CHUNK
        if last and (bool(rng.integers(0, 2)) if ragged is None else ragged):
            width = int(rng.integers(1, 2 * CHUNK))
        runs.append((pos, pos + width, int(rng.choice(WIDTHS))))
        pos += width
    return SegmentMap(tuple(runs))


def random_values(rng, k, segmap):
    """int8 values in each run's signed range (so assert_range passes)."""
    w = np.zeros((k, segmap.n), np.int8)
    for s, e, b in segmap.runs:
        lo, hi = packing.int_range(b, True)
        w[:, s:e] = rng.integers(lo, hi + 1, size=(k, e - s), dtype=np.int64)
    return w


# ------------------------------------------------------------ properties ---


def check_roundtrip(rng, seed):
    segmap = random_segmap(rng)
    k = int(rng.integers(1, 3 * CHUNK))
    w = random_values(rng, k, segmap)
    buf = np.asarray(packing.pack_segmented(w, segmap, assert_range=True))
    assert buf.dtype == np.int8
    assert buf.shape == (segmap.packed_bytes(k),), (seed, segmap.runs, k)
    out = np.asarray(packing.unpack_segmented(buf, segmap, k))
    assert out.shape == (packing.padded_size(k), segmap.n)
    np.testing.assert_array_equal(out[:k], w, err_msg=f"seed={seed}")
    # K padding rows unpack to exact zeros (zero containers, every width)
    np.testing.assert_array_equal(out[k:], 0)


def check_planar_consistency(rng, seed):
    """Each run's container block == the uniform packer's output for those
    columns, and its planes follow the planar_perm logical order."""
    segmap = random_segmap(rng)
    k = int(rng.integers(1, 3 * CHUNK))
    kp = packing.padded_size(k)
    w = random_values(rng, k, segmap)
    buf = packing.pack_segmented(w, segmap)
    for i, (s, e, b) in enumerate(segmap.runs):
        seg_view = np.asarray(packing.segment_packed(buf, segmap, i, k))
        uniform = np.asarray(packing.pack(
            packing.pad_to_chunk(w[:, s:e], axis=-2), b, axis=-2))
        np.testing.assert_array_equal(
            seg_view, uniform, err_msg=f"seed={seed} run={i}")
        # plane p, packed-row r holds logical element chunk*CHUNK + p*sub
        # + (r % sub): interleave the planes per chunk and the result must
        # equal the padded values gathered by planar_perm
        planes = packing.unpack_planes(seg_view, b, True)
        pf = packing.pack_factor(b)
        sub = CHUNK // pf
        stacked = np.stack([np.asarray(p) for p in planes], axis=0)
        planar = (stacked.reshape(pf, kp // CHUNK, sub, e - s)
                  .transpose(1, 0, 2, 3).reshape(kp, e - s))
        perm = packing.planar_perm(kp, b)
        padded = np.asarray(packing.pad_to_chunk(w[:, s:e], axis=-2))
        np.testing.assert_array_equal(
            planar, padded[perm], err_msg=f"seed={seed} run={i}")


def check_byte_accounting(rng, seed):
    segmap = random_segmap(rng)
    k = int(rng.integers(1, 3 * CHUNK))
    kp = packing.padded_size(k)
    sizes = [(e - s) * kp * b // 8 for s, e, b in segmap.runs]
    assert segmap.packed_bytes(k) == sum(sizes), (seed, segmap.runs)
    offs = segmap.seg_offsets(k)
    assert offs[0] == 0
    for i in range(len(offs) - 1):
        assert offs[i + 1] - offs[i] == sizes[i], (seed, i)
    assert offs[-1] + sizes[-1] == segmap.packed_bytes(k)
    # tile_table (on the CHUNK-padded map) tiles the padded buffer exactly
    buf = packing.pack_segmented(random_values(rng, k, segmap), segmap)
    buf_p, segmap_p = packing.pad_segmented(buf, segmap, k)
    codes, toffs = segmap_p.tile_table(k)
    widths = segmap_p.widths()
    assert codes.shape == toffs.shape == (segmap_p.n // CHUNK,)
    pos = 0
    for c, o in zip(codes, toffs):
        assert int(o) == pos, seed
        pos += (kp // packing.pack_factor(widths[int(c)])) * CHUNK
    assert pos == buf_p.shape[-1] == segmap_p.packed_bytes(k)


PROPERTIES = (check_roundtrip, check_planar_consistency,
              check_byte_accounting)


@pytest.mark.parametrize("prop", PROPERTIES, ids=lambda p: p.__name__)
@pytest.mark.parametrize("seed", range(N_SWEEP_SEEDS))
def test_seed_sweep(prop, seed):
    prop(np.random.default_rng(seed), seed)


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=200, deadline=None)
def test_fuzz_roundtrip(seed):
    check_roundtrip(np.random.default_rng(seed), seed)


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=100, deadline=None)
def test_fuzz_planar_consistency(seed):
    check_planar_consistency(np.random.default_rng(seed), seed)


@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=200, deadline=None)
def test_fuzz_byte_accounting(seed):
    check_byte_accounting(np.random.default_rng(seed), seed)


# ---------------------------------------------------------- loud failures ---


@pytest.mark.parametrize("runs,match", [
    ((), "empty run list"),
    (((0, 128, 3),), "unsupported width"),
    (((0, 128, 8), (256, 384, 4)), "gap"),
    (((0, 256, 8), (128, 384, 4)), "overlaps"),
    (((0, 0, 8),), "empty or reversed"),
    (((0, 128, 8), (128, 100, 4)), "empty or reversed"),
    (((128, 256, 8),), "expected n_start=0"),
    (((0, 100, 8), (100, 256, 4)), "not a\n?.*multiple of CHUNK|interior"),
])
def test_malformed_maps_raise(runs, match):
    with pytest.raises(ValueError, match=match):
        SegmentMap(tuple(runs))


def test_ragged_interior_boundary_raises():
    # only the FINAL run may end off-CHUNK
    with pytest.raises(ValueError, match="interior boundary"):
        SegmentMap(((0, 130, 8), (130, 256, 2)))
    SegmentMap(((0, 128, 8), (128, 130, 2)))  # ragged tail: fine


def test_pack_segmented_shape_mismatch_raises():
    segmap = SegmentMap(((0, 128, 8), (128, 256, 4)))
    with pytest.raises(ValueError, match="weight N=100"):
        packing.pack_segmented(np.zeros((64, 100), np.int8), segmap)


def test_pack_segmented_range_guard():
    segmap = SegmentMap(((0, 128, 8), (128, 256, 2)))
    w = np.zeros((32, 256), np.int8)
    w[0, 200] = 5  # out of signed 2-bit range [-2, 1]
    with pytest.raises(ValueError, match="2-bit range"):
        packing.pack_segmented(w, segmap, assert_range=True)


def test_tile_table_requires_padded_n():
    segmap = SegmentMap(((0, 128, 8), (128, 200, 4)))
    with pytest.raises(ValueError, match="pad the\n?.*container|CHUNK"):
        segmap.tile_table(64)


def test_uniform_degenerate_matches_plain_pack(rng):
    """Single-run maps are byte-identical to the uniform packer."""
    for bits in WIDTHS:
        lo, hi = packing.int_range(bits, True)
        w = rng.integers(lo, hi + 1, size=(200, 256), dtype=np.int64)
        w = w.astype(np.int8)
        segmap = SegmentMap.uniform(256, bits)
        buf = np.asarray(packing.pack_segmented(w, segmap))
        plain = np.asarray(packing.pack(
            packing.pad_to_chunk(w, axis=-2), bits, axis=-2))
        # panel-major flatten of the uniform container
        rows = plain.shape[0]
        parts = [plain[:, p:p + CHUNK].reshape(rows * CHUNK)
                 for p in range(0, 256, CHUNK)]
        np.testing.assert_array_equal(buf, np.concatenate(parts))
