"""Benchmark-artifact schemas (benchmarks/schema.py).

The perf-trajectory tooling diffs BENCH_kernels.json / BENCH_cluster.json
/ BENCH_e2e.json run over run, so their shapes are load-bearing. This file
pins the checked-in validators against known-good fixture payloads (the
exact shapes the writers emit, incl. the PR's pipeline + frac_of_peak
roofline columns) and proves every validator actually rejects the breakage
it claims to catch. The slow test runs the real fig8 benchmark and
validates the artifact run.py would write.
"""
import copy
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:  # `import benchmarks` from any rootdir
    sys.path.insert(0, str(ROOT))

from benchmarks import schema
from benchmarks.schema import SchemaError

KERNELS_OK = {
    "us_per_call": {"fig8_8bit_off": 171714.1,
                    "fig8_8bit_double_buffer": 320165.3,
                    "fig11_conv16x16_8bit_full": 1234.5},
    "derived": {"fig8_8bit_off": "v5e_us=2.723;macs=134217728"},
    "backend": {"fig8_8bit_off": "pallas_interpret"},
    "pipeline": {"fig8_8bit_off": "off",
                 "fig8_8bit_double_buffer": "double_buffer"},
    "frac_of_peak": {"fig8_8bit_off": 0.5004,
                     "fig8_8bit_double_buffer": 1.0},
    # counter-measured columns (PR 7): effective MAC/us + packed bytes
    # moved, sourced from repro.obs.counters during the timed run
    "macs_per_us": {"fig8_8bit_off": 781.5,
                    "fig8_8bit_double_buffer": 419.2},
    "packed_bytes": {"fig8_8bit_off": 786432,
                     "fig8_8bit_double_buffer": 786432},
    # fine-grain mixed-precision ladder (PR 9): "|"-joined container
    # widths the row's kernel consumed, widest first
    "segment_bits": {"fig8_8bit_off": "8",
                     "fig8_8bit_double_buffer": "8",
                     "fig11_conv16x16_8bit_full": "8|2"},
}

TRACE_OK = {
    "traceEvents": [
        {"name": "qdot", "cat": "kernel", "ph": "X", "ts": 10.0,
         "dur": 120.5, "pid": 0, "tid": 0,
         "args": {"backend": "pallas_interpret", "pipeline": "off",
                  "a_bits": 8, "w_bits": 4, "macs": 1048576,
                  "packed_bytes": 28672}},
        {"name": "dispatch:qdot", "cat": "dispatch", "ph": "i", "ts": 9.0,
         "pid": 0, "tid": 0, "s": "t"},
    ],
    "displayTimeUnit": "ms",
    "repro": {
        "version": 1,
        "counters": {"engine.waves": 2},
        "op_counters": {
            "qdot|w4a8|pallas_interpret|off": {
                "calls": 3, "macs": 3145728, "logical_bytes": 135168,
                "packed_bytes": 86016}},
        "dispatch": [
            {"op": "qdot", "backend": "pallas_interpret",
             "backend_source": "explicit", "pipeline": "off",
             "pipeline_source": "default", "ts": 9.0,
             "tune_cache_hit": False}],
    },
}

CLUSTER_OK = {
    "version": 1,
    "gemm": {"M": 256, "K": 2048, "N": 1024},
    "path": "repro.kernels.api.qdot_sharded",
    "rows": [{"name": "fig9_8bit_dev2", "bits": 8, "devices": 2,
              "us_per_call": 1813.1, "speedup": 1.91,
              "efficiency": 0.955, "per_dev_flops": 5.4e8,
              "coll_bytes": 0, "proj_us_v5e": 6.82}],
}

E2E_OK = {
    "version": 1,
    "batch": 8,
    "rows": [
        {"name": "e2e_resnet8_8_conv1_dev1", "net": "resnet8",
         "layer": "conv1", "bits": "8", "devices": 1,
         "us_per_call": 812.0, "macs_per_image": 1769472},
        {"name": "e2e_resnet8_mixed_total_dev2", "net": "resnet8",
         "layer": "total", "bits": "mixed", "devices": 2,
         "us_per_call": 9120.4, "macs_per_image": 12501504,
         "speedup": 1.8, "efficiency": 0.9, "bytes_streamed": 91032,
         "proj_us_v5e": 4.1},
    ],
}


def _serving_row(policy, tps, p99):
    return {"policy": policy, "requests": 24, "steps": 80,
            "tokens_out": 150, "makespan_s": 70.0,
            "throughput_rps": 24 / 70.0, "throughput_tps": tps,
            "latency_s": {"p50": 12.0, "p95": 20.0, "p99": p99,
                          "mean": 13.5, "max": 30.0},
            "queue_depth": {"mean": 1.2, "max": 6},
            "occupancy": {"mean": 0.8, "min": 0.0}}


SERVING_OK = {
    "version": 1,
    "workload": {"model": "qwen2p5-3b-smoke", "requests": 24, "qps": 0.6,
                 "step_cost_s": 1.0, "slots": 4, "max_len": 32,
                 "prompt_lens": [2, 6], "max_new": [1, 12], "seed": 0,
                 "devices": 1},
    "rows": [_serving_row("wave", 1.8, 48.0),
             _serving_row("continuous", 2.4, 24.0)],
    "acceptance": {"throughput_gain": 2.4 / 1.8, "p99_ratio": 0.5},
}


def _mutated(payload, fn):
    p = copy.deepcopy(payload)
    fn(p)
    return p


# ------------------------------------------------------------- kernels ---

def test_kernels_fixture_valid():
    schema.validate_kernels(KERNELS_OK)


@pytest.mark.parametrize("mutate,match", [
    (lambda p: p.pop("us_per_call"), "missing required field"),
    (lambda p: p.pop("pipeline"), "missing required field 'pipeline'"),
    (lambda p: p.pop("frac_of_peak"), "frac_of_peak"),
    (lambda p: p["pipeline"].update(fig8_8bit_off="triple_buffer"),
     r"\$\.pipeline\.fig8_8bit_off"),
    (lambda p: p["frac_of_peak"].update(fig8_8bit_off=1.5),
     "out of range"),
    (lambda p: p["frac_of_peak"].update(ghost_row=0.5),
     "not in us_per_call"),
    (lambda p: p["us_per_call"].update(fig8_8bit_off="fast"),
     "expected"),
    (lambda p: p["us_per_call"].update(fig8_8bit_off=True), "bool"),
    (lambda p: p.pop("macs_per_us"), "missing required field"),
    (lambda p: p.pop("packed_bytes"), "missing required field"),
    (lambda p: p["macs_per_us"].update(fig8_8bit_off=-1.0),
     "out of range"),
    (lambda p: p["packed_bytes"].update(fig8_8bit_off=1.5), "expected"),
    (lambda p: p.pop("segment_bits"), "missing required field"),
    (lambda p: p["segment_bits"].update(fig8_8bit_off="3"),
     "out of range"),
    (lambda p: p["segment_bits"].update(fig8_8bit_off="2|8"),
     "out of range"),         # must be widest first
    (lambda p: p["segment_bits"].update(fig8_8bit_off="8|8"),
     "out of range"),         # no duplicate widths
    (lambda p: p["segment_bits"].update(ghost_row="8"),
     "not in us_per_call"),
])
def test_kernels_rejects(mutate, match):
    with pytest.raises(SchemaError, match=match):
        schema.validate_kernels(_mutated(KERNELS_OK, mutate))


def test_fig8_roofline_acceptance_shape():
    """Per bit-width: an 'off' and a 'double_buffer' row, both with
    frac_of_peak, pipelined >= exposed-DMA."""
    schema.validate_fig8_roofline(KERNELS_OK, bits=(8,))
    with pytest.raises(SchemaError, match="missing fig8 roofline row"):
        schema.validate_fig8_roofline(KERNELS_OK, bits=(8, 4))
    bad = _mutated(KERNELS_OK,
                   lambda p: p["frac_of_peak"].update(
                       fig8_8bit_double_buffer=0.3))
    with pytest.raises(SchemaError, match="below the exposed-DMA"):
        schema.validate_fig8_roofline(bad, bits=(8,))
    nofrac = _mutated(KERNELS_OK,
                      lambda p: p["frac_of_peak"].pop("fig8_8bit_off"))
    with pytest.raises(SchemaError, match="missing roofline column"):
        schema.validate_fig8_roofline(nofrac, bits=(8,))
    nomacs = _mutated(KERNELS_OK,
                      lambda p: p["macs_per_us"].pop("fig8_8bit_off"))
    with pytest.raises(SchemaError, match="counter-measured column"):
        schema.validate_fig8_roofline(nomacs, bits=(8,))


# ------------------------------------------------------------- cluster ---

def test_cluster_fixture_valid():
    schema.validate_cluster(CLUSTER_OK)


@pytest.mark.parametrize("mutate,match", [
    (lambda p: p.update(version=2), "out of range"),
    (lambda p: p["gemm"].pop("K"), "missing required field 'K'"),
    (lambda p: p.update(rows=[]), "empty rows"),
    (lambda p: p["rows"][0].pop("speedup"), r"\$\.rows\[0\]"),
    (lambda p: p["rows"][0].update(bits=3), "out of range"),
    (lambda p: p["rows"][0].update(devices=0), "out of range"),
    (lambda p: p["rows"][0].update(coll_bytes=1.5), "expected"),
])
def test_cluster_rejects(mutate, match):
    with pytest.raises(SchemaError, match=match):
        schema.validate_cluster(_mutated(CLUSTER_OK, mutate))


# ----------------------------------------------------------------- e2e ---

def test_e2e_fixture_valid():
    schema.validate_e2e(E2E_OK)


@pytest.mark.parametrize("mutate,match", [
    (lambda p: p.pop("batch"), "missing required field 'batch'"),
    (lambda p: p["rows"][0].pop("macs_per_image"), "macs_per_image"),
    (lambda p: p["rows"][1].update(efficiency=-1.0), "out of range"),
    (lambda p: p["rows"][0].update(bits=None), "expected"),
    (lambda p: p["rows"][1].update(bytes_streamed="91032"), "expected"),
])
def test_e2e_rejects(mutate, match):
    with pytest.raises(SchemaError, match=match):
        schema.validate_e2e(_mutated(E2E_OK, mutate))


# ------------------------------------------------------------- serving ---

def test_serving_fixture_valid():
    schema.validate_serving(SERVING_OK)


@pytest.mark.parametrize("mutate,match", [
    (lambda p: p.update(version=2), "out of range"),
    (lambda p: p["workload"].pop("qps"), "missing required field 'qps'"),
    (lambda p: p["workload"].update(slots=0), "out of range"),
    (lambda p: p.update(rows=[]), "empty rows"),
    (lambda p: p["rows"][0].update(policy="batch"), "out of range"),
    (lambda p: p.update(rows=[_serving_row("continuous", 2.4, 24.0)]),
     "missing policy row 'wave'"),
    (lambda p: p["rows"][0]["latency_s"].pop("p99"),
     "missing required field 'p99'"),
    (lambda p: p["rows"][0]["occupancy"].update(mean=1.5),
     "out of range"),
    (lambda p: p["rows"][0]["queue_depth"].update(max=2.5), "expected"),
    (lambda p: p.pop("acceptance"), "missing required field"),
    # the acceptance ordering itself is enforced, fig8-roofline style:
    # continuous must strictly beat the wave baseline both ways
    (lambda p: p["rows"][1].update(throughput_tps=1.0),
     "does not beat the wave baseline on token throughput"),
    (lambda p: p["rows"][1]["latency_s"].update(p99=60.0),
     "does not beat the wave baseline on p99"),
    (lambda p: p["acceptance"].update(throughput_gain=0.9),
     "token throughput"),
    (lambda p: p["acceptance"].update(p99_ratio=1.1), "p99"),
])
def test_serving_rejects(mutate, match):
    with pytest.raises(SchemaError, match=match):
        schema.validate_serving(_mutated(SERVING_OK, mutate))


# ------------------------------------------------------------ accuracy ---

def _accuracy_row(name, mode, plan, w_bits, acc, bytes_, seg=0):
    return {"name": name, "mode": mode, "plan": plan, "w_bits": w_bits,
            "accuracy": acc, "correct": int(acc * 1000), "n": 1000,
            "packed_weight_bytes": bytes_, "train_steps": 600,
            "segmented_rules": seg}


ACCURACY_OK = {
    "version": 1, "net": "qat-cnn", "mode": "full",
    "dataset": {"name": "synthetic-digits", "noise": 0.45, "jitter": 3,
                "seed": 0, "eval_images": 1000},
    "budget_frac": 0.35,
    "path": "repro.vision.models.forward_int",
    "rows": [
        _accuracy_row("float", "float", "none", 32, 0.934, 326592),
        _accuracy_row("ptq_w8", "ptq", "uniform", 8, 0.928, 114872),
        _accuracy_row("qat_w8", "qat", "uniform", 8, 0.952, 114872),
        _accuracy_row("ptq_w4", "ptq", "uniform", 4, 0.874, 59320),
        _accuracy_row("qat_w4", "qat", "uniform", 4, 0.931, 59320),
        _accuracy_row("ptq_w2", "ptq", "uniform", 2, 0.103, 31544),
        _accuracy_row("qat_w2", "qat", "uniform", 2, 0.251, 31544),
        _accuracy_row("ptq_plan_layer", "ptq", "layer", 0, 0.315, 58168),
        _accuracy_row("qat_plan_layer", "qat", "layer", 0, 0.889, 58168),
        _accuracy_row("ptq_plan_channel_group", "ptq", "channel_group",
                      0, 0.528, 46520, seg=1),
        _accuracy_row("qat_plan_channel_group", "qat", "channel_group",
                      0, 0.921, 46520, seg=1),
    ],
    "acceptance": {"qat_ge_ptq_w4": True, "qat_ge_ptq_w2": True,
                   "plans_on_frontier": True,
                   "fine_dominates_layer": True, "all": True},
}


def test_accuracy_fixture_valid():
    schema.validate_accuracy(ACCURACY_OK)


def test_accuracy_smoke_mode_skips_gates():
    p = _mutated(ACCURACY_OK, lambda p: p.update(mode="smoke"))
    p["rows"][6]["accuracy"] = 0.01          # qat_w2 below ptq_w2
    schema.validate_accuracy(p)              # gates off, shapes still on
    with pytest.raises(SchemaError, match="missing required field"):
        schema.validate_accuracy(
            _mutated(p, lambda q: q["rows"][0].pop("packed_weight_bytes")))


@pytest.mark.parametrize("mutate,match", [
    (lambda p: p.pop("dataset"), "missing required field 'dataset'"),
    (lambda p: p.update(budget_frac=1.5), "out of range"),
    (lambda p: p["rows"][0].update(mode="train"), "out of range"),
    (lambda p: p["rows"][0].update(accuracy=1.2), "out of range"),
    (lambda p: p["rows"][0].update(accuracy=True), "got bool"),
    (lambda p: p["rows"][1].update(correct=2000),
     "correct 2000 > n 1000"),
    (lambda p: p.update(rows=[r for r in p["rows"]
                              if r["name"] != "qat_w4"]),
     "missing uniform row mode=qat w_bits=4"),
    (lambda p: p["acceptance"].pop("fine_dominates_layer"),
     "missing required field"),
    # gates recomputed from rows — lying booleans don't help:
    (lambda p: p["rows"][6].update(accuracy=0.01),
     "QAT .* below PTQ .* at W2"),
    (lambda p: p["rows"][4].update(accuracy=0.5),
     "QAT .* below PTQ .* at W4"),
    # a uniform row that dominates a plan row breaks the frontier gate
    (lambda p: p["rows"][8].update(accuracy=0.2, packed_weight_bytes=99999),
     "dominates qat_plan_layer"),
    # channel_group must dominate-or-match layer (bytes AND accuracy)
    (lambda p: p["rows"][10].update(accuracy=0.7),
     "does not dominate-or-match"),
    (lambda p: p["acceptance"].update(all=False),
     "gates hold but 'all' is false"),
])
def test_accuracy_rejects(mutate, match):
    with pytest.raises(SchemaError, match=match):
        schema.validate_accuracy(_mutated(ACCURACY_OK, mutate))


# --------------------------------------------------------------- trace ---

def test_trace_fixture_valid():
    schema.check_trace(TRACE_OK)


@pytest.mark.parametrize("mutate,match", [
    (lambda p: p.pop("traceEvents"), "missing required field"),
    (lambda p: p["traceEvents"][0].pop("name"), "missing required field"),
    (lambda p: p["traceEvents"][0].update(ph="Z"), "out of range"),
    (lambda p: p["traceEvents"][0].update(ts=-1.0), "out of range"),
    (lambda p: p["traceEvents"][0].pop("dur"), "missing required field"),
    (lambda p: p["traceEvents"][0].update(args=[]), "expected"),
    (lambda p: p["repro"].update(version=99), "out of range"),
    (lambda p: p["repro"]["op_counters"].update(bad_key={
        "calls": 1, "macs": 0, "logical_bytes": 0, "packed_bytes": 0}),
     "key is not op"),
    (lambda p: p["repro"]["op_counters"]
     ["qdot|w4a8|pallas_interpret|off"].pop("macs"),
     "missing required field"),
    (lambda p: p["repro"]["dispatch"][0].pop("backend_source"),
     "missing required field"),
    (lambda p: p["repro"]["dispatch"][0].update(pipeline="triple_buffer"),
     "out of range"),
])
def test_trace_rejects(mutate, match):
    with pytest.raises(SchemaError, match=match):
        schema.check_trace(_mutated(TRACE_OK, mutate))


def test_trace_roundtrips_from_live_modules():
    """A trace exported by repro.obs itself must pass check_trace — pins
    the writer and the validator to the same shape."""
    from repro.obs import counters, trace

    trace.reset()
    counters.reset()
    with trace.enabled_scope():
        with trace.span("qdot", cat="kernel", backend="xla", pipeline="off",
                        a_bits=8, w_bits=4, macs=100, packed_bytes=10):
            pass
        trace.dispatch_event(op="qdot", backend="xla",
                             backend_source="default", pipeline="off",
                             pipeline_source="default",
                             tune_cache_hit=False)
        counters.record("qdot", (32, 256, 128), 8, 4, backend="xla",
                        pipeline="off")
        doc = trace.chrome_trace()
    trace.reset()
    counters.reset()
    schema.check_trace(doc)


# ------------------------------------------------------------ dispatch ---

def test_validate_file_dispatch(tmp_path):
    import json

    for name, payload in (("BENCH_kernels.json", KERNELS_OK),
                          ("BENCH_cluster.json", CLUSTER_OK),
                          ("BENCH_e2e.json", E2E_OK),
                          ("BENCH_serving.json", SERVING_OK),
                          ("BENCH_accuracy.json", ACCURACY_OK),
                          ("BENCH_trace.json", TRACE_OK)):
        f = tmp_path / name
        f.write_text(json.dumps(payload))
        schema.validate_file(f)
    unknown = tmp_path / "BENCH_other.json"
    unknown.write_text("{}")
    with pytest.raises(SchemaError, match="no schema registered"):
        schema.validate_file(unknown)


# --------------------------------------------------- the real artifact ---

@pytest.mark.slow
def test_fig8_artifact_passes_roofline_schema():
    """Run the real fig8 benchmark in-process and validate the exact
    payload run.py would write — the PR's acceptance shape."""
    from benchmarks import common, fig8_macs_per_issue, run

    saved = common.ROWS[:]
    common.ROWS.clear()
    try:
        fig8_macs_per_issue.main()
        payload = run.payload_from_rows(common.ROWS)
    finally:
        common.ROWS[:] = saved
    schema.validate_fig8_roofline(payload, bits=(8, 4, 2))
