"""Elastic fault tolerance: a checkpoint saved under one device count
restores under a DIFFERENT device count (node failure / scale change) —
exercised with real separate processes and XLA host-device overrides."""
import pathlib
import subprocess
import sys
import tempfile

import pytest

pytestmark = pytest.mark.slow  # two real training subprocesses

SAVE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, jax, jax.numpy as jnp
sys.path.insert(0, "src")
from repro.models.api import build
from repro.configs.olmo_1b import smoke_config
from repro.launch.mesh import make_host_mesh
from repro.train.step import make_train_fns, TrainStepConfig
from repro.configs.base import ShapeConfig
from repro.ckpt.checkpoint import save
from repro.parallel.ctx import use_mesh
cfg = smoke_config(); model = build(cfg)
mesh = make_host_mesh(model=2)   # 2x2 mesh
init_fn, step, shards = make_train_fns(model, mesh, ShapeConfig("t",16,4,"train"), TrainStepConfig())
state = init_fn(jax.random.PRNGKey(0))
batch = {"tokens": jnp.ones((4,16), jnp.int32), "labels": jnp.ones((4,16), jnp.int32)}
with use_mesh(mesh):
    state, m = jax.jit(step)(state, batch)
save(sys.argv[1], 1, state)
print("SAVED", float(m["loss"]))
"""

RESTORE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax, jax.numpy as jnp
sys.path.insert(0, "src")
from repro.models.api import build
from repro.configs.olmo_1b import smoke_config
from repro.launch.mesh import make_host_mesh
from repro.train.step import make_train_fns, TrainStepConfig
from repro.configs.base import ShapeConfig
from repro.ckpt.checkpoint import restore
from repro.parallel.ctx import use_mesh
cfg = smoke_config(); model = build(cfg)
mesh = make_host_mesh(model=4)   # DIFFERENT mesh: 2x4
init_fn, step, shards = make_train_fns(model, mesh, ShapeConfig("t",16,4,"train"), TrainStepConfig())
state, s0 = restore(sys.argv[1], shardings=None)
batch = {"tokens": jnp.ones((4,16), jnp.int32), "labels": jnp.ones((4,16), jnp.int32)}
with use_mesh(mesh):
    state, m = jax.jit(step)(state, batch)
print("RESTORED", s0, float(m["loss"]))
"""


def test_cross_device_count_restore():
    tmp = tempfile.mkdtemp()
    root = pathlib.Path(__file__).resolve().parents[1]
    r1 = subprocess.run([sys.executable, "-c", SAVE, tmp], cwd=root,
                        capture_output=True, text=True, timeout=300)
    assert "SAVED" in r1.stdout, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c", RESTORE, tmp], cwd=root,
                        capture_output=True, text=True, timeout=300)
    assert "RESTORED 1" in r2.stdout, r2.stderr[-2000:]
