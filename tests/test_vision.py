"""The end-to-end quantized CNN subsystem (`repro.vision`).

* graph/trace sanity for both paper-class nets (MobileNetV1-style,
  MLPerf-Tiny-style ResNet-8);
* whole-network bit-exactness across kernel backends ({xla,
  pallas_interpret}), across mesh vs single-device, under uniform W8A8
  and a planner-produced mixed W{8,4,2} plan (the ISSUE-5 acceptance
  criterion), and across a plan-JSON round-trip;
* layer-boundary requantization edges: uint2/uint4 saturation, avg-pool
  floor rounding vs an int64 oracle, residual-add saturation vs an int64
  oracle, grid-preserving max pool;
* depthwise lowering: block-diagonal im2col+qdot vs per-group qconv vs
  an independent numpy depthwise oracle, all bit-exact;
* the conv calibration tap (`calibrate_vision`) and the VisionEngine's
  wave sharding/utilization accounting.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.calibration import calibrate_weight
from repro.core.quantize import QuantSpec, quantize, requantize_shift_i64
from repro.deploy.calibrate import calibrate_vision
from repro.deploy.planner import auto_budget, plan_mixed_precision
from repro.deploy.policy import PlanRule, PrecisionPlan, load_plan, save_plan
from repro.vision import layers as vl
from repro.vision.configs import get_vision_config
from repro.vision.models import (collect_absmax, forward_fp, forward_int,
                                 init_fp, quantize_input, quantize_net,
                                 trace_shapes, vision_artifact_bytes)

NETS = ("resnet8", "mobilenet-tiny")


@pytest.fixture(scope="module")
def art():
    """Per-net calibrated fp artifact: (cfg, params, stats, absmax, x)."""
    out = {}
    rng = np.random.default_rng(0)
    for name in NETS:
        cfg = get_vision_config(name, smoke=True)
        params = init_fp(cfg, seed=0)
        x = rng.uniform(0, 1, size=(4, *cfg.in_hw, cfg.in_ch)).astype(
            np.float32)
        stats, absmax = calibrate_vision(cfg, params, [x])
        out[name] = (cfg, params, stats, absmax, x)
    return out


# --------------------------------------------------------------- graph ---

@pytest.mark.parametrize("net", NETS)
def test_trace_and_fp_forward(net, art):
    cfg, params, _, _, x = art[net]
    trace = trace_shapes(cfg)
    assert trace[-1]["out"] == (0, 0, cfg.num_classes)
    kinds = {t["layer"].kind for t in trace}
    assert {"conv", "avgpool_global", "linear"} <= kinds
    y = forward_fp(cfg, params, jnp.asarray(x))
    assert y.shape == (4, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(y)))


# ------------------------------------------------- network bit-exactness ---

@pytest.mark.parametrize("net", NETS)
def test_backend_parity_uniform_w8a8(net, art):
    """Whole-net forward is bit-exact across {xla, pallas_interpret} at
    every integer edge, under uniform W8A8."""
    cfg, params, _, absmax, x = art[net]
    qnet = quantize_net(cfg, params, absmax)
    x_hat = quantize_input(qnet, x)
    edges = {}
    for be in ("xla", "pallas_interpret"):
        seen = []
        out = forward_int(qnet, x_hat, backend=be,
                          collect=lambda p, y: seen.append((p, np.asarray(y))))
        edges[be] = dict(seen)
        assert out.dtype == jnp.int32 and out.shape == (4, cfg.num_classes)
    assert edges["xla"].keys() == edges["pallas_interpret"].keys()
    for path in edges["xla"]:
        assert np.array_equal(edges["xla"][path],
                              edges["pallas_interpret"][path]), path


@pytest.mark.parametrize("net", NETS)
def test_backend_parity_mixed_plan(net, art):
    """Planner-produced mixed W{8,4,2} plan: bit-exact across backends,
    smaller artifact than uniform W8."""
    cfg, params, stats, absmax, x = art[net]
    plan = plan_mixed_precision(stats, auto_budget(stats))
    qnet = quantize_net(cfg, params, absmax, plan=plan)
    q8 = quantize_net(cfg, params, absmax)
    assert vision_artifact_bytes(qnet) < vision_artifact_bytes(q8)
    bits = set(qnet.layer_bits().values())
    assert bits <= {8, 4, 2} and len(bits) >= 1
    x_hat = quantize_input(qnet, x)
    a = np.asarray(forward_int(qnet, x_hat, backend="xla"))
    b = np.asarray(forward_int(qnet, x_hat, backend="pallas_interpret"))
    assert np.array_equal(a, b)


@pytest.mark.parametrize("net", NETS)
@pytest.mark.parametrize("mixed", [False, True])
def test_mesh_parity(net, mixed, art):
    """Mesh-sharded forward (images DP over a 4-device cluster, ragged
    batch) is bit-exact vs meshless, uniform and mixed."""
    cfg, params, stats, absmax, x = art[net]
    plan = (plan_mixed_precision(stats, auto_budget(stats)) if mixed
            else None)
    qnet = quantize_net(cfg, params, absmax, plan=plan)
    x5 = np.concatenate([x, x[:1]], axis=0)        # 5 % 4 != 0: pad path
    x_hat = quantize_input(qnet, x5)
    ref = np.asarray(forward_int(qnet, x_hat, backend="xla"))
    mesh = jax.make_mesh((4, 1), ("data", "model"),
                         devices=jax.devices()[:4])
    got = np.asarray(forward_int(qnet, x_hat, backend="xla", mesh=mesh))
    assert np.array_equal(ref, got)


def test_plan_json_roundtrip(tmp_path, art):
    cfg, params, stats, absmax, x = art["resnet8"]
    plan = plan_mixed_precision(stats, auto_budget(stats))
    save_plan(plan, tmp_path / "vplan.json")
    plan2 = load_plan(tmp_path / "vplan.json")
    q1 = quantize_net(cfg, params, absmax, plan=plan)
    q2 = quantize_net(cfg, params, absmax, plan=plan2)
    x_hat = quantize_input(q1, x)
    assert np.array_equal(np.asarray(forward_int(q1, x_hat, backend="xla")),
                          np.asarray(forward_int(q2, x_hat, backend="xla")))


def test_plan_rules_route_backends(art):
    """A plan rule's ``backend`` lands on the matching layers and is used
    unless the call site overrides it."""
    cfg, params, _, absmax, _ = art["resnet8"]
    plan = PrecisionPlan(rules=(
        PlanRule(pattern="s2/*", w_bits=4, backend="pallas_interpret"),))
    qnet = quantize_net(cfg, params, absmax, plan=plan)
    routed = {L.path: getattr(q, "backend", None)
              for L, q in qnet.qlayers if L.kind in ("conv", "dwconv")}
    assert routed["s2/c1"] == "pallas_interpret"
    assert routed["stem"] is None


# ---------------------------------------------- boundary requantization ---

@pytest.mark.parametrize("a_bits", [4, 2])
def test_sub_byte_boundaries_saturate(a_bits, art):
    """uint{4,2} end-to-end: every activation edge stays on the unsigned
    grid and the net still discriminates inputs."""
    _, _, _, _, x = art["resnet8"]
    cfg = get_vision_config("resnet8", smoke=True, a_bits=a_bits)
    params = init_fp(cfg, seed=0)
    absmax = collect_absmax(cfg, params, [x])
    qnet = quantize_net(cfg, params, absmax)
    x_hat = quantize_input(qnet, x)
    hi = packing.int_range(a_bits, False)[1]
    seen = {}
    forward_int(qnet, x_hat, backend="xla",
                collect=lambda p, y: seen.update({p: np.asarray(y)}))
    for path, y in seen.items():
        if path == "head":
            continue  # raw int32 logits, not an activation edge
        assert y.min() >= 0 and y.max() <= hi, (path, y.min(), y.max())
    # at least one edge actually reaches the grid ceiling (saturation is
    # exercised, not vacuously passed)
    assert any(y.max() == hi for p, y in seen.items() if p != "head")


def test_avgpool_global_floor_rounding_vs_oracle(rng):
    """Global avg pool requant == int64 floor oracle, element-exact."""
    x = rng.integers(0, 256, size=(3, 8, 8, 16)).astype(np.int32)
    x = np.clip(x, 0, 127).astype(np.int8)
    m, d = vl.fold_avgpool_requant(64, 0.031, 0.017)
    pool = vl.QAvgPool2D(window=0, stride=1, m=m, d=d, out_bits=8)
    got = np.asarray(pool.apply(jnp.asarray(x)))
    s = x.astype(np.int64).sum(axis=(1, 2))
    want = np.clip(requantize_shift_i64(s, m, d), 0, 127)
    assert np.array_equal(got, want.astype(np.int8))


def test_avgpool_windowed_vs_oracle(rng):
    x = rng.integers(0, 16, size=(2, 6, 6, 8)).astype(np.int8)
    m, d = vl.fold_avgpool_requant(4, 0.02, 0.03)
    pool = vl.QAvgPool2D(window=2, stride=2, m=m, d=d, out_bits=4)
    got = np.asarray(pool.apply(jnp.asarray(x)))
    xs = x.astype(np.int64)
    s = (xs[:, 0::2, 0::2] + xs[:, 1::2, 0::2]
         + xs[:, 0::2, 1::2] + xs[:, 1::2, 1::2])
    want = np.clip(requantize_shift_i64(s, m, d), 0, 15)
    assert np.array_equal(got, want.astype(np.int8))


@pytest.mark.parametrize("out_bits", [8, 4, 2])
def test_residual_add_saturates_and_matches_oracle(out_bits, rng):
    """Two-scale integer add: exact vs the int64 oracle, and the clip
    actually saturates at the uint{8,4,2} ceiling for hot inputs."""
    hi_in = packing.int_range(8, False)[1]
    a = rng.integers(0, hi_in + 1, size=(2, 4, 4, 8)).astype(np.int8)
    b = rng.integers(0, hi_in + 1, size=(2, 4, 4, 8)).astype(np.int8)
    a[0, 0, 0, :] = hi_in          # force the saturating corner
    b[0, 0, 0, :] = hi_in
    m1, m2, d = vl.fold_add_requant(0.04, 0.03, 0.02)
    add = vl.QResidualAdd(m1=m1, m2=m2, d=d, out_bits=out_bits)
    got = np.asarray(add.apply(jnp.asarray(a), jnp.asarray(b)))
    hi = packing.int_range(out_bits, False)[1]
    want = np.clip((a.astype(np.int64) * m1 + b.astype(np.int64) * m2) >> d,
                   0, hi)
    assert np.array_equal(got, want.astype(np.int8))
    assert got.max() == hi         # the hot corner saturated


def test_maxpool_is_grid_preserving(rng):
    """Integer max pool == pooling the dequantized values then
    re-quantizing: order-preserving, so no requant params exist."""
    spec = QuantSpec.activation(4, 3.0)
    x = rng.integers(0, 16, size=(2, 8, 8, 4)).astype(np.int8)
    pool = vl.QMaxPool2D(window=2, stride=2)
    got = np.asarray(pool.apply(jnp.asarray(x)))
    xs = x
    want = np.maximum.reduce([xs[:, 0::2, 0::2], xs[:, 1::2, 0::2],
                              xs[:, 0::2, 1::2], xs[:, 1::2, 1::2]])
    assert np.array_equal(got, want)
    assert got.max() <= spec.int_max


# ------------------------------------------------------------ depthwise ---

def _dw_oracle(x, w_hat, kappa, lam, m, d, out_bits, stride, padding):
    """Independent numpy depthwise conv + eq.3/4 epilogue (int64)."""
    n, h, wd, c = x.shape
    fh, fw, _ = w_hat.shape
    xp = np.zeros((n, h + 2 * padding, wd + 2 * padding, c), np.int64)
    xp[:, padding:padding + h, padding:padding + wd] = x
    oh = (h + 2 * padding - fh) // stride + 1
    ow = (wd + 2 * padding - fw) // stride + 1
    phi = np.zeros((n, oh, ow, c), np.int64)
    for dy in range(fh):
        for dx in range(fw):
            sl = xp[:, dy:dy + stride * oh:stride,
                    dx:dx + stride * ow:stride]
            phi += sl * w_hat[dy, dx].astype(np.int64)
    phi_p = phi * kappa.astype(np.int64) + lam.astype(np.int64)
    y = requantize_shift_i64(phi_p, m.astype(np.int64), d)
    hi = packing.int_range(out_bits, False)[1]
    return np.clip(y, 0, hi).astype(np.int8)


@pytest.mark.parametrize("wb", [8, 4, 2])
def test_depthwise_lowerings_bit_exact(wb, rng):
    """qdot (block-diagonal) and per_group lowerings agree with each
    other and with the numpy depthwise oracle, per bit-width."""
    c, h = 8, 6
    p = {"w": jnp.asarray(rng.normal(size=(3, 3, c)).astype(np.float32)
                          * 0.4),
         "bn_scale": jnp.asarray((rng.normal(size=(c,)) * 0.05 + 0.4
                                  ).astype(np.float32)),
         "bn_bias": jnp.asarray((rng.normal(size=(c,)) * 0.02
                                 ).astype(np.float32))}
    spec_x = QuantSpec.activation(8, 2.0)
    spec_y = QuantSpec.activation(8, 1.5)
    dw = vl.quantize_depthwise(p, spec_x, spec_y, wb, stride=2, padding=1)
    x = rng.integers(0, 128, size=(2, h, h, c)).astype(np.int8)
    xj = jnp.asarray(x)
    got_qdot = np.asarray(dw.apply(xj, backend="xla", lowering="qdot"))
    got_pg = np.asarray(dw.apply(xj, backend="xla", lowering="per_group"))
    got_pg_pal = np.asarray(dw.apply(xj, backend="pallas_interpret",
                                     lowering="per_group"))
    w_hat = np.asarray(quantize(p["w"], calibrate_weight(p["w"], wb)))
    g = dw.gemm
    want = _dw_oracle(x, w_hat, np.asarray(g.kappa), np.asarray(g.lam),
                      np.asarray(g.m), g.d, g.out_bits, 2, 1)
    assert np.array_equal(got_qdot, want)
    assert np.array_equal(got_pg, want)
    assert np.array_equal(got_pg_pal, want)


def test_depthwise_auto_lowering_and_errors(rng):
    p = {"w": jnp.ones((3, 3, 4), jnp.float32) * 0.1,
         "bn_scale": jnp.ones((4,), jnp.float32),
         "bn_bias": jnp.zeros((4,), jnp.float32)}
    spec = QuantSpec.activation(8, 2.0)
    dw = vl.quantize_depthwise(p, spec, spec, 8, stride=1, padding=1)
    x = jnp.zeros((1, 4, 4, 4), jnp.int8)
    with pytest.raises(ValueError, match="unknown depthwise lowering"):
        dw.apply(x, lowering="nope")
    # auto under an explicit pallas-family backend takes the per-group
    # fused route; under xla the single block-diagonal GEMM
    assert dw._auto_lowering(x, "pallas_interpret") == "per_group"
    assert dw._auto_lowering(x, "xla") == "qdot"


# ----------------------------------------------------------- calibration ---

def test_calibrate_vision_stats(art):
    cfg, params, stats, absmax, _ = art["resnet8"]
    compute_paths = {t["layer"].path for t in trace_shapes(cfg)
                     if t["layer"].kind in ("conv", "dwconv", "linear")}
    assert set(stats) == compute_paths
    for path, st in stats.items():
        assert st.taps > 0 and st.a_absmax > 0, path
        assert st.sens(2) > st.sens(8) >= 0, path
    requant_paths = {t["layer"].path for t in trace_shapes(cfg)
                     if t["layer"].kind in ("conv", "dwconv",
                                            "avgpool_global", "add")}
    assert requant_paths <= set(absmax)
    assert "__input__" in absmax


def test_conv_tap_restores_previous():
    calls = []
    with vl.conv_tap(lambda p, x: calls.append("a")):
        with vl.conv_tap(lambda p, x: calls.append("b")):
            vl.linear_fp({"w": jnp.ones((2, 2))}, jnp.ones((1, 2)))
        vl.linear_fp({"w": jnp.ones((2, 2))}, jnp.ones((1, 2)))
    vl.linear_fp({"w": jnp.ones((2, 2))}, jnp.ones((1, 2)))
    assert calls == ["b", "a"]


def test_quantize_net_missing_absmax_raises(art):
    cfg, params, _, absmax, _ = art["resnet8"]
    partial = {k: v for k, v in absmax.items() if k != "s2/c1"}
    with pytest.raises(KeyError, match="s2/c1"):
        quantize_net(cfg, params, partial)


# --------------------------------------------------------------- engine ---

def test_vision_engine_waves_and_utilization(art):
    """Ragged 6-request list in waves of 4 on a dp=2 mesh: outputs equal
    the meshless forward and the utilization means are exact."""
    from repro.serve.engine import VisionEngine

    cfg, params, _, absmax, x = art["resnet8"]
    qnet = quantize_net(cfg, params, absmax)
    rng = np.random.default_rng(3)
    images = rng.uniform(0, 1, size=(6, *cfg.in_hw, cfg.in_ch)).astype(
        np.float32)
    mesh = jax.make_mesh((2, 1), ("data", "model"),
                         devices=jax.devices()[:2])
    eng = VisionEngine(qnet, batch_size=4, mesh=mesh, backend="xla")
    got = eng.run(images)
    want = np.asarray(forward_int(
        qnet, quantize_input(qnet, images), backend="xla"))
    assert np.array_equal(got, want)
    rep = eng.utilization_report()
    # wave 1: 4/4 real -> [1, 1]; wave 2: 2/4 -> [1, 0]
    assert rep["waves"] == 2 and rep["devices"] == 2
    assert rep["per_device"] == [1.0, 0.5]
    assert rep["mean_util"] == pytest.approx(0.75)
    assert eng.artifact_bytes() == vision_artifact_bytes(qnet)


def test_vision_engine_ragged_batch_over_dp(art):
    """batch_size % dp != 0 no longer raises: the slot array is padded
    to whole per-device blocks and results still equal the meshless
    forward (the pads never reach admission)."""
    from repro.serve.engine import VisionEngine

    cfg, params, _, absmax, _ = art["resnet8"]
    qnet = quantize_net(cfg, params, absmax)
    rng = np.random.default_rng(5)
    images = rng.uniform(0, 1, size=(5, *cfg.in_hw, cfg.in_ch)).astype(
        np.float32)
    mesh = jax.make_mesh((4, 1), ("data", "model"),
                         devices=jax.devices()[:4])
    eng = VisionEngine(qnet, batch_size=3, mesh=mesh, backend="xla")
    got = eng.run(images)
    want = np.asarray(forward_int(
        qnet, quantize_input(qnet, images), backend="xla"))
    assert np.array_equal(got, want)
    assert eng.utilization_report()["devices"] == 4


# ------------------------------------------------------------ CLI (slow) ---

@pytest.mark.slow
def test_vision_cli(tmp_path):
    from tests.test_launchers import _run

    plan = tmp_path / "vplan.json"
    r = _run(["repro.launch.vision", "--net", "resnet8", "--smoke",
              "--budget", "auto", "--out", str(plan)])
    assert "vision deploy done" in r.stdout, r.stderr[-1500:]
    assert plan.exists()
    r2 = _run(["repro.launch.vision", "--net", "resnet8", "--smoke",
               "--from-plan", str(plan)])
    assert "vision deploy done" in r2.stdout, r2.stderr[-1500:]


@pytest.mark.slow
def test_e2e_benchmark_smoke(tmp_path):
    import json

    from tests.test_launchers import _run

    out = tmp_path / "BENCH_e2e.json"
    r = _run(["benchmarks.e2e_networks", "--smoke", "--nets", "resnet8",
              "--bits", "8", "--devices", "1,2", "--json", str(out),
              "--no-per-layer"],
             extra_env={"XLA_FLAGS":
                        "--xla_force_host_platform_device_count=8"})
    assert out.exists(), r.stderr[-1500:]
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks import schema
    schema.validate_file(out)           # the checked-in artifact schema
    rows = json.load(open(out))["rows"]
    totals = [row for row in rows if row["layer"] == "total"]
    assert {row["devices"] for row in totals} == {1, 2}
    assert all("us_per_call" in row and "bits" in row for row in rows)
    # the planner-mixed point always rides along the uniform sweep
    assert {row["bits"] for row in totals} == {"8", "mixed"}
