"""Cluster-parallel execution path (`repro.kernels.api.qdot_sharded` /
`qconv_sharded` + `repro.parallel.sharding` packed-artifact rules).

The conftest forces 8 host-platform devices, so these run the real
shard_map path on an 8-"core" cluster mesh on CPU (the CI parity job pins
the same XLA_FLAGS). Core claim under test: with packed weights sharded
over the output-feature axis and K unsharded, the sharded op is
**bit-exact** vs the single-device `eager_ref` oracle across the {8,4,2}²
bit grid — the psum-free epilogue argument of the paper's cluster.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.quantize import QuantizedLinearParams
from repro.kernels import api
from repro.parallel.sharding import (packed_conv_specs, packed_linear_specs,
                                     shard_packed_conv, shard_packed_linear)

BITS = (8, 4, 2)
NDEV = len(jax.devices())

needs_cluster = pytest.mark.skipif(
    NDEV < 2, reason="needs >=2 devices (XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8)")


def _mesh(dp, tp):
    return jax.make_mesh((dp, tp), ("data", "model"),
                         devices=jax.devices()[: dp * tp])


def _mesh_shapes():
    """(dp, tp) variants that fit the available devices: pure DP, pure
    TP, and mixed."""
    shapes = [(NDEV, 1), (1, NDEV)]
    if NDEV >= 4:
        shapes.append((2, NDEV // 2))
    return shapes


def _mixed_mesh():
    """One DP x TP mesh exercising both axes at once (the {8,4,2}² grid
    runs here; the full layout sweep runs at fixed bits). Capped at 2x2 —
    per-call compile cost on host devices grows with device count, and 4
    devices already prove the DP x TP composition; the 8-device layouts
    are covered by the *_all_mesh_layouts tests."""
    return _mesh(2, 2) if NDEV >= 4 else _mesh(1, NDEV)


def _mk_qdot_params(rng, a_bits, w_bits, K=256, N=128):
    lo, hi = packing.int_range(w_bits, True)
    w = rng.integers(lo, hi + 1, size=(K, N)).astype(np.int8)
    wp = packing.pack(jnp.asarray(w), w_bits, axis=0)
    return QuantizedLinearParams(
        w_packed=wp, w_bits=w_bits, a_bits=a_bits, a_signed=False,
        kappa=jnp.asarray(rng.integers(-64, 64, (N,)).astype(np.int32)),
        lam=jnp.asarray(rng.integers(-2**16, 2**16, (N,)).astype(np.int32)),
        m=jnp.asarray(rng.integers(0, 2**15, (N,)).astype(np.int32)),
        d=18, out_bits=8, k_logical=K)


def _mk_acts(rng, a_bits, M=16, K=256):
    lo, hi = packing.int_range(a_bits, False)
    return jnp.asarray(rng.integers(lo, hi + 1, (M, K)).astype(np.int8))


def _mk_conv(rng, a_bits, w_bits, H=8, W=8, cin=24, cout=32):
    from repro.core import calibrate_activation, calibrate_weight
    from repro.core.quantize import QuantSpec, quantize
    from repro.kernels.qconv import quantize_conv

    x = np.maximum(rng.normal(size=(2, H, W, cin)), 0).astype(np.float32)
    w = rng.normal(size=(3, 3, cin, cout)).astype(np.float32) * 0.08
    sw = calibrate_weight(jnp.asarray(w), w_bits)
    sx = calibrate_activation(x, a_bits, 100.0)
    sy = QuantSpec.activation(a_bits, 8.0)
    qp = quantize_conv(jnp.asarray(w), sw,
                       rng.normal(size=(cout,)).astype(np.float32) * .05 + .3,
                       np.zeros((cout,), np.float32), sx, sy, 1, 1)
    return qp, quantize(jnp.asarray(x), sx)


# ----------------------------------------------------- sharding rules ---

@needs_cluster
def test_packed_linear_specs_shard_n_only(rng):
    """The packed K axis must never be sharded; N + epilogue vectors
    shard together over the tp axis."""
    params = _mk_qdot_params(rng, 8, 4)
    mesh = _mesh(1, NDEV)
    specs = packed_linear_specs(params, mesh)
    assert tuple(specs["w_packed"]) == (None, "model")
    assert tuple(specs["kappa"]) == ("model",)
    assert tuple(specs["lam"]) == ("model",)
    assert tuple(specs["m"]) == ("model",)


@needs_cluster
def test_packed_specs_raise_on_ragged_n(rng):
    """N not divisible by tp is a mis-sized artifact, not a fallback."""
    params = _mk_qdot_params(rng, 8, 8, N=130)  # 130 % NDEV != 0 for 4/8
    mesh = _mesh(1, NDEV)
    if 130 % NDEV == 0:
        pytest.skip("N divides this device count")
    with pytest.raises(ValueError, match="not divisible"):
        packed_linear_specs(params, mesh)
    with pytest.raises(ValueError, match="not divisible"):
        api.qdot(params, _mk_acts(rng, 8), mesh=mesh)


def test_packed_specs_tp1_replicated(rng):
    """A tp=1 (or absent) axis yields fully-replicated specs."""
    params = _mk_qdot_params(rng, 8, 8)
    mesh = _mesh(max(NDEV, 1), 1)
    specs = packed_linear_specs(params, mesh)
    assert tuple(specs["w_packed"]) == (None, None)


# ------------------------------------------------------- qdot parity ---

@needs_cluster
@pytest.mark.parametrize("ab", BITS)
@pytest.mark.parametrize("wb", BITS)
def test_qdot_sharded_bit_exact(ab, wb, rng):
    """Sharded qdot == single-device eager_ref across the bit grid on a
    mixed DP x TP mesh."""
    params = _mk_qdot_params(rng, ab, wb)
    x = _mk_acts(rng, ab)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    got = np.asarray(api.qdot(params, x, mesh=_mixed_mesh()))
    assert np.array_equal(got, want), (ab, wb)


@needs_cluster
def test_qdot_sharded_all_mesh_layouts(rng):
    """Pure-DP, pure-TP, and mixed meshes all agree with the oracle
    (fixed bits; the bit grid runs on the mixed mesh above)."""
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    for dp, tp in _mesh_shapes():
        got = np.asarray(api.qdot(params, x, mesh=_mesh(dp, tp)))
        assert np.array_equal(got, want), (dp, tp)


@needs_cluster
def test_qdot_sharded_backends_and_presharded(rng):
    """Explicit backends agree on the sharded path; pre-sharding the
    artifact with `shard_packed_linear` (the fig9/serving setup) changes
    placement, not values."""
    params = _mk_qdot_params(rng, 4, 4)
    x = _mk_acts(rng, 4)
    want = np.asarray(api.qdot(params, x, backend="eager_ref"))
    mesh = _mesh(1, NDEV)
    for backend in ("xla", "pallas_interpret"):
        got = np.asarray(api.qdot(params, x, mesh=mesh, backend=backend))
        assert np.array_equal(got, want), backend
    sharded = shard_packed_linear(params, mesh)
    got = np.asarray(api.qdot(sharded, x, mesh=mesh))
    assert np.array_equal(got, want)


@needs_cluster
def test_qdot_sharded_ragged_m_pads(rng):
    """Row counts that don't divide dp are padded and sliced back."""
    params = _mk_qdot_params(rng, 8, 4)
    for m in (1, 13):
        x = _mk_acts(rng, 8, M=m)
        want = np.asarray(api.qdot(params, x, backend="eager_ref"))
        got = np.asarray(api.qdot(params, x, mesh=_mesh(NDEV, 1)))
        assert got.shape == want.shape == (m, 128)
        assert np.array_equal(got, want), m


@needs_cluster
def test_qdot_sharded_lead_dims_and_scale(rng):
    """Leading dims restore; per-channel dequant scale shards with N."""
    params = _mk_qdot_params(rng, 4, 4)
    x3 = _mk_acts(rng, 4, M=12).reshape(3, 4, 256)
    mesh = _mesh(2, NDEV // 2) if NDEV >= 4 else _mesh(1, NDEV)
    got = np.asarray(api.qdot(params, x3, mesh=mesh))
    want = np.asarray(api.qdot(params, x3, backend="xla"))
    assert got.shape == (3, 4, 128)
    assert np.array_equal(got, want)
    scale = rng.uniform(0.5, 2.0, size=(128,)).astype(np.float32)
    got = np.asarray(api.qdot(params, x3, mesh=mesh, epilogue="dequant",
                              scale=jnp.asarray(scale)), np.float32)
    want = np.asarray(api.qdot(params, x3, backend="xla",
                               epilogue="dequant",
                               scale=jnp.asarray(scale)), np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-2)


@needs_cluster
def test_qdot_sharded_rejects_eager_ref(rng):
    params = _mk_qdot_params(rng, 8, 8)
    with pytest.raises(ValueError, match="eager_ref"):
        api.qdot(params, _mk_acts(rng, 8), mesh=_mesh(1, NDEV),
                 backend="eager_ref")


# ------------------------------------------------------ qconv parity ---

@needs_cluster
@pytest.mark.parametrize("ab", BITS)
@pytest.mark.parametrize("wb", BITS)
def test_qconv_sharded_bit_exact(ab, wb, rng):
    """Sharded qconv == single-device eager_ref across the bit grid on a
    mixed DP x TP mesh."""
    qp, xq = _mk_conv(rng, ab, wb)
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    got = np.asarray(api.qconv(qp, xq, mesh=_mixed_mesh()))
    assert np.array_equal(got, want), (ab, wb)


@needs_cluster
def test_qconv_sharded_all_mesh_layouts(rng):
    """Every mesh layout agrees with the oracle at fixed bits."""
    qp, xq = _mk_conv(rng, 4, 4)
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    for dp, tp in _mesh_shapes():
        got = np.asarray(api.qconv(qp, xq, mesh=_mesh(dp, tp)))
        assert np.array_equal(got, want), (dp, tp)


@needs_cluster
def test_qconv_sharded_presharded_and_ragged_batch(rng):
    """`shard_packed_conv` placement + a batch that doesn't divide dp."""
    qp, xq = _mk_conv(rng, 4, 4)   # batch of 2
    mesh = _mesh(1, NDEV)
    specs = packed_conv_specs(qp, mesh)
    assert tuple(specs["w_packed_fused"]) == (None, "model")
    sharded = shard_packed_conv(qp, mesh)
    want = np.asarray(api.qconv(qp, xq, backend="eager_ref"))
    got = np.asarray(api.qconv(sharded, xq, mesh=mesh))
    assert np.array_equal(got, want)
    if NDEV >= 4:  # 2 images over dp=4: padded waves sliced back
        got = np.asarray(api.qconv(qp, xq, mesh=_mesh(4, NDEV // 4)))
        assert got.shape == want.shape
        assert np.array_equal(got, want)
