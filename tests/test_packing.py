"""Pack/unpack round-trips + chunk-planar order invariants (hypothesis)."""
import numpy as np
import jax.numpy as jnp
import pytest
from conftest import hypothesis_api

# guarded: property tests skip (not hard-fail) without hypothesis
given, settings, st = hypothesis_api()

from repro.core import packing


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("signed", [True, False])
def test_roundtrip(bits, signed, rng):
    lo, hi = packing.int_range(bits, signed)
    x = rng.integers(lo, hi + 1, size=(3, 4, 256)).astype(np.int8)
    p = packing.pack(jnp.asarray(x), bits, axis=-1)
    u = packing.unpack(p, bits, signed, axis=-1)
    assert np.array_equal(np.asarray(u), x)
    if bits != 8:
        assert p.shape[-1] == 256 // packing.pack_factor(bits)


@pytest.mark.parametrize("bits", [4, 2])
@pytest.mark.parametrize("axis", [0, 1, -1])
def test_roundtrip_axes(bits, axis, rng):
    lo, hi = packing.int_range(bits, True)
    x = rng.integers(lo, hi + 1, size=(256, 256, 2)).astype(np.int8)
    if x.shape[axis] % packing.CHUNK:
        pytest.skip("axis not chunk aligned")
    p = packing.pack(jnp.asarray(x), bits, axis=axis)
    u = packing.unpack(p, bits, True, axis=axis)
    assert np.array_equal(np.asarray(u), x)


@given(bits=st.sampled_from([4, 2]), signed=st.booleans(),
       n_chunks=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_roundtrip_property(bits, signed, n_chunks, seed):
    rng = np.random.default_rng(seed)
    lo, hi = packing.int_range(bits, signed)
    x = rng.integers(lo, hi + 1,
                     size=(packing.CHUNK * n_chunks,)).astype(np.int8)
    p = packing.pack(jnp.asarray(x), bits, axis=-1)
    u = packing.unpack(p, bits, signed, axis=-1)
    assert np.array_equal(np.asarray(u), x)


@pytest.mark.parametrize("bits", [4, 2])
def test_planar_order_matches_perm(bits, rng):
    """unpack_planes concat order == planar_perm of logical order."""
    k = 2 * packing.CHUNK
    lo, hi = packing.int_range(bits, True)
    x = rng.integers(lo, hi + 1, size=(k,)).astype(np.int8)
    p = packing.pack(jnp.asarray(x), bits, axis=-1)
    planes = packing.unpack_planes(jnp.asarray(p), bits, True)
    pf = packing.pack_factor(bits)
    sub = packing.CHUNK // pf
    planar = np.stack([np.asarray(pl).reshape(-1, sub) for pl in planes],
                      axis=1).reshape(-1)
    assert np.array_equal(planar, x[packing.planar_perm(k, bits)])


@pytest.mark.parametrize("bits", [4, 2])
def test_pack_assert_range_raises_instead_of_truncating(bits, rng):
    """Out-of-range values raise with the host-side guard armed — without
    it `pack` keeps only the low bits and silently corrupts the artifact."""
    lo, hi = packing.int_range(bits, True)
    ok = rng.integers(lo, hi + 1, size=(256,)).astype(np.int8)
    bad = ok.copy()
    bad[13] = hi + 1  # truncates to a *different valid value* without guard
    # guard off: silent truncation (documents the failure mode)
    corrupted = packing.unpack(packing.pack(jnp.asarray(bad), bits), bits,
                               True)
    assert not np.array_equal(np.asarray(corrupted), bad)
    # guard on: raises, and in-range packing is unchanged
    with pytest.raises(ValueError, match="silently truncate"):
        packing.pack(jnp.asarray(bad), bits, assert_range=True)
    a = packing.pack(jnp.asarray(ok), bits)
    b = packing.pack(jnp.asarray(ok), bits, assert_range=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_pack_assert_range_unsigned_grid():
    x = jnp.asarray(np.array([0, 15, -1], np.int8))
    with pytest.raises(ValueError, match="unsigned"):
        packing.pack(packing.pad_to_chunk(x), 4, assert_range=True,
                     signed=False)
    with pytest.raises(ValueError):  # 15 valid unsigned, not signed
        packing.pack(packing.pad_to_chunk(jnp.asarray(
            np.array([0, 15], np.int8))), 4, assert_range=True, signed=True)


def test_pad_to_chunk():
    x = jnp.ones((3, 200), jnp.int8)
    y = packing.pad_to_chunk(x, axis=-1)
    assert y.shape == (3, 256)
    assert int(y[:, 200:].sum()) == 0
