"""The multi-pod dry-run deliverable: every (arch x shape x mesh) cell has
a compile artifact with sane roofline terms (run `python -m
repro.launch.dryrun --all --mesh {pod,multipod}` to regenerate)."""
import json
import pathlib

import pytest

from repro.configs.base import cells_for
from repro.models.api import list_archs

DRY = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


@pytest.mark.skipif(not DRY.exists(), reason="dry-run not generated yet")
def test_every_cell_compiled_both_meshes():
    missing = []
    for arch in list_archs():
        for shape in cells_for(arch):
            for mesh in ("pod", "multipod"):
                f = DRY / f"{arch}__{shape.name}__{mesh}.json"
                if not f.exists():
                    missing.append(f.name)
                    continue
                r = json.loads(f.read_text())
                t = r["roofline"]
                assert t["compute_s"] >= 0 and t["memory_s"] > 0
                assert r["bytes_per_device"]["total"] > 0
                assert r["devices"] == (512 if mesh == "multipod" else 256)
    assert not missing, missing


@pytest.mark.skipif(not DRY.exists(), reason="dry-run not generated yet")
def test_cell_count_matches_assignment():
    # 10 archs x 4 shapes = 40 assigned cells; 7 long_500k skips documented
    # in DESIGN.md -> 33 runnable cells per mesh
    n = sum(len(cells_for(a)) for a in list_archs())
    assert n == 33
    skipped = 40 - n
    assert skipped == 7
