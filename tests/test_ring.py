"""shard_map ring collectives vs dense references."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.mesh import make_host_mesh
from repro.parallel.ring import collective_matmul, ring_decode_attention
from repro.parallel.ctx import use_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(model=max(len(jax.devices()) // 1, 1))


def test_collective_matmul_matches_dense(mesh):
    n = mesh.shape["model"]
    rng = np.random.default_rng(0)
    M, K, N = 16, 32 * n, 24 * n
    x = rng.normal(size=(M, K)).astype(np.float32)
    w = rng.normal(size=(K, N)).astype(np.float32)
    with use_mesh(mesh):
        y = collective_matmul(jnp.asarray(x), jnp.asarray(w), mesh)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=2e-4, atol=1e-4)


def test_ring_decode_attention_matches_dense(mesh):
    n = mesh.shape["model"]
    rng = np.random.default_rng(1)
    B, T, H, Dh = 2, 16 * n, 4, 32
    q = rng.normal(size=(B, H, Dh)).astype(np.float32)
    k = rng.normal(size=(B, T, H, Dh)).astype(np.float32)
    v = rng.normal(size=(B, T, H, Dh)).astype(np.float32)
    # causal-style validity: first t_valid positions per row
    t_valid = rng.integers(1, T, size=(B,))
    mask = np.arange(T)[None, :] < t_valid[:, None]
    with use_mesh(mesh):
        out = ring_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), jnp.asarray(mask), mesh)
    # dense reference
    s = np.einsum("bhd,bthd->bht", q, k) / np.sqrt(Dh)
    s = np.where(mask[:, None, :], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = np.where(mask[:, None, :], p, 0)
    ref = np.einsum("bht,bthd->bhd", p / p.sum(-1, keepdims=True), v)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_ring_attention_empty_shard_safe(mesh):
    """A shard whose mask is entirely False must contribute zeros, not
    NaNs (happens whenever index < shard offset in long-context decode)."""
    n = mesh.shape["model"]
    B, T, H, Dh = 1, 8 * n, 2, 16
    rng = np.random.default_rng(2)
    q = rng.normal(size=(B, H, Dh)).astype(np.float32)
    k = rng.normal(size=(B, T, H, Dh)).astype(np.float32)
    v = rng.normal(size=(B, T, H, Dh)).astype(np.float32)
    mask = np.zeros((B, T), bool)
    mask[:, :3] = True  # only the first shard sees valid keys
    with use_mesh(mesh):
        out = ring_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), jnp.asarray(mask), mesh)
    assert np.isfinite(np.asarray(out)).all()
