"""Sharding rules, divisibility fallback, attention strategy, and a real
jit'd train step on the host mesh with activation constraints active."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.parallel.sharding import DEFAULT_RULES, shard_spec_for
from repro.parallel.ctx import use_mesh


def test_rules_resolution():
    mesh = make_host_mesh()
    spec = DEFAULT_RULES.spec(("batch", None, "mlp"), mesh)
    assert spec[0] in ("data", ("data",)) or spec[0] is None or \
        isinstance(spec[0], tuple)


def test_divisibility_fallback():
    mesh = make_host_mesh()
    # dim 3 not divisible by any axis size > 1 -> replicated
    spec = shard_spec_for((3, 8), ("batch", "mlp"), mesh)
    n = mesh.shape.get("data", 1)
    if n > 1:
        assert spec[0] is None


def test_dedup_same_mesh_axis():
    """experts and expert_mlp both map to model: second occurrence must be
    dropped (PartitionSpec can't reuse a mesh axis)."""
    mesh = make_host_mesh()
    spec = DEFAULT_RULES.spec(("experts", "embed", "expert_mlp"), mesh)
    flat = []
    for e in spec:
        if isinstance(e, tuple):
            flat.extend(e)
        elif e is not None:
            flat.append(e)
    assert len(flat) == len(set(flat))


def test_attn_strategy():
    from repro.nn.attention import attn_strategy
    from repro.parallel.ctx import activation_sharding
    mesh = make_host_mesh()  # production mesh needs 256 devices
    with activation_sharding(mesh):
        m = mesh.shape.get("model", 1)
        assert attn_strategy(m, 1, 128, 128) == "tp"
        if m > 1:
            assert attn_strategy(m + 1, 1, m * 4, m * 4) == "cp"
    assert attn_strategy(1, 1, 4, 4) == "none"  # no active mesh


def test_host_mesh_train_step_with_constraints():
    from repro.configs.base import ShapeConfig
    from repro.configs.olmo_1b import smoke_config
    from repro.models.api import build
    from repro.train.step import TrainStepConfig, make_train_fns

    cfg = smoke_config()
    model = build(cfg)
    mesh = make_host_mesh()
    init_fn, step, shards = make_train_fns(
        model, mesh, ShapeConfig("t", 16, 2, "train"), TrainStepConfig())
    state = init_fn(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    with use_mesh(mesh):
        state, m = jax.jit(step)(state, batch)
    assert np.isfinite(float(m["loss"]))
