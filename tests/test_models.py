"""Per-arch smoke tests: reduced configs, one forward + loss + grad, shape
and finiteness checks (deliverable f)."""
import importlib
import pkgutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cpkg
from repro.models.api import build, list_archs

MODS = sorted(m.name for m in pkgutil.iter_modules(cpkg.__path__)
              if m.name != "base")
# big/exotic archs are several seconds each even at smoke size; keep a
# representative fast set per family, run the rest with --runslow
_HEAVY = {"recurrentgemma_9b", "llama3p2_vision_90b", "llama4_maverick_400b",
          "kimi_k2_1t", "seamless_m4t_large_v2", "gemma3_1b", "qwen2p5_3b"}


def _arch_params(names):
    return [pytest.param(n, marks=pytest.mark.slow) if n in _HEAVY else n
            for n in names]


@pytest.mark.parametrize("modname", _arch_params(MODS))
def test_smoke_forward(modname):
    m = importlib.import_module(f"repro.configs.{modname}")
    cfg = m.smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.family == "encdec" or cfg.cross_every:
        sl = S if cfg.family == "encdec" else cfg.src_len
        batch["src_embed"] = jnp.ones((B, sl, cfg.d_model),
                                      jnp.bfloat16) * 0.01
    from repro.nn.layers import padded_vocab
    logits, aux, _ = model.forward(params, batch)
    assert logits.shape == (B, S, padded_vocab(cfg.vocab))
    # padded vocab rows masked to -1e9; real rows finite
    real = np.asarray(logits, np.float32)[..., :cfg.vocab]
    assert np.isfinite(real).all()
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize(
    "modname",
    _arch_params(["qwen2p5_3b", "mamba2_370m", "recurrentgemma_9b"]))
def test_grad_finite(modname):
    m = importlib.import_module(f"repro.configs.{modname}")
    cfg = m.smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    g = jax.grad(lambda p: model.loss(p, batch))(params)
    gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_all_archs_registered():
    assert len(list_archs()) == 10


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    from repro.models.api import get_config
    c = get_config("gemma3-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff,
            c.vocab) == (26, 1152, 4, 1, 6912, 262144)
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.vocab) == \
        (61, 7168, 64, 8, 163840)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff) == (384, 8, 2048)
    c = get_config("llama-3.2-vision-90b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == \
        (100, 8192, 28672, 128256)
    c = get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.d_state, c.vocab) == \
        (48, 1024, 128, 50280)
    c = get_config("seamless-m4t-large-v2")
    assert (c.d_model, c.d_ff, c.vocab) == (1024, 8192, 256206)
