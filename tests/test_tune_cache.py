"""The measured autotune cache (`repro.kernels.tune`), schema v3.

Entries carry {block, pipeline, us}; this file pins the artifact
lifecycle the CI slow lane depends on:

* sweep -> persist -> reload round-trip: `autotune_qdot`/`autotune_qconv`
  winners survive save/clear/load/merge with block AND pipeline intact,
  and api.* consumes both on the reloaded cache;
* stale-version artifacts fail loudly (`load` raises; the env preload
  downgrades to a RuntimeWarning but loads nothing);
* merge() conflict semantics: incoming entry wins (last measurement is
  freshest) — pinned so cache-artifact merging in CI stays deterministic;
* REPRO_QTUNE_CACHE pointing at a missing path warns once and falls back
  to the analytic selectors.
"""
import json
import warnings

import numpy as np
import pytest

from repro.kernels import api, tune


@pytest.fixture(autouse=True)
def _clean_cache():
    tune.clear()
    yield
    tune.clear()


def test_entry_roundtrip_carries_pipeline_and_us(tmp_path):
    tune.record_block("qdot", (64, 256, 256), 4, 4, "pallas_interpret",
                      (32, 128, 128), pipeline="double_buffer", us=12.5)
    f = tmp_path / "tune.json"
    tune.save(f)
    tune.clear()
    assert tune.get_block("qdot", (64, 256, 256), 4, 4,
                          "pallas_interpret") is None
    tune.merge(tune.load(f))
    e = tune.get_entry("qdot", (64, 256, 256), 4, 4, "pallas_interpret")
    assert e == {"block": (32, 128, 128), "pipeline": "double_buffer",
                 "us": 12.5}
    assert tune.get_pipeline("qdot", (64, 256, 256), 4, 4,
                             "pallas_interpret") == "double_buffer"
    # the artifact is the versioned v3 schema
    d = json.loads(f.read_text())
    assert d["version"] == tune.CACHE_VERSION == 3
    (entry,) = d["entries"].values()
    assert set(entry) == {"block", "pipeline", "us"}


def test_record_rejects_unknown_pipeline():
    with pytest.raises(ValueError, match="unknown pipeline mode"):
        tune.record_block("qdot", (8, 128, 128), 8, 8, "xla",
                          (8, 128, 128), pipeline="bogus")


@pytest.mark.slow
def test_sweep_persist_reload_roundtrip(tmp_path, rng):
    """The full lifecycle: measured sweep -> JSON artifact -> fresh
    process state -> api picks up both the tile and the pipeline mode."""
    params, xp = tune._mk_qdot_artifact(rng, 32, 256, 128, 4, 4)
    blk, pipe = tune.autotune_qdot(
        params, xp, backend="pallas_interpret", iters=1,
        candidates=[(32, 128, 128), (32, 128, 256)])
    assert pipe in tune.PIPELINE_MODES
    cparams, x = tune._mk_qconv_artifact(rng, 8, 8, 16, 128, 3, 3, 1, 1,
                                         4, 4)
    cblk, cpipe = tune.autotune_qconv(cparams, x,
                                      backend="pallas_interpret", iters=1)
    f = tmp_path / "tune.json"
    tune.save(f)
    tune.clear()

    tune.merge(tune.load(f))
    e = tune.get_entry("qdot", (32, 256, 128), 4, 4, "pallas_interpret")
    assert tuple(e["block"]) == blk and e["pipeline"] == pipe
    assert e["us"] is not None and e["us"] > 0
    shape = (1, 8, 8, 16, 3, 3, 1, 1, 128, 1)
    ce = tune.get_entry("qconv", shape, 4, 4, "pallas_interpret")
    assert tuple(ce["block"]) == cblk and ce["pipeline"] == cpipe
    # the reloaded winners are live: api resolves them and stays bit-exact
    want = np.asarray(api.qdot_packed(params, xp, backend="eager_ref"))
    got = np.asarray(api.qdot_packed(params, xp,
                                     backend="pallas_interpret"))
    assert np.array_equal(got, want)


def test_stale_version_fails_loudly(tmp_path):
    f = tmp_path / "stale.json"
    f.write_text(json.dumps({"version": 2, "blocks":
                             {"qdot|8x128x128|a8w8|xla": [8, 128, 128]}}))
    with pytest.raises(ValueError, match="unsupported tune-cache version"):
        tune.load(f)


def test_merge_conflict_incoming_wins(tmp_path):
    tune.record_block("qdot", (64, 256, 256), 4, 4, "xla",
                      (32, 128, 128), pipeline="off")
    other = tune.TuneCache()
    other.put("qdot", (64, 256, 256), 4, 4, "xla", (64, 256, 256),
              pipeline="double_buffer", us=3.0)
    other.put("qdot", (8, 128, 128), 8, 8, "xla", (8, 128, 128))
    tune.merge(other)
    e = tune.get_entry("qdot", (64, 256, 256), 4, 4, "xla")
    assert e["block"] == (64, 256, 256)          # incoming replaced ours
    assert e["pipeline"] == "double_buffer"
    assert tune.get_block("qdot", (8, 128, 128), 8, 8, "xla") == \
        (8, 128, 128)                            # disjoint keys union


def _reset_env_preload(monkeypatch, path):
    monkeypatch.setenv(tune.CACHE_ENV, str(path))
    monkeypatch.setattr(tune, "_ENV_LOADED", False)


def test_env_preload_missing_path_warns(tmp_path, monkeypatch):
    _reset_env_preload(monkeypatch, tmp_path / "nope.json")
    with pytest.warns(RuntimeWarning, match="does not exist"):
        assert tune.get_block("qdot", (8, 128, 128), 8, 8, "xla") is None
    # one warning total: the preload latches
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        tune.get_block("qdot", (8, 128, 128), 8, 8, "xla")


def test_env_preload_stale_artifact_warns_not_raises(tmp_path, monkeypatch):
    f = tmp_path / "stale.json"
    f.write_text(json.dumps({"version": 1, "blocks": {}}))
    _reset_env_preload(monkeypatch, f)
    with pytest.warns(RuntimeWarning, match="unsupported tune-cache"):
        assert tune.get_block("qdot", (8, 128, 128), 8, 8, "xla") is None


def test_env_preload_valid_artifact_loads(tmp_path, monkeypatch):
    tune.record_block("qdot", (64, 256, 256), 4, 4, "pallas_interpret",
                      (32, 128, 256), pipeline="double_buffer")
    f = tmp_path / "tune.json"
    tune.save(f)
    tune.clear()
    _reset_env_preload(monkeypatch, f)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert tune.get_pipeline("qdot", (64, 256, 256), 4, 4,
                                 "pallas_interpret") == "double_buffer"
