"""Serving engine: batched generation with prefill+decode, incl. packed
int weights (the paper's deployment mode)."""
import dataclasses

import jax
import numpy as np

from repro.configs.qwen2p5_3b import smoke_config
from repro.models.api import build
from repro.serve.engine import Engine, Request


def test_generate_greedy():
    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=32)
    reqs = [Request(prompt=np.array([3, 5, 7], np.int32), max_new_tokens=5),
            Request(prompt=np.array([11, 2], np.int32), max_new_tokens=5)]
    out = eng.generate(reqs)
    assert len(out) == 2
    for r in out:
        assert r.out is not None and 1 <= len(r.out) <= 5
        assert (r.out >= 0).all() and (r.out < cfg.vocab).all()


def test_generate_multiwave_pads_never_leak():
    """requests % batch != 0: the last wave is padded with filler requests;
    `generate` must return exactly the caller's request objects, in order —
    the old `max_new_tokens > 1 or out is not None` filter admitted pads
    once outputs were assigned."""
    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=32)
    reqs = [Request(prompt=np.array([3 + i, 5], np.int32),
                    max_new_tokens=(1 if i == 0 else 3))  # real max_new=1 too
            for i in range(5)]
    out = eng.generate(reqs)
    assert len(out) == 5
    # identity, not just count: every returned object IS an input request
    for got, want in zip(out, reqs):
        assert got is want
        assert got.out is not None and len(got.out) <= got.max_new_tokens
    # single-prompt pathological case: one request, batch 4
    eng4 = Engine(model, params, batch_size=4, max_len=32)
    solo = [Request(prompt=np.array([7], np.int32), max_new_tokens=2)]
    out4 = eng4.generate(solo)
    assert len(out4) == 1 and out4[0] is solo[0]


def test_engine_wave_sharding_ragged():
    """Mesh-sharded engine == meshless engine on a ragged request list
    (5 requests, batch 4 -> a full wave + a 1/4 wave), with a sane
    per-device utilization report."""
    import pytest

    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    tp = len(jax.devices()) // 4
    mesh = jax.make_mesh((4, tp), ("data", "model"),
                         devices=jax.devices()[: 4 * tp])
    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mk = lambda: [Request(prompt=np.array([3 + i, 5], np.int32),
                          max_new_tokens=3) for i in range(5)]
    want = Engine(model, params, batch_size=4, max_len=32).generate(mk())
    eng = Engine(model, params, batch_size=4, max_len=32, mesh=mesh)
    reqs = mk()
    got = eng.generate(reqs)
    assert len(got) == 5 and all(g is r for g, r in zip(got, reqs))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.out, w.out)
    rep = eng.utilization_report()
    assert rep["devices"] == 4 and rep["waves"] == 2
    # wave 1 full (all devices 100%), wave 2 has 1 real slot of 4 ->
    # device 0 busy, devices 1-3 idle; means are [1, .5, .5, .5]
    assert rep["per_device"] == [1.0, 0.5, 0.5, 0.5]
    assert abs(rep["mean_util"] - 0.625) < 1e-9
    # ragged batch % dp: physical slots are padded to whole per-device
    # blocks (pads never admitted) instead of the old ValueError —
    # outputs still equal the meshless engine's
    eng3 = Engine(model, params, batch_size=3, max_len=32, mesh=mesh)
    reqs3 = mk()
    got3 = eng3.generate(reqs3)
    assert len(got3) == 5 and all(g is r for g, r in zip(got3, reqs3))
    for g, w in zip(got3, want):
        np.testing.assert_array_equal(g.out, w.out)
    assert eng3.utilization_report()["devices"] == 4
    # a mesh without the dp axis serves replicated (pure-TP tolerance,
    # same as the kernel cluster path) rather than crashing mid-wave
    tp_mesh = jax.make_mesh((2,), ("model",), devices=jax.devices()[:2])
    eng_tp = Engine(model, params, batch_size=4, max_len=32, mesh=tp_mesh)
    got_tp = eng_tp.generate(mk())
    for g, w in zip(got_tp, want):
        np.testing.assert_array_equal(g.out, w.out)
    assert eng_tp.utilization_report()["devices"] == 1


def test_generate_deterministic():
    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=32)
    mk = lambda: [Request(prompt=np.array([3, 5, 7], np.int32),
                          max_new_tokens=6),
                  Request(prompt=np.array([1], np.int32), max_new_tokens=6)]
    a = eng.generate(mk())
    b = eng.generate(mk())
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.out, y.out)
