"""Serving engine: batched generation with prefill+decode, incl. packed
int weights (the paper's deployment mode)."""
import dataclasses

import jax
import numpy as np

from repro.configs.qwen2p5_3b import smoke_config
from repro.models.api import build
from repro.serve.engine import Engine, Request


def test_generate_greedy():
    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=32)
    reqs = [Request(prompt=np.array([3, 5, 7], np.int32), max_new_tokens=5),
            Request(prompt=np.array([11, 2], np.int32), max_new_tokens=5)]
    out = eng.generate(reqs)
    assert len(out) == 2
    for r in out:
        assert r.out is not None and 1 <= len(r.out) <= 5
        assert (r.out >= 0).all() and (r.out < cfg.vocab).all()


def test_generate_deterministic():
    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_size=2, max_len=32)
    mk = lambda: [Request(prompt=np.array([3, 5, 7], np.int32),
                          max_new_tokens=6),
                  Request(prompt=np.array([1], np.int32), max_new_tokens=6)]
    a = eng.generate(mk())
    b = eng.generate(mk())
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.out, y.out)
