"""Shared fixtures + suite plumbing.

* 8 host-platform devices — set before the first jax import so the
  multi-device suite (sharded qdot/qconv parity, ring/pipeline
  collectives, engine wave sharding) exercises a real 8-"core" cluster
  mesh on CPU. An externally-set ``XLA_FLAGS`` wins (the CI parity job
  pins its own device count).
* ``rng`` — the deterministic numpy Generator every test uses.
* ``slow`` marker — long-running tests (CLI subprocess smokes, many-arch
  sweeps) are deselected by default so tier-1 stays fast; run them with
  ``pytest --runslow``.
* ``hypothesis_api()`` — guarded import of hypothesis so collection never
  hard-fails when it is not installed: property tests degrade to
  individually-skipped tests instead of breaking the whole module
  (a stricter variant of ``pytest.importorskip("hypothesis")``, which
  would skip the non-property tests in the same file too).
"""
import os
import sys

if "jax" not in sys.modules:  # too late to matter otherwise
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test, deselected unless --runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any strategies.* call at collection time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()


def hypothesis_api():
    """(given, settings, st) — real hypothesis, or collection-safe stubs
    that skip each property test when hypothesis is not installed."""
    return given, settings, st
