"""The shared int8 side-channel codecs (`core.quantize`) against pinned
verbatim copies of the private helpers they replaced.

`train/optimizer._q8_lin/_dq8_lin` (rowwise optimizer-state codec) and
`train/compress._quant_block/_dequant_block` (blockwise gradient wire)
were byte-for-byte duplicates of the same absmax/127 int8 grid; they now
alias `core.quantize.quantize_int8_{rowwise,blockwise}`. These tests pin
the ORIGINAL implementations inline — if the shared codec ever drifts
(different floor, rounding, clip, pad), saved int8 optimizer states and
the gradient wire format silently change, so drift must fail loudly here.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import (BLOCK, dequantize_int8_blockwise,
                                 dequantize_int8_rowwise,
                                 quantize_int8_blockwise,
                                 quantize_int8_rowwise)
from repro.train import compress, optimizer


# --- pinned originals (pre-dedupe train/optimizer.py @ 5387649) ----------

def _orig_q8_lin(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"codes": codes, "scale": scale[..., 0]}


def _orig_dq8_lin(s, shape):
    return s["codes"].astype(jnp.float32) * s["scale"][..., None]


# --- pinned originals (pre-dedupe train/compress.py @ 5387649) -----------

_ORIG_BLOCK = 256


def _orig_quant_block(x):
    n = x.size
    pad = (-n) % _ORIG_BLOCK
    xb = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, _ORIG_BLOCK)
    scale = jnp.maximum(
        jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def _orig_dequant_block(codes, scale, shape):
    import math
    x = codes.astype(jnp.float32) * scale
    return x.reshape(-1)[: math.prod(shape)].reshape(shape)


CASES = [
    np.zeros((4, 8), np.float32),
    np.ones((3, 300), np.float32) * 1e-15,          # below the scale floor
    np.linspace(-5, 5, 257, dtype=np.float32)[None, :],
    np.random.default_rng(7).normal(size=(5, 17, 64)).astype(np.float32),
    np.random.default_rng(8).normal(scale=1e4, size=(1, 1000)).astype(
        np.float32),
]


@pytest.mark.parametrize("i", range(len(CASES)))
def test_rowwise_matches_pinned_original(i):
    x = jnp.asarray(CASES[i])
    got, want = quantize_int8_rowwise(x), _orig_q8_lin(x)
    assert got["codes"].dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got["codes"]),
                                  np.asarray(want["codes"]))
    np.testing.assert_array_equal(np.asarray(got["scale"]),
                                  np.asarray(want["scale"]))
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8_rowwise(got, x.shape)),
        np.asarray(_orig_dq8_lin(want, x.shape)))


@pytest.mark.parametrize("i", range(len(CASES)))
def test_blockwise_matches_pinned_original(i):
    x = jnp.asarray(CASES[i])
    gc, gs = quantize_int8_blockwise(x)
    wc, ws = _orig_quant_block(x)
    assert gc.dtype == jnp.int8 and gc.shape[1] == BLOCK
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8_blockwise(gc, gs, x.shape)),
        np.asarray(_orig_dequant_block(wc, ws, x.shape)))


def test_consumers_alias_the_shared_codecs():
    # the dedupe contract: both modules now *are* the shared codecs
    assert optimizer._q8_lin is quantize_int8_rowwise
    assert optimizer._dq8_lin is dequantize_int8_rowwise
    assert compress._quant_block is quantize_int8_blockwise
    assert compress._dequant_block is dequantize_int8_blockwise
    assert compress.BLOCK == BLOCK == _ORIG_BLOCK == optimizer.BLOCK


def test_rowwise_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(3).normal(size=(9, 128)).astype(
        np.float32))
    r = dequantize_int8_rowwise(quantize_int8_rowwise(x), x.shape)
    # half-LSB per row: eps = rowmax/127
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1) / 127.0) * 0.5 + 1e-7
    err = np.asarray(jnp.max(jnp.abs(r - x), axis=-1))
    assert (err <= bound).all()


def test_blockwise_pad_cropped():
    x = jnp.asarray(np.random.default_rng(4).normal(size=(3, 7, 13)).astype(
        np.float32))   # 273 elements: one partial block
    codes, scale = quantize_int8_blockwise(x)
    assert codes.shape == (2, BLOCK)
    y = dequantize_int8_blockwise(codes, scale, x.shape)
    assert y.shape == x.shape
