"""CLI launchers run end-to-end (subprocess smoke) — slow, --runslow."""
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(args):
    return subprocess.run(
        [sys.executable, "-m"] + args, cwd=ROOT, capture_output=True,
        text=True, timeout=500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"})


def test_train_cli(tmp_path):
    r = _run(["repro.launch.train", "--arch", "olmo-1b", "--smoke",
              "--steps", "6", "--batch", "2", "--seq", "16",
              "--ckpt", str(tmp_path)])
    assert "final step 6" in r.stdout, r.stderr[-1500:]


def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--smoke",
              "--quant", "w4a8", "--requests", "2", "--batch", "2",
              "--max-new", "4"])
    assert "tok/s" in r.stdout, r.stderr[-1500:]


def test_deploy_then_serve_plan_cli(tmp_path):
    """calibrate -> plan -> pack via the deploy CLI, then serve the plan:
    mixed artifact must be strictly smaller than the uniform-w8 one."""
    plan = tmp_path / "plan.json"
    r = _run(["repro.launch.deploy", "--arch", "qwen2.5-3b", "--smoke",
              "--budget", "auto", "--out", str(plan)])
    assert "deploy done" in r.stdout, r.stderr[-1500:]
    import json
    import re
    d = json.loads(plan.read_text())
    assert len({rule["w_bits"] for rule in d["rules"]}) >= 2
    m = re.search(r"uniform-w8 ([\d,]+)\s+mixed ([\d,]+)", r.stdout)
    assert m, r.stdout
    w8, mixed = (int(g.replace(",", "")) for g in m.groups())
    assert mixed < w8
    r2 = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--smoke",
               "--plan", str(plan), "--requests", "3", "--batch", "2",
               "--max-new", "4"])
    assert "tok/s" in r2.stdout, r2.stderr[-1500:]
    m2 = re.search(r"\((\d[\d,]*) bytes\)", r2.stdout)
    assert m2 and int(m2.group(1).replace(",", "")) == mixed
