"""CLI launchers run end-to-end (subprocess smoke) — slow, --runslow."""
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(args, extra_env=None):
    import os

    # pin the jax platform: without it each subprocess burns minutes
    # probing for accelerator plugins before falling back to CPU
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    env.update(extra_env or {})
    return subprocess.run(
        [sys.executable, "-m"] + args, cwd=ROOT, capture_output=True,
        text=True, timeout=500, env=env)


def test_train_cli(tmp_path):
    r = _run(["repro.launch.train", "--arch", "olmo-1b", "--smoke",
              "--steps", "6", "--batch", "2", "--seq", "16",
              "--ckpt", str(tmp_path)])
    assert "final step 6" in r.stdout, r.stderr[-1500:]


def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--smoke",
              "--quant", "w4a8", "--requests", "2", "--batch", "2",
              "--max-new", "4"])
    assert "tok/s" in r.stdout, r.stderr[-1500:]


def test_serve_cli_mesh():
    """--mesh dp,tp serves on a forced-host-device cluster and prints the
    per-device utilization report."""
    r = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--smoke",
              "--quant", "w4a8", "--requests", "6", "--batch", "4",
              "--max-new", "4", "--mesh", "4,2"],
             extra_env={"XLA_FLAGS":
                        "--xla_force_host_platform_device_count=8"})
    assert "mesh: data=4 model=2" in r.stdout, r.stderr[-1500:]
    assert "cluster utilization" in r.stdout
    assert "d3=50%" in r.stdout  # wave 2 carries 2 real of 4 slots


def test_fig9_cluster_bench_cli(tmp_path):
    """The fig. 9 benchmark runs the sharded path end-to-end over
    --devices 1,2,4,8 and emits BENCH_cluster.json with a speedup
    column (ISSUE-4 acceptance criterion; the script forces 8 host
    devices itself when XLA_FLAGS is unset)."""
    import json

    out = tmp_path / "BENCH_cluster.json"
    r = _run(["benchmarks.fig9_cluster_scaling", "--devices", "1,2,4,8",
              "--json", str(out)])
    assert out.exists(), r.stderr[-1500:]
    sys.path.insert(0, str(ROOT))
    from benchmarks import schema
    schema.validate_file(out)           # the checked-in artifact schema
    d = json.loads(out.read_text())
    assert d["path"] == "repro.kernels.api.qdot_sharded"
    rows = d["rows"]
    assert {row["devices"] for row in rows} == {1, 2, 4, 8}
    assert {row["bits"] for row in rows} == {8, 4, 2}
    for row in rows:
        assert "speedup" in row and "efficiency" in row
        if row["devices"] == 1:
            assert row["speedup"] == 1.0


def test_deploy_then_serve_plan_cli(tmp_path):
    """calibrate -> plan -> pack via the deploy CLI, then serve the plan:
    mixed artifact must be strictly smaller than the uniform-w8 one."""
    plan = tmp_path / "plan.json"
    r = _run(["repro.launch.deploy", "--arch", "qwen2.5-3b", "--smoke",
              "--budget", "auto", "--out", str(plan)])
    assert "deploy done" in r.stdout, r.stderr[-1500:]
    import json
    import re
    d = json.loads(plan.read_text())
    assert len({rule["w_bits"] for rule in d["rules"]}) >= 2
    m = re.search(r"uniform-w8 ([\d,]+)\s+mixed ([\d,]+)", r.stdout)
    assert m, r.stdout
    w8, mixed = (int(g.replace(",", "")) for g in m.groups())
    assert mixed < w8
    r2 = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--smoke",
               "--plan", str(plan), "--requests", "3", "--batch", "2",
               "--max-new", "4"])
    assert "tok/s" in r2.stdout, r2.stderr[-1500:]
    m2 = re.search(r"\((\d[\d,]*) bytes\)", r2.stdout)
    assert m2 and int(m2.group(1).replace(",", "")) == mixed
