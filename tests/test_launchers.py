"""CLI launchers run end-to-end (subprocess smoke) — slow, --runslow."""
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(args):
    return subprocess.run(
        [sys.executable, "-m"] + args, cwd=ROOT, capture_output=True,
        text=True, timeout=500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"})


def test_train_cli(tmp_path):
    r = _run(["repro.launch.train", "--arch", "olmo-1b", "--smoke",
              "--steps", "6", "--batch", "2", "--seq", "16",
              "--ckpt", str(tmp_path)])
    assert "final step 6" in r.stdout, r.stderr[-1500:]


def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--smoke",
              "--quant", "w4a8", "--requests", "2", "--batch", "2",
              "--max-new", "4"])
    assert "tok/s" in r.stdout, r.stderr[-1500:]
