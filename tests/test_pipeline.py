"""GPipe over the pod axis == serial layer application (bitwise-close)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.parallel.pipeline import pipeline_apply, stage_stack
from repro.parallel.ctx import use_mesh


@pytest.fixture(scope="module")
def pod_mesh():
    devs = jax.devices()
    if len(devs) % 2:
        return jax.make_mesh((1,), ("pod",))
    return jax.make_mesh((min(2, len(devs)),), ("pod",),
                         devices=devs[: min(2, len(devs))]) \
        if len(devs) >= 2 else jax.make_mesh((1,), ("pod",))


def test_pipeline_matches_serial(pod_mesh):
    n_stages = pod_mesh.shape["pod"]
    rng = np.random.default_rng(0)
    L = 4 * n_stages          # layers, split into stages
    d = 16
    w = rng.normal(size=(L, d, d)).astype(np.float32) * 0.3

    def layer(wi, h):
        return jnp.tanh(h @ wi)

    def serial(h):
        for i in range(L):
            h = layer(jnp.asarray(w[i]), h)
        return h

    def stage_fn(sp, h):
        def body(h, wi):
            return layer(wi, h), None
        h, _ = jax.lax.scan(body, h, sp["w"])
        return h

    n_micro, mb = 4, 3
    x = rng.normal(size=(n_micro, mb, d)).astype(np.float32)
    staged = stage_stack({"w": jnp.asarray(w)}, n_stages)
    with use_mesh(pod_mesh):
        out = pipeline_apply(stage_fn, staged, jnp.asarray(x), pod_mesh)
    ref = np.stack([np.asarray(serial(jnp.asarray(x[i])))
                    for i in range(n_micro)])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)
