"""Integer-image algebra: requant exactness, spec math, BN folding."""
import numpy as np
import jax.numpy as jnp
import pytest
from conftest import hypothesis_api

# guarded: property tests skip (not hard-fail) without hypothesis
given, settings, st = hypothesis_api()

from repro.core import (QuantSpec, quantize, dequantize, requantize_shift,
                        requantize_shift_i64, fold_bn_requant, lin,
                        batchnorm_int, qnt_act, quantize_linear,
                        calibrate_weight, calibrate_activation, M_BITS)
from repro.core import packing


@given(phi=st.integers(-2**31, 2**31 - 1), m=st.integers(0, 2**15 - 1),
       d=st.integers(16, 31))
@settings(max_examples=300, deadline=None)
def test_requant_exact_vs_int64(phi, m, d):
    """The kernel's int32 split == the int64 oracle for every d in [16,31].
    This is the bit-exactness guarantee of eq. (4)."""
    got = int(np.asarray(requantize_shift(jnp.int32(phi), jnp.int32(m), d)))
    want = int(requantize_shift_i64(phi, m, d))
    assert got == want


def test_requant_exact_boundaries_exhaustive_d():
    """Deterministic (hypothesis-free) sweep: every d in [16, 31] crossed
    with the m = 2^15 - 1 boundary (and neighbors) at extreme phi values —
    the int32 split must match the int64 oracle at every corner."""
    phis = [-(2**31), -(2**31) + 1, -(2**16) - 1, -(2**16), -1, 0, 1,
            2**16 - 1, 2**16, 2**31 - 1]
    ms = [0, 1, 2**14, 2**15 - 2, 2**15 - 1]  # multiplier cap M_BITS=15
    for d in range(16, 32):
        for m in ms:
            for phi in phis:
                got = int(np.asarray(requantize_shift(
                    jnp.int32(phi), jnp.int32(m), d)))
                want = int(requantize_shift_i64(phi, m, d))
                assert got == want, (phi, m, d)


def test_requant_vectorized_boundary_grid(rng):
    """requantize_shift over whole arrays at the m boundary (the kernel
    epilogue applies it per-channel, not per-scalar)."""
    phi = rng.integers(-2**31, 2**31, size=(64, 32), dtype=np.int64
                       ).astype(np.int32)
    m = np.full((32,), 2**15 - 1, np.int32)
    for d in (16, 23, 31):
        got = np.asarray(requantize_shift(jnp.asarray(phi), jnp.asarray(m),
                                          d))
        want = requantize_shift_i64(phi, m, d)
        np.testing.assert_array_equal(got.astype(np.int64), want)


def test_quantspec_signed_symmetric():
    s = QuantSpec.weight(4, 1.0)
    assert s.int_min == -7 and s.int_max == 7
    assert abs(s.eps - 1.0 / 7) < 1e-9
    s2 = QuantSpec.weight(2, 1.0)   # 2-bit signed == ternary
    assert (s2.int_min, s2.int_max) == (-1, 1)


def test_quantize_dequantize_error_bound(rng):
    for bits in (8, 4, 2):
        s = QuantSpec.activation(bits, 4.0)
        x = rng.uniform(0, 4.0, size=(1000,)).astype(np.float32)
        q = quantize(jnp.asarray(x), s)
        err = np.abs(np.asarray(dequantize(q, s)) - x)
        assert err.max() <= s.eps / 2 + 1e-6


def test_fold_bn_requant_constraints(rng):
    bn_s = rng.normal(size=(32,)).astype(np.float32) * 0.2 + 1
    bn_b = rng.normal(size=(32,)).astype(np.float32) * 0.1
    kappa, lam, m, d = fold_bn_requant(0.01, 0.02, 0.05, bn_s, bn_b, 4)
    assert 16 <= d <= 31
    assert int(jnp.max(m)) < (1 << M_BITS)


def test_full_integer_pipeline_close_to_float(rng):
    K, N, M = 256, 64, 32
    w = rng.normal(size=(K, N)).astype(np.float32) * 0.05
    x = np.maximum(rng.normal(size=(M, K)), 0).astype(np.float32)
    bn_s = rng.normal(size=(N,)).astype(np.float32) * 0.1 + 1
    bn_b = rng.normal(size=(N,)).astype(np.float32) * 0.01
    sw = calibrate_weight(jnp.asarray(w), 8)
    sx = calibrate_activation(x, 8, 100.0)
    y_f = np.maximum((x @ w) * bn_s + bn_b, 0)
    sy = calibrate_activation(y_f, 8, 100.0)
    qp = quantize_linear(jnp.asarray(w), sw, bn_s, bn_b, sx, sy)
    xq = quantize(jnp.asarray(x), sx)
    xq = packing.pad_to_chunk(xq, axis=-1)
    w_unp = packing.unpack(qp.w_packed, 8, True, axis=0)
    phi = lin(w_unp, xq)
    yq = qnt_act(batchnorm_int(phi, qp.kappa, qp.lam), qp.m, qp.d, 8)
    y_int = np.asarray(dequantize(yq, sy))
    rel = np.abs(y_int - np.clip(y_f, 0, sy.beta)).max() / (y_f.max() + 1e-9)
    assert rel < 0.05  # 8-bit end-to-end error
