"""The continuous-batching serving runtime (`repro.serve.runtime`).

* slot/page manager unit behaviour: deterministic lowest-free placement,
  page reservation/used accounting, ragged-dp physical padding, capacity
  admission control, obs counters;
* scheduler edge cases: backpressure on a bounded admission queue,
  mid-wave eviction (a freed slot is re-admitted before the cohort
  finishes — the tentpole behaviour), zero-length prompts,
  max_new_tokens=0, drain on an empty queue;
* the bit-exactness invariant: per-request outputs identical across
  policies (continuous == wave == legacy Engine), admission orders,
  meshless vs dp-sharded (incl. ragged slots % dp), and greedy vs
  per-request-seeded sampling;
* engine-shim compat: `Engine`/`VisionEngine` wave stats and obs
  counters match the legacy semantics;
* the load generator: deterministic replay from a fixed seed and a
  BENCH_serving.json that passes its schema with continuous batching
  strictly beating the wave baseline.
"""
import pathlib
import sys

import numpy as np
import pytest

import jax

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:  # `import benchmarks` from any rootdir
    sys.path.insert(0, str(ROOT))

from repro.configs.qwen2p5_3b import smoke_config
from repro.models.api import build
from repro.obs import trace as obs
from repro.serve.runtime import (Backpressure, LMDecodeAdapter, Request,
                                 Scheduler, VisionAdapter)
from repro.serve.runtime.slots import CapacityError, SlotManager


@pytest.fixture(scope="module")
def lm():
    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _adapter(lm, mesh=None, max_len=32):
    _, model, params = lm
    return LMDecodeAdapter(model, params, max_len=max_len, mesh=mesh)


def _reqs(n=5, plen=2, max_new=3):
    """Equal-length prompts (bit-comparable to the legacy wave prefill),
    mixed generation budgets unless pinned."""
    return [Request(prompt=np.array([3 + i] + [5] * (plen - 1), np.int32),
                    max_new_tokens=(max_new if np.isscalar(max_new)
                                    else max_new[i]))
            for i in range(n)]


def _outs(reqs):
    return [r.out.tolist() for r in reqs]


# ------------------------------------------------------- slot manager ---

def test_slot_manager_lifecycle_and_pages():
    sm = SlotManager(3, max_len=32, page_tokens=8)  # 4 pages per slot
    assert (sm.real, sm.phys, sm.pages_per_slot, sm.capacity_pages) == \
        (3, 3, 4, 12)
    a = sm.admit(rid=10, reserve_tokens=9)    # ceil(9/8) = 2 pages
    b = sm.admit(rid=11, reserve_tokens=40)   # clamped to max_len -> 4
    assert (a, b) == (0, 1)                   # lowest-free placement
    assert sm.pages_reserved() == 6 and sm.pages_used() == 0
    sm.advance(a, 5)
    assert sm.slots[a].pos == 5 and sm.pages_used() == 1
    sm.advance(a, 9)
    assert sm.pages_used() == 2
    assert sm.occupancy() == pytest.approx(2 / 3)
    sm.evict(a)
    assert sm.free_slots == 2 and sm.pages_reserved() == 4
    # freed slot 0 is re-used before untouched slot 2 (deterministic)
    assert sm.admit(rid=12, reserve_tokens=1) == 0
    with pytest.raises(CapacityError, match="exceeds max_len"):
        sm.check_fits(33)
    sm.check_fits(32)  # exactly full is admissible


def test_slot_manager_ragged_dp_blocks():
    sm = SlotManager(3, max_len=16, dp=4)
    # padded to one whole slot per device; the pad is never in the free
    # list, so it can never be admitted
    assert (sm.block, sm.phys, sm.real, sm.free_slots) == (1, 4, 3, 3)
    for rid in range(3):
        sm.admit(rid, 4)
    assert sm.free_slots == 0
    assert sm.device_occupancy() == [1.0, 1.0, 1.0, 0.0]
    sm.evict(1)
    assert sm.device_occupancy() == [1.0, 0.0, 1.0, 0.0]


def test_slot_manager_obs_counters():
    obs.reset()
    with obs.enabled_scope():
        sm = SlotManager(2, max_len=32, page_tokens=16)
        sm.admit(0, 20)   # 2 pages
        sm.admit(1, 3)    # 1 page
        sm.evict(0)
        vals = obs.counter_values()
    assert vals["serve.admits"] == 2 and vals["serve.evicts"] == 1
    assert vals["serve.pages_reserved"] == 3
    assert vals["serve.pages_released"] == 2


# --------------------------------------------------- scheduler edges ---

def test_backpressure_on_full_queue(lm):
    sched = Scheduler(_adapter(lm), 1, max_queue=2)
    for i in range(2):
        sched.submit(Request(prompt=np.array([3 + i], np.int32),
                             max_new_tokens=1))
    with pytest.raises(Backpressure, match="admission queue full"):
        sched.submit(Request(prompt=np.array([9], np.int32),
                             max_new_tokens=1))
    sched.drain()          # queue empties ...
    rid = sched.submit(Request(prompt=np.array([9], np.int32),
                               max_new_tokens=1))  # ... and admits again
    sched.drain()
    assert sched.results[rid].out is not None


def test_mid_wave_eviction_refills_slot(lm):
    """The tentpole behaviour: with 2 slots and 3 requests, the third
    request must be admitted the moment the short first request frees
    its slot — strictly before the long second request finishes. The
    wave policy on the same workload must instead hold it back until
    the whole cohort drains."""
    reqs = _reqs(3, max_new=[1, 6, 6])
    sched = Scheduler(_adapter(lm), 2, policy="continuous")
    sched.serve([Request(prompt=r.prompt.copy(),
                         max_new_tokens=r.max_new_tokens) for r in reqs])
    log = {r["rid"]: r for r in sched.request_log}
    assert log[2]["admit_t"] < log[1]["finish_t"]   # mid-wave admission
    assert log[2]["admit_t"] >= log[0]["finish_t"]  # into slot 0's grave

    wave = Scheduler(_adapter(lm), 2, policy="wave")
    wave.serve([Request(prompt=r.prompt.copy(),
                        max_new_tokens=r.max_new_tokens) for r in reqs])
    wlog = {r["rid"]: r for r in wave.request_log}
    assert wlog[2]["admit_t"] >= wlog[1]["finish_t"]  # waits for cohort
    # fewer engine steps for the same work is the whole point
    assert sched.serving_report()["steps"] < wave.serving_report()["steps"]


def test_degenerate_requests(lm):
    sched = Scheduler(_adapter(lm), 2)
    # max_new_tokens=0 completes instantly without ever taking a slot
    rid0 = sched.submit(Request(prompt=np.array([3, 5], np.int32),
                                max_new_tokens=0))
    assert sched.results[rid0].out.tolist() == []
    assert sched.idle
    log0 = next(r for r in sched.request_log if r["rid"] == rid0)
    assert log0["admit_t"] is None and log0["tokens_out"] == 0
    # zero-length prompt is padded to a single BOS filler token
    rid1 = sched.submit(Request(prompt=np.array([], np.int32),
                                max_new_tokens=2))
    sched.drain()
    out = sched.results[rid1].out
    assert 1 <= len(out) <= 2
    # a prompt that can never fit its cache is rejected at submission
    with pytest.raises(CapacityError, match="exceeds max_len"):
        sched.submit(Request(prompt=np.zeros(40, np.int32) + 3,
                             max_new_tokens=1))


def test_drain_on_empty_queue_is_noop(lm):
    sched = Scheduler(_adapter(lm), 2)
    sched.drain()
    assert sched.step() == []
    assert sched.idle and sched.step_log == [] and sched.results == {}


# ------------------------------------------------------- bit-exactness ---

def test_policies_and_legacy_engine_bit_exact(lm):
    """continuous == wave == legacy Engine per request (equal-length
    prompts so the legacy pad-replaying prefill is comparable), and
    ragged prompt lengths agree across the two runtime policies."""
    from repro.serve.engine import Engine

    _, model, params = lm
    mixed = [1, 4, 2, 5, 3]
    want = Engine(model, params, batch_size=4, max_len=32).generate(
        _reqs(5, max_new=mixed))
    for policy in ("wave", "continuous"):
        got = Scheduler(_adapter(lm), 4, policy=policy).serve(
            _reqs(5, max_new=mixed))
        assert _outs(got) == _outs(want)
    # ragged prompts: per-request outputs are batching-independent
    rag = lambda: [Request(prompt=np.arange(2, 3 + i, dtype=np.int32),
                           max_new_tokens=4) for i in range(5)]
    a = Scheduler(_adapter(lm), 4, policy="wave").serve(rag())
    b = Scheduler(_adapter(lm), 2, policy="continuous").serve(rag())
    assert _outs(a) == _outs(b)


def test_admission_order_invariance(lm):
    fwd = Scheduler(_adapter(lm), 2).serve(_reqs(5, max_new=[1, 4, 2, 5, 3]))
    rev = Scheduler(_adapter(lm), 2).serve(
        list(reversed(_reqs(5, max_new=[1, 4, 2, 5, 3]))))
    assert _outs(fwd) == _outs(list(reversed(rev)))


def test_nongreedy_sampling_is_per_request(lm):
    """Sampled decoding draws from a per-request (seed, rid) generator,
    so outputs replay across runs AND across policies — the legacy
    shared-rng drew in wave order, which no admission-order-invariant
    scheduler can reproduce."""
    mk = lambda: _reqs(4, max_new=6)
    a = Scheduler(_adapter(lm), 2).serve(mk(), greedy=False, seed=7)
    b = Scheduler(_adapter(lm), 2).serve(mk(), greedy=False, seed=7)
    c = Scheduler(_adapter(lm), 3, policy="wave").serve(
        mk(), greedy=False, seed=7)
    assert _outs(a) == _outs(b) == _outs(c)
    # (the smoke model's softmax is near-degenerate, so different seeds
    # usually sample the argmax too — seed sensitivity is exercised at
    # the rng level below, not through the model)
    rng1 = np.random.default_rng((7, 0))
    rng2 = np.random.default_rng((8, 0))
    p = np.full(8, 1 / 8)
    assert [rng1.choice(8, p=p) for _ in range(16)] != \
        [rng2.choice(8, p=p) for _ in range(16)]


@pytest.mark.parametrize("num_slots", [4, 3])
def test_dp_sharded_parity(lm, num_slots):
    """Mesh-sharded runtime == meshless, bit-exact, including ragged
    num_slots % dp != 0 (physical pad slots are never admitted)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >=4 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    mixed = [3, 1, 4, 2, 5]
    want = Scheduler(_adapter(lm), num_slots).serve(_reqs(5, max_new=mixed))
    tp = len(jax.devices()) // 4
    mesh = jax.make_mesh((4, tp), ("data", "model"),
                         devices=jax.devices()[: 4 * tp])
    sched = Scheduler(_adapter(lm, mesh=mesh), num_slots, mesh=mesh)
    got = sched.serve(_reqs(5, max_new=mixed))
    assert _outs(got) == _outs(want)
    assert sched._dp == 4
    assert len(sched.step_log[0]["per_device"]) == 4
    assert sched.slots.phys % 4 == 0


def test_slot_state_reset_between_tenants(lm):
    """A slot's second tenant must produce the same output it would in a
    fresh scheduler — nothing carries over from the evicted request."""
    solo = Scheduler(_adapter(lm), 1).serve(
        [Request(prompt=np.array([9, 4], np.int32), max_new_tokens=4)])
    sched = Scheduler(_adapter(lm), 1)
    got = sched.serve(
        [Request(prompt=np.array([3, 5], np.int32), max_new_tokens=4),
         Request(prompt=np.array([9, 4], np.int32), max_new_tokens=4)])
    assert got[1].out.tolist() == solo[0].out.tolist()


# -------------------------------------------------------- engine shims ---

def test_engine_shim_stats_and_counters(lm):
    from repro.serve.engine import Engine

    _, model, params = lm
    eng = Engine(model, params, batch_size=2, max_len=32)
    obs.reset()
    with obs.enabled_scope():
        out = eng.generate(_reqs(5, max_new=2))
        vals = obs.counter_values()
    assert [len(r.out) for r in out] == [2] * 5
    # 5 requests in waves of 2 -> 3 waves, legacy counter semantics
    assert vals["engine.waves"] == 3 and vals["engine.requests"] == 5
    assert vals["serve.admits"] == 5 and vals["serve.evicts"] == 5
    rep = eng.utilization_report()
    assert rep["waves"] == 3 and rep["devices"] == 1
    assert rep["per_device"] == [pytest.approx((1 + 1 + 0.5) / 3)]
    assert rep["latency_us"] is not None and rep["latency_us"]["waves"] == 3
    assert rep["queue_depth"]["max"] == 3
    # the runtime's request-granular report rides along on the shim
    srep = eng.serving_report()
    assert srep["requests"] == 5 and srep["policy"] == "wave"


def test_vision_shim_matches_runtime(art=None):
    from repro.deploy.calibrate import calibrate_vision
    from repro.serve.engine import VisionEngine
    from repro.vision.configs import get_vision_config
    from repro.vision.models import (forward_int, init_fp, quantize_input,
                                     quantize_net)

    cfg = get_vision_config("resnet8", smoke=True)
    params = init_fp(cfg, seed=0)
    rng = np.random.default_rng(0)
    cal = rng.uniform(0, 1, (4, *cfg.in_hw, cfg.in_ch)).astype(np.float32)
    _, absmax = calibrate_vision(cfg, params, [cal])
    qnet = quantize_net(cfg, params, absmax)
    images = rng.uniform(0, 1, (5, *cfg.in_hw, cfg.in_ch)).astype(
        np.float32)
    want = np.asarray(forward_int(qnet, quantize_input(qnet, images),
                                  backend="xla"))
    shim = VisionEngine(qnet, batch_size=2, backend="xla").run(images)
    assert np.array_equal(shim, want)
    cont = Scheduler(VisionAdapter(qnet, backend="xla"), 2).serve(
        list(images))
    assert np.array_equal(np.stack(cont), want)
    empty = VisionEngine(qnet, batch_size=2, backend="xla").run(
        np.zeros((0, *cfg.in_hw, cfg.in_ch), np.float32))
    assert empty.shape == (0, cfg.num_classes)


# ----------------------------------------------------------- load gen ---

def test_loadgen_deterministic_replay_and_schema(tmp_path):
    """Same seed -> byte-identical BENCH_serving.json (virtual clock, no
    wall time anywhere), the artifact passes its validator, and the
    acceptance holds: continuous strictly beats wave on throughput and
    p99 at the same offered load."""
    from benchmarks import loadgen, schema

    args = ["--requests", "10", "--qps", "0.8", "--slots", "3",
            "--seed", "3", "--json", str(tmp_path / "BENCH_serving.json")]
    a = loadgen.main(args)
    b = loadgen.main(args)
    assert a == b
    schema.validate_file(tmp_path / "BENCH_serving.json")
    assert a["acceptance"]["throughput_gain"] > 1.0
    assert a["acceptance"]["p99_ratio"] < 1.0
    wave, cont = (next(r for r in a["rows"] if r["policy"] == p)
                  for p in ("wave", "continuous"))
    assert cont["steps"] < wave["steps"]
    assert cont["occupancy"]["mean"] > wave["occupancy"]["mean"]
