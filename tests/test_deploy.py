"""Mixed-precision deployment planner: policy resolution + JSON round-trip,
calibration stats, budgeted bit-width search, plan-driven packing
(bit-exact vs the uniform path per layer), and plan serving."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.qwen2p5_3b import smoke_config
from repro.deploy.apply import (apply_plan, dense_inventory,
                                quantized_dense_paths)
from repro.deploy.calibrate import CalibStats, calibrate
from repro.deploy.planner import (auto_budget, packed_weight_bytes,
                                  plan_mixed_precision)
from repro.deploy.policy import (PlanRule, PrecisionPlan, load_plan,
                                 resolve_qcfg, save_plan)
from repro.launch.convert import artifact_bytes, convert_params
from repro.models.api import Model, build
from repro.nn.layers import QuantConfig, dense_apply
from repro.serve.engine import Engine, Request

QINT = QuantConfig(mode="int", w_bits=8, a_bits=8)

EXPECTED_PATHS = {"layers/attn/wq", "layers/attn/wk", "layers/attn/wv",
                  "layers/attn/wo", "layers/mlp/wi", "layers/mlp/wg",
                  "layers/mlp/wo"}


def _smoke_models(plan=None):
    cfg = smoke_config()
    fp = build(cfg)
    q = Model(dataclasses.replace(cfg, quant=QINT, quant_plan=plan))
    return fp, q


# ---------------------------------------------------------------- policy ---

def test_policy_resolution_first_match_wins():
    plan = PrecisionPlan(rules=(
        PlanRule("layers/mlp/wi", 2, a_absmax=3.0),
        PlanRule("layers/mlp/*", 4),
        PlanRule("layers/attn/w[qk]", 8),
    ))
    base = QuantConfig(mode="int", a_absmax=5.0)
    assert plan.resolve("layers/mlp/wi", base).w_bits == 2
    assert plan.resolve("layers/mlp/wi", base).a_absmax == 3.0
    assert plan.resolve("layers/mlp/wg", base).w_bits == 4
    assert plan.resolve("layers/mlp/wg", base).a_absmax == 5.0  # inherited
    assert plan.resolve("layers/attn/wq", base).w_bits == 8
    # unmatched path -> plan defaults, base mode preserved
    r = plan.resolve("layers/attn/wo", base)
    assert r.w_bits == 8 and r.mode == "int"
    assert resolve_qcfg(None, "anything", base) is base


def test_plan_json_roundtrip(tmp_path):
    plan = PrecisionPlan(
        rules=(PlanRule("layers/mlp/*", 4, a_bits=8, a_absmax=2.5),
               PlanRule("layers/attn/*", 2, backend="pallas_interpret")),
        default_w_bits=8, meta={"arch": "qwen-smoke", "budget": 0.5})
    f = tmp_path / "plan.json"
    save_plan(plan, f)
    got = load_plan(f)
    assert got == plan                      # eq over rules + defaults
    assert got.meta["arch"] == "qwen-smoke"
    assert got.distinct_w_bits() == (2, 4, 8)
    # plans are hashable (they ride inside frozen ModelConfig)
    assert hash(got) == hash(plan)


# ----------------------------------------------------------- calibration ---

def test_calibrate_covers_all_quantized_paths(rng):
    fp, q = _smoke_models()
    params = fp.init(jax.random.PRNGKey(0))
    assert set(quantized_dense_paths(q.defs())) == EXPECTED_PATHS
    batches = [rng.integers(2, fp.cfg.vocab, size=(2, 16)).astype(np.int32)
               for _ in range(2)]
    stats = calibrate(fp, params, batches)
    assert set(stats) == EXPECTED_PATHS
    for st in stats.values():
        assert st.taps > 0 and st.a_absmax > 0
        # narrower grids hurt more (the knapsack's monotonicity premise)
        assert st.sens(2) > st.sens(4) > st.sens(8) >= 0
    inv = dense_inventory(params, stats)
    assert inv["layers/mlp/wi"] == (2, 64, 128)  # (L, K, N) of the smoke cfg


def test_calibrate_weight_only_fallback(rng):
    from repro.configs.mamba2_370m import smoke_config as mamba_smoke
    cfg = mamba_smoke()
    fp = build(cfg)
    params = fp.init(jax.random.PRNGKey(0))
    batches = [rng.integers(2, cfg.vocab, size=(2, 8)).astype(np.int32)]
    stats = calibrate(fp, params, batches)
    assert stats and all(st.sens(2) > st.sens(8) for st in stats.values())
    assert {"layers/mixer/in_proj", "layers/mixer/out_proj"} <= set(stats)


# ---------------------------------------------------------------- planner ---

def _fake_stats():
    """Hand-built stats: one cheap-to-narrow path, one expensive."""
    a = CalibStats("layers/mlp/wi", 2, 64, 128, a_absmax=3.0,
                   sq_err={8: 1e-6, 4: 1e-4, 2: 1e-3}, sq_ref=1.0, taps=1)
    b = CalibStats("layers/attn/wq", 2, 64, 64, a_absmax=2.0,
                   sq_err={8: 1e-6, 4: 0.5, 2: 5.0}, sq_ref=1.0, taps=1)
    return {a.path: a, b.path: b}


def test_planner_respects_budget_and_mixes():
    stats = _fake_stats()
    base = sum(st.sens(8) for st in stats.values())
    # budget admits wi all the way down but forbids touching wq
    plan = plan_mixed_precision(stats, base + 0.01)
    bits = {r.pattern: r.w_bits for r in plan.rules}
    assert bits["layers/mlp/wi"] == 2
    assert bits["layers/attn/wq"] == 8
    assert plan.meta["total_sensitivity"] <= base + 0.01
    assert len(set(bits.values())) >= 2
    # zero headroom -> nothing demoted
    all8 = plan_mixed_precision(stats, base)
    assert all(r.w_bits == 8 for r in all8.rules)
    # unbounded -> everything at the narrowest candidate
    all2 = plan_mixed_precision(stats, 1e9)
    assert all(r.w_bits == 2 for r in all2.rules)


def test_planner_monotone_in_budget():
    stats = _fake_stats()
    budgets = np.linspace(0.0, 6.0, 8)
    prev = None
    for b in budgets:
        plan = plan_mixed_precision(stats, b)
        total = plan.meta["packed_weight_bytes"]
        if prev is not None:
            assert total <= prev  # more budget never costs bytes
        prev = total


def test_packed_weight_bytes_matches_artifact():
    """The planner's byte accounting == actual packed artifact bytes."""
    fp, q = _smoke_models()
    fp_params = fp.init(jax.random.PRNGKey(0))
    q_params = convert_params(q.init(jax.random.PRNGKey(0)), fp_params, 8)
    inv = dense_inventory(fp_params, quantized_dense_paths(q.defs()))
    planned = sum(packed_weight_bytes(*shape, 8) for shape in inv.values())
    # difference = everything convert leaves fp (embeds, norms, biases)
    fp_rest = artifact_bytes(q_params) - planned
    assert fp_rest >= 0
    got = sum(
        q_params["layers"][g][n]["w_packed"].nbytes
        + q_params["layers"][g][n]["w_scale"].nbytes
        for g, names in (("attn", ("wq", "wk", "wv", "wo")),
                         ("mlp", ("wi", "wg", "wo"))) for n in names)
    assert got == planned


def test_int_dense_honors_a_bits_and_matches_sim(rng):
    """The serving int path quantizes activations on the qcfg.a_bits grid,
    and the calibrator's sensitivity simulation uses that exact grid —
    what the planner prices is what serving runs."""
    import jax.numpy as jnp

    from repro.deploy.calibrate import _sim_int_dense
    from repro.nn.layers import pack_dense_weights

    w = (rng.normal(size=(128, 32)) * 0.1).astype(np.float32)
    x = rng.normal(size=(4, 128)).astype(np.float32)
    packed, scale = pack_dense_weights(jnp.asarray(w), 8)
    p = {"w_packed": packed, "w_scale": scale}
    outs = {}
    for a_bits in (8, 4, 2):
        qcfg = QuantConfig(mode="int", w_bits=8, a_bits=a_bits, a_absmax=4.0)
        outs[a_bits] = np.asarray(dense_apply(p, jnp.asarray(x), qcfg=qcfg))
        sim = np.asarray(_sim_int_dense(jnp.asarray(x), jnp.asarray(w), 8,
                                        a_bits, 4.0))
        np.testing.assert_allclose(outs[a_bits], sim, rtol=1e-5, atol=1e-6)
    assert not np.allclose(outs[8], outs[4])
    assert not np.allclose(outs[4], outs[2])


# ------------------------------------------------------------------ apply ---

def _mixed_plan():
    return PrecisionPlan(rules=(
        PlanRule("layers/attn/*", 8, a_absmax=4.0),
        PlanRule("layers/mlp/wi", 4, a_absmax=4.0),
        PlanRule("layers/mlp/wg", 4, a_absmax=4.0),
        PlanRule("layers/mlp/wo", 2, a_absmax=4.0),
    ))


def test_apply_plan_bit_exact_vs_uniform_per_layer():
    """Every plan-quantized dense == the uniform int path at that layer's
    bit-width: identical packed containers, scales, and dense outputs."""
    plan = _mixed_plan()
    fp, q = _smoke_models(plan)
    fp_params = fp.init(jax.random.PRNGKey(0))
    q_params = apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, plan)

    per_path_bits = {"layers/attn/wq": 8, "layers/attn/wk": 8,
                     "layers/attn/wv": 8, "layers/attn/wo": 8,
                     "layers/mlp/wi": 4, "layers/mlp/wg": 4,
                     "layers/mlp/wo": 2}
    rng = np.random.default_rng(1)
    for bits in (8, 4, 2):
        _, u = _smoke_models()
        u_model = Model(dataclasses.replace(
            u.cfg, quant=dataclasses.replace(QINT, w_bits=bits)))
        u_params = convert_params(u_model.init(jax.random.PRNGKey(0)),
                                  fp_params, bits)
        for path, b in per_path_bits.items():
            if b != bits:
                continue
            grp, name = path.split("/")[1:]
            got = q_params["layers"][grp][name]
            want = u_params["layers"][grp][name]
            np.testing.assert_array_equal(np.asarray(got["w_packed"]),
                                          np.asarray(want["w_packed"]))
            np.testing.assert_array_equal(np.asarray(got["w_scale"]),
                                          np.asarray(want["w_scale"]))
            # and the integer GEMM output is bit-identical layer-by-layer
            d_in = fp_params["layers"][grp][name]["w"].shape[1]
            x = rng.normal(size=(3, d_in)).astype(np.float32)
            qcfg = plan.resolve(path, QINT)
            ucfg = dataclasses.replace(QINT, w_bits=bits,
                                       a_absmax=qcfg.a_absmax)
            for layer in range(got["w_packed"].shape[0]):
                lg = {k: v[layer] for k, v in got.items()}
                lw = {k: v[layer] for k, v in want.items()}
                yg = dense_apply(lg, x, qcfg=qcfg)
                yw = dense_apply(lw, x, qcfg=ucfg)
                np.testing.assert_array_equal(np.asarray(yg),
                                              np.asarray(yw))


def test_apply_plan_shrinks_artifact_below_uniform_w8():
    plan = _mixed_plan()
    fp, q = _smoke_models(plan)
    fp_params = fp.init(jax.random.PRNGKey(0))
    q_params = apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, plan)
    _, u8 = _smoke_models()
    u8_params = convert_params(u8.init(jax.random.PRNGKey(0)), fp_params, 8)
    assert artifact_bytes(q_params) < artifact_bytes(u8_params)


def test_apply_plan_wrong_plan_raises():
    plan = _mixed_plan()
    fp, q = _smoke_models(plan)
    fp_params = fp.init(jax.random.PRNGKey(0))
    other = PrecisionPlan(rules=(PlanRule("layers/*/w*", 2),))
    with pytest.raises(ValueError, match="not built with this plan"):
        apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, other)


def test_plan_json_apply_roundtrip(tmp_path):
    """plan JSON -> apply -> identical artifact as the in-memory plan."""
    plan = _mixed_plan()
    f = tmp_path / "plan.json"
    save_plan(plan, f)
    loaded = load_plan(f)
    fp, q = _smoke_models(plan)
    fp_params = fp.init(jax.random.PRNGKey(0))
    a = apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, plan)
    b = apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, loaded)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


# ---------------------------------------------------------------- serving ---

def test_engine_serves_mixed_plan():
    plan = _mixed_plan()
    fp, q = _smoke_models(plan)
    fp_params = fp.init(jax.random.PRNGKey(0))
    q_params = apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, plan)
    eng = Engine(q, q_params, batch_size=2, max_len=32, plan=plan)
    reqs = [Request(prompt=np.array([3, 5, 7], np.int32), max_new_tokens=4),
            Request(prompt=np.array([11, 2], np.int32), max_new_tokens=4),
            Request(prompt=np.array([9], np.int32), max_new_tokens=4)]
    out = eng.generate(reqs)
    assert len(out) == 3
    for r in out:
        assert r.out is not None and 1 <= len(r.out) <= 4
        assert (r.out >= 0).all() and (r.out < fp.cfg.vocab).all()
    assert eng.plan is plan
    assert eng.artifact_bytes() == artifact_bytes(q_params)


def test_end_to_end_calibrate_plan_pack(rng):
    """The full subsystem flow at smoke scale: calibrate -> auto budget ->
    plan (>= 2 distinct bit-widths) -> pack (< uniform w8)."""
    fp, _ = _smoke_models()
    fp_params = fp.init(jax.random.PRNGKey(0))
    batches = [rng.integers(2, fp.cfg.vocab, size=(2, 16)).astype(np.int32)]
    stats = calibrate(fp, fp_params, batches)
    plan = plan_mixed_precision(stats, auto_budget(stats))
    assigned = {r.w_bits for r in plan.rules}
    assert len(assigned) >= 2
    q = Model(dataclasses.replace(fp.cfg, quant=QINT, quant_plan=plan))
    q_params = apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, plan)
    _, u8 = _smoke_models()
    u8_params = convert_params(u8.init(jax.random.PRNGKey(0)), fp_params, 8)
    assert artifact_bytes(q_params) < artifact_bytes(u8_params)
    assert plan.meta["packed_weight_bytes"] < plan.meta["uniform_w8_bytes"]


# ------------------------------------------ fine-grain (channel groups) ---

def _fine_stats():
    """Skewed intra-layer sensitivity: path a's FIRST channel group is hot
    (demoting it below 8 bits is catastrophic) while the rest of the layer
    is nearly free to narrow; path b is uniformly cheap. A per-layer plan
    must keep ALL of a at 8 bits to protect the hot group — the
    channel-group plan carves it out and demotes the remaining channels."""
    import numpy as np
    from repro.core.packing import CHUNK

    def stats_for(path, d_out, hot_first_group):
        base = {8: 1e-8, 4: 1e-4, 2: 1e-2}
        col = {}
        for b, tot in base.items():
            cols = np.full((d_out,), tot / d_out, np.float64)
            if hot_first_group and b < 8:
                cols[:CHUNK] = 10.0 / CHUNK
            col[b] = cols
        return CalibStats(path, layers=2, d_in=256, d_out=d_out,
                          a_absmax=3.0,
                          sq_err={b: float(c.sum()) for b, c in col.items()},
                          sq_ref=1.0, taps=1, col_sq_err=col)

    a = stats_for("layers/mlp/wi", 3 * 128, hot_first_group=True)
    b = stats_for("layers/attn/wq", 2 * 128, hot_first_group=False)
    return {a.path: a, b.path: b}


def test_fine_plan_beats_per_layer_at_equal_budget():
    """At equal sensitivity budget the channel-group plan packs STRICTLY
    fewer bytes than the best per-layer plan on skewed stats (the ISSUE's
    acceptance bar), and the winning rule carries segments with the hot
    group kept wide."""
    stats = _fine_stats()
    budget = sum(st.sens(8) for st in stats.values()) + 0.05
    coarse = plan_mixed_precision(stats, budget, granularity="layer")
    fine = plan_mixed_precision(stats, budget, granularity="channel_group")
    assert (fine.meta["packed_weight_bytes"]
            < coarse.meta["packed_weight_bytes"])
    assert fine.meta["total_sensitivity"] <= budget
    assert fine.meta["granularity"] == "channel_group"
    by_pat = {r.pattern: r for r in fine.rules}
    wi = by_pat["layers/mlp/wi"]
    assert wi.segments is not None and len(wi.segments) >= 2
    s0, e0, b0 = wi.segments[0]
    assert (s0, e0, b0)[2] == 8 and e0 >= 128  # hot group survives at w8
    assert wi.w_bits == max(b for _, _, b in wi.segments)
    assert all(b < 8 for _, _, b in wi.segments[1:])
    # uniformly-cheap path stays a plain uniform rule (no segments)
    assert by_pat["layers/attn/wq"].segments is None


def test_fine_plan_never_worse_budget_sweep():
    """Best-of-both guarantee: across the whole budget range the fine plan
    never packs more bytes than per-layer at the same budget."""
    stats = _fine_stats()
    base = sum(st.sens(8) for st in stats.values())
    full = sum(st.sens(2) for st in stats.values())
    for frac in (0.0, 0.001, 0.01, 0.1, 0.5, 1.0):
        budget = base + frac * (full - base)
        coarse = plan_mixed_precision(stats, budget, granularity="layer")
        fine = plan_mixed_precision(stats, budget,
                                    granularity="channel_group")
        assert (fine.meta["packed_weight_bytes"]
                <= coarse.meta["packed_weight_bytes"]), frac
        # group-wise summation of the starting (all-w8) sensitivity can
        # differ from the layer sum in the last ulp — compare with slack
        assert fine.meta["total_sensitivity"] <= budget * (1 + 1e-9) + 1e-12


def test_fine_plan_group_size_validation():
    with pytest.raises(ValueError, match="CHUNK"):
        plan_mixed_precision(_fine_stats(), 1.0,
                             granularity="channel_group", group_size=100)
    with pytest.raises(ValueError, match="granularity"):
        plan_mixed_precision(_fine_stats(), 1.0, granularity="column")


def test_fine_plan_without_channel_detail_matches_layer_bytes():
    """No col_sq_err recorded: sensitivity is apportioned by group width,
    so groups demote together and the fine plan degenerates to (at worst)
    the per-layer answer — never an error, never more bytes."""
    stats = {p: dataclasses.replace(st, col_sq_err={})
             for p, st in _fine_stats().items()}
    budget = sum(st.sens(8) for st in stats.values()) + 0.05
    coarse = plan_mixed_precision(stats, budget, granularity="layer")
    fine = plan_mixed_precision(stats, budget, granularity="channel_group")
    assert (fine.meta["packed_weight_bytes"]
            <= coarse.meta["packed_weight_bytes"])


def test_plan_v4_json_roundtrip_with_segments(tmp_path):
    from repro.deploy.policy import PLAN_VERSION
    import json
    plan = plan_mixed_precision(
        _fine_stats(),
        sum(st.sens(8) for st in _fine_stats().values()) + 0.05,
        granularity="channel_group", backend="xla")
    assert any(r.segments for r in plan.rules)
    p = tmp_path / "plan.json"
    save_plan(plan, p)
    d = json.loads(p.read_text())
    assert d["version"] == PLAN_VERSION == 4
    loaded = load_plan(p)
    assert loaded.rules == plan.rules
    assert loaded.distinct_w_bits() == plan.distinct_w_bits()
    # segment widths surface in distinct_w_bits even when no uniform rule
    # uses them (the engine preloads kernels for every width it will see)
    seg_widths = {b for r in plan.rules if r.segments
                  for _, _, b in r.segments}
    assert seg_widths <= set(loaded.distinct_w_bits())


def test_plan_v3_artifact_loads_without_segments(tmp_path):
    """A v3 artifact (no segments field) loads clean: no warning, segments
    None everywhere, and resolution behaves exactly as before."""
    import json
    import warnings
    v3 = {
        "version": 3,
        "default": {"w_bits": 8, "a_bits": 8},
        "rules": [{"pattern": "layers/mlp/*", "w_bits": 4, "a_bits": 8,
                   "backend": "xla", "a_absmax": 3.0,
                   "pipeline": "double_buffer"}],
        "meta": {},
    }
    p = tmp_path / "v3.json"
    p.write_text(json.dumps(v3))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = load_plan(p)
    assert all(r.segments is None for r in plan.rules)
    qcfg = plan.resolve("layers/mlp/wi", QINT)
    assert qcfg.w_bits == 4 and qcfg.segments is None
    # re-save upgrades to v4 with explicit null segments
    save_plan(plan, p)
    d = json.loads(p.read_text())
    assert d["version"] == 4
    assert d["rules"][0]["segments"] is None


def test_plan_rule_segment_validation():
    # w_bits must equal the widest run width
    with pytest.raises(ValueError, match="widest"):
        PlanRule("layers/*", 4, segments=((0, 128, 8), (128, 256, 2)))
    # malformed maps fail loudly through SegmentMap
    with pytest.raises(ValueError, match="multiple of CHUNK"):
        PlanRule("layers/*", 8, segments=((0, 100, 8), (100, 256, 2)))
    r = PlanRule("layers/*", 8, segments=[[0, 128, 8], [128, 200, 2]])
    assert r.segments == ((0, 128, 8), (128, 200, 2))  # normalized tuples


def test_apply_plan_segmented_dense_bit_exact(rng):
    """A v4 rule with segments packs through the segmented container and
    serves bit-exactly as the composition of per-run uniform denses."""
    import jax.numpy as jnp
    from repro.core import packing
    from repro.nn.layers import dense_def, pack_dense_weights
    from repro.nn.module import init_params

    d_in, d_out = 200, 300
    segs = ((0, 128, 8), (128, 256, 4), (256, 300, 2))
    plan = PrecisionPlan(rules=(
        PlanRule("blk/proj", 8, a_absmax=3.0, segments=segs),))
    qcfg = plan.resolve("blk/proj", QINT)
    defs = {"blk": {"proj": dense_def(d_in, d_out, qcfg=qcfg)}}
    q0 = init_params(defs, jax.random.PRNGKey(0))
    w = rng.normal(size=(d_in, d_out)).astype(np.float32) * 0.1
    fp_tree = {"blk": {"proj": {"w": jnp.asarray(w)}}}
    q_params = apply_plan(q0, fp_tree, plan)
    assert q_params["blk"]["proj"]["w_packed"].shape == (
        packing.SegmentMap(segs).packed_bytes(d_in),)

    x = rng.normal(size=(5, d_in)).astype(np.float32)
    got = np.asarray(dense_apply(q_params["blk"]["proj"], x, qcfg=qcfg))
    # oracle: each run packed/served by the plain uniform dense path
    parts = []
    for s, e, b in segs:
        packed, scale = pack_dense_weights(jnp.asarray(w[:, s:e]), b,
                                           assert_range=True)
        ucfg = dataclasses.replace(QINT, w_bits=b, a_absmax=3.0)
        parts.append(np.asarray(dense_apply(
            {"w_packed": packed, "w_scale": scale}, x, qcfg=ucfg)))
    np.testing.assert_array_equal(got, np.concatenate(parts, axis=-1))
