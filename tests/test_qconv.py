"""Quantized conv (im2col+GEMM) vs direct-convolution oracle — the paper's
benchmark layer shapes at 8/4/2-bit."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (QuantSpec, quantize, calibrate_weight,
                        calibrate_activation)
from repro.core import packing
from repro.kernels.qconv import quantize_conv, qconv2d_apply, qconv2d_ref


# the 16x16 paper shape stays in the fast tier at the headline 4-bit
# width; its 8/2-bit variants (same code paths, bigger interpret grids) run
# with --runslow. The (8,12) non-square case runs at every width.
@pytest.mark.parametrize("bits,hw", [
    pytest.param(8, (16, 16), marks=pytest.mark.slow),
    (4, (16, 16)),
    pytest.param(2, (16, 16), marks=pytest.mark.slow),
    (8, (8, 12)), (4, (8, 12)), (2, (8, 12)),
])
def test_conv_vs_direct_oracle(bits, hw, rng):
    N, (H, W), Cin, Cout, F = 1, hw, 32, 64, 3
    w = rng.normal(size=(F, F, Cin, Cout)).astype(np.float32) * 0.08
    x = np.maximum(rng.normal(size=(N, H, W, Cin)), 0).astype(np.float32)
    bn_s = rng.normal(size=(Cout,)).astype(np.float32) * 0.05 + 0.3
    bn_b = rng.normal(size=(Cout,)).astype(np.float32) * 0.01
    sw = calibrate_weight(jnp.asarray(w), bits)
    sx = calibrate_activation(x, bits, 100.0)
    sy = QuantSpec.activation(bits, 8.0)
    qp = quantize_conv(jnp.asarray(w), sw, bn_s, bn_b, sx, sy, 1, 1)
    xq = quantize(jnp.asarray(x), sx)
    w_unp = np.asarray(packing.unpack(
        qp.gemm.w_packed, bits, True, axis=0))[: F * F * Cin]
    want = qconv2d_ref(np.asarray(xq), w_unp.reshape(F, F, Cin, Cout),
                       np.asarray(qp.gemm.kappa), np.asarray(qp.gemm.lam),
                       np.asarray(qp.gemm.m), qp.gemm.d, bits, 1, 1)
    got_k = qconv2d_apply(qp, xq, backend="pallas_interpret")
    got_j = qconv2d_apply(qp, xq, backend="xla")
    assert np.array_equal(np.asarray(got_k), want)
    assert np.array_equal(np.asarray(got_j), want)


def test_conv_stride2(rng):
    N, H, W, Cin, Cout, F = 1, 8, 8, 32, 32, 3
    w = rng.normal(size=(F, F, Cin, Cout)).astype(np.float32) * 0.1
    x = np.maximum(rng.normal(size=(N, H, W, Cin)), 0).astype(np.float32)
    sw = calibrate_weight(jnp.asarray(w), 4)
    sx = calibrate_activation(x, 4, 100.0)
    sy = QuantSpec.activation(4, 8.0)
    bn_s = np.ones((Cout,), np.float32) * 0.2
    bn_b = np.zeros((Cout,), np.float32)
    qp = quantize_conv(jnp.asarray(w), sw, bn_s, bn_b, sx, sy, 2, 1)
    xq = quantize(jnp.asarray(x), sx)
    w_unp = np.asarray(packing.unpack(
        qp.gemm.w_packed, 4, True, axis=0))[: F * F * Cin]
    want = qconv2d_ref(np.asarray(xq), w_unp.reshape(F, F, Cin, Cout),
                       np.asarray(qp.gemm.kappa), np.asarray(qp.gemm.lam),
                       np.asarray(qp.gemm.m), qp.gemm.d, 4, 2, 1)
    got = qconv2d_apply(qp, xq, backend="xla")
    assert np.array_equal(np.asarray(got), want)
    assert got.shape == (1, 4, 4, Cout)
