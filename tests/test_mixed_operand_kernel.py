"""Differential parity wall for the mixed-operand qdot (``qdot_mixed``).

Oracle by composition: running each segment through the *uniform* kernel
path and concatenating along N is bit-exact by construction (int32
accumulation is order-invariant), so every mixed-operand backend must
match it to the bit. The grid covers segment mixes {8|4, 8|2, 4|2,
8|4|2} x epilogues {int, dequant, raw} x ragged M/K/N x pipeline modes
{off, double_buffer} x backends {pallas_interpret, xla, eager_ref},
plus degenerate single-segment maps proving the uniform path is
untouched.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import packing
from repro.core.packing import CHUNK, SegmentMap
from repro.core.quantize import (QuantizedLinearParams,
                                 quantize_linear_segmented)
from repro.kernels import api
from repro.kernels.common import EPILOGUE_DTYPES

MIXES = {"8|4": (8, 4), "8|2": (8, 2), "4|2": (4, 2), "8|4|2": (8, 4, 2)}


def _segmap(widths, n):
    """One run per width: interior boundaries every CHUNK, ragged tail."""
    runs, pos = [], 0
    for i, b in enumerate(widths):
        end = n if i == len(widths) - 1 else pos + CHUNK
        runs.append((pos, end, b))
        pos = end
    return SegmentMap(tuple(runs))


def _mk_params(rng, k, n, widths, *, a_bits=8, a_signed=True, out_bits=8,
               d=18):
    segmap = _segmap(widths, n)
    w_hat = np.zeros((k, n), np.int8)
    for s, e, b in segmap.runs:
        lo, hi = packing.int_range(b, True)
        w_hat[:, s:e] = rng.integers(lo, hi + 1, size=(k, e - s))
    kappa = rng.integers(-127, 128, size=(n,)).astype(np.int32)
    lam = rng.integers(-2**18, 2**18, size=(n,)).astype(np.int32)
    m = rng.integers(0, 2**15, size=(n,)).astype(np.int32)
    return quantize_linear_segmented(
        jnp.asarray(w_hat), segmap, kappa, lam, m, a_bits=a_bits,
        a_signed=a_signed, d=d, out_bits=out_bits, assert_range=True)


def _mk_x(rng, mdim, k, a_bits, a_signed):
    lo, hi = packing.int_range(a_bits, a_signed)
    x = rng.integers(lo, hi + 1, size=(mdim, k)).astype(np.int8)
    xp = packing.pack(packing.pad_to_chunk(jnp.asarray(x), axis=-1),
                      a_bits, axis=-1)
    return xp


def _oracle(params, x_packed, *, epilogue="int", scale=1.0):
    """Segment-wise uniform-kernel composition (the bit-exactness oracle)."""
    outs = [api.qdot_packed(params.segment_params(i), x_packed,
                            epilogue=epilogue, scale=scale, backend="xla")
            for i in range(len(params.segmap.runs))]
    return np.concatenate([np.asarray(o) for o in outs], axis=-1)


# -------------------------------------------------------------- the grid ---


@pytest.mark.parametrize("backend", ["pallas_interpret", "xla", "eager_ref"])
@pytest.mark.parametrize("pipeline", ["off", "double_buffer"])
@pytest.mark.parametrize("mix", sorted(MIXES), ids=lambda m: f"mix={m}")
def test_parity_grid(mix, pipeline, backend, rng):
    # ragged everything: M=33, K=200 (not a CHUNK multiple), N=300
    # (ragged tail panel, exercises pad_segmented in the pallas path)
    mdim, k, n = 33, 200, 300
    params = _mk_params(rng, k, n, MIXES[mix])
    xp = _mk_x(rng, mdim, k, 8, True)
    want = _oracle(params, xp)
    got = api.qdot_packed(params, xp, backend=backend, pipeline=pipeline)
    assert got.shape == (mdim, n) and got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("backend", ["pallas_interpret", "xla"])
@pytest.mark.parametrize("epilogue", ["int", "dequant", "raw"])
def test_epilogue_parity(epilogue, backend, rng):
    params = _mk_params(rng, 200, 300, MIXES["8|4|2"])
    xp = _mk_x(rng, 16, 200, 8, True)
    scale = 0.0123 if epilogue == "dequant" else 1.0
    want = _oracle(params, xp, epilogue=epilogue, scale=scale)
    got = api.qdot_packed(params, xp, epilogue=epilogue, scale=scale,
                          backend=backend)
    assert got.dtype == EPILOGUE_DTYPES[epilogue]
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("shape", [
    (1, 128, 256),     # single row, aligned K/N
    (33, 200, 300),    # everything ragged
    (16, 256, 130),    # ragged tail panel only
    (48, 512, 384),    # aligned, multi-K-tile
])
def test_ragged_shape_sweep(shape, rng):
    mdim, k, n = shape
    params = _mk_params(rng, k, n, MIXES["8|2"])
    xp = _mk_x(rng, mdim, k, 8, True)
    want = _oracle(params, xp)
    for backend in ("pallas_interpret", "xla", "eager_ref"):
        got = api.qdot_packed(params, xp, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"{backend} {shape}")


@pytest.mark.parametrize("a_bits,a_signed", [(8, True), (4, False),
                                             (4, True), (2, False)])
def test_activation_width_mix(a_bits, a_signed, rng):
    """Mixed weights x sub-byte activations: both operands packed."""
    params = _mk_params(rng, 256, 300, MIXES["4|2"],
                        a_bits=a_bits, a_signed=a_signed)
    xp = _mk_x(rng, 32, 256, a_bits, a_signed)
    want = _oracle(params, xp)
    for backend in ("pallas_interpret", "xla"):
        got = api.qdot_packed(params, xp, backend=backend,
                              pipeline="double_buffer")
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=backend)


# -------------------------------------------------- degenerate / routing ---


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_single_segment_matches_uniform(bits, rng):
    """A one-run map must reproduce the plain uniform qdot exactly —
    the fast path for homogeneous layers is untouched."""
    k, n = 200, 256
    params = _mk_params(rng, k, n, (bits,))
    xp = _mk_x(rng, 24, k, 8, True)
    seg0 = params.segment_params(0)
    assert isinstance(seg0, QuantizedLinearParams)
    want = np.asarray(api.qdot_packed(seg0, xp, backend="xla"))
    for backend in ("pallas_interpret", "xla", "eager_ref"):
        got = api.qdot_packed(params, xp, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=backend)


def test_qdot_unpacked_entry(rng):
    """The api.qdot front door (pad + pack on the fly) routes segmented
    params through qdot_mixed, leading dims restored."""
    params = _mk_params(rng, 200, 300, MIXES["8|4"])
    lo, hi = packing.int_range(8, True)
    x = rng.integers(lo, hi + 1, size=(2, 5, 200)).astype(np.int8)
    got = api.qdot(params, jnp.asarray(x), backend="xla")
    assert got.shape == (2, 5, 300)
    xp = _mk_x(rng, 10, 200, 8, True)
    # regenerating x above != xp, so compare against the same flattened x
    xp = packing.pack(packing.pad_to_chunk(
        jnp.asarray(x.reshape(10, 200)), axis=-1), 8, axis=-1)
    want = _oracle(params, xp)
    np.testing.assert_array_equal(np.asarray(got).reshape(10, 300), want)


def test_mesh_not_implemented(rng):
    import jax
    from jax.sharding import Mesh
    params = _mk_params(rng, 128, 256, MIXES["8|4"])
    x = jnp.zeros((8, 128), jnp.int8)
    devs = np.array(jax.devices()[:2]).reshape(2, 1)
    with Mesh(devs, ("data", "model")) as mesh:
        with pytest.raises(NotImplementedError, match="co-aligned"):
            api.qdot(params, x, mesh=mesh)


def test_counters_record_segment_bytes(rng):
    """obs counters use the exact segmented byte count, not widest-width."""
    from repro import obs
    from repro.obs import counters as obs_counters
    params = _mk_params(rng, 256, 384, MIXES["8|2"])
    xp = _mk_x(rng, 16, 256, 8, True)
    obs_counters.reset()
    try:
        with obs.enabled_scope():
            api.qdot_packed(params, xp, backend="xla")
            snap = obs_counters.snapshot()
    finally:
        obs_counters.reset()
    rows = {k: v for k, v in snap.items()
            if obs_counters.parse_key(k)["op"] == "qdot_mixed"}
    assert len(rows) == 1
    (key, bucket), = rows.items()
    assert obs_counters.parse_key(key)["w_bits"] == 8  # widest width keys
    exact = params.segmap.packed_bytes(params.k_logical)
    m, k, n = 16, 256, 384
    assert bucket["packed_bytes"] == m * k + exact + m * n  # a_bits=8: pf=1
    # strictly fewer streamed bytes than a uniform-8-bit container
    assert bucket["packed_bytes"] < obs_counters.qdot_costs(
        (m, k, n), 8, 8)["packed_bytes"]
