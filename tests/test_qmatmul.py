"""Pallas packed GEMM vs numpy oracle: bit-exact across shapes/dtypes.

Per the deliverable: for each kernel, sweep shapes/dtypes and
assert_allclose (here: exact equality — integer kernels) against the
ref.py oracle.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from conftest import hypothesis_api

# guarded: property tests skip (not hard-fail) without hypothesis
given, settings, st = hypothesis_api()

from repro.core import packing
from repro.kernels.qmatmul import (qmatmul_packed, qmatmul_ref, qmatmul_jnp,
                                   qlinear_apply)
from repro.core import (QuantSpec, quantize, quantize_linear,
                        calibrate_weight, calibrate_activation)


def _mk(rng, bits, signed, shape, axis):
    lo, hi = packing.int_range(bits, signed)
    x = rng.integers(lo, hi + 1, size=shape).astype(np.int8)
    return packing.pack(jnp.asarray(x), bits, axis=axis)


BITS = [(8, 8), (8, 4), (8, 2), (4, 4), (4, 8), (2, 2), (4, 2), (2, 4),
        (2, 8)]


@pytest.mark.parametrize("ab,wb", BITS)
@pytest.mark.parametrize("signed_a", [False, True])
def test_kernel_bit_exact(ab, wb, signed_a, rng):
    # interpret-mode sizes: small, but >1 block in every grid dim
    # (grid 2x2x2 with the (32,128,128) block below)
    M, K, N = 64, 256, 256
    xp = _mk(rng, ab, signed_a, (M, K), -1)
    wp = _mk(rng, wb, True, (K, N), 0)
    kappa = rng.integers(-127, 128, size=(N,)).astype(np.int32)
    lam = rng.integers(-2**20, 2**20, size=(N,)).astype(np.int32)
    m = rng.integers(0, 2**15, size=(N,)).astype(np.int32)
    kw = dict(a_bits=ab, a_signed=signed_a, w_bits=wb, d=20, out_bits=4,
              epilogue="int")
    want = qmatmul_ref(np.asarray(xp), np.asarray(wp), kappa, lam, m, **kw)
    got = qmatmul_packed(xp, wp, jnp.asarray(kappa), jnp.asarray(lam),
                         jnp.asarray(m), block=(32, 128, 128),
                         interpret=True, **kw)
    assert np.array_equal(np.asarray(got), want)
    got_j = qmatmul_jnp(xp, wp, jnp.asarray(kappa), jnp.asarray(lam),
                        jnp.asarray(m), **kw)
    assert np.array_equal(np.asarray(got_j), want)


@pytest.mark.parametrize("shape", [(32, 128, 128), (96, 384, 128),
                                   (64, 768, 256)])
@pytest.mark.parametrize("block", [(32, 128, 128), (32, 128, 384)])
def test_kernel_shape_sweep(shape, block, rng):
    M, K, N = shape
    if K % block[2]:
        pytest.skip("K not multiple of bk")
    xp = _mk(rng, 4, False, (M, K), -1)
    wp = _mk(rng, 4, True, (K, N), 0)
    kappa = rng.integers(-64, 64, size=(N,)).astype(np.int32)
    lam = rng.integers(-2**16, 2**16, size=(N,)).astype(np.int32)
    m = rng.integers(0, 2**15, size=(N,)).astype(np.int32)
    kw = dict(a_bits=4, a_signed=False, w_bits=4, d=18, out_bits=8,
              epilogue="int")
    want = qmatmul_ref(np.asarray(xp), np.asarray(wp), kappa, lam, m, **kw)
    got = qmatmul_packed(xp, wp, jnp.asarray(kappa), jnp.asarray(lam),
                         jnp.asarray(m), block=block, interpret=True, **kw)
    assert np.array_equal(np.asarray(got), want)


@pytest.mark.parametrize("epi", ["raw", "dequant"])
def test_other_epilogues(epi, rng):
    M, K, N = 32, 256, 128
    xp = _mk(rng, 8, True, (M, K), -1)
    wp = _mk(rng, 4, True, (K, N), 0)
    z = jnp.zeros((N,), jnp.int32)
    kw = dict(a_bits=8, a_signed=True, w_bits=4, d=16, out_bits=8,
              epilogue=epi, scale=0.25)
    want = qmatmul_ref(np.asarray(xp), np.asarray(wp), z, z, z, **kw)
    got = qmatmul_packed(xp, wp, z, z, z, block=(32, 128, 256),
                         interpret=True, **kw)
    if epi == "raw":
        assert np.array_equal(np.asarray(got), want)
    else:
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=1e-2)


@given(seed=st.integers(0, 2**31 - 1),
       ab=st.sampled_from([8, 4, 2]), wb=st.sampled_from([8, 4, 2]),
       d=st.integers(16, 26))
@settings(max_examples=25, deadline=None)
def test_kernel_property(seed, ab, wb, d):
    rng = np.random.default_rng(seed)
    M, K, N = 32, 256, 128
    xp = _mk(rng, ab, False, (M, K), -1)
    wp = _mk(rng, wb, True, (K, N), 0)
    kappa = rng.integers(-127, 128, size=(N,)).astype(np.int32)
    lam = rng.integers(-2**18, 2**18, size=(N,)).astype(np.int32)
    m = rng.integers(0, 2**15, size=(N,)).astype(np.int32)
    kw = dict(a_bits=ab, a_signed=False, w_bits=wb, d=d, out_bits=8,
              epilogue="int")
    want = qmatmul_ref(np.asarray(xp), np.asarray(wp), kappa, lam, m, **kw)
    got = qmatmul_packed(xp, wp, jnp.asarray(kappa), jnp.asarray(lam),
                         jnp.asarray(m), block=(32, 128, 128),
                         interpret=True, **kw)
    assert np.array_equal(np.asarray(got), want)


def test_qlinear_apply_odd_shapes(rng):
    """ops.py wrapper: odd M/K/N with padding; calibrated params."""
    K, N, M = 288, 64, 50   # the paper's im2col K
    w = rng.normal(size=(K, N)).astype(np.float32) * 0.05
    x = np.maximum(rng.normal(size=(M, K)), 0).astype(np.float32) * 0.5
    bn_s = rng.normal(size=(N,)).astype(np.float32) * 0.1 + 1
    bn_b = rng.normal(size=(N,)).astype(np.float32) * 0.01
    sw = calibrate_weight(jnp.asarray(w), 4)
    sx = calibrate_activation(x, 4, 100.0)
    y_f = np.maximum((x @ w) * bn_s + bn_b, 0)
    sy = calibrate_activation(y_f, 4, 100.0)
    qp = quantize_linear(jnp.asarray(w), sw, bn_s, bn_b, sx, sy)
    xq = quantize(jnp.asarray(x), sx)
    yk = qlinear_apply(qp, xq, backend="pallas_interpret")
    yj = qlinear_apply(qp, xq, backend="xla")
    assert np.array_equal(np.asarray(yk), np.asarray(yj))
    assert yk.shape == (M, N)
