"""End-to-end system tests: the paper's full deployment pipeline (QAT ->
integer conv chain) and a tiny distributed-ish LM train run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.api import build
from repro.train.optimizer import OptConfig
from repro.train.step import TrainStepConfig, make_train_fns


def test_paper_pipeline_two_layer_integer_chain(rng=None):
    """conv -> BN+QNT/ACT -> conv, all-integer between layers (the PULP-NN
    execution model, §III-C), bit-exact between kernel and jnp paths."""
    rng = np.random.default_rng(0)
    from repro.core import (QuantSpec, quantize, calibrate_weight,
                            calibrate_activation)
    from repro.kernels.qconv import quantize_conv, qconv2d_apply

    N, H, W, C1, C2, C3, F = 1, 8, 8, 32, 64, 32, 3
    x = np.maximum(rng.normal(size=(N, H, W, C1)), 0).astype(np.float32)
    w1 = rng.normal(size=(F, F, C1, C2)).astype(np.float32) * 0.1
    w2 = rng.normal(size=(F, F, C2, C3)).astype(np.float32) * 0.1
    sx = calibrate_activation(x, 4, 100.0)
    s1 = QuantSpec.activation(4, 6.0)
    s2 = QuantSpec.activation(4, 6.0)
    q1 = quantize_conv(jnp.asarray(w1), calibrate_weight(jnp.asarray(w1), 4),
                       np.full((C2,), 0.2, np.float32),
                       np.zeros((C2,), np.float32), sx, s1)
    q2 = quantize_conv(jnp.asarray(w2), calibrate_weight(jnp.asarray(w2), 4),
                       np.full((C3,), 0.2, np.float32),
                       np.zeros((C3,), np.float32), s1, s2)
    xq = quantize(jnp.asarray(x), sx)
    for backend in ("xla", "pallas_interpret"):
        y1 = qconv2d_apply(q1, xq, backend=backend)
        y2 = qconv2d_apply(q2, y1, backend=backend)
        assert y2.shape == (N, H, W, C3)
        assert int(jnp.min(y2)) >= 0 and int(jnp.max(y2)) <= 15
        if backend == "xla":
            ref = np.asarray(y2)
        else:
            np.testing.assert_array_equal(np.asarray(y2), ref)


@pytest.mark.slow
def test_e2e_lm_train_loss_decreases():
    from repro.configs.gemma3_1b import smoke_config
    cfg = smoke_config()
    model = build(cfg)
    mesh = make_host_mesh()
    init_fn, step, _ = make_train_fns(
        model, mesh, ShapeConfig("t", 32, 4, "train"),
        TrainStepConfig(opt=OptConfig(lr=3e-3, warmup=5, total_steps=40)))
    data = SyntheticLM(cfg.vocab, 4, 32, seed=0)
    state = init_fn(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    losses = []
    for i in range(30):
        state, m = jstep(state, next(data))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


@pytest.mark.slow
def test_qat_fake_quant_trains():
    """QAT: fake-quant mode trains (STE gradients flow)."""
    from repro.configs.olmo_1b import smoke_config
    from repro.nn.layers import QuantConfig
    cfg = dataclasses.replace(
        smoke_config(), quant=QuantConfig(mode="fake", w_bits=4, a_bits=8))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    loss, g = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(float(loss)) and gn > 0


def test_int_deploy_mode_forward():
    """Integer deployment mode: packed weights + W4A8 XLA path."""
    from repro.configs.olmo_1b import smoke_config
    from repro.nn.layers import QuantConfig, pack_dense_weights
    cfg = dataclasses.replace(
        smoke_config(), quant=QuantConfig(mode="int", w_bits=4, a_bits=8))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))  # zeros-initialized packed
    # fill packed weights from a float init (simulating convert-from-ckpt)
    fp_cfg = smoke_config()
    fp_params = build(fp_cfg).init(jax.random.PRNGKey(0))

    def fill(qp, fp):
        if isinstance(qp, dict) and "w_packed" in qp:
            w = fp["w"]
            stack = w.ndim == 3
            if stack:
                packed, scale = jax.vmap(
                    lambda ww: pack_dense_weights(ww, 4))(w)
            else:
                packed, scale = pack_dense_weights(w, 4)
            return dict(qp, w_packed=packed, w_scale=scale)
        if isinstance(qp, dict):
            return {k: fill(qp[k], fp[k]) if k in fp else qp[k]
                    for k in qp}
        return qp

    params = fill(params, fp_params)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    logits, _, _ = model.forward(params, batch)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
