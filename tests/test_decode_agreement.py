"""THE canonical serving test: step-by-step decode must reproduce the
teacher-forced forward logits for every architecture family."""
import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.api import build

_ALL = ["olmo_1b", "phi3_mini_3p8b", "qwen2p5_3b", "gemma3_1b",
        "mamba2_370m", "recurrentgemma_9b", "seamless_m4t_large_v2",
        "llama3p2_vision_90b", "kimi_k2_1t", "llama4_maverick_400b"]
# one representative per family stays in the fast tier (attention LM, SSM);
# the remaining eight are several-second decode loops each: --runslow
_FAST = {"olmo_1b"}
ARCHS = [a if a in _FAST else pytest.param(a, marks=pytest.mark.slow)
         for a in _ALL]


def _fill_cross_kv(cfg, model, params, batch, cache):
    from repro.models.lm import _attn_cfg, _layer_split
    from repro.nn.attention import cross_kv_project
    acfg = _attn_cfg(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import encode
        enc = encode(params, batch["src_embed"], cfg)
        cache["cross_kv"] = jnp.stack([jnp.stack(cross_kv_project(
            jax.tree.map(lambda a: a[l], params["dec_layers"])["xattn"],
            enc, acfg)) for l in range(cfg.dec_layers)])
    elif cfg.cross_every:
        _, n_cross = _layer_split(cfg)
        cache["cross_kv"] = jnp.stack([jnp.stack(cross_kv_project(
            jax.tree.map(lambda a: a[l], params["cross_layers"])["xattn"],
            batch["src_embed"], acfg)) for l in range(n_cross)])
    return cache


@pytest.mark.parametrize("modname", ARCHS)
def test_decode_matches_forward(modname):
    m = importlib.import_module(f"repro.configs.{modname}")
    cfg = m.smoke_config()
    over = {"compute_dtype": "float32", "kv_quant_bits": 16}
    if cfg.moe:  # ample capacity: no train/serve drop mismatch in the test
        over["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, **over)
    model = build(cfg)
    key = jax.random.PRNGKey(7)
    params = model.init(key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec" or cfg.cross_every:
        sl = S if cfg.family == "encdec" else cfg.src_len
        batch["src_embed"] = jax.random.normal(
            key, (B, sl, cfg.d_model), jnp.float32) * 0.05
    lf, _, _ = model.forward(params, batch)
    cache = model.init_cache(B, S, jnp.float32)
    cache = _fill_cross_kv(cfg, model, params, batch, cache)
    errs = []
    for t in range(S):
        lg, cache = model.decode(params, cache, toks[:, t:t + 1],
                                 jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32) - lf[:, t].astype(jnp.float32)))))
    assert max(errs) < 2e-2, errs


@pytest.mark.slow
def test_int8_kv_cache_close():
    """int8 KV cache decode stays close to the bf16-cache decode."""
    m = importlib.import_module("repro.configs.qwen2p5_3b")
    cfg = dataclasses.replace(m.smoke_config(), compute_dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_quant_bits=8)
    key = jax.random.PRNGKey(3)
    model, model8 = build(cfg), build(cfg8)
    params = model.init(key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    c16 = model.init_cache(B, S, jnp.float32)
    c8 = model8.init_cache(B, S, jnp.float32)
    assert c8["kv"]["k"].dtype == jnp.int8
    for t in range(S):
        l16, c16 = model.decode(params, c16, toks[:, t:t + 1], jnp.int32(t))
        l8, c8 = model8.decode(params, c8, toks[:, t:t + 1], jnp.int32(t))
    p16 = jax.nn.softmax(l16[:, 0].astype(jnp.float32))
    p8 = jax.nn.softmax(l8[:, 0].astype(jnp.float32))
    assert float(jnp.max(jnp.abs(p16 - p8))) < 0.1
