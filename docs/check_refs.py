"""Docs reference checker (the CI `docs` job).

Two rules, kept deliberately narrow:

1. **Link check** — every relative markdown link `[text](target)` in
   `docs/*.md` and `README.md` must resolve to an existing file
   (anchors stripped; http(s) links skipped).
2. **paper_map contract** — every backtick code span in
   `docs/paper_map.md` that names a repo file (contains a `/` and ends
   in `.py` or `.md`) must exist relative to the repo root, so the
   paper → module/benchmark/test table can never silently rot.

Run locally: ``python docs/check_refs.py`` (exit 1 on any dangling ref).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
SPAN_RE = re.compile(r"`([^`]+)`")
PATH_RE = re.compile(r"^[A-Za-z0-9_./-]+\.(?:py|md)$")


def check_links(md: pathlib.Path, errors: list):
    for target in LINK_RE.findall(md.read_text()):
        target = target.split("#")[0].strip()
        if not target or target.startswith(("http://", "https://",
                                            "mailto:")):
            continue
        if not (md.parent / target).exists():
            errors.append(f"{md.relative_to(ROOT)}: dangling link "
                          f"-> {target}")


def check_paper_map(errors: list):
    pm = ROOT / "docs" / "paper_map.md"
    refs = set()
    for span in SPAN_RE.findall(pm.read_text()):
        span = span.strip()
        if "/" in span and PATH_RE.match(span):
            refs.add(span)
            if not (ROOT / span).exists():
                errors.append(f"docs/paper_map.md: missing file "
                              f"-> {span}")
    # coverage floor: all six benchmark scripts + both kernel op entry
    # modules + the vision subsystem must be mapped (ISSUE-4 criterion,
    # raised by ISSUE-5 to include the network-level benchmark, by
    # ISSUE-6 to include the Mac&Load pipeline row: the autotune cache,
    # the differential harness, and the benchmark-artifact schema, by
    # ISSUE-7 to include the observability subsystem, by ISSUE-8 to
    # include the continuous-batching serving runtime and its load
    # generator, by ISSUE-9 to include the fine-grain mixed-precision
    # stack: segmented containers, the mixed-operand kernel wall, and the
    # channel-group planner, and by ISSUE-10 to include the QAT→deploy
    # accuracy subsystem: STE fake-quant, task-loss calibration, and the
    # accuracy Pareto benchmark)
    required = {
        "src/repro/qat/fakequant.py",
        "src/repro/qat/train.py",
        "src/repro/qat/data.py",
        "src/repro/qat/evaluate.py",
        "src/repro/launch/qat.py",
        "src/repro/deploy/calibrate.py",
        "benchmarks/accuracy.py",
        "tests/test_qat.py",
        "src/repro/core/packing.py",
        "src/repro/core/quantize.py",
        "src/repro/deploy/planner.py",
        "src/repro/nn/layers.py",
        "tests/test_segmented_packing.py",
        "tests/test_mixed_operand_kernel.py",
        "tests/test_deploy.py",
        "src/repro/serve/runtime/scheduler.py",
        "src/repro/serve/runtime/slots.py",
        "src/repro/serve/runtime/adapters.py",
        "benchmarks/loadgen.py",
        "tests/test_runtime.py",
        "src/repro/obs/trace.py",
        "src/repro/obs/counters.py",
        "src/repro/obs/env.py",
        "src/repro/obs/report.py",
        "tests/test_obs.py",
        "benchmarks/fig8_macs_per_issue.py",
        "benchmarks/fig9_cluster_scaling.py",
        "benchmarks/fig11_conv_layers.py",
        "benchmarks/fig13_sota_comparison.py",
        "benchmarks/table1_envelope.py",
        "benchmarks/e2e_networks.py",
        "benchmarks/schema.py",
        "src/repro/kernels/qmatmul/kernel.py",
        "src/repro/kernels/qconv/kernel.py",
        "src/repro/kernels/api.py",
        "src/repro/kernels/tune.py",
        "src/repro/deploy/policy.py",
        "src/repro/vision/layers.py",
        "src/repro/vision/models.py",
        "tests/test_kernel_pipeline.py",
    }
    for miss in sorted(required - refs):
        errors.append(f"docs/paper_map.md: required coverage row absent "
                      f"-> {miss}")


def main() -> int:
    errors: list = []
    for md in [*sorted((ROOT / "docs").glob("*.md")), ROOT / "README.md"]:
        check_links(md, errors)
    check_paper_map(errors)
    for e in errors:
        print(f"ERROR: {e}")
    n_ok = "OK" if not errors else f"{len(errors)} error(s)"
    print(f"docs/check_refs: {n_ok}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
