"""Batched serving engine: prefill + streaming decode over the Model API.

Static-batch continuous decoding (slot-based): requests occupy slots; a
finished slot (EOS/max_len) is refilled from the queue at the next prefill
opportunity. Weights may be packed sub-byte (QuantConfig mode='int') — the
paper's deployment artifact; the KV cache may be int8 (kv_quant_bits=8).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    out: Optional[np.ndarray] = None


class Engine:
    def __init__(self, model: Model, params, batch_size: int,
                 max_len: int, eos_id: int = 1, plan=None):
        """`plan`: optional mixed-precision `PrecisionPlan` the params were
        packed with (repro.deploy) — kept for introspection/reporting; the
        packed shapes themselves already encode the per-layer bit-widths."""
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos = eos_id
        self.plan = plan
        self._decode = jax.jit(model.decode)

    def artifact_bytes(self) -> int:
        from repro.nn.module import param_bytes
        return param_bytes(self.params)

    def kernel_backends(self) -> dict:
        """Resolved default backend per quantized op (repro.kernels.api) —
        what this process routes int-mode denses/convs through unless a
        plan rule or REPRO_QBACKEND overrides it. For ops dashboards."""
        from repro.kernels import api
        return {op: api.default_backend(op) for op in api.OPS}

    def _prefill_scored(self, prompts):
        """Prefill via teacher-forced forward, then replay tokens into the
        decode cache (keeps one code path for cache layout)."""
        cache = self.model.init_cache(self.batch, self.max_len)
        max_p = max(len(p) for p in prompts)
        toks = np.zeros((self.batch, max_p), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        # replay prompt tokens through decode steps (slot-synchronous)
        logits = None
        for t in range(max_p):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(toks[:, t:t + 1]),
                jnp.int32(t))
        return logits, cache, max_p

    def generate(self, requests: List[Request], greedy: bool = True,
                 seed: int = 0) -> List[Request]:
        """Serve a list of requests in fixed-size batches."""
        rng = np.random.default_rng(seed)
        done: List[Request] = []
        queue = list(requests)
        while queue:
            wave = queue[: self.batch]
            queue = queue[self.batch:]
            n_real = len(wave)  # pads below must never reach `done`
            while len(wave) < self.batch:  # pad the last wave
                wave.append(Request(prompt=np.array([0], np.int32),
                                    max_new_tokens=1))
            prompts = [r.prompt for r in wave]
            logits, cache, pos = self._prefill_scored(prompts)
            outs = [[] for _ in wave]
            alive = np.ones(self.batch, bool)
            budget = np.array([r.max_new_tokens for r in wave])
            step = 0
            while alive.any() and pos + step < self.max_len and \
                    step < budget.max():
                lg = np.asarray(logits[:, -1].astype(jnp.float32))
                if greedy:
                    nxt = lg.argmax(-1).astype(np.int32)
                else:
                    p = np.exp(lg - lg.max(-1, keepdims=True))
                    p /= p.sum(-1, keepdims=True)
                    nxt = np.array([rng.choice(lg.shape[-1], p=pi)
                                    for pi in p], np.int32)
                for i in range(self.batch):
                    if alive[i]:
                        outs[i].append(int(nxt[i]))
                        if nxt[i] == self.eos or len(outs[i]) >= budget[i]:
                            alive[i] = False
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(nxt[:, None]),
                    jnp.int32(pos + step))
                step += 1
            for r, o in zip(wave, outs):
                r.out = np.array(o, np.int32)
            # only the real requests of this wave — the old
            # `max_new_tokens > 1 or out is not None` filter is always true
            # once outputs are assigned, so pad fillers leaked into `done`
            # and the final truncation could drop real requests behind them
            done.extend(wave[:n_real])
        return done
