"""Legacy batched serving engines — thin compat wrappers over
`repro.serve.runtime`.

`Engine` (LM) and `VisionEngine` (quantized CNN) keep their public
surface — ``generate``/``run``, ``utilization_report``,
``artifact_bytes``, ``kernel_backends`` — but the wave/slot/stats
machinery now lives exactly once in the runtime package: each shim is a
`Scheduler` over the matching `WorkloadAdapter` pinned to
``policy="wave"`` (admit only when every slot is free), which reproduces
the synchronous fixed-wave semantics and per-device utilization these
classes always had. Construct a `Scheduler` with the default
``policy="continuous"`` instead to get mid-wave re-admission on the same
adapters — same per-request outputs (bit-exact; see the runtime module
docs), strictly better slot occupancy.

Two legacy sharp edges are gone with the move:

* ``batch_size % dp`` no longer has to be 0 — the slot manager pads the
  physical array to the next dp multiple and never admits the pads, so
  device blocks stay whole and ragged batches just cost idle-slot
  utilization instead of a `ValueError`.
* Ragged-prompt waves are no longer pad-contaminated: the old wave
  prefill right-padded every prompt to the wave max and replayed the pad
  zeros into short prompts' caches, so a request's output could depend
  on its wave cohort. The runtime feeds each slot exactly its own
  prompt; outputs are per-request properties, independent of batching.
  (Equal-length prompts are unaffected — bit-identical to the old path.)

Cluster-parallel serving (paper fig. 9 analogy: one JAX mesh device ↔
one core of the 8-core PULP cluster): with ``mesh=`` the wave batch is
sharded data-parallel over ``dp_axis``, params are replicated, and
per-wave per-device real-slot utilization is recorded — an idle core is
a padded slot. Packed sub-byte params ride along replicated (wave DP) or
pre-sharded on output features by the kernel cluster path, never on the
packed reduction axis (`repro.parallel.sharding` invariants).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.models.api import Model
from repro.serve.runtime.adapters import (LMDecodeAdapter, Request,
                                          VisionAdapter)
from repro.serve.runtime.scheduler import Scheduler, WaveStats

# compat: tests and downstream code subclass/patch the stats mixin here
_WaveStats = WaveStats

__all__ = ["Engine", "Request", "VisionEngine", "_WaveStats"]


class _WaveShim:
    """Shared plumbing: expose the scheduler's wave-granular stats under
    the legacy attribute/method names."""

    _sched: Scheduler

    @property
    def wave_stats(self) -> List[dict]:
        return self._sched.wave_stats

    @property
    def _dp(self) -> int:
        return self._sched._dp

    def utilization_report(self) -> dict:
        return self._sched.utilization_report()

    def serving_report(self) -> dict:
        """Request-granular stats (new in the runtime; wave policy still
        records per-request submit→finish latency)."""
        return self._sched.serving_report()

    def kernel_backends(self) -> dict:
        """Resolved default backend per quantized op (repro.kernels.api) —
        what this process routes int-mode denses/convs through unless a
        plan rule or REPRO_QBACKEND overrides it. For ops dashboards."""
        from repro.kernels import api
        return {op: api.default_backend(op) for op in api.OPS}


class Engine(_WaveShim):
    """Batched LM serving: prefill + streaming decode over the Model API
    in synchronous fixed-size waves (see module docstring). Weights may
    be packed sub-byte (QuantConfig mode='int'); the KV cache may be
    int8 (kv_quant_bits=8)."""

    def __init__(self, model: Model, params, batch_size: int,
                 max_len: int, eos_id: int = 1, plan=None,
                 mesh=None, dp_axis: str = "data"):
        """`plan`: optional mixed-precision `PrecisionPlan` the params
        were packed with (repro.deploy) — kept for introspection; the
        packed shapes already encode the per-layer bit-widths.

        `mesh`: optional device mesh; waves are sharded data-parallel
        over `dp_axis` (any batch_size — ragged ones are padded to whole
        per-device blocks), params are replicated."""
        self.model = model
        self.batch = batch_size
        self.max_len = max_len
        self.eos = eos_id
        self.plan = plan
        self.mesh = mesh
        self.dp_axis = dp_axis
        self._adapter = LMDecodeAdapter(model, params, max_len,
                                        eos_id=eos_id, mesh=mesh,
                                        dp_axis=dp_axis, plan=plan)
        self.params = self._adapter.params
        self._sched = Scheduler(self._adapter, batch_size, mesh=mesh,
                                dp_axis=dp_axis, policy="wave")

    def artifact_bytes(self) -> int:
        from repro.nn.module import param_bytes
        return param_bytes(self.params)

    def generate(self, requests: List[Request], greedy: bool = True,
                 seed: int = 0) -> List[Request]:
        """Serve a list of requests in fixed-size (mesh-sharded) waves;
        returns the same `Request` objects, in order, with `.out` set."""
        return self._sched.serve(requests, greedy=greedy, seed=seed)


class VisionEngine(_WaveShim):
    """Batched quantized-CNN serving over fixed-size image waves.

    The CNN analogue of `Engine`: requests are images, a wave is a
    ``batch_size`` slab of them, and with ``mesh=`` every conv/linear in
    the net runs cluster-parallel (`repro.kernels.api` sharded entry
    points) with the wave's batch dim data-parallel over ``dp_axis`` —
    one mesh device ↔ one cluster core chewing its slice of the image
    batch. Ragged last waves (and ragged ``batch_size % dp``) are padded
    with never-admitted slots; pads don't reach results.
    """

    def __init__(self, qnet, batch_size: int, mesh=None,
                 dp_axis: str = "data", backend: Optional[str] = None):
        self.qnet = qnet
        self.batch = batch_size
        self.mesh = mesh
        self.dp_axis = dp_axis
        self.backend = backend
        self._adapter = VisionAdapter(qnet, mesh=mesh, dp_axis=dp_axis,
                                      backend=backend)
        self._sched = Scheduler(self._adapter, batch_size, mesh=mesh,
                                dp_axis=dp_axis, policy="wave")

    def artifact_bytes(self) -> int:
        from repro.vision.models import vision_artifact_bytes
        return vision_artifact_bytes(self.qnet)

    def run(self, images) -> np.ndarray:
        """Real images (M, H, W, C) -> int32 logits (M, classes), served
        in mesh-sharded waves. Dequantize with ``qnet.eps_logits``."""
        images = np.asarray(images, np.float32)
        if len(images) == 0:
            return np.zeros((0, self.qnet.cfg.num_classes), np.int32)
        return np.stack(self._sched.serve(list(images)))
