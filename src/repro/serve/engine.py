"""Batched serving engine: prefill + streaming decode over the Model API.

Static-batch continuous decoding (slot-based): requests occupy slots; a
finished slot (EOS/max_len) is refilled from the queue at the next prefill
opportunity. Weights may be packed sub-byte (QuantConfig mode='int') — the
paper's deployment artifact; the KV cache may be int8 (kv_quant_bits=8).

**Cluster-parallel serving (paper fig. 9 analogy: one JAX mesh device ↔
one core of the 8-core PULP cluster).** With ``mesh=`` the engine shards
every request wave data-parallel over the mesh's ``dp_axis``: the wave's
token/cache batch dim is laid out so device *d* owns the contiguous slot
range ``[d*B/dp, (d+1)*B/dp)``, params are replicated across the mesh,
and the jitted decode step runs SPMD — the serving analogue of the paper's
cores each processing a disjoint slice of the im2col batch. The last wave
of a ragged request list is padded to the full batch (pads never leak into
results — tracked by ``n_real``), and the engine records, per wave, how
many *real* slots each device carried; `utilization_report()` aggregates
this into the per-device utilization the paper's fig. 9 reads off the
cluster (idle cores == padded slots == lost speedup).

Sharding invariants for packed sub-byte params mirror
`repro.parallel.sharding`: packed weight arrays ride along replicated here
(wave DP), or pre-sharded over the output-feature axis by
`shard_packed_linear`/`shard_packed_conv` when the kernel-level cluster
path (`repro.kernels.api.qdot_sharded`) is in play — never sharded on the
packed reduction axis.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.obs import trace as obs


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    out: Optional[np.ndarray] = None


class _WaveStats:
    """Per-wave per-device slot utilization + latency bookkeeping, shared
    by the LM `Engine` and the CNN `VisionEngine`: device d owns the
    contiguous slot range [d*B/dp, (d+1)*B/dp); real (unpadded) slots
    fill from 0, so a padded slot is an idle cluster core (the fig. 9
    readout).

    Each wave additionally records its wall-clock latency (stamped by
    ``clock``, an instance-overridable callable so tests inject a
    deterministic fake) and the request-queue depth at admission;
    `utilization_report()` aggregates them into p50/p95/p99 latency and
    queue-depth stats next to the slot-utilization columns."""

    batch: int
    _dp: int
    clock = staticmethod(time.perf_counter)   # seconds; override in tests

    def _record_wave(self, n_real: int, queue_depth: int = 0):
        b_loc = self.batch // self._dp
        per_dev = [min(max(n_real - d * b_loc, 0), b_loc) / b_loc
                   for d in range(self._dp)]
        self.wave_stats.append({"n_real": n_real, "batch": self.batch,
                                "per_device": per_dev,
                                "queue_depth": queue_depth,
                                "t0": self.clock(), "latency_us": None})

    def _finish_wave(self):
        w = self.wave_stats[-1]
        w["latency_us"] = (self.clock() - w.pop("t0")) * 1e6
        obs.counter("engine.waves").add(1)
        obs.counter("engine.requests").add(w["n_real"])
        return w

    def utilization_report(self) -> dict:
        """Aggregate per-device slot utilization, wave-latency
        percentiles, and queue-depth stats across the waves served so
        far — a device whose slots were padding did no useful work."""
        if not self.wave_stats:
            return {"devices": self._dp, "waves": 0, "mean_util": 0.0,
                    "per_device": [0.0] * self._dp, "latency_us": None,
                    "queue_depth": None, "occupancy_timeline": []}
        per_dev = [float(np.mean([w["per_device"][d]
                                  for w in self.wave_stats]))
                   for d in range(self._dp)]
        lats = [w["latency_us"] for w in self.wave_stats
                if w.get("latency_us") is not None]
        latency = None
        if lats:
            latency = {"p50": float(np.percentile(lats, 50)),
                       "p95": float(np.percentile(lats, 95)),
                       "p99": float(np.percentile(lats, 99)),
                       "mean": float(np.mean(lats)),
                       "max": float(np.max(lats)),
                       "waves": len(lats)}
        depths = [w.get("queue_depth", 0) for w in self.wave_stats]
        return {"devices": self._dp, "waves": len(self.wave_stats),
                "mean_util": float(np.mean(per_dev)),
                "per_device": per_dev,
                "latency_us": latency,
                "queue_depth": {"mean": float(np.mean(depths)),
                                "max": int(np.max(depths))},
                # per-device real-slot occupancy over time, wave by wave
                "occupancy_timeline": [list(w["per_device"])
                                       for w in self.wave_stats]}


class Engine(_WaveStats):
    def __init__(self, model: Model, params, batch_size: int,
                 max_len: int, eos_id: int = 1, plan=None,
                 mesh=None, dp_axis: str = "data"):
        """`plan`: optional mixed-precision `PrecisionPlan` the params were
        packed with (repro.deploy) — kept for introspection/reporting; the
        packed shapes themselves already encode the per-layer bit-widths.

        `mesh`: optional device mesh; request waves are sharded
        data-parallel over `dp_axis` (batch_size must divide the axis so
        every device owns whole slots), params are replicated, and
        per-wave per-device slot utilization is recorded.
        """
        self.model = model
        self.batch = batch_size
        self.max_len = max_len
        self.eos = eos_id
        self.plan = plan
        self.mesh = mesh
        self.dp_axis = dp_axis
        self.wave_stats: List[dict] = []
        if mesh is not None:
            from repro.parallel.sharding import cluster_axis_size
            self._dp = cluster_axis_size(mesh, dp_axis)
            if batch_size % self._dp != 0:
                raise ValueError(
                    f"batch_size={batch_size} must be divisible by mesh "
                    f"axis {dp_axis!r} size {self._dp} so each device "
                    "owns whole request slots")
            from jax.sharding import NamedSharding, PartitionSpec as P
            params = jax.device_put(params, NamedSharding(mesh, P()))
        else:
            self._dp = 1
        self.params = params
        self._decode = jax.jit(model.decode)

    def artifact_bytes(self) -> int:
        from repro.nn.module import param_bytes
        return param_bytes(self.params)

    def kernel_backends(self) -> dict:
        """Resolved default backend per quantized op (repro.kernels.api) —
        what this process routes int-mode denses/convs through unless a
        plan rule or REPRO_QBACKEND overrides it. For ops dashboards."""
        from repro.kernels import api
        return {op: api.default_backend(op) for op in api.OPS}

    # ---------------------------------------------- wave sharding ----

    def _put_wave(self, arr):
        """Shard a wave-batched array (dim0 = slots) over the DP axis;
        a mesh without that axis serves replicated (dp=1), matching the
        kernel-level cluster path's pure-TP tolerance."""
        if self.mesh is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import axis_entry
        spec = P(axis_entry(self.mesh, self.dp_axis),
                 *([None] * (np.ndim(arr) - 1)))
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, spec))

    def _put_cache(self, cache):
        """Shard the decode cache's batch dim (layout-aware, see
        `repro.parallel.sharding.cache_shardings`)."""
        if self.mesh is None:
            return cache
        from repro.parallel.sharding import cache_shardings
        return jax.device_put(cache, cache_shardings(cache, self.mesh))

    # -------------------------------------------------- serving ----

    def _prefill_scored(self, prompts):
        """Prefill via teacher-forced forward, then replay tokens into the
        decode cache (keeps one code path for cache layout)."""
        cache = self._put_cache(
            self.model.init_cache(self.batch, self.max_len))
        max_p = max(len(p) for p in prompts)
        toks = np.zeros((self.batch, max_p), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
        # replay prompt tokens through decode steps (slot-synchronous)
        logits = None
        for t in range(max_p):
            logits, cache = self._decode(
                self.params, cache, self._put_wave(toks[:, t:t + 1]),
                jnp.int32(t))
        return logits, cache, max_p

    def generate(self, requests: List[Request], greedy: bool = True,
                 seed: int = 0) -> List[Request]:
        """Serve a list of requests in fixed-size (mesh-sharded) waves."""
        rng = np.random.default_rng(seed)
        done: List[Request] = []
        queue = list(requests)
        while queue:
            wave = queue[: self.batch]
            queue = queue[self.batch:]
            n_real = len(wave)  # pads below must never reach `done`
            self._record_wave(n_real, queue_depth=len(queue))
            with obs.span("engine.wave", cat="serve", n_real=n_real,
                          batch=self.batch,
                          queue_depth=len(queue)) as wave_span:
                while len(wave) < self.batch:  # pad the last wave
                    wave.append(Request(prompt=np.array([0], np.int32),
                                        max_new_tokens=1))
                prompts = [r.prompt for r in wave]
                with obs.span("engine.prefill", cat="serve"):
                    logits, cache, pos = self._prefill_scored(prompts)
                outs = [[] for _ in wave]
                alive = np.ones(self.batch, bool)
                budget = np.array([r.max_new_tokens for r in wave])
                step = 0
                while alive.any() and pos + step < self.max_len and \
                        step < budget.max():
                    lg = np.asarray(logits[:, -1].astype(jnp.float32))
                    if greedy:
                        nxt = lg.argmax(-1).astype(np.int32)
                    else:
                        p = np.exp(lg - lg.max(-1, keepdims=True))
                        p /= p.sum(-1, keepdims=True)
                        nxt = np.array([rng.choice(lg.shape[-1], p=pi)
                                        for pi in p], np.int32)
                    for i in range(self.batch):
                        if alive[i]:
                            outs[i].append(int(nxt[i]))
                            if nxt[i] == self.eos or \
                                    len(outs[i]) >= budget[i]:
                                alive[i] = False
                    logits, cache = self._decode(
                        self.params, cache, self._put_wave(nxt[:, None]),
                        jnp.int32(pos + step))
                    step += 1
                for r, o in zip(wave, outs):
                    r.out = np.array(o, np.int32)
                # only the real requests of this wave — the old
                # `max_new_tokens > 1 or out is not None` filter is always
                # true once outputs are assigned, so pad fillers leaked into
                # `done` and the final truncation could drop real requests
                # behind them
                done.extend(wave[:n_real])
                w = self._finish_wave()
                wave_span.set(decode_steps=step,
                              latency_us=w["latency_us"])
        return done


class VisionEngine(_WaveStats):
    """Batched quantized-CNN serving over fixed-size image waves.

    The CNN analogue of `Engine`: requests are images, a wave is a
    ``batch_size`` slab of them, and with ``mesh=`` every conv/linear in
    the net runs cluster-parallel (`repro.kernels.api` sharded entry
    points) with the wave's batch dim data-parallel over ``dp_axis`` —
    one mesh device ↔ one cluster core chewing its slice of the image
    batch. The last ragged wave is padded to the full batch (pads never
    reach results) and per-wave per-device real-slot utilization is
    recorded exactly like the LM engine's.
    """

    def __init__(self, qnet, batch_size: int, mesh=None,
                 dp_axis: str = "data", backend: Optional[str] = None):
        from repro.vision.models import forward_int

        self.qnet = qnet
        self.batch = batch_size
        self.mesh = mesh
        self.dp_axis = dp_axis
        self.backend = backend
        self.wave_stats: List[dict] = []
        if mesh is not None:
            from repro.parallel.sharding import cluster_axis_size
            self._dp = cluster_axis_size(mesh, dp_axis)
            if batch_size % self._dp != 0:
                raise ValueError(
                    f"batch_size={batch_size} must be divisible by mesh "
                    f"axis {dp_axis!r} size {self._dp} so each device "
                    "owns whole image slots")
        else:
            self._dp = 1
        self._forward = jax.jit(
            lambda xh: forward_int(qnet, xh, backend=backend, mesh=mesh))

    def artifact_bytes(self) -> int:
        from repro.vision.models import vision_artifact_bytes
        return vision_artifact_bytes(self.qnet)

    def kernel_backends(self) -> dict:
        from repro.kernels import api
        return {op: api.default_backend(op) for op in api.OPS}

    def run(self, images) -> np.ndarray:
        """Real images (M, H, W, C) -> int32 logits (M, classes), served
        in mesh-sharded waves. Dequantize with ``qnet.eps_logits``."""
        from repro.vision.models import quantize_input

        images = np.asarray(images, np.float32)
        x_hat = np.asarray(quantize_input(self.qnet, images))
        outs = []
        for start in range(0, len(images), self.batch):
            wave = x_hat[start:start + self.batch]
            n_real = len(wave)
            queued = max(len(images) - start - self.batch, 0)
            self._record_wave(n_real, queue_depth=queued)
            with obs.span("engine.wave", cat="serve", n_real=n_real,
                          batch=self.batch,
                          queue_depth=queued) as wave_span:
                if n_real < self.batch:  # pad last wave; pads sliced off
                    pad = np.zeros((self.batch - n_real, *wave.shape[1:]),
                                   wave.dtype)
                    wave = np.concatenate([wave, pad], axis=0)
                logits = self._forward(jnp.asarray(wave))
                outs.append(np.asarray(logits)[:n_real])
                w = self._finish_wave()
                wave_span.set(latency_us=w["latency_us"])
        return (np.concatenate(outs, axis=0) if outs
                else np.zeros((0, self.qnet.cfg.num_classes), np.int32))
