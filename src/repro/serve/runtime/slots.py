"""Slotted/paged decode-cache manager.

A *slot* is one row of the batched decode cache (KV rows for attention
LMs, recurrent state rows for SSM/Griffin, nothing for stateless vision
forwards). The manager owns the slot lifecycle — FREE → OCCUPIED on
admit, OCCUPIED → FREE on evict — and accounts cache capacity in
fixed-size *pages* of ``page_tokens`` cache positions each: a request
reserves ``ceil(min(prompt+max_new, max_len)/page_tokens)`` pages on
admission and touches them one by one as its position advances, so the
reserved-vs-used gap is the fragmentation a true shared-pool paged cache
(vLLM/MaxText page_manager style) would reclaim. The physical backing
here is still dense per slot — (slots, max_len, ...) arrays, page
accounting is bookkeeping + admission control, not indirection — which
keeps the decode step a plain batched call and bit-exact vs the wave
engines.

Ragged data-parallel meshes are absorbed here (the old engines' hard
``batch % dp == 0`` constraint): the physical slot count is padded up to
the next multiple of ``dp`` and the pad slots are never admitted, so
device *d* always owns the whole contiguous physical range
``[d*block, (d+1)*block)`` and real results are sliced back by slot id.

Capacity counters (cumulative, `repro.obs`): ``serve.admits``,
``serve.evicts``, ``serve.pages_reserved``, ``serve.pages_released``.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional

from repro.obs import trace as obs


class CapacityError(RuntimeError):
    """A request can never fit (prompt longer than the cache)."""


@dataclasses.dataclass
class Slot:
    """Lifecycle record for one cache row."""
    sid: int
    rid: Optional[int] = None        # occupying request, None == FREE
    pages_reserved: int = 0
    pages_used: int = 0
    pos: int = 0                     # next cache position the slot writes

    @property
    def free(self) -> bool:
        return self.rid is None


class SlotManager:
    def __init__(self, num_slots: int, max_len: int, *, dp: int = 1,
                 page_tokens: int = 16):
        if num_slots < 1:
            raise ValueError(f"num_slots={num_slots} must be >= 1")
        if page_tokens < 1:
            raise ValueError(f"page_tokens={page_tokens} must be >= 1")
        self.real = num_slots
        self.dp = max(int(dp), 1)
        # ragged dp: pad physical slots to the next dp multiple; pads are
        # never admitted and sliced off by slot id on the way out
        self.block = -(-num_slots // self.dp)     # slots per device
        self.phys = self.block * self.dp
        self.max_len = max_len
        self.page_tokens = page_tokens
        self.pages_per_slot = -(-max_len // page_tokens)
        self.capacity_pages = self.real * self.pages_per_slot
        self.slots: List[Slot] = [Slot(i) for i in range(self.real)]
        self._free: List[int] = list(range(self.real))  # sorted ascending

    # ------------------------------------------------------- lifecycle ---

    def _pages_for(self, tokens: int) -> int:
        return -(-min(max(tokens, 1), self.max_len) // self.page_tokens)

    def check_fits(self, prompt_len: int):
        """Admission control: a prompt longer than the cache can never be
        served (the old wave engines silently clamped the cache write)."""
        if prompt_len > self.max_len:
            raise CapacityError(
                f"prompt length {prompt_len} exceeds max_len="
                f"{self.max_len}: request can never fit its cache pages")

    def admit(self, rid: int, reserve_tokens: int) -> int:
        """Allocate the lowest free slot (deterministic placement) and
        reserve this request's worst-case pages. Caller guarantees a free
        slot exists (`free_slots > 0`)."""
        sid = self._free.pop(0)
        s = self.slots[sid]
        s.rid = rid
        s.pages_reserved = self._pages_for(reserve_tokens)
        s.pages_used = 0
        s.pos = 0
        obs.counter("serve.admits").add(1)
        obs.counter("serve.pages_reserved").add(s.pages_reserved)
        return sid

    def advance(self, sid: int, pos: int):
        """The slot just wrote cache position pos-1; grow touched pages."""
        s = self.slots[sid]
        s.pos = pos
        s.pages_used = min(self._pages_for(pos), s.pages_reserved)

    def evict(self, sid: int) -> Slot:
        """Release the slot back to the free list (lowest-first order is
        restored so placement stays deterministic)."""
        s = self.slots[sid]
        assert s.rid is not None, f"evicting free slot {sid}"
        obs.counter("serve.evicts").add(1)
        obs.counter("serve.pages_released").add(s.pages_reserved)
        out = dataclasses.replace(s)
        s.rid = None
        s.pages_reserved = s.pages_used = s.pos = 0
        bisect.insort(self._free, sid)
        return out

    # ------------------------------------------------------ accounting ---

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active(self) -> List[Slot]:
        """Occupied slots in ascending sid order."""
        return [s for s in self.slots if not s.free]

    def occupancy(self) -> float:
        return (self.real - len(self._free)) / self.real

    def pages_reserved(self) -> int:
        return sum(s.pages_reserved for s in self.slots)

    def pages_used(self) -> int:
        return sum(s.pages_used for s in self.slots)

    def device_occupancy(self) -> List[float]:
        """Fraction of each device's ``block`` physical slots doing real
        work — the fig. 9 readout (a pad or free slot is an idle core)."""
        busy = [0] * self.dp
        for s in self.slots:
            if not s.free:
                busy[s.sid // self.block] += 1
        return [b / self.block for b in busy]
