"""Event-driven serving scheduler: admission queue, per-slot occupancy,
mid-wave eviction.

The loop is token-synchronous: every `step()` runs the adapter's jitted
engine step once over the full physical slot array, feeds each occupied
slot its next input (prompt token, generated token, or image), folds the
per-slot outputs back into the request cursors, and **evicts finished
slots immediately** — under the default ``policy="continuous"`` the
freed slot is re-admitted from the queue at the very next step, so a
long request never holds the whole batch hostage (Orca-style iteration-
level scheduling). ``policy="wave"`` only admits when *all* slots are
free, which reproduces the legacy synchronous wave engines — same
per-request outputs, same `utilization_report()` — and is the baseline
the serving benchmark compares against.

Timestamps are injected (``submit(x, now=...)`` / ``step(now=...)``) so
the load generator can drive a deterministic virtual clock; when omitted
they fall back to ``self.clock`` (wall time). Latency is measured
submit→finish in the caller's time unit.

Because every adapter step is row-independent and sampling is keyed per
request, per-request outputs are **bit-exact across policies, admission
orders, and slot placements** — continuous batching changes *when* a
request runs, never *what* it computes.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from repro.obs import trace as obs
from repro.serve.runtime.slots import SlotManager


class Backpressure(RuntimeError):
    """Admission queue is full; retry after requests drain."""


class WaveStats:
    """Per-wave per-device slot utilization + latency bookkeeping (the
    legacy engines' `_WaveStats`, hoisted here so the runtime and the
    compat shims share one implementation): device d owns the contiguous
    slot range [d*B/dp, (d+1)*B/dp); real slots fill from 0, so a padded
    slot is an idle cluster core (the paper's fig. 9 readout).

    Each wave records its latency (stamped by ``clock``, an instance-
    overridable callable so tests inject a deterministic fake) and the
    request-queue depth at admission; `utilization_report()` aggregates
    p50/p95/p99 latency and queue-depth stats next to the utilization
    columns."""

    batch: int
    _dp: int
    clock = staticmethod(time.perf_counter)   # seconds; override in tests

    def __init__(self, batch: int = 0, dp: int = 1):
        self.batch = batch
        self._dp = dp
        self.wave_stats: List[dict] = []

    def _record_wave(self, n_real: int, queue_depth: int = 0):
        b_loc = self.batch // self._dp
        per_dev = [min(max(n_real - d * b_loc, 0), b_loc) / b_loc
                   for d in range(self._dp)]
        self.wave_stats.append({"n_real": n_real, "batch": self.batch,
                                "per_device": per_dev,
                                "queue_depth": queue_depth,
                                "t0": self.clock(), "latency_us": None})

    def _finish_wave(self):
        w = self.wave_stats[-1]
        w["latency_us"] = (self.clock() - w.pop("t0")) * 1e6
        obs.counter("engine.waves").add(1)
        obs.counter("engine.requests").add(w["n_real"])
        return w

    def utilization_report(self) -> dict:
        """Aggregate per-device slot utilization, wave-latency
        percentiles, and queue-depth stats across the waves served so
        far — a device whose slots were padding did no useful work."""
        if not self.wave_stats:
            return {"devices": self._dp, "waves": 0, "mean_util": 0.0,
                    "per_device": [0.0] * self._dp, "latency_us": None,
                    "queue_depth": None, "occupancy_timeline": []}
        per_dev = [float(np.mean([w["per_device"][d]
                                  for w in self.wave_stats]))
                   for d in range(self._dp)]
        lats = [w["latency_us"] for w in self.wave_stats
                if w.get("latency_us") is not None]
        latency = None
        if lats:
            latency = {"p50": float(np.percentile(lats, 50)),
                       "p95": float(np.percentile(lats, 95)),
                       "p99": float(np.percentile(lats, 99)),
                       "mean": float(np.mean(lats)),
                       "max": float(np.max(lats)),
                       "waves": len(lats)}
        depths = [w.get("queue_depth", 0) for w in self.wave_stats]
        return {"devices": self._dp, "waves": len(self.wave_stats),
                "mean_util": float(np.mean(per_dev)),
                "per_device": per_dev,
                "latency_us": latency,
                "queue_depth": {"mean": float(np.mean(depths)),
                                "max": int(np.max(depths))},
                # per-device real-slot occupancy over time, wave by wave
                "occupancy_timeline": [list(w["per_device"])
                                       for w in self.wave_stats]}


@dataclasses.dataclass
class _Entry:
    """One submitted request's lifecycle record."""
    rid: int
    cursor: Any
    submit_t: float
    admit_t: Optional[float] = None
    finish_t: Optional[float] = None
    sid: Optional[int] = None


class Scheduler(WaveStats):
    """Workload-agnostic serving loop over a `WorkloadAdapter`.

    Parameters: ``num_slots`` is the number of *real* request slots (the
    legacy engines' ``batch_size``); with ``mesh=`` the physical slot
    array is padded to the data-parallel axis size and sharded so device
    *d* owns a contiguous block (ragged ``num_slots % dp`` is absorbed
    by pad slots that are never admitted — the old hard divisibility
    constraint is gone). ``max_queue`` bounds the admission queue:
    `submit` raises `Backpressure` when it is full.
    """

    def __init__(self, adapter, num_slots: int, *, mesh=None,
                 dp_axis: str = "data", policy: str = "continuous",
                 max_queue: Optional[int] = None, page_tokens: int = 16):
        if policy not in ("continuous", "wave"):
            raise ValueError(f"unknown policy {policy!r}")
        if mesh is not None:
            from repro.parallel.sharding import cluster_axis_size
            dp = cluster_axis_size(mesh, dp_axis)
        else:
            dp = 1
        self.adapter = adapter
        self.policy = policy
        self.max_queue = max_queue
        self.slots = SlotManager(num_slots, adapter.max_len, dp=dp,
                                 page_tokens=page_tokens)
        # wave stats run over the *physical* array so per-device columns
        # line up with the mesh blocks even when num_slots % dp != 0
        super().__init__(batch=self.slots.phys, dp=dp)
        self.state = adapter.init_state(self.slots.phys)
        self._queue: Deque[_Entry] = collections.deque()
        self._entries: Dict[int, _Entry] = {}
        self.results: Dict[int, Any] = {}
        self.request_log: List[dict] = []
        self.step_log: List[dict] = []
        self._next_rid = 0
        self._rid0 = 0              # sampling-key base of the live serve()
        self._greedy = True
        self._seed = 0
        self._wave_live = 0

    # ------------------------------------------------------- admission ---

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def idle(self) -> bool:
        return not self._queue and not self.slots.active

    def submit(self, payload, now: Optional[float] = None) -> int:
        """Enqueue one request; returns its rid. Raises `Backpressure`
        when the admission queue is full and `CapacityError` when the
        request can never fit the cache."""
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            raise Backpressure(
                f"admission queue full ({self.max_queue} pending)")
        now = self.clock() if now is None else now
        rid = self._next_rid
        self._next_rid += 1
        cur = self.adapter.begin(payload, rid=rid - self._rid0,
                                 greedy=self._greedy, seed=self._seed)
        self.slots.check_fits(self.adapter.prompt_len(cur))
        e = _Entry(rid=rid, cursor=cur, submit_t=now)
        self._entries[rid] = e
        if getattr(cur, "done", False):
            # degenerate request (e.g. max_new_tokens == 0): completes
            # without ever occupying a slot
            self._finish(e, now)
        else:
            self._queue.append(e)
        return rid

    def _admit(self, now: float):
        admitted = []
        if self.policy == "wave":
            # legacy semantics: only admit when the whole array is free
            if self.slots.active or not self._queue:
                return
            n = min(self.slots.real, len(self._queue))
            for _ in range(n):
                admitted.append(self._admit_one(now))
            self._wave_live = n
            self._record_wave(n, queue_depth=len(self._queue))
        else:
            while self._queue and self.slots.free_slots:
                admitted.append(self._admit_one(now))
        if admitted:
            mask = np.zeros(self.slots.phys, bool)
            mask[[e.sid for e in admitted]] = True
            self.state = self.adapter.reset_state(self.state, mask)

    def _admit_one(self, now: float) -> _Entry:
        e = self._queue.popleft()
        e.sid = self.slots.admit(
            e.rid, self.adapter.reserve_tokens(e.cursor))
        e.admit_t = now
        return e

    # ------------------------------------------------------ event loop ---

    def step(self, now: Optional[float] = None) -> List[int]:
        """Admit from the queue, run one engine step over the slot
        array, evict finished requests. Returns finished rids. A step
        with nothing admitted and nothing active is a no-op (drain on an
        empty queue is safe)."""
        now = self.clock() if now is None else now
        self._admit(now)
        active = self.slots.active
        if not active:
            return []
        shape, dtype = self.adapter.input_spec()
        feed = np.zeros((self.slots.phys, *shape), dtype)
        pos = np.zeros(self.slots.phys, np.int32)
        for s in active:
            row, p = self.adapter.feed(self._entries[s.rid].cursor)
            feed[s.sid] = row
            pos[s.sid] = p
        with obs.span("serve.step", cat="serve", active=len(active),
                      queue_depth=len(self._queue)):
            rows, self.state = self.adapter.step(self.state, feed, pos)
        finished: List[int] = []
        for s in active:
            e = self._entries[s.rid]
            self.slots.advance(s.sid, int(pos[s.sid]) + 1)
            if self.adapter.consume(e.cursor, rows[s.sid]):
                self._finish(e, now)
                finished.append(e.rid)
        self.step_log.append({
            "t": now, "active": len(active),
            "queue_depth": len(self._queue),
            "occupancy": self.slots.occupancy(),
            "per_device": self.slots.device_occupancy()})
        return finished

    def _finish(self, e: _Entry, now: float):
        self.adapter.finish(e.cursor)
        if e.sid is not None:
            self.slots.evict(e.sid)
        e.finish_t = now
        self.results[e.rid] = self.adapter.result(e.cursor)
        self.request_log.append({
            "rid": e.rid, "submit_t": e.submit_t, "admit_t": e.admit_t,
            "finish_t": now,
            "prompt_len": self.adapter.prompt_len(e.cursor),
            "tokens_out": self.adapter.tokens_out(e.cursor)})
        if self.policy == "wave":
            if e.sid is not None:
                self._wave_live -= 1
                if self._wave_live == 0:
                    self._finish_wave()
        else:
            obs.counter("engine.requests").add(1)

    # ------------------------------------------------ batch convenience ---

    def serve(self, payloads, greedy: bool = True, seed: int = 0) -> list:
        """Submit everything, run to drain, return per-request results in
        submission order (the synchronous `Engine.generate` shape)."""
        self._greedy, self._seed = greedy, seed
        self._rid0 = self._next_rid
        rids = [self.submit(p) for p in payloads]
        self.drain()
        return [self.results[r] for r in rids]

    def drain(self):
        """Step until the queue and slot array are empty."""
        while not self.idle:
            self.step()

    # ---------------------------------------------------------- report ---

    def serving_report(self) -> dict:
        """Request-granular latency/occupancy stats (the continuous-
        batching analogue of `utilization_report`, which is wave-
        granular). Time unit is whatever the caller's clock used."""
        lats = [r["finish_t"] - r["submit_t"] for r in self.request_log]
        lat = None
        if lats:
            lat = {"p50": float(np.percentile(lats, 50)),
                   "p95": float(np.percentile(lats, 95)),
                   "p99": float(np.percentile(lats, 99)),
                   "mean": float(np.mean(lats)),
                   "max": float(np.max(lats))}
        depths = [s["queue_depth"] for s in self.step_log]
        occ = [s["occupancy"] for s in self.step_log]
        return {
            "policy": self.policy,
            "slots": self.slots.real,
            "devices": self._dp,
            "requests": len(self.request_log),
            "steps": len(self.step_log),
            "tokens_out": int(sum(r["tokens_out"]
                                  for r in self.request_log)),
            "latency": lat,
            "queue_depth": ({"mean": float(np.mean(depths)),
                             "max": int(np.max(depths))}
                            if depths else None),
            "occupancy": ({"mean": float(np.mean(occ)),
                           "min": float(np.min(occ))} if occ else None),
            "pages": {"per_slot": self.slots.pages_per_slot,
                      "capacity": self.slots.capacity_pages},
        }
