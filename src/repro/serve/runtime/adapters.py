"""Pluggable workload adapters for the serving runtime.

`WorkloadAdapter` is the contract between the scheduler (admission,
slots, stats — workload-agnostic) and a workload (what a request *is*
and what one engine step computes). An adapter provides:

* **cache spec** — ``init_state(phys_slots)`` builds the batched decode
  state (slot-major), ``place_state`` shards it over the mesh, and
  ``state_reset_keys`` names the per-slot *carried* state subtrees that
  must be cleared when a slot is re-admitted (SSM / RG-LRU recurrent
  rows; positional KV needs no clear — a fresh request's mask only ever
  admits positions it has itself written).
* **prefill/step** — ``step(state, feed, positions)`` runs one engine
  step over all physical slots and returns per-slot host outputs. The
  runtime is token-synchronous: LM prefill is the same step fed prompt
  tokens (exactly what the wave engine's replay prefill lowered to), so
  one jitted callable serves both phases at one compiled shape.
* **request cursor** — ``begin`` wraps a payload into a cursor,
  ``feed``/``consume`` drive it one step at a time, and ``consume``'s
  return value is the **finished predicate** (mid-wave eviction point).

Per-request bit-exactness invariant: every adapter's step must be
row-independent (slot *i*'s outputs depend only on slot *i*'s feeds),
which is what makes continuous batching bit-exact vs synchronous waves
regardless of admission order. The vector-position decode path
(`repro.nn.attention.attn_decode`) preserves this by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import numpy as np

# per-slot carried state that must be cleared on slot reuse, keyed by the
# cache subtree name: leaves are (layers, slots, ...) with zero init
STATE_RESET_KEYS = ("ssm", "rec")


@dataclasses.dataclass
class Request:
    """One LM generation request (public serving API; re-exported by
    `repro.serve.engine` for compatibility)."""
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    out: Optional[np.ndarray] = None


class WorkloadAdapter:
    """Base contract; see module docstring. Subclasses set ``name``,
    ``max_len`` and implement the hooks below."""

    name: str = "?"
    max_len: int = 1

    # ---- cache spec ----
    def init_state(self, phys_slots: int):
        return None

    def place_state(self, state, mesh, dp_axis):
        return state

    def reset_state(self, state, slot_mask: np.ndarray):
        """Clear carried per-slot state for slots where mask is True."""
        return state

    # ---- engine step ----
    def input_spec(self) -> Tuple[Tuple[int, ...], Any]:
        """(per-slot feed shape, dtype) for the scheduler's feed buffer."""
        raise NotImplementedError

    def step(self, state, feed: np.ndarray, positions: np.ndarray):
        """One step over all phys slots -> (per-slot host outputs, state)."""
        raise NotImplementedError

    # ---- request cursor ----
    def begin(self, payload, *, rid: int, greedy: bool = True,
              seed: int = 0):
        """Payload -> cursor. cursor.done may already be True (e.g.
        max_new_tokens == 0): such requests complete without ever
        occupying a slot."""
        raise NotImplementedError

    def feed(self, cursor) -> Tuple[np.ndarray, int]:
        """Next (input row, cache position) for this cursor's slot."""
        raise NotImplementedError

    def consume(self, cursor, row) -> bool:
        """Fold one step's output row into the cursor; True == finished
        (the scheduler evicts the slot and admits the next request)."""
        raise NotImplementedError

    def finish(self, cursor):
        """Attach final outputs to the payload (called exactly once)."""

    def result(self, cursor):
        """The per-request output object `Scheduler.serve` returns."""
        return cursor.payload

    def reserve_tokens(self, cursor) -> int:
        """Worst-case cache positions for page reservation."""
        return self.max_len

    def prompt_len(self, cursor) -> int:
        """Cache positions the request needs just to be admitted."""
        return 1

    def tokens_out(self, cursor) -> int:
        return 0


# ------------------------------------------------------------- LM decode ---

@dataclasses.dataclass
class _LMCursor:
    payload: Request
    rid: int
    prompt: np.ndarray
    max_new: int
    greedy: bool
    rng: Optional[np.random.Generator]
    next_pos: int = 0               # next cache position to feed
    pending: int = 0                # last sampled token, fed next
    out: Optional[List[int]] = None
    done: bool = False


class LMDecodeAdapter(WorkloadAdapter):
    """Token-synchronous LM decode over the Model API.

    Prefill and decode are the same jitted ``model.decode`` call with a
    per-slot position vector: a slot working through its prompt is fed
    prompt tokens (outputs ignored until the last prompt position — the
    wave engine's replay-prefill, now per slot), then generated tokens.
    An all-equal position vector is bit-exact vs the scalar-index wave
    path, so per-request outputs are identical to `Engine.generate`'s.

    Per-request semantics (cohort-independent, unlike the old ragged
    wave prefill which let a short prompt attend to pad tokens): output
    k exists iff ``k < max_new_tokens`` and ``prompt_len + k < max_len``
    and no earlier EOS; the EOS token itself is emitted (wave parity).
    Non-greedy sampling draws from a per-request generator seeded
    ``(seed, rid)`` so outputs stay admission-order invariant.
    """

    name = "lm"

    def __init__(self, model, params, max_len: int, *, eos_id: int = 1,
                 mesh=None, dp_axis: str = "data", plan=None):
        import jax

        self.model = model
        self.max_len = max_len
        self.eos = eos_id
        self.plan = plan
        self.mesh = mesh
        self.dp_axis = dp_axis
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            params = jax.device_put(params, NamedSharding(mesh, P()))
        self.params = params
        self._decode = jax.jit(model.decode)

    # ---- placement (same layout as the wave engine) ----

    def _put_wave(self, arr):
        import jax
        import jax.numpy as jnp

        if self.mesh is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.parallel.sharding import axis_entry
        spec = P(axis_entry(self.mesh, self.dp_axis),
                 *([None] * (np.ndim(arr) - 1)))
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, spec))

    def init_state(self, phys_slots: int):
        cache = self.model.init_cache(phys_slots, self.max_len)
        return self.place_state(cache, self.mesh, self.dp_axis)

    def place_state(self, cache, mesh, dp_axis):
        if mesh is None:
            return cache
        import jax

        from repro.parallel.sharding import cache_shardings
        return jax.device_put(cache, cache_shardings(cache, mesh))

    def reset_state(self, cache, slot_mask: np.ndarray):
        """Zero carried recurrent rows (SSM / RG-LRU) for re-admitted
        slots; positional KV subtrees are left alone — the causal mask
        only admits positions the new request has itself written."""
        keys = [k for k in STATE_RESET_KEYS if k in cache]
        if not keys:
            return cache
        import jax
        import jax.numpy as jnp

        mask = jnp.asarray(slot_mask)

        def clear(leaf):
            m = mask.reshape((1, mask.shape[0]) + (1,) * (leaf.ndim - 2))
            return jnp.where(m, jnp.zeros_like(leaf), leaf)

        out = dict(cache)
        for k in keys:
            out[k] = jax.tree.map(clear, cache[k])
        return self.place_state(out, self.mesh, self.dp_axis)

    # ---- engine step ----

    def input_spec(self):
        return ((1,), np.int32)

    def step(self, cache, feed, positions):
        import jax.numpy as jnp

        logits, cache = self._decode(
            self.params, cache, self._put_wave(feed),
            self._put_wave(positions.astype(np.int32)))
        rows = np.asarray(logits[:, -1].astype(jnp.float32))  # (B, V)
        return rows, cache

    # ---- request cursor ----

    def begin(self, payload: Request, *, rid: int, greedy: bool = True,
              seed: int = 0):
        prompt = np.asarray(payload.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            # zero-length prompt: pad to a single BOS(=0) token, the
            # wave engines' filler convention
            prompt = np.zeros((1,), np.int32)
        max_new = int(payload.max_new_tokens)
        cur = _LMCursor(
            payload=payload, rid=rid, prompt=prompt, max_new=max_new,
            greedy=greedy,
            rng=None if greedy else np.random.default_rng((seed, rid)),
            out=[])
        if max_new <= 0:
            cur.done = True        # completes without occupying a slot
        return cur

    def reserve_tokens(self, cur: _LMCursor) -> int:
        return len(cur.prompt) + cur.max_new

    def prompt_len(self, cur: _LMCursor) -> int:
        return len(cur.prompt)

    def feed(self, cur: _LMCursor):
        p = cur.next_pos
        tok = cur.prompt[p] if p < len(cur.prompt) else cur.pending
        return np.asarray([tok], np.int32), p

    def _sample(self, cur: _LMCursor, row: np.ndarray) -> int:
        if cur.greedy:
            return int(row.argmax(-1))
        p = np.exp(row - row.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        return int(cur.rng.choice(row.shape[-1], p=p))

    def consume(self, cur: _LMCursor, row: np.ndarray) -> bool:
        q = cur.next_pos            # the position just fed
        cur.next_pos = q + 1
        if q < len(cur.prompt) - 1:
            return False            # still prefilling: output ignored
        # output k = q - (P-1); emit iff k < max_new and P + k < max_len
        if len(cur.out) < cur.max_new and cur.next_pos < self.max_len:
            nxt = self._sample(cur, row)
            cur.out.append(nxt)
            cur.pending = nxt
            if (nxt == self.eos or len(cur.out) >= cur.max_new
                    or cur.next_pos + 1 >= self.max_len):
                cur.done = True
        else:
            cur.done = True         # no room left for another token
        return cur.done

    def finish(self, cur: _LMCursor):
        cur.payload.out = np.array(cur.out, np.int32)

    def tokens_out(self, cur: _LMCursor) -> int:
        return len(cur.out)


# ---------------------------------------------------------------- vision ---

@dataclasses.dataclass
class _VisionCursor:
    payload: np.ndarray             # quantized integer image (H, W, C)
    rid: int
    out: Optional[np.ndarray] = None
    done: bool = False


class VisionAdapter(WorkloadAdapter):
    """Stateless quantized-CNN classification: a request is one image,
    one engine step is one batched integer forward, and every admitted
    request finishes after exactly one step (admission is the only
    scheduling decision, so continuous batching == don't wait for a full
    wave). Images are quantized per request with the net's input spec —
    elementwise, so identical to the wave engine's whole-batch quantize.
    """

    name = "vision"
    max_len = 1

    def __init__(self, qnet, *, mesh=None, dp_axis: str = "data",
                 backend: Optional[str] = None):
        import jax

        from repro.vision.models import forward_int

        self.qnet = qnet
        self.mesh = mesh
        self.dp_axis = dp_axis
        self.backend = backend
        self._forward = jax.jit(
            lambda xh: forward_int(qnet, xh, backend=backend, mesh=mesh))
        self._spec = ((*qnet.cfg.in_hw, qnet.cfg.in_ch), np.int8)

    def input_spec(self):
        return self._spec

    def step(self, state, feed, positions):
        import jax.numpy as jnp

        logits = self._forward(jnp.asarray(feed))
        return np.asarray(logits), state

    def begin(self, payload, *, rid: int, greedy: bool = True,
              seed: int = 0):
        from repro.vision.models import quantize_input

        img = np.asarray(payload, np.float32)
        x_hat = np.asarray(quantize_input(self.qnet, img[None]))[0]
        return _VisionCursor(payload=x_hat, rid=rid)

    def reserve_tokens(self, cur) -> int:
        return 1

    def prompt_len(self, cur) -> int:
        return 1

    def feed(self, cur: _VisionCursor):
        return cur.payload, 0

    def consume(self, cur: _VisionCursor, row) -> bool:
        cur.out = np.asarray(row)
        cur.done = True
        return True

    def result(self, cur: _VisionCursor):
        return cur.out

    def tokens_out(self, cur: _VisionCursor) -> int:
        return 1
