"""Continuous-batching serving runtime.

One scheduler + slot manager + stats stack serves every workload through
a pluggable adapter (`adapters.WorkloadAdapter`): LM token decode and
quantized-CNN image classification ship here; the legacy wave engines in
`repro.serve.engine` are thin compat wrappers over this package.

The design mirrors the paper's cluster-utilization argument at request
granularity: a synchronous wave keeps "cores" (slots) idle behind the
wave's straggler exactly like an unbalanced im2col split idles cluster
cores; continuous batching re-admits queued requests into freed slots
mid-wave so the slot array — and with ``mesh=`` every data-parallel
device behind it — stays busy.
"""
from repro.serve.runtime.adapters import (LMDecodeAdapter, Request,
                                          VisionAdapter, WorkloadAdapter)
from repro.serve.runtime.scheduler import (Backpressure, Scheduler,
                                           WaveStats)
from repro.serve.runtime.slots import SlotManager

__all__ = ["Backpressure", "LMDecodeAdapter", "Request", "Scheduler",
           "SlotManager", "VisionAdapter", "WaveStats", "WorkloadAdapter"]
