"""Per-layer precision policy: param-path pattern -> {w_bits, a_bits, ...}.

A `PrecisionPlan` is the serializable deployment artifact of the
mixed-precision flow (calibrate -> plan -> pack -> serve). Each rule maps an
fnmatch pattern over "/"-joined parameter paths (the path of the *dense
subtree*, e.g. ``layers/mlp/wi`` or ``dec_layers/xattn/w*``) to the
bit-widths that dense layer serves at. Layer stacks are scanned
(`stack_defs`), so one path names one dense matrix group across the whole
depth — exactly the granularity at which packed shapes must stay uniform
for `jax.lax.scan`.

Plans are frozen/hashable (they ride inside the frozen `ModelConfig`) and
round-trip through JSON (`save_plan`/`load_plan`).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import pathlib
from typing import Optional, Tuple

from repro.nn.layers import QuantConfig

PLAN_VERSION = 1


@dataclasses.dataclass(frozen=True)
class PlanRule:
    """One policy entry: first matching pattern wins."""

    pattern: str                       # fnmatch over "/"-joined dense path
    w_bits: int
    a_bits: int = 8
    use_kernel: bool = False
    a_absmax: Optional[float] = None   # calibrated static activation absmax

    def matches(self, path: str) -> bool:
        return fnmatch.fnmatchcase(path, self.pattern)


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    rules: Tuple[PlanRule, ...] = ()
    default_w_bits: int = 8
    default_a_bits: int = 8
    # report/debug payload (per-path sensitivities, byte accounting, budget);
    # excluded from eq/hash so the plan stays usable inside frozen configs
    meta: dict = dataclasses.field(default_factory=dict, compare=False)

    def rule_for(self, path: str) -> Optional[PlanRule]:
        for r in self.rules:
            if r.matches(path):
                return r
        return None

    def resolve(self, path: str, base: QuantConfig) -> QuantConfig:
        """Per-dense QuantConfig for ``path``; ``base`` supplies mode and
        unspecified fields (no matching rule -> plan defaults)."""
        r = self.rule_for(path)
        if r is None:
            return dataclasses.replace(
                base, w_bits=self.default_w_bits, a_bits=self.default_a_bits)
        return dataclasses.replace(
            base, w_bits=r.w_bits, a_bits=r.a_bits, use_kernel=r.use_kernel,
            a_absmax=r.a_absmax if r.a_absmax is not None else base.a_absmax)

    def distinct_w_bits(self) -> Tuple[int, ...]:
        return tuple(sorted({r.w_bits for r in self.rules}
                            | {self.default_w_bits}))

    # ------------------------------------------------------------- json ---

    def to_json(self) -> str:
        return json.dumps({
            "version": PLAN_VERSION,
            "default": {"w_bits": self.default_w_bits,
                        "a_bits": self.default_a_bits},
            "rules": [{
                "pattern": r.pattern, "w_bits": r.w_bits, "a_bits": r.a_bits,
                "use_kernel": r.use_kernel, "a_absmax": r.a_absmax,
            } for r in self.rules],
            "meta": self.meta,
        }, indent=2, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "PrecisionPlan":
        d = json.loads(text)
        if d.get("version") != PLAN_VERSION:
            raise ValueError(f"unsupported plan version {d.get('version')}")
        rules = tuple(PlanRule(
            pattern=r["pattern"], w_bits=int(r["w_bits"]),
            a_bits=int(r.get("a_bits", 8)),
            use_kernel=bool(r.get("use_kernel", False)),
            a_absmax=(None if r.get("a_absmax") is None
                      else float(r["a_absmax"])),
        ) for r in d.get("rules", []))
        default = d.get("default", {})
        return PrecisionPlan(
            rules=rules,
            default_w_bits=int(default.get("w_bits", 8)),
            default_a_bits=int(default.get("a_bits", 8)),
            meta=d.get("meta", {}))


def resolve_qcfg(plan: Optional[PrecisionPlan], path: str,
                 base: QuantConfig) -> QuantConfig:
    """Per-dense QuantConfig resolution used throughout nn/: identity when
    no plan is active (the uniform `ModelConfig.quant` path)."""
    if plan is None:
        return base
    return plan.resolve(path, base)


def save_plan(plan: PrecisionPlan, path) -> None:
    pathlib.Path(path).write_text(plan.to_json())


def load_plan(path) -> PrecisionPlan:
    return PrecisionPlan.from_json(pathlib.Path(path).read_text())
