"""Per-layer precision policy: param-path pattern -> {w_bits, a_bits, ...}.

A `PrecisionPlan` is the serializable deployment artifact of the
mixed-precision flow (calibrate -> plan -> pack -> serve). Each rule maps an
fnmatch pattern over "/"-joined parameter paths (the path of the *dense
subtree*, e.g. ``layers/mlp/wi`` or ``dec_layers/xattn/w*``) to the
bit-widths that dense layer serves at, plus the kernel ``backend`` the op
registry (`repro.kernels.api`) should route it through. Layer stacks are
scanned (`stack_defs`), so one path names one dense matrix group across the
whole depth — exactly the granularity at which packed shapes must stay
uniform for `jax.lax.scan`.

Plans are frozen/hashable (they ride inside the frozen `ModelConfig`) and
round-trip through JSON (`save_plan`/`load_plan`). Schema v4 adds the
per-rule ``segments`` field — fine-grain mixed precision (Nadalini et al.
2307.01056): ordered (n_start, n_end, w_bits) runs over the layer's
output-feature axis, validated through `packing.SegmentMap`
(CHUNK-aligned interior boundaries), with the rule's ``w_bits`` equal to
the widest run; v1–v3 plans load clean with segments=None (uniform).
Schema v3 added the per-rule ``pipeline`` field (kernel
software-pipeline mode, the Mac&Load knob — see
`repro.kernels.common.PIPELINE_MODES`); v2 plans (``backend`` but no
``pipeline``) load unchanged with pipeline=None (resolve at run time).
v1 plans (the pre-registry ``use_kernel`` boolean) load with a single
DeprecationWarning and map True -> 'pallas_interpret', False -> 'xla'
(the booleans were explicit path pins; the same mapping every shim uses)
— re-save (e.g. via ``repro.launch.deploy --from-plan``) to upgrade the
artifact.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import pathlib
import warnings
from typing import Optional, Tuple

from repro.core import packing
from repro.kernels.common import check_pipeline
from repro.nn.layers import QuantConfig

PLAN_VERSION = 4


@dataclasses.dataclass(frozen=True)
class PlanRule:
    """One policy entry: first matching pattern wins."""

    pattern: str                       # fnmatch over "/"-joined dense path
    w_bits: int
    a_bits: int = 8
    backend: Optional[str] = None      # kernel backend (repro.kernels.api)
    a_absmax: Optional[float] = None   # calibrated static activation absmax
    pipeline: Optional[str] = None     # kernel pipeline mode (Mac&Load knob)
    # Fine-grain mixed precision (schema v4): (n_start, n_end, w_bits)
    # runs over the matched layer's output-feature axis; None -> uniform
    # w_bits. Validated via packing.SegmentMap; the rule's w_bits must be
    # the widest run width (so coarse consumers that only read w_bits
    # never under-provision).
    segments: Optional[Tuple[Tuple[int, int, int], ...]] = None
    # DEPRECATION SHIM: pre-registry boolean; normalized to None in
    # __post_init__ after mapping onto `backend`.
    use_kernel: Optional[bool] = None

    def __post_init__(self):
        if self.pipeline is not None:
            check_pipeline(self.pipeline)
        if self.segments is not None:
            sm = packing.SegmentMap(
                tuple(tuple(r) for r in self.segments))
            widest = max(b for _, _, b in sm.runs)
            if self.w_bits != widest:
                raise ValueError(
                    f"rule w_bits={self.w_bits} must equal the widest "
                    f"segment width {widest} (runs: {sm.runs})")
            object.__setattr__(self, "segments", sm.runs)
        if self.use_kernel is not None:
            if self.backend is not None:
                raise ValueError(
                    "pass either backend= or the deprecated use_kernel=, "
                    "not both")
            warnings.warn(
                "PlanRule(use_kernel=...) is deprecated; pass backend=...",
                DeprecationWarning, stacklevel=3)
            # same mapping as every other shim: the booleans were explicit
            # path pins, so False stays pinned to the XLA route
            object.__setattr__(
                self, "backend",
                "pallas_interpret" if self.use_kernel else "xla")
            object.__setattr__(self, "use_kernel", None)

    def matches(self, path: str) -> bool:
        return fnmatch.fnmatchcase(path, self.pattern)


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    rules: Tuple[PlanRule, ...] = ()
    default_w_bits: int = 8
    default_a_bits: int = 8
    # report/debug payload (per-path sensitivities, byte accounting, budget);
    # excluded from eq/hash so the plan stays usable inside frozen configs
    meta: dict = dataclasses.field(default_factory=dict, compare=False)

    def rule_for(self, path: str) -> Optional[PlanRule]:
        for r in self.rules:
            if r.matches(path):
                return r
        return None

    def resolve(self, path: str, base: QuantConfig) -> QuantConfig:
        """Per-dense QuantConfig for ``path``; ``base`` supplies mode and
        unspecified fields (no matching rule -> plan defaults)."""
        r = self.rule_for(path)
        if r is None:
            return dataclasses.replace(
                base, w_bits=self.default_w_bits, a_bits=self.default_a_bits,
                segments=None)
        return dataclasses.replace(
            base, w_bits=r.w_bits, a_bits=r.a_bits,
            backend=r.backend if r.backend is not None else base.backend,
            a_absmax=r.a_absmax if r.a_absmax is not None else base.a_absmax,
            pipeline=r.pipeline if r.pipeline is not None else base.pipeline,
            segments=r.segments)

    def distinct_w_bits(self) -> Tuple[int, ...]:
        seg = {b for r in self.rules if r.segments
               for _, _, b in r.segments}
        return tuple(sorted({r.w_bits for r in self.rules}
                            | {self.default_w_bits} | seg))

    # ------------------------------------------------------------- json ---

    def to_json(self) -> str:
        return json.dumps({
            "version": PLAN_VERSION,
            "default": {"w_bits": self.default_w_bits,
                        "a_bits": self.default_a_bits},
            "rules": [{
                "pattern": r.pattern, "w_bits": r.w_bits, "a_bits": r.a_bits,
                "backend": r.backend, "a_absmax": r.a_absmax,
                "pipeline": r.pipeline,
                "segments": (None if r.segments is None
                             else [list(run) for run in r.segments]),
            } for r in self.rules],
            "meta": self.meta,
        }, indent=2, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "PrecisionPlan":
        d = json.loads(text)
        version = d.get("version")
        if version not in (1, 2, 3, PLAN_VERSION):
            raise ValueError(f"unsupported plan version {version}")
        raw_rules = d.get("rules", [])
        if version == 1 or any("use_kernel" in r for r in raw_rules):
            # one warning per artifact, not one per rule
            warnings.warn(
                "plan JSON uses the deprecated schema-v1 'use_kernel' "
                "field; mapping True -> backend='pallas_interpret'. "
                "Re-save (repro.launch.deploy --from-plan) to upgrade.",
                DeprecationWarning, stacklevel=2)
        def _backend(r):
            if r.get("backend") is not None:
                return r["backend"]
            if "use_kernel" in r:   # v1: the boolean was an explicit pin
                return "pallas_interpret" if r["use_kernel"] else "xla"
            return None
        rules = tuple(PlanRule(
            pattern=r["pattern"], w_bits=int(r["w_bits"]),
            a_bits=int(r.get("a_bits", 8)),
            backend=_backend(r),
            a_absmax=(None if r.get("a_absmax") is None
                      else float(r["a_absmax"])),
            pipeline=r.get("pipeline"),   # absent in v1/v2 -> None
            segments=(None if r.get("segments") is None
                      else tuple(tuple(int(v) for v in run)
                                 for run in r["segments"])),  # v1–v3 -> None
        ) for r in raw_rules)
        default = d.get("default", {})
        return PrecisionPlan(
            rules=rules,
            default_w_bits=int(default.get("w_bits", 8)),
            default_a_bits=int(default.get("a_bits", 8)),
            meta=d.get("meta", {}))


def resolve_qcfg(plan: Optional[PrecisionPlan], path: str,
                 base: QuantConfig) -> QuantConfig:
    """Per-dense QuantConfig resolution used throughout nn/: identity when
    no plan is active (the uniform `ModelConfig.quant` path)."""
    if plan is None:
        return base
    return plan.resolve(path, base)


def save_plan(plan: PrecisionPlan, path) -> None:
    pathlib.Path(path).write_text(plan.to_json())


def load_plan(path) -> PrecisionPlan:
    return PrecisionPlan.from_json(pathlib.Path(path).read_text())
