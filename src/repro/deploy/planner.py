"""Bit-width search: assign W{8,4,2} per dense layer to minimize packed
weight bytes subject to a total-sensitivity budget.

Objective: the deployment memory-roofline term — packed weight HBM bytes
(byte accounting via `launch/hlo_costs.py::shape_numel_bytes`, the same
helper the dry-run cost model charges HBM traffic with). Decode serving is
weight-streaming-bound, so packed bytes ~ time-per-token.

Constraint: sum of per-path output-MSE sensitivity proxies (from
`calibrate`) must stay <= budget.

Search: greedy marginal-rate knapsack. Start everything at the widest
candidate (8), repeatedly take the single one-step demotion (8->4 or 4->2)
with the best bytes-saved-per-sensitivity-added ratio that still fits the
budget. Monotone candidate chains make this the classic 2-approximation;
at per-matrix-group granularity (a handful to a few dozen paths) it is
effectively exact and deterministic.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.core import packing
from repro.deploy.calibrate import CANDIDATE_BITS, CalibStats
from repro.deploy.policy import PlanRule, PrecisionPlan
from repro.launch.hlo_costs import shape_numel_bytes
from repro.obs import trace as obs


def packed_weight_bytes(layers: int, d_in: int, d_out: int,
                        w_bits: int) -> int:
    """HBM bytes of one dense path's packed weights (int8 containers,
    chunk-planar along the padded K axis) + its f32 per-channel scales."""
    kp = packing.padded_size(d_in) // packing.pack_factor(w_bits)
    _, wb = shape_numel_bytes(f"s8[{layers},{kp},{d_out}]")
    _, sb = shape_numel_bytes(f"f32[{layers},{d_out}]")
    return wb + sb


def _path_bytes(st: CalibStats, bits: int) -> int:
    return packed_weight_bytes(st.layers, st.d_in, st.d_out, bits)


def auto_budget(stats: Dict[str, CalibStats],
                candidates: Sequence[int] = CANDIDATE_BITS,
                frac: float = 0.5) -> float:
    """A budget `frac` of the way between the all-widest total sensitivity
    and the all-narrowest one — guaranteed to admit some demotions and
    (for any non-degenerate sensitivity spread) to forbid others."""
    hi_b, lo_b = max(candidates), min(candidates)
    base = sum(st.sens(hi_b) for st in stats.values())
    full = sum(st.sens(lo_b) for st in stats.values())
    return base + frac * (full - base)


def plan_mixed_precision(stats: Dict[str, CalibStats], budget: float, *,
                         candidates: Sequence[int] = CANDIDATE_BITS,
                         a_bits: int = 8, backend: Optional[str] = None,
                         meta: Optional[dict] = None) -> PrecisionPlan:
    """Greedy knapsack over calibration stats -> serializable plan.

    ``backend`` names the kernel backend (repro.kernels.api) the plan's
    rules route their quantized ops through; None defers to the registry's
    capability-ordered default at serve time.
    """
    cand = sorted(set(candidates), reverse=True)      # e.g. [8, 4, 2]
    if not cand:
        raise ValueError("no candidate bit-widths")
    assign = {p: cand[0] for p in stats}
    total = sum(stats[p].sens(cand[0]) for p in stats)

    def next_bits(b: int) -> Optional[int]:
        i = cand.index(b)
        return cand[i + 1] if i + 1 < len(cand) else None

    with obs.span("plan.search", cat="deploy", paths=len(stats),
                  budget=float(budget)) as search_span:
        while True:
            best, best_rate = None, -1.0
            for p, b in assign.items():
                nb = next_bits(b)
                if nb is None:
                    continue
                d_sens = stats[p].sens(nb) - stats[p].sens(b)
                d_bytes = _path_bytes(stats[p], b) - _path_bytes(stats[p], nb)
                if d_bytes <= 0:
                    continue
                if total + max(d_sens, 0.0) > budget:
                    continue
                rate = d_bytes / max(d_sens, 1e-12)
                if rate > best_rate:
                    best, best_rate = (p, nb, d_sens), rate
            if best is None:
                break
            p, nb, d_sens = best
            assign[p] = nb
            total += d_sens
        search_span.set(
            total_sensitivity=total,
            demotions=sum(1 for p in assign if assign[p] != cand[0]))

    table = {p: {
        "w_bits": assign[p],
        "layers": stats[p].layers, "d_in": stats[p].d_in,
        "d_out": stats[p].d_out,
        "a_absmax": round(stats[p].a_absmax, 6),
        "sens": {str(b): stats[p].sens(b) for b in cand},
        "bytes": {str(b): _path_bytes(stats[p], b) for b in cand},
    } for p in sorted(stats)}
    plan_meta = {
        "budget": budget,
        "total_sensitivity": total,
        "packed_weight_bytes": sum(
            _path_bytes(stats[p], assign[p]) for p in stats),
        "uniform_w8_bytes": sum(
            _path_bytes(stats[p], cand[0]) for p in stats),
        "paths": table,
    }
    if meta:
        plan_meta.update(meta)
    rules = tuple(
        PlanRule(pattern=p, w_bits=assign[p], a_bits=a_bits,
                 backend=backend,
                 a_absmax=(round(stats[p].a_absmax, 6)
                           if stats[p].a_absmax > 0 else None))
        for p in sorted(stats))
    return PrecisionPlan(rules=rules, default_w_bits=cand[0],
                         default_a_bits=a_bits, meta=plan_meta)
