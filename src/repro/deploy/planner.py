"""Bit-width search: assign W{8,4,2} per dense layer to minimize packed
weight bytes subject to a total-sensitivity budget.

Objective: the deployment memory-roofline term — packed weight HBM bytes
(byte accounting via `launch/hlo_costs.py::shape_numel_bytes`, the same
helper the dry-run cost model charges HBM traffic with). Decode serving is
weight-streaming-bound, so packed bytes ~ time-per-token.

Constraint: sum of per-path output-MSE sensitivity proxies (from
`calibrate`) must stay <= budget.

Search: greedy marginal-rate knapsack. Start everything at the widest
candidate (8), repeatedly take the single one-step demotion (8->4 or 4->2)
with the best bytes-saved-per-sensitivity-added ratio that still fits the
budget. Monotone candidate chains make this the classic 2-approximation;
at per-matrix-group granularity (a handful to a few dozen paths) it is
effectively exact and deterministic.

**Granularity** (fine-grain mixed precision, Nadalini et al. 2307.01056):
``granularity='layer'`` is the classic whole-path knapsack above;
``'channel_group'`` splits every path's output-feature axis into
CHUNK-sized channel groups and lets the same greedy demote groups
independently (sensitivity signal: `CalibStats.col_sens`, apportioned by
width when channel detail is absent). Adjacent equal-width groups merge
into (n_start, n_end, w_bits) runs -> `PlanRule.segments` (plan schema
v4); a path whose groups all land on one width emits a plain uniform
rule. Because greedy isn't optimal, the channel-group planner also runs
the per-layer search at the same budget and returns whichever plan packs
fewer total bytes — fine plans are never worse, and strictly better
whenever sensitivity is skewed *within* a layer.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.core import packing
from repro.deploy.calibrate import CANDIDATE_BITS, CalibStats
from repro.deploy.policy import PlanRule, PrecisionPlan
from repro.launch.hlo_costs import shape_numel_bytes
from repro.obs import trace as obs


def packed_weight_bytes(layers: int, d_in: int, d_out: int,
                        w_bits: int) -> int:
    """HBM bytes of one dense path's packed weights (int8 containers,
    chunk-planar along the padded K axis) + its f32 per-channel scales."""
    kp = packing.padded_size(d_in) // packing.pack_factor(w_bits)
    _, wb = shape_numel_bytes(f"s8[{layers},{kp},{d_out}]")
    _, sb = shape_numel_bytes(f"f32[{layers},{d_out}]")
    return wb + sb


def _path_bytes(st: CalibStats, bits: int) -> int:
    return packed_weight_bytes(st.layers, st.d_in, st.d_out, bits)


def segmented_path_bytes(layers: int, d_in: int, d_out: int, runs) -> int:
    """HBM bytes of one dense path's *segmented* packed weights + scales.

    For a single uniform run this equals `packed_weight_bytes` exactly
    (the segmented container of one run is byte-identical to the uniform
    one), so per-layer and fine-grain plans are compared on one scale."""
    total = packing.SegmentMap(tuple(runs)).packed_bytes(d_in)
    _, wb = shape_numel_bytes(f"s8[{layers},{total}]")
    _, sb = shape_numel_bytes(f"f32[{layers},{d_out}]")
    return wb + sb


def auto_budget(stats: Dict[str, CalibStats],
                candidates: Sequence[int] = CANDIDATE_BITS,
                frac: float = 0.5) -> float:
    """A budget `frac` of the way between the all-widest total sensitivity
    and the all-narrowest one — guaranteed to admit some demotions and
    (for any non-degenerate sensitivity spread) to forbid others."""
    hi_b, lo_b = max(candidates), min(candidates)
    base = sum(st.sens(hi_b) for st in stats.values())
    full = sum(st.sens(lo_b) for st in stats.values())
    return base + frac * (full - base)


def plan_mixed_precision(stats: Dict[str, CalibStats], budget: float, *,
                         candidates: Sequence[int] = CANDIDATE_BITS,
                         a_bits: int = 8, backend: Optional[str] = None,
                         meta: Optional[dict] = None,
                         granularity: str = "layer",
                         group_size: int = packing.CHUNK) -> PrecisionPlan:
    """Greedy knapsack over calibration stats -> serializable plan.

    ``backend`` names the kernel backend (repro.kernels.api) the plan's
    rules route their quantized ops through; None defers to the registry's
    capability-ordered default at serve time. ``granularity`` selects the
    move set (module docstring): 'layer' demotes whole paths,
    'channel_group' demotes ``group_size``-wide output-channel groups and
    emits `PlanRule.segments` — never packing more bytes than the
    per-layer plan at the same budget.
    """
    cand = sorted(set(candidates), reverse=True)      # e.g. [8, 4, 2]
    if not cand:
        raise ValueError("no candidate bit-widths")
    if granularity == "channel_group":
        if group_size % packing.CHUNK:
            raise ValueError(
                f"group_size={group_size} must be a CHUNK "
                f"({packing.CHUNK}) multiple: SegmentMap requires "
                "CHUNK-aligned interior run boundaries")
        fine = _plan_channel_groups(stats, budget, cand, a_bits, backend,
                                    meta, group_size)
        coarse = _plan_layer(stats, budget, cand, a_bits, backend, meta)
        # greedy is a 2-approximation, not optimal: guarantee fine plans
        # never lose to per-layer at equal budget by taking the better
        if (coarse.meta["packed_weight_bytes"]
                < fine.meta["packed_weight_bytes"]):
            return coarse
        return fine
    if granularity != "layer":
        raise ValueError(
            f"unknown granularity {granularity!r}; expected 'layer' or "
            "'channel_group'")
    return _plan_layer(stats, budget, cand, a_bits, backend, meta)


def _plan_layer(stats: Dict[str, CalibStats], budget: float, cand,
                a_bits: int, backend: Optional[str],
                meta: Optional[dict]) -> PrecisionPlan:
    assign = {p: cand[0] for p in stats}
    total = sum(stats[p].sens(cand[0]) for p in stats)

    def next_bits(b: int) -> Optional[int]:
        i = cand.index(b)
        return cand[i + 1] if i + 1 < len(cand) else None

    with obs.span("plan.search", cat="deploy", paths=len(stats),
                  budget=float(budget)) as search_span:
        while True:
            best, best_rate = None, -1.0
            for p, b in assign.items():
                nb = next_bits(b)
                if nb is None:
                    continue
                d_sens = stats[p].sens(nb) - stats[p].sens(b)
                d_bytes = _path_bytes(stats[p], b) - _path_bytes(stats[p], nb)
                if d_bytes <= 0:
                    continue
                if total + max(d_sens, 0.0) > budget:
                    continue
                rate = d_bytes / max(d_sens, 1e-12)
                if rate > best_rate:
                    best, best_rate = (p, nb, d_sens), rate
            if best is None:
                break
            p, nb, d_sens = best
            assign[p] = nb
            total += d_sens
        search_span.set(
            total_sensitivity=total,
            demotions=sum(1 for p in assign if assign[p] != cand[0]))

    table = {p: {
        "w_bits": assign[p],
        "layers": stats[p].layers, "d_in": stats[p].d_in,
        "d_out": stats[p].d_out,
        "a_absmax": round(stats[p].a_absmax, 6),
        "sens": {str(b): stats[p].sens(b) for b in cand},
        "bytes": {str(b): _path_bytes(stats[p], b) for b in cand},
    } for p in sorted(stats)}
    plan_meta = {
        "budget": budget,
        "total_sensitivity": total,
        "packed_weight_bytes": sum(
            _path_bytes(stats[p], assign[p]) for p in stats),
        "uniform_w8_bytes": sum(
            _path_bytes(stats[p], cand[0]) for p in stats),
        "paths": table,
    }
    if meta:
        plan_meta.update(meta)
    rules = tuple(
        PlanRule(pattern=p, w_bits=assign[p], a_bits=a_bits,
                 backend=backend,
                 a_absmax=(round(stats[p].a_absmax, 6)
                           if stats[p].a_absmax > 0 else None))
        for p in sorted(stats))
    return PrecisionPlan(rules=rules, default_w_bits=cand[0],
                         default_a_bits=a_bits, meta=plan_meta)


def _plan_channel_groups(stats: Dict[str, CalibStats], budget: float, cand,
                         a_bits: int, backend: Optional[str],
                         meta: Optional[dict],
                         group_size: int) -> PrecisionPlan:
    """Channel-group knapsack: same greedy loop as `_plan_layer`, but the
    demotion items are (path, output-channel group) pairs."""
    groups = {}                  # (path, gi) -> (n_start, n_end)
    for p, st in stats.items():
        for gi, s in enumerate(range(0, st.d_out, group_size)):
            groups[(p, gi)] = (s, min(s + group_size, st.d_out))

    def g_sens(p, g, b):
        st = stats[p]
        cols = st.col_sens(b)
        s, e = g
        if cols is None:
            # no channel detail recorded: apportion the layer sensitivity
            # by group width (keeps group sums == layer sens, so the
            # budget means the same thing at both granularities)
            return st.sens(b) * (e - s) / max(st.d_out, 1)
        return float(cols[s:e].sum())

    def g_bytes(p, g, b):
        st = stats[p]
        s, e = g
        kp = packing.padded_size(st.d_in) // packing.pack_factor(b)
        return st.layers * kp * (e - s)   # scales don't vary with width

    def next_bits(b):
        i = cand.index(b)
        return cand[i + 1] if i + 1 < len(cand) else None

    assign = {k: cand[0] for k in groups}
    total = sum(g_sens(p, g, cand[0]) for (p, _), g in groups.items())

    with obs.span("plan.search", cat="deploy", paths=len(stats),
                  groups=len(groups), budget=float(budget),
                  granularity="channel_group") as search_span:
        while True:
            best, best_rate = None, -1.0
            for key, b in assign.items():
                nb = next_bits(b)
                if nb is None:
                    continue
                p, _ = key
                g = groups[key]
                d_sens = g_sens(p, g, nb) - g_sens(p, g, b)
                d_bytes = g_bytes(p, g, b) - g_bytes(p, g, nb)
                if d_bytes <= 0:
                    continue
                if total + max(d_sens, 0.0) > budget:
                    continue
                rate = d_bytes / max(d_sens, 1e-12)
                if rate > best_rate:
                    best, best_rate = (key, nb, d_sens), rate
            if best is None:
                break
            key, nb, d_sens = best
            assign[key] = nb
            total += d_sens
        search_span.set(
            total_sensitivity=total,
            demotions=sum(1 for k in assign if assign[k] != cand[0]))

    # merge adjacent equal-width groups into (n_start, n_end, w_bits) runs
    path_runs, path_bytes = {}, {}
    for p in sorted(stats):
        runs = []
        gi = 0
        while (p, gi) in groups:
            s, e = groups[(p, gi)]
            b = assign[(p, gi)]
            if runs and runs[-1][2] == b:
                runs[-1] = (runs[-1][0], e, b)
            else:
                runs.append((s, e, b))
            gi += 1
        path_runs[p] = tuple(runs)
        path_bytes[p] = segmented_path_bytes(
            stats[p].layers, stats[p].d_in, stats[p].d_out, runs)

    table = {p: {
        "w_bits": max(b for _, _, b in path_runs[p]),
        "segments": [list(r) for r in path_runs[p]],
        "layers": stats[p].layers, "d_in": stats[p].d_in,
        "d_out": stats[p].d_out,
        "a_absmax": round(stats[p].a_absmax, 6),
        "sens": {str(b): stats[p].sens(b) for b in cand},
        "bytes": path_bytes[p],
    } for p in sorted(stats)}
    plan_meta = {
        "budget": budget,
        "granularity": "channel_group",
        "group_size": group_size,
        "total_sensitivity": total,
        "packed_weight_bytes": sum(path_bytes.values()),
        "uniform_w8_bytes": sum(
            _path_bytes(stats[p], cand[0]) for p in stats),
        "paths": table,
    }
    if meta:
        plan_meta.update(meta)
    rules = tuple(
        PlanRule(pattern=p,
                 w_bits=max(b for _, _, b in path_runs[p]), a_bits=a_bits,
                 backend=backend,
                 a_absmax=(round(stats[p].a_absmax, 6)
                           if stats[p].a_absmax > 0 else None),
                 segments=(None if len(path_runs[p]) == 1
                           else path_runs[p]))
        for p in sorted(stats))
    return PrecisionPlan(rules=rules, default_w_bits=cand[0],
                         default_a_bits=a_bits, meta=plan_meta)
