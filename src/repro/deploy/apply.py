"""Plan-driven checkpoint conversion: fp tree -> mixed-precision packed tree.

Generalizes the uniform `launch/convert.py::convert_params` to heterogeneous
bit-widths: the walk tracks the "/"-joined parameter path and resolves each
dense subtree's `w_bits` through the `PrecisionPlan`. Packing happens on the
host (eager), so the out-of-range truncation guard in `core/packing.py` is
armed — a mis-quantized value raises instead of corrupting the artifact.

Per-dense math is `nn/layers.py::pack_dense_weights` (per-output-channel
symmetric grids, chunk-planar packing), so a plan-converted layer is
bit-exact against the uniform path at the same bit-width. Rules with
``segments`` (plan schema v4, fine-grain mixed precision) pack through
`pack_dense_weights_segmented` into the flat segmented container the
v4-built defs expect.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.deploy.policy import PrecisionPlan
from repro.nn.layers import (QuantConfig, pack_dense_weights,
                             pack_dense_weights_segmented)


def _is_dense_q(node) -> bool:
    return isinstance(node, dict) and "w_packed" in node


def apply_plan(q_tree, fp_tree, plan: Optional[PrecisionPlan],
               default_w_bits: int = 8, *, assert_range: bool = True,
               _path: Tuple[str, ...] = ()):
    """Fill an int-mode parameter tree (zeros-initialized `w_packed` /
    `w_scale` leaves) from the fp checkpoint tree, quantizing each dense
    at its plan-resolved bit-width. Stacked (scanned) layer weights pack
    along their own K axis — no vmap, so the range guard sees the whole
    stack. `plan=None` reproduces the uniform `default_w_bits` path."""
    if _is_dense_q(q_tree):
        path = "/".join(_path)
        qcfg = QuantConfig(mode="int", w_bits=default_w_bits)
        if plan is not None:
            qcfg = plan.resolve(path, qcfg)
        if qcfg.segments is not None:
            packed, scale = pack_dense_weights_segmented(
                fp_tree["w"], qcfg.segments, assert_range=assert_range)
        else:
            packed, scale = pack_dense_weights(fp_tree["w"], qcfg.w_bits,
                                               assert_range=assert_range)
        if packed.shape != q_tree["w_packed"].shape:
            raise ValueError(
                f"{path}: packed shape {packed.shape} != def shape "
                f"{q_tree['w_packed'].shape} — the model was not built with "
                "this plan (pass the same plan via ModelConfig.quant_plan)")
        out = dict(q_tree, w_packed=packed, w_scale=scale)
        if "b" in q_tree and "b" in fp_tree:
            out["b"] = fp_tree["b"]
        return out
    if isinstance(q_tree, dict):
        return {k: (apply_plan(q_tree[k], fp_tree[k], plan, default_w_bits,
                               assert_range=assert_range,
                               _path=_path + (k,))
                    if k in fp_tree else q_tree[k]) for k in q_tree}
    # non-dense leaves (norms, embeddings, router, conv, ...) pass through
    return fp_tree


def quantized_dense_paths(defs, _path: Tuple[str, ...] = ()) -> Tuple[str, ...]:
    """Paths of every dense subtree the int deployment mode packs (walked
    from a ParamDef tree built with `quant.mode == "int"`). This is the
    planner's decision universe — denses defined with `qcfg=QOFF` (e.g. the
    untied logits head) never appear."""
    if isinstance(defs, dict):
        if "w_packed" in defs:
            return ("/".join(_path),)
        out: list = []
        for k in sorted(defs):
            out.extend(quantized_dense_paths(defs[k], _path + (k,)))
        return tuple(out)
    return ()


def dense_inventory(fp_params, paths) -> Dict[str, Tuple[int, int, int]]:
    """path -> (n_stacked_layers, d_in, d_out) for each quantized dense,
    read off the fp checkpoint ((K,N) or stacked (L,K,N) `w` leaves)."""
    out = {}
    for path in paths:
        node = fp_params
        for part in path.split("/"):
            node = node[part]
        w = node["w"]
        if w.ndim == 3:
            out[path] = (int(w.shape[0]), int(w.shape[1]), int(w.shape[2]))
        else:
            out[path] = (1, int(w.shape[0]), int(w.shape[1]))
    return out
