"""Mixed-precision deployment planner (XpulpNN's *flexible* inference).

Turns an fp checkpoint into a heterogeneous W{8,4,2} packed serving
artifact: a per-dense-layer precision policy (`policy`), a calibration
pass recording activation ranges and bit-width sensitivity (`calibrate`),
a byte-minimizing bit-width search under a sensitivity budget (`planner`),
and the plan-driven checkpoint converter (`apply`).

Only `policy` is imported eagerly: `configs.base` embeds `PrecisionPlan`
in `ModelConfig`, while `calibrate` imports the model zoo (which imports
`configs.base`) — the heavier submodules load lazily via PEP 562.
"""
from repro.deploy.policy import (PlanRule, PrecisionPlan, load_plan,  # noqa
                                 resolve_qcfg, save_plan)

_LAZY = {
    "apply_plan": "repro.deploy.apply",
    "dense_inventory": "repro.deploy.apply",
    "quantized_dense_paths": "repro.deploy.apply",
    "CalibStats": "repro.deploy.calibrate",
    "calibrate": "repro.deploy.calibrate",
    "calibrate_vision": "repro.deploy.calibrate",
    "auto_budget": "repro.deploy.planner",
    "plan_mixed_precision": "repro.deploy.planner",
}

__all__ = ["PlanRule", "PrecisionPlan", "resolve_qcfg", "save_plan",
           "load_plan"] + sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
