"""Calibration: per-layer activation ranges + bit-width sensitivity proxies.

An *eager* layer-by-layer replay of the fp model (no jit, no scan — stacked
layer params are indexed per depth) with the `nn/layers.py::dense_tap`
observer installed. For every quantized dense path the tap records:

  a_absmax   — running max |x| over all calibration tokens (the static
               activation scale the int serving path uses), and
  sens[b]    — an output-MSE sensitivity proxy per candidate w_bits b:
               relative MSE of the simulated W{b}A8 integer GEMM against
               the fp matmul, accumulated over depth instances and batches.

The proxy simulates exactly the deployed integer path's arithmetic
(per-output-channel symmetric weight grids, symmetric int8 activations) but
skips packing — so it prices what serving at bits b actually costs in
output error, per layer, on real activation statistics. The planner trades
these against packed-byte savings.

Families without an eager replay (encdec/mamba/griffin and cross-attn LMs)
fall back to weight-only sensitivities (activation second moment assumed
1.0, default absmax) — still a usable ordering, just less sharp.

**CNNs** (`repro.vision`) calibrate through `calibrate_vision`: the
`repro.vision.layers::conv_tap` observer (the conv analogue of
`dense_tap`) records per-conv/depthwise/head input absmax and a simulated
W{b}A8 output-MSE sensitivity — the quantized op is simulated on the
layer's real geometry (stride/padding/groups from the graph) with the
same *per-tensor* symmetric weight grids the vision packers deploy
(`calibrate_weight`; the LM denses use per-output-channel grids instead) —
while an `edge_tap` records every layer-boundary absmax, which
`repro.vision.models.quantize_net` turns into the chained activation
grids. The same `CalibStats` come out, so `plan_mixed_precision` searches
CNN plans with zero changes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.deploy.apply import dense_inventory, quantized_dense_paths
from repro.nn.layers import dense_tap, quantize_dense_weights
from repro.obs import trace as obs

CANDIDATE_BITS = (8, 4, 2)


@dataclasses.dataclass
class CalibStats:
    """Accumulated calibration record for one dense path."""

    path: str
    layers: int                 # stacked depth instances
    d_in: int
    d_out: int
    a_absmax: float = 0.0
    sq_err: Dict[int, float] = dataclasses.field(default_factory=dict)
    sq_ref: float = 0.0
    taps: int = 0
    # per-output-channel squared error, (d_out,) float64 per candidate
    # bits — the fine-grain planner's channel-group demotion signal.
    # Sums over channels to sq_err[b], so group sensitivities and the
    # per-layer sens() share one normalization.
    col_sq_err: Dict[int, np.ndarray] = dataclasses.field(
        default_factory=dict)

    def sens(self, bits: int) -> float:
        """Relative output MSE at w_bits=bits (the planner's cost unit)."""
        return self.sq_err.get(bits, 0.0) / (self.sq_ref + 1e-12)

    def col_sens(self, bits: int) -> Optional[np.ndarray]:
        """(d_out,) per-output-channel relative MSE at w_bits=bits, on the
        same normalization as `sens` (so it sums to ~sens(bits)); None
        when the calibration pass didn't record channel detail."""
        cols = self.col_sq_err.get(bits)
        if cols is None:
            return None
        return np.asarray(cols, np.float64) / (self.sq_ref + 1e-12)

    def _add_col_err(self, bits: int, err):
        """Accumulate one tap's per-channel squared error (err: (..., N))."""
        cols = np.asarray(
            jnp.sum(jnp.asarray(err, jnp.float32) ** 2,
                    axis=tuple(range(err.ndim - 1))), np.float64)
        prev = self.col_sq_err.get(bits)
        self.col_sq_err[bits] = cols if prev is None else prev + cols


def _sim_int_dense(x, w, w_bits: int, a_bits: int, a_absmax: float):
    """Simulate the deployed integer dense without packing: the weight grid
    is the serving one (`layers.quantize_dense_weights`, shared with
    `apply_plan`), activations are symmetric on the a_bits grid exactly as
    `layers._int_matmul` quantizes them."""
    w_hat, w_scale = quantize_dense_weights(w, w_bits)
    a_max = packing.int_range(a_bits, True)[1]
    a_scale = max(a_absmax, 1e-8) / a_max
    x_q = jnp.clip(jnp.round(x / a_scale), -a_max, a_max)
    return (x_q @ w_hat.astype(jnp.float32)) * (w_scale * a_scale)


def _walk_dense_ids(tree, prefix: Tuple[str, ...] = ()):
    """id(w-leaf) -> "/"-joined dense path, for one (unstacked) layer's
    params. Eager apply passes these exact arrays into dense_apply."""
    out = {}
    if isinstance(tree, dict):
        if "w" in tree and not isinstance(tree["w"], dict):
            out[id(tree["w"])] = "/".join(prefix)
        for k, v in tree.items():
            if isinstance(v, dict):
                out.update(_walk_dense_ids(v, prefix + (k,)))
    return out


class _Collector:
    def __init__(self, stats: Dict[str, CalibStats], bits: Sequence[int],
                 a_bits: int, max_rows: int):
        self.stats = stats
        self.bits = tuple(bits)
        self.a_bits = a_bits
        self.max_rows = max_rows
        self.id2path: Dict[int, str] = {}

    def __call__(self, p, x):
        w = p.get("w")
        if w is None:
            return
        path = self.id2path.get(id(w))
        if path is None or path not in self.stats:
            return
        st = self.stats[path]
        x2 = jnp.asarray(x, jnp.float32).reshape(-1, x.shape[-1])
        # the static serving scale must see every token — subsample only
        # the (quadratic-cost) MSE simulation below
        absmax = float(jnp.max(jnp.abs(x2)))
        st.a_absmax = max(st.a_absmax, absmax)
        if x2.shape[0] > self.max_rows:
            stride = -(-x2.shape[0] // self.max_rows)
            x2 = x2[::stride]
        wf = jnp.asarray(w, jnp.float32)
        y_ref = x2 @ wf
        st.sq_ref += float(jnp.sum(y_ref * y_ref))
        for b in self.bits:
            y_q = _sim_int_dense(x2, wf, b, self.a_bits, absmax)
            err = y_q - y_ref
            st.sq_err[b] = st.sq_err.get(b, 0.0) + float(jnp.sum(err * err))
            st._add_col_err(b, err)
        st.taps += 1


def _replay_lm(model, params, tokens, collector):
    """Eager per-depth replay of models/lm.forward (no cross-attn)."""
    from repro.models.lm import (_block, _layer_schedule, _layer_split,
                                 _ropes)
    cfg = model.cfg
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    from repro.nn.layers import embedding_apply
    x = embedding_apply(params["embed"], jnp.asarray(tokens)).astype(dtype)
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    s = x.shape[1]
    (cg, sg), (cl, sl) = _ropes(cfg, s, dtype)
    win, rsel = _layer_schedule(cfg, s)
    win, rsel = np.asarray(win), np.asarray(rsel)
    n_self, _ = _layer_split(cfg)
    for i in range(n_self):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        collector.id2path = _walk_dense_ids(lp, ("layers",))
        cos, sin = ((cl, sl) if rsel[i] == 1 else (cg, sg))
        x, _, _ = _block(cfg, lp, x, cos, sin, jnp.int32(win[i]), False)
    return x


def _weight_only(stats: Dict[str, CalibStats], fp_params, bits, a_absmax):
    """Fallback sensitivity: weight-quantization MSE scaled by an assumed
    unit activation second moment; a_absmax stays at the config default."""
    for path, st in stats.items():
        node = fp_params
        for part in path.split("/"):
            node = node[part]
        w = jnp.asarray(node["w"], jnp.float32)
        w2 = w.reshape(-1, w.shape[-1]) if w.ndim == 3 else w
        st.a_absmax = a_absmax
        st.sq_ref += float(jnp.sum(w2 * w2))
        for b in bits:
            w_hat, scale = quantize_dense_weights(w2, b)
            err = w_hat.astype(jnp.float32) * scale - w2
            st.sq_err[b] = st.sq_err.get(b, 0.0) + float(jnp.sum(err * err))
            st._add_col_err(b, err)
        st.taps += 1


def calibrate(model, fp_params, token_batches: Sequence[np.ndarray], *,
              bits: Sequence[int] = CANDIDATE_BITS, a_bits: int = 8,
              max_rows: int = 512,
              default_a_absmax: float = 4.0) -> Dict[str, CalibStats]:
    """Run calibration batches through the fp model, returning per-dense
    `CalibStats` keyed by param path. `token_batches`: (B, S) int32 arrays.
    """
    import dataclasses as _dc

    from repro.models.api import Model
    from repro.nn.layers import QuantConfig

    cfg = model.cfg
    q_defs = Model(_dc.replace(cfg, quant=QuantConfig(mode="int"),
                               quant_plan=None)).defs()
    paths = quantized_dense_paths(q_defs)
    inv = dense_inventory(fp_params, paths)
    stats = {p: CalibStats(p, *inv[p]) for p in paths}

    if cfg.family == "lm" and not cfg.cross_every:
        collector = _Collector(stats, bits, a_bits, max_rows)
        with dense_tap(collector):
            for i, toks in enumerate(token_batches):
                with obs.span("calibrate.batch", cat="deploy", batch=i,
                              tokens=int(np.asarray(toks).size)):
                    _replay_lm(model, fp_params, toks, collector)
        # paths the replay never reaches (none today for plain LMs) fall
        # back to weight-only so the planner always has full coverage
        missed = {p: st for p, st in stats.items() if st.taps == 0}
        if missed:
            _weight_only(missed, fp_params, bits, default_a_absmax)
    else:
        _weight_only(stats, fp_params, bits, default_a_absmax)
    return stats


# -------------------------------------------------------------- vision ---

def _sim_quant_weights(w, b: int):
    """Quantize-dequantize ``w`` on the *per-tensor* symmetric grid the
    vision packers deploy (`calibrate_weight` -> `quantize` in
    `repro.vision.layers` — NOT the LM zoo's per-output-channel grids;
    the sim must price exactly the grid that will serve)."""
    from repro.core.calibration import calibrate_weight
    from repro.core.quantize import dequantize, quantize as q_int

    spec = calibrate_weight(w, b)
    return dequantize(q_int(w, spec), spec)


def _sim_int_conv(x, w, b: int, a_bits: int, absmax: float, *,
                  stride: int, padding: int, groups: int):
    """Simulated W{b}A{a_bits} conv for the sensitivity proxy: weights on
    the deployed per-tensor symmetric grid (`_sim_quant_weights`),
    activations symmetric on the a_bits grid — the quantize-dequantize
    image of the deployed integer conv, on the layer's real geometry."""
    from repro.vision.layers import conv2d_raw

    w_q = _sim_quant_weights(w, b)
    if w.ndim == 3:          # depthwise (fh, fw, C) -> HWIO with I=1
        w_q = w_q.reshape(*w.shape[:2], 1, w.shape[-1])
    a_max = packing.int_range(a_bits, True)[1]
    a_scale = max(absmax, 1e-8) / a_max
    x_q = jnp.clip(jnp.round(x / a_scale), -a_max, a_max) * a_scale
    return conv2d_raw(x_q, w_q, stride=stride, padding=padding,
                      groups=groups)


class _ConvCollector:
    """`conv_tap` observer for the vision fp replay — the CNN analogue of
    `_Collector`: per-layer input absmax + simulated-W{b} output-MSE
    sensitivity, priced against the fp conv on the layer's geometry."""

    def __init__(self, stats: Dict[str, CalibStats], geom: Dict[str, dict],
                 bits: Sequence[int], a_bits: int, max_images: int):
        self.stats = stats
        self.geom = geom           # path -> {stride, padding, groups, w}
        self.bits = tuple(bits)
        self.a_bits = a_bits
        self.max_images = max_images
        self.id2path: Dict[int, str] = {}

    def __call__(self, p, x):
        from repro.vision.layers import conv2d_raw

        w = p.get("w")
        path = self.id2path.get(id(w)) if w is not None else None
        if path is None or path not in self.stats:
            return
        st = self.stats[path]
        g = self.geom[path]
        xf = jnp.asarray(x, jnp.float32)
        absmax = float(jnp.max(jnp.abs(xf)))
        st.a_absmax = max(st.a_absmax, absmax)
        if xf.ndim == 4 and xf.shape[0] > self.max_images:
            xf = xf[:self.max_images]
        wf = jnp.asarray(w, jnp.float32)
        if g["kind"] == "linear":
            y_ref = xf @ wf
        else:
            w4 = (wf.reshape(*wf.shape[:2], 1, wf.shape[-1])
                  if wf.ndim == 3 else wf)
            y_ref = conv2d_raw(xf, w4, stride=g["stride"],
                               padding=g["padding"], groups=g["groups"])
        st.sq_ref += float(jnp.sum(y_ref * y_ref))
        for b in self.bits:
            if g["kind"] == "linear":
                # the vision head deploys per-tensor grids
                # (`quantize_linear_head`), unlike the LM denses
                a_max = packing.int_range(self.a_bits, True)[1]
                a_scale = max(absmax, 1e-8) / a_max
                x_q = jnp.clip(jnp.round(xf / a_scale), -a_max,
                               a_max) * a_scale
                y_q = x_q @ _sim_quant_weights(wf, b)
            else:
                y_q = _sim_int_conv(xf, wf, b, self.a_bits, absmax,
                                    stride=g["stride"],
                                    padding=g["padding"],
                                    groups=g["groups"])
            err = y_q - y_ref
            st.sq_err[b] = st.sq_err.get(b, 0.0) + float(jnp.sum(err * err))
            st._add_col_err(b, err)
        st.taps += 1


def _vision_stats_geom(cfg, fp_params):
    """The shared stats/geometry walk: per compute path, an empty
    `CalibStats` with the deployable artifact's (d_in, d_out) plus the
    layer geometry and the id(w) -> path map the conv_tap needs."""
    from repro.vision.models import COMPUTE_KINDS, get_path, trace_shapes

    stats: Dict[str, CalibStats] = {}
    geom: Dict[str, dict] = {}
    id2path: Dict[int, str] = {}
    for t in trace_shapes(cfg):
        L, (h, w, c) = t["layer"], t["in"]
        if L.kind not in COMPUTE_KINDS:
            continue
        node = get_path(fp_params, L.path)
        if L.kind == "conv":
            d_in, d_out, groups = L.fh * L.fw * c, L.cout, 1
        elif L.kind == "dwconv":
            # the deployable block-diagonal artifact is (fh*fw*C, C)
            d_in, d_out, groups = L.fh * L.fw * c, c, c
        else:
            d_in, d_out, groups = c, L.cout, 1
        stats[L.path] = CalibStats(L.path, 1, d_in, d_out)
        geom[L.path] = {"kind": L.kind, "stride": L.stride,
                        "padding": L.padding, "groups": groups}
        id2path[id(node["w"])] = L.path
    return stats, geom, id2path


def calibrate_vision(cfg, fp_params, image_batches: Sequence[np.ndarray], *,
                     bits: Sequence[int] = CANDIDATE_BITS, a_bits: int = 8,
                     max_images: int = 64, sensitivity: str = "mse",
                     labels: Optional[Sequence[np.ndarray]] = None,
                     group_size: int = packing.CHUNK):
    """Calibrate a vision net: (per-layer `CalibStats`, per-edge absmax).

    `cfg` is a `repro.vision.models.VisionConfig`; `image_batches` are
    (B, H, W, C) float arrays. The stats feed `plan_mixed_precision`
    unchanged; the absmax dict feeds
    `repro.vision.models.quantize_net` (activation-grid chaining).

    ``sensitivity`` selects the per-layer cost signal:

    * ``"mse"`` (default) — the output-MSE proxy of `_ConvCollector`:
      cheap, label-free, but prices *local* layer error, not what the
      task loses.
    * ``"task_loss"`` — per-layer (and per-channel-group) sensitivity is
      the **cross-entropy degradation on labeled batches** when that
      layer (or group) alone is quantized to the candidate width:
      sens(b) = max(loss_quantized(b) - loss_float, 0), sq_ref = 1. The
      planner's knapsack then trades bytes directly against measured
      task-loss increase (Nadalini et al. 2307.01056's accuracy-aware
      group assignment). Requires ``labels`` (one int array per image
      batch). Deterministic: pure forwards, no sampling.
    """
    if sensitivity == "task_loss":
        return _calibrate_vision_task_loss(
            cfg, fp_params, image_batches, labels, bits=bits,
            a_bits=a_bits, group_size=group_size)
    if sensitivity != "mse":
        raise ValueError(f"unknown sensitivity {sensitivity!r}; expected "
                         "'mse' or 'task_loss'")
    from repro.vision.layers import conv_tap
    from repro.vision.models import forward_fp

    stats, geom, id2path = _vision_stats_geom(cfg, fp_params)
    absmax: Dict[str, float] = {}

    def edge_tap(path, tensor):
        absmax[path] = max(absmax.get(path, 0.0),
                           float(jnp.max(jnp.abs(tensor))))

    collector = _ConvCollector(stats, geom, bits, a_bits, max_images)
    collector.id2path = id2path
    with conv_tap(collector):
        for i, imgs in enumerate(image_batches):
            with obs.span("calibrate.batch", cat="deploy", batch=i,
                          images=int(np.asarray(imgs).shape[0])):
                forward_fp(cfg, fp_params, jnp.asarray(imgs, jnp.float32),
                           edge_tap=edge_tap)
    return stats, absmax


def _mean_ce_loss(cfg, params, xs, ys) -> float:
    """Mean cross-entropy of the fp forward over the labeled batches."""
    from repro.vision.models import forward_fp

    total = n = 0.0
    for x, y in zip(xs, ys):
        logits = forward_fp(cfg, params, x)
        logp = jax.nn.log_softmax(jnp.asarray(logits, jnp.float32), -1)
        picked = jnp.take_along_axis(
            logp, jnp.asarray(y, jnp.int32)[:, None], axis=-1)
        total += float(-jnp.sum(picked))
        n += picked.size
    return total / max(n, 1.0)


def _with_quantized_path(fp_params, path: str, w_q):
    """A shallow-copied param tree with ``path``'s weight replaced."""
    parts = path.split("/")
    out = dict(fp_params)
    node = out
    for p in parts[:-1]:
        node[p] = dict(node[p])
        node = node[p]
    leaf = dict(node[parts[-1]])
    leaf["w"] = w_q
    node[parts[-1]] = leaf
    return out


def _calibrate_vision_task_loss(cfg, fp_params, image_batches, labels, *,
                                bits, a_bits, group_size):
    """Task-loss sensitivity: loss degradation per (layer, width) and per
    (channel group, width), on the deployed per-tensor / per-run grids.

    Only weights are simulated-quantized (activation grids are uniform
    a_bits everywhere, so the planner's only degree of freedom is weight
    width — pricing exactly that keeps the signal clean). Group
    sensitivities quantize one CHUNK-aligned output-channel slice at a
    time on its *own* per-run grid (`_sim_quant_weights` of the slice) —
    the precise arithmetic `quantize_conv_layer_segmented` deploys — and
    are rescaled so groups sum to the layer sensitivity, keeping the
    knapsack budget commensurable across granularities."""
    from repro.vision.models import forward_fp, get_path

    if labels is None:
        raise ValueError("sensitivity='task_loss' needs labels= (one int "
                         "label array per image batch)")
    if len(labels) != len(image_batches):
        raise ValueError(f"{len(image_batches)} image batches but "
                         f"{len(labels)} label batches")
    stats, geom, _ = _vision_stats_geom(cfg, fp_params)
    xs = [jnp.asarray(x, jnp.float32) for x in image_batches]
    ys = [np.asarray(y) for y in labels]

    # one taped pass for the edge absmax (the activation-grid side) and
    # per-layer input absmax (PlanRule.a_absmax reporting)
    absmax: Dict[str, float] = {}

    def edge_tap(path, tensor):
        absmax[path] = max(absmax.get(path, 0.0),
                           float(jnp.max(jnp.abs(tensor))))

    from repro.vision.layers import conv_tap

    def input_tap(p, x):
        w = p.get("w")
        if w is None:
            return
        for path, st in stats.items():
            if get_path(fp_params, path)["w"] is w:
                st.a_absmax = max(st.a_absmax,
                                  float(jnp.max(jnp.abs(x))))

    with conv_tap(input_tap):
        base_loss = 0.0
        for x in xs:
            forward_fp(cfg, fp_params, x, edge_tap=edge_tap)
        base_loss = _mean_ce_loss(cfg, fp_params, xs, ys)

    with obs.span("calibrate.task_loss", cat="deploy", arch=cfg.name,
                  paths=len(stats), batches=len(xs),
                  base_loss=base_loss) as sp:
        evals = 0
        for path, st in stats.items():
            st.sq_ref = 1.0
            w = jnp.asarray(get_path(fp_params, path)["w"], jnp.float32)
            d_out = st.d_out
            n_groups = -(-d_out // group_size)
            for b in bits:
                w_q = _sim_quant_weights(w, b)
                loss_b = _mean_ce_loss(
                    cfg, _with_quantized_path(fp_params, path, w_q),
                    xs, ys)
                evals += 1
                sens = max(loss_b - base_loss, 0.0)
                st.sq_err[b] = sens
                cols = np.zeros((d_out,), np.float64)
                if n_groups > 1 and geom[path]["kind"] == "conv":
                    for s in range(0, d_out, group_size):
                        e = min(s + group_size, d_out)
                        w_g = w.at[..., s:e].set(
                            _sim_quant_weights(w[..., s:e], b))
                        loss_g = _mean_ce_loss(
                            cfg, _with_quantized_path(fp_params, path,
                                                      w_g), xs, ys)
                        evals += 1
                        cols[s:e] = max(loss_g - base_loss, 0.0) / (e - s)
                    gsum = cols.sum()
                    if gsum > 0 and sens > 0:
                        cols *= sens / gsum
                    elif sens > 0:
                        cols[:] = sens / d_out
                else:
                    # single group (or depthwise/head): channel detail
                    # adds nothing — apportion uniformly so col_sens
                    # stays consistent with sens at every granularity
                    cols[:] = sens / max(d_out, 1)
                st.col_sq_err[b] = cols
            st.taps = len(xs)
        sp.set(loss_evals=evals)
    return stats, absmax
