"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone
[arXiv:2308.11596; hf]. 24 encoder + 24 decoder layers (hf card per-stack
depth), d=1024, 16H (kv=16), ff=8192, vocab=256206. Audio frontend stubbed:
input_specs provides precomputed frame embeddings."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=48, enc_layers=24, dec_layers=24,
    d_model=1024, n_heads=16, kv_heads=16, d_ff=8192, vocab=256206,
    act="gelu", norm="layernorm", tie_embeddings=True, src_len=4096,
))

def smoke_config():
    return ModelConfig(
        name="seamless-smoke", family="encdec",
        n_layers=4, enc_layers=2, dec_layers=2,
        d_model=64, n_heads=4, kv_heads=4, d_ff=128, vocab=128,
        act="gelu", norm="layernorm", src_len=16, remat=False)
