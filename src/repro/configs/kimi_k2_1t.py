"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2]. 61L, d=7168, 64H (kv=8), expert ff=2048,
vocab=163840."""
from repro.configs.base import ModelConfig, MoeSpec
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b", family="lm",
    n_layers=61, d_model=7168, n_heads=64, kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840, act="swiglu", norm="rmsnorm",
    moe=MoeSpec(n_experts=384, top_k=8, d_ff=2048, group_size=1024),
    param_dtype="bfloat16",
))

def smoke_config():
    return ModelConfig(
        name="kimi-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=64,
        vocab=128, act="swiglu", norm="rmsnorm",
        moe=MoeSpec(n_experts=8, top_k=2, d_ff=64, group_size=64),
        remat=False)
