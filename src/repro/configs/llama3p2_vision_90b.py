"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-Vision]. 100L total = 80 self + 20 cross
(1 cross after every 4 self), d=8192, 64H (kv=8), ff=28672,
vocab=128256. Vision frontend stubbed (patch embeddings provided)."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b", family="lm",
    n_layers=100, d_model=8192, n_heads=64, kv_heads=8, d_ff=28672,
    vocab=128256, act="swiglu", norm="rmsnorm",
    cross_every=4, src_len=4096, tie_embeddings=False,
    param_dtype="bfloat16",
))

def smoke_config():
    return ModelConfig(
        name="vision-smoke", family="lm",
        n_layers=5, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=128, act="swiglu", norm="rmsnorm",
        cross_every=4, src_len=16, tie_embeddings=False, remat=False)
