"""qwen2.5-3b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5].
36L, d=2048, 16H (kv=2), ff=11008, vocab=151936."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b", family="lm",
    n_layers=36, d_model=2048, n_heads=16, kv_heads=2, d_ff=11008,
    vocab=151936, act="swiglu", norm="rmsnorm", qkv_bias=True,
))

def smoke_config():
    return ModelConfig(
        name="qwen-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=128, act="swiglu", norm="rmsnorm", qkv_bias=True, remat=False)
