"""mamba2-370m [ssm] — SSD state-space duality [arXiv:2405.21060].
48L, d=1024, attn-free, ssm_state=128, vocab=50280."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="mamba2-370m", family="mamba",
    n_layers=48, d_model=1024, n_heads=0, kv_heads=0, d_ff=0,
    vocab=50280, norm="rmsnorm",
    d_state=128, d_conv=4, expand=2, headdim=64,
))

def smoke_config():
    return ModelConfig(
        name="mamba-smoke", family="mamba",
        n_layers=2, d_model=64, n_heads=0, kv_heads=0, d_ff=0,
        vocab=128, norm="rmsnorm",
        d_state=16, d_conv=4, expand=2, headdim=16, ssd_chunk=8,
        remat=False)
