"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 rec : 1 attn
[arXiv:2402.19427]. 38L, d=4096, 16H (kv=1), ff=12288, vocab=256000,
local window 2048."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b", family="griffin",
    n_layers=38, d_model=4096, n_heads=16, kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000, act="geglu", norm="gemma_rmsnorm",
    scale_embed=True, window=2048, rnn_pattern=("rec", "rec", "attn"),
    lru_width=4096,
))

def smoke_config():
    return ModelConfig(
        name="rgemma-smoke", family="griffin",
        n_layers=8, d_model=64, n_heads=4, kv_heads=1, head_dim=16,
        d_ff=128, vocab=128, act="geglu", norm="gemma_rmsnorm",
        scale_embed=True, window=8, rnn_pattern=("rec", "rec", "attn"),
        lru_width=64, remat=False)
