"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].
32L, d=3072, 32H (kv=32), ff=8192, vocab=32064."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b", family="lm",
    n_layers=32, d_model=3072, n_heads=32, kv_heads=32, d_ff=8192,
    vocab=32064, act="swiglu", norm="rmsnorm",
))

def smoke_config():
    return ModelConfig(
        name="phi3-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=128, act="swiglu", norm="rmsnorm", remat=False)
