"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
early fusion [hf:meta-llama/Llama-4]. 48L, d=5120, 40H (kv=8), ff=8192
per expert, vocab=202048. Assigned config specifies plain GQA (full
attention) -> long_500k skipped (DESIGN.md)."""
from repro.configs.base import ModelConfig, MoeSpec
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="lm",
    n_layers=48, d_model=5120, n_heads=40, kv_heads=8, d_ff=8192,
    vocab=202048, act="swiglu", norm="rmsnorm",
    moe=MoeSpec(n_experts=128, top_k=1, d_ff=8192, group_size=1024),
    param_dtype="bfloat16",
))

def smoke_config():
    return ModelConfig(
        name="llama4-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=128, act="swiglu", norm="rmsnorm",
        moe=MoeSpec(n_experts=4, top_k=1, d_ff=128, group_size=64),
        remat=False)
