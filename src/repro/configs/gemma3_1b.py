"""gemma3-1b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt]. 26L, d=1152, 4H (GQA kv=1), head_dim=256,
ff=6912, vocab=262144; local window 512; dual rope theta (10k local /
1M global); gemma rmsnorm + scaled embeddings."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="gemma3-1b", family="lm",
    n_layers=26, d_model=1152, n_heads=4, kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    act="geglu", norm="gemma_rmsnorm", scale_embed=True,
    window=512, pattern=("local",) * 5 + ("global",),
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
))

def smoke_config():
    return ModelConfig(
        name="gemma3-smoke", family="lm",
        n_layers=6, d_model=64, n_heads=4, kv_heads=1, head_dim=16,
        d_ff=128, vocab=128, act="geglu", norm="gemma_rmsnorm",
        scale_embed=True, window=8, pattern=("local",) * 5 + ("global",),
        rope_theta=1_000_000.0, rope_theta_local=10_000.0, remat=False)
