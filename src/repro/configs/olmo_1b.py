"""olmo-1b [dense] — non-parametric LayerNorm [arXiv:2402.00838; hf].
16L, d=2048, 16H (kv=16), ff=8192, vocab=50304."""
from repro.configs.base import ModelConfig
from repro.models.api import register

CONFIG = register(ModelConfig(
    name="olmo-1b", family="lm",
    n_layers=16, d_model=2048, n_heads=16, kv_heads=16, d_ff=8192,
    vocab=50304, act="swiglu", norm="nonparam_ln",
))

def smoke_config():
    return ModelConfig(
        name="olmo-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=128, act="swiglu", norm="nonparam_ln", remat=False)
