"""Config dataclasses: model, quantization, parallelism, shapes.

Every assigned architecture file (src/repro/configs/<id>.py) builds a
ModelConfig with its exact published numbers plus a reduced smoke_config()
of the same family for CPU tests. Shape presets (train_4k / prefill_32k /
decode_32k / long_500k) are shared across LM archs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.deploy.policy import PrecisionPlan
from repro.nn.layers import QOFF, QuantConfig


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    n_experts: int
    top_k: int
    d_ff: int                  # per-expert hidden
    capacity_factor: float = 1.25
    group_size: int = 1024
    shared_expert: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # lm | encdec | mamba | griffin
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    act: str = "swiglu"
    norm: str = "rmsnorm"      # rmsnorm|layernorm|nonparam_ln|gemma_rmsnorm
    qkv_bias: bool = False
    tie_embeddings: bool = True
    scale_embed: bool = False  # gemma family: embed * sqrt(d)
    rope_theta: float = 10000.0
    # sliding-window schedule: window size used on "local" layers; pattern
    # gives the repeating layer kinds, e.g. ("local",)*5 + ("global",) for
    # gemma3. Empty pattern -> all-global.
    window: int = 0
    pattern: Tuple[str, ...] = ()
    rope_theta_local: Optional[float] = None
    # MoE
    moe: Optional[MoeSpec] = None
    # vision cross-attn: one cross layer after every `cross_every` self
    # layers; n_layers counts BOTH kinds (llama-3.2-vision: 80 self+20 cross)
    cross_every: int = 0
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # mamba
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ssd_chunk: int = 256
    # griffin (recurrentgemma): pattern handled via rnn_pattern
    lru_width: int = 0
    rnn_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn")
    # quantization (the paper's technique). `quant` is the uniform/default
    # QuantConfig; `quant_plan` (mixed-precision deployment) overrides
    # {w_bits, a_bits, backend, a_absmax} per dense param path — see
    # repro/deploy/policy.py. Packed param shapes follow the resolved bits.
    quant: QuantConfig = QOFF
    quant_plan: Optional[PrecisionPlan] = None
    kv_quant_bits: int = 16
    # training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # modality frontend stub (audio/vlm): src embeddings length
    src_len: int = 0

    @property
    def head_dim_(self):
        return self.head_dim or (self.d_model // self.n_heads if self.n_heads else 0)

    def layer_kinds(self):
        """Expanded per-layer kind list for pattern-scheduled archs."""
        if not self.pattern:
            return ["global"] * self.n_layers
        reps = (self.n_layers + len(self.pattern) - 1) // len(self.pattern)
        return list((self.pattern * reps)[: self.n_layers])


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs whose attention is sub-quadratic enough for long_500k decode
# (SSM / hybrid / mostly-local); pure full-attention archs skip it
# (documented in DESIGN.md §Arch-applicability).
LONG_CONTEXT_OK = {"gemma3-1b", "recurrentgemma-9b", "mamba2-370m"}


def cells_for(arch_name: str):
    """The (arch x shape) cells this arch runs in the dry-run matrix."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and arch_name not in LONG_CONTEXT_OK:
            continue
        out.append(s)
    return out
