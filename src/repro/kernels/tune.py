"""Per-(shape, bits, backend) measured autotune cache: block shapes and
pipeline modes.

The block selectors in `kernels/common.py` (`default_block`,
`conv_default_block`) pick safe VMEM-bounded tiles analytically. This
module layers a *measured* cache on top: `repro.kernels.api` consults
`get_block(...)` / `get_pipeline(...)` before falling back to the analytic
default (block) and ``'off'`` (pipeline), so a shape that has been
autotuned once keeps its best tile *and* its best Mac&Load pipeline mode
across runs via a small JSON artifact.

`autotune_qdot` / `autotune_qconv` time candidate block shapes x pipeline
modes (`repro.kernels.common.PIPELINE_MODES`) per (shape, bits, backend)
and persist the winner — the paper's register-tiling exploration plus its
mac&load on/off ablation, per shape.

Cache key: ``op|MxKxN|a{a_bits}w{w_bits}|backend`` (conv keys use the full
geometry tuple). The JSON artifact is versioned and round-trips through
`save`/`load`; set ``REPRO_QTUNE_CACHE=/path/to/cache.json`` to preload it
at import-free first use. CI uploads the artifact so the tuned tiles ride
along with the perf trajectory.

CLI:

    # targeted qdot tune (the CI parity-matrix artifact)
    PYTHONPATH=src python -m repro.kernels.tune \
        --shapes 64x256x256,64x512x128 --bits 8x8,4x4 \
        --backend pallas_interpret --out tune_cache.json

    # full measured sweep: both ops x candidate blocks x pipeline modes
    PYTHONPATH=src python -m repro.kernels.tune --sweep \
        --backend pallas_interpret --out tune_cache.json
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional, Sequence, Tuple

from repro.kernels.common import PIPELINE_MODES
from repro.obs import env as obsenv
from repro.obs import trace as obs

# v3: entries carry the measured pipeline-mode winner (and its time in us)
# next to the block shape — v2 artifacts' bare block lists can't express
# the pipeline decision, so the version bump makes stale artifacts fail
# loudly (`load`) or skip with a warning (env preload) instead of silently
# running every pipelined shape in 'off' mode.
# (v2 had bumped v1 for the grouped-conv shape-key tail.)
CACHE_VERSION = 3
CACHE_ENV = "REPRO_QTUNE_CACHE"


def _key(op: str, shape: Sequence[int], a_bits: int, w_bits: int,
         backend: str) -> str:
    return (f"{op}|{'x'.join(str(int(s)) for s in shape)}"
            f"|a{a_bits}w{w_bits}|{backend}")


class TuneCache:
    """In-memory measured-winner cache with a versioned JSON round-trip.

    Each entry: ``{"block": [...], "pipeline": "off"|"double_buffer",
    "us": float|None}`` — the winning tile, the winning pipeline mode,
    and the measured time that won (None for hand-recorded entries).
    """

    def __init__(self):
        self.entries: Dict[str, dict] = {}

    def get(self, op, shape, a_bits, w_bits, backend) -> Optional[dict]:
        e = self.entries.get(_key(op, shape, a_bits, w_bits, backend))
        return None if e is None else dict(e)

    def put(self, op, shape, a_bits, w_bits, backend, block,
            pipeline: str = "off", us: Optional[float] = None):
        if pipeline not in PIPELINE_MODES:
            raise ValueError(f"unknown pipeline mode {pipeline!r}")
        self.entries[_key(op, shape, a_bits, w_bits, backend)] = {
            "block": tuple(int(b) for b in block),
            "pipeline": str(pipeline),
            "us": None if us is None else round(float(us), 3),
        }

    def to_json(self) -> str:
        return json.dumps({
            "version": CACHE_VERSION,
            "entries": {k: {"block": list(e["block"]),
                            "pipeline": e["pipeline"], "us": e["us"]}
                        for k, e in sorted(self.entries.items())},
        }, indent=2, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "TuneCache":
        d = json.loads(text)
        if d.get("version") != CACHE_VERSION:
            raise ValueError(
                f"unsupported tune-cache version {d.get('version')} "
                f"(expected {CACHE_VERSION}); re-run "
                "`python -m repro.kernels.tune --sweep` to regenerate")
        c = TuneCache()
        for k, e in d.get("entries", {}).items():
            c.entries[k] = {
                "block": tuple(int(b) for b in e["block"]),
                "pipeline": str(e.get("pipeline", "off")),
                "us": None if e.get("us") is None else float(e["us"]),
            }
        return c


# module-level cache; REPRO_QTUNE_CACHE preloads it lazily on first lookup
_CACHE = TuneCache()
_ENV_LOADED = False


def _maybe_load_env():
    global _ENV_LOADED
    if _ENV_LOADED:
        return
    _ENV_LOADED = True
    path = obsenv.get(CACHE_ENV)
    if not path:
        return
    import warnings
    if pathlib.Path(path).exists():
        try:
            merge(load(path))
        except ValueError as e:
            warnings.warn(
                f"{CACHE_ENV}={path}: {e}; no tuned blocks loaded — "
                "re-run `python -m repro.kernels.tune` to regenerate",
                RuntimeWarning, stacklevel=2)
    else:
        warnings.warn(
            f"{CACHE_ENV}={path} does not exist; no tuned blocks loaded "
            "(every lookup falls back to the analytic block selectors)",
            RuntimeWarning, stacklevel=2)


def get_entry(op: str, shape, a_bits: int, w_bits: int,
              backend: str) -> Optional[dict]:
    """Full cached entry ({'block', 'pipeline', 'us'}) or None."""
    _maybe_load_env()
    return _CACHE.get(op, shape, a_bits, w_bits, backend)


def get_block(op: str, shape, a_bits: int, w_bits: int,
              backend: str) -> Optional[Tuple[int, ...]]:
    """Cached block for this exact (op, shape, bits, backend), or None —
    callers fall back to the analytic selector on a miss."""
    e = get_entry(op, shape, a_bits, w_bits, backend)
    return None if e is None else tuple(e["block"])


def get_pipeline(op: str, shape, a_bits: int, w_bits: int,
                 backend: str) -> Optional[str]:
    """Cached measured pipeline-mode winner, or None (-> 'off' upstream)."""
    e = get_entry(op, shape, a_bits, w_bits, backend)
    return None if e is None else e["pipeline"]


def record_block(op: str, shape, a_bits: int, w_bits: int, backend: str,
                 block, pipeline: str = "off",
                 us: Optional[float] = None) -> None:
    _CACHE.put(op, shape, a_bits, w_bits, backend, block, pipeline, us)


def clear() -> None:
    _CACHE.entries.clear()


def save(path) -> None:
    pathlib.Path(path).write_text(_CACHE.to_json())


def load(path) -> TuneCache:
    return TuneCache.from_json(pathlib.Path(path).read_text())


def merge(other: TuneCache) -> None:
    """Merge ``other`` into the module cache; on a key conflict the
    *incoming* entry wins (last merge is the freshest measurement)."""
    _CACHE.entries.update(other.entries)


def entries() -> Dict[str, dict]:
    return {k: dict(e) for k, e in _CACHE.entries.items()}


# ---------------------------------------------------------------- tuning ---

def _time(fn, iters=2):
    """Seconds per call — thin alias over the shared timer
    (`repro.obs.time_call`, which reports µs)."""
    return obs.time_call(fn, warmup=1, iters=iters) / 1e6


def qdot_candidates(m: int, n: int, k: int, a_bits: int,
                    w_bits: int) -> Tuple[Tuple[int, int, int], ...]:
    """Small candidate ladder around the analytic default (the paper's
    4x2 -> 4x4 register-tiling exploration, per shape)."""
    from repro.core import packing
    from repro.kernels.common import LANE, SUBLANE_I8, default_block

    bm0, bn0, bk0 = default_block(m, n, k, a_bits, w_bits)
    cands = {(bm0, bn0, bk0)}
    for bm in {bm0, max(SUBLANE_I8, bm0 // 2), bm0 * 2}:
        for bn in {bn0, max(LANE, bn0 // 2)}:
            # halved bk rounded down to a CHUNK multiple — the kernel
            # requires CHUNK-aligned K tiles (the ragged *final* tile is
            # zero-padded, but the tile size itself must stay aligned)
            bk_half = max(packing.CHUNK, (bk0 // 2) // packing.CHUNK
                          * packing.CHUNK)
            for bk in {bk0, bk_half}:
                if m % bm == 0 or bm <= m:
                    cands.add((bm, bn, bk))
    # bm/bn are padded to by the wrapper; a ragged final K tile is now
    # zero-padded inside qmatmul_packed (exact — zero containers hold zero
    # in every plane), so bk is no longer limited to divisors of K. Keep
    # only tiles that don't overshoot K entirely.
    return tuple(sorted(c for c in cands if c[2] <= max(k, packing.CHUNK)))


def qconv_candidates(shape, a_bits: int,
                     w_bits: int) -> Tuple[Tuple[int, int], ...]:
    """(bho, bn) ladder around the analytic conv default."""
    from repro.core import packing
    from repro.kernels.common import LANE, conv_default_block

    n, h, w, cin, fh, fw, stride, padding, cout = shape[:9]
    ho = (h + 2 * padding - fh) // stride + 1
    wo = (w + 2 * padding - fw) // stride + 1
    bho0, bn0 = conv_default_block(n, ho, wo, cout, fh, fw,
                                   packing.padded_size(cin), stride,
                                   a_bits, w_bits)
    cands = set()
    for bho in {bho0, max(1, bho0 // 2), min(ho, bho0 * 2)}:
        for bn in {bn0, max(LANE, bn0 // 2)}:
            cands.add((bho, bn))
    return tuple(sorted(cands))


def _sweep(op: str, shape, a_bits: int, w_bits: int, backend: str,
           run_candidate, cands, pipelines, iters: int):
    """Time every (block x pipeline) candidate; record + return the winner
    as (block, pipeline)."""
    best, best_t = None, float("inf")
    with obs.span("tune.sweep", cat="tune", op=op,
                  shape=tuple(int(s) for s in shape), a_bits=int(a_bits),
                  w_bits=int(w_bits), backend=backend,
                  candidates=len(cands) * len(pipelines)) as sweep_span:
        for blk in cands:
            for pipe in pipelines:
                try:
                    t = _time(lambda b=blk, p=pipe: run_candidate(b, p),
                              iters=iters)
                except Exception:
                    continue              # candidate not runnable; skip
                if t < best_t:
                    best, best_t = (blk, pipe), t
        if best is None:
            raise RuntimeError(
                f"no runnable (block, pipeline) candidate for {op} {shape}")
        sweep_span.set(winner_block=tuple(int(b) for b in best[0]),
                       winner_pipeline=best[1],
                       winner_us=round(best_t * 1e6, 3))
    record_block(op, shape, a_bits, w_bits, backend, best[0], best[1],
                 us=best_t * 1e6)
    return best


def autotune_qdot(params, x_packed, *, backend: str = "pallas_interpret",
                  epilogue: str = "int", iters: int = 2,
                  candidates=None, pipelines=PIPELINE_MODES):
    """Time candidate GEMM blocks x pipeline modes for one packed shape.

    Returns the winning ``(block, pipeline)``; the result also lands in
    the module cache so subsequent `api.qdot` calls at this shape pick up
    both the tile and the Mac&Load mode.
    """
    from repro.core import packing
    from repro.kernels import api

    m = x_packed.shape[0]
    k = x_packed.shape[1] * packing.pack_factor(params.a_bits)
    n = params.w_packed.shape[1]
    shape = (m, k, n)
    cands = tuple(candidates or qdot_candidates(m, n, k, params.a_bits,
                                                params.w_bits))
    spec = api.get("qdot", backend)
    if not spec.name.startswith("pallas"):
        pipelines = ("off",)              # mode only exists for the kernel
    return _sweep(
        "qdot", shape, params.a_bits, params.w_bits, backend,
        lambda b, p: spec.run(params, x_packed, epilogue=epilogue,
                              scale=1.0, block=b, pipeline=p),
        cands, pipelines, iters)


def autotune_qconv(params, x_hat, *, backend: str = "pallas_interpret",
                   epilogue: str = "int", iters: int = 2,
                   candidates=None, pipelines=PIPELINE_MODES):
    """Time candidate conv tiles x pipeline modes for one image geometry.

    Returns the winning ``((bho, bn), pipeline)`` and records it under the
    same shape key `api.qconv` looks up.
    """
    from repro.kernels import api

    g = params.gemm
    shape = (x_hat.shape[0], x_hat.shape[1], x_hat.shape[2], x_hat.shape[3],
             params.fh, params.fw, params.stride, params.padding,
             params.cout, getattr(params, "groups", 1))
    cands = tuple(candidates or qconv_candidates(shape, g.a_bits, g.w_bits))
    spec = api.get("qconv", backend)
    if not spec.name.startswith("pallas"):
        pipelines = ("off",)
    return _sweep(
        "qconv", shape, g.a_bits, g.w_bits, backend,
        lambda b, p: spec.run(params, x_hat, epilogue=epilogue,
                              scale=1.0, block=b, pipeline=p),
        cands, pipelines, iters)


# ------------------------------------------------------------------- CLI ---

def _mk_qdot_artifact(rng, m, k, n, ab, wb):
    import numpy as np
    import jax.numpy as jnp

    from repro.core import packing
    from repro.core.quantize import QuantizedLinearParams

    lo, hi = packing.int_range(ab, False)
    xp = packing.pack(jnp.asarray(rng.integers(
        lo, hi + 1, size=(m, k)).astype(np.int8)), ab, axis=-1)
    lo, hi = packing.int_range(wb, True)
    wp = packing.pack(jnp.asarray(rng.integers(
        lo, hi + 1, size=(k, n)).astype(np.int8)), wb, axis=0)
    params = QuantizedLinearParams(
        w_packed=wp, w_bits=wb, a_bits=ab, a_signed=False,
        kappa=jnp.ones((n,), jnp.int32),
        lam=jnp.zeros((n,), jnp.int32),
        m=jnp.full((n,), 1 << 14, jnp.int32), d=20, out_bits=8,
        k_logical=k)
    return params, xp


def _mk_qconv_artifact(rng, h, w, cin, cout, fh, fw, stride, padding,
                       ab, wb):
    import numpy as np
    import jax.numpy as jnp

    from repro.core import packing
    from repro.core.quantize import QuantSpec
    from repro.kernels.qconv.ops import quantize_conv

    wgt = (rng.normal(size=(fh, fw, cin, cout)) * 0.2).astype(np.float32)
    params = quantize_conv(
        jnp.asarray(wgt), QuantSpec.weight(wb, 0.6),
        jnp.ones((cout,), np.float32), jnp.zeros((cout,), np.float32),
        QuantSpec.activation(ab, 2.0), QuantSpec.activation(ab, 2.0),
        stride=stride, padding=padding)
    lo, hi = packing.int_range(ab, False)
    x = jnp.asarray(rng.integers(lo, hi + 1,
                                 size=(1, h, w, cin)).astype(np.int8))
    return params, x


# the paper's fig.11 conv geometries (16x16 / 32x32 IoT layers)
SWEEP_CONV_SHAPES = ((16, 16, 16, 64, 3, 3, 1, 1),
                     (32, 32, 16, 32, 3, 3, 1, 1))
SWEEP_GEMM_SHAPES = ((64, 256, 256), (64, 512, 128), (256, 4608, 256))


def main():
    import argparse

    import numpy as np

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shapes", default="64x256x256",
                    help="comma-separated MxKxN GEMM shapes")
    ap.add_argument("--bits", default="8x8,4x4,2x2",
                    help="comma-separated AxW bit pairs")
    ap.add_argument("--backend", default="pallas_interpret")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--out", default="tune_cache.json")
    ap.add_argument("--sweep", action="store_true",
                    help="full measured sweep: both ops (qdot over "
                         "--shapes plus the built-in ladder, qconv over "
                         "the paper's fig.11 geometries) x candidate "
                         "blocks x pipeline modes")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    bit_pairs = [tuple(int(v) for v in pair.split("x"))
                 for pair in args.bits.split(",")]
    gemm_shapes = [tuple(int(v) for v in sh.split("x"))
                   for sh in args.shapes.split(",")]
    if args.sweep:
        gemm_shapes = sorted(set(gemm_shapes) | set(SWEEP_GEMM_SHAPES))

    for m, k, n in gemm_shapes:
        for ab, wb in bit_pairs:
            params, xp = _mk_qdot_artifact(rng, m, k, n, ab, wb)
            blk, pipe = autotune_qdot(params, xp, backend=args.backend,
                                      iters=args.iters)
            print(f"qdot {m}x{k}x{n} A{ab}W{wb} [{args.backend}] "
                  f"-> {blk} pipeline={pipe}")

    if args.sweep:
        for h, w, cin, cout, fh, fw, stride, padding in SWEEP_CONV_SHAPES:
            for ab, wb in bit_pairs:
                params, x = _mk_qconv_artifact(
                    rng, h, w, cin, cout, fh, fw, stride, padding, ab, wb)
                blk, pipe = autotune_qconv(params, x, backend=args.backend,
                                           iters=args.iters)
                print(f"qconv {h}x{w}x{cin}->{cout} {fh}x{fw}s{stride} "
                      f"A{ab}W{wb} [{args.backend}] -> {blk} "
                      f"pipeline={pipe}")

    save(args.out)
    print(f"tune cache ({len(entries())} entries) -> {args.out}")


if __name__ == "__main__":
    main()
