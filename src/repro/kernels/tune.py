"""Per-(shape, bits, backend) block-shape autotune cache.

The block selectors in `kernels/common.py` (`default_block`,
`conv_default_block`) pick safe VMEM-bounded tiles analytically. This
module layers a measured cache on top: `repro.kernels.api` consults
`get_block(op, shape, a_bits, w_bits, backend)` before falling back to the
analytic default, so a shape that has been autotuned once keeps its best
tile across runs via a small JSON artifact.

Cache key: ``op|MxKxN|a{a_bits}w{w_bits}|backend`` (conv keys use the full
geometry tuple). The JSON artifact is versioned and round-trips through
`save`/`load`; set ``REPRO_QTUNE_CACHE=/path/to/cache.json`` to preload it
at import-free first use. CI uploads the artifact so the tuned tiles ride
along with the perf trajectory.

CLI (used by the CI parity matrix to produce the artifact):

    PYTHONPATH=src python -m repro.kernels.tune \
        --shapes 64x256x256,64x512x128 --bits 8x8,4x4 \
        --backend pallas_interpret --out tune_cache.json
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Dict, Optional, Sequence, Tuple

# v2: qconv cache keys carry the grouped-conv tail (api._conv_shape grew
# from 9 to 10 elements), so v1 artifacts' conv entries can never match a
# lookup again — the version bump makes stale artifacts fail loudly
# (`load`) or skip with a warning (env preload) instead of silently
# missing on every lookup.
CACHE_VERSION = 2
CACHE_ENV = "REPRO_QTUNE_CACHE"


def _key(op: str, shape: Sequence[int], a_bits: int, w_bits: int,
         backend: str) -> str:
    return (f"{op}|{'x'.join(str(int(s)) for s in shape)}"
            f"|a{a_bits}w{w_bits}|{backend}")


class TuneCache:
    """In-memory block cache with a versioned JSON round-trip."""

    def __init__(self):
        self.blocks: Dict[str, Tuple[int, ...]] = {}

    def get(self, op, shape, a_bits, w_bits, backend):
        blk = self.blocks.get(_key(op, shape, a_bits, w_bits, backend))
        return None if blk is None else tuple(blk)

    def put(self, op, shape, a_bits, w_bits, backend, block):
        self.blocks[_key(op, shape, a_bits, w_bits, backend)] = tuple(
            int(b) for b in block)

    def to_json(self) -> str:
        return json.dumps({
            "version": CACHE_VERSION,
            "blocks": {k: list(v) for k, v in sorted(self.blocks.items())},
        }, indent=2, sort_keys=True)

    @staticmethod
    def from_json(text: str) -> "TuneCache":
        d = json.loads(text)
        if d.get("version") != CACHE_VERSION:
            raise ValueError(
                f"unsupported tune-cache version {d.get('version')}")
        c = TuneCache()
        c.blocks = {k: tuple(int(b) for b in v)
                    for k, v in d.get("blocks", {}).items()}
        return c


# module-level cache; REPRO_QTUNE_CACHE preloads it lazily on first lookup
_CACHE = TuneCache()
_ENV_LOADED = False


def _maybe_load_env():
    global _ENV_LOADED
    if _ENV_LOADED:
        return
    _ENV_LOADED = True
    path = os.environ.get(CACHE_ENV)
    if not path:
        return
    import warnings
    if pathlib.Path(path).exists():
        try:
            merge(load(path))
        except ValueError as e:
            warnings.warn(
                f"{CACHE_ENV}={path}: {e}; no tuned blocks loaded — "
                "re-run `python -m repro.kernels.tune` to regenerate",
                RuntimeWarning, stacklevel=2)
    else:
        warnings.warn(
            f"{CACHE_ENV}={path} does not exist; no tuned blocks loaded "
            "(every lookup falls back to the analytic block selectors)",
            RuntimeWarning, stacklevel=2)


def get_block(op: str, shape, a_bits: int, w_bits: int,
              backend: str) -> Optional[Tuple[int, ...]]:
    """Cached block for this exact (op, shape, bits, backend), or None —
    callers fall back to the analytic selector on a miss."""
    _maybe_load_env()
    return _CACHE.get(op, shape, a_bits, w_bits, backend)


def record_block(op: str, shape, a_bits: int, w_bits: int, backend: str,
                 block) -> None:
    _CACHE.put(op, shape, a_bits, w_bits, backend, block)


def clear() -> None:
    _CACHE.blocks.clear()


def save(path) -> None:
    pathlib.Path(path).write_text(_CACHE.to_json())


def load(path) -> TuneCache:
    return TuneCache.from_json(pathlib.Path(path).read_text())


def merge(other: TuneCache) -> None:
    _CACHE.blocks.update(other.blocks)


def entries() -> Dict[str, Tuple[int, ...]]:
    return dict(_CACHE.blocks)


# ---------------------------------------------------------------- tuning ---

def _time(fn, iters=2):
    import jax
    jax.block_until_ready(fn())          # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def qdot_candidates(m: int, n: int, k: int, a_bits: int,
                    w_bits: int) -> Tuple[Tuple[int, int, int], ...]:
    """Small candidate ladder around the analytic default (the paper's
    4x2 -> 4x4 register-tiling exploration, per shape)."""
    from repro.core import packing
    from repro.kernels.common import LANE, SUBLANE_I8, default_block

    bm0, bn0, bk0 = default_block(m, n, k, a_bits, w_bits)
    cands = {(bm0, bn0, bk0)}
    for bm in {bm0, max(SUBLANE_I8, bm0 // 2), bm0 * 2}:
        for bn in {bn0, max(LANE, bn0 // 2)}:
            for bk in {bk0, max(packing.CHUNK, bk0 // 2)}:
                if m % bm == 0 or bm <= m:
                    cands.add((bm, bn, bk))
    # keep only tiles that divide the padded problem cleanly enough for the
    # wrapper (bk must divide K; bm/bn are padded to by the wrapper)
    return tuple(sorted(c for c in cands if k % c[2] == 0))


def autotune_qdot(params, x_packed, *, backend: str = "pallas_interpret",
                  epilogue: str = "int", iters: int = 2,
                  candidates=None) -> Tuple[int, int, int]:
    """Time candidate GEMM blocks for one packed-shape and record the best.

    Returns the winning (bm, bn, bk); the result also lands in the module
    cache so subsequent `api.qdot` calls at this shape pick it up.
    """
    from repro.core import packing
    from repro.kernels import api

    m = x_packed.shape[0]
    k = x_packed.shape[1] * packing.pack_factor(params.a_bits)
    n = params.w_packed.shape[1]
    shape = (m, k, n)
    cands = tuple(candidates or qdot_candidates(m, n, k, params.a_bits,
                                                params.w_bits))
    spec = api.get("qdot", backend)
    best, best_t = None, float("inf")
    for blk in cands:
        try:
            t = _time(lambda b=blk: spec.run(
                params, x_packed, epilogue=epilogue, scale=1.0, block=b),
                iters=iters)
        except Exception:
            continue                      # candidate not runnable; skip
        if t < best_t:
            best, best_t = blk, t
    if best is None:
        raise RuntimeError(f"no runnable block candidate for {shape}")
    record_block("qdot", shape, params.a_bits, params.w_bits, backend, best)
    return best


def main():
    import argparse

    import numpy as np
    import jax.numpy as jnp

    from repro.core import packing
    from repro.core.quantize import QuantizedLinearParams

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shapes", default="64x256x256",
                    help="comma-separated MxKxN GEMM shapes")
    ap.add_argument("--bits", default="8x8,4x4,2x2",
                    help="comma-separated AxW bit pairs")
    ap.add_argument("--backend", default="pallas_interpret")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--out", default="tune_cache.json")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    for sh in args.shapes.split(","):
        m, k, n = (int(v) for v in sh.split("x"))
        for pair in args.bits.split(","):
            ab, wb = (int(v) for v in pair.split("x"))
            lo, hi = packing.int_range(ab, False)
            xp = packing.pack(jnp.asarray(rng.integers(
                lo, hi + 1, size=(m, k)).astype(np.int8)), ab, axis=-1)
            lo, hi = packing.int_range(wb, True)
            wp = packing.pack(jnp.asarray(rng.integers(
                lo, hi + 1, size=(k, n)).astype(np.int8)), wb, axis=0)
            params = QuantizedLinearParams(
                w_packed=wp, w_bits=wb, a_bits=ab, a_signed=False,
                kappa=jnp.ones((n,), jnp.int32),
                lam=jnp.zeros((n,), jnp.int32),
                m=jnp.full((n,), 1 << 14, jnp.int32), d=20, out_bits=8,
                k_logical=k)
            blk = autotune_qdot(params, xp, backend=args.backend,
                                iters=args.iters)
            print(f"qdot {m}x{k}x{n} A{ab}W{wb} [{args.backend}] -> {blk}")
    save(args.out)
    print(f"tune cache ({len(entries())} entries) -> {args.out}")


if __name__ == "__main__":
    main()
