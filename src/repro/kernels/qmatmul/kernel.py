"""Packed sub-byte integer GEMM — the XpulpNN `sdotp`/`mac&load` analogue.

One Pallas TPU kernel implements the whole paper pipeline per output tile
(see repro.kernels.common for the shared unpack/dot/epilogue machinery):

    unpack(W, X) -> int8        (the nibble/crumb SIMD operands, Table II)
    int8 x int8 -> int32 MXU    (pv.sdotp: sum-of-dot-product, eq. 2)
    kappa*acc + lambda          (integer batch-norm, eq. 3)
    (m * .) >> d, clip          (QNT/ACT, eq. 4)  [epilogue='int']

Mac&Load mapping — two pipeline modes (``pipeline=``):

  'off'            `pallas_call` grid pipelining double-buffers every
                   HBM->VMEM block copy, so the DMA of tile k+1 overlaps
                   the MXU work on tile k implicitly.
  'double_buffer'  the explicit Mac&Load analogue: the packed operands
                   stay in HBM (`memory_space=ANY`), the kernel owns two
                   VMEM slots per operand and issues manual async copies —
                   tile k+1's DMA starts before tile k's unpack+dot runs,
                   exactly how the paper's fused mac&load issues the next
                   load in the MAC's issue slot. The K grid dimension
                   disappears (the kernel loops K itself), so one grid
                   step owns the whole contraction.

Either way VMEM scratch plays the NN-RF role and the fused load never costs
an issue slot. OPEF -> 1 becomes "DMA fully hidden behind the MXU". Both
modes consume identical packed operands and accumulate in the same int32
order, so they are bit-exact against each other and the eager oracle
(tests/test_kernel_pipeline.py is the differential harness).

Tiling ("4x2 -> 4x4 MatMul layout" analogue): block sizes (bm, bn, bk) are
chosen so the double-buffered working set fits VMEM, with bm/bn multiples of
the MXU tile and bk a multiple of packing.CHUNK so chunk-planar unpacking
uses only static contiguous slices (no lane shuffles).

Grid is (M/bm, N/bn, K/bk) with K innermost ("arbitrary" semantics); the
int32 accumulator lives in a VMEM scratch buffer across K steps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.kernels.common import (EPILOGUE_DTYPES, apply_epilogue,
                                  check_pipeline, compiler_params,
                                  default_block, matmul_planes,
                                  segmented_bk, segmented_default_block)

# Back-compat re-exports: these lived here before the kernels/common split.
from repro.kernels.common import (LANE, SUBLANE_I8,  # noqa: F401
                                  matmul_planes as _matmul_planes,
                                  subsplit as _subsplit)


def _qmatmul_kernel(x_ref, w_ref, kappa_ref, lam_ref, m_ref, o_ref, acc_ref,
                    *, nk: int, a_bits: int, a_signed: bool, w_bits: int,
                    d: int, out_bits: int, epilogue: str, scale: float):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += matmul_planes(
        x_ref[...], w_ref[...], a_bits, a_signed, w_bits)

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        o_ref[...] = apply_epilogue(
            acc_ref[...], kappa_ref[...], lam_ref[...], m_ref[...],
            d=d, out_bits=out_bits, epilogue=epilogue, scale=scale,
            out_dtype=o_ref.dtype)


def _qmatmul_kernel_db(x_hbm, w_hbm, kappa_ref, lam_ref, m_ref, o_ref,
                       x_buf, w_buf, sems, acc_ref,
                       *, nk: int, bm: int, bn: int, bka: int, bkw: int,
                       a_bits: int, a_signed: bool, w_bits: int,
                       d: int, out_bits: int, epilogue: str, scale: float):
    """Double-buffered variant: x/w stay in HBM; two VMEM slots per
    operand; the DMA of K tile kk+1 is issued before tile kk's dot runs.

    x_buf: (2, bm, bka) int8 slots; w_buf: (2, bkw, bn) int8 slots;
    sems: (2, 2) DMA semaphores ([slot, operand]).
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    def x_dma(slot, kk):
        return pltpu.make_async_copy(
            x_hbm.at[pl.dslice(i * bm, bm), pl.dslice(kk * bka, bka)],
            x_buf.at[slot], sems.at[slot, 0])

    def w_dma(slot, kk):
        return pltpu.make_async_copy(
            w_hbm.at[pl.dslice(kk * bkw, bkw), pl.dslice(j * bn, bn)],
            w_buf.at[slot], sems.at[slot, 1])

    # warm-up: tile 0's copies are in flight before the loop starts
    x_dma(0, 0).start()
    w_dma(0, 0).start()
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(kk, carry):
        cur = jax.lax.rem(kk, 2)
        nxt = jax.lax.rem(kk + 1, 2)

        @pl.when(kk + 1 < nk)
        def _prefetch():        # next tile's DMA rides behind this dot
            x_dma(nxt, kk + 1).start()
            w_dma(nxt, kk + 1).start()

        x_dma(cur, kk).wait()
        w_dma(cur, kk).wait()
        acc_ref[...] += matmul_planes(
            x_buf[cur], w_buf[cur], a_bits, a_signed, w_bits)
        return carry

    jax.lax.fori_loop(0, nk, body, 0)
    o_ref[...] = apply_epilogue(
        acc_ref[...], kappa_ref[...], lam_ref[...], m_ref[...],
        d=d, out_bits=out_bits, epilogue=epilogue, scale=scale,
        out_dtype=o_ref.dtype)


def qmatmul_packed(x, w_packed, kappa, lam, m_mul, *,
                   a_bits: int, a_signed: bool, w_bits: int,
                   d: int, out_bits: int, epilogue: str = "int",
                   scale: float = 1.0,
                   block: Optional[tuple] = None,
                   out_dtype=None,
                   pipeline: str = "off",
                   interpret: bool = False):
    """Packed GEMM: x (M, K/pf_a) @ w (K/pf_w, N) with fused epilogue.

    K is the padded logical contraction dim (multiple of CHUNK); both
    operands are chunk-planar packed along K (bits==8 means unpacked).
    kappa/lam/m_mul are (N,) int32 epilogue params (ignored unless
    epilogue=='int'). ``pipeline`` selects the execution mode (module
    docstring): 'off' grids over K, 'double_buffer' loops K inside the
    kernel with manual two-slot DMA prefetch.

    ``interpret`` defaults to False (real Mosaic lowering); interpreter
    runs go through the explicit ``pallas_interpret`` backend of
    `repro.kernels.api` (tests pass interpret=True directly).
    """
    check_pipeline(pipeline)
    mdim = x.shape[0]
    pf_a, pf_w = packing.pack_factor(a_bits), packing.pack_factor(w_bits)
    k = x.shape[1] * pf_a
    assert w_packed.shape[0] * pf_w == k, (
        x.shape, w_packed.shape, a_bits, w_bits)
    n = w_packed.shape[1]
    if block is None:
        block = default_block(mdim, n, k, a_bits, w_bits)
    bm, bn, bk = block
    assert bk % packing.CHUNK == 0, (k, bk)
    assert mdim % bm == 0 and n % bn == 0, (mdim, n, bm, bn)
    if k % bk:
        # Ragged final K tile: zero-pad both packed operands to the next
        # bk multiple. Zero containers hold zero in every plane (signed or
        # not), so the extra MACs contribute nothing — exact in both
        # pipeline modes, and tuned bk choices aren't limited to divisors.
        k_fit = k + bk - k % bk
        x = jnp.pad(x, ((0, 0), (0, (k_fit - k) // pf_a)))
        w_packed = jnp.pad(w_packed, ((0, (k_fit - k) // pf_w), (0, 0)))
        k = k_fit
    nk = k // bk

    if out_dtype is None:
        out_dtype = EPILOGUE_DTYPES[epilogue]

    if pipeline == "double_buffer":
        kernel = functools.partial(
            _qmatmul_kernel_db, nk=nk, bm=bm, bn=bn, bka=bk // pf_a,
            bkw=bk // pf_w, a_bits=a_bits, a_signed=a_signed,
            w_bits=w_bits, d=d, out_bits=out_bits, epilogue=epilogue,
            scale=scale)
        return pl.pallas_call(
            kernel,
            grid=(mdim // bm, n // bn),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((1, bn), lambda i, j: (0, j)),
                pl.BlockSpec((1, bn), lambda i, j: (0, j)),
                pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mdim, n), out_dtype),
            scratch_shapes=[
                pltpu.VMEM((2, bm, bk // pf_a), jnp.int8),
                pltpu.VMEM((2, bk // pf_w, bn), jnp.int8),
                pltpu.SemaphoreType.DMA((2, 2)),
                pltpu.VMEM((bm, bn), jnp.int32),
            ],
            compiler_params=compiler_params(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(x, w_packed, kappa.reshape(1, -1), lam.reshape(1, -1),
          m_mul.reshape(1, -1))

    kernel = functools.partial(
        _qmatmul_kernel, nk=nk, a_bits=a_bits, a_signed=a_signed,
        w_bits=w_bits, d=d, out_bits=out_bits, epilogue=epilogue, scale=scale)

    grid = (mdim // bm, n // bn, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk // pf_a), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // pf_w, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mdim, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, kappa.reshape(1, -1), lam.reshape(1, -1),
      m_mul.reshape(1, -1))


def _qmatmul_segmented_kernel(code_ref, off_ref, x_ref, kappa_ref, lam_ref,
                              m_ref, w_hbm, o_ref, w_buf, sems, acc_ref,
                              *, nk: int, bk: int, widths, a_bits: int,
                              a_signed: bool, d: int, out_bits: int,
                              epilogue: str, scale: float, pipeline: str):
    """Mixed-operand GEMM tile (fine-grain mixed precision, 2307.01056).

    One grid step owns one (bm, LANE) output tile. The weight panel for
    N-tile j lives at byte offset ``off_ref[j]`` in the flat segmented
    buffer, packed at width ``widths[code_ref[j]]`` — both scalars arrive
    via prefetch, so the kernel picks its DMA size and planar unpack
    width per tile with a `jax.lax.switch` over the (static) width set.
    K loops inside the kernel: panel-major layout makes tile kk of the
    panel the contiguous byte range [off + kk*sz, off + (kk+1)*sz).
    """
    j = pl.program_id(1)
    code = code_ref[j]
    base = off_ref[j]
    pf_a = packing.pack_factor(a_bits)
    bka = bk // pf_a
    sizes = [bk // packing.pack_factor(b) * LANE for b in widths]

    def dma(slot, kk, wi):
        sz = sizes[wi]
        return pltpu.make_async_copy(
            w_hbm.at[pl.dslice(base + kk * sz, sz)],
            w_buf.at[slot, pl.dslice(0, sz)], sems.at[slot])

    def start(slot, kk):
        jax.lax.switch(code, [
            (lambda wi=wi: dma(slot, kk, wi).start())
            for wi in range(len(widths))])

    def wait(slot, kk):
        jax.lax.switch(code, [
            (lambda wi=wi: dma(slot, kk, wi).wait())
            for wi in range(len(widths))])

    def tile_dot(slot, kk):
        xb = x_ref[:, pl.dslice(kk * bka, bka)]

        def dot_at(wi):
            rows = bk // packing.pack_factor(widths[wi])
            wb = w_buf[slot, pl.dslice(0, rows * LANE)].reshape(rows, LANE)
            return matmul_planes(xb, wb, a_bits, a_signed, widths[wi])

        return jax.lax.switch(code, [
            (lambda wi=wi: dot_at(wi)) for wi in range(len(widths))])

    acc_ref[...] = jnp.zeros_like(acc_ref)
    if pipeline == "double_buffer":
        start(0, 0)

        def body(kk, carry):
            cur = jax.lax.rem(kk, 2)
            nxt = jax.lax.rem(kk + 1, 2)

            @pl.when(kk + 1 < nk)
            def _prefetch():    # next K tile's DMA rides behind this dot
                start(nxt, kk + 1)

            wait(cur, kk)
            acc_ref[...] += tile_dot(cur, kk)
            return carry
    else:

        def body(kk, carry):
            start(0, kk)
            wait(0, kk)
            acc_ref[...] += tile_dot(0, kk)
            return carry

    jax.lax.fori_loop(0, nk, body, 0)
    o_ref[...] = apply_epilogue(
        acc_ref[...], kappa_ref[...], lam_ref[...], m_ref[...],
        d=d, out_bits=out_bits, epilogue=epilogue, scale=scale,
        out_dtype=o_ref.dtype)


def qmatmul_segmented(x, w_flat, segmap, kappa, lam, m_mul, *,
                      k_logical: int, a_bits: int, a_signed: bool,
                      d: int, out_bits: int, epilogue: str = "int",
                      scale: float = 1.0,
                      block: Optional[tuple] = None,
                      out_dtype=None,
                      pipeline: str = "off",
                      interpret: bool = False):
    """Mixed-operand packed GEMM over a segmented weight container.

    x: (M, K_pad/pf_a) packed activations; w_flat: a flat
    `packing.pack_segmented` buffer (panel-major) whose N must be a
    CHUNK/LANE multiple — callers `packing.pad_segmented` first. The grid
    is (M/bm, N/LANE): each N tile is exactly one CHUNK-wide column
    panel, so a tile never straddles a segment boundary and its unpack
    width + byte offset come from the prefetched per-tile descriptor
    (`segmap.tile_table`). K loops inside the kernel with manual DMA from
    the flat buffer — 'off' copies/waits/dots serially per K tile,
    'double_buffer' rotates two slots with the next tile's copy issued
    behind the current dot. Both orders accumulate identically in int32,
    so they are bit-exact vs each other and vs running each segment
    through the uniform kernel and concatenating (the composition
    oracle, tests/test_mixed_operand_kernel.py).
    """
    check_pipeline(pipeline)
    mdim = x.shape[0]
    pf_a = packing.pack_factor(a_bits)
    k_pad = x.shape[1] * pf_a
    assert k_pad == packing.padded_size(k_logical), (k_pad, k_logical)
    n = segmap.n
    assert n % LANE == 0, n
    assert w_flat.ndim == 1 and w_flat.shape[0] == segmap.packed_bytes(
        k_logical), (w_flat.shape, segmap.runs)
    widths = segmap.widths()
    if block is None:
        bm, bk = segmented_default_block(mdim, k_pad, a_bits, widths)
    else:
        bm, _, bk = block
        bk = segmented_bk(k_pad, bk)
    assert mdim % bm == 0, (mdim, bm)
    nk = k_pad // bk
    nslots = 2 if pipeline == "double_buffer" else 1
    slot_bytes = bk // min(packing.pack_factor(b) for b in widths) * LANE

    codes, offs = segmap.tile_table(k_logical)
    if out_dtype is None:
        out_dtype = EPILOGUE_DTYPES[epilogue]

    kernel = functools.partial(
        _qmatmul_segmented_kernel, nk=nk, bk=bk, widths=widths,
        a_bits=a_bits, a_signed=a_signed, d=d, out_bits=out_bits,
        epilogue=epilogue, scale=scale, pipeline=pipeline)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mdim // bm, n // LANE),
        in_specs=[
            pl.BlockSpec((bm, k_pad // pf_a), lambda i, j, *_: (i, 0)),
            pl.BlockSpec((1, LANE), lambda i, j, *_: (0, j)),
            pl.BlockSpec((1, LANE), lambda i, j, *_: (0, j)),
            pl.BlockSpec((1, LANE), lambda i, j, *_: (0, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((bm, LANE), lambda i, j, *_: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((nslots, slot_bytes), jnp.int8),
            pltpu.SemaphoreType.DMA((nslots,)),
            pltpu.VMEM((bm, LANE), jnp.int32),
        ])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mdim, n), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(codes, jnp.int32), jnp.asarray(offs, jnp.int32),
      x, kappa.reshape(1, -1), lam.reshape(1, -1), m_mul.reshape(1, -1),
      w_flat)
