"""Packed sub-byte integer GEMM — the XpulpNN `sdotp`/`mac&load` analogue.

One Pallas TPU kernel implements the whole paper pipeline per output tile:

    unpack(W, X) -> int8        (the nibble/crumb SIMD operands, Table II)
    int8 x int8 -> int32 MXU    (pv.sdotp: sum-of-dot-product, eq. 2)
    kappa*acc + lambda          (integer batch-norm, eq. 3)
    (m * .) >> d, clip          (QNT/ACT, eq. 4)  [epilogue='int']

Mac&Load mapping: `pallas_call` grid pipelining double-buffers every
HBM->VMEM block copy, so the DMA of tile k+1 overlaps the MXU work on tile k
— VMEM scratch plays the NN-RF role and the fused load never costs an issue
slot. OPEF -> 1 becomes "DMA fully hidden behind the MXU".

Tiling ("4x2 -> 4x4 MatMul layout" analogue): block sizes (bm, bn, bk) are
chosen so the double-buffered working set fits VMEM, with bm/bn multiples of
the MXU tile and bk a multiple of packing.CHUNK so chunk-planar unpacking
uses only static contiguous slices (no lane shuffles).

Grid is (M/bm, N/bn, K/bk) with K innermost ("arbitrary" semantics); the
int32 accumulator lives in a VMEM scratch buffer across K steps.

Field extraction is elementwise (shift+mask on int8 containers), so a plane
of a packed block keeps the block's shape; planes of X pair one-to-one with
planes of W because both sides use the same chunk-planar logical K order and
integer accumulation is order-invariant.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.core.quantize import requantize_shift

# int8 MXU-friendly minimum tile: (32, 128); accumulate in int32.
LANE = 128
SUBLANE_I8 = 32


def _subsplit(planes, factor, axis):
    """Split coarse chunk-planes into `factor`-finer planes along `axis`.

    A plane of a pf-packed operand covers, per chunk, a contiguous logical
    run of R = CHUNK // pf elements; the finer layout needs runs of
    R // factor. Chunk order is shared, so this is a pure static reshape.
    Fine plane q = p_coarse * factor + f.
    """
    if factor == 1:
        return planes
    pf_coarse = len(planes)
    run = packing.CHUNK // pf_coarse
    fine_run = run // factor
    out = []
    for p in planes:
        if axis == 0:
            k, n = p.shape
            q = p.reshape(k // run, factor, fine_run, n)
            out.extend(q[:, f].reshape(k // factor, n) for f in range(factor))
        else:
            m, k = p.shape
            q = p.reshape(m, k // run, factor, fine_run)
            out.extend(q[:, :, f].reshape(m, k // factor)
                       for f in range(factor))
    return out


def _matmul_planes(x_block, w_block, a_bits, a_signed, w_bits):
    """Planar sub-byte dot product -> (bm, bn) int32 partial sum."""
    pf_a = packing.pack_factor(a_bits)
    pf_w = packing.pack_factor(w_bits)
    x_planes = packing.unpack_planes(x_block, a_bits, a_signed)
    w_planes = packing.unpack_planes(w_block, w_bits, True)  # weights signed

    pf = max(pf_a, pf_w)
    x_planes = _subsplit(x_planes, pf // pf_a, axis=1)
    w_planes = _subsplit(w_planes, pf // pf_w, axis=0)

    acc = None
    for xp, wp in zip(x_planes, w_planes):
        part = jax.lax.dot_general(
            xp, wp, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = part if acc is None else acc + part
    return acc


def _qmatmul_kernel(x_ref, w_ref, kappa_ref, lam_ref, m_ref, o_ref, acc_ref,
                    *, nk: int, a_bits: int, a_signed: bool, w_bits: int,
                    d: int, out_bits: int, epilogue: str, scale: float):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _matmul_planes(
        x_ref[...], w_ref[...], a_bits, a_signed, w_bits)

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if epilogue == "int":
            # eq.(3): integer BN (per out-channel), then eq.(4) requant+clip
            phi_p = acc * kappa_ref[...] + lam_ref[...]
            y = requantize_shift(phi_p, m_ref[...], d)
            hi = packing.int_range(out_bits, False)[1]
            o_ref[...] = jnp.clip(y, 0, hi).astype(jnp.int8)
        elif epilogue == "dequant":
            o_ref[...] = (acc.astype(jnp.float32) * scale).astype(o_ref.dtype)
        else:  # 'raw' int32 accumulators
            o_ref[...] = acc


def default_block(m, n, k, a_bits, w_bits,
                  vmem_budget: int = 8 * 1024 * 1024):
    """Pick (bm, bn, bk): MXU-aligned, chunk-aligned, VMEM-bounded.

    The paper's 4x2 -> 4x4 register-tiling exploration becomes this block
    shape selection; benchmarks/fig8 measures the ladder.
    """
    def align(v, unit):
        return max(unit, (v // unit) * unit)

    bm = align(min(m, 256), SUBLANE_I8)
    bn = align(min(n, 512), LANE)
    bk = align(min(k, 1024), packing.CHUNK)
    pf_a, pf_w = packing.pack_factor(a_bits), packing.pack_factor(w_bits)

    def fits(bm, bn, bk):
        x_b = bm * (bk // pf_a)
        w_b = (bk // pf_w) * bn
        io = bm * bn * 4 * 2  # acc scratch + out block
        return 2 * (x_b + w_b) + io <= vmem_budget

    while not fits(bm, bn, bk) and bk > packing.CHUNK:
        bk //= 2
    while not fits(bm, bn, bk) and bn > LANE:
        bn //= 2
    while not fits(bm, bn, bk) and bm > SUBLANE_I8:
        bm //= 2
    return bm, bn, bk


def qmatmul_packed(x, w_packed, kappa, lam, m_mul, *,
                   a_bits: int, a_signed: bool, w_bits: int,
                   d: int, out_bits: int, epilogue: str = "int",
                   scale: float = 1.0,
                   block: Optional[tuple] = None,
                   out_dtype=None,
                   interpret: bool = True):
    """Packed GEMM: x (M, K/pf_a) @ w (K/pf_w, N) with fused epilogue.

    K is the padded logical contraction dim (multiple of CHUNK); both
    operands are chunk-planar packed along K (bits==8 means unpacked).
    kappa/lam/m_mul are (N,) int32 epilogue params (ignored unless
    epilogue=='int').
    """
    mdim = x.shape[0]
    pf_a, pf_w = packing.pack_factor(a_bits), packing.pack_factor(w_bits)
    k = x.shape[1] * pf_a
    assert w_packed.shape[0] * pf_w == k, (
        x.shape, w_packed.shape, a_bits, w_bits)
    n = w_packed.shape[1]
    if block is None:
        block = default_block(mdim, n, k, a_bits, w_bits)
    bm, bn, bk = block
    assert k % bk == 0 and bk % packing.CHUNK == 0, (k, bk)
    assert mdim % bm == 0 and n % bn == 0, (mdim, n, bm, bn)
    nk = k // bk

    if out_dtype is None:
        out_dtype = {"int": jnp.int8, "dequant": jnp.bfloat16,
                     "raw": jnp.int32}[epilogue]

    kernel = functools.partial(
        _qmatmul_kernel, nk=nk, a_bits=a_bits, a_signed=a_signed,
        w_bits=w_bits, d=d, out_bits=out_bits, epilogue=epilogue, scale=scale)

    grid = (mdim // bm, n // bn, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk // pf_a), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // pf_w, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mdim, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, kappa.reshape(1, -1), lam.reshape(1, -1),
      m_mul.reshape(1, -1))
