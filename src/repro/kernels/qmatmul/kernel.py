"""Packed sub-byte integer GEMM — the XpulpNN `sdotp`/`mac&load` analogue.

One Pallas TPU kernel implements the whole paper pipeline per output tile
(see repro.kernels.common for the shared unpack/dot/epilogue machinery):

    unpack(W, X) -> int8        (the nibble/crumb SIMD operands, Table II)
    int8 x int8 -> int32 MXU    (pv.sdotp: sum-of-dot-product, eq. 2)
    kappa*acc + lambda          (integer batch-norm, eq. 3)
    (m * .) >> d, clip          (QNT/ACT, eq. 4)  [epilogue='int']

Mac&Load mapping: `pallas_call` grid pipelining double-buffers every
HBM->VMEM block copy, so the DMA of tile k+1 overlaps the MXU work on tile k
— VMEM scratch plays the NN-RF role and the fused load never costs an issue
slot. OPEF -> 1 becomes "DMA fully hidden behind the MXU".

Tiling ("4x2 -> 4x4 MatMul layout" analogue): block sizes (bm, bn, bk) are
chosen so the double-buffered working set fits VMEM, with bm/bn multiples of
the MXU tile and bk a multiple of packing.CHUNK so chunk-planar unpacking
uses only static contiguous slices (no lane shuffles).

Grid is (M/bm, N/bn, K/bk) with K innermost ("arbitrary" semantics); the
int32 accumulator lives in a VMEM scratch buffer across K steps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.kernels.common import (EPILOGUE_DTYPES, apply_epilogue,
                                  compiler_params, default_block,
                                  matmul_planes)

# Back-compat re-exports: these lived here before the kernels/common split.
from repro.kernels.common import (LANE, SUBLANE_I8,  # noqa: F401
                                  matmul_planes as _matmul_planes,
                                  subsplit as _subsplit)


def _qmatmul_kernel(x_ref, w_ref, kappa_ref, lam_ref, m_ref, o_ref, acc_ref,
                    *, nk: int, a_bits: int, a_signed: bool, w_bits: int,
                    d: int, out_bits: int, epilogue: str, scale: float):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += matmul_planes(
        x_ref[...], w_ref[...], a_bits, a_signed, w_bits)

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        o_ref[...] = apply_epilogue(
            acc_ref[...], kappa_ref[...], lam_ref[...], m_ref[...],
            d=d, out_bits=out_bits, epilogue=epilogue, scale=scale,
            out_dtype=o_ref.dtype)


def qmatmul_packed(x, w_packed, kappa, lam, m_mul, *,
                   a_bits: int, a_signed: bool, w_bits: int,
                   d: int, out_bits: int, epilogue: str = "int",
                   scale: float = 1.0,
                   block: Optional[tuple] = None,
                   out_dtype=None,
                   interpret: bool = False):
    """Packed GEMM: x (M, K/pf_a) @ w (K/pf_w, N) with fused epilogue.

    K is the padded logical contraction dim (multiple of CHUNK); both
    operands are chunk-planar packed along K (bits==8 means unpacked).
    kappa/lam/m_mul are (N,) int32 epilogue params (ignored unless
    epilogue=='int').

    ``interpret`` defaults to False (real Mosaic lowering); interpreter
    runs go through the explicit ``pallas_interpret`` backend of
    `repro.kernels.api` (tests pass interpret=True directly).
    """
    mdim = x.shape[0]
    pf_a, pf_w = packing.pack_factor(a_bits), packing.pack_factor(w_bits)
    k = x.shape[1] * pf_a
    assert w_packed.shape[0] * pf_w == k, (
        x.shape, w_packed.shape, a_bits, w_bits)
    n = w_packed.shape[1]
    if block is None:
        block = default_block(mdim, n, k, a_bits, w_bits)
    bm, bn, bk = block
    assert k % bk == 0 and bk % packing.CHUNK == 0, (k, bk)
    assert mdim % bm == 0 and n % bn == 0, (mdim, n, bm, bn)
    nk = k // bk

    if out_dtype is None:
        out_dtype = EPILOGUE_DTYPES[epilogue]

    kernel = functools.partial(
        _qmatmul_kernel, nk=nk, a_bits=a_bits, a_signed=a_signed,
        w_bits=w_bits, d=d, out_bits=out_bits, epilogue=epilogue, scale=scale)

    grid = (mdim // bm, n // bn, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk // pf_a), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // pf_w, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mdim, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, kappa.reshape(1, -1), lam.reshape(1, -1),
      m_mul.reshape(1, -1))
