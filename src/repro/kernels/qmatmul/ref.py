"""Pure-numpy oracle for the packed sub-byte GEMM.

Independent implementation of eq.(2)-(4) used by every kernel test. Where
jnp lacks int64 (x64 disabled), numpy's int64 is used for the requant
product, making this oracle *wider* than the int32 kernel path — exactness
of the kernel's int32 split is itself asserted against this oracle.
"""
from __future__ import annotations

import numpy as np

from repro.core import packing


def unpack_np(p, bits: int, signed: bool, axis: int = -1) -> np.ndarray:
    """numpy chunk-planar unpack (independent of repro.core.packing jnp path).
    """
    p = np.asarray(p, dtype=np.int8)
    if bits == 8:
        return p
    pf = 8 // bits
    p = np.moveaxis(p, axis, -1)
    *lead, kp = p.shape
    sub = packing.CHUNK // pf
    chunks = p.reshape(*lead, kp // sub, sub).astype(np.uint8)
    planes = []
    for pl in range(pf):
        field = (chunks >> (bits * pl)) & ((1 << bits) - 1)
        if signed:
            sign = 1 << (bits - 1)
            field = (field.astype(np.int16) ^ sign) - sign
        planes.append(field.astype(np.int8))
    out = np.stack(planes, axis=-2).reshape(*lead, kp * pf)
    return np.moveaxis(out, -1, axis)


def qmatmul_ref(x_packed, w_packed, kappa, lam, m_mul, *,
                a_bits: int, a_signed: bool, w_bits: int,
                d: int, out_bits: int, epilogue: str = "int",
                scale: float = 1.0) -> np.ndarray:
    x = unpack_np(x_packed, a_bits, a_signed, axis=-1).astype(np.int32)
    w = unpack_np(w_packed, w_bits, True, axis=0).astype(np.int32)
    with np.errstate(over="ignore"):
        acc = (x @ w).astype(np.int32)  # int32 accumulation semantics
        if epilogue == "raw":
            return acc
        if epilogue == "dequant":
            return (acc.astype(np.float32) * np.float32(scale))
        kappa = np.asarray(kappa, dtype=np.int32).reshape(1, -1)
        lam = np.asarray(lam, dtype=np.int32).reshape(1, -1)
        m = np.asarray(m_mul, dtype=np.int64).reshape(1, -1)
        phi_p = (acc * kappa + lam).astype(np.int32)
        y = (m * phi_p.astype(np.int64)) >> d
        hi = packing.int_range(out_bits, False)[1]
        return np.clip(y, 0, hi).astype(np.int8)
