from repro.kernels.qmatmul.ops import (qlinear_apply, qlinear_apply_packed,
                                       qmatmul_jnp)
from repro.kernels.qmatmul.kernel import qmatmul_packed, default_block
from repro.kernels.qmatmul.ref import qmatmul_ref, unpack_np
from repro.kernels.api import qdot, qdot_packed
