"""jit'd public wrapper around the packed sub-byte GEMM kernel.

Handles leading-batch flattening, K padding to packing.CHUNK, M/N padding to
block multiples, activation quantize+pack on the way in, and exposes the
three epilogues. `use_kernel=False` falls back to a pure-jnp path with
identical integer semantics (used on the 512-device dry-run meshes where the
interpret-mode kernel would be prohibitively slow to trace per device, and
as the XLA-native production path: the packed GEMM then lowers to XLA
convert+dot which the TPU compiler fuses).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quantize import (QuantizedLinearParams, batchnorm_int,
                                 qnt_act, requantize_shift)
from repro.kernels.qmatmul.kernel import qmatmul_packed


def _flatten_lead(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_axis(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def qmatmul_jnp(x_packed, w_packed, kappa, lam, m_mul, *,
                a_bits, a_signed, w_bits, d, out_bits,
                epilogue="int", scale=1.0):
    """Pure-jnp path, bit-identical to the kernel (shares requant helper)."""
    x = packing.unpack(x_packed, a_bits, a_signed, axis=-1)
    w = packing.unpack(w_packed, w_bits, True, axis=0)
    acc = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    if epilogue == "raw":
        return acc
    if epilogue == "dequant":
        return (acc.astype(jnp.float32) * scale).astype(jnp.bfloat16)
    phi_p = batchnorm_int(acc, kappa, lam)
    return qnt_act(phi_p, m_mul, d, out_bits)


def qlinear_apply(params: QuantizedLinearParams, x_hat, *,
                  epilogue: str = "int", scale: float = 1.0,
                  use_kernel: bool = True, block: Optional[tuple] = None,
                  interpret: bool = True):
    """Apply a quantized linear layer to integer-image activations.

    x_hat: (..., K_logical) int8 integer images (unpacked). They are padded
    to CHUNK and packed on the fly when a_bits < 8 (in a fused chain the
    previous layer's epilogue already emits packed activations and
    `qlinear_apply_packed` skips this step).
    """
    x2, lead = _flatten_lead(x_hat)
    x2 = packing.pad_to_chunk(x2, axis=-1)
    xp = packing.pack(x2, params.a_bits, axis=-1)
    out = qlinear_apply_packed(
        params, xp, epilogue=epilogue, scale=scale, use_kernel=use_kernel,
        block=block, interpret=interpret)
    return out.reshape(*lead, out.shape[-1])


def qlinear_apply_packed(params: QuantizedLinearParams, x_packed, *,
                         epilogue: str = "int", scale: float = 1.0,
                         use_kernel: bool = True,
                         block: Optional[tuple] = None,
                         interpret: bool = True):
    kw = dict(a_bits=params.a_bits, a_signed=params.a_signed,
              w_bits=params.w_bits, d=params.d, out_bits=params.out_bits,
              epilogue=epilogue, scale=scale)
    if not use_kernel:
        return qmatmul_jnp(x_packed, params.w_packed, params.kappa,
                           params.lam, params.m, **kw)
    # pad M to the block multiple the kernel picks
    m = x_packed.shape[0]
    pf_a = packing.pack_factor(params.a_bits)
    k = x_packed.shape[1] * pf_a
    n = params.w_packed.shape[1]
    from repro.kernels.qmatmul.kernel import default_block
    bm, bn, bk = block or default_block(m, n, k, params.a_bits, params.w_bits)
    bm = min(bm, _round_up(m, 32))
    xp = _pad_axis(x_packed, bm, 0)
    wp = _pad_axis(params.w_packed, bn, 1)
    kappa = _pad_axis(params.kappa, bn, 0)
    lam = _pad_axis(params.lam, bn, 0)
    mm = _pad_axis(params.m, bn, 0)
    out = qmatmul_packed(xp, wp, kappa, lam, mm, block=(bm, bn, bk),
                         interpret=interpret, **kw)
    return out[:m, :n]


def _round_up(x, mult):
    return x + (-x) % mult
