"""Compat wrappers around the unified quantized-op API (`repro.kernels.api`).

`qlinear_apply`/`qlinear_apply_packed` are thin shims over `api.qdot` /
`api.qdot_packed`: backend selection, block lookup, padding, and packing
all live in the registry layer now. The deprecated ``use_kernel`` /
``interpret`` booleans map onto named backends (True -> 'pallas_interpret'
— the old default silently ran interpret mode; True + interpret=False ->
'pallas'; False -> 'xla') with a DeprecationWarning.

`qmatmul_jnp` keeps its raw-argument signature (tests/benchmarks build
operands directly) but is now a wrapper over the one shared XLA int-GEMM
implementation (`api.xla_int_gemm`) — the same code path the nn dense int
mode runs.
"""
from __future__ import annotations

from typing import Optional

from repro.core import packing
from repro.core.quantize import QuantizedLinearParams
from repro.kernels import api
from repro.obs import trace as obs


def qmatmul_jnp(x_packed, w_packed, kappa, lam, m_mul, *,
                a_bits, a_signed, w_bits, d, out_bits,
                epilogue="int", scale=1.0):
    """Pure-XLA path, bit-identical to the kernel (shared requant helper)."""
    x = packing.unpack(x_packed, a_bits, a_signed, axis=-1)
    return api.xla_int_gemm(x, w_packed, w_bits=w_bits, kappa=kappa,
                            lam=lam, m_mul=m_mul, d=d, out_bits=out_bits,
                            epilogue=epilogue, scale=scale)


def qlinear_apply(params: QuantizedLinearParams, x_hat, *,
                  epilogue: str = "int", scale: float = 1.0,
                  backend: Optional[str] = None,
                  block: Optional[tuple] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: Optional[bool] = None):
    """Apply a quantized linear layer to integer-image activations.

    Thin compat wrapper over `repro.kernels.api.qdot`; prefer calling that
    directly. ``use_kernel``/``interpret`` are deprecated aliases.
    """
    backend = api.resolve_legacy_backend(backend, use_kernel, interpret)
    with obs.span("qlinear_apply", cat="compat",
                  legacy=use_kernel is not None or interpret is not None):
        return api.qdot(params, x_hat, epilogue=epilogue, scale=scale,
                        backend=backend, block=block)


def qlinear_apply_packed(params: QuantizedLinearParams, x_packed, *,
                         epilogue: str = "int", scale: float = 1.0,
                         backend: Optional[str] = None,
                         block: Optional[tuple] = None,
                         use_kernel: Optional[bool] = None,
                         interpret: Optional[bool] = None):
    """`qlinear_apply` over already-packed activations (compat wrapper over
    `repro.kernels.api.qdot_packed`)."""
    backend = api.resolve_legacy_backend(backend, use_kernel, interpret)
    with obs.span("qlinear_apply_packed", cat="compat",
                  legacy=use_kernel is not None or interpret is not None):
        return api.qdot_packed(params, x_packed, epilogue=epilogue,
                               scale=scale, backend=backend, block=block)
