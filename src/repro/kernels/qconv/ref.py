"""numpy direct-convolution oracle for the quantized conv layer.

Deliberately does NOT use im2col — it convolves directly with int32
accumulation and int64 requant, so a bug in the im2col/GEMM path cannot hide
in a shared code path.
"""
from __future__ import annotations

import numpy as np


def qconv2d_ref(x_hat, w_hat, kappa, lam, m_mul, d, out_bits,
                stride: int = 1, padding: int = 1) -> np.ndarray:
    """x_hat: (N,H,W,Cin) int8, w_hat: (fh,fw,cin,cout) int8 (UNPACKED)."""
    x = np.asarray(x_hat, dtype=np.int32)
    w = np.asarray(w_hat, dtype=np.int32)
    n, h, ww_, c = x.shape
    fh, fw, cin, cout = w.shape
    assert cin == c
    if padding:
        x = np.pad(x, ((0, 0), (padding, padding), (padding, padding),
                       (0, 0)))
    ho = (h + 2 * padding - fh) // stride + 1
    wo = (ww_ + 2 * padding - fw) // stride + 1
    acc = np.zeros((n, ho, wo, cout), dtype=np.int64)
    for dy in range(fh):
        for dx in range(fw):
            patch = x[:, dy:dy + stride * ho:stride,
                      dx:dx + stride * wo:stride]  # (n,ho,wo,cin)
            acc += np.einsum("nhwc,co->nhwo", patch, w[dy, dx],
                             dtype=np.int64)
    acc = acc.astype(np.int32)  # int32 accumulator semantics
    kappa = np.asarray(kappa, dtype=np.int32)
    lam = np.asarray(lam, dtype=np.int32)
    with np.errstate(over="ignore"):
        phi_p = (acc * kappa + lam).astype(np.int32)
    from repro.core import packing
    y = (np.asarray(m_mul, dtype=np.int64) * phi_p.astype(np.int64)) >> d
    hi = packing.int_range(out_bits, False)[1]
    return np.clip(y, 0, hi).astype(np.int8)
