"""Quantized HWC convolution = im2col + packed sub-byte GEMM (paper §III-C).

PULP-NN's execution model is reproduced structurally: an im2col transform
arranges each output pixel's receptive field (F*F*Cin contiguous, HWC
layout) into a GEMM row, then the MatMul + BN + QNT/ACT pipeline runs as one
fused kernel (repro.kernels.qmatmul). On TPU the im2col is pure data
movement the XLA compiler folds into the surrounding program; the compute
hot-spot is the packed GEMM.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.quantize import (QuantSpec, QuantizedLinearParams,
                                 fold_bn_requant, quantize)
from repro.kernels.qmatmul import qlinear_apply


def im2col_hwc(x, fh: int, fw: int, stride: int = 1, padding: int = 0):
    """(N, H, W, C) -> (N, Ho, Wo, fh*fw*C); receptive field flattened in
    (dy, dx, c) order, matching the paper's HWC im2col buffer."""
    n, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding),
                        (0, 0)))
    ho = (h + 2 * padding - fh) // stride + 1
    wo = (w + 2 * padding - fw) // stride + 1
    cols = []
    for dy in range(fh):
        for dx in range(fw):
            sl = x[:, dy:dy + stride * ho:stride, dx:dx + stride * wo:stride]
            cols.append(sl)
    return jnp.concatenate(cols, axis=-1), ho, wo


@dataclasses.dataclass(frozen=True)
class QuantizedConvParams:
    """Deployable artifact for one quantized conv layer."""

    gemm: QuantizedLinearParams   # packed (fh*fw*cin -> cout) GEMM
    fh: int
    fw: int
    stride: int
    padding: int
    cin: int
    cout: int


def quantize_conv(w, spec_w: QuantSpec, bn_scale, bn_bias,
                  spec_x: QuantSpec, spec_y: QuantSpec,
                  stride: int = 1, padding: int = 1) -> QuantizedConvParams:
    """w: (fh, fw, cin, cout) real weights -> packed integer artifact."""
    fh, fw, cin, cout = w.shape
    w_hat = quantize(w.reshape(fh * fw * cin, cout), spec_w)
    k_logical = w_hat.shape[0]
    w_hat = packing.pad_to_chunk(w_hat, axis=0)
    w_packed = packing.pack(w_hat, spec_w.bits, axis=0)
    kappa, lam, m, d = fold_bn_requant(
        spec_w.eps, spec_x.eps, spec_y.eps, bn_scale, bn_bias, spec_y.bits)
    gemm = QuantizedLinearParams(
        w_packed=w_packed, w_bits=spec_w.bits, a_bits=spec_x.bits,
        a_signed=spec_x.signed, kappa=kappa, lam=lam, m=m, d=d,
        out_bits=spec_y.bits, k_logical=k_logical)
    return QuantizedConvParams(gemm=gemm, fh=fh, fw=fw, stride=stride,
                               padding=padding, cin=cin, cout=cout)


def qconv2d_apply(params: QuantizedConvParams, x_hat, *,
                  use_kernel: bool = True, interpret: bool = True,
                  block: Optional[tuple] = None):
    """x_hat: (N, H, W, Cin) int8 integer images -> (N, Ho, Wo, Cout) int8."""
    cols, ho, wo = im2col_hwc(x_hat, params.fh, params.fw, params.stride,
                              params.padding)
    y = qlinear_apply(params.gemm, cols, use_kernel=use_kernel,
                      interpret=interpret, block=block)
    return y.reshape(x_hat.shape[0], ho, wo, params.cout)
