"""Quantized HWC convolution (paper §III-C) — backends via the registry.

The conv is the implicit GEMM (N*Ho*Wo, fh*fw*Cin) @ (fh*fw*Cin, Cout).
The `pallas`/`pallas_interpret` backends run
`repro.kernels.qconv.kernel.qconv2d_fused`: the PULP-NN execution model
inside one Pallas kernel — receptive fields are gathered from the packed
HWC image straight into a VMEM scratch buffer (the NN-RF/im2col-buffer
analogue), then MatMul + BN + QNT/ACT run on the tile with no HBM-resident
im2col tensor, so the gather loads hide behind the MXU the way Mac&Load
hides loads behind MACs.

The `xla` backend keeps the original explicit route: an XLA im2col
(`im2col_hwc`) materializes the column tensor, then the XLA packed GEMM
consumes it. All backends share the quantization artifact and are
bit-identical; `xla` also covers images too large for the fused kernel's
whole-image VMEM block. `qconv2d_apply` below is a thin compat wrapper
over `repro.kernels.api.qconv` (the deprecated ``use_kernel``/
``interpret`` booleans map onto named backends).

Weights are packed twice at quantization time (a few KB each at IoT scale):
the flat im2col layout (K = fh*fw*cin padded once at the tail) for the
fallback, and the per-tap layout (each tap's Cin padded to a CHUNK multiple
independently, K = fh*fw*cin_pad, tap-major) the fused gather needs so every
receptive-field slice stays chunk-planar aligned.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.quantize import (QuantSpec, QuantizedLinearParams,
                                 fold_bn_requant, quantize)


def im2col_hwc(x, fh: int, fw: int, stride: int = 1, padding: int = 0):
    """(N, H, W, C) -> (N, Ho, Wo, fh*fw*C); receptive field flattened in
    (dy, dx, c) order, matching the paper's HWC im2col buffer."""
    n, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding),
                        (0, 0)))
    ho = (h + 2 * padding - fh) // stride + 1
    wo = (w + 2 * padding - fw) // stride + 1
    cols = []
    for dy in range(fh):
        for dx in range(fw):
            sl = x[:, dy:dy + stride * ho:stride, dx:dx + stride * wo:stride]
            cols.append(sl)
    return jnp.concatenate(cols, axis=-1), ho, wo


@dataclasses.dataclass(frozen=True)
class QuantizedConvParams:
    """Deployable artifact for one quantized conv layer."""

    gemm: QuantizedLinearParams   # packed (fh*fw*cin -> cout) GEMM
    fh: int
    fw: int
    stride: int
    padding: int
    cin: int
    cout: int
    # fused implicit-GEMM layout: per-tap Cin padded to cin_pad, tap-major
    # K = fh*fw*cin_pad, packed chunk-planar along K.
    w_packed_fused: jnp.ndarray = None
    cin_pad: int = 0
    # filter groups (grouped/depthwise conv: cin is the *per-group* channel
    # count, cout the total). No registered backend runs groups > 1 today —
    # the registry rejects such params cleanly (see repro.kernels.api) and
    # repro.vision.layers.QDepthwiseConv2D lowers depthwise onto the
    # supported ops (per-group qconv, or block-diagonal im2col + qdot).
    groups: int = 1


def quantize_conv(w, spec_w: QuantSpec, bn_scale, bn_bias,
                  spec_x: QuantSpec, spec_y: QuantSpec,
                  stride: int = 1, padding: int = 1) -> QuantizedConvParams:
    """w: (fh, fw, cin, cout) real weights -> packed integer artifact.

    Builds both weight layouts from one quantization pass so the fused and
    fallback routes consume bit-identical integer weights.
    """
    fh, fw, cin, cout = w.shape
    w_hat = quantize(w.reshape(fh * fw * cin, cout), spec_w)
    k_logical = w_hat.shape[0]
    # im2col layout: one tail pad on the flat K axis
    w_flat = packing.pad_to_chunk(w_hat, axis=0)
    w_packed = packing.pack(w_flat, spec_w.bits, axis=0)
    # fused layout: pad each tap's channel run independently
    cin_pad = packing.padded_size(cin)
    w_tap = w_hat.reshape(fh * fw, cin, cout)
    w_tap = jnp.pad(w_tap, ((0, 0), (0, cin_pad - cin), (0, 0)))
    w_packed_fused = packing.pack(
        w_tap.reshape(fh * fw * cin_pad, cout), spec_w.bits, axis=0)
    kappa, lam, m, d = fold_bn_requant(
        spec_w.eps, spec_x.eps, spec_y.eps, bn_scale, bn_bias, spec_y.bits)
    gemm = QuantizedLinearParams(
        w_packed=w_packed, w_bits=spec_w.bits, a_bits=spec_x.bits,
        a_signed=spec_x.signed, kappa=kappa, lam=lam, m=m, d=d,
        out_bits=spec_y.bits, k_logical=k_logical)
    return QuantizedConvParams(gemm=gemm, fh=fh, fw=fw, stride=stride,
                               padding=padding, cin=cin, cout=cout,
                               w_packed_fused=w_packed_fused,
                               cin_pad=cin_pad)


def qconv2d_apply(params: QuantizedConvParams, x_hat, *,
                  backend: Optional[str] = None,
                  block: Optional[tuple] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: Optional[bool] = None):
    """x_hat: (N, H, W, Cin) int8 integer images -> (N, Ho, Wo, Cout) int8.

    Thin compat wrapper over `repro.kernels.api.qconv`; prefer calling
    that directly. ``backend`` selects a registered conv backend (block =
    (bho, bn) conv tile override for the fused kernel); ``use_kernel``/
    ``interpret`` are deprecated aliases mapped by
    `api.resolve_legacy_backend`.
    """
    from repro.kernels import api
    from repro.obs import trace as obs

    backend = api.resolve_legacy_backend(backend, use_kernel, interpret)
    with obs.span("qconv2d_apply", cat="compat",
                  legacy=use_kernel is not None or interpret is not None):
        return api.qconv(params, x_hat, backend=backend, block=block)
