from repro.kernels.qconv.ops import (im2col_hwc, quantize_conv,
                                     qconv2d_apply, QuantizedConvParams)
from repro.kernels.qconv.kernel import qconv2d_fused
from repro.kernels.qconv.ref import qconv2d_ref
from repro.kernels.common import conv_default_block
from repro.kernels.api import qconv
