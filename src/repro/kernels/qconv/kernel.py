"""Fused implicit-GEMM quantized conv — the PULP-NN execution model in one
Pallas kernel (paper §III-C; PULP-NN, arXiv:1908.11263).

PULP-NN convolves by interleaving an im2col of each output tile's receptive
fields into the NN register file with the MatMul + BN + QNT/ACT pipeline,
so the loads ride behind the MACs (Mac&Load) and no HBM-resident im2col
tensor ever exists. This kernel reproduces that structure on TPU:

  * the packed HWC input image is the only activation tensor in HBM;
  * per grid step the kernel *gathers* the receptive fields of a
    (bho output rows x Wo columns) tile directly out of the image block —
    one strided slice per filter tap (dy, dx) — into a VMEM scratch buffer
    that plays the NN-RF/im2col-buffer role;
  * the planar sub-byte dot product (repro.kernels.common.matmul_planes)
    then contracts the whole fh*fw*Cin_pad axis against the packed weight
    panel on the MXU, and the eq.(3)/(4) integer BN + requant epilogue is
    applied before the tile is written back.

Because the gather happens between pipelined MXU invocations of adjacent
grid steps, the Pallas grid pipeliner overlaps it with compute exactly the
way Mac&Load hides the pointer-walk loads of the RISC-V core.

``pipeline='double_buffer'`` makes that overlap explicit *inside* one grid
step (the Mac&Load analogue at tap granularity): the packed image stays in
HBM, the kernel owns two VMEM patch slots, and while tap t's per-tap
partial dot runs on the MXU, tap t+1's receptive-field patch DMA is
already in flight. The contraction becomes a sum of per-tap partial dots
— integer accumulation is order-invariant, so the result is bit-exact
against the one-pass 'off' mode and the eager oracle
(tests/test_kernel_pipeline.py).

Layout: the implicit GEMM is (N*Ho*Wo, fh*fw*Cin_pad) @ (fh*fw*Cin_pad,
Cout). Cin is padded per-tap to a CHUNK multiple so every tap's channel
run is chunk-planar packable on its own (zero padding == zero MACs); the
weight panel uses the matching per-tap layout built by
`quantize_conv` (`w_packed_fused`). The grid is (N, ceil(Ho/bho),
Cout_pad/bn) — each step owns its full contraction; the cout dim is
innermost and 'arbitrary' so the gathered scratch is reused across cout
panels instead of re-gathered.

Sizing: the whole packed image is one VMEM block (IoT-scale images — the
paper's layers are 16x16/32x32 — fit trivially); `conv_default_block`
checks the budget and raises for images that would not fit, in which case
the HBM im2col fallback (the `xla` backend of `repro.kernels.api.qconv`)
applies.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.kernels.common import (EPILOGUE_DTYPES, apply_epilogue,
                                  check_pipeline, compiler_params,
                                  conv_default_block, matmul_planes,
                                  round_up)


def _qconv_kernel(x_ref, w_ref, kappa_ref, lam_ref, m_ref, o_ref, col_ref,
                  *, fh: int, fw: int, stride: int, bho: int, wo: int,
                  cp: int, a_bits: int, a_signed: bool, w_bits: int,
                  d: int, out_bits: int, epilogue: str, scale: float):
    """One grid step: implicit-GEMM for (bho x wo) output pixels.

    x_ref:   (Hp, Wp, cp) whole packed image (cp = cin_pad/pf_a; batch dim
             squeezed by the BlockSpec).
    w_ref:   (fh*fw*cin_pad/pf_w, bn) packed weight panel, tap-major K.
    col_ref: (bho*wo, fh*fw*cp) VMEM scratch — the NN-RF/im2col buffer.
    o_ref:   (bho, wo, bn) output tile (batch dim squeezed).
    """
    i = pl.program_id(1)
    r0 = i * bho * stride  # first input row of this tile's receptive field
    rows_span = (bho - 1) * stride + 1
    cols_span = (wo - 1) * stride + 1

    # im2col gather: one strided slice per filter tap, written to the
    # tap's chunk-aligned column run of the scratch buffer. The scratch
    # depends only on (b, i); with the cout dim innermost ('arbitrary', so
    # the scratch persists across j steps) the gather runs once per output
    # tile, not once per cout panel.
    @pl.when(pl.program_id(2) == 0)
    def _gather():
        for dy in range(fh):
            for dx in range(fw):
                patch = pl.load(
                    x_ref,
                    (pl.dslice(r0 + dy, rows_span),
                     pl.dslice(dx, cols_span), slice(None)))
                patch = patch[::stride, ::stride]      # (bho, wo, cp)
                t = dy * fw + dx
                col_ref[:, t * cp:(t + 1) * cp] = patch.reshape(
                    bho * wo, cp)

    # MatMul + BN + QNT/ACT on the gathered tile (full K, one pass).
    acc = matmul_planes(col_ref[...], w_ref[...], a_bits, a_signed, w_bits)
    y = apply_epilogue(
        acc, kappa_ref[...], lam_ref[...], m_ref[...],
        d=d, out_bits=out_bits, epilogue=epilogue, scale=scale,
        out_dtype=o_ref.dtype)
    o_ref[...] = y.reshape(bho, wo, -1)


def _qconv_kernel_db(x_hbm, w_ref, kappa_ref, lam_ref, m_ref, o_ref,
                     buf, sems, *, fh: int, fw: int, stride: int, bho: int,
                     wo: int, cp: int, kpt: int, a_bits: int,
                     a_signed: bool, w_bits: int, d: int, out_bits: int,
                     epilogue: str, scale: float):
    """Double-buffered tap gather: per filter tap, the next tap's patch
    DMA overlaps the current tap's partial sub-byte dot.

    x_hbm: (N, Hp, Wp, cp) whole packed image, resident in HBM.
    buf:   (2, rows_span, cols_span, cp) int8 patch slots.
    kpt:   packed weight rows per tap (cin_pad / pf_w); tap t's panel rows
           are w_ref[t*kpt:(t+1)*kpt] (tap-major K, static slices).
    """
    b = pl.program_id(0)
    i = pl.program_id(1)
    r0 = i * bho * stride
    rows_span = (bho - 1) * stride + 1
    cols_span = (wo - 1) * stride + 1
    taps = fh * fw

    def tap_dma(slot, t):
        dy, dx = divmod(t, fw)
        return pltpu.make_async_copy(
            x_hbm.at[b, pl.dslice(r0 + dy, rows_span),
                     pl.dslice(dx, cols_span), slice(None)],
            buf.at[slot], sems.at[slot])

    tap_dma(0, 0).start()
    acc = jnp.zeros((bho * wo, o_ref.shape[-1]), jnp.int32)
    # static Python loop: taps are compile-time, so slot indices and the
    # per-tap weight-panel slices stay static while the DMA of tap t+1
    # rides behind tap t's MXU contraction
    for t in range(taps):
        if t + 1 < taps:
            tap_dma((t + 1) % 2, t + 1).start()
        tap_dma(t % 2, t).wait()
        patch = buf[t % 2][::stride, ::stride]          # (bho, wo, cp)
        acc += matmul_planes(patch.reshape(bho * wo, cp),
                             w_ref[t * kpt:(t + 1) * kpt, :],
                             a_bits, a_signed, w_bits)
    y = apply_epilogue(
        acc, kappa_ref[...], lam_ref[...], m_ref[...],
        d=d, out_bits=out_bits, epilogue=epilogue, scale=scale,
        out_dtype=o_ref.dtype)
    o_ref[...] = y.reshape(bho, wo, -1)


def qconv2d_fused(x_hat, w_packed_fused, kappa, lam, m_mul, *,
                  fh: int, fw: int, stride: int, padding: int,
                  cin_pad: int, cout: int,
                  a_bits: int, a_signed: bool, w_bits: int,
                  d: int, out_bits: int, epilogue: str = "int",
                  scale: float = 1.0,
                  block: Optional[tuple] = None,
                  out_dtype=None,
                  pipeline: str = "off",
                  interpret: bool = False):
    """Fused implicit-GEMM conv on integer images.

    x_hat: (N, H, W, Cin) int8 integer images (unpacked). Spatial and
    channel padding plus sub-byte packing happen here; the Pallas kernel
    sees only the packed image. w_packed_fused is the per-tap-padded
    packed weight panel from `quantize_conv` (K = fh*fw*cin_pad,
    tap-major). ``pipeline`` selects the execution mode (module
    docstring): 'off' gathers the whole receptive field into the im2col
    scratch once per tile, 'double_buffer' keeps the image in HBM and
    double-buffers the per-tap patch copies behind per-tap partial dots.
    Returns (N, Ho, Wo, Cout).
    """
    check_pipeline(pipeline)
    n, h, w_, cin = x_hat.shape
    assert cin <= cin_pad and cin_pad % packing.CHUNK == 0, (cin, cin_pad)
    ho = (h + 2 * padding - fh) // stride + 1
    wo = (w_ + 2 * padding - fw) // stride + 1
    assert ho > 0 and wo > 0, (ho, wo)
    pf_a = packing.pack_factor(a_bits)
    pf_w = packing.pack_factor(w_bits)
    cp = cin_pad // pf_a
    kp = fh * fw * cin_pad // pf_w
    assert w_packed_fused.shape[0] == kp, (w_packed_fused.shape, kp)

    if block is None:
        block = conv_default_block(n, ho, wo, cout, fh, fw, cin_pad,
                                   stride, a_bits, w_bits)
    bho, bn = block
    bho = min(bho, ho)
    n_ho = -(-ho // bho)
    ho_pad = n_ho * bho

    # Spatial pad: `padding` zeros on top/left, and enough rows/cols below
    # so even the ragged last row tile's receptive field stays in bounds
    # (the extra rows are zeros; their outputs are sliced off).
    hp = max(h + 2 * padding, (ho_pad - 1) * stride + fh)
    wp = max(w_ + 2 * padding, (wo - 1) * stride + fw)
    x = jnp.pad(x_hat, ((0, 0),
                        (padding, hp - h - padding),
                        (padding, wp - w_ - padding),
                        (0, cin_pad - cin)))
    xp = packing.pack(x, a_bits, axis=-1)  # (N, hp, wp, cp)

    cout_pad = round_up(cout, bn)
    wpk = jnp.pad(w_packed_fused, ((0, 0), (0, cout_pad - cout)))
    kappa2 = jnp.pad(kappa.reshape(1, -1), ((0, 0), (0, cout_pad - cout)))
    lam2 = jnp.pad(lam.reshape(1, -1), ((0, 0), (0, cout_pad - cout)))
    mm2 = jnp.pad(m_mul.reshape(1, -1), ((0, 0), (0, cout_pad - cout)))

    if out_dtype is None:
        out_dtype = EPILOGUE_DTYPES[epilogue]

    grid = (n, n_ho, cout_pad // bn)
    if pipeline == "double_buffer":
        rows_span = (bho - 1) * stride + 1
        cols_span = (wo - 1) * stride + 1
        kernel = functools.partial(
            _qconv_kernel_db, fh=fh, fw=fw, stride=stride, bho=bho, wo=wo,
            cp=cp, kpt=cin_pad // pf_w, a_bits=a_bits, a_signed=a_signed,
            w_bits=w_bits, d=d, out_bits=out_bits, epilogue=epilogue,
            scale=scale)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((kp, bn), lambda b, i, j: (0, j)),
                pl.BlockSpec((1, bn), lambda b, i, j: (0, j)),
                pl.BlockSpec((1, bn), lambda b, i, j: (0, j)),
                pl.BlockSpec((1, bn), lambda b, i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((None, bho, wo, bn),
                                   lambda b, i, j: (b, i, 0, j)),
            out_shape=jax.ShapeDtypeStruct((n, ho_pad, wo, cout_pad),
                                           out_dtype),
            scratch_shapes=[
                pltpu.VMEM((2, rows_span, cols_span, cp), jnp.int8),
                pltpu.SemaphoreType.DMA((2,)),
            ],
            compiler_params=compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(xp, wpk, kappa2, lam2, mm2)
        return out[:, :ho, :, :cout]

    kernel = functools.partial(
        _qconv_kernel, fh=fh, fw=fw, stride=stride, bho=bho, wo=wo, cp=cp,
        a_bits=a_bits, a_signed=a_signed, w_bits=w_bits, d=d,
        out_bits=out_bits, epilogue=epilogue, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, hp, wp, cp), lambda b, i, j: (b, 0, 0, 0)),
            pl.BlockSpec((kp, bn), lambda b, i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda b, i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda b, i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda b, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((None, bho, wo, bn),
                               lambda b, i, j: (b, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, ho_pad, wo, cout_pad), out_dtype),
        scratch_shapes=[pltpu.VMEM((bho * wo, fh * fw * cp), jnp.int8)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wpk, kappa2, lam2, mm2)
    return out[:, :ho, :, :cout]
