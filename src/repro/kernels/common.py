"""Shared machinery for the packed sub-byte Pallas kernels.

Both integer kernels (qmatmul: packed GEMM, qconv: fused implicit-GEMM
conv) run the same per-tile pipeline from the paper:

    unpack(W, X) -> int8        (nibble/crumb SIMD operands, Table II)
    int8 x int8 -> int32 MXU    (pv.sdotp: sum-of-dot-product, eq. 2)
    kappa*acc + lambda          (integer batch-norm, eq. 3)
    (m * .) >> d, clip          (QNT/ACT, eq. 4)  [epilogue='int']

This module holds the pieces they share: the chunk-planar plane splitter
(`subsplit`), the planar sub-byte dot product (`matmul_planes`), the three
epilogues (`apply_epilogue`, int / dequant / raw), and block-shape
selection for both the GEMM grid (`default_block`) and the conv grid
(`conv_default_block`).

Field extraction is elementwise (shift+mask on int8 containers), so a
plane of a packed block keeps the block's shape; planes of X pair
one-to-one with planes of W because both sides use the same chunk-planar
logical K order and integer accumulation is order-invariant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.core.quantize import requantize_shift

# int8 MXU-friendly minimum tile: (32, 128); accumulate in int32.
LANE = 128
SUBLANE_I8 = 32

EPILOGUES = ("int", "dequant", "raw")
EPILOGUE_DTYPES = {"int": jnp.int8, "dequant": jnp.bfloat16, "raw": jnp.int32}

# Software-pipeline execution modes for the Pallas kernels — the Mac&Load
# analogue knob. 'off' leans on the pallas_call grid pipeliner alone;
# 'double_buffer' keeps the packed operands in HBM and issues manual
# double-buffered async copies so the next K tile's (or receptive-field
# tap's) DMA overlaps the current tile's unpack+dot explicitly.
PIPELINE_MODES = ("off", "double_buffer")


def check_pipeline(mode: str) -> str:
    if mode not in PIPELINE_MODES:
        raise ValueError(
            f"unknown pipeline mode {mode!r}; expected one of "
            f"{PIPELINE_MODES}")
    return mode

# jax 0.4.x names the TPU compiler-params struct TPUCompilerParams; newer
# releases renamed it CompilerParams. Resolve once here so every kernel
# works against either.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def compiler_params(**kwargs):
    return _COMPILER_PARAMS(**kwargs)


def round_up(x: int, mult: int) -> int:
    return x + (-x) % mult


def subsplit(planes, factor, axis):
    """Split coarse chunk-planes into `factor`-finer planes along `axis`.

    A plane of a pf-packed operand covers, per chunk, a contiguous logical
    run of R = CHUNK // pf elements; the finer layout needs runs of
    R // factor. Chunk order is shared, so this is a pure static reshape.
    Fine plane q = p_coarse * factor + f.
    """
    if factor == 1:
        return planes
    pf_coarse = len(planes)
    run = packing.CHUNK // pf_coarse
    fine_run = run // factor
    out = []
    for p in planes:
        if axis == 0:
            k, n = p.shape
            q = p.reshape(k // run, factor, fine_run, n)
            out.extend(q[:, f].reshape(k // factor, n) for f in range(factor))
        else:
            m, k = p.shape
            q = p.reshape(m, k // run, factor, fine_run)
            out.extend(q[:, :, f].reshape(m, k // factor)
                       for f in range(factor))
    return out


def matmul_planes(x_block, w_block, a_bits, a_signed, w_bits):
    """Planar sub-byte dot product -> (bm, bn) int32 partial sum.

    x_block: (bm, bk/pf_a) packed containers, K along axis 1.
    w_block: (bk/pf_w, bn) packed containers, K along axis 0.
    Both sides must share the chunk-planar logical K order.
    """
    pf_a = packing.pack_factor(a_bits)
    pf_w = packing.pack_factor(w_bits)
    x_planes = packing.unpack_planes(x_block, a_bits, a_signed)
    w_planes = packing.unpack_planes(w_block, w_bits, True)  # weights signed

    pf = max(pf_a, pf_w)
    x_planes = subsplit(x_planes, pf // pf_a, axis=1)
    w_planes = subsplit(w_planes, pf // pf_w, axis=0)

    acc = None
    for xp, wp in zip(x_planes, w_planes):
        part = jax.lax.dot_general(
            xp, wp, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = part if acc is None else acc + part
    return acc


def apply_epilogue(acc, kappa, lam, m_mul, *, d: int, out_bits: int,
                   epilogue: str, scale: float, out_dtype):
    """Fused epilogue on an int32 accumulator tile.

    'int':     eq.(3) integer BN (per out-channel) then eq.(4) requant+clip.
    'dequant': float rescale (QAT-style inspection path).
    'raw':     int32 accumulators, no epilogue.
    kappa/lam/m_mul broadcast against acc along the lane (out-channel) dim.
    """
    if epilogue == "int":
        phi_p = acc * kappa + lam
        y = requantize_shift(phi_p, m_mul, d)
        hi = packing.int_range(out_bits, False)[1]
        return jnp.clip(y, 0, hi).astype(out_dtype)
    if epilogue == "dequant":
        return (acc.astype(jnp.float32) * scale).astype(out_dtype)
    return acc.astype(out_dtype)  # 'raw'


def gemm_working_set(bm, bn, bk, a_bits, w_bits) -> int:
    """VMEM bytes a (bm, bn, bk) GEMM tile needs with every copy
    double-buffered.

    Counts 2x residency for *all* pipelined blocks — the packed activation
    and weight K tiles (grid pipeliner in 'off' mode, the manual DMA slots
    in 'double_buffer' mode: same two-buffer footprint either way), the
    output tile, and the three epilogue-parameter blocks — plus the
    single int32 accumulator scratch that persists across K steps. The
    pre-fix check under-counted (single-buffered out block, no epilogue
    params), so an autotuned pipelined tile at the budget edge could
    overflow VMEM.
    """
    pf_a, pf_w = packing.pack_factor(a_bits), packing.pack_factor(w_bits)
    x_b = bm * (bk // pf_a)
    w_b = (bk // pf_w) * bn
    params = 3 * bn * 4                # kappa/lam/m blocks
    out = bm * bn * 4                  # out tile (<= int32)
    acc = bm * bn * 4                  # int32 accumulator scratch
    return 2 * (x_b + w_b + params + out) + acc


def default_block(m, n, k, a_bits, w_bits,
                  vmem_budget: int = 8 * 1024 * 1024):
    """Pick GEMM (bm, bn, bk): MXU-aligned, chunk-aligned, VMEM-bounded.

    The paper's 4x2 -> 4x4 register-tiling exploration becomes this block
    shape selection; benchmarks/fig8 measures the ladder. The fit check
    (`gemm_working_set`) counts both buffers of every double-buffered
    copy, so the same tile is safe in either pipeline mode.
    """
    def align(v, unit):
        return max(unit, (v // unit) * unit)

    bm = align(min(m, 256), SUBLANE_I8)
    bn = align(min(n, 512), LANE)
    bk = align(min(k, 1024), packing.CHUNK)

    def fits(bm, bn, bk):
        return gemm_working_set(bm, bn, bk, a_bits, w_bits) <= vmem_budget

    while not fits(bm, bn, bk) and bk > packing.CHUNK:
        bk = align(bk // 2, packing.CHUNK)
    while not fits(bm, bn, bk) and bn > LANE:
        bn = align(bn // 2, LANE)
    while not fits(bm, bn, bk) and bm > SUBLANE_I8:
        bm = align(bm // 2, SUBLANE_I8)
    return bm, bn, bk


def segmented_bk(k_pad: int, target: int) -> int:
    """Largest CHUNK-multiple divisor of ``k_pad`` that is <= ``target``.

    The mixed-operand kernel loops K inside the grid step with manual DMA
    at per-width static sizes, so its K tile must divide the padded
    contraction exactly (no ragged tail inside the kernel — raggedness is
    handled by container zero-padding at the wrapper).
    """
    if k_pad % packing.CHUNK:
        raise ValueError(f"k_pad={k_pad} not a CHUNK multiple")
    c = k_pad // packing.CHUNK
    best = 1
    for t in range(1, c + 1):
        if c % t == 0 and t * packing.CHUNK <= target:
            best = t
    return best * packing.CHUNK


def segmented_working_set(bm, k_pad, bk, a_bits, widths) -> int:
    """VMEM bytes of one mixed-operand GEMM tile.

    The activation block holds the full packed K row panel (K loops inside
    the kernel); the weight side is two manual-DMA slots sized for the
    widest width present (widest => most container bytes per K tile);
    epilogue params and the out tile are grid-pipelined (2x); the int32
    accumulator persists across the K loop.
    """
    pf_a = packing.pack_factor(a_bits)
    pf_min = min(packing.pack_factor(b) for b in widths)
    x_b = bm * (k_pad // pf_a)
    w_slots = 2 * (bk // pf_min) * LANE
    params = 3 * LANE * 4
    out = bm * LANE * 4
    acc = bm * LANE * 4
    return 2 * (x_b + params + out) + w_slots + acc


def segmented_default_block(m, k_pad, a_bits, widths,
                            vmem_budget: int = 8 * 1024 * 1024):
    """Pick (bm, bk) for the mixed-operand kernel (bn is pinned to LANE:
    one N tile == one CHUNK column panel, so a tile never straddles a
    segment boundary)."""
    def align(v, unit):
        return max(unit, (v // unit) * unit)

    bm = align(min(m, 256), SUBLANE_I8)
    bk = segmented_bk(k_pad, min(k_pad, 1024))

    def fits(bm, bk):
        return segmented_working_set(
            bm, k_pad, bk, a_bits, widths) <= vmem_budget

    while not fits(bm, bk) and bk > packing.CHUNK:
        bk = segmented_bk(k_pad, bk // 2)
    while not fits(bm, bk) and bm > SUBLANE_I8:
        bm //= 2
    return bm, bk


def conv_working_set(bho, bn, *, ho, wo, cout, fh, fw, cin_pad, stride,
                     a_bits, w_bits):
    """VMEM bytes the fused conv kernel needs for a (bho, bn) tile.

    Counts the double-buffered pipeline blocks (full packed image, weight
    panel, epilogue params, output tile) plus the single-buffered im2col
    scratch and the int32 accumulator. Uses a safe upper bound for the
    padded image extent (the wrapper pads rows so every tile's receptive
    field is in-bounds).
    """
    pf_a = packing.pack_factor(a_bits)
    pf_w = packing.pack_factor(w_bits)
    cp = cin_pad // pf_a
    kp = fh * fw * cin_pad // pf_w
    n_tiles = -(-ho // bho)
    hp = n_tiles * bho * stride + fh          # >= (ho_pad-1)*s + fh
    wp = wo * stride + fw                     # >= (wo-1)*s + fw
    bm = bho * wo
    img = hp * wp * cp                        # packed int8 image block
    w_b = kp * bn                             # packed weight panel
    params = 3 * bn * 4                       # kappa/lam/m blocks
    out = bm * bn * 4                         # out tile (<= int32)
    col = bm * fh * fw * cp                   # im2col VMEM scratch (NN-RF)
    acc = bm * bn * 4                         # int32 accumulator
    return 2 * (img + w_b + params + out) + col + acc


def conv_default_block(n, ho, wo, cout, fh, fw, cin_pad, stride,
                       a_bits, w_bits, vmem_budget: int = 8 * 1024 * 1024):
    """Pick the fused conv tile (bho, bn): the M dim of the implicit GEMM
    is the flattened output-pixel axis N*Ho*Wo, tiled as (batch image) x
    (bho output rows x all Wo columns); the N dim is Cout tiled by bn.

    Invariants (property-tested): bn is a LANE multiple, the per-tap
    contraction run cin_pad is a CHUNK multiple (so every tap of the
    im2col scratch stays chunk-planar aligned), ceil(ho/bho) tiles cover a
    ragged Ho, and the whole working set fits `vmem_budget`.
    """
    if cin_pad % packing.CHUNK:
        raise ValueError(f"cin_pad={cin_pad} not a CHUNK multiple")
    bn = max(LANE, min(round_up(cout, LANE), 4 * LANE))
    # target bm = bho*wo around 256 output pixels, at least one row
    bho = max(1, min(ho, 256 // max(wo, 1)))

    def fits(bho, bn):
        return conv_working_set(
            bho, bn, ho=ho, wo=wo, cout=cout, fh=fh, fw=fw,
            cin_pad=cin_pad, stride=stride, a_bits=a_bits,
            w_bits=w_bits) <= vmem_budget

    while not fits(bho, bn) and bho > 1:
        bho = max(1, bho // 2)
    while not fits(bho, bn) and bn > LANE:
        bn //= 2
    if not fits(bho, bn):
        raise ValueError(
            f"fused conv tile (bho=1, bn={LANE}) exceeds the VMEM budget "
            f"for image ho={ho} wo={wo} cin_pad={cin_pad}; use the im2col "
            f"fallback (backend='xla') for images this large")
    return bho, bn
