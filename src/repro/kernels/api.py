"""Unified quantized-op backend API: one registry, one entry point per op.

The paper's core contribution is *flexible* dispatch of sub-byte SIMD
dot-product kernels across precisions; PULP-NN makes that usable with a
kernel-library API where one entry point per op selects the backend. This
module is that layer for the TPU repro. Backends register under
``(op, name)`` for the ops ``qdot`` (packed sub-byte GEMM, eq. 2-4) and
``qconv`` (fused implicit-GEMM conv), each exposing

    supports(shape, a_bits, w_bits, platform) -> bool
    run(params, x, *, epilogue, scale, block) -> array

Registered backends:

  pallas            real Mosaic/TPU Pallas kernel (asserts a TPU platform —
                    no production call site can silently fall into
                    interpret mode again)
  pallas_interpret  the same kernel under the Pallas interpreter: the
                    correctness/tests/dry-run backend, selected explicitly
  xla               XLA-native unpack + int dot_general + fused epilogue —
                    the production lowering off-TPU and for shapes the
                    kernels reject
  eager_ref         the independent numpy oracles (tests/debugging)

Resolution order for the per-call backend: explicit ``backend=`` argument
-> ``REPRO_QBACKEND`` env override -> capability-ordered default
(``pallas`` where supported, i.e. on TPU, else ``xla``). Block shapes come
from the per-(shape, bits, backend) autotune cache (`repro.kernels.tune`),
falling back to the analytic `default_block`/`conv_default_block`.

**Pipeline modes (Mac&Load analogue).** The pallas-family backends take a
``pipeline`` mode (`repro.kernels.common.PIPELINE_MODES`): ``off`` leans
on the grid pipeliner, ``double_buffer`` issues manual two-slot DMA
prefetch so the next K tile's (qdot) / receptive-field tap's (qconv) copy
overlaps the current tile's unpack+dot. Resolution order: explicit
``pipeline=`` argument (or plan hint / plan-rule field) ->
``REPRO_QPIPELINE`` env override -> the measured autotune-cache winner
for this (op, shape, bits, backend) -> ``off``. The ``xla`` and
``eager_ref`` backends have no pipeline concept and ignore the mode, so
differential tests can force one mode suite-wide.

**Observability.** With ``REPRO_OBS=1`` (`repro.obs`), every resolution
records one structured dispatch event — requested backend/pipeline, plan
hint, env override, tune-cache hit/miss and winner, final choice with
per-field provenance — queryable via `repro.obs.dispatch_log()`, and
every entry-point call bumps the per-(op, bits, backend, pipeline)
MAC/byte counters and runs inside a ``cat='kernel'`` span. Disabled
(the default), the instrumentation is a single predicate per call.

**Cluster-parallel path (paper fig. 9).** Passing ``mesh=`` to
`qdot`/`qconv` (or calling `qdot_sharded`/`qconv_sharded` directly) runs
the op under `shard_map` on an N-device mesh — the JAX analog of the
paper's N-core PULP cluster. Packed weights are tensor-parallel over the
output-feature axis (each device owns a disjoint Cout slice, like a
cluster core writing its own output-channel group into TCDM), activations
are data-parallel over the batch axis. Because K stays unsharded, each
shard's int32 accumulation is complete and the eq. 3/4 epilogue (all
per-output-channel parameters) runs locally — the sharded path needs **no
psum** and is bit-exact vs the single-device backends. The inner backend
is resolved per *local shard shape* by the same registry rules;
``eager_ref`` is host-side numpy and is rejected under `shard_map`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.quantize import SegmentedLinearParams
from repro.kernels import tune
from repro.kernels.common import (PIPELINE_MODES, apply_epilogue,
                                  check_pipeline, round_up)
from repro.obs import counters as obs_counters
from repro.obs import env as obsenv
from repro.obs import trace as obs

# "qdot_mixed" is the fine-grain mixed-precision GEMM (segmented weight
# containers, per-tile unpack width — Nadalini et al. 2307.01056); qdot
# routes into it when params is a SegmentedLinearParams.
OPS = ("qdot", "qdot_mixed", "qconv")
ENV_VAR = "REPRO_QBACKEND"
ENV_PIPELINE = "REPRO_QPIPELINE"
# capability-ordered default resolution; backends not listed here (the
# interpreter, the numpy oracle) are only ever selected explicitly
DEFAULT_ORDER: Tuple[str, ...] = ("pallas", "xla")


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    op: str
    name: str
    supports: Callable  # (shape, a_bits, w_bits, platform) -> bool
    run: Callable       # (params, x, *, epilogue, scale, block, pipeline)
    doc: str = ""


_REGISTRY: Dict[Tuple[str, str], BackendSpec] = {}


def register(op: str, name: str, *, supports: Callable, run: Callable,
             doc: str = "", override: bool = False) -> BackendSpec:
    """Register a backend for ``op``; later kernels (fused-load qdot, GPU,
    2-bit crumb paths) add themselves here instead of another boolean.
    Re-registering an existing (op, name) raises unless ``override=True``
    — silent replacement of a production backend is never an accident
    worth allowing."""
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; ops: {OPS}")
    if not override and (op, name) in _REGISTRY:
        raise ValueError(
            f"backend {name!r} already registered for op {op!r}; pass "
            "override=True to replace it")
    spec = BackendSpec(op=op, name=name, supports=supports, run=run, doc=doc)
    _REGISTRY[(op, name)] = spec
    return spec


def backends(op: str) -> Tuple[str, ...]:
    """Registered backend names for ``op`` (sorted)."""
    return tuple(sorted(n for (o, n) in _REGISTRY if o == op))


def get(op: str, name: str) -> BackendSpec:
    spec = _REGISTRY.get((op, name))
    if spec is None:
        raise KeyError(
            f"no backend {name!r} registered for op {op!r}; "
            f"available: {list(backends(op))}")
    return spec


def platform() -> str:
    return jax.default_backend()


def resolve(op: str, shape, a_bits: int, w_bits: int, *,
            backend: Optional[str] = None) -> BackendSpec:
    """Pick the backend for one call.

    Explicit ``backend`` -> ``REPRO_QBACKEND`` env override ->
    capability-ordered default (first DEFAULT_ORDER entry whose
    ``supports`` accepts this shape/bits/platform).
    """
    requested = backend or obsenv.get(ENV_VAR) or None
    if requested:
        return get(op, requested)
    plat = platform()
    for name in DEFAULT_ORDER:
        spec = _REGISTRY.get((op, name))
        if spec is not None and spec.supports(shape, a_bits, w_bits, plat):
            return spec
    raise RuntimeError(
        f"no default backend supports op {op!r} shape {shape} "
        f"A{a_bits}W{w_bits} on {plat!r}; registered: {list(backends(op))}")


def default_backend(op: str, shape=None, a_bits: int = 8,
                    w_bits: int = 8) -> str:
    """Name the default resolution would pick (diagnostics/banners)."""
    if shape is None:
        shape = ((256, 1024, 1024) if op == "qdot"
                 else (1, 16, 16, 32, 3, 3, 1, 1, 64, 1))
    return resolve(op, shape, a_bits, w_bits).name


def registry_table() -> Tuple[Tuple[str, str, str], ...]:
    """(op, backend, doc) rows for docs/CLIs."""
    return tuple((op, name, _REGISTRY[(op, name)].doc)
                 for (op, name) in sorted(_REGISTRY))


def resolve_legacy_backend(backend: Optional[str],
                           use_kernel: Optional[bool],
                           interpret: Optional[bool]) -> Optional[str]:
    """Deprecation shim shared by the op compat wrappers
    (`qlinear_apply`, `qconv2d_apply`): map the pre-registry
    ``use_kernel``/``interpret`` booleans onto a backend name.

    True -> 'pallas_interpret' (the old default silently ran interpret
    mode), True + interpret=False -> 'pallas', False -> 'xla'. Passing
    both the new ``backend`` and a deprecated boolean is contradictory
    and raises.
    """
    if use_kernel is None and interpret is None:
        return backend
    if backend is not None:
        raise ValueError(
            "pass either backend= or the deprecated use_kernel=/"
            "interpret= booleans, not both")
    warnings.warn(
        "use_kernel=/interpret= are deprecated; pass backend="
        "'pallas'|'pallas_interpret'|'xla'|'eager_ref' instead "
        "(see repro.kernels.api)", DeprecationWarning, stacklevel=3)
    uk = True if use_kernel is None else use_kernel
    if not uk:
        return "xla"
    return "pallas" if interpret is False else "pallas_interpret"


# ------------------------------------------------------- shared XLA core ---

def xla_int_gemm(x_q, w_packed, *, w_bits: int, kappa=None, lam=None,
                 m_mul=None, d: int = 0, out_bits: int = 8,
                 epilogue: str = "int", scale=1.0, out_dtype=None):
    """The one shared XLA int-GEMM + epilogue implementation.

    x_q: (..., K_pad) int8 integer images (already on the a_bits grid);
    w_packed: (K_pad/pf_w, N) chunk-planar packed weights. Unpack lowers to
    XLA convert ops the TPU compiler fuses into the int dot. ``scale`` may
    be a scalar or per-channel (N,) array (dequant epilogue). Used by the
    ``xla`` qdot backend and by the nn dense int path — previously two
    divergent copies (`qmatmul_jnp` vs `nn/layers._int_matmul`).
    """
    w = packing.unpack(w_packed, w_bits, True, axis=0)
    acc = jax.lax.dot_general(
        x_q, w, (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    if out_dtype is None:
        out_dtype = {"int": jnp.int8, "dequant": jnp.bfloat16,
                     "raw": jnp.int32}[epilogue]
    return apply_epilogue(acc, kappa, lam, m_mul, d=d, out_bits=out_bits,
                          epilogue=epilogue, scale=scale,
                          out_dtype=out_dtype)


# ------------------------------------------------------------ qdot entry ---

def _flatten_lead(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pad_axis(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _resolve_call(op: str, shape, a_bits: int, w_bits: int, *,
                  backend: Optional[str], block: Optional[tuple],
                  pipeline: Optional[str], plan_hints: Optional[dict],
                  sharded: bool = False):
    """One-stop per-call resolution: merge plan hints, resolve the
    backend (explicit -> plan -> ``REPRO_QBACKEND`` -> capability
    default), look up the tuned (block, pipeline) with one cache probe,
    and — when observability is on — record the full decision with
    provenance in the dispatch log (`repro.obs.dispatch_log`).

    Pipeline: explicit -> plan -> ``REPRO_QPIPELINE`` -> tuned winner ->
    'off'. Block: explicit -> plan -> tuned winner -> None (the backend's
    analytic selector). Returns ``(spec, block, pipeline)``.
    """
    hints = plan_hints or {}
    explicit_backend, explicit_block = backend, block
    explicit_pipeline = pipeline
    backend = backend or hints.get("backend")
    block = block or hints.get("block")
    pipeline = pipeline or hints.get("pipeline")

    env_backend = obsenv.get(ENV_VAR) or None
    spec = resolve(op, shape, a_bits, w_bits, backend=backend)
    if sharded:
        _reject_host_backend(spec)
    entry = tune.get_entry(op, shape, a_bits, w_bits, spec.name)

    block_source = ("explicit" if explicit_block is not None
                    else "plan" if block is not None
                    else "tuned" if entry is not None else "analytic")
    if block is None and entry is not None:
        block = tuple(entry["block"])

    # env is only consulted (and therefore only validated) when nothing
    # higher-precedence decided — an explicit arg or plan hint must
    # shadow even a bogus REPRO_QPIPELINE value
    env_pipeline = (None if pipeline is not None
                    else obsenv.get(ENV_PIPELINE) or None)
    pipeline_source = ("explicit" if explicit_pipeline is not None
                      else "plan" if pipeline is not None
                      else "env" if env_pipeline is not None
                      else "tuned" if entry is not None else "default")
    pipeline = check_pipeline(
        pipeline or env_pipeline
        or (entry["pipeline"] if entry is not None else None) or "off")

    if obs.enabled():
        backend_source = ("explicit" if explicit_backend is not None
                          else "plan" if backend is not None
                          else "env" if env_backend is not None
                          else "default")
        obs.dispatch_event(
            op=op, shape=tuple(int(s) for s in shape),
            a_bits=int(a_bits), w_bits=int(w_bits),
            backend=spec.name, backend_source=backend_source,
            plan_backend=hints.get("backend"), env_backend=env_backend,
            block=None if block is None else tuple(int(b) for b in block),
            block_source=block_source,
            pipeline=pipeline, pipeline_source=pipeline_source,
            env_pipeline=env_pipeline,
            tune_cache_hit=entry is not None,
            tune_winner=None if entry is None else {
                "block": list(entry["block"]),
                "pipeline": entry["pipeline"], "us": entry["us"]},
            sharded=sharded)
    return spec, block, pipeline


def _run_counted(spec, op: str, shape, a_bits: int, w_bits: int,
                 pipeline: str, thunk, w_packed_bytes: Optional[int] = None):
    """Run the resolved backend. With observability on, bump the
    (op, bits, backend, pipeline) MAC/byte counters and wrap the run in
    a ``cat='kernel'`` span that blocks on the result so device time
    lands inside it; off, it's a bare call. ``w_packed_bytes`` overrides
    the uniform-container weight-byte estimate (segmented containers
    stream fewer bytes than a uniform buffer at the widest width)."""
    if not obs.enabled():
        return thunk()
    costs = obs_counters.record(op, shape, a_bits, w_bits,
                                backend=spec.name, pipeline=pipeline,
                                w_packed_bytes=w_packed_bytes)
    with obs.span(op, cat="kernel", backend=spec.name, pipeline=pipeline,
                  a_bits=int(a_bits), w_bits=int(w_bits),
                  shape=tuple(int(s) for s in shape),
                  macs=costs["macs"],
                  packed_bytes=costs["packed_bytes"]) as sp:
        return sp.sync(thunk())


def qdot(params, x_hat, *, epilogue: str = "int", scale=1.0,
         backend: Optional[str] = None, block: Optional[tuple] = None,
         pipeline: Optional[str] = None,
         plan_hints: Optional[dict] = None, mesh=None,
         dp_axis: str = "data", tp_axis: str = "model"):
    """Quantized dot: integer-image activations x packed weights.

    params: `QuantizedLinearParams` — or `SegmentedLinearParams`, which
    routes through the mixed-operand op ``qdot_mixed`` (per-segment
    weight widths, same backend names). x_hat: (..., K_logical) int8
    integer images (unpacked); padded to CHUNK and packed on the fly.
    Leading dims are flattened for the GEMM and restored on the output.
    With ``mesh=`` the call routes through `qdot_sharded`
    (cluster-parallel execution). ``pipeline`` selects the kernel
    execution mode (module docstring).
    """
    if mesh is not None:
        if isinstance(params, SegmentedLinearParams):
            raise NotImplementedError(
                "qdot(mesh=...) does not take SegmentedLinearParams yet: "
                "segment boundaries and the TP output-feature split would "
                "have to be co-aligned; shard per segment above the "
                "registry instead")
        return qdot_sharded(params, x_hat, mesh=mesh, dp_axis=dp_axis,
                            tp_axis=tp_axis, epilogue=epilogue, scale=scale,
                            backend=backend, block=block, pipeline=pipeline,
                            plan_hints=plan_hints)
    x2, lead = _flatten_lead(x_hat)
    x2 = packing.pad_to_chunk(x2, axis=-1)
    xp = packing.pack(x2, params.a_bits, axis=-1)
    out = qdot_packed(params, xp, epilogue=epilogue, scale=scale,
                      backend=backend, block=block, pipeline=pipeline,
                      plan_hints=plan_hints)
    return out.reshape(*lead, out.shape[-1])


def qdot_packed(params, x_packed, *, epilogue: str = "int", scale=1.0,
                backend: Optional[str] = None,
                block: Optional[tuple] = None,
                pipeline: Optional[str] = None,
                plan_hints: Optional[dict] = None):
    """`qdot` over already-packed activations (fused chains where the
    previous layer's epilogue emitted packed integer images).

    `SegmentedLinearParams` dispatches to the ``qdot_mixed`` registry op:
    same backend names, but the pallas kernel switches unpack width per
    N tile and the xla/eager backends loop segments. The resolution/tune
    key uses the widest segment width (containers at mixed widths share
    one cache row per widest width)."""
    if isinstance(params, SegmentedLinearParams):
        m = x_packed.shape[0]
        k = x_packed.shape[1] * packing.pack_factor(params.a_bits)
        n = params.segmap.n
        w_key = params.segmap.widths()[0]   # widest width present
        spec, block, pipeline = _resolve_call(
            "qdot_mixed", (m, k, n), params.a_bits, w_key,
            backend=backend, block=block, pipeline=pipeline,
            plan_hints=plan_hints)
        return _run_counted(
            spec, "qdot_mixed", (m, k, n), params.a_bits, w_key, pipeline,
            lambda: spec.run(params, x_packed, epilogue=epilogue,
                             scale=scale, block=block, pipeline=pipeline),
            w_packed_bytes=params.segmap.packed_bytes(params.k_logical))
    m = x_packed.shape[0]
    k = x_packed.shape[1] * packing.pack_factor(params.a_bits)
    n = params.w_packed.shape[1]
    spec, block, pipeline = _resolve_call(
        "qdot", (m, k, n), params.a_bits, params.w_bits, backend=backend,
        block=block, pipeline=pipeline, plan_hints=plan_hints)
    return _run_counted(
        spec, "qdot", (m, k, n), params.a_bits, params.w_bits, pipeline,
        lambda: spec.run(params, x_packed, epilogue=epilogue, scale=scale,
                         block=block, pipeline=pipeline))


# ----------------------------------------------------------- qconv entry ---

def _conv_shape(params, x_hat):
    """qconv shape key: (n, h, w, cin, fh, fw, stride, padding, cout,
    groups). ``groups`` (grouped/depthwise conv) rides at the tail so
    ``supports()`` can reject grouped geometry it cannot lower; helpers
    accept the legacy 9-tuple (groups=1) for hand-built keys."""
    n, h, w, cin = x_hat.shape
    return (n, h, w, cin, params.fh, params.fw, params.stride,
            params.padding, params.cout, getattr(params, "groups", 1))


def conv_shape_groups(shape) -> int:
    return int(shape[9]) if len(shape) > 9 else 1


def _check_grouped(params, spec, shape):
    """Explicit ``backend=`` bypasses capability resolution, so grouped
    params must be re-checked against ``supports`` here — running a
    grouped conv through an ungrouped lowering would silently contract
    the wrong K (mis-shaped output, no error)."""
    if conv_shape_groups(shape) == 1:
        return
    if not spec.supports(shape, params.gemm.a_bits, params.gemm.w_bits,
                         platform()):
        raise ValueError(
            f"qconv backend {spec.name!r} does not support grouped conv "
            f"(groups={params.groups}); lower depthwise/grouped layers via "
            "repro.vision.layers.QDepthwiseConv2D (per-group qconv or "
            "block-diagonal im2col + qdot)")


def qconv(params, x_hat, *, epilogue: str = "int", scale=1.0,
          backend: Optional[str] = None, block: Optional[tuple] = None,
          pipeline: Optional[str] = None,
          plan_hints: Optional[dict] = None, mesh=None,
          dp_axis: str = "data", tp_axis: str = "model"):
    """Quantized HWC conv: (N, H, W, Cin) int8 images -> (N, Ho, Wo, Cout).

    params: `QuantizedConvParams` (both weight layouts built by
    `quantize_conv`, so every backend consumes bit-identical integers).
    With ``mesh=`` the call routes through `qconv_sharded`. ``pipeline``
    selects the kernel execution mode (module docstring).
    """
    if mesh is not None:
        return qconv_sharded(params, x_hat, mesh=mesh, dp_axis=dp_axis,
                             tp_axis=tp_axis, epilogue=epilogue, scale=scale,
                             backend=backend, block=block, pipeline=pipeline,
                             plan_hints=plan_hints)
    shape = _conv_shape(params, x_hat)
    g = params.gemm
    spec, block, pipeline = _resolve_call(
        "qconv", shape, g.a_bits, g.w_bits, backend=backend, block=block,
        pipeline=pipeline, plan_hints=plan_hints)
    _check_grouped(params, spec, shape)
    return _run_counted(
        spec, "qconv", shape, g.a_bits, g.w_bits, pipeline,
        lambda: spec.run(params, x_hat, epilogue=epilogue, scale=scale,
                         block=block, pipeline=pipeline))


# ------------------------------------------------ cluster-parallel path ---

def _cluster_prologue(mesh, dp_axis, tp_axis):
    """(dp, tp, dp_spec_entry, tp_spec_entry) for a cluster call; absent
    axes act as size-1 / replicated so pure-DP and pure-TP meshes work."""
    from repro.parallel import sharding as shrules

    dp = shrules.cluster_axis_size(mesh, dp_axis)
    tp = shrules.cluster_axis_size(mesh, tp_axis)
    return dp, tp, shrules.axis_entry(mesh, dp_axis), \
        shrules.axis_entry(mesh, tp_axis)


def _reject_host_backend(spec):
    if spec.name == "eager_ref":
        raise ValueError(
            "backend 'eager_ref' is a host-side numpy oracle and cannot "
            "run under shard_map; run it on one device and compare against "
            "the sharded result instead (tests/test_cluster.py does)")
    return spec


def qdot_sharded(params, x_hat, *, mesh, dp_axis: str = "data",
                 tp_axis: str = "model", epilogue: str = "int", scale=1.0,
                 backend: Optional[str] = None,
                 block: Optional[tuple] = None,
                 pipeline: Optional[str] = None,
                 plan_hints: Optional[dict] = None):
    """`qdot` on an N-device mesh — the paper's N-core cluster (fig. 9).

    Packed weights + per-channel epilogue vectors are tensor-parallel over
    the output-feature axis N (``tp_axis``); activation rows are
    data-parallel over ``dp_axis`` (padded to a multiple, sliced back).
    K is never sharded, so each shard runs the full eq. 2-4 pipeline
    locally and the result is bit-exact vs single-device — no psum.
    The inner backend resolves on the *local* shard shape.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel import sharding as shrules

    dp, tp, dpe, tpe = _cluster_prologue(mesh, dp_axis, tp_axis)
    wspecs = shrules.packed_linear_specs(params, mesh, tp_axis=tp_axis)

    x2, lead = _flatten_lead(x_hat)
    m = x2.shape[0]
    x2 = _pad_axis(x2, dp, 0)
    n = params.w_packed.shape[1]
    k_pad = params.w_packed.shape[0] * packing.pack_factor(params.w_bits)
    m_loc, n_loc = x2.shape[0] // dp, n // tp
    spec, block, pipeline = _resolve_call(
        "qdot", (m_loc, k_pad, n_loc), params.a_bits, params.w_bits,
        backend=backend, block=block, pipeline=pipeline,
        plan_hints=plan_hints, sharded=True)
    per_n = np.ndim(scale) == 1  # per-channel dequant scale shards with N
    sc = jnp.asarray(scale)

    def local(xs, wp, kappa, lam, mm, s):
        p_loc = dataclasses.replace(params, w_packed=wp, kappa=kappa,
                                    lam=lam, m=mm)
        xp = packing.pack(packing.pad_to_chunk(xs, axis=-1),
                          params.a_bits, axis=-1)
        return spec.run(p_loc, xp, epilogue=epilogue, scale=s, block=block,
                        pipeline=pipeline)

    # counted at the *global* GEMM size (the shard-local per-device work
    # is global/dp/tp; the dispatch event above carries the local shape)
    out = _run_counted(
        spec, "qdot", (x2.shape[0], k_pad, n), params.a_bits,
        params.w_bits, pipeline,
        lambda: shard_map(
            local, mesh=mesh,
            in_specs=(P(dpe, None), wspecs["w_packed"], wspecs["kappa"],
                      wspecs["lam"], wspecs["m"],
                      P(tpe) if per_n else P()),
            out_specs=P(dpe, tpe), check_rep=False)(
            x2, params.w_packed, params.kappa, params.lam, params.m, sc))
    return out[:m].reshape(*lead, n)


def qconv_sharded(params, x_hat, *, mesh, dp_axis: str = "data",
                  tp_axis: str = "model", epilogue: str = "int", scale=1.0,
                  backend: Optional[str] = None,
                  block: Optional[tuple] = None,
                  pipeline: Optional[str] = None,
                  plan_hints: Optional[dict] = None):
    """`qconv` on an N-device mesh: images data-parallel over the batch
    dim (padded to a ``dp`` multiple, sliced back), both packed weight
    layouts + epilogue vectors tensor-parallel over Cout. Same psum-free
    bit-exactness argument as `qdot_sharded` — a device is a cluster core
    producing its own output-channel group.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel import sharding as shrules

    dp, tp, dpe, tpe = _cluster_prologue(mesh, dp_axis, tp_axis)
    wspecs = shrules.packed_conv_specs(params, mesh, tp_axis=tp_axis)

    nb = x_hat.shape[0]
    x = _pad_axis(x_hat, dp, 0)
    g = params.gemm
    cout_loc = params.cout // tp
    shape_loc = (x.shape[0] // dp, x.shape[1], x.shape[2], x.shape[3],
                 params.fh, params.fw, params.stride, params.padding,
                 cout_loc, getattr(params, "groups", 1))
    spec, block, pipeline = _resolve_call(
        "qconv", shape_loc, g.a_bits, g.w_bits, backend=backend,
        block=block, pipeline=pipeline, plan_hints=plan_hints,
        sharded=True)
    _check_grouped(params, spec, shape_loc)
    per_n = np.ndim(scale) == 1
    sc = jnp.asarray(scale)

    def local(xs, wpf, wp, kappa, lam, mm, s):
        g_loc = dataclasses.replace(g, w_packed=wp, kappa=kappa, lam=lam,
                                    m=mm)
        p_loc = dataclasses.replace(params, gemm=g_loc, w_packed_fused=wpf,
                                    cout=cout_loc)
        return spec.run(p_loc, xs, epilogue=epilogue, scale=s, block=block,
                        pipeline=pipeline)

    shape_glob = (x.shape[0], x.shape[1], x.shape[2], x.shape[3],
                  params.fh, params.fw, params.stride, params.padding,
                  params.cout, getattr(params, "groups", 1))
    out = _run_counted(
        spec, "qconv", shape_glob, g.a_bits, g.w_bits, pipeline,
        lambda: shard_map(
            local, mesh=mesh,
            in_specs=(P(dpe, None, None, None), wspecs["w_packed_fused"],
                      wspecs["gemm"]["w_packed"], wspecs["gemm"]["kappa"],
                      wspecs["gemm"]["lam"], wspecs["gemm"]["m"],
                      P(tpe) if per_n else P()),
            out_specs=P(dpe, None, None, tpe), check_rep=False)(
            x, params.w_packed_fused, g.w_packed, g.kappa, g.lam, g.m, sc))
    return out[:nb]


# -------------------------------------------------------- qdot backends ---

def _require_tpu(name: str):
    plat = platform()
    if plat != "tpu":
        raise RuntimeError(
            f"backend {name!r} requires a real TPU/Mosaic platform "
            f"(got {plat!r}); select 'pallas_interpret' explicitly for "
            "interpreter-mode runs, or 'xla' for the native lowering")


def _qdot_pallas(params, x_packed, *, epilogue, scale, block,
                 pipeline: str, interpret: bool):
    """Pad M/N to the block multiples the kernel picks, run the Pallas
    packed GEMM, slice back."""
    from repro.kernels.qmatmul.kernel import default_block, qmatmul_packed

    m = x_packed.shape[0]
    k = x_packed.shape[1] * packing.pack_factor(params.a_bits)
    n = params.w_packed.shape[1]
    bm, bn, bk = block or default_block(m, n, k, params.a_bits,
                                        params.w_bits)
    bm = min(bm, round_up(m, 32))
    xp = _pad_axis(x_packed, bm, 0)
    wp = _pad_axis(params.w_packed, bn, 1)
    kappa = _pad_axis(params.kappa, bn, 0)
    lam = _pad_axis(params.lam, bn, 0)
    mm = _pad_axis(params.m, bn, 0)
    out = qmatmul_packed(
        xp, wp, kappa, lam, mm, a_bits=params.a_bits,
        a_signed=params.a_signed, w_bits=params.w_bits, d=params.d,
        out_bits=params.out_bits, epilogue=epilogue, scale=scale,
        block=(bm, bn, bk), pipeline=pipeline, interpret=interpret)
    return out[:m, :n]


def _qdot_pallas_run(params, x_packed, *, epilogue, scale, block=None,
                     pipeline: str = "off"):
    _require_tpu("pallas")
    return _qdot_pallas(params, x_packed, epilogue=epilogue, scale=scale,
                        block=block, pipeline=pipeline, interpret=False)


def _qdot_interpret_run(params, x_packed, *, epilogue, scale, block=None,
                        pipeline: str = "off"):
    return _qdot_pallas(params, x_packed, epilogue=epilogue, scale=scale,
                        block=block, pipeline=pipeline, interpret=True)


def _qdot_xla_run(params, x_packed, *, epilogue, scale, block=None,
                  pipeline: str = "off"):
    del block, pipeline  # XLA picks its own tiling/pipelining
    x = packing.unpack(x_packed, params.a_bits, params.a_signed, axis=-1)
    return xla_int_gemm(
        x, params.w_packed, w_bits=params.w_bits, kappa=params.kappa,
        lam=params.lam, m_mul=params.m, d=params.d,
        out_bits=params.out_bits, epilogue=epilogue, scale=scale)


def _qdot_eager_run(params, x_packed, *, epilogue, scale, block=None,
                    pipeline: str = "off"):
    del block, pipeline
    from repro.kernels.qmatmul.ref import qmatmul_ref

    if np.ndim(scale) > 0:
        raise NotImplementedError("eager_ref qdot: scalar scale only")
    out = qmatmul_ref(
        np.asarray(x_packed), np.asarray(params.w_packed),
        np.asarray(params.kappa), np.asarray(params.lam),
        np.asarray(params.m), a_bits=params.a_bits,
        a_signed=params.a_signed, w_bits=params.w_bits, d=params.d,
        out_bits=params.out_bits, epilogue=epilogue, scale=float(scale))
    dtype = {"int": jnp.int8, "dequant": jnp.bfloat16,
             "raw": jnp.int32}[epilogue]
    return jnp.asarray(out).astype(dtype)


# -------------------------------------------------- qdot_mixed backends ---

def _qdot_mixed_pallas(params, x_packed, *, epilogue, scale, block,
                       pipeline: str, interpret: bool):
    """Mixed-operand Pallas path: zero-pad the ragged tail panel of the
    segmented container to a full CHUNK (`pad_segmented` — the artifact
    itself stays exact-bytes), pad M to the block multiple, run
    `qmatmul_segmented`, slice back."""
    from repro.kernels.common import LANE, segmented_default_block
    from repro.kernels.qmatmul.kernel import qmatmul_segmented

    if np.ndim(scale) > 0:
        raise NotImplementedError(
            "pallas qdot_mixed: scalar scale only (like the uniform "
            "kernel); use backend='xla' for per-channel dequant scales")
    m = x_packed.shape[0]
    k_pad = x_packed.shape[1] * packing.pack_factor(params.a_bits)
    n = params.segmap.n
    w_flat, segmap_p = packing.pad_segmented(
        params.w_flat, params.segmap, params.k_logical)
    if block is None:
        bm, bk = segmented_default_block(m, k_pad, params.a_bits,
                                         params.segmap.widths())
    else:
        bm, bk = block[0], block[2]
    bm = min(bm, round_up(m, 32))
    xp = _pad_axis(x_packed, bm, 0)
    kappa = _pad_axis(params.kappa, LANE, 0)
    lam = _pad_axis(params.lam, LANE, 0)
    mm = _pad_axis(params.m, LANE, 0)
    out = qmatmul_segmented(
        xp, w_flat, segmap_p, kappa, lam, mm, k_logical=params.k_logical,
        a_bits=params.a_bits, a_signed=params.a_signed, d=params.d,
        out_bits=params.out_bits, epilogue=epilogue, scale=scale,
        block=(bm, LANE, bk), pipeline=pipeline, interpret=interpret)
    return out[:m, :n]


def _qdot_mixed_pallas_run(params, x_packed, *, epilogue, scale, block=None,
                           pipeline: str = "off"):
    _require_tpu("pallas")
    return _qdot_mixed_pallas(params, x_packed, epilogue=epilogue,
                              scale=scale, block=block, pipeline=pipeline,
                              interpret=False)


def _qdot_mixed_interpret_run(params, x_packed, *, epilogue, scale,
                              block=None, pipeline: str = "off"):
    return _qdot_mixed_pallas(params, x_packed, epilogue=epilogue,
                              scale=scale, block=block, pipeline=pipeline,
                              interpret=True)


def _qdot_mixed_xla_run(params, x_packed, *, epilogue, scale, block=None,
                        pipeline: str = "off"):
    """Segment-looping XLA fallback: each run is a uniform container view
    (`segment_packed`), so each goes through `xla_int_gemm` with its own
    width and epilogue slice; outputs concatenate along N."""
    del block, pipeline
    x = packing.unpack(x_packed, params.a_bits, params.a_signed, axis=-1)
    outs = []
    for i, (s, e, b) in enumerate(params.segmap.runs):
        sp = params.segment_params(i)
        sc = scale if np.ndim(scale) == 0 else scale[..., s:e]
        outs.append(xla_int_gemm(
            x, sp.w_packed, w_bits=b, kappa=sp.kappa, lam=sp.lam,
            m_mul=sp.m, d=sp.d, out_bits=sp.out_bits, epilogue=epilogue,
            scale=sc))
    return jnp.concatenate(outs, axis=-1)


def _qdot_mixed_eager_run(params, x_packed, *, epilogue, scale, block=None,
                          pipeline: str = "off"):
    del block, pipeline
    from repro.kernels.qmatmul.ref import qmatmul_ref

    if np.ndim(scale) > 0:
        raise NotImplementedError("eager_ref qdot_mixed: scalar scale only")
    outs = []
    for i in range(len(params.segmap.runs)):
        sp = params.segment_params(i)
        outs.append(qmatmul_ref(
            np.asarray(x_packed), np.asarray(sp.w_packed),
            np.asarray(sp.kappa), np.asarray(sp.lam), np.asarray(sp.m),
            a_bits=sp.a_bits, a_signed=sp.a_signed, w_bits=sp.w_bits,
            d=sp.d, out_bits=sp.out_bits, epilogue=epilogue,
            scale=float(scale)))
    dtype = {"int": jnp.int8, "dequant": jnp.bfloat16,
             "raw": jnp.int32}[epilogue]
    return jnp.asarray(np.concatenate(outs, axis=-1)).astype(dtype)


# ------------------------------------------------------- qconv backends ---

def _conv_fits_vmem(shape, a_bits, w_bits) -> bool:
    from repro.kernels.common import conv_default_block

    if conv_shape_groups(shape) != 1:
        return False  # the fused kernel contracts the full fh*fw*cin axis
    n, h, w, cin, fh, fw, stride, padding, cout = shape[:9]
    ho = (h + 2 * padding - fh) // stride + 1
    wo = (w + 2 * padding - fw) // stride + 1
    if ho <= 0 or wo <= 0:
        return False
    try:
        conv_default_block(n, ho, wo, cout, fh, fw,
                           packing.padded_size(cin), stride, a_bits, w_bits)
        return True
    except ValueError:
        return False


def _qconv_fused(params, x_hat, *, epilogue, scale, block, pipeline: str,
                 interpret: bool):
    from repro.kernels.qconv.kernel import qconv2d_fused

    g = params.gemm
    return qconv2d_fused(
        x_hat, params.w_packed_fused, g.kappa, g.lam, g.m,
        fh=params.fh, fw=params.fw, stride=params.stride,
        padding=params.padding, cin_pad=params.cin_pad, cout=params.cout,
        a_bits=g.a_bits, a_signed=g.a_signed, w_bits=g.w_bits, d=g.d,
        out_bits=g.out_bits, epilogue=epilogue, scale=scale, block=block,
        pipeline=pipeline, interpret=interpret)


def _qconv_pallas_run(params, x_hat, *, epilogue, scale, block=None,
                      pipeline: str = "off"):
    _require_tpu("pallas")
    return _qconv_fused(params, x_hat, epilogue=epilogue, scale=scale,
                        block=block, pipeline=pipeline, interpret=False)


def _qconv_interpret_run(params, x_hat, *, epilogue, scale, block=None,
                         pipeline: str = "off"):
    return _qconv_fused(params, x_hat, epilogue=epilogue, scale=scale,
                        block=block, pipeline=pipeline, interpret=True)


def _qconv_xla_run(params, x_hat, *, epilogue, scale, block=None,
                   pipeline: str = "off"):
    del block, pipeline
    from repro.kernels.qconv.ops import im2col_hwc  # lazy: ops imports api

    cols, ho, wo = im2col_hwc(x_hat, params.fh, params.fw, params.stride,
                              params.padding)
    y = qdot(params.gemm, cols, epilogue=epilogue, scale=scale,
             backend="xla")
    return y.reshape(x_hat.shape[0], ho, wo, params.cout)


def _qconv_eager_run(params, x_hat, *, epilogue, scale, block=None,
                     pipeline: str = "off"):
    del block, pipeline
    from repro.kernels.qconv.ref import qconv2d_ref
    from repro.kernels.qmatmul.ref import unpack_np

    if epilogue != "int":
        raise NotImplementedError("eager_ref qconv: 'int' epilogue only")
    g = params.gemm
    w_flat = unpack_np(np.asarray(params.w_packed_fused), g.w_bits, True,
                       axis=0)
    w_tap = w_flat.reshape(params.fh * params.fw, params.cin_pad,
                           params.cout)[:, :params.cin, :]
    w_hat = w_tap.reshape(params.fh, params.fw, params.cin, params.cout)
    out = qconv2d_ref(np.asarray(x_hat), w_hat, np.asarray(g.kappa),
                      np.asarray(g.lam), np.asarray(g.m), g.d, g.out_bits,
                      stride=params.stride, padding=params.padding)
    return jnp.asarray(out)


# --------------------------------------------------------- registrations ---

def _on_tpu(shape, a_bits, w_bits, plat) -> bool:
    return plat == "tpu"


def _always(shape, a_bits, w_bits, plat) -> bool:
    return True


def _conv_ungrouped(shape, a_bits, w_bits, plat) -> bool:
    # every registered conv lowering contracts one full fh*fw*cin GEMM;
    # grouped/depthwise geometry must be lowered above the registry
    # (repro.vision.layers) until a grouped backend registers itself
    return conv_shape_groups(shape) == 1


register("qdot", "pallas", supports=_on_tpu, run=_qdot_pallas_run,
         doc="Mosaic packed sub-byte GEMM kernel (TPU only)")
register("qdot", "pallas_interpret", supports=_always,
         run=_qdot_interpret_run,
         doc="same kernel under the Pallas interpreter (tests/dry-runs)")
register("qdot", "xla", supports=_always, run=_qdot_xla_run,
         doc="XLA-native unpack + int dot_general + fused epilogue")
register("qdot", "eager_ref", supports=_always, run=_qdot_eager_run,
         doc="independent numpy oracle (bit-exactness baseline)")

register("qdot_mixed", "pallas", supports=_on_tpu,
         run=_qdot_mixed_pallas_run,
         doc="mixed-operand segmented GEMM kernel (per-tile unpack width)")
register("qdot_mixed", "pallas_interpret", supports=_always,
         run=_qdot_mixed_interpret_run,
         doc="mixed-operand kernel under the Pallas interpreter")
register("qdot_mixed", "xla", supports=_always, run=_qdot_mixed_xla_run,
         doc="segment-looping XLA fallback (uniform int GEMM per run)")
register("qdot_mixed", "eager_ref", supports=_always,
         run=_qdot_mixed_eager_run,
         doc="segment-looping numpy oracle (uniform ref GEMM per run)")

register("qconv", "pallas",
         supports=lambda s, a, w, p: p == "tpu" and _conv_fits_vmem(s, a, w),
         run=_qconv_pallas_run,
         doc="fused implicit-GEMM conv kernel (TPU only, VMEM-bounded)")
register("qconv", "pallas_interpret",
         supports=lambda s, a, w, p: _conv_fits_vmem(s, a, w),
         run=_qconv_interpret_run,
         doc="fused conv kernel under the Pallas interpreter")
register("qconv", "xla", supports=_conv_ungrouped, run=_qconv_xla_run,
         doc="XLA im2col + xla qdot (also the large-image fallback)")
register("qconv", "eager_ref", supports=_conv_ungrouped,
         run=_qconv_eager_run,
         doc="direct-convolution numpy oracle (no shared im2col path)")
