"""Fault-tolerant training runtime: checkpoint/restart loop, straggler
monitor, preemption handling, elastic restore.

The loop is deliberately dumb-robust (the production property that matters
at 1000+ nodes): every state transition goes through the atomic
checkpointer; any exception inside a step triggers restore-from-latest and
replay; SIGTERM (preemption notice) triggers a final sync checkpoint.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_step, restore)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0   # step > factor*median -> flagged


class StragglerMonitor:
    """Tracks step times; flags outliers. On real multi-host deployments
    the per-host step times come from a collective timeline; here the
    single-process step time stands in, and the mitigation hook is where a
    production deployment re-balances data shards / evicts the slow host.
    """

    def __init__(self, factor: float = 2.0, window: int = 50):
        self.factor = factor
        self.window = window
        self.times: list = []
        self.flags = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if dt > self.factor * med:
                self.flags += 1
                return True
        return False

    @property
    def median(self):
        return float(np.median(self.times)) if self.times else 0.0


class Trainer:
    def __init__(self, init_fn, step_fn, batch_iter, cfg: TrainerConfig,
                 state_shardings=None, mesh=None):
        self.init_fn = init_fn
        self.step_fn = step_fn
        self.batch_iter = batch_iter
        self.cfg = cfg
        self.state_shardings = state_shardings
        self.mesh = mesh
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.monitor = StragglerMonitor(cfg.straggler_factor)
        self.metrics_log: list = []
        self._preempted = False

    def _install_preemption_handler(self):
        def _h(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, _h)
        except ValueError:
            pass  # not main thread (tests)

    def _restore_or_init(self, key):
        step = latest_step(self.cfg.ckpt_dir)
        if step is not None:
            state, step = restore(self.cfg.ckpt_dir, step,
                                  shardings=self.state_shardings)
            return state, step
        return self.init_fn(key), 0

    def run(self, key):
        """Run to total_steps with restart-on-failure. Returns (state,
        metrics_log)."""
        self._install_preemption_handler()
        restarts = 0
        state, start = self._restore_or_init(key)
        step = start
        while step < self.cfg.total_steps:
            try:
                batch = next(self.batch_iter)
                t0 = time.time()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                slow = self.monitor.record(dt)
                step += 1
                rec = {k: float(v) for k, v in metrics.items()}
                rec.update(step=step, dt=dt, straggler=slow)
                self.metrics_log.append(rec)
                if step % self.cfg.ckpt_every == 0 or \
                        step == self.cfg.total_steps:
                    self.ckpt.save_async(step, state)
                if self._preempted:
                    self.ckpt.wait()
                    self.ckpt.save_async(step, state)
                    self.ckpt.wait()
                    break
            except (FloatingPointError, RuntimeError) as e:
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise
                # node failure / NaN blowup: restore and replay
                self.ckpt.wait()
                state, step = self._restore_or_init(key)
        self.ckpt.wait()
        return state, self.metrics_log
