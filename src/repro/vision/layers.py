"""Quantized vision layers — the PULP-NN layer set on the backend registry.

PULP-NN (Garofalo et al., the paper's software substrate) defines the
layer set a QNN inference library needs: convolution, depthwise
convolution, pooling, elementwise add, and fully-connected, every one with
the fused requantization epilogue (eqs. 3/4) at its output so activations
stay *integer images* end to end — uint{8,4,2} tensors between layers,
int32 accumulation inside. This module is that layer set for the TPU
repro, each compute layer routed through `repro.kernels.api`:

  QConv2D            one `api.qconv` call (fused Pallas kernel or XLA
                     im2col, per the registry's backend resolution)
  QDepthwiseConv2D   grouped conv lowered *above* the registry: either
                     per-group `api.qconv` calls (cin=1, cout=1 standard
                     convs — admitted when a fused backend supports the
                     per-group shape) or one block-diagonal im2col +
                     `api.qdot` GEMM (the always-available fallback);
                     both lowerings consume the same integer weights and
                     the same single per-layer (kappa, lam, m, d) fold,
                     so they are bit-exact against each other
  QLinear            `api.qdot` (classifier head; 'raw' int32 logits)
  QMaxPool2D         grid-preserving integer max — no requantization
  QAvgPool2D         int32 window sum + eq. 4 requant (`requantize_shift`
                     floor semantics, same helper as the kernel epilogue)
  QResidualAdd       two-scale integer add: y = clip((m1*a + m2*b) >> d);
                     operands are uint{8,4,2} so every product fits int32
                     directly (no hi/lo split needed, d may be < 16)

The fp reference applies (`conv2d_fp`, ...) are the calibration-time
forward; `conv_tap` mirrors `nn/layers.py::dense_tap` so the deploy
calibrator can observe per-conv activations during an eager replay.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.calibration import calibrate_weight
from repro.core.quantize import (QuantSpec, QuantizedLinearParams,
                                 fold_bn_requant, pick_requant_md, quantize,
                                 requantize_shift)
from repro.kernels.qconv.ops import (QuantizedConvParams, im2col_hwc,
                                     quantize_conv)

# Calibration tap: when set, the fp conv/depthwise/linear applies call it
# with (params_dict, x) before the op — the vision analogue of
# `nn/layers.py::dense_tap` (host-side eager calibration passes only).
_CONV_TAP: Optional[Callable] = None


@contextlib.contextmanager
def conv_tap(fn: Callable):
    """Install ``fn(params_dict, x)`` as the vision-layer observer."""
    global _CONV_TAP
    prev = _CONV_TAP
    _CONV_TAP = fn
    try:
        yield
    finally:
        _CONV_TAP = prev


# ------------------------------------------------------- fp reference ---

def conv2d_raw(x, w, *, stride: int, padding: int, groups: int = 1):
    """Raw fp conv (no BN/ReLU): x (N,H,W,Cin) f32, w (fh,fw,Cin/g,Cout).

    Shared by the fp forward and the calibrator's W{b}A8 simulation."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(padding, padding)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def conv2d_fp(p, x, *, stride: int, padding: int, relu: bool = True):
    """fp conv + BN + ReLU; p: {"w": (fh,fw,cin,cout), "bn_scale",
    "bn_bias"}. Calls the conv_tap observer (calibration)."""
    if _CONV_TAP is not None:
        _CONV_TAP(p, x)
    y = conv2d_raw(x, p["w"], stride=stride, padding=padding)
    y = y * p["bn_scale"] + p["bn_bias"]
    return jnp.maximum(y, 0.0) if relu else y


def depthwise_fp(p, x, *, stride: int, padding: int, relu: bool = True):
    """fp depthwise conv + BN + ReLU; p["w"]: (fh, fw, C)."""
    if _CONV_TAP is not None:
        _CONV_TAP(p, x)
    w = p["w"]
    c = w.shape[-1]
    y = conv2d_raw(x, w.reshape(*w.shape[:2], 1, c), stride=stride,
                   padding=padding, groups=c)
    y = y * p["bn_scale"] + p["bn_bias"]
    return jnp.maximum(y, 0.0) if relu else y


def linear_fp(p, x):
    """fp classifier head (no BN/activation); p["w"]: (d_in, classes)."""
    if _CONV_TAP is not None:
        _CONV_TAP(p, x)
    return x @ p["w"]


def maxpool_fp(x, window: int, stride: int):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


def avgpool_global_fp(x):
    return jnp.mean(x, axis=(1, 2))


# ----------------------------------------------------- requant folds ---

def fold_avgpool_requant(count: int, eps_x: float, eps_y: float):
    """(m, d) for integer average pooling over ``count`` window elements.

    y_real = (1/count) * sum(x_real)  =>  y_hat = (eps_x / (eps_y*count))
    * sum(x_hat); the requant runs through `requantize_shift` (floor
    semantics, d >= 16 — the window sum is an int32 accumulator).
    """
    return pick_requant_md(float(eps_x) / (float(eps_y) * count))


def fold_add_requant(eps_a: float, eps_b: float, eps_y: float):
    """(m1, m2, d) for the two-scale residual add.

    y_hat = clip((m1*a_hat + m2*b_hat) >> d) with m_i = round(eps_i/eps_y
    * 2^d). Operands are uint{8,4,2} integer images (< 2^8), so m*x <
    2^23 fits int32 without the hi/lo split — d may go below 16 (ratios
    near 1 need d ~ 14).
    """
    r1 = float(eps_a) / float(eps_y)
    r2 = float(eps_b) / float(eps_y)
    _, d = pick_requant_md(max(r1, r2), d_min=0)
    return (int(np.round(r1 * (1 << d))), int(np.round(r2 * (1 << d))), d)


# -------------------------------------------------- quantized layers ---

@dataclasses.dataclass(frozen=True)
class QConv2D:
    """One quantized conv layer: `api.qconv` + fused eq. 3/4 epilogue.

    ``backend`` is the plan-routed kernel backend for this layer (None ->
    registry resolution); an explicit ``backend=`` on `apply` wins.
    """

    conv: QuantizedConvParams
    backend: Optional[str] = None

    def apply(self, x_hat, *, backend: Optional[str] = None, mesh=None):
        from repro.kernels import api
        return api.qconv(self.conv, x_hat,
                         backend=backend or self.backend, mesh=mesh)


@dataclasses.dataclass(frozen=True)
class QDepthwiseConv2D:
    """Depthwise conv lowered onto the registry ops (no grouped backend
    exists — `api.qconv` rejects grouped params cleanly).

    Two bit-exact lowerings from one quantization pass:

    * ``qdot``: one block-diagonal im2col GEMM — K = fh*fw*C with
      W[t*C + c, c'] = 0 unless c == c' (zero weights are zero MACs, so
      the block-diagonal contraction *is* the depthwise conv), requant
      epilogue per channel. One registry call; the default.
    * ``per_group``: C standard convs (cin=1, cout=1) through
      `api.qconv`, sharing the single per-layer (kappa, lam, m, d) fold
      (slices, never re-folded — a per-channel re-fold would pick
      different shifts d and break cross-lowering bit-exactness).

    ``lowering='auto'`` picks per_group only when the registry resolves a
    fused (pallas-family) backend for the per-group shape — the in-kernel
    receptive-field gather is the only reason to pay C dispatches;
    everywhere else the single block-diagonal GEMM wins. Under a mesh the
    qdot route is forced (cout=1 per-group convs cannot be
    tensor-parallel).
    """

    gemm: QuantizedLinearParams            # block-diagonal (fh*fw*C -> C)
    per_group: Tuple[QuantizedConvParams, ...]
    fh: int
    fw: int
    stride: int
    padding: int
    channels: int
    backend: Optional[str] = None

    def apply(self, x_hat, *, backend: Optional[str] = None, mesh=None,
              lowering: str = "auto"):
        from repro.kernels import api

        backend = backend or self.backend
        if lowering == "auto":
            lowering = ("qdot" if mesh is not None
                        else self._auto_lowering(x_hat, backend))
        if lowering == "per_group":
            if mesh is not None:
                raise ValueError(
                    "depthwise lowering 'per_group' cannot run on a mesh "
                    "(cout=1 per-group convs have no tensor-parallel "
                    "axis); use lowering='qdot' or 'auto'")
            outs = [api.qconv(pg, x_hat[..., c:c + 1], backend=backend)
                    for c, pg in enumerate(self.per_group)]
            return jnp.concatenate(outs, axis=-1)
        if lowering != "qdot":
            raise ValueError(f"unknown depthwise lowering {lowering!r}; "
                             "expected 'auto', 'qdot' or 'per_group'")
        cols, _, _ = im2col_hwc(x_hat, self.fh, self.fw, self.stride,
                                self.padding)
        return api.qdot(self.gemm, cols, backend=backend, mesh=mesh)

    def _auto_lowering(self, x_hat, backend) -> str:
        from repro.kernels import api
        if not self.per_group:
            return "qdot"
        shape = (x_hat.shape[0], x_hat.shape[1], x_hat.shape[2], 1,
                 self.fh, self.fw, self.stride, self.padding, 1, 1)
        try:
            spec = api.resolve("qconv", shape, self.gemm.a_bits,
                               self.gemm.w_bits, backend=backend)
        except (KeyError, RuntimeError):
            return "qdot"
        return "per_group" if spec.name.startswith("pallas") else "qdot"


@dataclasses.dataclass(frozen=True)
class QLinear:
    """Quantized fully-connected head via `api.qdot`. ``epilogue='raw'``
    keeps int32 logits (argmax-exact; dequantize with the net's
    ``eps_logits``)."""

    gemm: QuantizedLinearParams
    epilogue: str = "raw"
    backend: Optional[str] = None

    def apply(self, x_hat, *, backend: Optional[str] = None, mesh=None):
        from repro.kernels import api
        return api.qdot(self.gemm, x_hat, epilogue=self.epilogue,
                        backend=backend or self.backend, mesh=mesh)


@dataclasses.dataclass(frozen=True)
class QMaxPool2D:
    """Integer max pooling — order-preserving on the uint grid, so the
    output stays on the *input's* quantization grid: no requantization,
    bit-exact by construction."""

    window: int
    stride: int

    def apply(self, x_hat):
        return jax.lax.reduce_window(
            x_hat, jnp.int8(-128), jax.lax.max,
            (1, self.window, self.window, 1),
            (1, self.stride, self.stride, 1), "VALID")


@dataclasses.dataclass(frozen=True)
class QAvgPool2D:
    """Integer average pooling: int32 window sum + eq. 4 requantization
    (floor semantics via `requantize_shift` — the same helper the kernel
    epilogues use, so pooling rounds exactly like every other boundary).
    ``window == 0`` means global pooling, returning (N, C)."""

    window: int
    stride: int
    m: int
    d: int
    out_bits: int

    def apply(self, x_hat):
        x32 = x_hat.astype(jnp.int32)
        if self.window == 0:
            s = jnp.sum(x32, axis=(1, 2))
        else:
            s = jax.lax.reduce_window(
                x32, jnp.int32(0), jax.lax.add,
                (1, self.window, self.window, 1),
                (1, self.stride, self.stride, 1), "VALID")
        y = requantize_shift(s, jnp.int32(self.m), self.d)
        hi = packing.int_range(self.out_bits, False)[1]
        return jnp.clip(y, 0, hi).astype(jnp.int8)


@dataclasses.dataclass(frozen=True)
class QResidualAdd:
    """Two-scale integer residual add: y = clip((m1*a + m2*b) >> d).

    Operands are uint{8,4,2} images, so each product fits int32 without
    the hi/lo split; the clip saturates onto the unsigned out_bits grid
    (the clip-at-zero is a no-op on unsigned operands — the ReLU-after-add
    of the fp net is inherent in the grid)."""

    m1: int
    m2: int
    d: int
    out_bits: int

    def apply(self, a_hat, b_hat):
        acc = (a_hat.astype(jnp.int32) * self.m1
               + b_hat.astype(jnp.int32) * self.m2) >> self.d
        hi = packing.int_range(self.out_bits, False)[1]
        return jnp.clip(acc, 0, hi).astype(jnp.int8)


# --------------------------------------------------- layer builders ---

def quantize_conv_layer(p, spec_x: QuantSpec, spec_y: QuantSpec,
                        w_bits: int, *, stride: int, padding: int,
                        backend: Optional[str] = None) -> QConv2D:
    """fp conv node {"w","bn_scale","bn_bias"} -> deployable QConv2D."""
    spec_w = calibrate_weight(p["w"], w_bits)
    conv = quantize_conv(p["w"], spec_w, p["bn_scale"], p["bn_bias"],
                         spec_x, spec_y, stride, padding)
    return QConv2D(conv=conv, backend=backend)


@dataclasses.dataclass(frozen=True)
class QSegmentedConv2D:
    """Fine-grain mixed-precision conv: one uniform `QConv2D` per
    output-channel run, outputs concatenated along cout.

    This is the PR-9 composition contract applied to the conv layers:
    each ``(n_start, n_end, w_bits)`` run of the plan's segments is
    quantized as a *uniform layer over its column slice* — its own
    per-tensor weight grid (`calibrate_weight` on the slice) and its own
    eq. 3/4 fold, exactly what `SegmentedLinearParams.segment_params`
    defines as the segmented container's meaning. Per-run artifacts are
    byte-identical to a uniform layer of that slice, so the whole-layer
    output is bit-exact against any fused mixed-operand execution."""

    runs: Tuple[Tuple[int, int, int], ...]
    parts: Tuple[QConv2D, ...]

    def apply(self, x_hat, *, backend: Optional[str] = None, mesh=None):
        return jnp.concatenate(
            [p.apply(x_hat, backend=backend, mesh=mesh)
             for p in self.parts], axis=-1)


def quantize_conv_layer_segmented(p, spec_x: QuantSpec, spec_y: QuantSpec,
                                  runs, *, stride: int, padding: int,
                                  backend: Optional[str] = None
                                  ) -> QSegmentedConv2D:
    """fp conv node + plan segments -> per-run quantized conv.

    ``runs``: CHUNK-aligned ``(n_start, n_end, w_bits)`` output-channel
    runs covering [0, cout) (`PlanRule.segments`). Every run re-slices
    the BN fold too — (kappa, lam, m, d) are per-run, matching the
    uniform layer each run is defined to be."""
    runs = tuple(tuple(int(v) for v in r) for r in runs)
    cout = int(p["w"].shape[-1])
    if runs[0][0] != 0 or runs[-1][1] != cout or any(
            runs[i][1] != runs[i + 1][0] for i in range(len(runs) - 1)):
        raise ValueError(f"segments {runs} do not tile [0, {cout})")
    parts = []
    for s, e, b in runs:
        sub = {"w": p["w"][..., s:e], "bn_scale": p["bn_scale"][s:e],
               "bn_bias": p["bn_bias"][s:e]}
        parts.append(quantize_conv_layer(
            sub, spec_x, spec_y, b, stride=stride, padding=padding,
            backend=backend))
    return QSegmentedConv2D(runs=runs, parts=tuple(parts))


def quantize_depthwise(p, spec_x: QuantSpec, spec_y: QuantSpec,
                       w_bits: int, *, stride: int, padding: int,
                       backend: Optional[str] = None) -> QDepthwiseConv2D:
    """fp depthwise node (w: (fh, fw, C)) -> QDepthwiseConv2D with both
    lowerings built from ONE quantization + ONE (kappa, lam, m, d) fold."""
    w = p["w"]
    fh, fw, c = w.shape
    spec_w = calibrate_weight(w, w_bits)
    w_hat = quantize(w, spec_w)                       # (fh, fw, C) int8
    kappa, lam, m, d = fold_bn_requant(
        spec_w.eps, spec_x.eps, spec_y.eps, p["bn_scale"], p["bn_bias"],
        spec_y.bits)

    # block-diagonal im2col GEMM: K = fh*fw*C (tap-major, matching
    # im2col_hwc's (dy, dx, c) order), N = C; off-diagonal zeros are
    # zero MACs, so the contraction is exactly the depthwise conv
    taps = np.asarray(w_hat).reshape(fh * fw, c)
    bd = np.zeros((fh * fw, c, c), np.int8)
    bd[:, np.arange(c), np.arange(c)] = taps
    bd = jnp.asarray(bd.reshape(fh * fw * c, c))
    k_logical = fh * fw * c
    w_packed = packing.pack(packing.pad_to_chunk(bd, axis=0), w_bits,
                            axis=0)
    gemm = QuantizedLinearParams(
        w_packed=w_packed, w_bits=w_bits, a_bits=spec_x.bits,
        a_signed=spec_x.signed, kappa=kappa, lam=lam, m=m, d=d,
        out_bits=spec_y.bits, k_logical=k_logical)

    # per-group artifacts: channel c as a standard (cin=1, cout=1) conv,
    # slicing the shared fold (never re-folding — d must stay per-layer)
    cin_pad = packing.padded_size(1)
    per_group = []
    for ci in range(c):
        wc = jnp.asarray(taps[:, ci:ci + 1])          # (fh*fw, 1)
        wp_flat = packing.pack(packing.pad_to_chunk(wc, axis=0), w_bits,
                               axis=0)
        w_tap = jnp.zeros((fh * fw, cin_pad, 1), jnp.int8
                          ).at[:, 0, 0].set(wc[:, 0])
        wp_fused = packing.pack(w_tap.reshape(fh * fw * cin_pad, 1),
                                w_bits, axis=0)
        g = QuantizedLinearParams(
            w_packed=wp_flat, w_bits=w_bits, a_bits=spec_x.bits,
            a_signed=spec_x.signed, kappa=kappa[ci:ci + 1],
            lam=lam[ci:ci + 1], m=m[ci:ci + 1], d=d,
            out_bits=spec_y.bits, k_logical=fh * fw)
        per_group.append(QuantizedConvParams(
            gemm=g, fh=fh, fw=fw, stride=stride, padding=padding,
            cin=1, cout=1, w_packed_fused=wp_fused, cin_pad=cin_pad))
    return QDepthwiseConv2D(
        gemm=gemm, per_group=tuple(per_group), fh=fh, fw=fw,
        stride=stride, padding=padding, channels=c, backend=backend)


def quantize_linear_head(p, spec_x: QuantSpec, w_bits: int, *,
                         backend: Optional[str] = None):
    """fp head {"w": (d_in, classes)} -> (QLinear with raw int32 logits,
    eps_logits). kappa/lam/m ride as identity placeholders — the 'raw'
    epilogue never reads them, but every backend's signature does."""
    w = p["w"]
    spec_w = calibrate_weight(w, w_bits)
    w_hat = quantize(w, spec_w)
    k_logical, n = w_hat.shape
    w_packed = packing.pack(packing.pad_to_chunk(w_hat, axis=0), w_bits,
                            axis=0)
    gemm = QuantizedLinearParams(
        w_packed=w_packed, w_bits=w_bits, a_bits=spec_x.bits,
        a_signed=spec_x.signed,
        kappa=jnp.ones((n,), jnp.int32),
        lam=jnp.zeros((n,), jnp.int32),
        m=jnp.ones((n,), jnp.int32), d=16, out_bits=8,
        k_logical=k_logical)
    eps_logits = float(spec_w.eps) * float(spec_x.eps)
    return QLinear(gemm=gemm, epilogue="raw", backend=backend), eps_logits
