"""Vision model graphs: one LayerDef list, interpreted twice.

A `VisionConfig` is an ordered tuple of `LayerDef`s — a flat dataflow
graph with named side edges for residual skips and branch layers (the 1x1
projection convs of ResNet downsample stages). The same graph drives:

* `forward_fp`   — the float calibration forward (conv+BN+ReLU per
  layer, `repro.vision.layers` fp applies; `edge_tap` observes every
  layer output so calibration can place the activation grids), and
* `forward_int`  — the deployed integer forward: uint{a_bits} integer
  images at every boundary, int32 accumulation inside layers, the
  eq. 3/4 requantization epilogue at each output — routed through the
  `repro.kernels.api` registry (per-layer ``backend`` from the plan) and
  optionally `mesh=`-sharded (images data-parallel over the cluster).

`quantize_net` turns (fp params, per-edge absmax, `PrecisionPlan`) into
the deployable `QuantizedVisionNet`: per-layer W{8,4,2} from the plan's
fnmatch rules over the same "/"-joined param paths the deploy calibrator
records — the CNN analogue of the LM zoo's per-dense path labels.

Activation grids chain: layer i's output `QuantSpec` *is* layer i+1's
input spec (alpha=0 unsigned grids per the paper; every conv output is
ReLU-clipped by the unsigned requant, the PULP-NN convention). Grid-
preserving layers (max pool) inherit their producer's spec; requantizing
layers (conv, depthwise, avg pool, residual add) get their own
calibrated spec.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantSpec, quantize
from repro.deploy.policy import PrecisionPlan, resolve_qcfg
from repro.nn.layers import QuantConfig
from repro.vision import layers as vl

COMPUTE_KINDS = ("conv", "dwconv", "linear")     # plan-addressable layers


@dataclasses.dataclass(frozen=True)
class LayerDef:
    """One graph node. ``path`` doubles as the param/plan label."""

    path: str
    kind: str                 # conv | dwconv | linear | maxpool |
                              # avgpool_global | add
    cout: int = 0             # conv/linear output features
    fh: int = 3
    fw: int = 3
    stride: int = 1
    padding: int = 1
    window: int = 2           # maxpool window (stride == window)
    input_from: Optional[str] = None   # read a saved edge, not the stream
    save_as: Optional[str] = None      # save output under this edge name
    branch: bool = False               # do not advance the main stream
    skip_from: Optional[str] = None    # add: second operand edge


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    name: str
    layers: Tuple[LayerDef, ...]
    num_classes: int
    in_hw: Tuple[int, int]
    in_ch: int = 3
    a_bits: int = 8           # activation bits at every layer boundary


# ------------------------------------------------------------ tracing ---

def trace_shapes(cfg: VisionConfig):
    """Per-layer (in_hwc, out_hwc) walk; ``hwc = (h, w, c)``, with
    ``h == w == 0`` once the stream is flat (post global pool)."""
    out = []
    stream = (*cfg.in_hw, cfg.in_ch)
    edges: Dict[str, tuple] = {}
    for L in cfg.layers:
        src = edges[L.input_from] if L.input_from else stream
        h, w, c = src
        if L.kind == "conv":
            oh = (h + 2 * L.padding - L.fh) // L.stride + 1
            ow = (w + 2 * L.padding - L.fw) // L.stride + 1
            dst = (oh, ow, L.cout)
        elif L.kind == "dwconv":
            oh = (h + 2 * L.padding - L.fh) // L.stride + 1
            ow = (w + 2 * L.padding - L.fw) // L.stride + 1
            dst = (oh, ow, c)
        elif L.kind == "maxpool":
            dst = ((h - L.window) // L.stride + 1,
                   (w - L.window) // L.stride + 1, c)
        elif L.kind == "avgpool_global":
            dst = (0, 0, c)
        elif L.kind == "add":
            skip = edges[L.skip_from]
            if skip != src:
                raise ValueError(
                    f"{L.path}: add operands disagree {src} vs {skip}")
            dst = src
        elif L.kind == "linear":
            dst = (0, 0, L.cout)
        else:
            raise ValueError(f"{L.path}: unknown kind {L.kind!r}")
        if min(dst[:2]) < 0 or (dst[0] == 0) != (dst[1] == 0):
            raise ValueError(f"{L.path}: bad output geometry {dst}")
        out.append({"layer": L, "in": src, "out": dst})
        if L.save_as:
            edges[L.save_as] = dst
        if not L.branch:
            stream = dst
    return out


# --------------------------------------------------------------- init ---

def _set_path(tree: dict, path: str, node: dict):
    parts = path.split("/")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = node


def get_path(tree: dict, path: str):
    for p in path.split("/"):
        tree = tree[p]
    return tree


def init_fp(cfg: VisionConfig, seed: int = 0) -> dict:
    """He-initialized fp param tree keyed by the "/"-joined layer paths.

    Conv/depthwise nodes carry {"w", "bn_scale", "bn_bias"}; the head
    carries {"w"} only (raw logits, no BN)."""
    rng = np.random.default_rng(seed)
    params: dict = {}
    for t in trace_shapes(cfg):
        L, (h, w, c) = t["layer"], t["in"]
        if L.kind == "conv":
            fan_in = L.fh * L.fw * c
            node = {
                "w": jnp.asarray(rng.normal(
                    size=(L.fh, L.fw, c, L.cout)).astype(np.float32)
                    * (2.0 / fan_in) ** 0.5),
                "bn_scale": jnp.asarray(
                    (rng.normal(size=(L.cout,)) * 0.05 + 0.4).astype(
                        np.float32)),
                "bn_bias": jnp.asarray(
                    (rng.normal(size=(L.cout,)) * 0.05).astype(np.float32)),
            }
        elif L.kind == "dwconv":
            node = {
                "w": jnp.asarray(rng.normal(
                    size=(L.fh, L.fw, c)).astype(np.float32)
                    * (2.0 / (L.fh * L.fw)) ** 0.5),
                "bn_scale": jnp.asarray(
                    (rng.normal(size=(c,)) * 0.05 + 0.4).astype(np.float32)),
                "bn_bias": jnp.asarray(
                    (rng.normal(size=(c,)) * 0.05).astype(np.float32)),
            }
        elif L.kind == "linear":
            node = {"w": jnp.asarray(rng.normal(
                size=(c, L.cout)).astype(np.float32) / c ** 0.5)}
        else:
            continue
        _set_path(params, L.path, node)
    return params


# ----------------------------------------------------------- forwards ---

def forward_fp(cfg: VisionConfig, params: dict, x,
               edge_tap: Optional[Callable] = None):
    """Float forward. ``edge_tap(path, tensor)`` observes the net input
    (path "__input__") and every layer output — calibration places the
    activation grids from exactly these edges."""
    if edge_tap is not None:
        edge_tap("__input__", x)
    stream = x
    edges: Dict[str, jnp.ndarray] = {}
    for L in cfg.layers:
        xin = edges[L.input_from] if L.input_from else stream
        if L.kind == "conv":
            y = vl.conv2d_fp(get_path(params, L.path), xin,
                             stride=L.stride, padding=L.padding)
        elif L.kind == "dwconv":
            y = vl.depthwise_fp(get_path(params, L.path), xin,
                                stride=L.stride, padding=L.padding)
        elif L.kind == "maxpool":
            y = vl.maxpool_fp(xin, L.window, L.stride)
        elif L.kind == "avgpool_global":
            y = vl.avgpool_global_fp(xin)
        elif L.kind == "add":
            y = xin + edges[L.skip_from]
        elif L.kind == "linear":
            y = vl.linear_fp(get_path(params, L.path), xin)
        else:
            raise ValueError(f"{L.path}: unknown kind {L.kind!r}")
        if edge_tap is not None:
            edge_tap(L.path, y)
        if L.save_as:
            edges[L.save_as] = y
        if not L.branch:
            stream = y
    return stream


def collect_absmax(cfg: VisionConfig, params: dict, batches) -> dict:
    """Per-edge running absmax over fp forwards of ``batches`` — the
    range side of calibration (the full calibrator in
    `repro.deploy.calibrate.calibrate_vision` also prices bit-width
    sensitivities; this is the cheap range-only pass)."""
    absmax: Dict[str, float] = {}

    def tap(path, t):
        absmax[path] = max(absmax.get(path, 0.0),
                           float(jnp.max(jnp.abs(t))))

    for x in batches:
        forward_fp(cfg, params, jnp.asarray(x, jnp.float32), edge_tap=tap)
    return absmax


# --------------------------------------------------------- quantizing ---

@dataclasses.dataclass(frozen=True)
class QuantizedVisionNet:
    """The deployable CNN artifact: the graph + one quantized layer per
    node + the input grid. ``eps_logits`` dequantizes the head's raw
    int32 logits (logits_real = eps_logits * logits_hat)."""

    cfg: VisionConfig
    qlayers: Tuple[tuple, ...]          # ((LayerDef, qlayer), ...)
    input_spec: QuantSpec
    eps_logits: float
    plan: Optional[PrecisionPlan] = None

    def layer_bits(self) -> Dict[str, int]:
        """path -> w_bits for the plan-addressable layers (reporting);
        segmented convs report their widest run (the `PlanRule.w_bits`
        convention)."""
        out = {}
        for L, q in self.qlayers:
            if L.kind == "conv":
                if isinstance(q, vl.QSegmentedConv2D):
                    out[L.path] = max(p.conv.gemm.w_bits for p in q.parts)
                else:
                    out[L.path] = q.conv.gemm.w_bits
            elif L.kind == "dwconv":
                out[L.path] = q.gemm.w_bits
            elif L.kind == "linear":
                out[L.path] = q.gemm.w_bits
        return out


def quantize_net(cfg: VisionConfig, fp_params: dict, absmax: dict, *,
                 plan: Optional[PrecisionPlan] = None,
                 default_w_bits: int = 8,
                 backend: Optional[str] = None) -> QuantizedVisionNet:
    """(fp params, per-edge absmax, plan) -> integer-only deployable net.

    ``absmax`` maps "__input__" and every requantizing layer's path to
    the calibrated output absmax (`collect_absmax` /
    `deploy.calibrate.calibrate_vision`). Per-layer w_bits and kernel
    backend come from the plan's rules (pattern over layer paths);
    ``backend`` is the net-wide fallback route."""
    base = QuantConfig(mode="int", w_bits=default_w_bits, a_bits=cfg.a_bits)

    def out_spec(path):
        if path not in absmax:
            raise KeyError(
                f"no calibrated absmax for layer {path!r}; run "
                "collect_absmax/calibrate_vision over the same config")
        return QuantSpec.activation(cfg.a_bits, max(absmax[path], 1e-6))

    spec = QuantSpec.activation(cfg.a_bits, max(absmax["__input__"], 1e-6))
    input_spec = spec
    edge_specs: Dict[str, QuantSpec] = {}
    qlayers = []
    eps_logits = 1.0
    for t in trace_shapes(cfg):
        L = t["layer"]
        spec_x = edge_specs[L.input_from] if L.input_from else spec
        qcfg = resolve_qcfg(plan, L.path, base)
        lyr_backend = (qcfg.backend if L.kind in COMPUTE_KINDS
                       and qcfg.backend is not None else backend)
        if L.kind == "conv":
            spec_y = out_spec(L.path)
            if qcfg.segments is not None:
                q = vl.quantize_conv_layer_segmented(
                    get_path(fp_params, L.path), spec_x, spec_y,
                    qcfg.segments, stride=L.stride, padding=L.padding,
                    backend=lyr_backend)
            else:
                q = vl.quantize_conv_layer(
                    get_path(fp_params, L.path), spec_x, spec_y,
                    qcfg.w_bits, stride=L.stride, padding=L.padding,
                    backend=lyr_backend)
        elif L.kind == "dwconv":
            if qcfg.segments is not None:
                raise NotImplementedError(
                    f"{L.path}: segmented plans are not supported on "
                    "depthwise layers (per-channel grids make channel-"
                    "group demotion a per-layer width change; plan with "
                    "granularity='layer' for depthwise nets)")
            spec_y = out_spec(L.path)
            q = vl.quantize_depthwise(
                get_path(fp_params, L.path), spec_x, spec_y, qcfg.w_bits,
                stride=L.stride, padding=L.padding, backend=lyr_backend)
        elif L.kind == "maxpool":
            spec_y = spec_x                      # grid-preserving
            q = vl.QMaxPool2D(window=L.window, stride=L.stride)
        elif L.kind == "avgpool_global":
            spec_y = out_spec(L.path)
            h, w, _ = t["in"]
            m, d = vl.fold_avgpool_requant(h * w, spec_x.eps, spec_y.eps)
            q = vl.QAvgPool2D(window=0, stride=1, m=m, d=d,
                              out_bits=cfg.a_bits)
        elif L.kind == "add":
            spec_b = edge_specs[L.skip_from]
            spec_y = out_spec(L.path)
            m1, m2, d = vl.fold_add_requant(spec_x.eps, spec_b.eps,
                                            spec_y.eps)
            q = vl.QResidualAdd(m1=m1, m2=m2, d=d, out_bits=cfg.a_bits)
        elif L.kind == "linear":
            if qcfg.segments is not None:
                raise NotImplementedError(
                    f"{L.path}: segmented plans are not supported on the "
                    "classifier head (d_out = num_classes < CHUNK, so "
                    "the planner never splits it)")
            q, eps_logits = vl.quantize_linear_head(
                get_path(fp_params, L.path), spec_x, qcfg.w_bits,
                backend=lyr_backend)
            spec_y = spec_x                      # raw logits: no new grid
        qlayers.append((L, q))
        if L.save_as:
            edge_specs[L.save_as] = spec_y
        if not L.branch:
            spec = spec_y
    return QuantizedVisionNet(cfg=cfg, qlayers=tuple(qlayers),
                              input_spec=input_spec,
                              eps_logits=eps_logits, plan=plan)


def quantize_input(qnet: QuantizedVisionNet, x):
    """Real images (N, H, W, C) f32 -> uint{a_bits} integer images."""
    return quantize(jnp.asarray(x, jnp.float32), qnet.input_spec)


def forward_int(qnet: QuantizedVisionNet, x_hat, *,
                backend: Optional[str] = None, mesh=None,
                collect: Optional[Callable] = None):
    """Integer-only forward: uint{a_bits} in, int32 logits out.

    ``backend`` forces one kernel backend net-wide (parity tests);
    otherwise each layer routes through its plan-assigned backend or the
    registry default. ``mesh`` shards every conv/linear data-parallel
    over the image batch (`qconv_sharded`/`qdot_sharded` — bit-exact vs
    meshless by the registry's psum-free construction).
    ``collect(path, y_hat)`` observes every integer edge (tests)."""
    stream = x_hat
    edges: Dict[str, jnp.ndarray] = {}
    for L, q in qnet.qlayers:
        xin = edges[L.input_from] if L.input_from else stream
        if L.kind in ("conv", "dwconv", "linear"):
            y = q.apply(xin, backend=backend, mesh=mesh)
        elif L.kind == "add":
            y = q.apply(xin, edges[L.skip_from])
        else:
            y = q.apply(xin)
        if collect is not None:
            collect(L.path, y)
        if L.save_as:
            edges[L.save_as] = y
        if not L.branch:
            stream = y
    return stream


def streamed_weight_bytes(qnet: QuantizedVisionNet) -> int:
    """HBM bytes of the weight-side arrays one forward actually streams:
    per compute layer, the qdot-route packed weights plus the epilogue
    vectors. This is the memory-roofline term — unlike
    `vision_artifact_bytes` it counts ONE depthwise lowering (the
    block-diagonal GEMM the default route runs), not every materialized
    layout."""
    total = 0
    for L, q in qnet.qlayers:
        if L.kind == "conv":
            gemms = ([p.conv.gemm for p in q.parts]
                     if isinstance(q, vl.QSegmentedConv2D) else
                     [q.conv.gemm])
        elif L.kind in ("dwconv", "linear"):
            gemms = [q.gemm]
        else:
            continue
        for g in gemms:
            for arr in (g.w_packed, g.kappa, g.lam, g.m):
                total += arr.size * arr.dtype.itemsize
    return total


def vision_artifact_bytes(qnet: QuantizedVisionNet) -> int:
    """Total bytes of the packed arrays in the deployable net (both
    depthwise lowerings' weights are materialized and both count)."""
    seen = set()

    def walk(obj) -> int:
        if isinstance(obj, (jnp.ndarray, np.ndarray)):
            if id(obj) in seen:
                return 0
            seen.add(id(obj))
            return obj.size * obj.dtype.itemsize
        if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
            return sum(walk(getattr(obj, f.name))
                       for f in dataclasses.fields(obj))
        if isinstance(obj, (tuple, list)):
            return sum(walk(v) for v in obj)
        return 0

    return sum(walk(q) for _, q in qnet.qlayers)
