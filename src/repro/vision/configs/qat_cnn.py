"""The QAT digit CNN: 16x16x1 in, BitNetMCU-scale, channel-group-wide.

The accuracy benchmark's network. Three design constraints:

* MNIST-scale input (16x16 grayscale, 10 classes) so a full QAT ->
  deploy -> integer-eval loop runs in CPU minutes (the hermetic
  `repro.qat.data` digits);
* a 256-channel final conv: wider than one `packing.CHUNK` (128), so the
  channel-group planner has *real* groups to demote independently and
  `PlanRule.segments` plans are exercised end to end (every other layer
  fits in one group, where fine == layer granularity by construction);
* plain conv/pool graph (no residuals) — accuracy differences between
  W8/W4/W2 come from the quantization, not from graph exotica.

The smoke variant shrinks widths (tier-1: 20-step loss-decrease + fold
bit-exactness) — too narrow for channel groups, which is exactly why the
full variant exists.
"""
from __future__ import annotations

from repro.vision.models import LayerDef, VisionConfig


def qat_cnn(smoke: bool = False, a_bits: int = 8) -> VisionConfig:
    c1, c2, c3 = (8, 16, 32) if smoke else (16, 32, 256)
    layers = (
        LayerDef(path="c1", kind="conv", cout=c1),
        LayerDef(path="p1", kind="maxpool"),              # 16 -> 8
        LayerDef(path="c2", kind="conv", cout=c2),
        LayerDef(path="p2", kind="maxpool"),              # 8 -> 4
        LayerDef(path="c3", kind="conv", cout=c3),
        LayerDef(path="pool", kind="avgpool_global"),
        LayerDef(path="head", kind="linear", cout=10),
    )
    return VisionConfig(
        name="qat-cnn" + ("-smoke" if smoke else ""),
        layers=layers, num_classes=10, in_hw=(16, 16), in_ch=1,
        a_bits=a_bits)
