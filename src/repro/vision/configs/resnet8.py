"""ResNet-8 (MLPerf-Tiny image-classification class) as a QNN graph.

Three residual stages over a conv stem: stage 1 with an identity skip,
stages 2/3 stride-2 with 1x1 projection convs on the skip path, global
average pooling, linear head. Every conv output is requantized onto the
unsigned activation grid (the paper's alpha=0 QNT/ACT — ReLU is inherent
at every boundary), and each residual add is the two-scale integer add
(`repro.vision.layers.QResidualAdd`).
"""
from __future__ import annotations

from repro.vision.models import LayerDef, VisionConfig


def _stage(name: str, cin_edge: str, cout: int, stride: int,
           out_edge: str):
    """One residual stage reading edge ``cin_edge``: two 3x3 convs on the
    main stream + (projection or identity) skip + requantizing add."""
    layers = [
        LayerDef(path=f"{name}/c1", kind="conv", cout=cout, stride=stride),
        LayerDef(path=f"{name}/c2", kind="conv", cout=cout),
    ]
    if stride != 1:
        layers.append(LayerDef(
            path=f"{name}/skip", kind="conv", cout=cout, fh=1, fw=1,
            stride=stride, padding=0, input_from=cin_edge,
            save_as=f"{name}_skip", branch=True))
        skip_edge = f"{name}_skip"
    else:
        skip_edge = cin_edge
    layers.append(LayerDef(path=f"{name}/add", kind="add",
                           skip_from=skip_edge, save_as=out_edge))
    return layers


def resnet8(smoke: bool = False, a_bits: int = 8) -> VisionConfig:
    width = 8 if smoke else 16
    in_hw = (16, 16) if smoke else (32, 32)
    layers = [
        LayerDef(path="stem", kind="conv", cout=width, save_as="s1_in"),
        *_stage("s1", "s1_in", width, 1, "s2_in"),
        *_stage("s2", "s2_in", 2 * width, 2, "s3_in"),
        *_stage("s3", "s3_in", 4 * width, 2, "feat"),
        LayerDef(path="pool", kind="avgpool_global"),
        LayerDef(path="head", kind="linear", cout=10),
    ]
    return VisionConfig(
        name="resnet8" + ("-smoke" if smoke else ""),
        layers=tuple(layers), num_classes=10, in_hw=in_hw, in_ch=3,
        a_bits=a_bits)
