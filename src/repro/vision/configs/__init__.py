"""Named vision network configs (full + smoke variants).

Mirrors `repro.configs` for the LM zoo: one module per network family,
one registry the CLIs/benchmarks/tests resolve names through.
"""
from __future__ import annotations

from repro.vision.configs.mobilenet_v1 import mobilenet_v1_tiny
from repro.vision.configs.qat_cnn import qat_cnn
from repro.vision.configs.resnet8 import resnet8

VISION_CONFIGS = {
    "mobilenet-tiny": mobilenet_v1_tiny,
    "qat-cnn": qat_cnn,
    "resnet8": resnet8,
}


def get_vision_config(name: str, *, smoke: bool = False, a_bits: int = 8):
    builder = VISION_CONFIGS.get(name)
    if builder is None:
        raise KeyError(f"unknown vision config {name!r}; "
                       f"available: {sorted(VISION_CONFIGS)}")
    return builder(smoke=smoke, a_bits=a_bits)
