"""MobileNetV1-style depthwise-separable QNN (CIFAR/MLPerf-Tiny scale).

The paper-class depthwise-separable architecture: a strided conv stem,
then [3x3 depthwise + 1x1 pointwise] blocks doubling channels as the
spatial extent halves, global average pooling and a linear classifier —
the network family the fine-grain mixed-precision cluster follow-up
(Nadalini et al.) drives per-layer W{8,4,2} plans through. Adapted to
IoT-scale inputs (32x32, the paper's conv benchmark scale): a 2x2 max
pool after the stem takes the place of the first stride-2 depthwise
stage's extra resolution (and exercises the grid-preserving pooling
path end to end).
"""
from __future__ import annotations

from repro.vision.models import LayerDef, VisionConfig


def mobilenet_v1_tiny(smoke: bool = False, a_bits: int = 8) -> VisionConfig:
    width = 8 if smoke else 16
    in_hw = (16, 16) if smoke else (32, 32)
    n_blocks = 2 if smoke else 3
    layers = [
        LayerDef(path="stem", kind="conv", cout=width, fh=3, fw=3,
                 stride=2, padding=1),
        LayerDef(path="pool0", kind="maxpool", window=2, stride=2),
    ]
    c = width
    for b in range(n_blocks):
        stride = 2 if (b and b % 2 == 0) else 1
        cout = c * 2 if b < n_blocks - 1 else c
        layers += [
            LayerDef(path=f"block{b}/dw", kind="dwconv", fh=3, fw=3,
                     stride=stride, padding=1),
            LayerDef(path=f"block{b}/pw", kind="conv", cout=cout, fh=1,
                     fw=1, stride=1, padding=0),
        ]
        c = cout
    layers += [
        LayerDef(path="pool", kind="avgpool_global"),
        LayerDef(path="head", kind="linear", cout=10),
    ]
    return VisionConfig(
        name="mobilenet-tiny" + ("-smoke" if smoke else ""),
        layers=tuple(layers), num_classes=10, in_hw=in_hw, in_ch=3,
        a_bits=a_bits)
