"""repro.vision — end-to-end quantized CNN subsystem (paper §VI networks).

The paper's headline results are network-level: QNN conv layers composed
into full CNNs on the cluster. This package is that layer of the repro:

* `layers`  — the PULP-NN layer set as quantized TPU layers (conv,
  depthwise conv, linear, max/avg pooling, residual add) with the
  eq. 3/4 requantization epilogue at every layer boundary, all routed
  through the `repro.kernels.api` backend registry;
* `models`  — a graph interpreter + two paper-class networks
  (MobileNetV1-style depthwise-separable, MLPerf-Tiny-style ResNet-8)
  with per-path param labels so the `repro.deploy` calibrate -> plan ->
  pack flow drives per-layer W{8,4,2} plans through real CNNs;
* `configs` — named network configs (full + smoke variants).
"""

from repro.vision.layers import (QConv2D, QDepthwiseConv2D, QLinear,
                                 QMaxPool2D, QAvgPool2D, QResidualAdd,
                                 conv_tap, fold_add_requant,
                                 fold_avgpool_requant, quantize_depthwise)
from repro.vision.models import (LayerDef, VisionConfig, QuantizedVisionNet,
                                 collect_absmax, init_fp, forward_fp,
                                 forward_int, quantize_net, quantize_input,
                                 trace_shapes, vision_artifact_bytes)
from repro.vision.configs import get_vision_config, VISION_CONFIGS
