"""STE fake-quantization primitives — the grid-matching half of QAT.

Quantization-aware training simulates the deployed integer grids inside
the float forward (fake-quant: quantize-dequantize) and trains through
the staircase with the straight-through estimator (Bengio et al.; PACT
for the learned activation range). The whole value of the exercise rests
on one invariant, enforced by tests/test_qat.py:

    **Every fake-quant grid here is bit-exactly the grid the deployment
    pipeline folds.**

Concretely:

* `fake_quant_weight(w, bits)` quantizes on the per-tensor symmetric
  signed grid of `core.calibration.calibrate_weight` +
  `core.quantize.quantize` — the grid `vision.layers.quantize_conv_layer`
  / `quantize_linear_head` deploy. Same absmax floor (1e-8), same
  round-then-clip, same symmetric int_min = -int_max (2-bit => ternary).
* `fake_quant_weight(w, bits, per_channel=True)` matches the LM zoo's
  per-output-channel grids (`nn.layers.quantize_dense_weights`).
* `fake_quant_weight_segmented(w, runs)` applies a per-tensor grid *per
  output-channel run* — the exact composition
  `vision.layers.quantize_conv_layer_segmented` deploys (PR-9 contract:
  each run is a uniform layer over its column slice).
* `fake_quant_act(x, beta, bits)` is the unsigned alpha=0 activation grid
  of `QuantSpec.activation` with `quantize_net`'s 1e-6 beta floor; the
  clip-at-zero is the paper's ReLU-inherent QNT/ACT semantic.

So a trained model's weight *codes* and activation *grids* transfer into
`vision.models.quantize_net` without any re-quantization error: the only
train/deploy divergence left is f32 accumulation order vs exact int32
accumulation (boundary codes within +-1 LSB; see docs/architecture.md).

Gradient contract: `ste_quantize` differentiates as the clipped-identity
surrogate (1/eps inside the representable range, 0 outside; the grid
parameters eps get zero cotangent). Learned activation ranges (PACT)
flow through the *clip* surrogate instead: d/dbeta = 1 where x >= beta.
EMA ranges are tracked outside the gradient tape (`ema_update`).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import packing

WEIGHT_ABSMAX_FLOOR = 1e-8   # == core.calibration.calibrate_weight /
                             #    nn.layers.quantize_dense_weights
ACT_BETA_FLOOR = 1e-6        # == vision.models.quantize_net's absmax floor


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def ste_quantize(t, eps, lo: int, hi: int):
    """Integer codes ``clip(round(t / eps), lo, hi)`` (f32 values) with a
    straight-through gradient: d(codes)/dt = 1/eps where t lies inside
    the representable range [lo*eps, hi*eps], 0 outside — the derivative
    of the clipped-identity surrogate, scaled onto the code axis. The
    grid quantum ``eps`` (scalar or per-channel array, broadcastable
    against ``t``) receives a zero cotangent: ranges are EMA-tracked or
    PACT-learned through the clip surrogate, never through the rounding.
    """
    return jnp.clip(jnp.round(t / eps), lo, hi)


def _ste_fwd(t, eps, lo, hi):
    return ste_quantize(t, eps, lo, hi), (t, eps)


def _ste_bwd(lo, hi, res, g):
    t, eps = res
    inside = (t >= lo * eps) & (t <= hi * eps)
    dt = jnp.where(inside, g / eps, 0.0)
    # broadcast eps: reduce the cotangent back to eps's shape (all-zero,
    # but it must be shape-correct for jax)
    return dt.astype(t.dtype), jnp.zeros_like(eps)


ste_quantize.defvjp(_ste_fwd, _ste_bwd)


def weight_absmax(w, *, per_channel: bool = False):
    """The deployed grids' absmax statistic, stop-gradded and floored.

    per_channel=False: one scalar (`calibrate_weight`'s per-tensor grid).
    per_channel=True: per-output-channel over the last axis
    (`quantize_dense_weights`' reduction for a 2-D (K, N) weight)."""
    w = jnp.asarray(w)
    if per_channel:
        a = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)))
    else:
        a = jnp.max(jnp.abs(w))
    return jax.lax.stop_gradient(jnp.maximum(a, WEIGHT_ABSMAX_FLOOR))


def fake_quant_weight(w, bits: int, *, absmax=None,
                      per_channel: bool = False):
    """Quantize-dequantize ``w`` on the deployed symmetric signed W{bits}
    grid, STE gradient. ``absmax`` overrides the observed statistic
    (already floored/stop-gradded by the caller when given)."""
    int_max = packing.int_range(bits, True)[1]
    if absmax is None:
        absmax = weight_absmax(w, per_channel=per_channel)
    eps = absmax / int_max
    return eps * ste_quantize(w, eps, -int_max, int_max)


def fake_quant_weight_segmented(w, runs: Sequence[Tuple[int, int, int]]):
    """Per-run fake-quant over the last (output-channel) axis: each
    ``(n_start, n_end, bits)`` run gets its own per-tensor grid over its
    column slice — bit-matching the segmented deployment
    (`vision.layers.quantize_conv_layer_segmented`), where every run is
    packed as a uniform layer over that slice."""
    parts = [fake_quant_weight(w[..., s:e], b) for s, e, b in runs]
    return jnp.concatenate(parts, axis=-1)


def fake_quant_act(x, beta, bits: int, *, learned: bool = False):
    """Unsigned alpha=0 activation fake-quant (`QuantSpec.activation`).

    The clip at zero *is* the ReLU (the paper folds it into QNT/ACT).
    EMA mode (default): ``beta`` is a tracked range — stop-gradded here.
    ``learned=True`` (PACT): gradients reach ``beta`` through the clip
    surrogate (d/dbeta = 1 where x >= beta)."""
    int_max = packing.int_range(bits, False)[1]
    beta = jnp.maximum(jnp.asarray(beta, jnp.float32), ACT_BETA_FLOOR)
    if not learned:
        beta = jax.lax.stop_gradient(beta)
    eps = beta / int_max
    x_c = jnp.clip(x, 0.0, beta)
    sg = jax.lax.stop_gradient
    q = sg(eps) * ste_quantize(sg(x), sg(eps), 0, int_max)
    return x_c + sg(q - x_c)


def batch_absmax(t):
    """Observed |t| max for range tracking (stop-gradded scalar)."""
    return jax.lax.stop_gradient(jnp.max(jnp.abs(t)))


def ema_update(prev, observed, momentum: float = 0.9):
    """EMA absmax tracking; a zero-initialized range snaps to the first
    observation instead of averaging against 0."""
    observed = jax.lax.stop_gradient(observed)
    return jnp.where(prev > 0.0,
                     momentum * prev + (1.0 - momentum) * observed,
                     observed)
