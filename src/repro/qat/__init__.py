"""QAT -> deploy accuracy subsystem.

Closes calibrate->plan->pack->serve into
train->calibrate->plan->pack->serve->measure:

  fakequant   STE fake-quant primitives, bit-matching the deployed grids
  data        hermetic seeded 16x16 digit dataset (+ optional real MNIST)
  train       jitted QAT loop on the vision graphs (AdamW, EMA/PACT
              ranges, plan-resolved per-layer/segmented widths)
  evaluate    integer-path (forward_int) accuracy of the packed artifact

Entry points: `examples/train_qat.py`, `python -m repro.launch.qat`,
`benchmarks/accuracy.py` (-> BENCH_accuracy.json).
"""
from repro.qat.fakequant import (fake_quant_act, fake_quant_weight,
                                 fake_quant_weight_segmented, ste_quantize)
from repro.qat.train import QATConfig, QATResult, train_qat
from repro.qat.evaluate import deploy, evaluate_int, fold_check

__all__ = [
    "ste_quantize", "fake_quant_weight", "fake_quant_weight_segmented",
    "fake_quant_act", "QATConfig", "QATResult", "train_qat", "deploy",
    "evaluate_int", "fold_check",
]
