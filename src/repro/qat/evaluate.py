"""Integer-path evaluation of the deployed QAT artifact.

The number that matters is not the float (or fake-quant) model's
accuracy — it is the accuracy of the *packed integer artifact* the
serving path runs: `vision.models.quantize_net` -> `forward_int`
(uint{a_bits} integer images at every edge, int32 accumulation, eq. 3/4
requantization — through the kernel registry, segmented mixed-precision
plans included). Everything in `BENCH_accuracy.json` reports this.

`deploy` folds a `qat.train.QATResult` without any re-calibration: the
EMA/PACT activation ranges ARE the deployment absmax, and the weight
grids are re-derived by the same `calibrate_weight` statistic the
fake-quant used, so the integer codes are bit-exactly the codes training
simulated (`fold_check` asserts this). The residual train/deploy gap is
f32 vs int32 accumulation order — boundary codes within ~1 LSB, measured
by `edge_agreement`."""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.calibration import calibrate_weight
from repro.core.quantize import dequantize, quantize
from repro.deploy.policy import PrecisionPlan
from repro.obs import trace as obs
from repro.qat import fakequant as fq
from repro.qat.train import ACT_KEY, QATResult, qat_forward
from repro.vision.models import (COMPUTE_KINDS, QuantizedVisionNet,
                                 forward_int, get_path, quantize_input,
                                 quantize_net)


def deploy(result: QATResult, *, plan: Optional[PrecisionPlan] = None,
           default_w_bits: Optional[int] = None,
           backend: Optional[str] = None) -> QuantizedVisionNet:
    """Fold a trained result into the deployable integer artifact.

    Defaults deploy exactly what was trained (the result's plan and
    w_bits); pass ``plan``/``default_w_bits`` to deploy the same weights
    under a different quantization (the PTQ rows: float-trained params
    packed at W{8,4,2})."""
    if plan is None and default_w_bits is None:
        plan = result.plan
    if default_w_bits is None:
        default_w_bits = result.qc.w_bits or 8
    return quantize_net(result.cfg, result.model_params(),
                        result.deployment_absmax(), plan=plan,
                        default_w_bits=default_w_bits, backend=backend)


def evaluate_int(qnet: QuantizedVisionNet, batches, *,
                 backend: Optional[str] = None, mesh=None) -> dict:
    """Integer-path accuracy of the deployed artifact over ``batches``
    of (images, labels). Raw int32 logits; argmax needs no dequant."""
    correct = n = 0
    with obs.span("qat.evaluate_int", cat="qat",
                  net=qnet.cfg.name) as sp:
        for x, y in batches:
            x_hat = quantize_input(qnet, jnp.asarray(x, jnp.float32))
            logits = forward_int(qnet, x_hat, backend=backend, mesh=mesh)
            preds = np.asarray(jnp.argmax(logits, axis=-1))
            correct += int((preds == np.asarray(y)).sum())
            n += len(preds)
        acc = correct / max(n, 1)
        sp.set(images=n, accuracy=acc)
    obs.counter("qat.images_evaluated").add(n)
    return {"accuracy": acc, "correct": correct, "n": n}


def evaluate_fq(result: QATResult, batches) -> dict:
    """Accuracy of the train-time fake-quant forward (the float view of
    the same grids) — the reference `evaluate_int` is compared against."""
    correct = n = 0
    betas = (result.params[ACT_KEY] if result.qc.learned_absmax
             else result.absmax)
    for x, y in batches:
        logits, _ = qat_forward(result.cfg, result.params,
                                jnp.asarray(x, jnp.float32), betas,
                                lquant=result.lquant,
                                a_bits=result.qc.a_bits,
                                learned=result.qc.learned_absmax)
        preds = np.asarray(jnp.argmax(logits, axis=-1))
        correct += int((preds == np.asarray(y)).sum())
        n += len(preds)
    return {"accuracy": correct / max(n, 1), "correct": correct, "n": n}


def fold_check(result: QATResult) -> None:
    """Assert the grid-matching invariant on the trained weights: for
    every compute layer, the fake-quant values the last training step
    used are EXACTLY dequantize(quantize(w)) on the deployment grid —
    same codes, same quantum, zero re-quantization error at fold time.
    Raises AssertionError with the offending path."""
    if result.lquant is None:
        raise ValueError("float-trained result has no quantization to "
                         "check; train with w_bits or a plan")
    params = result.model_params()
    for L in result.cfg.layers:
        if L.kind not in COMPUTE_KINDS:
            continue
        w = jnp.asarray(get_path(params, L.path)["w"], jnp.float32)
        lq = result.lquant[L.path]
        runs = lq.segments or ((0, int(w.shape[-1]), lq.w_bits),)
        fq_w = (fq.fake_quant_weight_segmented(w, lq.segments)
                if lq.segments is not None
                else fq.fake_quant_weight(w, lq.w_bits))
        deployed = []
        for s, e, b in runs:
            spec = calibrate_weight(w[..., s:e], b)
            deployed.append(dequantize(quantize(w[..., s:e], spec), spec))
        dep = jnp.concatenate(deployed, axis=-1)
        if not bool(jnp.all(fq_w == dep)):
            bad = int(jnp.sum(fq_w != dep))
            raise AssertionError(
                f"{L.path}: fake-quant values diverge from the deployed "
                f"grid on {bad} weight(s) — the grid-matching invariant "
                "is broken")


def edge_agreement(result: QATResult, qnet: QuantizedVisionNet,
                   x_batch) -> dict:
    """Compare the integer forward's edge codes against the fake-quant
    forward's values quantized onto the same grids.

    f32 conv accumulation cannot reproduce int32 accumulation to 0.5 ULP
    of a ~2^20-scale accumulator, so exact equality is not the contract;
    the honest one (docs/architecture.md) is boundary codes within +-1
    LSB almost everywhere plus argmax agreement. Returns
    {"within_1lsb": frac, "max_dev": int, "argmax_agree": frac}."""
    x = jnp.asarray(x_batch, jnp.float32)
    betas = result.deployment_absmax()

    fq_edges: Dict[str, jnp.ndarray] = {}
    logits_fq, _ = qat_forward(
        result.cfg, result.params, x,
        {k: jnp.asarray(v) for k, v in betas.items()},
        lquant=result.lquant, a_bits=result.qc.a_bits,
        learned=False, edge_tap=lambda p, t: fq_edges.setdefault(p, t))

    int_edges: Dict[str, jnp.ndarray] = {}
    x_hat = quantize_input(qnet, x)
    logits_int = forward_int(qnet, x_hat,
                             collect=lambda p, t: int_edges.setdefault(p, t))

    total = within = 0
    max_dev = 0
    a_bits = result.qc.a_bits
    from repro.core.quantize import QuantSpec
    for path, fq_val in fq_edges.items():
        if path not in int_edges or path == "__input__":
            continue
        beta = max(betas[path], 1e-6)
        spec = QuantSpec.activation(a_bits, beta)
        codes_fq = jnp.round(fq_val / spec.eps).astype(jnp.int32)
        codes_int = int_edges[path].astype(jnp.int32)
        dev = jnp.abs(codes_fq - codes_int)
        total += int(dev.size)
        within += int(jnp.sum(dev <= 1))
        max_dev = max(max_dev, int(jnp.max(dev)))
    agree = float(jnp.mean((jnp.argmax(logits_fq, -1)
                            == jnp.argmax(logits_int, -1)
                            ).astype(jnp.float32)))
    return {"within_1lsb": within / max(total, 1), "max_dev": max_dev,
            "argmax_agree": agree}
