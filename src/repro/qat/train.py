"""Jitted QAT loop for the vision nets (fake-quant forward + AdamW).

One training loop serves four roles in the accuracy benchmark:

* **QAT uniform** — ``w_bits in {8,4,2}``: every compute layer's weights
  fake-quantized per-tensor, every requantizing edge fake-quantized on
  the unsigned a_bits grid (EMA-tracked absmax; ``learned_absmax=True``
  switches to PACT learned ranges).
* **QAT planned** — ``plan=`` a `PrecisionPlan`: per-layer widths (and
  per-output-channel-run segment widths, PR-9) resolved through the same
  `resolve_qcfg` the deployment packer uses, so training quantizes
  exactly what will deploy.
* **Float / PTQ baseline** — ``w_bits=None``: plain float training; the
  EMA absmax tracker still runs, so the trained result carries its own
  activation calibration for the post-training-quantization rows.
* **Fine-tune from checkpoint** — ``from_ckpt=`` restores a previous
  state (`repro.ckpt`) and continues (the `launch.qat --from-ckpt` path).

The fake-quant forward mirrors `vision.models.forward_fp` edge-for-edge:
requantizing layers (conv, dwconv, global avg-pool, residual add) get an
activation fake-quant at their output; grid-preserving layers (max pool)
inherit; the head emits raw float logits (deployment keeps raw int32
logits — argmax needs no grid). Saved side edges carry the *fake-quanted*
value, matching the deployed dataflow where skips read integer images.

Optimizer is the shared `train.optimizer` AdamW (cosine schedule, decay
on matrices only — so EMA/PACT scalars are never decayed). ``mesh=``
shards the image batch data-parallel over ``mesh.shape['data']`` devices
(the `parallel/` dp path); state stays replicated.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.deploy.policy import PrecisionPlan, resolve_qcfg
from repro.nn.layers import QuantConfig
from repro.obs import trace as obs
from repro.qat import fakequant as fq
from repro.train.optimizer import OptConfig, adamw_init, adamw_update
from repro.vision import layers as vl
from repro.vision.models import COMPUTE_KINDS, VisionConfig, get_path

ACT_KEY = "__act_absmax__"   # learned-range leaves live inside params


@dataclasses.dataclass(frozen=True)
class QATConfig:
    steps: int = 200
    batch: int = 64
    lr: float = 1e-2
    warmup: int = 20
    weight_decay: float = 1e-4
    clip_norm: float = 1.0
    w_bits: Optional[int] = 8     # None => float training (PTQ baseline)
    a_bits: int = 8
    ema_momentum: float = 0.9
    learned_absmax: bool = False  # PACT learned ranges instead of EMA
    seed: int = 0
    log_every: int = 20
    ckpt_every: int = 50


@dataclasses.dataclass(frozen=True)
class LayerQuant:
    """Static per-compute-layer quantization resolved from the plan."""

    w_bits: int
    segments: Optional[Tuple[Tuple[int, int, int], ...]] = None


def resolve_layer_quant(cfg: VisionConfig, plan: Optional[PrecisionPlan],
                        default_w_bits: int, a_bits: int
                        ) -> Dict[str, LayerQuant]:
    """Per-path (w_bits, segments) through the deployment's own
    `resolve_qcfg` — training and packing can never disagree on widths."""
    base = QuantConfig(mode="int", w_bits=default_w_bits, a_bits=a_bits)
    out = {}
    for L in cfg.layers:
        if L.kind not in COMPUTE_KINDS:
            continue
        qcfg = resolve_qcfg(plan, L.path, base)
        segs = (tuple(tuple(r) for r in qcfg.segments)
                if qcfg.segments is not None else None)
        out[L.path] = LayerQuant(w_bits=qcfg.w_bits, segments=segs)
    return out


def _fq_w(w, lq: Optional[LayerQuant]):
    if lq is None:
        return w
    if lq.segments is not None:
        return fq.fake_quant_weight_segmented(w, lq.segments)
    return fq.fake_quant_weight(w, lq.w_bits)


def qat_forward(cfg: VisionConfig, params: dict, x, betas: Dict[str, jnp.ndarray],
                *, lquant: Optional[Dict[str, LayerQuant]], a_bits: int,
                learned: bool = False,
                edge_tap: Optional[Callable] = None):
    """Fake-quant forward; returns (float logits, observed absmax).

    ``lquant=None`` disables all fake-quant (float forward) while still
    observing ranges. ``observed`` maps "__input__" and every
    requantizing layer path to the batch's pre-quantization absmax (the
    EMA update signal). ``edge_tap(path, fq_value)`` observes every
    fake-quanted edge (the fold-losslessness tests)."""
    quant = lquant is not None
    observed: Dict[str, jnp.ndarray] = {}

    def act(path, t, relu=False):
        # float mode mirrors forward_fp exactly (ReLU only where the fp
        # graph has one); quant mode's clip-at-zero IS the ReLU, and on
        # the relu-free edges (add/avgpool/input) operands are already
        # non-negative unsigned images, so the clip is a no-op there
        observed[path] = fq.batch_absmax(t)
        if not quant:
            return jnp.maximum(t, 0.0) if relu else t
        y = fq.fake_quant_act(t, betas[path], a_bits, learned=learned)
        if edge_tap is not None:
            edge_tap(path, y)
        return y

    stream = act("__input__", x)
    edges: Dict[str, jnp.ndarray] = {}
    for L in cfg.layers:
        xin = edges[L.input_from] if L.input_from else stream
        if L.kind == "conv":
            p = get_path(params, L.path)
            w = _fq_w(p["w"], lquant.get(L.path) if quant else None)
            y = vl.conv2d_raw(xin, w, stride=L.stride, padding=L.padding)
            y = y * p["bn_scale"] + p["bn_bias"]
            y = act(L.path, y, relu=True)
        elif L.kind == "dwconv":
            p = get_path(params, L.path)
            w = _fq_w(p["w"], lquant.get(L.path) if quant else None)
            c = w.shape[-1]
            y = vl.conv2d_raw(xin, w.reshape(*w.shape[:2], 1, c),
                              stride=L.stride, padding=L.padding, groups=c)
            y = y * p["bn_scale"] + p["bn_bias"]
            y = act(L.path, y, relu=True)
        elif L.kind == "maxpool":
            y = vl.maxpool_fp(xin, L.window, L.stride)   # grid-preserving
        elif L.kind == "avgpool_global":
            y = act(L.path, vl.avgpool_global_fp(xin))
        elif L.kind == "add":
            y = act(L.path, xin + edges[L.skip_from])
        elif L.kind == "linear":
            p = get_path(params, L.path)
            w = _fq_w(p["w"], lquant.get(L.path) if quant else None)
            y = xin @ w                                  # raw logits
        else:
            raise ValueError(f"{L.path}: unknown kind {L.kind!r}")
        if L.save_as:
            edges[L.save_as] = y
        if not L.branch:
            stream = y
    return stream, observed


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None],
                                         axis=-1))


def make_qat_step(cfg: VisionConfig, qc: QATConfig,
                  lquant: Optional[Dict[str, LayerQuant]],
                  opt_cfg: OptConfig):
    """One jit-able (state, batch) -> (state, metrics) QAT step."""

    def loss_fn(params, absmax, x, y):
        betas = params[ACT_KEY] if qc.learned_absmax else absmax
        logits, observed = qat_forward(
            cfg, params, x, betas, lquant=lquant, a_bits=qc.a_bits,
            learned=qc.learned_absmax)
        loss = cross_entropy(logits, y)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return loss, (observed, acc)

    def step(state, batch):
        (loss, (observed, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], state["absmax"],
                                   batch["x"], batch["y"])
        new_p, new_opt, om = adamw_update(state["params"], grads,
                                          state["opt"], opt_cfg)
        new_absmax = {k: fq.ema_update(v, observed[k], qc.ema_momentum)
                      for k, v in state["absmax"].items()}
        return ({"params": new_p, "absmax": new_absmax, "opt": new_opt},
                {"loss": loss, "acc": acc, **om})

    return step


def _absmax_paths(cfg: VisionConfig):
    """The edges that carry their own activation grid at deployment:
    the net input plus every requantizing layer (`quantize_net`'s
    `out_spec` calls)."""
    paths = ["__input__"]
    for L in cfg.layers:
        if L.kind in ("conv", "dwconv", "avgpool_global", "add"):
            paths.append(L.path)
    return paths


@dataclasses.dataclass
class QATResult:
    """Trained artifact: params + activation ranges + the quantization
    the net was trained under (what `qat.evaluate.deploy` folds)."""

    cfg: VisionConfig
    qc: QATConfig
    params: dict                      # may carry ACT_KEY learned ranges
    absmax: Dict[str, jnp.ndarray]    # EMA-tracked per-edge ranges
    lquant: Optional[Dict[str, LayerQuant]]
    plan: Optional[PrecisionPlan]
    log: list

    def model_params(self) -> dict:
        """Params without the learned-range leaves (what deploys)."""
        return {k: v for k, v in self.params.items() if k != ACT_KEY}

    def deployment_absmax(self) -> Dict[str, float]:
        """Per-edge absmax for `vision.models.quantize_net` — the
        trained ranges ARE the deployment calibration (no re-calibration
        pass: the grids fold identically by construction)."""
        src = (self.params[ACT_KEY] if self.qc.learned_absmax
               else self.absmax)
        return {k: float(v) for k, v in src.items()}


def train_qat(cfg: VisionConfig, data, qc: QATConfig, *,
              plan: Optional[PrecisionPlan] = None,
              init_params: Optional[dict] = None,
              mesh=None, ckpt_dir=None, from_ckpt=None) -> QATResult:
    """Train ``cfg`` on ``data`` (the `qat.data` iterator API).

    ``plan`` resolves per-layer (segmented) widths; ``mesh`` shards the
    batch over the 'data' axis; ``ckpt_dir``/``from_ckpt`` save/resume
    full training state through `repro.ckpt.checkpoint`."""
    from repro.vision.models import init_fp

    lquant = (None if qc.w_bits is None and plan is None
              else resolve_layer_quant(cfg, plan, qc.w_bits or 8,
                                       qc.a_bits))
    opt_cfg = OptConfig(lr=qc.lr, warmup=qc.warmup, total_steps=qc.steps,
                        weight_decay=qc.weight_decay,
                        clip_norm=qc.clip_norm)

    batches = data.batches(qc.batch, qc.steps)
    start_step = 0
    if from_ckpt is not None:
        from repro.ckpt import checkpoint as ckpt
        state, start_step = ckpt.restore(from_ckpt)
    else:
        if init_params is not None:
            params = init_params
        else:
            # init_fp's bn_scale ~0.4 is tuned for the deploy smoke
            # nets' activation headroom; training from scratch through
            # three such attenuating affines stalls (the "BN" here is a
            # fixed fold-style affine, not a normalizer). Unit scale
            # trains cleanly and the EMA absmax adapts the grids anyway.
            params = init_fp(cfg, seed=qc.seed)
            for L in cfg.layers:
                if L.kind in ("conv", "dwconv"):
                    node = dict(get_path(params, L.path))
                    node["bn_scale"] = jnp.ones_like(node["bn_scale"])
                    parts = L.path.split("/")
                    parent = params
                    for p in parts[:-1]:
                        parent = parent[p]
                    parent[parts[-1]] = node
        # seed the ranges from one real batch (deterministic: the
        # observation forward is float and tap-free) so step 0 already
        # fake-quantizes on sane grids
        x0, y0 = next(batches)
        _, obs0 = qat_forward(cfg, params, jnp.asarray(x0), {},
                              lquant=None, a_bits=qc.a_bits)
        absmax = {k: jnp.asarray(float(obs0[k]), jnp.float32)
                  for k in _absmax_paths(cfg)}
        if qc.learned_absmax:
            params = dict(params)
            params[ACT_KEY] = {k: jnp.asarray(float(obs0[k]), jnp.float32)
                               for k in _absmax_paths(cfg)}
        state = {"params": params, "absmax": absmax,
                 "opt": adamw_init(params, opt_cfg)}

    step_fn = jax.jit(make_qat_step(cfg, qc, lquant, opt_cfg))
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        data_shard = NamedSharding(mesh, P("data"))

    log = []
    with obs.span("qat.train", cat="qat", net=cfg.name,
                  steps=qc.steps, w_bits=(qc.w_bits or 0),
                  a_bits=qc.a_bits, planned=plan is not None) as sp:
        for i in range(start_step, qc.steps):
            try:
                x, y = next(batches)
            except StopIteration:
                batches = data.batches(qc.batch, qc.steps)
                x, y = next(batches)
            batch = {"x": jnp.asarray(x, jnp.float32),
                     "y": jnp.asarray(y, jnp.int32)}
            if mesh is not None:
                batch = {k: jax.device_put(v, data_shard)
                         for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            obs.counter("qat.steps").add(1)
            if (i % qc.log_every == 0) or (i == qc.steps - 1):
                log.append({"step": i,
                            "loss": float(metrics["loss"]),
                            "acc": float(metrics["acc"])})
            if ckpt_dir is not None and ((i + 1) % qc.ckpt_every == 0
                                         or i == qc.steps - 1):
                from repro.ckpt import checkpoint as ckpt
                ckpt.save(ckpt_dir, i + 1, state)
        if log:
            sp.set(final_loss=log[-1]["loss"], final_acc=log[-1]["acc"])

    return QATResult(cfg=cfg, qc=qc, params=state["params"],
                     absmax=state["absmax"], lquant=lquant, plan=plan,
                     log=log)
