"""Hermetic MNIST-scale datasets behind one iterator API.

The default is a procedurally rendered 16x16 digit dataset
(BitNetMCU-scale: 16x16x1, 10 classes): a 5x7 glyph per class, 2x
up-scaled onto the canvas with per-sample position jitter, intensity
scaling and additive Gaussian noise. Entirely seeded — **replaying a
split is byte-identical** (tests/test_qat.py pins this), so every
accuracy number in `BENCH_accuracy.json` is reproducible from the seed
alone, with no data download in CI.

An optional on-disk real-MNIST loader (`MNISTDigits`) reads the classic
IDX files when a data dir is provided, nearest-resampled to the same
16x16 geometry; it is never exercised in CI (no download) but shares the
iterator API, so the QAT loop/benchmark run on real data unchanged:

    ds = make_dataset("synthetic", split="train", seed=0)
    for x, y in ds.batches(64, 100):   # x (64,16,16,1) f32, y (64,) i32
        ...

`batches()` re-derives its rng from (seed, split) on every call: two
iterations of the same dataset object — or of two equally-configured
objects — yield identical bytes.
"""
from __future__ import annotations

import dataclasses
import gzip
import pathlib
import struct
from typing import Iterator, Optional, Tuple

import numpy as np

SIDE = 16
NUM_CLASSES = 10

# 5x7 digit glyphs ('#' = on) — rendered, not copied from any font file.
_GLYPHS = (
    (" ### ", "#   #", "#  ##", "# # #", "##  #", "#   #", " ### "),  # 0
    ("  #  ", " ##  ", "  #  ", "  #  ", "  #  ", "  #  ", " ### "),  # 1
    (" ### ", "#   #", "    #", "  ## ", " #   ", "#    ", "#####"),  # 2
    (" ### ", "#   #", "    #", "  ## ", "    #", "#   #", " ### "),  # 3
    ("   # ", "  ## ", " # # ", "#  # ", "#####", "   # ", "   # "),  # 4
    ("#####", "#    ", "#### ", "    #", "    #", "#   #", " ### "),  # 5
    (" ### ", "#    ", "#    ", "#### ", "#   #", "#   #", " ### "),  # 6
    ("#####", "    #", "   # ", "  #  ", "  #  ", " #   ", " #   "),  # 7
    (" ### ", "#   #", "#   #", " ### ", "#   #", "#   #", " ### "),  # 8
    (" ### ", "#   #", "#   #", " ####", "    #", "    #", " ### "),  # 9
)

_SPLIT_IDS = {"train": 0, "test": 1, "val": 2}


def _glyph_arrays() -> np.ndarray:
    """(10, 14, 10) f32 — each 5x7 glyph 2x nearest-upscaled."""
    out = np.zeros((NUM_CLASSES, 14, 10), np.float32)
    for d, rows in enumerate(_GLYPHS):
        g = np.array([[1.0 if ch == "#" else 0.0 for ch in r]
                      for r in rows], np.float32)
        out[d] = np.kron(g, np.ones((2, 2), np.float32))
    return out


_GLYPH_CACHE = _glyph_arrays()


@dataclasses.dataclass(frozen=True)
class SyntheticDigits:
    """Seeded procedural 16x16 digit classes (the hermetic default)."""

    split: str = "train"
    seed: int = 0
    noise: float = 0.18
    jitter: int = 2
    side: int = SIDE
    classes: int = NUM_CLASSES

    def __post_init__(self):
        if self.split not in _SPLIT_IDS:
            raise ValueError(f"unknown split {self.split!r}; expected one "
                             f"of {sorted(_SPLIT_IDS)}")

    def _rng(self) -> np.random.Generator:
        # re-derived per batches() call => byte-identical replay
        return np.random.default_rng(
            (int(self.seed), _SPLIT_IDS[self.split], 0xD161))

    def batches(self, batch_size: int, n_batches: int
                ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = self._rng()
        gh, gw = _GLYPH_CACHE.shape[1:]
        base_r = (self.side - gh) // 2
        base_c = (self.side - gw) // 2
        for _ in range(n_batches):
            y = rng.integers(0, self.classes, size=batch_size)
            x = np.zeros((batch_size, self.side, self.side, 1), np.float32)
            dr = rng.integers(-self.jitter, self.jitter + 1,
                              size=batch_size)
            dc = rng.integers(-self.jitter, self.jitter + 1,
                              size=batch_size)
            inten = rng.uniform(0.6, 1.0, size=batch_size)
            for i in range(batch_size):
                r = int(np.clip(base_r + dr[i], 0, self.side - gh))
                c = int(np.clip(base_c + dc[i], 0, self.side - gw))
                x[i, r:r + gh, c:c + gw, 0] = \
                    _GLYPH_CACHE[y[i]] * inten[i]
            x += rng.normal(0.0, self.noise,
                            size=x.shape).astype(np.float32)
            np.clip(x, 0.0, 1.0, out=x)
            yield x, y.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class MNISTDigits:
    """Real-MNIST loader (classic IDX gz files in ``data_dir``),
    nearest-resampled 28 -> 16 so the same nets apply. Optional — raises
    FileNotFoundError when the files are absent."""

    data_dir: str
    split: str = "train"
    seed: int = 0
    side: int = SIDE
    classes: int = NUM_CLASSES

    def _load(self) -> Tuple[np.ndarray, np.ndarray]:
        stem = "train" if self.split == "train" else "t10k"
        d = pathlib.Path(self.data_dir)
        imgs = _read_idx(d / f"{stem}-images-idx3-ubyte.gz")
        labels = _read_idx(d / f"{stem}-labels-idx1-ubyte.gz")
        sel = np.round(np.linspace(0, imgs.shape[1] - 1,
                                   self.side)).astype(int)
        x = imgs[:, sel][:, :, sel].astype(np.float32) / 255.0
        return x[..., None], labels.astype(np.int32)

    def batches(self, batch_size: int, n_batches: int
                ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        x, y = self._load()
        rng = np.random.default_rng(
            (int(self.seed), _SPLIT_IDS.get(self.split, 1), 0xFEED))
        for _ in range(n_batches):
            idx = rng.integers(0, len(x), size=batch_size)
            yield x[idx], y[idx]


def _read_idx(path: pathlib.Path) -> np.ndarray:
    with gzip.open(path, "rb") as f:
        magic = struct.unpack(">HBB", f.read(4))
        ndim = magic[2]
        shape = struct.unpack(f">{ndim}I", f.read(4 * ndim))
        return np.frombuffer(f.read(), np.uint8).reshape(shape)


def make_dataset(name: str = "synthetic", *, split: str = "train",
                 seed: int = 0, data_dir: Optional[str] = None):
    """One constructor for both sources behind the iterator API."""
    if name == "synthetic":
        return SyntheticDigits(split=split, seed=seed)
    if name == "mnist":
        if not data_dir:
            raise ValueError("dataset 'mnist' needs data_dir with the "
                             "IDX .gz files")
        return MNISTDigits(data_dir=data_dir, split=split, seed=seed)
    raise KeyError(f"unknown dataset {name!r}; expected 'synthetic' or "
                   "'mnist'")
