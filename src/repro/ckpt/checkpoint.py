"""Sharded, atomic, elastic checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json      — tree structure, shapes, dtypes, step
            <leaf-path>.npy    — one file per leaf (full logical array)

Writes go to step_<N>.tmp/ then rename — a crashed writer never corrupts
the latest checkpoint (atomic-manifest pattern). `save_async` runs the
serialization on a worker thread, overlapping I/O with the next train
steps (checkpoint stall ≈ device->host copy only).

Elastic restore: leaves are saved as full logical arrays, so `restore`
can materialize them under a *different* mesh/sharding than they were
saved with — the node-count-change path of the fault-tolerance story.
(At real 1000-node scale per-shard files + resharding-on-read would
replace full-array files; the manifest/atomic-rename structure is the
same.)
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree.keys()):
            out.extend(_flatten(tree[k], path + (str(k),)))
        return out
    return [(path, tree)]


def _unflatten(leaves: dict):
    out: dict = {}
    for path, value in leaves.items():
        d = out
        parts = path.split("/")
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = value
    return out


def save(ckpt_dir, step: int, tree) -> pathlib.Path:
    """Synchronous atomic save of a pytree-of-arrays."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {"step": step, "leaves": {}}
    for path, leaf in _flatten(tree):
        key = "/".join(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training: device_get happens on call
    (cheap, blocking), file writes happen on the worker thread."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _work():
            try:
                save(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s:08d}",
                          ignore_errors=True)


def list_steps(ckpt_dir) -> list:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                (p / "manifest.json").exists():
            out.append(int(p.name[5:]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir, step: Optional[int] = None, shardings=None,
            mesh=None):
    """Load a checkpoint; optionally placing leaves with `shardings` (tree
    of NamedSharding matching the checkpoint tree) — this is the elastic
    path: the target mesh may differ from the one that saved."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(d / meta["file"])
        leaves[key] = arr
    tree = _unflatten(leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest["step"]
