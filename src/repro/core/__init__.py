"""repro.core — the paper's contribution: sub-byte integer-image QNN algebra,
chunk-planar packing, and the quantized-linear deployment artifact."""

from repro.core.packing import (CHUNK, pack, unpack, unpack_planes,
                                pack_factor, int_range, pad_to_chunk,
                                padded_size, planar_perm)
from repro.core.quantize import (QuantSpec, QuantizedLinearParams,
                                 quantize, dequantize, fake_quantize,
                                 lin, batchnorm_int, qnt_act,
                                 requantize_shift, requantize_shift_i64,
                                 fold_bn_requant, pick_requant_md,
                                 quantize_linear, M_BITS, D_MIN, D_MAX)
from repro.core.calibration import (calibrate_weight, calibrate_activation,
                                    RunningCalibrator)
