"""Integer-image quantization algebra — paper §III-A, eqs. (1)-(4), bit-exact.

Every QNN tensor t has a real range [alpha, beta) discretized on 2^N levels:

    t = alpha + eps * t_hat,      eps = (beta - alpha) / (2^N - 1)      (1)

with alpha_x = alpha_y = 0 for activations/outputs (so activation integer
images are unsigned). The three QNN operators act on integer images:

    LIN:      phi_hat   = sum_n w_hat[m,n] * x_hat[n]        (int32 accum) (2)
    BN:       phi'_hat  = kappa_hat * phi_hat + lambda_hat   (int32)       (3)
    QNT/ACT:  y_hat     = clip((m * phi'_hat) >> d, 0, 2^N-1)              (4)
              m = round(eps_phi' * 2^d / eps_y)

The requantization product m * phi' needs ~47 bits; the paper's RISC-V core
computes it with 32-bit ops. We reproduce (4) **exactly in int32** with a
high/low split valid for d >= 16 (see :func:`requantize_shift`); calibration
always produces d >= 16 because eps_phi'/eps_y << 1 in any sane QNN. The same
helper is used inside the Pallas kernel epilogue, so kernel and pure-jnp
paths are bit-identical; tests/hypothesis cross-check against a numpy int64
oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import packing

M_BITS = 15  # requant multiplier m in [0, 2^15): keeps every split term in int32
D_MIN, D_MAX = 16, 31


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Affine quantization grid for one tensor (eq. 1)."""

    bits: int
    signed: bool
    alpha: float  # range lower bound (0 for activations, per paper)
    beta: float

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1

    @property
    def eps(self) -> float:
        if self.signed:
            # symmetric grid: t = eps * t_hat, t_hat in [-int_max, int_max]
            # (most-negative code dropped; 2-bit signed => ternary {-1,0,1})
            return self.beta / self.int_max
        # unsigned: grid covers [alpha, beta] with int_max steps (int_max is
        # container-capped at 127 for 8-bit, see packing._INT_INFO)
        return (self.beta - self.alpha) / self.int_max

    @property
    def int_min(self) -> int:
        if self.signed:
            return -self.int_max  # symmetric
        return packing.int_range(self.bits, self.signed)[0]

    @property
    def int_max(self) -> int:
        return packing.int_range(self.bits, self.signed)[1]

    @staticmethod
    def activation(bits: int, beta: float) -> "QuantSpec":
        return QuantSpec(bits=bits, signed=False, alpha=0.0, beta=beta)

    @staticmethod
    def weight(bits: int, absmax: float) -> "QuantSpec":
        # symmetric signed grid for weights (paper's kernels are symmetric)
        return QuantSpec(bits=bits, signed=True, alpha=-absmax, beta=absmax)


def quantize(t, spec: QuantSpec):
    """Real tensor -> integer image (int8 container), eq. (1) inverted."""
    zero = 0.0 if spec.signed else spec.alpha
    t_hat = jnp.round((t - zero) / spec.eps)
    t_hat = jnp.clip(t_hat, spec.int_min, spec.int_max)
    return t_hat.astype(jnp.int8)


def dequantize(t_hat, spec: QuantSpec):
    zero = 0.0 if spec.signed else spec.alpha
    return zero + spec.eps * t_hat.astype(jnp.float32)


def fake_quantize(t, spec: QuantSpec):
    """Quantize-dequantize with straight-through estimator (QAT forward).

    Gradient is identity inside the representable range, zero outside
    (PACT-style clipped STE)."""
    import jax

    q = dequantize(quantize(t, spec), spec)
    lo = spec.alpha + spec.eps * spec.int_min if spec.signed else spec.alpha
    hi = spec.alpha + spec.eps * spec.int_max
    t_clip = jnp.clip(t, lo, hi)
    return t_clip + jax.lax.stop_gradient(q - t_clip)


# --- shared int8 side-channel codecs (optimizer state, gradient wire) ---
# Symmetric absmax int8 — the same grid family as `QuantSpec.weight` but
# jit-traced (scales are tensors, not floats) and shaped for streaming
# state, not packed serving artifacts. Two layouts:
#   rowwise   — scale per last-axis row; codes keep the param shape, so
#               ZeRO/GSPMD shardings propagate untouched (optimizer m)
#   blockwise — flat BLOCK-sized runs with one scale each; shape-agnostic
#               (the gradient compression wire format)

BLOCK = 256          # blockwise run length (gradient wire)


def quantize_int8_rowwise(x):
    """Per-row (last axis) symmetric int8: {"codes", "scale"}."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"codes": codes, "scale": scale[..., 0]}


def dequantize_int8_rowwise(s, shape=None):
    """Inverse of `quantize_int8_rowwise` (``shape`` accepted for
    signature-compatibility with the log-scale codec; codes already
    carry it)."""
    return s["codes"].astype(jnp.float32) * s["scale"][..., None]


def quantize_int8_blockwise(x):
    """Flat BLOCK-run symmetric int8 -> (codes (n/BLOCK, BLOCK), scale)."""
    n = x.size
    pad = (-n) % BLOCK
    xb = jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(
        jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8_blockwise(codes, scale, shape):
    """Inverse of `quantize_int8_blockwise`, cropping the pad."""
    import math
    x = codes.astype(jnp.float32) * scale
    return x.reshape(-1)[: math.prod(shape)].reshape(shape)


def lin(w_hat, x_hat):
    """Eq. (2): integer dot product with int32 accumulation."""
    return jnp.matmul(
        x_hat.astype(jnp.int8), w_hat.astype(jnp.int8),
        preferred_element_type=jnp.int32)


def batchnorm_int(phi, kappa, lam):
    """Eq. (3): per-output-channel integer batch-norm (int32 wraparound
    semantics, matching 32-bit RISC-V MAC)."""
    return phi * kappa.astype(jnp.int32) + lam.astype(jnp.int32)


def requantize_shift(phi, m, d):
    """Exact ``(m * phi) >> d`` (floor) in pure int32, for d in [16, 31].

    Split the 47-bit product: with hi = phi >> 16, lo = phi & 0xFFFF,
        m*phi = A * 2^16 + B,   A = m*hi + ((m*lo) >> 16),  B = (m*lo) & 0xFFFF
    and for s = d - 16 >= 0:  floor(m*phi / 2^d) = A >> s  exactly, because
    the discarded ``r*2^16 + B`` remainder is < 2^(s+16). Every intermediate
    fits int32 given m < 2^15. Used verbatim in the Pallas kernel epilogue.
    """
    phi = phi.astype(jnp.int32)
    m = m.astype(jnp.int32)
    hi = phi >> 16
    lo = phi & 0xFFFF
    mlo = m * lo
    a = m * hi + (mlo >> 16)
    return a >> (d - 16)


def requantize_shift_i64(phi, m, d):
    """numpy int64 oracle for :func:`requantize_shift` (tests only)."""
    phi = np.asarray(phi, dtype=np.int64)
    m = np.asarray(m, dtype=np.int64)
    return ((m * phi) >> d).astype(np.int64)


def qnt_act(phi_prime, m, d, out_bits: int):
    """Eq. (4): requantize + clip to the unsigned N-bit activation grid.

    The clip-at-zero implements the ReLU-style activation semantic the paper
    folds into QNT/ACT (alpha_y = 0).
    """
    y = requantize_shift(phi_prime, m, d)
    hi = packing.int_range(out_bits, False)[1]
    return jnp.clip(y, 0, hi).astype(jnp.int8)


def pick_requant_md(ratio: float, d_min: int = D_MIN) -> tuple:
    """Largest-precision ``(m, d)`` with ``m = round(ratio * 2^d) < 2^15``.

    ``ratio`` is the real requantization factor (eps_in / eps_out terms);
    ``d_min`` is the smallest admissible shift — `D_MIN` (16) when the
    requant runs through :func:`requantize_shift` (the int32 hi/lo split
    needs it), 0 for small-operand requants (e.g. residual add, where
    ``m * x`` fits int32 directly). Shared by `fold_bn_requant` and the
    vision-layer folds (avg-pool, residual add).
    """
    ratio = float(ratio)
    if ratio <= 0:
        raise ValueError("invalid quanta")
    d = min(D_MAX, int(np.floor(np.log2((1 << M_BITS) - 1) - np.log2(ratio))))
    if d < d_min:
        raise ValueError(
            f"requant ratio {ratio} too large for int32 requant "
            f"(d={d} < {d_min}); re-calibrate output quantum")
    return int(np.round(ratio * (1 << d))), d


def fold_bn_requant(eps_w: float, eps_x: float, eps_y: float,
                    bn_scale, bn_bias,
                    bits_out: int,
                    kappa_bits: int = 8):
    """Calibrate integer BN + QNT/ACT parameters from real-valued BN.

    Real pipeline:  y = clip((bn_scale * phi_real + bn_bias) / eps_y)
    with phi_real = eps_w*eps_x*phi_hat. We pick the accumulator quantum
    eps_phi' and integer kappa_hat (kappa_bits) per channel, lambda_hat int32,
    and (m, d) with m < 2^15, d in [16, 31], maximizing precision.

    Returns (kappa_hat i32[n], lambda_hat i32[n], m i32[n], d int scalar).
    """
    bn_scale = np.asarray(bn_scale, dtype=np.float64)
    bn_bias = np.asarray(bn_bias, dtype=np.float64)
    eps_phi = float(eps_w) * float(eps_x)

    # kappa_hat = round(bn_scale / eps_kappa); choose per-layer eps_kappa so
    # the largest channel scale uses the full kappa_bits range.
    kmax = max(np.abs(bn_scale).max(), 1e-12)
    eps_kappa = kmax / ((1 << (kappa_bits - 1)) - 1)
    kappa_hat = np.round(bn_scale / eps_kappa).astype(np.int32)
    eps_phi_p = eps_phi * eps_kappa
    lambda_hat = np.round(bn_bias / eps_phi_p).astype(np.int32)

    ratio = eps_phi_p / float(eps_y)
    # largest d in [D_MIN, D_MAX] with m = round(ratio * 2^d) < 2^M_BITS
    m_scalar, d = pick_requant_md(ratio)
    m = np.broadcast_to(np.int32(m_scalar), bn_scale.shape).copy()
    return (jnp.asarray(kappa_hat), jnp.asarray(lambda_hat),
            jnp.asarray(m), d)


@dataclasses.dataclass(frozen=True)
class QuantizedLinearParams:
    """Everything the integer GEMM needs — the deployable artifact."""

    w_packed: jnp.ndarray  # (K_pad/pf, N) int8 containers, chunk-planar
    w_bits: int
    a_bits: int
    a_signed: bool
    kappa: jnp.ndarray     # (N,) int32
    lam: jnp.ndarray       # (N,) int32
    m: jnp.ndarray         # (N,) int32
    d: int
    out_bits: int
    k_logical: int         # pre-padding K


@dataclasses.dataclass(frozen=True)
class SegmentedLinearParams:
    """Mixed-width deployable artifact: per-output-channel-run containers.

    ``w_flat`` is a `packing.pack_segmented` buffer whose runs over the
    output-feature axis are named by ``segmap`` (fine-grain mixed
    precision, Nadalini et al. 2307.01056). Epilogue vectors span the full
    N. `segment_params` views one run as a uniform
    `QuantizedLinearParams` — running each segment through the uniform
    kernel and concatenating along N is the mixed-operand kernel's
    bit-exactness oracle (and the segment-looping xla/eager backends).
    """

    w_flat: jnp.ndarray    # (total_bytes,) int8, panel-major segmented
    segmap: "packing.SegmentMap"
    a_bits: int
    a_signed: bool
    kappa: jnp.ndarray     # (N,) int32
    lam: jnp.ndarray       # (N,) int32
    m: jnp.ndarray         # (N,) int32
    d: int
    out_bits: int
    k_logical: int         # pre-padding K

    @property
    def n(self) -> int:
        return self.segmap.n

    def segment_params(self, index: int) -> QuantizedLinearParams:
        s, e, b = self.segmap.runs[index]
        return QuantizedLinearParams(
            w_packed=packing.segment_packed(self.w_flat, self.segmap,
                                            index, self.k_logical),
            w_bits=b, a_bits=self.a_bits, a_signed=self.a_signed,
            kappa=self.kappa[s:e], lam=self.lam[s:e], m=self.m[s:e],
            d=self.d, out_bits=self.out_bits, k_logical=self.k_logical)


def quantize_linear_segmented(w_hat, segmap, kappa, lam, m, *,
                              a_bits: int, a_signed: bool, d: int,
                              out_bits: int,
                              assert_range: bool = False
                              ) -> SegmentedLinearParams:
    """Pack already-quantized int8 weight values (K, N) at per-run widths.

    The integer-side companion of `quantize_linear` for segmented
    containers: values must already sit on each run's ``w_bits`` grid
    (``assert_range=True`` arms the truncation guard per run).
    """
    k_logical = int(w_hat.shape[-2])
    return SegmentedLinearParams(
        w_flat=packing.pack_segmented(w_hat, segmap,
                                      assert_range=assert_range),
        segmap=segmap, a_bits=a_bits, a_signed=a_signed,
        kappa=jnp.asarray(kappa, jnp.int32), lam=jnp.asarray(lam, jnp.int32),
        m=jnp.asarray(m, jnp.int32), d=d, out_bits=out_bits,
        k_logical=k_logical)


def quantize_linear(w, spec_w: QuantSpec, bn_scale, bn_bias,
                    spec_x: QuantSpec, spec_y: QuantSpec) -> QuantizedLinearParams:
    """Full deployment quantization of one linear layer (paper's pipeline)."""
    w_hat = quantize(w, spec_w)                       # (K, N) int8
    k_logical = w_hat.shape[0]
    w_hat = packing.pad_to_chunk(w_hat, axis=0)
    w_packed = packing.pack(w_hat, spec_w.bits, axis=0)
    kappa, lam, m, d = fold_bn_requant(
        spec_w.eps, spec_x.eps, spec_y.eps, bn_scale, bn_bias, spec_y.bits)
    return QuantizedLinearParams(
        w_packed=w_packed, w_bits=spec_w.bits, a_bits=spec_x.bits,
        a_signed=spec_x.signed, kappa=kappa, lam=lam, m=m, d=d,
        out_bits=spec_y.bits, k_logical=k_logical)
