"""Sub-byte pack/unpack — the storage layer of the XpulpNN reproduction.

The paper's `nibble` (4-bit) and `crumb` (2-bit) SIMD operands live packed in
32-bit registers; on TPU we store them packed in int8 *containers* in HBM and
unpack inside the Pallas kernel (VREG-level shifts), mirroring the paper's
"no unpack overhead when the ISA supports it natively" argument: unpacking
costs shift+mask ALU work overlapped with the MXU, not extra memory traffic.

Layout: **chunk-planar packing** along the reduction (K) axis.  Within each
chunk of ``CHUNK = 128`` logical elements, the packed byte ``j`` of the chunk
holds logical elements ``j, j+64`` (4-bit) or ``j, j+32, j+64, j+96`` (2-bit)
in its low→high bit-fields.  Planar layout means the kernel unpacks a packed
tile into ``pack_factor`` *contiguous* sub-tiles (cheap static slices — no
lane interleave), and because integer accumulation is order-invariant the
matmul can consume the sub-tiles in planar order as long as the *other*
operand is sliced with the same chunk-planar order.  This is the TPU analogue
of Marlin-style permuted weight packing.

All functions are pure jnp and usable both on host (packing checkpoints) and
inside kernels (unpacking blocks).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Logical elements per packing chunk. The packed chunk is CHUNK // pack_factor
# containers: 64 bytes for 4-bit, 32 bytes for 2-bit — both sublane-aligned
# for int8 TPU tiles. K dims must be padded to a multiple of CHUNK.
CHUNK = 128

# NOTE: unsigned 8-bit caps at 127, not 255 — containers are int8 and
# XLA's dot_general has no mixed-signedness mode (unlike pv.sdotusp on the
# paper's ISA), so byte activations sacrifice 1 bit of range. The paper's
# focus (nibble/crumb) is unaffected. See DESIGN.md assumption changes.
_INT_INFO = {
    8: (-128, 127, 0, 127),
    4: (-8, 7, 0, 15),
    2: (-2, 1, 0, 3),
}


def pack_factor(bits: int) -> int:
    if bits not in (8, 4, 2):
        raise ValueError(f"unsupported bitwidth {bits}")
    return 8 // bits


def int_range(bits: int, signed: bool) -> tuple[int, int]:
    lo_s, hi_s, lo_u, hi_u = _INT_INFO[bits]
    return (lo_s, hi_s) if signed else (lo_u, hi_u)


def _check_last_axis(x, bits):
    if x.shape[-1] % CHUNK != 0:
        raise ValueError(
            f"packing axis ({x.shape[-1]}) must be a multiple of CHUNK={CHUNK}"
        )


def check_range(x, bits: int, signed: bool = True):
    """Assert every value of ``x`` fits the ``bits``-wide integer grid.

    ``pack`` keeps only the low ``bits`` bits, so an out-of-range value is
    silently truncated into a *different* in-range value — a corrupt
    artifact with no error. Host-side packing paths call this first; it
    forces concrete values (``np.asarray``) and therefore must not be used
    under jit/vmap tracing.
    """
    lo, hi = int_range(bits, signed)
    xv = np.asarray(x)
    if xv.size == 0:
        return
    saw_lo, saw_hi = int(xv.min()), int(xv.max())
    if saw_lo < lo or saw_hi > hi:
        raise ValueError(
            f"pack: values outside the {'signed' if signed else 'unsigned'} "
            f"{bits}-bit range [{lo}, {hi}] (saw min={saw_lo}, "
            f"max={saw_hi}); packing would silently truncate — "
            "quantize/clip first")


def pack(x, bits: int, axis: int = -1, *, assert_range: bool = False,
         signed: bool = True):
    """Pack sub-byte integer values (stored as int8) into int8 containers.

    ``x`` values must already be in the signed/unsigned range of ``bits``
    (packing only keeps the low ``bits`` bits, so signed and unsigned share
    one packer).  Packing is chunk-planar along ``axis``.

    ``assert_range=True`` raises instead of truncating out-of-range values
    (``signed`` selects the grid checked). Host/eager paths only — the check
    needs concrete values.
    """
    if assert_range:
        check_range(x, bits, signed)
    if bits == 8:
        return x.astype(jnp.int8)
    pf = pack_factor(bits)
    x = jnp.moveaxis(x, axis, -1)
    _check_last_axis(x, bits)
    *lead, k = x.shape
    sub = CHUNK // pf  # packed bytes per chunk
    # (..., n_chunks, pf, sub): plane p holds logical j = p*sub + j_in_plane
    planes = x.reshape(*lead, k // CHUNK, pf, sub).astype(jnp.int32)
    mask = (1 << bits) - 1
    out = jnp.zeros((*lead, k // CHUNK, sub), dtype=jnp.int32)
    for p in range(pf):
        out = out | ((planes[..., p, :] & mask) << (bits * p))
    out = out.reshape(*lead, k // pf).astype(jnp.int8)
    return jnp.moveaxis(out, -1, axis)


def unpack(p, bits: int, signed: bool, axis: int = -1):
    """Inverse of :func:`pack`; returns int8 values in the sub-byte range."""
    if bits == 8:
        return p.astype(jnp.int8)
    pf = pack_factor(bits)
    p = jnp.moveaxis(p, axis, -1)
    *lead, kp = p.shape
    sub = CHUNK // pf
    if kp % sub != 0:
        raise ValueError(f"packed axis ({kp}) not a multiple of {sub}")
    chunks = p.reshape(*lead, kp // sub, sub)
    planes = []
    for pl in range(pf):
        planes.append(_extract_field(chunks, bits, pl, signed))
    out = jnp.stack(planes, axis=-2)  # (..., n_chunks, pf, sub)
    out = out.reshape(*lead, kp * pf)
    return jnp.moveaxis(out, -1, axis)


def _extract_field(container, bits: int, plane: int, signed: bool):
    """Extract bit-field ``plane`` from int8 containers, with sign/zero ext.

    Works on int8 arrays with int8 ops only — safe inside Pallas kernels.
    """
    c = container.astype(jnp.int8)
    shift = bits * plane
    if signed:
        # left-align the field then arithmetic-shift right to sign-extend
        left = 8 - bits - shift
        return ((c << left) >> (8 - bits)).astype(jnp.int8)
    mask = (1 << bits) - 1
    return ((c >> shift) & mask).astype(jnp.int8)


def unpack_planes(p_block, bits: int, signed: bool):
    """Kernel-side unpack: split a packed block into ``pf`` planar sub-blocks.

    ``p_block`` has its *packed* K dim as the leading axis and must cover a
    whole number of chunks.  Returns a list of ``pf`` arrays, each with
    leading dim ``p_block.shape[0]`` (one plane), such that plane ``p`` holds
    logical elements ``chunk*CHUNK + p*sub + j``.  Consuming the planes in
    order with the matching planar slices of the other operand reproduces the
    exact integer matmul (accumulation order is irrelevant for ints).
    """
    if bits == 8:
        return [p_block.astype(jnp.int8)]
    pf = pack_factor(bits)
    return [_extract_field(p_block, bits, pl, signed) for pl in range(pf)]


def planar_perm(k: int, bits: int) -> np.ndarray:
    """Permutation mapping *planar order* position -> logical K index.

    After unpacking with :func:`unpack_planes`, concatenating the planes of
    every chunk yields elements in planar order: for chunk c and plane p the
    run ``c*CHUNK + p*sub + [0..sub)``. The *other* (unpacked) matmul operand
    must be gathered with this permutation so both sides agree. When both
    operands are packed with the same chunk-planar scheme no permutation is
    needed anywhere — planes pair up one-to-one.
    """
    if bits == 8:
        return np.arange(k)
    pf = pack_factor(bits)
    sub = CHUNK // pf
    idx = np.arange(k).reshape(k // CHUNK, pf, sub)
    return idx.reshape(-1)


def pad_to_chunk(x, axis: int = -1, value: int = 0):
    """Pad ``axis`` up to a CHUNK multiple (zero padding == zero MACs)."""
    size = x.shape[axis]
    pad = (-size) % CHUNK
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def padded_size(k: int) -> int:
    return k + ((-k) % CHUNK)
