"""Sub-byte pack/unpack — the storage layer of the XpulpNN reproduction.

The paper's `nibble` (4-bit) and `crumb` (2-bit) SIMD operands live packed in
32-bit registers; on TPU we store them packed in int8 *containers* in HBM and
unpack inside the Pallas kernel (VREG-level shifts), mirroring the paper's
"no unpack overhead when the ISA supports it natively" argument: unpacking
costs shift+mask ALU work overlapped with the MXU, not extra memory traffic.

Layout: **chunk-planar packing** along the reduction (K) axis.  Within each
chunk of ``CHUNK = 128`` logical elements, the packed byte ``j`` of the chunk
holds logical elements ``j, j+64`` (4-bit) or ``j, j+32, j+64, j+96`` (2-bit)
in its low→high bit-fields.  Planar layout means the kernel unpacks a packed
tile into ``pack_factor`` *contiguous* sub-tiles (cheap static slices — no
lane interleave), and because integer accumulation is order-invariant the
matmul can consume the sub-tiles in planar order as long as the *other*
operand is sliced with the same chunk-planar order.  This is the TPU analogue
of Marlin-style permuted weight packing.

**Segmented containers** (fine-grain mixed precision — Nadalini et al.
2307.01056 on the same cluster family): a `SegmentMap` partitions the
*output-feature* (N) axis into ordered runs, each packed at its own w_bits.
`pack_segmented` lays the runs out in one contiguous int8 buffer,
column-panel-major within each run (panels of CHUNK output channels, each
panel's packed K rows contiguous), so a kernel N-tile of CHUNK channels is
one contiguous byte range addressed by the per-segment offset table
(`SegmentMap.seg_offsets` / `SegmentMap.tile_table`). Interior run
boundaries must be CHUNK-aligned so no kernel N-tile ever straddles two
widths; only the final run may end ragged.

All functions are pure jnp and usable both on host (packing checkpoints) and
inside kernels (unpacking blocks).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

# Logical elements per packing chunk. The packed chunk is CHUNK // pack_factor
# containers: 64 bytes for 4-bit, 32 bytes for 2-bit — both sublane-aligned
# for int8 TPU tiles. K dims must be padded to a multiple of CHUNK.
CHUNK = 128

# NOTE: unsigned 8-bit caps at 127, not 255 — containers are int8 and
# XLA's dot_general has no mixed-signedness mode (unlike pv.sdotusp on the
# paper's ISA), so byte activations sacrifice 1 bit of range. The paper's
# focus (nibble/crumb) is unaffected. See DESIGN.md assumption changes.
_INT_INFO = {
    8: (-128, 127, 0, 127),
    4: (-8, 7, 0, 15),
    2: (-2, 1, 0, 3),
}


def pack_factor(bits: int) -> int:
    if bits not in (8, 4, 2):
        raise ValueError(f"unsupported bitwidth {bits}")
    return 8 // bits


def int_range(bits: int, signed: bool) -> tuple[int, int]:
    lo_s, hi_s, lo_u, hi_u = _INT_INFO[bits]
    return (lo_s, hi_s) if signed else (lo_u, hi_u)


def _check_last_axis(x, bits):
    if x.shape[-1] % CHUNK != 0:
        raise ValueError(
            f"packing axis ({x.shape[-1]}) must be a multiple of CHUNK={CHUNK}"
        )


def check_range(x, bits: int, signed: bool = True):
    """Assert every value of ``x`` fits the ``bits``-wide integer grid.

    ``pack`` keeps only the low ``bits`` bits, so an out-of-range value is
    silently truncated into a *different* in-range value — a corrupt
    artifact with no error. Host-side packing paths call this first; it
    forces concrete values (``np.asarray``) and therefore must not be used
    under jit/vmap tracing.
    """
    lo, hi = int_range(bits, signed)
    xv = np.asarray(x)
    if xv.size == 0:
        return
    saw_lo, saw_hi = int(xv.min()), int(xv.max())
    if saw_lo < lo or saw_hi > hi:
        raise ValueError(
            f"pack: values outside the {'signed' if signed else 'unsigned'} "
            f"{bits}-bit range [{lo}, {hi}] (saw min={saw_lo}, "
            f"max={saw_hi}); packing would silently truncate — "
            "quantize/clip first")


def pack(x, bits: int, axis: int = -1, *, assert_range: bool = False,
         signed: bool = True):
    """Pack sub-byte integer values (stored as int8) into int8 containers.

    ``x`` values must already be in the signed/unsigned range of ``bits``
    (packing only keeps the low ``bits`` bits, so signed and unsigned share
    one packer).  Packing is chunk-planar along ``axis``.

    ``assert_range=True`` raises instead of truncating out-of-range values
    (``signed`` selects the grid checked). Host/eager paths only — the check
    needs concrete values.
    """
    if assert_range:
        check_range(x, bits, signed)
    if bits == 8:
        return x.astype(jnp.int8)
    pf = pack_factor(bits)
    x = jnp.moveaxis(x, axis, -1)
    _check_last_axis(x, bits)
    *lead, k = x.shape
    sub = CHUNK // pf  # packed bytes per chunk
    # (..., n_chunks, pf, sub): plane p holds logical j = p*sub + j_in_plane
    planes = x.reshape(*lead, k // CHUNK, pf, sub).astype(jnp.int32)
    mask = (1 << bits) - 1
    out = jnp.zeros((*lead, k // CHUNK, sub), dtype=jnp.int32)
    for p in range(pf):
        out = out | ((planes[..., p, :] & mask) << (bits * p))
    out = out.reshape(*lead, k // pf).astype(jnp.int8)
    return jnp.moveaxis(out, -1, axis)


def unpack(p, bits: int, signed: bool, axis: int = -1):
    """Inverse of :func:`pack`; returns int8 values in the sub-byte range."""
    if bits == 8:
        return p.astype(jnp.int8)
    pf = pack_factor(bits)
    p = jnp.moveaxis(p, axis, -1)
    *lead, kp = p.shape
    sub = CHUNK // pf
    if kp % sub != 0:
        raise ValueError(f"packed axis ({kp}) not a multiple of {sub}")
    chunks = p.reshape(*lead, kp // sub, sub)
    planes = []
    for pl in range(pf):
        planes.append(_extract_field(chunks, bits, pl, signed))
    out = jnp.stack(planes, axis=-2)  # (..., n_chunks, pf, sub)
    out = out.reshape(*lead, kp * pf)
    return jnp.moveaxis(out, -1, axis)


def _extract_field(container, bits: int, plane: int, signed: bool):
    """Extract bit-field ``plane`` from int8 containers, with sign/zero ext.

    Works on int8 arrays with int8 ops only — safe inside Pallas kernels.
    """
    c = container.astype(jnp.int8)
    shift = bits * plane
    if signed:
        # left-align the field then arithmetic-shift right to sign-extend
        left = 8 - bits - shift
        return ((c << left) >> (8 - bits)).astype(jnp.int8)
    mask = (1 << bits) - 1
    return ((c >> shift) & mask).astype(jnp.int8)


def unpack_planes(p_block, bits: int, signed: bool):
    """Kernel-side unpack: split a packed block into ``pf`` planar sub-blocks.

    ``p_block`` has its *packed* K dim as the leading axis and must cover a
    whole number of chunks.  Returns a list of ``pf`` arrays, each with
    leading dim ``p_block.shape[0]`` (one plane), such that plane ``p`` holds
    logical elements ``chunk*CHUNK + p*sub + j``.  Consuming the planes in
    order with the matching planar slices of the other operand reproduces the
    exact integer matmul (accumulation order is irrelevant for ints).
    """
    if bits == 8:
        return [p_block.astype(jnp.int8)]
    pf = pack_factor(bits)
    return [_extract_field(p_block, bits, pl, signed) for pl in range(pf)]


def planar_perm(k: int, bits: int) -> np.ndarray:
    """Permutation mapping *planar order* position -> logical K index.

    After unpacking with :func:`unpack_planes`, concatenating the planes of
    every chunk yields elements in planar order: for chunk c and plane p the
    run ``c*CHUNK + p*sub + [0..sub)``. The *other* (unpacked) matmul operand
    must be gathered with this permutation so both sides agree. When both
    operands are packed with the same chunk-planar scheme no permutation is
    needed anywhere — planes pair up one-to-one.
    """
    if bits == 8:
        return np.arange(k)
    pf = pack_factor(bits)
    sub = CHUNK // pf
    idx = np.arange(k).reshape(k // CHUNK, pf, sub)
    return idx.reshape(-1)


def pad_to_chunk(x, axis: int = -1, value: int = 0):
    """Pad ``axis`` up to a CHUNK multiple (zero padding == zero MACs)."""
    size = x.shape[axis]
    pad = (-size) % CHUNK
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def padded_size(k: int) -> int:
    return k + ((-k) % CHUNK)


# ------------------------------------------------- segmented containers ---

# Candidate container widths, widest first — the canonical order
# `SegmentMap.widths()` and the mixed-operand kernel's branch table use.
WIDTHS = (8, 4, 2)


@dataclasses.dataclass(frozen=True)
class SegmentMap:
    """Ordered ``(n_start, n_end, w_bits)`` runs over the output-feature axis.

    Invariants (validated loudly — a malformed map would silently corrupt a
    packed artifact):

    * runs are non-empty, start at 0, and tile N contiguously (no gaps, no
      overlaps: each run starts where the previous ended);
    * every *interior* boundary is a multiple of CHUNK, so a kernel N-tile
      of CHUNK output channels never straddles two widths (only the final
      run may end ragged);
    * widths come from `WIDTHS` ({8, 4, 2}).

    The map is hashable (rides inside frozen plan rules / QuantConfigs) and
    JSON-serializable via `to_json_obj`/`from_json_obj`.
    """

    runs: Tuple[Tuple[int, int, int], ...]

    def __post_init__(self):
        runs = tuple((int(s), int(e), int(b)) for s, e, b in self.runs)
        object.__setattr__(self, "runs", runs)
        if not runs:
            raise ValueError("SegmentMap: empty run list")
        pos = 0
        for i, (s, e, b) in enumerate(runs):
            if b not in WIDTHS:
                raise ValueError(
                    f"SegmentMap: run {i} has unsupported width {b}; "
                    f"expected one of {WIDTHS}")
            if s != pos:
                kind = "overlaps" if s < pos else "leaves a gap after"
                raise ValueError(
                    f"SegmentMap: run {i} [{s}, {e}) {kind} the previous "
                    f"run (expected n_start={pos}); runs must tile N "
                    "contiguously in order")
            if e <= s:
                raise ValueError(
                    f"SegmentMap: run {i} [{s}, {e}) is empty or reversed")
            if i + 1 < len(runs) and e % CHUNK:
                raise ValueError(
                    f"SegmentMap: interior boundary {e} (run {i}) is not a "
                    f"multiple of CHUNK={CHUNK}; a kernel N-tile would "
                    "straddle two container widths (only the final run may "
                    "end ragged)")
            pos = e

    # ------------------------------------------------------- structure ---

    @staticmethod
    def uniform(n: int, bits: int) -> "SegmentMap":
        return SegmentMap(((0, int(n), int(bits)),))

    @property
    def n(self) -> int:
        return self.runs[-1][1]

    @property
    def is_uniform(self) -> bool:
        return len(self.runs) == 1

    def widths(self) -> Tuple[int, ...]:
        """Distinct run widths, widest first (the kernel's branch order)."""
        present = {b for _, _, b in self.runs}
        return tuple(b for b in WIDTHS if b in present)

    def run_lengths(self) -> Tuple[int, ...]:
        return tuple(e - s for s, e, _ in self.runs)

    # ------------------------------------------------- byte accounting ---

    def _run_bytes(self, run, k: int) -> int:
        s, e, b = run
        return (padded_size(k) // pack_factor(b)) * (e - s)

    def packed_bytes(self, k: int) -> int:
        """Total container bytes for a (K=k, N=self.n) weight matrix —
        exactly ``sum(run_len * K_pad * bits / 8)``."""
        return sum(self._run_bytes(r, k) for r in self.runs)

    def seg_offsets(self, k: int) -> Tuple[int, ...]:
        """Byte offset of each run's container block in the flat buffer."""
        offs, off = [], 0
        for r in self.runs:
            offs.append(off)
            off += self._run_bytes(r, k)
        return tuple(offs)

    def tile_table(self, k: int):
        """Per-N-tile kernel descriptors: ``(codes, offsets)`` int32 arrays,
        one entry per CHUNK-wide output-channel tile.

        ``codes[j]`` indexes `widths()` (the tile's unpack-width branch);
        ``offsets[j]`` is the byte offset of the tile's contiguous column
        panel in the flat buffer. Requires an N already padded to CHUNK
        (`pad_segmented`) — a ragged tail panel has no full-width tile.
        """
        if self.n % CHUNK:
            raise ValueError(
                f"tile_table: N={self.n} is not a CHUNK multiple; pad the "
                "container first (pad_segmented)")
        widths = self.widths()
        kp = padded_size(k)
        codes, offs = [], []
        off = 0
        for s, e, b in self.runs:
            rows = kp // pack_factor(b)
            for _ in range(s, e, CHUNK):
                codes.append(widths.index(b))
                offs.append(off)
                off += rows * CHUNK
        return (np.asarray(codes, np.int32), np.asarray(offs, np.int32))

    def pad_to(self, n_pad: int) -> "SegmentMap":
        """Extend the final run to ``n_pad`` (zero-channel padding)."""
        if n_pad < self.n:
            raise ValueError(f"pad_to: {n_pad} < N={self.n}")
        if n_pad == self.n:
            return self
        s, _, b = self.runs[-1]
        return SegmentMap(self.runs[:-1] + ((s, int(n_pad), b),))

    # ------------------------------------------------------------ json ---

    def to_json_obj(self):
        return [[s, e, b] for s, e, b in self.runs]

    @staticmethod
    def from_json_obj(obj) -> "SegmentMap":
        return SegmentMap(tuple((int(s), int(e), int(b))
                                for s, e, b in obj))


def _iter_panels(length: int):
    """(panel_start, panel_width) pairs tiling ``length`` by CHUNK."""
    for p0 in range(0, length, CHUNK):
        yield p0, min(CHUNK, length - p0)


def pack_segmented(w_hat, segmap: SegmentMap, *, assert_range: bool = False):
    """Pack int8 weight values (..., K, N) into one flat segmented buffer.

    Each run ``(s, e, b)`` of ``segmap`` packs columns [s, e) chunk-planar
    along K at width ``b`` (K zero-padded to CHUNK), then flattens
    column-panel-major: panels of CHUNK output channels, each panel's
    packed rows contiguous. Returns an int8 array (..., total_bytes) with
    ``total_bytes == segmap.packed_bytes(K)``; per-run offsets are
    `segmap.seg_offsets(K)`.
    """
    n = w_hat.shape[-1]
    if n != segmap.n:
        raise ValueError(
            f"pack_segmented: weight N={n} != SegmentMap N={segmap.n}")
    lead = w_hat.shape[:-2]
    parts = []
    for s, e, b in segmap.runs:
        seg = w_hat[..., s:e]
        if assert_range:
            check_range(seg, b, True)
        packed = pack(pad_to_chunk(seg, axis=-2), b, axis=-2,
                      signed=True)                     # (..., kp/pf, e-s)
        rows = packed.shape[-2]
        for p0, pw in _iter_panels(e - s):
            parts.append(packed[..., p0:p0 + pw].reshape(*lead, rows * pw))
    return jnp.concatenate(parts, axis=-1).astype(jnp.int8)


def segment_packed(buf, segmap: SegmentMap, index: int, k: int):
    """Run ``index``'s uniform container view: (..., K_pad/pf_b, run_len).

    The exact array `pack` would have produced for that column range —
    the composition oracle and the segment-looping backends consume these.
    """
    s, e, b = segmap.runs[index]
    rows = padded_size(k) // pack_factor(b)
    off = segmap.seg_offsets(k)[index]
    lead = buf.shape[:-1]
    parts, pos = [], off
    for _, pw in _iter_panels(e - s):
        blk = buf[..., pos:pos + rows * pw]
        parts.append(blk.reshape(*lead, rows, pw))
        pos += rows * pw
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate(parts, axis=-1)


def unpack_segmented(buf, segmap: SegmentMap, k: int):
    """Inverse of :func:`pack_segmented`: (..., K_pad, N) int8 values.

    Returns the CHUNK-padded K extent (slice ``[..., :k, :]`` for the
    logical matrix), matching `pack`'s padding convention.
    """
    outs = [unpack(segment_packed(buf, segmap, i, k), b, True, axis=-2)
            for i, (_, _, b) in enumerate(segmap.runs)]
    return jnp.concatenate(outs, axis=-1)


def pad_segmented(buf, segmap: SegmentMap, k: int):
    """Zero-pad the ragged tail panel to a full CHUNK of output channels.

    Kernel callers only: the artifact stays exact-bytes; the mixed-operand
    kernel needs every N-tile to be a full contiguous CHUNK-wide panel.
    Returns ``(buf_padded, segmap_padded)`` (identity when N is aligned).
    """
    n = segmap.n
    n_pad = padded_size(n)
    if n_pad == n:
        return buf, segmap
    _, _, b = segmap.runs[-1]
    rows = padded_size(k) // pack_factor(b)
    rem = n - (n // CHUNK) * CHUNK          # ragged tail panel width
    tail_bytes = rows * rem
    lead = buf.shape[:-1]
    head = buf[..., :buf.shape[-1] - tail_bytes]
    tail = buf[..., buf.shape[-1] - tail_bytes:].reshape(*lead, rows, rem)
    widths = [(0, 0)] * tail.ndim
    widths[-1] = (0, CHUNK - rem)
    tail = jnp.pad(tail, widths).reshape(*lead, rows * CHUNK)
    return (jnp.concatenate([head, tail], axis=-1),
            segmap.pad_to(n_pad))
