"""Post-training-quantization range calibration -> QuantSpec.

The paper consumes already-quantized networks (from QAT or PTQ flows, refs
[12],[20],[45]); the framework needs its own calibrator so examples are
end-to-end. Two estimators: absolute min/max and percentile (robust to
outliers, the practical default for activations).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantSpec


def calibrate_weight(w, bits: int) -> QuantSpec:
    absmax = float(jnp.max(jnp.abs(w)))
    absmax = max(absmax, 1e-8)
    return QuantSpec.weight(bits, absmax)


def calibrate_activation(samples, bits: int, percentile: float = 99.9,
                         ) -> QuantSpec:
    """Unsigned activation spec (alpha=0 per paper): beta from percentile."""
    x = np.asarray(samples, dtype=np.float32).reshape(-1)
    x = np.maximum(x, 0.0)  # activation grids start at 0 (ReLU semantic)
    if percentile >= 100.0:
        beta = float(x.max())
    else:
        beta = float(np.percentile(x, percentile))
    beta = max(beta, 1e-8)
    return QuantSpec.activation(bits, beta)


class RunningCalibrator:
    """Streaming min/max + moving-percentile calibrator for activation taps."""

    def __init__(self, bits: int, momentum: float = 0.9,
                 percentile: float = 99.9):
        self.bits = bits
        self.momentum = momentum
        self.percentile = percentile
        self._beta = None

    def observe(self, x) -> None:
        x = np.asarray(x, dtype=np.float32).reshape(-1)
        x = np.maximum(x, 0.0)
        b = float(np.percentile(x, self.percentile)) if x.size else 0.0
        if self._beta is None:
            self._beta = b
        else:
            self._beta = self.momentum * self._beta + (1 - self.momentum) * b

    def spec(self) -> QuantSpec:
        if self._beta is None:
            raise ValueError("no observations")
        return QuantSpec.activation(self.bits, max(self._beta, 1e-8))
