"""int8 gradient compression with error feedback.

The sub-byte insight applied to the interconnect: gradients crossing the
data axis are blockwise int8-quantized (4x fewer bytes on the reduction
path); the quantization error is fed back into the next step's gradient
(error-feedback/EF-SGD, Seide et al. / Karimireddy et al.), which keeps
convergence unbiased in practice.

Under GSPMD the quantize-dequantize pair straddles the gradient psum:
XLA sees int8 tensors feeding the cross-replica reduction region, shrinking
collective bytes — verified in the §Perf HLO inspection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import (BLOCK, dequantize_int8_blockwise,
                                 quantize_int8_blockwise)

# the blockwise codec is shared repo-wide (core.quantize); these aliases
# keep the wire-format call sites and their tests stable
_quant_block = quantize_int8_blockwise
_dequant_block = dequantize_int8_blockwise


def compress_grads(grads, error_feedback):
    """g' = Q(g + ef); ef' = (g + ef) - g'. Returns (g', ef')."""
    def one(g, ef):
        gf = g.astype(jnp.float32) + ef.astype(jnp.float32)
        codes, scale = _quant_block(gf)
        gq = _dequant_block(codes, scale, g.shape)
        return gq.astype(g.dtype), (gf - gq).astype(ef.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
