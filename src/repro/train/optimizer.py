"""Optimizers with ZeRO-sharded state and int8 state quantization.

- AdamW: fp32 m/v, standard decoupled weight decay, global-norm clipping.
- AdamW8: blockwise-int8 m/v (bitsandbytes-style) — a distributed-
  optimization trick in the paper's own spirit (quantize what is
  memory-bound): cuts optimizer HBM from 8 to ~2.06 bytes/param, which is
  what lets the 1T-param arch train inside a 512-chip slice.

Optimizer state inherits the parameter's logical axes, so ZeRO-3 sharding
(embed->data) applies to m/v automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import (dequantize_int8_rowwise,
                                 quantize_int8_rowwise)

BLOCK = 256  # int8 state block size


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10000
    state_bits: int = 32          # 32 | 8 (blockwise int8 m/v)


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup)
                    / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------- int8 states ---
# Row-wise (last-axis) int8 quantization: codes keep the PARAM SHAPE, so
# ZeRO sharding propagates untouched (blocked (N/256,256) layouts forced
# param-sized f32 reshapes that GSPMD could only replicate — observed
# 7.9 TB/device temps on the 1T arch).
# m (signed): linear absmax-per-row.  v (non-negative, huge dynamic range):
# LOG-scale per row — linear coding crushes small entries to 0 and the
# 1/sqrt(v) update explodes (observed: loss 0.13 -> 1.8e4).

_LOG_FLOOR = 1e-30


# the linear rowwise codec is shared repo-wide (core.quantize); the
# log-scale one below is optimizer-specific (v's dynamic range)
_q8_lin = quantize_int8_rowwise
_dq8_lin = dequantize_int8_rowwise


def _q8_log(x):
    lx = jnp.log(jnp.maximum(x, _LOG_FLOOR))
    lmin = jnp.min(lx, axis=-1, keepdims=True)
    lrange = jnp.maximum(jnp.max(lx, axis=-1, keepdims=True) - lmin, 1e-6)
    codes = jnp.clip(jnp.round((lx - lmin) / lrange * 254.0) - 127,
                     -127, 127).astype(jnp.int8)
    return {"codes": codes, "lmin": lmin[..., 0], "lrange": lrange[..., 0]}


def _dq8_log(s, shape):
    lx = ((s["codes"].astype(jnp.float32) + 127.0) / 254.0
          * s["lrange"][..., None] + s["lmin"][..., None])
    x = jnp.exp(lx)
    return jnp.where(x <= _LOG_FLOOR * 2, 0.0, x)


def _zeros_state(p, bits, kind="lin"):
    if bits == 8:
        s = {"codes": jnp.zeros(p.shape, jnp.int8)}
        lead = p.shape[:-1]
        if kind == "lin":
            s["scale"] = jnp.zeros(lead, jnp.float32)
        else:
            s["lmin"] = jnp.full(lead, jnp.log(_LOG_FLOOR), jnp.float32)
            s["lrange"] = jnp.full(lead, 1e-6, jnp.float32)
        return s
    return jnp.zeros(p.shape, jnp.float32)


def _read_state(s, shape, bits, kind="lin"):
    if bits == 8:
        return _dq8_lin(s, shape) if kind == "lin" else _dq8_log(s, shape)
    return s


def _write_state(x, bits, kind="lin"):
    if bits == 8:
        return _q8_lin(x) if kind == "lin" else _q8_log(x)
    return x


# -------------------------------------------------------------- adamw -----

def adamw_init(params, cfg: OptConfig):
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(
            lambda p: _zeros_state(p, cfg.state_bits, "lin"), params),
        "v": jax.tree.map(
            lambda p: _zeros_state(p, cfg.state_bits, "log"), params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = _schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))

    is_state = lambda x: isinstance(x, dict) and "codes" in x

    def upd(p, g, m_s, v_s):
        g = g.astype(jnp.float32) * scale
        m = _read_state(m_s, p.shape, cfg.state_bits, "lin")
        v = _read_state(v_s, p.shape, cfg.state_bits, "log")
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, _write_state(m, cfg.state_bits, "lin"), \
            _write_state(v, cfg.state_bits, "log")

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, {"step": step, "m": new_m, "v": new_v}, {
        "grad_norm": gn, "lr": lr}


def state_logical_specs(param_specs, cfg: OptConfig):
    """Optimizer-state logical axes mirroring the params (ZeRO-3)."""
    if cfg.state_bits == 8:
        def m_axes(axes):
            return {"codes": axes, "scale": axes[:-1]}
        def v_axes(axes):
            return {"codes": axes, "lmin": axes[:-1], "lrange": axes[:-1]}
        st_m = jax.tree.map(m_axes, param_specs,
                            is_leaf=lambda x: isinstance(x, tuple))
        st_v = jax.tree.map(v_axes, param_specs,
                            is_leaf=lambda x: isinstance(x, tuple))
        return {"step": (), "m": st_m, "v": st_v}
    st = param_specs
    return {"step": (), "m": st, "v": st}
