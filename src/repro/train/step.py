"""Distributed train/serve step builders: jit + shardings for a mesh.

train_step = loss -> grad -> (optional int8 grad compression) -> AdamW.
Everything is GSPMD-partitioned from logical axis rules; no shard_map needed
for the baseline path (XLA inserts the reduce-scatter/all-gather schedule
for the ZeRO-3 layout).

Gradient compression (beyond-paper, same spirit — quantize the bandwidth-
bound tensor): gradients are quantized to int8 blockwise *before* the
cross-data-axis reduction, with an error-feedback accumulator kept in the
optimizer state; see train/compress.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.api import Model
from repro.parallel.ctx import activation_sharding
from repro.parallel.sharding import (DEFAULT_RULES, batch_sharding,
                                     cache_shardings, params_shardings,
                                     shard_spec_for)
from repro.train.optimizer import (OptConfig, adamw_init, adamw_update,
                                   state_logical_specs)
from repro.train.compress import compress_grads


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    opt: OptConfig = OptConfig()
    grad_compress_bits: int = 32   # 32 (off) | 8 (int8 + error feedback)


def make_train_fns(model: Model, mesh: Mesh, shape: ShapeConfig,
                   tcfg: TrainStepConfig = TrainStepConfig(),
                   rules=DEFAULT_RULES):
    """Returns (init_fn, train_step, shardings) ready to jit/lower.

    init_fn(key) -> state {params, opt, ef}
    train_step(state, batch) -> (state, metrics)
    """
    specs = model.specs()
    pdefs = model.defs()
    shapes = jax.eval_shape(lambda k: model.init(k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = params_shardings(specs, shapes, mesh, rules)

    opt_specs = state_logical_specs(specs, tcfg.opt)
    use_ef = tcfg.grad_compress_bits == 8

    def init_fn(key):
        params = model.init(key)
        opt = adamw_init(params, tcfg.opt)
        state = {"params": params, "opt": opt}
        if use_ef:
            state["ef"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
        return state

    state_shapes = jax.eval_shape(
        init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))

    def spec_of(path_axes, shaped):
        return NamedSharding(
            mesh, shard_spec_for(shaped.shape, path_axes, mesh, rules))

    opt_shard = jax.tree.map(
        spec_of, {"params": specs, "opt": opt_specs,
                  **({"ef": specs} if use_ef else {})},
        {"params": state_shapes["params"], "opt": state_shapes["opt"],
         **({"ef": state_shapes["ef"]} if use_ef else {})},
        is_leaf=lambda x: isinstance(x, tuple))

    def train_step(state, batch):
        with activation_sharding(mesh, rules):
            params = state["params"]

            def loss_fn(p):
                return model.loss(p, batch)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            if use_ef:
                grads, new_ef = compress_grads(grads, state["ef"])
            new_params, new_opt, metrics = adamw_update(
                params, grads, state["opt"], tcfg.opt)
            metrics["loss"] = loss
            new_state = {"params": new_params, "opt": new_opt}
            if use_ef:
                new_state["ef"] = new_ef
            return new_state, metrics

    batch_shardings = {
        k: batch_sharding(mesh, len(v.shape), rules, v.shape)
        for k, v in model.input_specs(shape).items()}

    return init_fn, train_step, {
        "state": opt_shard, "batch": batch_shardings}


def make_decode_fns(model: Model, mesh: Mesh, shape: ShapeConfig,
                    rules=DEFAULT_RULES):
    """Returns (decode_step, shardings) for serving dry-runs/engines."""
    specs = model.specs()
    shapes = jax.eval_shape(lambda k: model.init(k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = params_shardings(specs, shapes, mesh, rules)
    in_specs = model.input_specs(shape)
    cache_shard = cache_shardings(in_specs["cache"], mesh, rules)

    def decode_step(params, cache, token, index):
        with activation_sharding(mesh, rules):
            logits, new_cache = model.decode(params, cache, token, index)
            return logits, new_cache

    shard = {
        "params": p_shard,
        "cache": cache_shard,
        "token": batch_sharding(mesh, 2, rules,
                                in_specs["token"].shape),
        "index": NamedSharding(mesh, P()),
    }
    return decode_step, shard


def make_prefill_fns(model: Model, mesh: Mesh, shape: ShapeConfig,
                     rules=DEFAULT_RULES):
    specs = model.specs()
    shapes = jax.eval_shape(lambda k: model.init(k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = params_shardings(specs, shapes, mesh, rules)
    in_specs = model.input_specs(shape)

    def prefill_step(params, batch):
        with activation_sharding(mesh, rules):
            logits, _, _ = model.forward(params, batch)
            return logits[:, -1:]

    batch_shardings = {k: batch_sharding(mesh, len(v.shape), rules, v.shape)
                       for k, v in in_specs.items()}
    return prefill_step, {"params": p_shard, "batch": batch_shardings}
