"""Logical-axis sharding rules (MaxText-style) -> PartitionSpecs.

**Paper analogy (XpulpNN fig. 9).** A JAX mesh device plays one core of
the paper's tightly-coupled 8-core PULP cluster: the `model` axis is the
cluster (operands resident per core, collective-free integer inner
loops), `data`/`pod` is multi-cluster scale-out. The paper's near-linear
1->8-core MAC/cycle scaling corresponds here to per-device FLOPs/bytes
falling as 1/n with no growth in collective bytes.

The mesh axes are ("data", "model") per pod and ("pod", "data", "model")
across pods. Default assignment:

  batch        -> (pod, data)    DP across pods and the data axis
  vocab/heads/kv_heads/mlp/expert_mlp/experts -> model   (TP / EP)
  embed        -> data           ZeRO-3/FSDP: weights + optimizer states
                                 sharded over data, all-gathered at use
  kv_seq       -> model          SP: long-context KV cache sharding
  layers/stack -> None           (replicated stacking dim)

**Sharding invariants for packed sub-byte arrays** (the W{8,4,2}
deployment artifacts, `repro.core.packing`): a packed weight array
`w_packed` has shape (K_pad // pack_factor, N) — its *packed* dim is the
reduction axis and is NOT the logical K (one int8 container holds
`pack_factor` logical elements, chunk-planar within CHUNK-element
groups). The cluster path therefore shards packed operands **only on the
output-feature axis N** (`model`, tensor-parallel):

  * N-sharding keeps every CHUNK group intact on one device, so shards
    unpack locally with zero cross-device fixup;
  * the int32 accumulation of eq. (2) runs over the full (unsharded) K on
    each device, so the BN + requant epilogue (eqs. 3/4, all per-N
    parameters) is local per shard — **no psum anywhere**, mirroring the
    paper's cores writing disjoint output-channel groups into TCDM;
  * sharding the packed K axis is forbidden unless the split lands on a
    CHUNK // pack_factor container boundary AND a psum is added after the
    partial GEMMs; `packed_linear_specs` never produces such a spec.

Per-output-channel epilogue vectors (kappa, lam, m, per-channel dequant
scale) shard with N. `shard_packed_linear` / `shard_packed_conv` apply
these rules to whole artifacts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple = (
        ("batch", ("pod", "data")),
        ("batch_full", ("pod", "data", "model")),
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("mlp", "model"),
        ("mlp2", None),
        ("expert_mlp", "model"),
        ("experts", "model"),
        ("embed", "data"),       # ZeRO-3 shard dim
        ("opt_shard", ("data", "model")),  # blocked int8 optimizer states
        ("kv_seq", "model"),     # sequence-parallel KV
        ("seq_model", "model"),  # context-parallel fallback for few-head GQA
        ("seq", None),
        ("layers", None),
    )

    def lookup(self, name):
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def spec(self, axes, mesh: Mesh) -> P:
        """logical axes tuple -> PartitionSpec, dropping mesh axes that are
        absent or whose dim isn't divisible (validated separately)."""
        out = []
        used = set()
        for ax in axes:
            tgt = self.lookup(ax) if ax is not None else None
            tgt_t = tgt if isinstance(tgt, tuple) else (
                (tgt,) if tgt else ())
            tgt_t = tuple(t for t in tgt_t
                          if t in mesh.axis_names and t not in used)
            used.update(tgt_t)
            if len(tgt_t) == 0:
                out.append(None)
            elif len(tgt_t) == 1:
                out.append(tgt_t[0])
            else:
                out.append(tgt_t)
        return P(*out)


DEFAULT_RULES = ShardingRules()


def _divisible(dim: int, spec_entry, mesh: Mesh) -> bool:
    if spec_entry is None:
        return True
    axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def shard_spec_for(shape, axes, mesh: Mesh,
                   rules: ShardingRules = DEFAULT_RULES) -> P:
    """PartitionSpec with divisibility fallback: any mesh axis that does not
    divide the dim is dropped (replicated) — production behaviour, never a
    crash on odd dims."""
    spec = rules.spec(axes, mesh)
    fixed = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        fixed.append(entry if _divisible(dim, entry, mesh) else None)
    return P(*fixed)


def params_shardings(spec_tree, shape_tree, mesh: Mesh,
                     rules: ShardingRules = DEFAULT_RULES):
    """Map the logical-spec tree + shapes tree -> NamedSharding tree."""
    def one(axes, shaped):
        return NamedSharding(
            mesh, shard_spec_for(shaped.shape, axes, mesh, rules))
    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_sharding(mesh: Mesh, ndim: int,
                   rules: ShardingRules = DEFAULT_RULES,
                   shape=None) -> NamedSharding:
    """Inputs: shard dim0 (batch) over (pod, data); drops axes the batch
    dim can't divide (long_500k decode has global_batch=1)."""
    if shape is not None:
        spec = shard_spec_for(tuple(shape), ("batch",) + (None,) *
                              (ndim - 1), mesh, rules)
        return NamedSharding(mesh, spec)
    entry = rules.spec(("batch",), mesh)
    return NamedSharding(mesh, P(entry[0], *([None] * (ndim - 1))))


def cache_shardings(cache_shapes, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES):
    """KV caches: (layers, batch, seq, kv_heads, head_dim) — shard batch
    over (pod,data) and the kv_heads dim over model; when batch or kv_heads
    don't divide, fall back to sequence (SP) sharding for long-context."""
    def one(s):
        shape = s.shape
        if len(shape) >= 4:
            # (L, B, T, Hk, Dh) or (L, 2, B, T, Hk, Dh) cross
            if len(shape) == 5:
                axes = ("layers", "batch", "kv_seq_or_none", "kv_heads",
                        None)
                return NamedSharding(mesh, _kv_spec(shape, mesh, rules))
            if len(shape) == 6:
                p = _kv_spec(shape[1:], mesh, rules)
                return NamedSharding(mesh, P(None, *tuple(p)))
        # ssm/conv states: (L, B, ...): batch over data
        entry = rules.spec(("batch",), mesh)[0]
        if len(shape) >= 2 and _divisible(shape[1], entry, mesh):
            return NamedSharding(
                mesh, P(None, entry, *([None] * (len(shape) - 2))))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree.map(one, cache_shapes)


# ------------------------------------------------- packed QNN artifacts ---

def cluster_axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    """Size of a mesh axis, treating an absent/None axis as 1 (so callers
    can pass pure-DP or pure-TP meshes without special-casing)."""
    if axis is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def axis_entry(mesh: Mesh, axis: Optional[str]):
    """PartitionSpec entry for an axis: None when the axis is absent (the
    spec must not name axes the mesh does not have). Public counterpart
    of `cluster_axis_size` — the cluster path in `repro.kernels.api` uses
    the pair to tolerate pure-DP / pure-TP meshes."""
    return axis if axis is not None and axis in mesh.axis_names else None


def packed_linear_specs(params, mesh: Mesh, *, tp_axis: str = "model"):
    """PartitionSpecs for a `QuantizedLinearParams` artifact, TP over the
    output-feature axis N (see module docstring for why only N).

    Returns a dict: ``w_packed`` -> P(None, tp), ``kappa``/``lam``/``m``
    -> P(tp). The packed reduction axis stays unsharded by construction.
    Raises when N does not divide the tp axis — packed weights are static
    deployment artifacts, so a silent replication fallback would hide a
    mis-sized mesh rather than tolerate a ragged batch.
    """
    tp = cluster_axis_size(mesh, tp_axis)
    n = params.w_packed.shape[1]
    if n % tp != 0:
        raise ValueError(
            f"packed linear: output features N={n} not divisible by "
            f"mesh axis {tp_axis!r} size {tp}; pad Cout at quantization "
            "time or use a smaller cluster")
    ent = axis_entry(mesh, tp_axis) if tp > 1 else None
    return {"w_packed": P(None, ent), "kappa": P(ent), "lam": P(ent),
            "m": P(ent)}


def shard_packed_linear(params, mesh: Mesh, *, tp_axis: str = "model"):
    """device_put a `QuantizedLinearParams` with `packed_linear_specs`
    (weights resident per shard before serving — the cluster's
    weight-stationary setup step)."""
    specs = packed_linear_specs(params, mesh, tp_axis=tp_axis)
    put = lambda a, s: jax.device_put(a, NamedSharding(mesh, s))
    return dataclasses.replace(
        params,
        w_packed=put(params.w_packed, specs["w_packed"]),
        kappa=put(params.kappa, specs["kappa"]),
        lam=put(params.lam, specs["lam"]),
        m=put(params.m, specs["m"]))


def packed_conv_specs(params, mesh: Mesh, *, tp_axis: str = "model"):
    """PartitionSpecs for a `QuantizedConvParams` artifact: the fused
    per-tap layout ``w_packed_fused`` (K_tap_pad//pf, Cout) shards on Cout
    exactly like the GEMM layout; both layouts plus the per-Cout epilogue
    vectors move together so every backend sees consistent shards."""
    gemm = packed_linear_specs(params.gemm, mesh, tp_axis=tp_axis)
    return {"gemm": gemm, "w_packed_fused": gemm["w_packed"]}


def shard_packed_conv(params, mesh: Mesh, *, tp_axis: str = "model"):
    """device_put a `QuantizedConvParams` with `packed_conv_specs`."""
    specs = packed_conv_specs(params, mesh, tp_axis=tp_axis)
    gemm = shard_packed_linear(params.gemm, mesh, tp_axis=tp_axis)
    wpf = jax.device_put(
        params.w_packed_fused,
        NamedSharding(mesh, specs["w_packed_fused"]))
    return dataclasses.replace(params, gemm=gemm, w_packed_fused=wpf)


def _kv_spec(shape, mesh, rules):
    """(L, B, T, Hk, Dh): prefer batch->(pod,data), heads->model; if heads
    don't divide model, shard T (SP) instead — the long_500k path."""
    l, b, t, hk, dh = shape
    bent = rules.spec(("batch",), mesh)[0]
    bent = bent if _divisible(b, bent, mesh) else None
    ment = "model" if hk % mesh.shape.get("model", 1) == 0 else None
    tent = None
    if ment is None and t % mesh.shape.get("model", 1) == 0:
        tent = "model"
    return P(None, bent, tent, ment, None)
