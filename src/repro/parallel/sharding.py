"""Logical-axis sharding rules (MaxText-style) -> PartitionSpecs.

The mesh axes are ("data", "model") per pod and ("pod", "data", "model")
across pods. Default assignment:

  batch        -> (pod, data)    DP across pods and the data axis
  vocab/heads/kv_heads/mlp/expert_mlp/experts -> model   (TP / EP)
  embed        -> data           ZeRO-3/FSDP: weights + optimizer states
                                 sharded over data, all-gathered at use
  kv_seq       -> model          SP: long-context KV cache sharding
  layers/stack -> None           (replicated stacking dim)

The PULP-cluster analogy (DESIGN.md): `model` plays the tightly-coupled
8-core cluster (operands resident, collective-free inner loops), `data`/
`pod` plays multi-cluster scale-out.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple = (
        ("batch", ("pod", "data")),
        ("batch_full", ("pod", "data", "model")),
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("mlp", "model"),
        ("mlp2", None),
        ("expert_mlp", "model"),
        ("experts", "model"),
        ("embed", "data"),       # ZeRO-3 shard dim
        ("opt_shard", ("data", "model")),  # blocked int8 optimizer states
        ("kv_seq", "model"),     # sequence-parallel KV
        ("seq_model", "model"),  # context-parallel fallback for few-head GQA
        ("seq", None),
        ("layers", None),
    )

    def lookup(self, name):
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def spec(self, axes, mesh: Mesh) -> P:
        """logical axes tuple -> PartitionSpec, dropping mesh axes that are
        absent or whose dim isn't divisible (validated separately)."""
        out = []
        used = set()
        for ax in axes:
            tgt = self.lookup(ax) if ax is not None else None
            tgt_t = tgt if isinstance(tgt, tuple) else (
                (tgt,) if tgt else ())
            tgt_t = tuple(t for t in tgt_t
                          if t in mesh.axis_names and t not in used)
            used.update(tgt_t)
            if len(tgt_t) == 0:
                out.append(None)
            elif len(tgt_t) == 1:
                out.append(tgt_t[0])
            else:
                out.append(tgt_t)
        return P(*out)


DEFAULT_RULES = ShardingRules()


def _divisible(dim: int, spec_entry, mesh: Mesh) -> bool:
    if spec_entry is None:
        return True
    axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def shard_spec_for(shape, axes, mesh: Mesh,
                   rules: ShardingRules = DEFAULT_RULES) -> P:
    """PartitionSpec with divisibility fallback: any mesh axis that does not
    divide the dim is dropped (replicated) — production behaviour, never a
    crash on odd dims."""
    spec = rules.spec(axes, mesh)
    fixed = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        fixed.append(entry if _divisible(dim, entry, mesh) else None)
    return P(*fixed)


def params_shardings(spec_tree, shape_tree, mesh: Mesh,
                     rules: ShardingRules = DEFAULT_RULES):
    """Map the logical-spec tree + shapes tree -> NamedSharding tree."""
    def one(axes, shaped):
        return NamedSharding(
            mesh, shard_spec_for(shaped.shape, axes, mesh, rules))
    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_sharding(mesh: Mesh, ndim: int,
                   rules: ShardingRules = DEFAULT_RULES,
                   shape=None) -> NamedSharding:
    """Inputs: shard dim0 (batch) over (pod, data); drops axes the batch
    dim can't divide (long_500k decode has global_batch=1)."""
    if shape is not None:
        spec = shard_spec_for(tuple(shape), ("batch",) + (None,) *
                              (ndim - 1), mesh, rules)
        return NamedSharding(mesh, spec)
    entry = rules.spec(("batch",), mesh)
    return NamedSharding(mesh, P(entry[0], *([None] * (ndim - 1))))


def cache_shardings(cache_shapes, mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES):
    """KV caches: (layers, batch, seq, kv_heads, head_dim) — shard batch
    over (pod,data) and the kv_heads dim over model; when batch or kv_heads
    don't divide, fall back to sequence (SP) sharding for long-context."""
    def one(s):
        shape = s.shape
        if len(shape) >= 4:
            # (L, B, T, Hk, Dh) or (L, 2, B, T, Hk, Dh) cross
            if len(shape) == 5:
                axes = ("layers", "batch", "kv_seq_or_none", "kv_heads",
                        None)
                return NamedSharding(mesh, _kv_spec(shape, mesh, rules))
            if len(shape) == 6:
                p = _kv_spec(shape[1:], mesh, rules)
                return NamedSharding(mesh, P(None, *tuple(p)))
        # ssm/conv states: (L, B, ...): batch over data
        entry = rules.spec(("batch",), mesh)[0]
        if len(shape) >= 2 and _divisible(shape[1], entry, mesh):
            return NamedSharding(
                mesh, P(None, entry, *([None] * (len(shape) - 2))))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree.map(one, cache_shapes)


def _kv_spec(shape, mesh, rules):
    """(L, B, T, Hk, Dh): prefer batch->(pod,data), heads->model; if heads
    don't divide model, shard T (SP) instead — the long_500k path."""
    l, b, t, hk, dh = shape
    bent = rules.spec(("batch",), mesh)[0]
    bent = bent if _divisible(b, bent, mesh) else None
    ment = "model" if hk % mesh.shape.get("model", 1) == 0 else None
    tent = None
    if ment is None and t % mesh.shape.get("model", 1) == 0:
        tent = "model"
    return P(None, bent, tent, ment, None)
