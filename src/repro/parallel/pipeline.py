"""GPipe-style pipeline parallelism over the `pod` mesh axis.

For the 100B+ archs the pod axis can carry pipeline stages instead of DP:
layer stacks are split into n_stages contiguous stages (stage s holds the
(s * L/n,. ..) slice of the stacked params, sharded on the stacking dim
over `pod`), and microbatches flow through a shard_map ring: every step,
each stage applies its layers to the activation it holds and
collective-permutes the result to the next stage. Bubble fraction =
(n_stages - 1) / (n_micro + n_stages - 1).

Inter-pod links are the slowest in the hierarchy, which is exactly why
pipelining (O(activations) point-to-point per microbatch) beats DP
(O(grads) all-reduce) across pods at the 1T scale — see DESIGN.md §5.

**Paper analogy:** the pod axis is the *multi-cluster* tier — the paper's
SoC instantiating several 8-core clusters — while the in-pod `model` axis
is the cluster itself (`repro.parallel.sharding`, device ↔ core). Stage
params may be packed sub-byte artifacts: the stacking dim (dim0 of each
stage slice) is a layer index, not a tensor axis, so sharding it over
`pod` never touches the packed reduction axis and the per-stage kernels
keep the psum-free epilogue invariant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_micro, mesh: Mesh,
                   axis: str = "pod"):
    """Run a GPipe forward.

    stage_fn(params_slice, h) -> h : applies ONE stage's layers.
    stage_params: pytree with leaves stacked (n_stages, ...) — sharded on
      dim0 over `axis` (each pod holds its stage's layers).
    x_micro: (n_micro, mb, ...) microbatched input, replicated.
    Returns (n_micro, mb, ...) outputs, replicated (psum-broadcast from
    the last stage).
    """
    n_stages = mesh.shape[axis]
    perm = [(j, (j + 1) % n_stages) for j in range(n_stages)]

    def local(sp, xm):
        s = jax.lax.axis_index(axis)
        sp = jax.tree.map(lambda a: a[0], sp)  # (1, ...) shard -> stage tree
        n_micro = xm.shape[0]
        total = n_micro + n_stages - 1
        out = jnp.zeros_like(xm)
        cur = jnp.zeros_like(xm[0])

        def step(t, carry):
            out, cur = carry
            # stage 0 ingests microbatch t while it exists
            inj = xm[jnp.minimum(t, n_micro - 1)]
            h_in = jnp.where(s == 0, inj, cur)
            h_out = stage_fn(sp, h_in)
            # emit: the last stage finishes microbatch t - (n_stages - 1)
            idx = t - (n_stages - 1)
            take = jnp.logical_and(s == n_stages - 1,
                                   jnp.logical_and(idx >= 0, idx < n_micro))
            slot = jnp.clip(idx, 0, n_micro - 1)
            out = jnp.where(
                take, out.at[slot].set(h_out), out)
            cur = jax.lax.ppermute(h_out, axis, perm)
            return out, cur

        out, _ = jax.lax.fori_loop(0, total, step, (out, cur))
        # broadcast the last stage's outputs to every stage
        mask = (s == n_stages - 1).astype(out.dtype)
        return jax.lax.psum(out * mask, axis)

    return shard_map(
        local, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params,
                               is_leaf=lambda x: hasattr(x, "shape")),
                  P()),
        out_specs=P(), check_rep=False)(stage_params, x_micro)


def stage_stack(params_stacked, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    return jax.tree.map(
        lambda a: a.reshape(n_stages, a.shape[0] // n_stages, *a.shape[1:]),
        params_stacked)
