"""Activation-sharding context: logical constraints inside model code.

**Paper analogy (XpulpNN §V):** an active mesh is the paper's parallel
cluster — one JAX device per cluster core. `use_mesh` is the repo-wide
way to enter that cluster context; everything layered above
(`repro.kernels.api.qdot_sharded`, the serve engine's wave sharding, the
GSPMD constraints below) assumes it. Packed sub-byte arrays inside the
context obey the invariants in `repro.parallel.sharding`: sharded only on
the output-feature axis, never on the packed reduction axis (a shard
boundary inside a CHUNK group would split int8 containers across cores).

Model code calls `constrain(x, axes)` (or `constrain_first(x, options)`)
on major intermediates; when a mesh context is active (set by the step
builders during tracing) this lowers to with_sharding_constraint with the
rules-resolved PartitionSpec; otherwise it is a no-op, so the same model
code runs unsharded in unit tests.

Without these constraints GSPMD replicates attention/MLP activations over
the `model` axis (observed: 78 GiB/device temp for a 1B model at train_4k —
the scores tensor was materialized with ALL heads per device).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.sharding import DEFAULT_RULES, shard_spec_for

_ACTIVE = contextvars.ContextVar("repro_mesh_ctx", default=None)


def use_mesh(mesh):
    """Ambient-mesh context manager across jax versions.

    Newer jax spells it `jax.set_mesh(mesh)`; on jax<=0.4 the Mesh object
    itself is the context manager with the same ambient-mesh effect for
    jit/shard_map spec resolution. Every repro call site (and the tests)
    goes through this helper instead of `jax.set_mesh` directly.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


@contextlib.contextmanager
def activation_sharding(mesh, rules=DEFAULT_RULES):
    tok = _ACTIVE.set((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def active_mesh():
    ctx = _ACTIVE.get()
    return ctx[0] if ctx else None


def constrain(x, axes):
    """Constrain x's sharding by logical axes (None entries replicated).
    Non-divisible axes are dropped per shard_spec_for. No-op without an
    active mesh context."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = shard_spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_first(x, options):
    """Apply the first option whose mesh-mapped axes all divide — e.g.
    shard attention over heads when possible, else over sequence (context
    parallelism fallback for few-head GQA archs)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    for axes in options:
        spec = shard_spec_for(x.shape, axes, mesh, rules)
        want = rules.spec(axes, mesh)
        if tuple(spec) == tuple(want):   # nothing was dropped
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
    return constrain(x, options[-1])
