"""Explicit shard_map collectives: ring decode-attention and collective
matmul — the "below GSPMD" tools the §Perf Cell-B analysis identified
(GSPMD cannot repartition gathers/5-D einsum backwards across changed
layouts and falls back to replication; writing the collective schedule by
hand fixes the pattern).

**Paper analogy:** each shard_map body here is what one core of the
XpulpNN cluster executes between synchronization points — the ring
permutes play the role of the cluster's TCDM interconnect moving operand
tiles between cores. Contrast with the *psum-free* quantized cluster path
(`repro.kernels.api.qdot_sharded`): integer QNN GEMMs shard the
output-feature axis and need no collective at all, while the float
attention/matmul patterns here genuinely need cross-device combines —
which is why they get hand-written schedules. Packed sub-byte operands
never enter these ring paths: the sharding invariant (packed reduction
axis unsharded, `repro.parallel.sharding`) means a K-sharded collective
matmul over packed weights would split CHUNK containers and is rejected
at spec level.

ring_decode_attention — flash-decoding over a KV cache sequence-sharded on
the `model` axis: each shard computes partial (numerator, denominator,
max) over its KV slice and one log-sum-exp combine (psum of O(B*H*Dh))
merges them — instead of all-gathering O(B*H*T) scores. This is the
long_500k serving path for the global layers.

collective_matmul — all-gather-overlapped GEMM (Wang et al.): x arrives
K-sharded, w is N-sharded; each ring hop multiplies the resident x shard
against the matching K-block of the local w columns while the next x
shard is collective-permuted in. The MXU hides the transfer; no
materialized all-gather buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def ring_decode_attention(q, k_shard, v_shard, valid_mask, mesh: Mesh,
                          axis: str = "model"):
    """q: (B,H,Dh) replicated over `axis`; k/v: (B,T,H,Dh) KV-sequence
    sharded on T over `axis`; valid_mask: (B,T) bool. Returns (B,H,Dh)."""

    def local(q, k, v, mask):
        dh = q.shape[-1]
        s = jnp.einsum("bhd,bthd->bht", q, k,
                       preferred_element_type=jnp.float32) * dh ** -0.5
        s = jnp.where(mask[:, None, :], s, -jnp.inf)
        m_loc = jnp.max(s, axis=-1)                        # (B,H)
        has = jnp.isfinite(m_loc)
        safe_m = jnp.where(has, m_loc, 0.0)
        p = jnp.where(mask[:, None, :],
                      jnp.exp(s - safe_m[..., None]), 0.0)
        num = jnp.einsum("bht,bthd->bhd", p.astype(v.dtype), v)
        den = jnp.sum(p, axis=-1)                          # (B,H)
        m_glob = jax.lax.pmax(jnp.where(has, m_loc, -jnp.inf), axis)
        scale = jnp.exp(safe_m - m_glob) * has
        num = jax.lax.psum(num * scale[..., None].astype(num.dtype), axis)
        den = jax.lax.psum(den * scale, axis)
        return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)

    spec_kv = P(None, axis, None, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), spec_kv, spec_kv, P(None, axis)),
        out_specs=P(), check_rep=False)(q, k_shard, v_shard, valid_mask)


def collective_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """y = x @ w. x: (M,K) sharded on K over `axis`; w: (K,N) sharded on N
    over `axis`. Returns y (M,N) sharded on N.

    Ring schedule: after i hops device d holds x shard (d - i) mod n and
    multiplies it with its own w rows [(d-i)*kloc : (d-i+1)*kloc, :] —
    every (x_shard_j, w_block_j) pair is formed exactly once.
    """
    n = mesh.shape[axis]
    perm = [(j, (j + 1) % n) for j in range(n)]

    def local(x_loc, w_loc):
        idx = jax.lax.axis_index(axis)
        kloc = x_loc.shape[-1]
        acc = jnp.zeros((x_loc.shape[0], w_loc.shape[1]),
                        jnp.promote_types(x_loc.dtype, w_loc.dtype))

        def body(i, carry):
            acc, xs = carry
            src = (idx - i) % n
            block = jax.lax.dynamic_slice_in_dim(w_loc, src * kloc, kloc, 0)
            acc = acc + xs @ block
            xs = jax.lax.ppermute(xs, axis, perm)
            return acc, xs

        acc, _ = jax.lax.fori_loop(0, n, body, (acc, x_loc))
        return acc.astype(x_loc.dtype)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(None, axis), P(None, axis)),
                     out_specs=P(None, axis), check_rep=False)(x, w)
