"""Deterministic synthetic token pipeline with sharded host placement.

Real deployments swap `SyntheticLM` for a tokenized corpus reader; the
contract the trainer relies on is: deterministic per (seed, step) batches
(replayable after restart — data order survives checkpoint/restore without
persisting reader state), and device placement via the provided sharding.
"""
from __future__ import annotations

import numpy as np

import jax


class SyntheticLM:
    """Zipf-ish token stream with next-token labels; per-step determinism.

    A light markov flavour (token depends on previous) gives the training
    loss a learnable structure so examples show a real loss curve.
    """

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 sharding=None, src_dim: int = 0, src_len: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.sharding = sharding
        self.src_dim = src_dim
        self.src_len = src_len
        self._step = 0

    def _batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        # zipf-distributed tokens, clipped to vocab
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(z, self.vocab - 1).astype(np.int32)
        # markov structure: even positions copy previous token ± 1
        toks[:, 1::2] = np.minimum(
            toks[:, 0:-1:2] + (rng.integers(0, 2, toks[:, 1::2].shape)),
            self.vocab - 1)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.src_dim:
            batch["src_embed"] = rng.standard_normal(
                (self.batch, self.src_len, self.src_dim)).astype(np.float16) \
                * 0.05
        return batch

    def __iter__(self):
        return self

    def __next__(self):
        batch = self._batch_at(self._step)
        self._step += 1
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding[k])
                     for k, v in batch.items()}
        return batch

    def seek(self, step: int) -> None:
        self._step = step
