"""QAT deployment launcher: train -> calibrate -> plan -> pack -> eval.

The accuracy-side analogue of `repro.launch.vision`: where that CLI
calibrates a random-init net and prices plans in bytes, this one closes
the full quantization-aware loop on labeled data — fake-quant train
(`repro.qat`), task-loss calibrate on the *trained* weights, search a
mixed-precision plan against the measured loss degradation, fold the
integer artifact, and report integer-path accuracy for uniform and
planned deployments side by side:

    PYTHONPATH=src python -m repro.launch.qat --smoke --steps 60 \
        --out qat_plan.json --report qat_accuracy.json

``--from-ckpt DIR`` resumes training from a `repro.ckpt` checkpoint
(the state `--ckpt-dir` saves every `ckpt_every` steps); ``--w-bits``
picks the uniform training width (the planned deployments always ride
on the same trained weights). The report JSON is a lightweight run
record (NOT the schema-validated BENCH_accuracy.json — that is
`benchmarks/accuracy`'s artifact; this one is per-run tooling).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="qat-cnn",
                    help="vision config name (repro.vision.configs)")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-size net (CI: <2 min CPU)")
    ap.add_argument("--dataset", default="synthetic",
                    choices=("synthetic", "mnist"))
    ap.add_argument("--data-dir", default=None,
                    help="IDX directory for --dataset mnist")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--w-bits", type=int, default=4,
                    help="uniform QAT width (0 = float training)")
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--learned-absmax", action="store_true",
                    help="PACT learned activation ranges instead of EMA")
    ap.add_argument("--bits", default="8,4,2",
                    help="plan candidate widths, widest first")
    ap.add_argument("--budget-frac", type=float, default=0.35)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--eval-batch", type=int, default=100)
    ap.add_argument("--backend", default=None)
    ap.add_argument("--mesh", default=None, metavar="DP",
                    help="shard training batches over DP devices")
    ap.add_argument("--ckpt-dir", default=None,
                    help="save full train state here (repro.ckpt)")
    ap.add_argument("--from-ckpt", default=None,
                    help="resume training from this checkpoint dir")
    ap.add_argument("--out", default="qat_plan.json",
                    help="plan artifact (deploy.policy schema)")
    ap.add_argument("--report", default="qat_accuracy.json",
                    help="accuracy run record")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # heavy imports after argparse so --help stays instant
    import json

    import jax
    import numpy as np

    from repro.deploy.calibrate import calibrate_vision
    from repro.deploy.planner import auto_budget, plan_mixed_precision
    from repro.deploy.policy import save_plan
    from repro.obs import trace as obs
    from repro.qat.data import make_dataset
    from repro.qat.evaluate import deploy, evaluate_int, fold_check
    from repro.qat.train import QATConfig, train_qat
    from repro.vision.configs import get_vision_config
    from repro.vision.models import streamed_weight_bytes

    cfg = get_vision_config(args.net, smoke=args.smoke, a_bits=args.a_bits)
    data = make_dataset(args.dataset, split="train", seed=args.seed,
                        data_dir=args.data_dir)
    test = make_dataset(args.dataset, split="test", seed=args.seed,
                        data_dir=args.data_dir)
    candidates = tuple(int(b) for b in args.bits.split(","))

    mesh = None
    if args.mesh:
        dp = int(args.mesh.split(",")[0])
        mesh = jax.make_mesh((dp,), ("data",),
                             devices=jax.devices()[:dp])

    qc = QATConfig(steps=args.steps, batch=args.batch, lr=args.lr,
                   warmup=args.warmup,
                   w_bits=(args.w_bits or None), a_bits=args.a_bits,
                   learned_absmax=args.learned_absmax, seed=args.seed,
                   log_every=max(args.steps // 5, 1))
    with obs.span("launch.qat", cat="qat", net=cfg.name,
                  steps=args.steps, w_bits=args.w_bits):
        result = train_qat(cfg, data, qc, mesh=mesh,
                           ckpt_dir=args.ckpt_dir,
                           from_ckpt=args.from_ckpt)
        print(f"# trained {cfg.name}: "
              + " ".join(f"step{r['step']}={r['loss']:.3f}"
                         for r in result.log))
        if args.w_bits:
            fold_check(result)
            print("# fold_check: weight grids fold bit-exact")

        # task-loss calibration on the trained weights
        xs, ys = [], []
        for x, y in data.batches(args.batch, args.calib_batches):
            xs.append(np.asarray(x))
            ys.append(np.asarray(y))
        stats, _ = calibrate_vision(cfg, result.model_params(), xs,
                                    sensitivity="task_loss", labels=ys,
                                    a_bits=args.a_bits, bits=candidates)
        budget = auto_budget(stats, candidates, frac=args.budget_frac)
        plan = plan_mixed_precision(
            stats, budget, candidates=candidates, a_bits=args.a_bits,
            backend=args.backend,
            meta={"source": "task_loss", "net": cfg.name},
            granularity="channel_group")
        print(f"# plan (budget={budget:.4f}): "
              f"{ {r.pattern: r.w_bits for r in plan.rules} }")
        save_plan(plan, args.out)
        print(f"# wrote plan -> {args.out}")

        rows = []
        deployments = [("uniform", None)] if not args.w_bits else \
            [(f"uniform_w{args.w_bits}", None)]
        deployments.append(("task_loss_plan", plan))
        for tag, p in deployments:
            qnet = deploy(result, plan=p, backend=args.backend)
            ev = evaluate_int(qnet,
                              test.batches(args.eval_batch,
                                           args.eval_batches),
                              backend=args.backend)
            row = {"deployment": tag,
                   "accuracy": round(float(ev["accuracy"]), 6),
                   "correct": int(ev["correct"]), "n": int(ev["n"]),
                   "packed_weight_bytes":
                       int(streamed_weight_bytes(qnet))}
            rows.append(row)
            print(f"# {tag}: acc={row['accuracy']:.4f} "
                  f"bytes={row['packed_weight_bytes']}")

    report = {"net": cfg.name, "dataset": args.dataset,
              "train": {"steps": args.steps, "w_bits": args.w_bits,
                        "a_bits": args.a_bits, "seed": args.seed,
                        "final_loss": result.log[-1]["loss"]},
              "budget": budget, "rows": rows}
    with open(args.report, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote report -> {args.report}")


if __name__ == "__main__":
    main()
