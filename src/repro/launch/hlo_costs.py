"""Trip-count-aware cost extraction from post-optimization HLO text.

xla's cost_analysis() visits every computation ONCE — a jax.lax.scan body
(our layer stack) is counted a single time regardless of trip count
(verified empirically: 2-layer and 16-layer models report identical flops).
This walker parses the HLO text, builds the while-loop call graph, reads
`known_trip_count` from backend_config (fallback: the largest constant in
the loop condition), and multiplies per-computation costs through.

Costs per computation:
  flops      — 2 * numel(out) * contraction for every `dot` (+ rough conv);
               elementwise flops are ignored (MXU roofline dominated).
  io bytes   — sum of (operand + output) bytes over top-level instructions,
               skipping pure control ops (tuple/gte/parameter/bitcast/...).
               This is the post-fusion HBM-traffic approximation.
  collective — in/out bytes per collective op kind.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s8v": 1,
}

_CONTROL_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w[\w]*)\[([\d,]*)\]")
_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"}')


def _parse_instr_line(line: str):
    """Procedural parse: '%name = <shape> opcode(operands), attrs'.
    Tuple shapes contain parens, braces and /*index=N*/ comments, so regex
    on the shape is unreliable — walk balanced parens instead."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not (s.startswith("%") or s[:eq].replace(".", "").replace(
            "-", "").replace("_", "").isalnum()):
        return None
    name = s[:eq].strip().lstrip("%")
    rhs = s[eq + 3:].lstrip()
    if rhs.startswith("("):
        depth = 0
        i = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        out_shape = rhs[:i + 1]
        rest = rhs[i + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        out_shape = rhs[:sp]
        rest = rhs[sp + 1:].lstrip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    return Instr(name, out_shape, m.group(1), rest[m.end():])


def shape_numel_bytes(shape_str: str) -> Tuple[int, int]:
    """Sum over all array shapes found in the string."""
    numel = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dt]
    return numel, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    out_shape: str
    opcode: str
    rest: str       # everything after the '('


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]      # param name -> shape str
    instrs: List[Instr]
    is_entry: bool = False


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line.strip())
            if m and ("->" in line):
                params = {}
                for pm in re.finditer(r"%?([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                      m.group(3)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(2), params, [],
                                  is_entry=bool(m.group(1)))
                comps[cur.name] = cur
            continue
        if line.startswith("}") or line.strip() == "}":
            cur = None
            continue
        ins = _parse_instr_line(line)
        if ins is not None:
            cur.instrs.append(ins)
    return comps


def _operand_names(rest: str) -> List[str]:
    """Names of operands in 'a, %b, c), attrs...' (up to closing paren).

    Handles both operand spellings XLA emits: bare names ('%a, %b') and
    shape-prefixed ('f32[64,128]{1,0} %a, ...') — commas inside []/{} are
    not separators, and a shape prefix before the name is dropped."""
    depth_paren, depth_brack = 1, 0
    parts: List[str] = []
    token = ""
    for ch in rest:
        if ch == "(":
            depth_paren += 1
        elif ch == ")":
            depth_paren -= 1
            if depth_paren == 0:
                break
        if ch in "[{":
            depth_brack += 1
        elif ch in "]}":
            depth_brack -= 1
        if ch == "," and depth_paren == 1 and depth_brack == 0:
            parts.append(token)
            token = ""
        else:
            token += ch
    parts.append(token)
    names = []
    for t in parts:
        t = t.strip()
        if t:
            names.append(t.split()[-1].lstrip("%"))
    return names


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_numel, _ = shape_numel_bytes(instr.out_shape)
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", instr.rest)
    ops = _operand_names(instr.rest)
    if not m or not ops:
        return 0.0
    lhs_shape = shapes.get(ops[0], "")
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 0.0
    dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            contract *= dims[int(idx)]
    return 2.0 * out_numel * contract


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    io_bytes: float
    collective_in: Dict[str, float]
    collective_out: Dict[str, float]
    collective_counts: Dict[str, float]
    breakdown: Optional[list] = None

    @property
    def total_collective_in(self):
        return sum(self.collective_in.values())


def _dot_io(ins, shapes) -> int:
    """dot IO with operand dtypes capped at 2 bytes: the TPU MXU consumes
    bf16/int8 operands; XLA-CPU's bf16->f32 upcast must not be charged."""
    total = 0
    for name in _operand_names(ins.rest):
        if name in shapes:
            n, b = shape_numel_bytes(shapes[name])
            total += min(b, n * 2)
    n, b = shape_numel_bytes(ins.out_shape)
    return total + min(b, n * 2)


def analyze(text: str, breakdown: bool = False) -> ModuleCosts:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # multipliers via BFS over while calls (fusions inherit the caller's
    # multiplier; their bodies are not separately IO-counted)
    mult: Dict[str, float] = {entry.name: 1.0}
    stack = [entry.name]
    fusion_mult: Dict[str, float] = {}
    while stack:
        cname = stack.pop()
        comp = comps[cname]
        m0 = mult[cname]
        for ins in comp.instrs:
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                tm = _TRIP_RE.search(ins.rest)
                trip = float(tm.group(1)) if tm else _cond_trip(
                    comps.get(cm.group(1)) if cm else None)
                for target, f in ((bm, trip), (cm, trip + 1)):
                    if target and target.group(1) in comps:
                        tn = target.group(1)
                        add = m0 * f
                        if tn in mult:
                            mult[tn] += add
                        else:
                            mult[tn] = add
                            stack.append(tn)
            elif ins.opcode in ("fusion", "call", "custom-call", "map",
                                "reduce", "reduce-window", "scatter", "sort",
                                "conditional"):
                for target in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                         ins.rest):
                    if target in comps:
                        fusion_mult[target] = fusion_mult.get(target, 0.0) \
                            + m0
                # conditional branches
                for target in re.findall(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations)=\(?%?([\w.\-]+)", ins.rest):
                    if target in comps:
                        fusion_mult[target] = fusion_mult.get(target, 0.0) \
                            + m0

    flops = 0.0
    io = 0.0
    bd = []
    cin = {k: 0.0 for k in _COLLECTIVES}
    cout = {k: 0.0 for k in _COLLECTIVES}
    ccnt = {k: 0.0 for k in _COLLECTIVES}

    def _fusion_kind(called: Computation):
        """Classify a fused computation for IO accounting.

        'convert': pure dtype-convert fusion — a CPU-backend artifact (XLA
        CPU upcasts bf16 dots to f32); native-bf16 TPUs never materialize
        these, so count 0 bytes.
        'dus': root is dynamic-update-slice — XLA aliases in place; count
        2x the update region + the small operands, not the full buffer.
        """
        body_ops = [i for i in called.instrs
                    if i.opcode not in ("parameter", "constant")]
        # layout-only fusions (convert/copy/transpose/reshape chains): the
        # TPU compiler folds these into the consuming dot's operand read —
        # which the walker charges separately (alias-resolved, bf16-capped)
        # — so counting them here would double-charge phantom traffic.
        if body_ops and all(i.opcode in ("convert", "copy", "bitcast",
                                         "transpose", "reshape")
                            for i in body_ops):
            return "convert", None
        # any DUS inside the fusion: the big buffer is aliased in place on
        # TPU (converts around it fuse into the producer)
        for i in body_ops:
            if i.opcode == "dynamic-update-slice":
                ops_ = _operand_names(i.rest)
                upd = ops_[1] if len(ops_) > 1 else None
                return "dus", upd
        return "plain", None

    for cname, comp in comps.items():
        m0 = mult.get(cname)
        in_fusion = False
        if m0 is None:
            m0 = fusion_mult.get(cname)
            in_fusion = True
        if m0 is None:
            continue
        shapes = dict(comp.params)
        for ins in comp.instrs:
            shapes[ins.name] = ins.out_shape
        # alias pure-convert fusion outputs to their (cheaper) source: XLA
        # CPU upcasts bf16->f32 for dots; TPU reads the bf16/int8 original,
        # so consumers must be charged the source bytes.
        for ins in comp.instrs:
            if ins.opcode not in ("fusion", "convert", "copy", "bitcast",
                                  "transpose"):
                continue
            if ins.opcode == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                called = comps.get(cm.group(1)) if cm else None
                if called is None or _fusion_kind(called)[0] != "convert":
                    continue
            srcs = [n for n in _operand_names(ins.rest) if n in shapes]
            if len(srcs) == 1:
                _, sb = shape_numel_bytes(shapes[srcs[0]])
                _, ob = shape_numel_bytes(ins.out_shape)
                if sb <= ob:
                    shapes[ins.name] = shapes[srcs[0]]
        for ins in comp.instrs:
            op = ins.opcode
            io0 = io
            if op == "dot":
                flops += m0 * _dot_flops(ins, shapes)
                if not in_fusion:
                    io += m0 * _dot_io(ins, shapes)
                    if breakdown:
                        bd.append((io - io0, m0, cname, op, ins.name,
                                   ins.out_shape[:48]))
                continue
            if in_fusion:
                continue  # IO counted at the fusion call site
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                _, ob = shape_numel_bytes(ins.out_shape)
                ib = _operand_bytes(ins, shapes)
                cin[base] += m0 * ib
                cout[base] += m0 * ob
                ccnt[base] += m0
                io += m0 * (ib + ob)
            elif op in _CONTROL_OPS or op == "while":
                continue
            elif op == "dynamic-update-slice":
                # in-place: traffic = 2x the update region, not the operand
                ops_ = _operand_names(ins.rest)
                ub = 0
                if len(ops_) >= 2 and ops_[1] in shapes:
                    _, ub = shape_numel_bytes(shapes[ops_[1]])
                io += m0 * 2 * ub
            elif op == "dynamic-slice":
                _, ob = shape_numel_bytes(ins.out_shape)
                io += m0 * 2 * ob  # read slice + write result
            elif op == "fusion":
                called_m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                called = comps.get(called_m.group(1)) if called_m else None
                kind, upd = ("plain", None) if called is None \
                    else _fusion_kind(called)
                if kind == "convert":
                    continue
                if kind == "dus":
                    ub = 0
                    if upd is not None:
                        pnames = list(called.params)
                        if upd in called.params:
                            # update comes straight from a call operand
                            idx = pnames.index(upd)
                            ops_ = _operand_names(ins.rest)
                            if idx < len(ops_) and ops_[idx] in shapes:
                                _, ub = shape_numel_bytes(shapes[ops_[idx]])
                        else:
                            ishapes = {i.name: i.out_shape
                                       for i in called.instrs}
                            if upd in ishapes:
                                _, ub = shape_numel_bytes(ishapes[upd])
                    if ub == 0:  # fallback: smallest non-scalar operand
                        cands = []
                        for name in _operand_names(ins.rest):
                            if name in shapes:
                                _, b2 = shape_numel_bytes(shapes[name])
                                if b2 > 8:
                                    cands.append(b2)
                        ub = min(cands) if cands else 0
                    io += m0 * 2 * ub
                    continue
                # plain fusion: params consumed ONLY via dynamic-slice are
                # charged at the SLICE size (scan reads one layer of the
                # stacked params per trip, not the whole stack), bf16-capped
                # (stacked-param f32 copies are a CPU upcast artifact).
                ds_params = {}
                used_elsewhere = set()
                for i2 in called.instrs:
                    ops2 = _operand_names(i2.rest)
                    if i2.opcode == "dynamic-slice" and ops2 and \
                            ops2[0] in called.params:
                        n2, b2 = shape_numel_bytes(i2.out_shape)
                        ds_params.setdefault(ops2[0], 0)
                        ds_params[ops2[0]] += min(b2, n2 * 2)
                        ops2 = ops2[1:]
                    for o2 in ops2:
                        used_elsewhere.add(o2)
                _, ob = shape_numel_bytes(ins.out_shape)
                total = ob
                pnames = list(called.params)
                call_ops = _operand_names(ins.rest)
                for pi, pname in enumerate(pnames):
                    if pi >= len(call_ops):
                        break
                    src = call_ops[pi]
                    if pname in ds_params and pname not in used_elsewhere:
                        total += 2 * ds_params[pname]
                    elif src in shapes:
                        _, b2 = shape_numel_bytes(shapes[src])
                        total += b2
                io += m0 * total
            else:
                _, ob = shape_numel_bytes(ins.out_shape)
                io += m0 * (ob + _operand_bytes(ins, shapes))
            if breakdown and io > io0:
                bd.append((io - io0, m0, cname, op, ins.name,
                           ins.out_shape[:48]))
    bd2 = sorted(bd, reverse=True)[:40] if breakdown else None
    return ModuleCosts(flops, io, cin, cout, ccnt, bd2)


def _operand_bytes(ins: Instr, shapes: Dict[str, str]) -> int:
    total = 0
    for name in _operand_names(ins.rest):
        if name in shapes:
            _, b = shape_numel_bytes(shapes[name])
            total += b
    return total


def _cond_trip(cond: Optional[Computation]) -> float:
    if cond is None:
        return 1.0
    best = 1.0
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, float(m.group(1)))
    return best
