"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and emit memory/cost/collective analysis JSON.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
        --shape train_4k --mesh pod            # 16x16 single pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod

This never allocates real arrays: inputs are ShapeDtypeStructs and only
.lower().compile() runs. Failures here are sharding/memory bugs by
definition (see EXPERIMENTS.md §Dry-run).

The os.environ lines below MUST run before any jax import (jax locks the
device count at first init); `repro.obs.env` is import-light (no jax) so
reading the knob through it is safe here.
"""
import os

from repro.obs import env as obsenv

os.environ["XLA_FLAGS"] = ((obsenv.get("REPRO_EXTRA_XLA") or "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ShapeConfig, cells_for
from repro.launch.hlo_analysis import model_flops, roofline
from repro.launch.hlo_costs import analyze as analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.api import build, get_config, list_archs
from repro.nn.module import param_count
from repro.parallel.ctx import use_mesh
from repro.train.step import (TrainStepConfig, make_decode_fns,
                              make_prefill_fns, make_train_fns)

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def active_params(model) -> float:
    """N_active for the 6ND rule: MoE counts top_k+shared experts only."""
    cfg = model.cfg
    shapes = jax.eval_shape(lambda k: model.init(k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(s.size for s in jax.tree.leaves(shapes))
    if cfg.moe is None:
        return float(total)
    moe_leaves = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [getattr(k, "key", "") for k in path]
        if any(k in ("wi", "wg", "wo") for k in keys) and "moe" in keys and \
                "shared" not in keys:
            moe_leaves += leaf.size
    dense = total - moe_leaves
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return float(dense + moe_leaves * frac)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             quant_mode: str = "off", save: bool = True,
             rules=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    if quant_mode != "off":
        from repro.nn.layers import QuantConfig
        w_bits = int(quant_mode[1])
        a_bits = int(quant_mode[3]) if len(quant_mode) > 2 else 8
        cfg = dataclasses.replace(
            cfg, quant=QuantConfig(mode="int", w_bits=w_bits, a_bits=a_bits),
            kv_quant_bits=8 if shape_name.startswith(("decode", "long"))
            else 16)
    model = build(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = mesh.devices.size

    t0 = time.time()
    kwargs = dict(rules=rules) if rules is not None else {}
    if shape.kind == "train":
        from repro.train.optimizer import OptConfig
        tcfg = TrainStepConfig()
        if cfg.param_dtype == "bfloat16":  # 100B+ archs: int8 m/v (DESIGN)
            tcfg = TrainStepConfig(opt=OptConfig(state_bits=8))
        init_fn, step, shards = make_train_fns(
            model, mesh, shape, tcfg, **kwargs)
        state_shapes = jax.eval_shape(
            init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))
        in_specs = model.input_specs(shape)
        jitted = jax.jit(step, in_shardings=(shards["state"],
                                             shards["batch"]),
                         out_shardings=(shards["state"], None),
                         donate_argnums=(0,))
        with use_mesh(mesh):
            lowered = jitted.lower(state_shapes, in_specs)
    elif shape.kind == "prefill":
        step, shards = make_prefill_fns(model, mesh, shape, **kwargs)
        pshapes = jax.eval_shape(lambda k: model.init(k),
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        in_specs = model.input_specs(shape)
        jitted = jax.jit(step, in_shardings=(shards["params"],
                                             shards["batch"]))
        with use_mesh(mesh):
            lowered = jitted.lower(pshapes, in_specs)
    else:  # decode
        step, shards = make_decode_fns(model, mesh, shape, **kwargs)
        pshapes = jax.eval_shape(lambda k: model.init(k),
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        in_specs = model.input_specs(shape)
        jitted = jax.jit(step, in_shardings=(
            shards["params"], shards["cache"], shards["token"],
            shards["index"]),
            out_shardings=(None, shards["cache"]), donate_argnums=(1,))
        with use_mesh(mesh):
            lowered = jitted.lower(pshapes, in_specs["cache"],
                                   in_specs["token"], in_specs["index"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    mc = analyze_hlo(hlo)  # trip-count aware: flops/io/collectives x loops

    flops_dev = mc.flops
    bytes_dev = mc.io_bytes
    terms = roofline(flops_dev, bytes_dev, mc.total_collective_in)

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        # fwd-only: 2ND per token
    else:
        tokens = shape.global_batch  # one token per sequence
    n_act = active_params(model)
    mf_factor = 6.0 if shape.kind == "train" else 2.0
    mflops = mf_factor * n_act * tokens
    useful_ratio = mflops / max(flops_dev * n_dev, 1.0)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": n_dev, "quant": quant_mode, "tag": tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "total": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                      + mem.temp_size_in_bytes),
        },
        "flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collectives": {
            "counts": mc.collective_counts,
            "in_bytes": mc.collective_in,
            "out_bytes": mc.collective_out,
            "total_in": mc.total_collective_in,
        },
        "roofline": terms,
        "model_flops_total": mflops,
        "useful_flops_ratio": useful_ratio,
        "n_active_params": n_act,
    }
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{quant_mode}" if quant_mode != "off" else ""
        suffix += f"_{tag}" if tag else ""
        out = OUT_DIR / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--quant", default="off",
                    help="off | w8a8 | w4a8 | w4a4 | w2a8 | w2a2")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for s in cells_for(a):
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, args.mesh, args.quant, tag=args.tag)
            r = rec["roofline"]
            print(f"PASS {arch:26s} {shape:12s} {args.mesh:8s} "
                  f"mem/dev={rec['bytes_per_device']['total']/2**30:.2f}GiB "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s"
                  f" coll={r['collective_s']:.3e}s dom={r['dominant']}",
                  flush=True)
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch} {shape}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
