"""Production mesh builders. Importing this module never touches jax device
state — meshes are built inside functions only.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries DP
(or pipeline stages for the 1T-class archs, see parallel/pipeline.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
