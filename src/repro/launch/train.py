"""Training launcher: config-driven, fault-tolerant, mesh-aware.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 200 --batch 8 --seq 256 --mesh host --ckpt /tmp/ckpt

`--mesh host` uses whatever devices exist (CPU tests / single host);
`--mesh pod|multipod` builds the production mesh (requires the matching
device count — on a real slice, run under the usual multi-host launcher).
Checkpoints are atomic + async; re-running the same command resumes.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.base import SHAPES, ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import build, get_config
from repro.nn.layers import QuantConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig
from repro.train.step import TrainStepConfig, make_train_fns
from repro.parallel.ctx import use_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for this arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--qat", default=None,
                    help="fake-quant bits for QAT, e.g. w4a8")
    ap.add_argument("--opt-state-bits", type=int, default=32,
                    choices=[32, 8])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        from repro.models.api import get_smoke_config
        cfg = get_smoke_config(args.arch)
    else:
        cfg = get_config(args.arch)
    if args.qat:
        cfg = dataclasses.replace(cfg, quant=QuantConfig(
            mode="fake", w_bits=int(args.qat[1]), a_bits=int(args.qat[3])))

    model = build(cfg)
    mesh = (make_host_mesh() if args.mesh == "host" else
            make_production_mesh(multi_pod=args.mesh == "multipod"))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainStepConfig(opt=OptConfig(
        lr=args.lr, warmup=args.warmup, total_steps=args.steps,
        state_bits=args.opt_state_bits))
    init_fn, step, shards = make_train_fns(model, mesh, shape, tcfg)
    data = SyntheticLM(
        cfg.vocab, args.batch, args.seq, seed=args.seed,
        src_dim=cfg.d_model if (cfg.family == "encdec" or cfg.cross_every)
        else 0,
        src_len=args.seq if cfg.family == "encdec" else cfg.src_len)

    with use_mesh(mesh):
        jstep = jax.jit(step, in_shardings=(shards["state"],
                                            shards["batch"]),
                        out_shardings=(shards["state"], None),
                        donate_argnums=(0,))
        trainer = Trainer(init_fn, jstep, data, TrainerConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt))
        state, log = trainer.run(jax.random.PRNGKey(args.seed))
    for rec in log[:: max(len(log) // 10, 1)]:
        print(f"step {rec['step']:6d} loss {rec['loss']:.4f} "
              f"gnorm {rec['grad_norm']:.2f} {rec['dt'] * 1e3:.0f} ms")
    print(f"final step {log[-1]['step']} loss {log[-1]['loss']:.4f}; "
          f"stragglers {trainer.monitor.flags}; ckpts at {args.ckpt}")


if __name__ == "__main__":
    main()
