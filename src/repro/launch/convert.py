"""Checkpoint -> packed integer deployment artifact (the Dory-style flow
the paper integrates into, §V-B): walk an fp parameter tree, quantize and
chunk-planar-pack every dense weight, and emit the int-mode parameter tree
the serving path consumes.
"""
from __future__ import annotations

import jax

from repro.nn.layers import pack_dense_weights


def convert_params(q_tree, fp_tree, w_bits: int):
    """Fill an int-mode parameter tree (zeros-initialized `w_packed` /
    `w_scale` leaves) from the fp checkpoint tree. Stacked (scanned) layer
    weights are vmapped over the layer dim."""
    if isinstance(q_tree, dict) and "w_packed" in q_tree:
        w = fp_tree["w"]
        if w.ndim == 3:   # (layers, K, N) stacked
            packed, scale = jax.vmap(
                lambda ww: pack_dense_weights(ww, w_bits))(w)
        else:
            packed, scale = pack_dense_weights(w, w_bits)
        out = dict(q_tree, w_packed=packed, w_scale=scale)
        if "b" in q_tree and "b" in fp_tree:
            out["b"] = fp_tree["b"]
        return out
    if isinstance(q_tree, dict):
        return {k: (convert_params(q_tree[k], fp_tree[k], w_bits)
                    if k in fp_tree else q_tree[k]) for k in q_tree}
    # non-dense leaves (norms, embeddings, router, conv, ...) pass through
    return fp_tree


def artifact_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
