"""Checkpoint -> packed integer deployment artifact (the Dory-style flow
the paper integrates into, §V-B): walk an fp parameter tree, quantize and
chunk-planar-pack every dense weight, and emit the int-mode parameter tree
the serving path consumes.

The uniform single-bit-width entry point below is a thin wrapper over the
mixed-precision converter (`repro.deploy.apply.apply_plan`), which walks
with parameter paths and resolves per-dense bit-widths from a
`PrecisionPlan` — `plan=None` degenerates to uniform `w_bits` everywhere.
"""
from __future__ import annotations

from repro.deploy.apply import apply_plan
from repro.nn.module import param_bytes


def convert_params(q_tree, fp_tree, w_bits: int):
    """Fill an int-mode parameter tree (zeros-initialized `w_packed` /
    `w_scale` leaves) from the fp checkpoint tree at one uniform bit-width.
    Stacked (scanned) layer weights pack along their own K axis."""
    return apply_plan(q_tree, fp_tree, None, w_bits)


def artifact_bytes(params) -> int:
    """Total bytes of a (packed or fp) parameter tree — one accounting
    (`nn/module.py::param_bytes`) shared by converter, engine, and CLIs."""
    return param_bytes(params)
