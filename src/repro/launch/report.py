"""Render the dry-run JSON records into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report [--mesh pod]
"""
from __future__ import annotations

import argparse
import json
import pathlib

DRY = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x * 1e6:.1f}us"
    if x < 0.1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    return f"{x / 2**30:.2f}"


def load(mesh: str, tag: str = ""):
    out = {}
    for p in sorted(DRY.glob(f"*__{mesh}{tag}.json")):
        r = json.loads(p.read_text())
        if r.get("tag", "") != (tag.lstrip("_") if tag else "") or \
                r.get("quant", "off") != "off":
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def table(mesh: str, tag: str = "") -> str:
    recs = load(mesh, tag)
    lines = [
        "| arch | shape | GiB/dev | compute | memory | collective | "
        "dominant | roofline frac | useful/HLO flops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = sorted(recs, key=lambda k: (k[0], k[1]))
    for key in order:
        r = recs[key]
        t = r["roofline"]
        lines.append(
            "| {} | {} | {} | {} | {} | {} | {} | {:.1%} | {:.2f} |".format(
                key[0], key[1], fmt_b(r["bytes_per_device"]["total"]),
                fmt_s(t["compute_s"]), fmt_s(t["memory_s"]),
                fmt_s(t["collective_s"]),
                t["dominant"].replace("_s", ""),
                t["roofline_fraction"], r["useful_flops_ratio"]))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(table(args.mesh, f"_{args.tag}" if args.tag else ""))


if __name__ == "__main__":
    main()
