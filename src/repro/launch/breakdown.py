"""Per-op IO/collective breakdown for one dry-run cell — the 'profile'
driving §Perf hypotheses (dry-run counterpart of a wall-clock profiler).

    PYTHONPATH=src python -m repro.launch.breakdown --arch X --shape Y
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.launch.hlo_costs import analyze
from repro.launch.mesh import make_production_mesh
from repro.models.api import build, get_config
from repro.parallel.ctx import use_mesh
from repro.train.step import (TrainStepConfig, make_decode_fns,
                              make_prefill_fns, make_train_fns)


def compile_cell(arch, shape_name, mesh_kind="pod", quant="off", rules=None):
    import dataclasses
    cfg = get_config(arch)
    if quant != "off":
        from repro.nn.layers import QuantConfig
        cfg = dataclasses.replace(
            cfg, quant=QuantConfig(mode="int", w_bits=int(quant[1]),
                                   a_bits=int(quant[3])),
            kv_quant_bits=8 if shape_name.startswith(("decode", "long"))
            else 16)
    model = build(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    kwargs = dict(rules=rules) if rules is not None else {}
    if shape.kind == "train":
        from repro.train.optimizer import OptConfig
        tcfg = TrainStepConfig()
        if cfg.param_dtype == "bfloat16":
            tcfg = TrainStepConfig(opt=OptConfig(state_bits=8))
        init_fn, step, shards = make_train_fns(model, mesh, shape, tcfg,
                                               **kwargs)
        ss = jax.eval_shape(init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))
        ins = model.input_specs(shape)
        with use_mesh(mesh):
            return jax.jit(step, in_shardings=(shards["state"],
                                               shards["batch"]),
                           out_shardings=(shards["state"], None),
                           donate_argnums=(0,)).lower(ss, ins).compile()
    if shape.kind == "prefill":
        step, shards = make_prefill_fns(model, mesh, shape, **kwargs)
        ps = jax.eval_shape(lambda k: model.init(k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
        ins = model.input_specs(shape)
        with use_mesh(mesh):
            return jax.jit(step, in_shardings=(shards["params"],
                                               shards["batch"])
                           ).lower(ps, ins).compile()
    step, shards = make_decode_fns(model, mesh, shape, **kwargs)
    ps = jax.eval_shape(lambda k: model.init(k),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
    ins = model.input_specs(shape)
    with use_mesh(mesh):
        return jax.jit(step, in_shardings=(
            shards["params"], shards["cache"], shards["token"],
            shards["index"]),
            out_shardings=(None, shards["cache"]),
            donate_argnums=(1,)).lower(
                ps, ins["cache"], ins["token"], ins["index"]).compile()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--quant", default="off")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()
    c = compile_cell(args.arch, args.shape, args.mesh, args.quant)
    mc = analyze(c.as_text(), breakdown=True)
    print(f"flops/dev {mc.flops:.3e}  io {mc.io_bytes/1e9:.1f} GB/dev  "
          f"coll_in {mc.total_collective_in/1e9:.1f} GB/dev")
    print("collectives:", {k: f"{v/1e9:.1f}GB"
                           for k, v in mc.collective_in.items() if v})
    print(f"{'GB':>8} {'xTrip':>6} op/name")
    for t, m, cn, op, n, osh in mc.breakdown[: args.top]:
        print(f"{t/1e9:8.1f} x{m:5.0f} {op:14s} {n:44s} {osh}")


if __name__ == "__main__":
    main()
