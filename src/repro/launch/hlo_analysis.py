"""Parse compiled HLO for collective bytes + derive roofline terms.

collective_bytes is not in cost_analysis(): we scan the post-optimization
HLO text for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops and sum operand sizes (spec definition: input
operands; output bytes are recorded too for reference).

Roofline terms (per device, seconds)  — v5e constants:
    compute    = HLO_FLOPs / peak_FLOPs           (197e12 bf16 FLOP/s)
    memory     = HLO_bytes / HBM_bw               (819e9 B/s)
    collective = collective_bytes / link_bw       (~50e9 B/s per link)
cost_analysis flops/bytes are already per-partition under SPMD.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12        # bf16 / int8 MXU, per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    in_bytes: dict
    out_bytes: dict

    @property
    def total_in(self):
        return sum(self.in_bytes.values())

    @property
    def total_out(self):
        return sum(self.out_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    in_b = {k: 0 for k in _COLLECTIVES}
    out_b = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # "<out_shape> op-name(" — fused/async starts count once (-start)
        m = re.match(r"^[%\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        out_shape, opname = m.group(1), m.group(2)
        base = opname.replace("-start", "")
        if base not in _COLLECTIVES:
            continue
        if opname.endswith("-done"):
            continue
        args = ls[ls.find("(") + 1:]
        counts[base] += 1
        in_b[base] += _shape_bytes(args.split("),", 1)[0]
                                   if args.startswith("(") else
                                   args.split(")", 1)[0])
        out_b[base] += _shape_bytes(out_shape)
    return CollectiveStats(counts, in_b, out_b)


def roofline(flops_per_device: float, bytes_per_device: float,
             collective_in_bytes: float, n_links: int = 1) -> dict:
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = bytes_per_device / HBM_BW
    t_collective = collective_in_bytes / (LINK_BW * n_links)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms.update({
        "dominant": dom,
        "bound_s": bound,
        # fraction of roofline: useful work time / achievable-bound time
        "roofline_fraction": (t_compute / bound) if bound > 0 else 1.0,
    })
    return terms


def model_flops(n_params_active: float, tokens: float) -> float:
    """6·N·D rule (fwd+bwd); callers pass N_active for MoE."""
    return 6.0 * n_params_active * tokens
