"""Vision deployment launcher: calibrate -> plan -> pack -> serve a CNN.

The CNN analogue of `repro.launch.deploy` + `repro.launch.serve` in one
CLI: build a paper-class network (`repro.vision.configs`), calibrate it
on images (random in --smoke runs), search a per-layer W{8,4,2} plan,
pack the integer artifact, and serve an image batch through the
`VisionEngine` (optionally mesh-sharded):

    PYTHONPATH=src python -m repro.launch.vision --net resnet8 --smoke \
        --budget auto --out vplan.json --requests 6 --batch 4

``--from-plan plan.json`` skips calibration/search and re-packs from an
existing plan artifact (the round-trip CI exercises); ``--mesh dp,tp``
serves waves data-parallel on a device mesh (tp shards conv output
channels inside the kernels when it divides them).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", required=True,
                    help="vision config name (repro.vision.configs)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--a-bits", type=int, default=8,
                    help="activation bits at every layer boundary")
    ap.add_argument("--bits", default="8,4,2",
                    help="candidate w_bits, widest first")
    ap.add_argument("--budget", default="auto")
    ap.add_argument("--backend", default=None,
                    help="kernel backend the net routes through "
                         "(repro.kernels.api; default: registry)")
    ap.add_argument("--from-plan", default=None,
                    help="existing plan JSON: skip calibrate/search")
    ap.add_argument("--out", default="vision_plan.json")
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--calib-batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="serve on a (data=DP, model=TP) device mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # heavy imports after argparse so --help stays instant
    import jax
    import numpy as np

    from repro.deploy.calibrate import calibrate_vision
    from repro.deploy.planner import auto_budget, plan_mixed_precision
    from repro.deploy.policy import load_plan, save_plan
    from repro.serve.engine import VisionEngine
    from repro.vision.configs import get_vision_config
    from repro.vision.models import (collect_absmax, init_fp, quantize_net,
                                     vision_artifact_bytes)

    mesh = None
    if args.mesh:
        try:
            dp, tp = (int(v) for v in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh {args.mesh!r}: expected DP,TP")
        need, have = dp * tp, len(jax.devices())
        if need > have:
            raise SystemExit(
                f"--mesh {args.mesh} needs {need} devices, found {have}; "
                "set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{need}")
        mesh = jax.make_mesh((dp, tp), ("data", "model"),
                             devices=jax.devices()[:need])

    cfg = get_vision_config(args.net, smoke=args.smoke, a_bits=args.a_bits)
    candidates = tuple(int(b) for b in args.bits.split(","))
    rng = np.random.default_rng(args.seed)
    fp_params = init_fp(cfg, seed=args.seed)
    batches = [rng.uniform(0, 1, size=(
        args.calib_batch, *cfg.in_hw, cfg.in_ch)).astype(np.float32)
        for _ in range(args.calib_batches)]

    from repro.obs import trace as obs

    if args.from_plan:
        plan = load_plan(args.from_plan)
        absmax = collect_absmax(cfg, fp_params, batches)
        print(f"loaded plan {args.from_plan} ({len(plan.rules)} rules, "
              f"w_bits {plan.distinct_w_bits()})")
    else:
        print(f"calibrating {cfg.name}: {len(batches)} batches of "
              f"{args.calib_batch} images {cfg.in_hw}, "
              f"candidates W{candidates}")
        with obs.span("deploy.calibrate", cat="deploy", arch=cfg.name,
                      batches=len(batches), candidates=candidates):
            stats, absmax = calibrate_vision(cfg, fp_params, batches,
                                             bits=candidates)
        with obs.span("deploy.plan", cat="deploy", arch=cfg.name,
                      paths=len(stats)):
            budget = (auto_budget(stats, candidates)
                      if args.budget == "auto" else float(args.budget))
            plan = plan_mixed_precision(
                stats, budget, candidates=candidates, a_bits=args.a_bits,
                backend=args.backend,
                meta={"arch": cfg.name, "smoke": args.smoke})
        for r in plan.rules:
            st = stats[r.pattern]
            print(f"  {r.pattern:<16} W{r.w_bits}A{r.a_bits}  "
                  f"absmax={st.a_absmax:.3f}  sens="
                  f"{{{', '.join(f'{b}:{st.sens(b):.2e}' for b in candidates)}}}")
        save_plan(plan, args.out)
        print(f"plan ({len(plan.rules)} rules, w_bits "
              f"{plan.distinct_w_bits()}) -> {args.out}")

    with obs.span("deploy.pack", cat="deploy", arch=cfg.name,
                  rules=len(plan.rules)):
        qnet = quantize_net(cfg, fp_params, absmax, plan=plan,
                            backend=args.backend)
    print(f"packed artifact: {vision_artifact_bytes(qnet):,} bytes, "
          f"per-layer bits {qnet.layer_bits()}")

    engine = VisionEngine(qnet, batch_size=args.batch, mesh=mesh,
                          backend=args.backend)
    if mesh is not None:
        print(f"mesh: data={mesh.shape['data']} model={mesh.shape['model']}"
              f" ({len(mesh.devices.flat)} devices)")
    print(f"kernel backends: {engine.kernel_backends()}")
    images = rng.uniform(0, 1, size=(
        args.requests, *cfg.in_hw, cfg.in_ch)).astype(np.float32)
    with obs.span("serve.generate", cat="serve", requests=len(images),
                  batch=args.batch):
        logits = engine.run(images)
    preds = logits.argmax(-1)
    print(f"served {len(images)} images in waves of {args.batch}: "
          f"preds {preds.tolist()}")
    rep = engine.utilization_report()
    lat = rep["latency_us"]
    if lat is not None:
        qd = rep["queue_depth"]
        print(f"wave latency: p50={lat['p50'] / 1e3:.1f}ms "
              f"p95={lat['p95'] / 1e3:.1f}ms p99={lat['p99'] / 1e3:.1f}ms "
              f"over {lat['waves']} wave(s); queue depth mean "
              f"{qd['mean']:.1f} max {qd['max']}")
    if mesh is not None:
        print(f"utilization: mean {rep['mean_util']:.3f} over "
              f"{rep['waves']} waves, per-device "
              f"{[round(u, 3) for u in rep['per_device']]}")
    trace_path = obs.export_if_configured("vision_trace.json")
    if trace_path:
        print(f"trace -> {trace_path} (render: python -m repro.obs.report)")
    print("vision deploy done")


if __name__ == "__main__":
    main()
