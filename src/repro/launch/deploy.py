"""Mixed-precision deployment launcher: calibrate -> plan -> pack -> save.

Turns an fp checkpoint (or a fresh init in --smoke runs) into a per-layer
W{8,4,2} packed serving artifact plus the JSON plan that describes it:

    PYTHONPATH=src python -m repro.launch.deploy --arch qwen2.5-3b --smoke \
        --budget auto --out plan.json

The plan is then served with `python -m repro.launch.serve ... --plan
plan.json` (see README §Mixed-precision deployment).

``--from-plan old_plan.json`` skips calibration/search and re-packs from
an existing plan, re-saving it to ``--out`` in the current schema — the
upgrade path for pre-registry (schema-v1 ``use_kernel``) artifacts, which
load with a DeprecationWarning and map onto the ``backend`` field.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.deploy.apply import apply_plan
from repro.deploy.calibrate import calibrate
from repro.deploy.planner import auto_budget, plan_mixed_precision
from repro.deploy.policy import PLAN_VERSION, load_plan, save_plan
from repro.launch.convert import artifact_bytes
from repro.models.api import Model, build, get_config
from repro.nn.layers import QuantConfig
from repro.obs import trace as obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--budget", default="auto",
                    help="total sensitivity budget (float) or 'auto'")
    ap.add_argument("--bits", default="8,4,2",
                    help="candidate w_bits, widest first")
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    help="kernel backend the plan rules route through "
                         "(repro.kernels.api; default: registry resolution)")
    ap.add_argument("--from-plan", default=None,
                    help="existing plan JSON: skip calibrate/search, "
                         "re-save to --out in the current schema, and pack")
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--calib-batch", type=int, default=2)
    ap.add_argument("--calib-seq", type=int, default=32)
    ap.add_argument("--out", default="plan.json")
    ap.add_argument("--artifact", default=None,
                    help="directory to save the packed param tree into")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir to load fp params from")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        from repro.models.api import get_smoke_config
        cfg = get_smoke_config(args.arch)
    else:
        cfg = get_config(args.arch)
    candidates = tuple(int(b) for b in args.bits.split(","))

    fp_model = build(cfg)
    if args.ckpt:
        from repro.ckpt.checkpoint import restore
        state, _ = restore(args.ckpt)
        fp_params = state["params"] if "params" in state else state
    else:
        fp_params = fp_model.init(jax.random.PRNGKey(args.seed))

    if args.from_plan:
        ignored = [f for f, dflt in (("--backend", None), ("--bits", "8,4,2"),
                                     ("--budget", "auto"), ("--a-bits", 8))
                   if getattr(args, f.lstrip("-").replace("-", "_")) != dflt]
        if ignored:
            print(f"warning: {', '.join(ignored)} ignored with --from-plan "
                  "(the existing plan's rules are kept verbatim)")
        plan = load_plan(args.from_plan)   # v1 artifacts warn + map backend
        save_plan(plan, args.out)
        print(f"re-saved plan {args.from_plan} -> {args.out} "
              f"(schema v{PLAN_VERSION}, {len(plan.rules)} rules, "
              f"w_bits {plan.distinct_w_bits()}, backends "
              f"{sorted({r.backend for r in plan.rules}, key=str)})")
    else:
        rng = np.random.default_rng(args.seed)
        batches = [rng.integers(2, cfg.vocab, size=(
            args.calib_batch, args.calib_seq)).astype(np.int32)
            for _ in range(args.calib_batches)]
        print(f"calibrating {cfg.name}: {len(batches)} batches of "
              f"{args.calib_batch}x{args.calib_seq} tokens, "
              f"candidates W{candidates}")
        with obs.span("deploy.calibrate", cat="deploy", arch=cfg.name,
                      batches=len(batches), candidates=candidates):
            stats = calibrate(fp_model, fp_params, batches, bits=candidates,
                              a_bits=args.a_bits)

        with obs.span("deploy.plan", cat="deploy", arch=cfg.name,
                      paths=len(stats)):
            budget = (auto_budget(stats, candidates)
                      if args.budget == "auto" else float(args.budget))
            plan = plan_mixed_precision(
                stats, budget, candidates=candidates, a_bits=args.a_bits,
                backend=args.backend,
                meta={"arch": cfg.name, "smoke": args.smoke})
        print(f"budget {budget:.6g} -> total sensitivity "
              f"{plan.meta['total_sensitivity']:.6g}")
        for r in plan.rules:
            st = stats[r.pattern]
            print(f"  {r.pattern:<28} W{r.w_bits}A{r.a_bits}  "
                  f"absmax={st.a_absmax:.3f}  "
                  f"sens={{{', '.join(f'{b}:{st.sens(b):.2e}' for b in candidates)}}}")
        save_plan(plan, args.out)
        print(f"plan ({len(plan.rules)} rules, w_bits "
              f"{plan.distinct_w_bits()}) -> {args.out}")

    base = QuantConfig(mode="int", w_bits=plan.default_w_bits,
                       a_bits=plan.default_a_bits)
    q_model = Model(dataclasses.replace(cfg, quant=base, quant_plan=plan))
    with obs.span("deploy.pack", cat="deploy", arch=cfg.name,
                  rules=len(plan.rules)):
        q_params = apply_plan(q_model.init(jax.random.PRNGKey(0)),
                              fp_params, plan, plan.default_w_bits)
    mixed_b = artifact_bytes(q_params)
    fp_b = artifact_bytes(fp_params)
    if {"packed_weight_bytes", "uniform_w8_bytes"} <= set(plan.meta):
        # uniform-w8 comparison without packing a second artifact: the
        # non-dense remainder (embeds/norms/biases) is identical, only the
        # planner-accounted dense bytes differ
        w8_b = (mixed_b - plan.meta["packed_weight_bytes"]
                + plan.meta["uniform_w8_bytes"])
        print(f"artifact bytes: fp {fp_b:,}  uniform-w8 {w8_b:,}  "
              f"mixed {mixed_b:,}  ({mixed_b / w8_b:.3f}x of w8)")
    else:  # hand-written / stripped-meta plans (--from-plan)
        print(f"artifact bytes: fp {fp_b:,}  mixed {mixed_b:,}")

    if args.artifact:
        from repro.ckpt.checkpoint import save
        save(args.artifact, 0, {"params": q_params})
        save_plan(plan, f"{args.artifact}/plan.json")
        print(f"packed artifact -> {args.artifact}")
    trace_path = obs.export_if_configured("deploy_trace.json")
    if trace_path:
        print(f"trace -> {trace_path} (render: python -m repro.obs.report)")
    print("deploy done")


if __name__ == "__main__":
    main()
