"""Serving launcher: load (or init) params, optionally convert to the
packed sub-byte deployment artifact, and serve a batch of synthetic
requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --quant w4a8 --requests 8 --max-new 16

Mixed-precision serving: pass a deployment plan produced by
`python -m repro.launch.deploy` and each dense layer is packed at its
plan-resolved bit-width instead of one uniform --quant:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --plan plan.json --requests 8

Cluster-parallel serving: ``--mesh dp,tp`` builds a (data=dp, model=tp)
device mesh (the paper's N-core cluster; on CPU force host devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), shards request
waves data-parallel over the `data` axis, and prints the per-device slot
utilization report after serving:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --quant w4a8 --requests 8 --batch 4 --mesh 4,2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.launch.convert import convert_params
from repro.models.api import build, get_config
from repro.nn.layers import QuantConfig
from repro.obs import trace as obs
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="off", help="off | w8a8 | w4a8 ...")
    ap.add_argument("--plan", default=None,
                    help="mixed-precision plan JSON (repro.launch.deploy); "
                         "overrides --quant")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[16, 8])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir to load params from")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DP,TP",
                    help="serve on a (data=DP, model=TP) device mesh, "
                         "e.g. --mesh 4,2; waves are sharded "
                         "data-parallel over DP (batch must divide DP). "
                         "On CPU, export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        try:
            dp, tp = (int(v) for v in args.mesh.split(","))
        except ValueError:
            raise SystemExit(
                f"--mesh {args.mesh!r}: expected DP,TP (two comma-"
                "separated ints), e.g. --mesh 4,2 or --mesh 8,1")
        need = dp * tp
        have = len(jax.devices())
        if have < need:
            raise SystemExit(
                f"--mesh {args.mesh} needs {need} devices, found {have}; "
                "on CPU set XLA_FLAGS=--xla_force_host_platform_"
                f"device_count={need} before launching")
        mesh = jax.make_mesh((dp, tp), ("data", "model"),
                             devices=jax.devices()[:need])

    if args.smoke:
        from repro.models.api import get_smoke_config
        cfg = get_smoke_config(args.arch)
    else:
        cfg = get_config(args.arch)
    cfg = dataclasses.replace(cfg, kv_quant_bits=args.kv_bits)

    fp_model = build(cfg)
    if args.ckpt:
        from repro.ckpt.checkpoint import restore
        state, _ = restore(args.ckpt)
        fp_params = state["params"] if "params" in state else state
    else:
        fp_params = fp_model.init(jax.random.PRNGKey(args.seed))

    plan = None
    if args.plan:
        from repro.deploy.apply import apply_plan
        from repro.deploy.policy import load_plan
        plan = load_plan(args.plan)
        qcfg = QuantConfig(mode="int", w_bits=plan.default_w_bits,
                           a_bits=plan.default_a_bits)
        cfg_q = dataclasses.replace(cfg, quant=qcfg, quant_plan=plan)
        model = build(cfg_q)
        params = apply_plan(model.init(jax.random.PRNGKey(0)), fp_params,
                            plan, plan.default_w_bits)
        mode = f"plan:{args.plan} w_bits={plan.distinct_w_bits()}"
    elif args.quant != "off":
        qcfg = QuantConfig(mode="int", w_bits=int(args.quant[1]),
                           a_bits=int(args.quant[3]))
        cfg_q = dataclasses.replace(cfg, quant=qcfg)
        model = build(cfg_q)
        params = convert_params(model.init(jax.random.PRNGKey(0)),
                                fp_params, qcfg.w_bits)
        mode = args.quant
    else:
        model, params = fp_model, fp_params
        mode = "off"

    from repro.nn.module import param_bytes
    pbytes = param_bytes(params)
    print(f"{cfg.name} [{mode}] params {pbytes / 2**20:.1f} MiB "
          f"({pbytes:,} bytes)")

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(2, cfg.vocab, size=(
        int(rng.integers(2, 8)),)).astype(np.int32),
        max_new_tokens=args.max_new) for _ in range(args.requests)]
    eng = Engine(model, params, batch_size=args.batch, max_len=args.max_len,
                 plan=plan, mesh=mesh)
    if mesh is not None:
        print(f"mesh: data={mesh.shape['data']} model={mesh.shape['model']} "
              f"({len(mesh.devices.flat)} devices; waves sharded over "
              "'data')")
    if mode != "off":
        from repro.kernels.api import ENV_VAR
        kb = eng.kernel_backends()
        print(f"kernel backends: qdot={kb['qdot']} qconv={kb['qconv']} "
              f"(override: {ENV_VAR} or QuantConfig.backend)")
    t0 = time.time()
    with obs.span("serve.generate", cat="serve", requests=len(reqs),
                  batch=args.batch):
        out = eng.generate(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in out)
    print(f"{toks} tokens / {dt:.2f}s = {toks / dt:.1f} tok/s (CPU, "
          f"structure-comparative only)")
    rep = eng.utilization_report()
    lat = rep["latency_us"]
    if lat is not None:
        qd = rep["queue_depth"]
        print(f"wave latency: p50={lat['p50'] / 1e3:.1f}ms "
              f"p95={lat['p95'] / 1e3:.1f}ms p99={lat['p99'] / 1e3:.1f}ms "
              f"over {lat['waves']} wave(s); queue depth mean "
              f"{qd['mean']:.1f} max {qd['max']}")
    if mesh is not None:
        per = " ".join(f"d{d}={u:.0%}" for d, u in
                       enumerate(rep["per_device"]))
        print(f"cluster utilization: {rep['mean_util']:.0%} over "
              f"{rep['waves']} wave(s) [{per}] — idle devices == padded "
              "slots")
    for r in out[:3]:
        print("  prompt", r.prompt.tolist(), "->", r.out.tolist())
    trace_path = obs.export_if_configured("serve_trace.json")
    if trace_path:
        print(f"trace -> {trace_path} (render: python -m repro.obs.report)")


if __name__ == "__main__":
    main()
