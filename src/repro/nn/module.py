"""Minimal functional parameter system (no flax dependency).

A module is a pair of plain functions over nested dicts:
  *_def(cfg)   -> tree of ParamDef (single source of truth: shape + logical
                  axes + initializer)
  *_apply(p,.) -> forward

`init_params` materializes a ParamDef tree with per-leaf derived RNG keys;
`logical_specs` extracts the logical-axis tree that parallel/sharding.py
turns into PartitionSpecs. Layer stacking for lax.scan prepends a "layers"
axis via `stack_defs`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple          # logical axis names, len == len(shape), None ok
    init: str = "normal"  # normal | zeros | ones | embed | scalar:<v>
    dtype: Any = jnp.float32
    scale: float = 1.0   # stddev multiplier for "normal" (fan-in scaled)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(d: ParamDef, key):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init.startswith("scalar:"):
        return jnp.full(d.shape, float(d.init.split(":")[1]), d.dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape) * d.scale).astype(d.dtype)
    # fan-in scaled normal: last-but-one dim is fan-in for matrices
    fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
    std = d.scale / (fan_in ** 0.5)
    return (jax.random.normal(key, d.shape) * std).astype(d.dtype)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, key):
    """Materialize a ParamDef tree. Keys are derived from the tree path via
    fold_in of stable hashes, so adding a parameter never reshuffles others
    (important for elastic restarts / warm starts)."""
    leaves = _flatten(defs)
    out = {}
    for path, d in leaves:
        k = key
        for part in path:
            k = jax.random.fold_in(k, _stable_hash(part))
        _set(out, path, _init_leaf(d, k))
    return out


def logical_specs(defs):
    leaves = _flatten(defs)
    out = {}
    for path, d in leaves:
        _set(out, path, d.axes)
    return out


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for scan-over-layers parameter stacking)."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes,
                           d.init, d.dtype, d.scale),
        defs, is_leaf=_is_def)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in str(s):
        h = (h ^ ord(ch)) * 16777619 & 0xFFFFFFFF
    return h


def _flatten(tree, path=()):
    if _is_def(tree):
        return [(path, tree)]
    out = []
    for k in sorted(tree.keys()):
        out.extend(_flatten(tree[k], path + (k,)))
    return out


def _set(d, path, value):
    for p in path[:-1]:
        d = d.setdefault(p, {})
    d[path[-1]] = value
