"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Diagonal gated linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t*x_t)
with a_t = exp(-c * softplus(Lambda) * r_t), computed with
jax.lax.associative_scan for training/prefill and one-step update for
decode. Projections are quantization-aware Dense (the paper's GEMMs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.deploy.policy import PrecisionPlan, resolve_qcfg
from repro.nn.layers import QOFF, QuantConfig, dense_apply, dense_def
from repro.nn.module import ParamDef
from repro.parallel.ctx import constrain

_C = 8.0


@dataclasses.dataclass(frozen=True)
class RglruConfig:
    d_model: int
    lru_width: int
    d_conv: int = 4
    qcfg: QuantConfig = QOFF
    plan: "PrecisionPlan | None" = None
    path: str = "rec_layers/rec"

    def q(self, name: str) -> QuantConfig:
        return resolve_qcfg(self.plan, f"{self.path}/{name}", self.qcfg)


def rglru_block_def(cfg: RglruConfig, dtype=jnp.float32):
    d, w = cfg.d_model, cfg.lru_width
    return {
        "in_x": dense_def(d, w, ("embed", "mlp"), qcfg=cfg.q("in_x"),
                          dtype=dtype),
        "in_gate": dense_def(d, w, ("embed", "mlp"), qcfg=cfg.q("in_gate"),
                             dtype=dtype),
        "conv_w": ParamDef((cfg.d_conv, w), (None, "mlp"), "normal", dtype),
        "conv_b": ParamDef((w,), ("mlp",), "zeros", dtype),
        "w_a": dense_def(w, w, ("mlp", "mlp2"), bias=True, qcfg=cfg.q("w_a"),
                         dtype=dtype),
        "w_i": dense_def(w, w, ("mlp", "mlp2"), bias=True, qcfg=cfg.q("w_i"),
                         dtype=dtype),
        "lam": ParamDef((w,), ("mlp",), "scalar:0.5", jnp.float32),
        "out": dense_def(w, d, ("mlp", "embed"), qcfg=cfg.q("out"),
                         dtype=dtype),
    }


def _gates(p, x, cfg):
    r = jax.nn.sigmoid(dense_apply(p["w_a"], x, qcfg=cfg.q("w_a"))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["w_i"], x, qcfg=cfg.q("w_i"))
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"])[None, :] * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i


def _conv_causal(u, w, b):
    from repro.nn.ssm import _causal_conv_dw
    return _causal_conv_dw(u, w) + b[None, None, :]


def rglru_block_apply(p, xin, cfg: RglruConfig):
    """Full-sequence recurrent block. xin: (B,L,d)."""
    gate = constrain(
        jax.nn.gelu(dense_apply(p["in_gate"], xin, qcfg=cfg.q("in_gate"))),
        ("batch", None, "mlp"))
    x = constrain(dense_apply(p["in_x"], xin, qcfg=cfg.q("in_x")),
                  ("batch", None, "mlp"))
    x = _conv_causal(x, p["conv_w"].astype(xin.dtype),
                     p["conv_b"].astype(xin.dtype))
    a, bx_gate = _gates(p, x, cfg)
    bx = bx_gate * x.astype(jnp.float32)
    # h_t = a_t h_{t-1} + bx_t: associative scan with (a, b) composition
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(comb, (a, bx), axis=1)
    y = (h.astype(xin.dtype) * gate)
    return dense_apply(p["out"], y, qcfg=cfg.q("out"))


def rglru_init_cache(cfg: RglruConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


def rglru_block_decode(p, xin, cache, cfg: RglruConfig):
    """Single-token decode. xin: (B,1,d)."""
    gate = jax.nn.gelu(dense_apply(p["in_gate"], xin, qcfg=cfg.q("in_gate")))[:, 0]
    x = dense_apply(p["in_x"], xin, qcfg=cfg.q("in_x"))[:, 0]
    conv_buf = jnp.concatenate([cache["conv"], x[:, None, :]], axis=1)
    w = p["conv_w"].astype(xin.dtype)
    xc = jnp.einsum("bkc,kc->bc", conv_buf, w) + p["conv_b"].astype(xin.dtype)
    a, bx_gate = _gates(p, xc, cfg)
    h = a * cache["h"] + bx_gate * xc.astype(jnp.float32)
    y = (h.astype(xin.dtype) * gate)
    out = dense_apply(p["out"], y[:, None, :], qcfg=cfg.q("out"))
    return out, {"conv": conv_buf[:, 1:], "h": h}
