"""Mamba-2 SSD (state-space duality) block — chunked parallel training form
plus O(1)-state decode. Follows the minimal SSD reference of Dao & Gu
(arXiv:2405.21060) §6, ported to JAX einsums.

The paper's technique applies to the in/out projections (GEMM-shaped); the
scan itself keeps fp32 state (the paper's rule: accumulators stay
high-precision).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.deploy.policy import PrecisionPlan, resolve_qcfg
from repro.nn.layers import QOFF, QuantConfig, dense_apply, dense_def
from repro.nn.module import ParamDef
from repro.parallel.ctx import constrain


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 256
    qcfg: QuantConfig = QOFF
    plan: "PrecisionPlan | None" = None
    path: str = "layers/mixer"

    @property
    def d_inner(self):
        return self.expand * self.d_model

    def q(self, name: str) -> QuantConfig:
        return resolve_qcfg(self.plan, f"{self.path}/{name}", self.qcfg)

    @property
    def n_heads(self):
        return self.d_inner // self.headdim

    @property
    def conv_dim(self):
        return self.d_inner + 2 * self.d_state  # x + B + C channels


def mamba_def(cfg: MambaConfig, dtype=jnp.float32):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    d_in_proj = 2 * di + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": dense_def(cfg.d_model, d_in_proj, ("embed", "mlp"),
                             qcfg=cfg.q("in_proj"), dtype=dtype),
        "conv_w": ParamDef((cfg.d_conv, cfg.conv_dim), (None, "mlp"),
                           "normal", dtype),
        "conv_b": ParamDef((cfg.conv_dim,), ("mlp",), "zeros", dtype),
        "a_log": ParamDef((h,), (None,), "zeros", jnp.float32),
        "d_skip": ParamDef((h,), (None,), "ones", jnp.float32),
        "dt_bias": ParamDef((h,), (None,), "zeros", jnp.float32),
        "norm_scale": ParamDef((di,), ("mlp",), "ones", dtype),
        "out_proj": dense_def(di, cfg.d_model, ("mlp", "embed"),
                              qcfg=cfg.q("out_proj"), dtype=dtype),
    }


def _segsum(a):
    """(..., l) -> (..., l, l) lower-triangular cumulative segment sums."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, ss, -jnp.inf)


def _ssd_chunked(x, a, b, c, chunk):
    """SSD scan. x: (B,L,H,P) values; a: (B,L,H) log-decay (= dt*A, <=0);
    b, c: (B,L,H,N). Returns y (B,L,H,P) and final state (B,H,P,N)."""
    bs, l, h, p = x.shape
    n = b.shape[-1]
    nc = l // chunk
    xs = x.reshape(bs, nc, chunk, h, p)
    as_ = a.reshape(bs, nc, chunk, h).transpose(0, 3, 1, 2)  # (B,H,C,l)
    bs_ = b.reshape(bs, nc, chunk, h, n)
    cs_ = c.reshape(bs, nc, chunk, h, n)

    a_cum = jnp.cumsum(as_, axis=-1)                         # (B,H,C,l)

    # 1. intra-chunk (diagonal blocks)
    ll = jnp.exp(_segsum(as_)).astype(xs.dtype)              # (B,H,C,l,l)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        cs_, bs_, ll, xs,
                        preferred_element_type=jnp.float32)

    # 2. states at chunk ends
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum).astype(xs.dtype)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn",
                        bs_, decay_states, xs,
                        preferred_element_type=jnp.float32)

    # 3. inter-chunk recurrence
    chunk_decay = a_cum[..., -1]                             # (B,H,C)
    pad = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    dc = jnp.exp(_segsum(pad))                               # (B,H,C+1,C+1)
    dc = jnp.where(jnp.isfinite(dc), dc, 0.0)
    init = jnp.zeros((bs, 1) + states.shape[2:], states.dtype)
    all_states = jnp.concatenate([init, states], axis=1)     # (B,C+1,H,P,N)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dc[..., :], all_states)
    prev_states = new_states[:, :-1]                         # (B,C,H,P,N)
    final_state = new_states[:, -1]

    # 4. state -> output
    out_decay = jnp.exp(a_cum).astype(xs.dtype)              # (B,H,C,l)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       cs_, prev_states.astype(xs.dtype), out_decay,
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(bs, l, h, p)
    return y, final_state


def _causal_conv(u, w, b):
    """Depthwise causal conv. u: (B,L,C); w: (K,C).

    Uses lax.conv_general_dilated (depthwise, causal padding): the shift-
    and-add formulation materialized k=4 full-sequence copies per call —
    ~600 GB/device/step at mamba2 train_4k (see EXPERIMENTS.md §Perf)."""
    k = w.shape[0]
    c = u.shape[-1]
    rhs = w.T[:, None, :, None]          # (C, 1, K, 1) OIHW-ish
    y = jax.lax.conv_general_dilated(
        u[..., None],                    # (B, L, C, 1) -> NHWC with W=C?
        rhs, (1, 1), [(k - 1, 0), (0, 0)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=1) if False else _causal_conv_dw(u, w)
    return y + b[None, None, :]


def _causal_conv_dw(u, w):
    """(B,L,C) depthwise causal conv, conv dims: N=B, spatial=L, feature=C."""
    k = w.shape[0]
    c = u.shape[-1]
    rhs = w[:, None, :]                   # (K, 1, C): HIO with I=1 (dw)
    return jax.lax.conv_general_dilated(
        u, rhs, window_strides=(1,), padding=[(k - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=c)


def _split_proj(zxbcdt, cfg: MambaConfig):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim:]
    return z, xbc, dt


def mamba_apply(p, xin, cfg: MambaConfig):
    """Full-sequence forward. xin: (B,L,d_model)."""
    bs, l, _ = xin.shape
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
    zxbcdt = dense_apply(p["in_proj"], xin, qcfg=cfg.q("in_proj"))
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(xin.dtype),
                                   p["conv_b"].astype(xin.dtype)))
    x = constrain(xbc[..., :di].reshape(bs, l, h, pd),
                  ("batch", None, "heads", None))
    b = xbc[..., di:di + n]
    c = xbc[..., di + n:]
    b = jnp.broadcast_to(b[:, :, None, :], (bs, l, h, n))
    c = jnp.broadcast_to(c[:, :, None, :], (bs, l, h, n))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])       # (B,L,H)
    a = -jnp.exp(p["a_log"])[None, None, :] * dt              # log-decay
    # SSD einsum operands in the compute dtype (decay cumsums stay f32;
    # einsums accumulate f32 via preferred_element_type)
    xdt = (x.astype(jnp.float32) * dt[..., None]).astype(xin.dtype)
    # pad L to a chunk multiple; zero x-contributions keep outputs exact
    pad = (-l) % cfg.chunk
    if pad:
        padt = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (t.ndim - 2))
        xdt, a, b, c = padt(xdt), padt(a), padt(b), padt(c)
    y, _ = _ssd_chunked(xdt, a, b.astype(xin.dtype),
                        c.astype(xin.dtype), cfg.chunk)
    if pad:
        y = y[:, :l]
    y = y + x.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = constrain(y.reshape(bs, l, di).astype(xin.dtype),
                  ("batch", None, "mlp"))
    y = y * jax.nn.silu(z)
    y = _rms(y, p["norm_scale"])
    return dense_apply(p["out_proj"], y, qcfg=cfg.q("out_proj"))


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def mamba_init_cache(cfg: MambaConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state),
                         jnp.float32),
    }


def mamba_decode(p, xin, cache, cfg: MambaConfig):
    """Single-token decode. xin: (B,1,d_model). O(1) state update."""
    bs = xin.shape[0]
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
    zxbcdt = dense_apply(p["in_proj"], xin, qcfg=cfg.q("in_proj"))
    z, xbc, dt = _split_proj(zxbcdt[:, 0], cfg)
    conv_buf = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    w = p["conv_w"].astype(xin.dtype)
    xbc_c = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_buf, w) + p["conv_b"].astype(xin.dtype))
    new_conv = conv_buf[:, 1:]
    x = xbc_c[..., :di].reshape(bs, h, pd).astype(jnp.float32)
    b = xbc_c[..., di:di + n].astype(jnp.float32)
    c = xbc_c[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt)           # (B,H)
    ssm = cache["ssm"] * a[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", x, b, dt)
    y = jnp.einsum("bhpn,bn->bhp", ssm, c)
    y = y + x * p["d_skip"][None, :, None]
    y = y.reshape(bs, di).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    y = _rms(y, p["norm_scale"])
    out = dense_apply(p["out_proj"], y[:, None, :], qcfg=cfg.q("out_proj"))
    return out, {"conv": new_conv, "ssm": ssm}
