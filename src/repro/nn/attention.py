"""Grouped-query attention with causal/local/bidirectional masks, cross
attention, and an (optionally int8-quantized) KV cache for decode.

GQA is computed with an explicit group dim (no KV head replication is ever
materialized). All projections are quantization-aware Dense layers — the
paper's packed sub-byte GEMM applies to every projection here.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.deploy.policy import PrecisionPlan, resolve_qcfg
from repro.nn.layers import (QuantConfig, QOFF, dense_apply, dense_def,
                             rope_apply, rope_single)
from repro.parallel.ctx import active_mesh, constrain, constrain_first

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    qkv_bias: bool = False        # qwen2.5
    kv_quant_bits: int = 16       # 16 (bf16) | 8 (int8 cache)
    qcfg: QuantConfig = QOFF
    # mixed-precision deployment: per-projection override of qcfg resolved
    # by this block's param path + projection name (wq/wk/wv/wo)
    plan: Optional[PrecisionPlan] = None
    path: str = "layers/attn"

    @property
    def groups(self):
        return self.n_heads // self.kv_heads

    def q(self, name: str) -> QuantConfig:
        return resolve_qcfg(self.plan, f"{self.path}/{name}", self.qcfg)


def attn_def(cfg: AttnConfig, dtype=jnp.float32):
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    return {
        "wq": dense_def(d, h * dh, ("embed", "heads"), bias=cfg.qkv_bias,
                        qcfg=cfg.q("wq"), dtype=dtype),
        "wk": dense_def(d, hk * dh, ("embed", "kv_heads"), bias=cfg.qkv_bias,
                        qcfg=cfg.q("wk"), dtype=dtype),
        "wv": dense_def(d, hk * dh, ("embed", "kv_heads"), bias=cfg.qkv_bias,
                        qcfg=cfg.q("wv"), dtype=dtype),
        "wo": dense_def(h * dh, d, ("heads", "embed"), qcfg=cfg.q("wo"),
                        dtype=dtype),
    }


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _mask_full(q_len, k_len, mode, window, q_offset=0):
    """(q_len, k_len) bool allow-mask. mode: causal|local|bidir.
    `window` may be a traced scalar (per-layer scanned value)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(k_len)[None, :]
    if mode == "bidir":
        return jnp.ones((q_len, k_len), bool)
    allow = k_pos <= q_pos
    if mode == "local":
        allow = allow & (q_pos - k_pos < window)
    return allow


def attn_strategy(hk: int, groups: int, s_len: int, t_len: int,
                  batch=None) -> str:
    """One coherent sharding strategy per attention call (mixing per-tensor
    first-fit choices forces SPMD reshard copies of score-sized tensors):

    'tp'  — kv_heads divide the model axis: classic TP (Megatron).
    'gp'  — q-head groups divide: shard the GQA group dim (q-only TP).
    'cp'  — context parallel: shard q-seq (train/prefill) / kv-seq (decode),
            GSPMD emits partial-softmax psums (flash-decode style).
    """
    mesh = active_mesh()
    if mesh is None:
        return "none"
    m = mesh.shape.get("model", 1)
    if hk % m == 0:
        return "tp"
    # NOTE: a batch-parallel variant (batch over data x model for the
    # attention region) was tried for the few-kv-head case and REFUTED:
    # per-layer residual resharding across the model axis cost more than
    # the CP score handling it replaced (kimi train_4k: collective term
    # 56.9s -> 125.3s, compute 10.9s -> 46.8s; EXPERIMENTS.md §Perf).
    if (s_len > 1 and s_len % m == 0) or (s_len == 1 and t_len % m == 0):
        return "cp"
    if groups % m == 0:
        return "gp"
    return "none"


_SCORE_AXES = {  # (B, Hk, G, S, T)
    "tp": ("batch", "kv_heads", None, None, None),
    "gp": ("batch", None, "heads", None, None),
    "bp": ("batch_full", None, None, None, None),
}


def _sdpa(q, k, v, mask, strategy="none"):
    """q: (B,S,Hk,G,Dh), k/v: (B,T,Hk,Dh), mask broadcastable to
    (B,Hk,G,S,T). float32 softmax."""
    dh = q.shape[-1]
    s_len = q.shape[1]
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k,
                        preferred_element_type=jnp.float32)
    if strategy in _SCORE_AXES:
        scores = constrain(scores, _SCORE_AXES[strategy])
    elif strategy == "cp":
        scores = constrain(scores, ("batch", None, None, "seq_model", None)
                           if s_len > 1 else
                           ("batch", None, None, None, "kv_seq"))
    scores = scores * (dh ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return out


def _kv_store(x, bits):
    if bits == 8:
        scale = 8.0 / 127.0  # static symmetric grid for normalized k/v
        return jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                        -127, 127).astype(jnp.int8)
    return x


def _kv_load(x, bits, dtype):
    if bits == 8:
        return (x.astype(jnp.float32) * (8.0 / 127.0)).astype(dtype)
    return x


def attn_apply(p, x, cfg: AttnConfig, *, cos, sin, mode="causal",
               window=None, cross_kv=None):
    """Full-sequence attention (training / prefill).

    cross_kv: (k_src, v_src) pre-projected encoder K/V for cross-attention
    (mode must be 'bidir'; RoPE skipped).
    Returns (out, (k, v)) so callers can build decode caches from prefill.
    """
    b, s, _ = x.shape
    h, hk, dh, g = cfg.n_heads, cfg.kv_heads, cfg.head_dim, cfg.groups
    q = _split_heads(dense_apply(p["wq"], x, qcfg=cfg.q("wq")), h, dh)
    t_len = x.shape[1] if cross_kv is None else cross_kv[0].shape[1]
    strat = attn_strategy(hk, g, s, t_len, batch=b)
    if cross_kv is None:
        k = _split_heads(dense_apply(p["wk"], x, qcfg=cfg.q("wk")), hk, dh)
        v = _split_heads(dense_apply(p["wv"], x, qcfg=cfg.q("wv")), hk, dh)
        kv_axes = {"tp": ("batch", None, "kv_heads", None),
                   "gp": ("batch", None, None, None),
                   "bp": ("batch_full", None, None, None),
                   "cp": ("batch", None, None, None)}.get(strat)
        if kv_axes:
            k = constrain(k, kv_axes)
            v = constrain(v, kv_axes)
        q = rope_apply(q, cos, sin)
        k = rope_apply(k, cos, sin)
    else:
        k, v = cross_kv
    q = q.reshape(b, s, hk, g, dh)
    q_axes = {"tp": ("batch", None, "kv_heads", None, None),
              "gp": ("batch", None, None, "heads", None),
              "bp": ("batch_full", None, None, None, None),
              "cp": ("batch", "seq_model", None, None, None)}.get(strat)
    if q_axes:
        q = constrain(q, q_axes)
    t = k.shape[1]
    mask = _mask_full(s, t, mode, window)[None, None, None]
    out = _sdpa(q, k, v, mask, strat)
    out = out.reshape(b, s, h * dh)
    y = dense_apply(p["wo"], out, qcfg=cfg.q("wo"))
    return constrain(y, ("batch", None, None)), (k, v)


def cross_kv_project(p, enc_out, cfg: AttnConfig):
    """Project encoder states once; reused across decode steps."""
    hk, dh = cfg.kv_heads, cfg.head_dim
    k = _split_heads(dense_apply(p["wk"], enc_out, qcfg=cfg.q("wk")), hk, dh)
    v = _split_heads(dense_apply(p["wv"], enc_out, qcfg=cfg.q("wv")), hk, dh)
    return k, v


def init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (batch, max_len, cfg.kv_heads, cfg.head_dim)
    store_t = jnp.int8 if cfg.kv_quant_bits == 8 else dtype
    return {"k": jnp.zeros(shape, store_t), "v": jnp.zeros(shape, store_t)}


def attn_decode(p, x, cache, index, cfg: AttnConfig, *, theta=10000.0,
                mode="causal", window=None, cross_kv=None,
                ring: bool = False):
    """One-token decode. x: (B,1,d); index: the TRUE position — a scalar
    int32 (wave decode: every row at the same step) or a (B,) int32
    vector (continuous batching: each slot at its own position);
    cache: dict(k,v) of (B,T,Hk,Dh). Returns (out, new_cache).

    The per-slot (vector) form runs the same per-element math as the
    scalar form — RoPE phases, cache writes, and masks are all computed
    row-wise — so an all-equal position vector is bit-exact vs the
    scalar path (the serve runtime's parity invariant).

    ring=True treats the cache as a ring buffer of T=window slots (local
    attention): slot = index % T, each slot j holds true position
    index - ((index - j) mod T); RoPE always uses true positions so the
    relative phases stay exact across wraps.
    """
    b = x.shape[0]
    h, hk, dh, g = cfg.n_heads, cfg.kv_heads, cfg.head_dim, cfg.groups
    index = jnp.asarray(index)
    per_slot = index.ndim == 1            # (B,) per-slot positions
    q = _split_heads(dense_apply(p["wq"], x, qcfg=cfg.q("wq")), h, dh)
    if cross_kv is None:
        k_new = _split_heads(dense_apply(p["wk"], x, qcfg=cfg.q("wk")), hk, dh)
        v_new = _split_heads(dense_apply(p["wv"], x, qcfg=cfg.q("wv")), hk, dh)
        q = rope_single(q, index, theta)
        k_new = rope_single(k_new, index, theta)
        kq = _kv_store(k_new, cfg.kv_quant_bits)
        vq = _kv_store(v_new, cfg.kv_quant_bits)
        t = cache["k"].shape[1]
        slot = (index % t) if ring else index
        if per_slot:
            # one write position per row; values are unchanged, only the
            # write address is batched, so bit-exactness is preserved
            upd = jax.vmap(lambda c, u, s:
                           jax.lax.dynamic_update_slice_in_dim(c, u, s,
                                                               axis=0))
            cache = {"k": upd(cache["k"], kq, slot),
                     "v": upd(cache["v"], vq, slot)}
        else:
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq,
                                                         slot, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq,
                                                         slot, axis=1),
            }
        k = _kv_load(cache["k"], cfg.kv_quant_bits, x.dtype)
        v = _kv_load(cache["v"], cfg.kv_quant_bits, x.dtype)
        k_pos = jnp.arange(t)[None, :]
        idx = index[:, None] if per_slot else index  # (B,1) | scalar
        if ring:
            true_pos = idx - ((idx - k_pos) % t)
            allow = true_pos >= 0
            if window is not None:
                allow = allow & (idx - true_pos < window)
        else:
            allow = k_pos <= idx
            if mode == "local":
                allow = allow & (idx - k_pos < window)
    else:
        k, v = cross_kv
        t = k.shape[1]
        allow = jnp.ones((1, t), bool)
    q = q.reshape(b, 1, hk, g, dh)
    strat = attn_strategy(hk, g, 1, t)
    mask = allow[:, None, None, None, :]  # (B,1,1,1,T) / (1,...)
    out = _sdpa(q, k, v, mask, strat)
    out = out.reshape(b, 1, h * dh)
    return dense_apply(p["wo"], out, qcfg=cfg.q("wo")), cache
