"""Core layers: quantization-aware Dense, embeddings, norms, RoPE.

Dense is where the paper's technique plugs into every architecture: a
`QuantConfig` selects fp / fake-quant (QAT) / integer deployment mode, the
latter holding chunk-planar *packed* sub-byte weights in HBM and running the
int8 MXU GEMM with a dequant epilogue (W{8,4,2}A8 serving) — the XpulpNN
pipeline adapted to TPU (see DESIGN.md §2).
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quantize import QuantSpec, fake_quantize
from repro.nn.module import ParamDef


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "off"        # off | fake | int
    w_bits: int = 8
    a_bits: int = 8
    # static activation scale (absmax) used in int mode; per-tensor dynamic
    # quantization when None (max computed on the fly; costs a reduction)
    a_absmax: Optional[float] = 4.0
    # named kernel backend for the quantized-op registry
    # (repro.kernels.api: pallas | pallas_interpret | xla | eager_ref);
    # None -> capability-ordered default resolution. Honored by the op
    # entry points (api.qdot / api.qconv); dense_apply's int path runs the
    # shared `xla` implementation (the production lowering) — the field is
    # carried through deployment plans for call sites that route kernels.
    backend: Optional[str] = None
    # kernel software-pipeline mode ('off' | 'double_buffer', the Mac&Load
    # knob — repro.kernels.common.PIPELINE_MODES); None -> runtime
    # resolution (REPRO_QPIPELINE env -> tune-cache winner -> 'off').
    # Like `backend`, honored by call sites routing through the op
    # registry and carried through deployment plans (PlanRule.pipeline).
    pipeline: Optional[str] = None
    # Fine-grain mixed precision (plan schema v4): ordered
    # (n_start, n_end, w_bits) runs over the output-feature axis — one
    # dense layer serves different channel groups at different widths
    # (Nadalini et al. 2307.01056). None -> uniform w_bits. Normalized to
    # a tuple-of-int-tuples (hashable) and validated through
    # `packing.SegmentMap` in __post_init__.
    segments: Optional[tuple] = None
    # DEPRECATION SHIM: pre-registry boolean. Normalized to None in
    # __post_init__ after mapping True -> 'pallas_interpret' (the old
    # default silently ran interpret mode), False -> 'xla'.
    use_kernel: Optional[bool] = None

    def __post_init__(self):
        if self.pipeline is not None:
            from repro.kernels.common import check_pipeline
            check_pipeline(self.pipeline)
        if self.segments is not None:
            sm = packing.SegmentMap(tuple(tuple(r) for r in self.segments))
            object.__setattr__(self, "segments", sm.runs)
        if self.use_kernel is not None:
            if self.backend is not None:
                raise ValueError(
                    "pass either backend= or the deprecated use_kernel=, "
                    "not both")
            warnings.warn(
                "QuantConfig(use_kernel=...) is deprecated; pass "
                "backend='pallas'|'pallas_interpret'|'xla'|'eager_ref' "
                "(see repro.kernels.api)", DeprecationWarning, stacklevel=3)
            object.__setattr__(
                self, "backend",
                "pallas_interpret" if self.use_kernel else "xla")
            object.__setattr__(self, "use_kernel", None)

    @property
    def enabled(self):
        return self.mode != "off"


QOFF = QuantConfig()


# Calibration tap: when set, dense_apply calls it with (params, x) before
# the matmul. The deploy calibrator uses this to record per-dense activation
# absmax and bit-width sensitivity during an *eager* replay — callbacks get
# concrete arrays only when no jit/scan tracing is active, so taps are for
# host-side calibration passes, never inside compiled training/serving.
_DENSE_TAP: Optional[Callable] = None


@contextlib.contextmanager
def dense_tap(fn: Callable):
    """Install ``fn(params_dict, x)`` as the dense-apply observer."""
    global _DENSE_TAP
    prev = _DENSE_TAP
    _DENSE_TAP = fn
    try:
        yield
    finally:
        _DENSE_TAP = prev


# ---------------------------------------------------------------- dense ---

def dense_def(d_in: int, d_out: int, axes=("embed", "mlp"), *,
              bias: bool = False, qcfg: QuantConfig = QOFF,
              dtype=jnp.float32, scale: float = 1.0):
    if qcfg.mode == "int" and qcfg.segments is not None:
        segmap = packing.SegmentMap(qcfg.segments)
        if segmap.n != d_out:
            raise ValueError(
                f"segment map covers N={segmap.n} but d_out={d_out}")
        # flat segmented container (panel-major, exact bytes); the sharding
        # axis collapses away — segmented denses are not TP-sharded today
        p = {"w_packed": ParamDef((segmap.packed_bytes(d_in),), (None,),
                                  "zeros", jnp.int8),
             "w_scale": ParamDef((d_out,), (axes[1],), "ones", jnp.float32)}
    elif qcfg.mode == "int":
        kp = packing.padded_size(d_in) // packing.pack_factor(qcfg.w_bits)
        p = {"w_packed": ParamDef((kp, d_out), (axes[0], axes[1]),
                                  "zeros", jnp.int8),
             "w_scale": ParamDef((d_out,), (axes[1],), "ones", jnp.float32)}
    else:
        p = {"w": ParamDef((d_in, d_out), axes, "normal", dtype, scale)}
    if bias:
        p["b"] = ParamDef((d_out,), (axes[1],), "zeros", dtype)
    return p


def dense_apply(p, x, *, qcfg: QuantConfig = QOFF, precision=None):
    """x: (..., d_in) bf16/f32 -> (..., d_out)."""
    if _DENSE_TAP is not None:
        _DENSE_TAP(p, x)
    if qcfg.mode == "int":
        y = _int_matmul(p, x, qcfg)
    elif qcfg.mode == "fake":
        w = p["w"]
        sw = QuantSpec.weight(qcfg.w_bits, 3.0 / (w.shape[0] ** 0.5))
        sa = QuantSpec(qcfg.a_bits, True, -qcfg.a_absmax, qcfg.a_absmax)
        y = jnp.matmul(fake_quantize(x, sa).astype(x.dtype),
                       fake_quantize(w, sw).astype(x.dtype))
    else:
        y = jnp.matmul(x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def _int_matmul(p, x, qcfg: QuantConfig):
    """W{8,4,2}A{8,4,2} integer GEMM with dequant epilogue.

    Activations are symmetrically quantized onto the a_bits grid (int8
    containers, so A8 caps at ±127) with a static scale; the GEMM +
    per-channel dequant epilogue is the shared `xla` implementation of the
    quantized-op registry (`repro.kernels.api.xla_int_gemm`) — the same
    code path the `xla` qdot backend runs, so dense serving and the packed
    kernel wrappers no longer maintain divergent copies. HBM traffic for
    weights is 1/pf of the bf16 baseline — the paper's sub-byte gain
    mapped to the TPU memory roofline term.
    """
    from repro.kernels.api import xla_int_gemm

    absmax = qcfg.a_absmax or 4.0
    a_max = packing.int_range(qcfg.a_bits, True)[1]  # A8 caps at 127 (int8)
    a_scale = absmax / a_max
    k_logical = x.shape[-1]
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / a_scale), -a_max, a_max
                   ).astype(jnp.int8)
    x_q = packing.pad_to_chunk(x_q, axis=-1)
    if qcfg.segments is not None:
        # fine-grain mixed precision: each N-run is a uniform container
        # view of the flat segmented buffer — a static Python loop over
        # runs, so the path stays jit/scan-safe (segment maps are config,
        # not data)
        segmap = packing.SegmentMap(qcfg.segments)
        outs = []
        for i, (s, e, b) in enumerate(segmap.runs):
            wp = packing.segment_packed(p["w_packed"], segmap, i, k_logical)
            sc = (p["w_scale"][s:e] * a_scale).astype(jnp.float32)
            outs.append(xla_int_gemm(x_q, wp, w_bits=b, epilogue="dequant",
                                     scale=sc, out_dtype=x.dtype))
        return jnp.concatenate(outs, axis=-1)
    scale = (p["w_scale"] * a_scale).astype(jnp.float32)
    return xla_int_gemm(x_q, p["w_packed"], w_bits=qcfg.w_bits,
                        epilogue="dequant", scale=scale, out_dtype=x.dtype)


def quantize_dense_weights(w, w_bits: int):
    """fp weights (..., K, N) -> (w_hat int8 in-range, w_scale (..., N))
    on per-output-channel symmetric grids. Leading dims (a stacked layer
    axis) broadcast — no vmap needed, so host paths can range-check the
    whole stack before packing."""
    red = w.ndim - 2  # K axis
    absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=red), 1e-8)
    int_max = packing.int_range(w_bits, True)[1]
    w_scale = absmax / int_max
    w_hat = jnp.clip(jnp.round(w / jnp.expand_dims(w_scale, red)),
                     -int_max, int_max).astype(jnp.int8)
    return w_hat, w_scale


def pack_dense_weights(w, w_bits: int, *, assert_range: bool = False):
    """fp weights (K,N) or stacked (L,K,N) -> (w_packed, w_scale) for
    int-mode params. ``assert_range`` enables the host-side truncation
    guard (eager only)."""
    w_hat, w_scale = quantize_dense_weights(w, w_bits)
    red = w.ndim - 2
    w_hat = packing.pad_to_chunk(w_hat, axis=red)
    return packing.pack(w_hat, w_bits, axis=red,
                        assert_range=assert_range), w_scale


def pack_dense_weights_segmented(w, segments, *, assert_range: bool = False):
    """fp weights (K,N) or stacked (L,K,N) -> (w_flat, w_scale) at
    per-run widths: each output-channel run quantizes on its own
    per-channel symmetric grid at its own w_bits, then the runs pack into
    one flat segmented container (`packing.pack_segmented`). w_scale
    spans the full N regardless of widths."""
    segmap = (segments if isinstance(segments, packing.SegmentMap)
              else packing.SegmentMap(tuple(tuple(r) for r in segments)))
    if w.shape[-1] != segmap.n:
        raise ValueError(
            f"segment map covers N={segmap.n} but weights have "
            f"d_out={w.shape[-1]}")
    hats, scales = [], []
    for s, e, b in segmap.runs:
        h, sc = quantize_dense_weights(w[..., s:e], b)
        hats.append(h)
        scales.append(sc)
    w_hat = jnp.concatenate(hats, axis=-1)
    w_scale = jnp.concatenate(scales, axis=-1)
    return packing.pack_segmented(w_hat, segmap,
                                  assert_range=assert_range), w_scale


# ------------------------------------------------------------ embedding ---

VOCAB_PAD = 256  # pad vocab so logits/vocab-sharded ops divide the mesh
# (odd vocabs — mamba2 50280, seamless 256206 — otherwise replicate the
# (tokens x vocab) logits per device: +52 GB/dev f32 at mamba2 train_4k)


def padded_vocab(vocab: int) -> int:
    return vocab + (-vocab) % VOCAB_PAD


def embedding_def(vocab: int, d: int, dtype=jnp.float32):
    return {"table": ParamDef((padded_vocab(vocab), d), ("vocab", "embed"),
                              "embed", dtype, scale=1.0)}


def embedding_apply(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def embedding_logits(p, x, vocab: int = 0):
    """Tied output head: (..., d) @ (vocab_pad, d)^T. Padded rows are
    masked to -inf so the softmax ignores them."""
    lg = jnp.matmul(x, p["table"].astype(x.dtype).T)
    vp = p["table"].shape[0]
    if vocab and vp != vocab:
        mask = (jnp.arange(vp) < vocab)
        lg = jnp.where(mask, lg, jnp.asarray(-1e9, lg.dtype))
    return lg


# ---------------------------------------------------------------- norms ---

def norm_def(d: int, kind: str = "rmsnorm", dtype=jnp.float32):
    if kind == "nonparam_ln":   # OLMo: non-parametric LayerNorm
        return {}
    if kind == "layernorm":
        return {"scale": ParamDef((d,), ("embed",), "ones", dtype),
                "bias": ParamDef((d,), ("embed",), "zeros", dtype)}
    # rmsnorm / gemma_rmsnorm ((1+scale) form)
    return {"scale": ParamDef((d,), ("embed",),
                              "zeros" if kind == "gemma_rmsnorm" else "ones",
                              dtype)}


def norm_apply(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind in ("layernorm", "nonparam_ln"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(
                jnp.float32)
        return y.astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    scale = p["scale"].astype(jnp.float32)
    if kind == "gemma_rmsnorm":
        scale = 1.0 + scale
    return (y * scale).astype(x.dtype)


# ----------------------------------------------------------------- rope ---

def rope_tables(seq_len: int, head_dim: int, theta: float = 10000.0,
                dtype=jnp.float32):
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)            # (S, half)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def rope_apply(x, cos, sin):
    """x: (..., S, H, Dh); tables (S, Dh/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, None, :]
    s = sin[:, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def rope_apply_at(x, cos, sin, positions):
    """Decode-time RoPE: positions (B,) int32 index the tables."""
    c = jnp.take(cos, positions, axis=0)[:, None, None, :]  # (B,1,1,half)
    s = jnp.take(sin, positions, axis=0)[:, None, None, :]
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def rope_single(x, position, theta):
    """Table-free decode RoPE: x (B,1,H,Dh); position a scalar (wave
    decode: every row at the same step) or a (B,) vector (continuous
    batching: each slot at its own true position). The per-element math
    is identical in both forms, so an all-equal vector is bit-exact vs
    the scalar path.

    `theta` may be a traced scalar (per-layer dual-theta schedules). Avoids
    materializing (max_len, Dh/2) tables in decode — at 512k context the
    tables alone would cost hundreds of MB.
    """
    half = x.shape[-1] // 2
    theta = jnp.asarray(theta, jnp.float32)
    freqs = jnp.power(theta, -jnp.arange(0, half, dtype=jnp.float32) / half)
    position = jnp.asarray(position)
    if position.ndim == 0:
        ang = position.astype(jnp.float32) * freqs          # (half,)
        c = jnp.cos(ang).astype(x.dtype)[None, None, None, :]
        s = jnp.sin(ang).astype(x.dtype)[None, None, None, :]
    else:
        ang = position.astype(jnp.float32)[:, None] * freqs  # (B, half)
        c = jnp.cos(ang).astype(x.dtype)[:, None, None, :]
        s = jnp.sin(ang).astype(x.dtype)[:, None, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
