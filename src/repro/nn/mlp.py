"""FFN (SwiGLU/GeGLU/GELU) and Mixture-of-Experts with expert parallelism.

The MoE dispatch uses group-limited one-hot einsum dispatch (GShard-style
with capacity factor), sized so the dispatch tensors stay modest; experts
are sharded over the `model` mesh axis (EP). Sub-byte expert weights are the
single biggest win of the paper's technique at LM scale: expert streaming is
memory-bound, so packed int4/int2 experts cut the dominant roofline term by
2-4x (see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.deploy.policy import PrecisionPlan, resolve_qcfg
from repro.nn.layers import QOFF, QuantConfig, dense_apply, dense_def
from repro.nn.module import ParamDef
from repro.parallel.ctx import constrain


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_model: int
    d_ff: int
    act: str = "swiglu"          # swiglu | geglu | gelu
    qcfg: QuantConfig = QOFF
    # mixed-precision deployment: per-dense override of qcfg, resolved by
    # this block's param path (e.g. "layers/mlp") + the dense name
    plan: Optional[PrecisionPlan] = None
    path: str = "layers/mlp"

    def q(self, name: str) -> QuantConfig:
        return resolve_qcfg(self.plan, f"{self.path}/{name}", self.qcfg)


def mlp_def(cfg: MlpConfig, dtype=jnp.float32):
    gated = cfg.act in ("swiglu", "geglu")
    p = {"wi": dense_def(cfg.d_model, cfg.d_ff, ("embed", "mlp"),
                         qcfg=cfg.q("wi"), dtype=dtype),
         "wo": dense_def(cfg.d_ff, cfg.d_model, ("mlp", "embed"),
                         qcfg=cfg.q("wo"), dtype=dtype)}
    if gated:
        p["wg"] = dense_def(cfg.d_model, cfg.d_ff, ("embed", "mlp"),
                            qcfg=cfg.q("wg"), dtype=dtype)
    return p


def _act(h, g, kind):
    if kind == "swiglu":
        return jax.nn.silu(g) * h
    if kind == "geglu":
        return jax.nn.gelu(g) * h
    return jax.nn.gelu(h)


def mlp_apply(p, x, cfg: MlpConfig):
    h = constrain(dense_apply(p["wi"], x, qcfg=cfg.q("wi")),
                  ("batch", None, "mlp"))
    g = dense_apply(p["wg"], x, qcfg=cfg.q("wg")) if "wg" in p else None
    if g is not None:
        g = constrain(g, ("batch", None, "mlp"))
    y = dense_apply(p["wo"], _act(h, g, cfg.act), qcfg=cfg.q("wo"))
    return constrain(y, ("batch", None, None))


# ------------------------------------------------------------------ MoE ---

@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 1024    # tokens per dispatch group
    shared_expert: bool = True
    act: str = "swiglu"
    qcfg: QuantConfig = QOFF
    plan: Optional[PrecisionPlan] = None
    path: str = "layers/moe"

    def capacity(self, tokens_per_group: int) -> int:
        c = int(tokens_per_group * self.top_k * self.capacity_factor
                / self.n_experts) + 1
        return max(c, 4)


def moe_def(cfg: MoeConfig, dtype=jnp.float32):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": ParamDef((d, e), ("embed", "experts"), "normal", dtype,
                           scale=0.02),
        "wi": ParamDef((e, d, f), ("experts", "embed", "expert_mlp"),
                       "normal", dtype),
        "wg": ParamDef((e, d, f), ("experts", "embed", "expert_mlp"),
                       "normal", dtype),
        "wo": ParamDef((e, f, d), ("experts", "expert_mlp", "embed"),
                       "normal", dtype),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_def(
            MlpConfig(d, f, cfg.act, cfg.qcfg, cfg.plan,
                      f"{cfg.path}/shared"), dtype)
    return p


def moe_apply(p, x, cfg: MoeConfig):
    """x: (B, S, d). Group-limited scatter/gather dispatch with capacity
    dropping.

    The classic GShard one-hot dispatch materializes a (g, t, E, C) tensor
    = g*k*cf elements PER TOKEN — at 384-expert/top-8 scale that is ~1.4
    TB/device (observed). Instead the routing is materialized as an integer
    slot map (g, E, C) built with a scatter, token vectors are *gathered*
    into expert slots, and the combine is top_k gathers from expert
    outputs. No tensor larger than (g, E, C, d) ever exists.

    Returns (y, aux_loss). Router in float32; Switch load-balancing loss.
    """
    b, s, d = x.shape
    gs = min(cfg.group_size, b * s)
    tokens = x.reshape(-1, d)
    n_tok = tokens.shape[0]
    pad = (-n_tok) % gs
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    ng = tokens.shape[0] // gs
    # NOTE (refuted optimization, EXPERIMENTS.md §Perf): sharding groups
    # over data x model to turn the dispatch-gather backward into a
    # reduce-scatter made things dramatically worse (collective term
    # 56.9s -> 1085s at kimi train_4k) — GSPMD cannot partition a gather
    # whose indices live on a different axis layout and falls back to
    # replication. Tokens stay data-sharded / model-replicated.
    tokens = constrain(tokens.reshape(ng, gs, d), ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)  # (g,t,k)

    cap = cfg.capacity(gs)
    e = cfg.n_experts
    # position-in-expert via cumsum over the flattened (t,k) choice order
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (g,t,k,e)
    flat = onehot.reshape(ng, gs * cfg.top_k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                        # (g,t*k,e)
    pos = (pos * flat).sum(-1).reshape(ng, gs, cfg.top_k)     # (g,t,k)
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # ---- dispatch: scatter token ids into (g, E, C) slots, gather rows
    g_ar = jnp.arange(ng)[:, None, None]
    t_ar = jnp.broadcast_to(jnp.arange(gs)[None, :, None],
                            (ng, gs, cfg.top_k))
    pos_c = jnp.where(keep, pos, cap)  # cap == out-of-bounds -> dropped
    slot_tok = jnp.full((ng, e, cap), gs, jnp.int32)  # gs == padding row id
    slot_tok = slot_tok.at[
        jnp.broadcast_to(g_ar, (ng, gs, cfg.top_k)),
        expert_idx, pos_c].set(t_ar, mode="drop")
    tokens_pad = jnp.concatenate(
        [tokens, jnp.zeros((ng, 1, d), tokens.dtype)], axis=1)
    expert_in = jax.vmap(lambda tt, st: tt[st])(tokens_pad, slot_tok)
    expert_in = constrain(expert_in, ("batch", "experts", None, None))

    h = jnp.einsum("gecd,edf->gecf", expert_in, p["wi"].astype(x.dtype))
    g_ = jnp.einsum("gecd,edf->gecf", expert_in, p["wg"].astype(x.dtype))
    hidden = constrain(_act(h, g_, cfg.act),
                       ("batch", "experts", None, None))
    expert_out = constrain(
        jnp.einsum("gecf,efd->gecd", hidden, p["wo"].astype(x.dtype)),
        ("batch", "experts", None, None))

    # ---- combine: top_k gathers of (g, t, d) — never (g,t,E,C)
    flat_eo = expert_out.reshape(ng, e * cap, d)
    y = jnp.zeros((ng, gs, d), x.dtype)
    for kk in range(cfg.top_k):
        idx = expert_idx[:, :, kk] * cap + pos_c[:, :, kk]    # (g,t)
        idx = jnp.minimum(idx, e * cap - 1)
        gathered = jax.vmap(lambda eo, ix: eo[ix])(flat_eo, idx)
        w = (gate_vals[:, :, kk] * keep[:, :, kk]).astype(x.dtype)
        y = y + gathered * w[..., None]
    y = constrain(y, ("batch", None, None))
    y = y.reshape(-1, d)[:n_tok].reshape(b, s, d)

    if cfg.shared_expert:
        y = y + mlp_apply(p["shared"], x,
                          MlpConfig(cfg.d_model, cfg.d_ff, cfg.act, cfg.qcfg,
                                    cfg.plan, f"{cfg.path}/shared"))

    # Switch aux loss: e * sum_e(frac_tokens_e * frac_probs_e)
    frac_tok = jnp.mean(onehot[:, :, 0].astype(jnp.float32), axis=1)  # (g,e)
    frac_prob = jnp.mean(probs, axis=1)
    aux = e * jnp.mean(jnp.sum(frac_tok * frac_prob, axis=-1))
    return y, aux
