"""Model registry: one uniform API over all families.

Model exposes: init / specs / loss / forward / prefill / decode / init_cache
/ input_specs. The dry-run, trainer, server, and benchmarks only talk to
this API.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, griffin, lm, mamba
from repro.nn.module import init_params, logical_specs

_FAMILIES = {
    "lm": (lm.lm_def, lm.forward, lm.decode_step, lm.lm_init_cache),
    "encdec": (encdec.encdec_def, encdec.forward, encdec.decode_step,
               encdec.encdec_init_cache),
    "mamba": (mamba.mamba_lm_def, mamba.forward, mamba.decode_step,
              mamba.mamba_lm_init_cache),
    "griffin": (griffin.griffin_def, griffin.forward, griffin.decode_step,
                griffin.griffin_init_cache),
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def _fns(self):
        return _FAMILIES[self.cfg.family]

    # ---- params ----
    def defs(self, dtype=jnp.float32):
        pd = jnp.float32 if self.cfg.param_dtype == "float32" else jnp.bfloat16
        return self._fns[0](self.cfg, pd)

    def init(self, key):
        return init_params(self.defs(), key)

    def specs(self):
        return logical_specs(self.defs())

    # ---- training ----
    def loss(self, params, batch, aux_weight: float = 0.01):
        logits, aux, _ = self._fns[1](
            params, batch["tokens"], self.cfg,
            src_embed=batch.get("src_embed"))
        logits = logits.astype(jnp.float32)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        # z-loss keeps logits bounded (stability at scale)
        zl = 1e-4 * jnp.square(jax.nn.logsumexp(logits, axis=-1))
        return jnp.mean(nll) + jnp.mean(zl) + aux_weight * aux

    def forward(self, params, batch):
        return self._fns[1](params, batch["tokens"], self.cfg,
                            src_embed=batch.get("src_embed"))

    # ---- serving ----
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return self._fns[3](self.cfg, batch, max_len, dtype)

    def prefill(self, params, batch):
        """Full forward over the prompt; returns last-position logits.
        (Cache population from prefill KV is handled in serve/engine.py.)"""
        logits, _, kvs = self._fns[1](
            params, batch["tokens"], self.cfg,
            src_embed=batch.get("src_embed"), collect_kv=True)
        return logits[:, -1:], kvs

    def decode(self, params, cache, token, index, src_embed=None):
        return self._fns[2](params, cache, token, index, self.cfg,
                            src_embed=src_embed)

    # ---- shapes for dry-run / launchers ----
    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32, bf16 = jnp.int32, jnp.bfloat16
        d = cfg.d_model
        if shape.kind == "train":
            spec = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
            if _needs_src(cfg):
                spec["src_embed"] = jax.ShapeDtypeStruct((b, s, d), bf16)
            return spec
        if shape.kind == "prefill":
            if cfg.family == "encdec":
                # long input lives on the encoder side; short decoder draft
                return {"tokens": jax.ShapeDtypeStruct((b, 256), i32),
                        "src_embed": jax.ShapeDtypeStruct((b, s, d), bf16)}
            spec = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if _needs_src(cfg):
                spec["src_embed"] = jax.ShapeDtypeStruct(
                    (b, cfg.src_len, d), bf16)
            return spec
        # decode: one new token against a seq_len-deep cache
        cache = jax.eval_shape(functools.partial(self.init_cache, b, s))
        spec = {"token": jax.ShapeDtypeStruct((b, 1), i32),
                "index": jax.ShapeDtypeStruct((), i32),
                "cache": cache}
        return spec


def _needs_src(cfg: ModelConfig) -> bool:
    return cfg.family == "encdec" or cfg.cross_every > 0


_REGISTRY: dict = {}
_LOADED = False  # `not _REGISTRY` is the wrong guard: importing any single
# config module registers it and would mask the rest forever


def register(cfg: ModelConfig):
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _LOADED:
        _load_all()
    return _REGISTRY[name]


def list_archs():
    if not _LOADED:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    global _LOADED
    import importlib
    import pkgutil

    import repro.configs as cpkg
    for mod in pkgutil.iter_modules(cpkg.__path__):
        if mod.name not in ("base",):
            importlib.import_module(f"repro.configs.{mod.name}")
    _LOADED = True


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for the arch with this registry name."""
    import importlib
    import pkgutil

    import repro.configs as cpkg
    for mod in pkgutil.iter_modules(cpkg.__path__):
        if mod.name == "base":
            continue
        m = importlib.import_module(f"repro.configs.{mod.name}")
        if getattr(m, "CONFIG", None) is not None and m.CONFIG.name == name:
            return m.smoke_config()
    raise KeyError(name)


def build(name_or_cfg) -> Model:
    cfg = (name_or_cfg if isinstance(name_or_cfg, ModelConfig)
           else get_config(name_or_cfg))
    return Model(cfg)
