"""Unified decoder LM covering the dense / MoE / vision-cross-attn archs.

Layers are scanned (jax.lax.scan over stacked params) so HLO size is
depth-independent. Pattern-scheduled attention (gemma3's 5 local : 1 global)
is handled with *uniform* layer structure + per-layer scanned scalars
(window size, rope-table selector), so a single scan covers the whole stack.
Vision archs group the stack as [cross_every self-layers + 1 cross-layer]
per scan step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.attention import (AttnConfig, attn_apply, attn_decode,
                                attn_def, cross_kv_project, init_cache)
from repro.nn.layers import (dense_apply, dense_def, embedding_apply,
                             embedding_def, embedding_logits, norm_apply,
                             norm_def, rope_tables)
from repro.nn.mlp import MlpConfig, MoeConfig, mlp_apply, mlp_def, moe_apply, moe_def
from repro.nn.module import stack_defs
from repro.parallel.ctx import constrain


def _attn_cfg(cfg: ModelConfig, path: str = "layers/attn") -> AttnConfig:
    """`path` locates this block in the param tree so the mixed-precision
    plan (cfg.quant_plan) can resolve per-projection bit-widths."""
    return AttnConfig(cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim_,
                      qkv_bias=cfg.qkv_bias, kv_quant_bits=cfg.kv_quant_bits,
                      qcfg=cfg.quant, plan=cfg.quant_plan, path=path)


def _mlp_cfg(cfg: ModelConfig, path: str = "layers/mlp") -> MlpConfig:
    return MlpConfig(cfg.d_model, cfg.d_ff, cfg.act, cfg.quant,
                     cfg.quant_plan, path)


def _moe_cfg(cfg: ModelConfig, path: str = "layers/moe") -> MoeConfig:
    m = cfg.moe
    return MoeConfig(cfg.d_model, m.d_ff, m.n_experts, m.top_k,
                     m.capacity_factor, m.group_size, m.shared_expert,
                     cfg.act, cfg.quant, cfg.quant_plan, path)


def _layer_def(cfg: ModelConfig, dtype):
    p = {"ln1": norm_def(cfg.d_model, cfg.norm, dtype),
         "attn": attn_def(_attn_cfg(cfg), dtype),
         "ln2": norm_def(cfg.d_model, cfg.norm, dtype)}
    if cfg.moe is not None:
        p["moe"] = moe_def(_moe_cfg(cfg), dtype)
    else:
        p["mlp"] = mlp_def(_mlp_cfg(cfg), dtype)
    return p


def _cross_layer_def(cfg: ModelConfig, dtype):
    return {"ln1": norm_def(cfg.d_model, cfg.norm, dtype),
            "xattn": attn_def(_attn_cfg(cfg, "cross_layers/xattn"), dtype),
            "ln2": norm_def(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_def(_mlp_cfg(cfg, "cross_layers/mlp"), dtype)}


def lm_def(cfg: ModelConfig, dtype=jnp.float32):
    n_self, n_cross = _layer_split(cfg)
    p = {"embed": embedding_def(cfg.vocab, cfg.d_model, dtype),
         "layers": stack_defs(_layer_def(cfg, dtype), n_self),
         "final_norm": norm_def(cfg.d_model, cfg.norm, dtype)}
    if n_cross:
        p["cross_layers"] = stack_defs(_cross_layer_def(cfg, dtype), n_cross)
    if not cfg.tie_embeddings:
        from repro.nn.layers import padded_vocab
        p["head"] = dense_def(cfg.d_model, padded_vocab(cfg.vocab),
                              ("embed", "vocab"), dtype=dtype)
    return p


def _layer_split(cfg: ModelConfig):
    if cfg.cross_every:
        n_cross = cfg.n_layers // (cfg.cross_every + 1)
        return cfg.n_layers - n_cross, n_cross
    return cfg.n_layers, 0


def _layer_schedule(cfg: ModelConfig, seq_len: int):
    """Per-layer (window, rope_select) scanned arrays.

    window: effective attention window per layer (global -> seq_len).
    rope_select: 1 where the layer uses the local rope table.
    """
    kinds = cfg.layer_kinds()
    win = jnp.array([cfg.window if k == "local" else max(seq_len, 1)
                     for k in kinds], jnp.int32)
    rsel = jnp.array([1 if (k == "local" and cfg.rope_theta_local) else 0
                      for k in kinds], jnp.int32)
    return win, rsel


def _ropes(cfg: ModelConfig, seq_len: int, dtype):
    cos_g, sin_g = rope_tables(seq_len, cfg.head_dim_, cfg.rope_theta, dtype)
    if cfg.rope_theta_local:
        cos_l, sin_l = rope_tables(seq_len, cfg.head_dim_,
                                   cfg.rope_theta_local, dtype)
    else:
        cos_l, sin_l = cos_g, sin_g
    return (cos_g, sin_g), (cos_l, sin_l)


def _block(cfg, lp, x, cos, sin, window, collect_kv):
    """One decoder block (pre-norm). Returns (x, aux, kv).

    mode="local": window is a per-layer scanned value; global layers carry
    window == seq_len, so one uniform mask covers pattern schedules."""
    h, kv = attn_apply(lp["attn"], norm_apply(lp.get("ln1", {}), x, cfg.norm),
                       _attn_cfg(cfg), cos=cos, sin=sin, mode="local",
                       window=window)
    x = x + h
    aux = 0.0
    if cfg.moe is not None:
        h, aux = moe_apply(lp["moe"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                           _moe_cfg(cfg))
    else:
        h = mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                      _mlp_cfg(cfg))
    x = x + h
    return x, aux, (kv if collect_kv else None)


def _cross_block(cfg, lp, x, src_kv):
    h, _ = attn_apply(lp["xattn"], norm_apply(lp.get("ln1", {}), x, cfg.norm),
                      _attn_cfg(cfg, "cross_layers/xattn"), cos=None, sin=None,
                      mode="bidir", cross_kv=src_kv)
    x = x + h
    x = x + mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                      _mlp_cfg(cfg, "cross_layers/mlp"))
    return x


def forward(params, tokens, cfg: ModelConfig, *, src_embed=None,
            collect_kv: bool = False):
    """Training/prefill forward. tokens (B,S) -> logits (B,S,V).

    src_embed: (B, S_src, d) modality-frontend stub output for vision archs.
    Returns (logits, aux_loss, kv_stack or None).
    """
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    b, s = tokens.shape
    x = constrain(embedding_apply(params["embed"], tokens).astype(dtype),
                  ("batch", None, None))
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    (cg, sg), (cl, sl) = _ropes(cfg, s, dtype)
    win, rsel = _layer_schedule(cfg, s)

    n_self, n_cross = _layer_split(cfg)
    acfg = _attn_cfg(cfg, "cross_layers/xattn")  # only used for cross K/V

    if n_cross == 0:
        def body(carry, per_layer):
            x, aux = carry
            lp, w_l, r_l = per_layer
            cos = jnp.where(r_l == 1, cl, cg)
            sin = jnp.where(r_l == 1, sl, sg)
            x, a, kv = _block(cfg, lp, x, cos, sin, w_l, collect_kv)
            return (x, aux + a), kv

        body = jax.checkpoint(body) if cfg.remat else body
        (x, aux), kvs = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (params["layers"], win, rsel))
    else:
        # grouped scan: cross_every self layers then one cross layer
        assert src_embed is not None, f"{cfg.name} needs src_embed input"
        src = src_embed.astype(dtype)
        ce = cfg.cross_every
        n_groups = n_cross
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, ce, *a.shape[1:]),
            params["layers"])

        def group_body(carry, per_group):
            x, aux = carry
            gp, xp, w_g, r_g = per_group

            def inner(c2, pl2):
                x2, aux2 = c2
                lp, w_l, r_l = pl2
                cos = jnp.where(r_l == 1, cl, cg)
                sin = jnp.where(r_l == 1, sl, sg)
                x2, a2, _ = _block(cfg, lp, x2, cos, sin, w_l, False)
                return (x2, aux2 + a2), None

            (x, aux), _ = jax.lax.scan(inner, (x, aux), (gp, w_g, r_g))
            src_kv = cross_kv_project(xp["xattn"], src, acfg)
            x = _cross_block(cfg, xp, x, src_kv)
            return (x, aux), None

        group_body = jax.checkpoint(group_body) if cfg.remat else group_body
        win_g = win[:n_self].reshape(n_groups, ce)
        rsel_g = rsel[:n_self].reshape(n_groups, ce)
        (x, aux), _ = jax.lax.scan(
            group_body, (x, jnp.float32(0.0)),
            (grouped, params["cross_layers"], win_g, rsel_g))
        kvs = None

    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    logits = _logits(params, x, cfg)
    return logits, aux, kvs


def _logits(params, x, cfg):
    if cfg.tie_embeddings:
        lg = embedding_logits(params["embed"], x, cfg.vocab)
    else:
        lg = dense_apply(params["head"], x)
        vp = lg.shape[-1]
        if vp != cfg.vocab:
            mask = (jnp.arange(vp) < cfg.vocab)
            lg = jnp.where(mask, lg, jnp.asarray(-1e9, lg.dtype))
    return constrain(lg, ("batch", None, "vocab"))


# ------------------------------------------------------------- serving ---

def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    n_self, n_cross = _layer_split(cfg)
    acfg = _attn_cfg(cfg)
    one = init_cache(acfg, batch, max_len, dtype)
    cache = {"kv": jax.tree.map(
        lambda a: jnp.zeros((n_self,) + a.shape, a.dtype), one)}
    if n_cross:
        dh, hk = acfg.head_dim, acfg.kv_heads
        cache["cross_kv"] = jnp.zeros(
            (n_cross, 2, batch, cfg.src_len, hk, dh), dtype)
    return cache


def decode_step(params, cache, token, index, cfg: ModelConfig, *,
                src_embed=None):
    """One decode step. token (B,1) int32; index scalar int32.

    For vision archs the cross K/V are recomputed from src_embed on step 0
    and cached (prefill fills them in practice; dry-run lowers this path).
    Returns (logits (B,1,V), new_cache).
    """
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    b = token.shape[0]
    max_len = cache["kv"]["k"].shape[2]
    x = embedding_apply(params["embed"], token).astype(dtype)
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    th_g = jnp.float32(cfg.rope_theta)
    th_l = jnp.float32(cfg.rope_theta_local or cfg.rope_theta)
    win, rsel = _layer_schedule(cfg, max_len)
    n_self, n_cross = _layer_split(cfg)
    acfg = _attn_cfg(cfg)
    acfg_x = _attn_cfg(cfg, "cross_layers/xattn")

    if n_cross == 0:
        def body(x, per_layer):
            lp, kv_l, w_l, r_l = per_layer
            th = jnp.where(r_l == 1, th_l, th_g)
            h, new_kv = attn_decode(
                lp["attn"], norm_apply(lp.get("ln1", {}), x, cfg.norm), kv_l, index,
                acfg, theta=th, mode="local", window=w_l)
            x = x + h
            if cfg.moe is not None:
                h, _ = moe_apply(lp["moe"],
                                 norm_apply(lp.get("ln2", {}), x, cfg.norm),
                                 _moe_cfg(cfg))
            else:
                h = mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                              _mlp_cfg(cfg))
            return x + h, new_kv

        x, new_kv = jax.lax.scan(body, x, (params["layers"],
                                           cache["kv"], win, rsel))
        new_cache = dict(cache, kv=new_kv)
    else:
        ce = cfg.cross_every
        n_groups = n_cross
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, ce, *a.shape[1:]),
            params["layers"])
        kv_grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, ce, *a.shape[1:]), cache["kv"])
        win_g = win[:n_self].reshape(n_groups, ce)
        rsel_g = rsel[:n_self].reshape(n_groups, ce)

        def group_body(x, per_group):
            gp, xp, kvg, xkv, w_g, r_g = per_group

            def inner(x2, pl2):
                lp, kv_l, w_l, r_l = pl2
                th = jnp.where(r_l == 1, th_l, th_g)
                h, nkv = attn_decode(
                    lp["attn"], norm_apply(lp.get("ln1", {}), x2, cfg.norm), kv_l,
                    index, acfg, theta=th, mode="local", window=w_l)
                x2 = x2 + h
                h = mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x2, cfg.norm),
                              _mlp_cfg(cfg))
                return x2 + h, nkv

            x, nkvg = jax.lax.scan(inner, x, (gp, kvg, w_g, r_g))
            h, _ = attn_decode(
                xp["xattn"], norm_apply(xp.get("ln1", {}), x, cfg.norm), None, index,
                acfg_x, mode="bidir", cross_kv=(xkv[0], xkv[1]))
            x = x + h
            x = x + mlp_apply(xp["mlp"], norm_apply(xp.get("ln2", {}), x, cfg.norm),
                              _mlp_cfg(cfg, "cross_layers/mlp"))
            return x, nkvg

        x, new_kvg = jax.lax.scan(
            group_body, x,
            (grouped, params["cross_layers"], kv_grouped,
             cache["cross_kv"], win_g, rsel_g))
        new_kv = jax.tree.map(
            lambda a: a.reshape(n_self, *a.shape[2:]), new_kvg)
        new_cache = dict(cache, kv=new_kv)

    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    return _logits(params, x, cfg), new_cache
