"""Encoder-decoder LM (seamless-m4t-large-v2 backbone).

Audio frontend is a stub per the assignment: `input_specs` supplies
precomputed frame embeddings (B, S_src, d). The encoder is a bidirectional
transformer over those frames; the decoder is a causal transformer with
cross-attention into encoder states. 24 encoder + 24 decoder layers
(matching the hf card's per-stack depth; see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import _attn_cfg, _mlp_cfg, _logits
from repro.nn.attention import (attn_apply, attn_decode, attn_def,
                                cross_kv_project, init_cache)
from repro.nn.layers import (embedding_apply, embedding_def, norm_apply,
                             norm_def, rope_tables)
from repro.nn.mlp import mlp_apply, mlp_def
from repro.nn.module import stack_defs


def _enc_layer_def(cfg, dtype):
    return {"ln1": norm_def(cfg.d_model, cfg.norm, dtype),
            "attn": attn_def(_attn_cfg(cfg, "enc_layers/attn"), dtype),
            "ln2": norm_def(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_def(_mlp_cfg(cfg, "enc_layers/mlp"), dtype)}


def _dec_layer_def(cfg, dtype):
    return {"ln1": norm_def(cfg.d_model, cfg.norm, dtype),
            "attn": attn_def(_attn_cfg(cfg, "dec_layers/attn"), dtype),
            "lnx": norm_def(cfg.d_model, cfg.norm, dtype),
            "xattn": attn_def(_attn_cfg(cfg, "dec_layers/xattn"), dtype),
            "ln2": norm_def(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_def(_mlp_cfg(cfg, "dec_layers/mlp"), dtype)}


def encdec_def(cfg: ModelConfig, dtype=jnp.float32):
    return {
        "embed": embedding_def(cfg.vocab, cfg.d_model, dtype),
        "enc_layers": stack_defs(_enc_layer_def(cfg, dtype), cfg.enc_layers),
        "enc_norm": norm_def(cfg.d_model, cfg.norm, dtype),
        "dec_layers": stack_defs(_dec_layer_def(cfg, dtype), cfg.dec_layers),
        "final_norm": norm_def(cfg.d_model, cfg.norm, dtype),
    }


def encode(params, src_embed, cfg: ModelConfig):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = src_embed.astype(dtype)
    s = x.shape[1]
    cos, sin = rope_tables(s, cfg.head_dim_, cfg.rope_theta, dtype)
    acfg = _attn_cfg(cfg, "enc_layers/attn")

    def body(x, lp):
        h, _ = attn_apply(lp["attn"], norm_apply(lp.get("ln1", {}), x, cfg.norm),
                          acfg, cos=cos, sin=sin, mode="bidir")
        x = x + h
        x = x + mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                          _mlp_cfg(cfg, "enc_layers/mlp"))
        return x, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return norm_apply(params.get("enc_norm", {}), x, cfg.norm)


def decode_train(params, enc_out, tokens, cfg: ModelConfig):
    """Teacher-forced decoder pass -> logits (B,S,V)."""
    dtype = enc_out.dtype
    b, s = tokens.shape
    x = embedding_apply(params["embed"], tokens).astype(dtype)
    cos, sin = rope_tables(s, cfg.head_dim_, cfg.rope_theta, dtype)
    acfg = _attn_cfg(cfg, "dec_layers/attn")
    acfg_x = _attn_cfg(cfg, "dec_layers/xattn")

    def body(x, lp):
        h, _ = attn_apply(lp["attn"], norm_apply(lp.get("ln1", {}), x, cfg.norm),
                          acfg, cos=cos, sin=sin, mode="causal")
        x = x + h
        src_kv = cross_kv_project(lp["xattn"], enc_out, acfg_x)
        h, _ = attn_apply(lp["xattn"], norm_apply(lp.get("lnx", {}), x, cfg.norm),
                          acfg_x, cos=None, sin=None, mode="bidir",
                          cross_kv=src_kv)
        x = x + h
        x = x + mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                          _mlp_cfg(cfg, "dec_layers/mlp"))
        return x, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    return _logits(params, x, cfg)


def forward(params, tokens, cfg: ModelConfig, *, src_embed=None,
            collect_kv=False):
    """Joint train forward (audio frames -> text)."""
    assert src_embed is not None, f"{cfg.name} needs src_embed input"
    enc_out = encode(params, src_embed, cfg)
    logits = decode_train(params, enc_out, tokens, cfg)
    return logits, jnp.float32(0.0), None


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    acfg = _attn_cfg(cfg)
    one = init_cache(acfg, batch, max_len, dtype)
    dh, hk = acfg.head_dim, acfg.kv_heads
    return {
        "kv": jax.tree.map(
            lambda a: jnp.zeros((cfg.dec_layers,) + a.shape, a.dtype), one),
        "cross_kv": jnp.zeros(
            (cfg.dec_layers, 2, batch, cfg.src_len, hk, dh), dtype),
    }


def decode_step(params, cache, token, index, cfg: ModelConfig, *,
                src_embed=None):
    """Single decoder token step using cached self+cross K/V."""
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = embedding_apply(params["embed"], token).astype(dtype)
    acfg = _attn_cfg(cfg, "dec_layers/attn")
    acfg_x = _attn_cfg(cfg, "dec_layers/xattn")

    def body(x, per_layer):
        lp, kv_l, xkv = per_layer
        h, nkv = attn_decode(lp["attn"],
                             norm_apply(lp.get("ln1", {}), x, cfg.norm), kv_l, index,
                             acfg, theta=cfg.rope_theta, mode="causal")
        x = x + h
        h, _ = attn_decode(lp["xattn"], norm_apply(lp.get("lnx", {}), x, cfg.norm),
                           None, index, acfg_x, mode="bidir",
                           cross_kv=(xkv[0], xkv[1]))
        x = x + h
        x = x + mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                          _mlp_cfg(cfg, "dec_layers/mlp"))
        return x, nkv

    x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], cache["kv"],
                                       cache["cross_kv"]))
    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    return _logits(params, x, cfg), dict(cache, kv=new_kv)
