"""RecurrentGemma / Griffin hybrid: (rec, rec, local-attn) repeating pattern.

38 layers = 12 groups of (RG-LRU, RG-LRU, local attention) + 2 trailing
RG-LRU blocks. Every layer is followed by an MLP block (pre-norm residual),
matching Griffin's residual structure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import _attn_cfg, _mlp_cfg, _logits
from repro.nn.attention import attn_apply, attn_decode, attn_def, init_cache
from repro.nn.layers import (embedding_apply, embedding_def, norm_apply,
                             norm_def, rope_tables)
from repro.nn.mlp import mlp_apply, mlp_def
from repro.nn.module import stack_defs
from repro.nn.rglru import (RglruConfig, rglru_block_apply,
                            rglru_block_decode, rglru_block_def,
                            rglru_init_cache)


def _rcfg(cfg: ModelConfig) -> RglruConfig:
    return RglruConfig(cfg.d_model, cfg.lru_width or cfg.d_model,
                       cfg.d_conv, cfg.quant, cfg.quant_plan,
                       "rec_layers/rec")


def _group_counts(cfg: ModelConfig):
    """(n_groups, n_tail_rec): 38 -> (12, 2)."""
    plen = len(cfg.rnn_pattern)  # ("rec","rec","attn")
    n_groups = cfg.n_layers // plen
    return n_groups, cfg.n_layers - n_groups * plen


def _rec_layer_def(cfg, dtype):
    return {"ln": norm_def(cfg.d_model, cfg.norm, dtype),
            "rec": rglru_block_def(_rcfg(cfg), dtype),
            "ln2": norm_def(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_def(_mlp_cfg(cfg, "rec_layers/mlp"), dtype)}


def _attn_layer_def(cfg, dtype):
    return {"ln": norm_def(cfg.d_model, cfg.norm, dtype),
            "attn": attn_def(_attn_cfg(cfg, "attn_layers/attn"), dtype),
            "ln2": norm_def(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_def(_mlp_cfg(cfg, "attn_layers/mlp"), dtype)}


def griffin_def(cfg: ModelConfig, dtype=jnp.float32):
    ng, tail = _group_counts(cfg)
    n_rec_per_group = sum(1 for k in cfg.rnn_pattern if k == "rec")
    p = {
        "embed": embedding_def(cfg.vocab, cfg.d_model, dtype),
        "rec_layers": stack_defs(_rec_layer_def(cfg, dtype),
                                 ng * n_rec_per_group + tail),
        "attn_layers": stack_defs(_attn_layer_def(cfg, dtype), ng),
        "final_norm": norm_def(cfg.d_model, cfg.norm, dtype),
    }
    return p


def _rec_block(cfg, lp, x):
    x = x + rglru_block_apply(lp["rec"], norm_apply(lp.get("ln", {}), x, cfg.norm),
                              _rcfg(cfg))
    x = x + mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                      _mlp_cfg(cfg, "rec_layers/mlp"))
    return x


def _attn_block(cfg, lp, x, cos, sin):
    h, _ = attn_apply(lp["attn"], norm_apply(lp.get("ln", {}), x, cfg.norm),
                      _attn_cfg(cfg, "attn_layers/attn"), cos=cos, sin=sin,
                      mode="local", window=cfg.window)
    x = x + h
    x = x + mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                      _mlp_cfg(cfg, "attn_layers/mlp"))
    return x


def forward(params, tokens, cfg: ModelConfig, *, src_embed=None,
            collect_kv=False):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = embedding_apply(params["embed"], tokens).astype(dtype)
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    s = tokens.shape[1]
    cos, sin = rope_tables(s, cfg.head_dim_, cfg.rope_theta, dtype)
    ng, tail = _group_counts(cfg)
    nrg = sum(1 for k in cfg.rnn_pattern if k == "rec")

    rec_grouped = jax.tree.map(
        lambda a: a[:ng * nrg].reshape(ng, nrg, *a.shape[1:]),
        params["rec_layers"])
    rec_tail = jax.tree.map(lambda a: a[ng * nrg:], params["rec_layers"])

    def group_body(x, per_group):
        rp, ap = per_group

        def inner(x2, lp):
            return _rec_block(cfg, lp, x2), None

        x, _ = jax.lax.scan(inner, x, rp)
        x = _attn_block(cfg, ap, x, cos, sin)
        return x, None

    group_body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, _ = jax.lax.scan(group_body, x, (rec_grouped, params["attn_layers"]))

    def tail_body(x, lp):
        return _rec_block(cfg, lp, x), None
    x, _ = jax.lax.scan(tail_body, x, rec_tail)

    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    return _logits(params, x, cfg), jnp.float32(0.0), None


def griffin_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    ng, tail = _group_counts(cfg)
    nrg = sum(1 for k in cfg.rnn_pattern if k == "rec")
    acfg = _attn_cfg(cfg, "attn_layers/attn")
    # local attention only needs `window` KV slots, but decode uses absolute
    # positions; keep window-sized ring handled as full buffer of max_len
    # capped at window for memory (ring indexing = index % window).
    attn_len = min(max_len, cfg.window)
    rec_one = rglru_init_cache(_rcfg(cfg), batch, dtype)
    return {
        "rec": jax.tree.map(
            lambda a: jnp.zeros((ng * nrg + tail,) + a.shape, a.dtype),
            rec_one),
        "kv": jax.tree.map(
            lambda a: jnp.zeros((ng,) + a.shape, a.dtype),
            init_cache(acfg, batch, attn_len, dtype)),
    }


def decode_step(params, cache, token, index, cfg: ModelConfig, *,
                src_embed=None):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = embedding_apply(params["embed"], token).astype(dtype)
    if cfg.scale_embed:
        x = x * (cfg.d_model ** 0.5)
    ng, tail = _group_counts(cfg)
    nrg = sum(1 for k in cfg.rnn_pattern if k == "rec")
    acfg = _attn_cfg(cfg, "attn_layers/attn")

    rec_grouped = jax.tree.map(
        lambda a: a[:ng * nrg].reshape(ng, nrg, *a.shape[1:]), cache["rec"])
    rp_grouped = jax.tree.map(
        lambda a: a[:ng * nrg].reshape(ng, nrg, *a.shape[1:]),
        params["rec_layers"])

    def group_body(x, per_group):
        rp, rc, ap, kv_l = per_group

        def inner(x2, pl):
            lp, c_l = pl
            h, nc = rglru_block_decode(
                lp["rec"], norm_apply(lp.get("ln", {}), x2, cfg.norm), c_l,
                _rcfg(cfg))
            x2 = x2 + h
            x2 = x2 + mlp_apply(lp["mlp"],
                                norm_apply(lp.get("ln2", {}), x2, cfg.norm),
                                _mlp_cfg(cfg, "rec_layers/mlp"))
            return x2, nc

        x, nrc = jax.lax.scan(inner, x, (rp, rc))
        h, nkv = attn_decode(
            ap["attn"], norm_apply(ap.get("ln", {}), x, cfg.norm), kv_l, index,
            acfg, theta=cfg.rope_theta, mode="local", window=cfg.window,
            ring=True)
        x = x + h
        x = x + mlp_apply(ap["mlp"], norm_apply(ap.get("ln2", {}), x, cfg.norm),
                          _mlp_cfg(cfg, "attn_layers/mlp"))
        return x, (nrc, nkv)

    ap_stack = params["attn_layers"]
    x, (new_rec_g, new_kv) = jax.lax.scan(
        group_body, x, (rp_grouped, rec_grouped, ap_stack, cache["kv"]))

    rec_tail_p = jax.tree.map(lambda a: a[ng * nrg:], params["rec_layers"])
    rec_tail_c = jax.tree.map(lambda a: a[ng * nrg:], cache["rec"])

    def tail_body(x, pl):
        lp, c_l = pl
        h, nc = rglru_block_decode(
            lp["rec"], norm_apply(lp.get("ln", {}), x, cfg.norm), c_l, _rcfg(cfg))
        x = x + h
        x = x + mlp_apply(lp["mlp"], norm_apply(lp.get("ln2", {}), x, cfg.norm),
                          _mlp_cfg(cfg, "rec_layers/mlp"))
        return x, nc

    x, new_rec_t = jax.lax.scan(tail_body, x, (rec_tail_p, rec_tail_c))

    new_rec = jax.tree.map(
        lambda g, t: jnp.concatenate(
            [g.reshape(ng * nrg, *g.shape[2:]), t], axis=0),
        new_rec_g, new_rec_t)
    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    return _logits(params, x, cfg), {"rec": new_rec, "kv": new_kv}
