"""Mamba-2 (SSD) language model — attention-free, O(1)-state decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import _logits
from repro.nn.layers import (embedding_apply, embedding_def, norm_apply,
                             norm_def)
from repro.nn.module import stack_defs
from repro.nn.ssm import (MambaConfig, mamba_apply, mamba_decode, mamba_def,
                          mamba_init_cache)


def _mcfg(cfg: ModelConfig) -> MambaConfig:
    return MambaConfig(cfg.d_model, cfg.d_state, cfg.d_conv, cfg.expand,
                       cfg.headdim, cfg.ssd_chunk, cfg.quant,
                       cfg.quant_plan, "layers/mixer")


def mamba_lm_def(cfg: ModelConfig, dtype=jnp.float32):
    return {
        "embed": embedding_def(cfg.vocab, cfg.d_model, dtype),
        "layers": stack_defs({
            "ln": norm_def(cfg.d_model, cfg.norm, dtype),
            "mixer": mamba_def(_mcfg(cfg), dtype)}, cfg.n_layers),
        "final_norm": norm_def(cfg.d_model, cfg.norm, dtype),
    }


def forward(params, tokens, cfg: ModelConfig, *, src_embed=None,
            collect_kv=False):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = embedding_apply(params["embed"], tokens).astype(dtype)
    mcfg = _mcfg(cfg)

    def body(x, lp):
        x = x + mamba_apply(lp["mixer"], norm_apply(lp.get("ln", {}), x, cfg.norm),
                            mcfg)
        return x, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    return _logits(params, x, cfg), jnp.float32(0.0), None


def mamba_lm_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                        dtype=jnp.bfloat16):
    one = mamba_init_cache(_mcfg(cfg), batch, dtype)
    return {"ssm": jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)}


def decode_step(params, cache, token, index, cfg: ModelConfig, *,
                src_embed=None):
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = embedding_apply(params["embed"], token).astype(dtype)
    mcfg = _mcfg(cfg)

    def body(x, per_layer):
        lp, c_l = per_layer
        h, nc = mamba_decode(lp["mixer"], norm_apply(lp.get("ln", {}), x, cfg.norm),
                             c_l, mcfg)
        return x + h, nc

    x, new_c = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
    x = norm_apply(params.get("final_norm", {}), x, cfg.norm)
    return _logits(params, x, cfg), {"ssm": new_c}
