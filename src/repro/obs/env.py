"""Central registry of the repo's ``REPRO_*`` environment knobs.

Every env knob the runtime honours is declared here with its type, its
validated value space, and a one-line doc. Call sites read through
:func:`get` / :func:`get_bool` instead of ``os.environ`` so that

* a typo'd knob (``REPRO_QBACKND=xla``) warns instead of being silently
  ignored — :func:`warn_unknown` scans the process environment for
  ``REPRO_*`` names that no knob declares;
* an invalid *value* for a choice knob raises immediately with the list
  of accepted values, instead of surfacing as a confusing downstream
  ``KeyError`` five layers deeper;
* the README's knob table is generated (``python -m repro.obs.env``)
  rather than hand-maintained.

This module is import-light on purpose: no jax, no numpy, nothing from
``repro.kernels``. ``launch/dryrun.py`` imports it *before* jax is
initialised to assemble ``XLA_FLAGS``, and ``repro.obs.trace`` imports
it at interpreter startup to decide whether observability is on.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Optional, Tuple

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off", "")


@dataclasses.dataclass(frozen=True)
class Knob:
    name: str
    doc: str
    kind: str = "str"          # 'str' | 'bool' | 'path' | 'choice'
    choices: Tuple[str, ...] = ()   # for kind='choice'
    legacy: Tuple[str, ...] = ()    # deprecated aliases still honoured


KNOBS = {k.name: k for k in (
    Knob("REPRO_QBACKEND",
         "Force the kernel backend for every `qdot`/`qconv` call "
         "(`pallas` / `pallas_interpret` / `xla` / `eager_ref`); "
         "validated against the registry at resolve time."),
    Knob("REPRO_QPIPELINE",
         "Force the kernel pipeline mode suite-wide.",
         kind="choice", choices=("off", "double_buffer")),
    Knob("REPRO_QTUNE_CACHE",
         "Path to an autotune-cache JSON preloaded at first lookup "
         "(block-shape + pipeline winners from `tune.py --sweep`).",
         kind="path"),
    Knob("REPRO_EXTRA_XLA",
         "Extra `XLA_FLAGS` prepended by `repro.launch.dryrun` before "
         "jax initialises.", legacy=("_REPRO_EXTRA_XLA",)),
    Knob("REPRO_OBS",
         "Enable the observability layer (`repro.obs`): spans, MAC/byte "
         "counters, dispatch decision log. Off by default — disabled "
         "mode records nothing and adds one predicate per call.",
         kind="bool"),
    Knob("REPRO_OBS_TRACE",
         "Path where instrumented CLIs/benchmarks export the Chrome "
         "trace-event JSON artifact on exit (implies nothing unless "
         "REPRO_OBS is on).", kind="path"),
)}

_warned_unknown = False


def warn_unknown() -> Tuple[str, ...]:
    """Warn (once) about ``REPRO_*`` env vars no knob declares.

    Returns the offending names so tests can assert on them without
    capturing warnings."""
    global _warned_unknown
    known = set(KNOBS)
    for k in KNOBS.values():
        known.update(k.legacy)
    unknown = tuple(sorted(
        n for n in os.environ if n.startswith("REPRO_") and n not in known))
    if unknown and not _warned_unknown:
        _warned_unknown = True
        warnings.warn(
            f"unrecognized REPRO_* environment variable(s): "
            f"{', '.join(unknown)}; known knobs: {', '.join(sorted(KNOBS))}",
            stacklevel=2)
    return unknown


def get(name: str) -> Optional[str]:
    """The validated value of knob ``name``, or None when unset/empty.

    Unknown ``name`` raises (call sites must declare their knobs);
    invalid values for choice knobs raise ValueError.
    """
    knob = KNOBS.get(name)
    if knob is None:
        raise KeyError(
            f"undeclared env knob {name!r}; declare it in "
            f"repro.obs.env.KNOBS (known: {sorted(KNOBS)})")
    warn_unknown()
    raw = os.environ.get(name)
    if raw is None:
        for legacy in knob.legacy:
            raw = os.environ.get(legacy)
            if raw is not None:
                warnings.warn(
                    f"env var {legacy!r} is deprecated; use {name!r}",
                    DeprecationWarning, stacklevel=2)
                break
    if not raw:
        return None
    if knob.kind == "choice" and raw not in knob.choices:
        raise ValueError(
            f"{name}={raw!r} is not a valid value; choices: {knob.choices}")
    if knob.kind == "bool" and raw.lower() not in _TRUE + _FALSE:
        raise ValueError(
            f"{name}={raw!r} is not boolean; use one of {_TRUE + _FALSE}")
    return raw


def get_bool(name: str) -> bool:
    raw = get(name)
    return raw is not None and raw.lower() in _TRUE


def table() -> str:
    """The README knob table (GitHub markdown), generated from KNOBS."""
    rows = ["| Variable | Type | Meaning |", "| --- | --- | --- |"]
    for knob in sorted(KNOBS.values(), key=lambda k: k.name):
        kind = ("/".join(knob.choices) if knob.kind == "choice"
                else knob.kind)
        rows.append(f"| `{knob.name}` | {kind} | {knob.doc} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print(table())
