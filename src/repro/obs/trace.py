"""Tracing core: spans, named counters, dispatch log, Chrome-trace export.

The software analogue of the paper's hardware performance-counter setup
(Sec. V): everything the runtime wants to measure funnels through this
module into one in-process ring buffer, and one exported artifact makes
a run auditable after the fact.

Design points:

* **Zero overhead when disabled.** `span()`/`counter()` return shared
  no-op singletons and `dispatch_event()` returns immediately; the only
  cost on the hot path is one module-global predicate. Enablement comes
  from the ``REPRO_OBS`` env at import (via `repro.obs.env`) or
  programmatically via `enable()`/`disable()`.
* **Thread-safe ring buffers.** Spans/instants land in a bounded
  `collections.deque` guarded by one lock; old events fall off the
  front instead of growing without bound under serving load.
* **Chrome trace-event export.** `chrome_trace()` renders the buffer as
  the trace-event JSON object form (openable in Perfetto /
  chrome://tracing); repo-specific payloads (generic counters, the
  per-(op, bits, backend, pipeline) op counters, the dispatch log) ride
  under a top-level ``"repro"`` key, which the format explicitly allows.
* **jax-aware, jax-free.** jax is imported lazily inside `time_call` /
  `Span.sync` only, so this module (and `repro.obs.env`) can load
  before jax initialises. `jax.block_until_ready` is tracer-safe, so
  spans may wrap code under `jit` tracing — such a span measures *trace*
  time and fires once per compilation, which is exactly when the op
  counters record too (documented in docs/architecture.md).

Timestamps are microseconds relative to a module-load epoch
(`perf_counter_ns`), matching the trace-event format's ``ts``/``dur``
unit.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from repro.obs import env as obsenv

TRACE_SCHEMA_VERSION = 1
DEFAULT_CAPACITY = 100_000

_T0_NS = time.perf_counter_ns()
_LOCK = threading.RLock()
_EVENTS: deque = deque(maxlen=DEFAULT_CAPACITY)
_DISPATCH: deque = deque(maxlen=DEFAULT_CAPACITY)
_COUNTERS: Dict[str, "Counter"] = {}
_TIDS: Dict[int, int] = {}
_ENABLED = obsenv.get_bool("REPRO_OBS")
_XLA_ANNOTATIONS = False


def _now_us() -> float:
    return (time.perf_counter_ns() - _T0_NS) / 1e3


def _tid() -> int:
    """Small stable per-thread id (trace viewers want dense tids)."""
    ident = threading.get_ident()
    with _LOCK:
        tid = _TIDS.get(ident)
        if tid is None:
            tid = _TIDS[ident] = len(_TIDS)
        return tid


# ------------------------------------------------------------- lifecycle ---

def enabled() -> bool:
    return _ENABLED


def enable(capacity: Optional[int] = None,
           xla_annotations: Optional[bool] = None) -> None:
    """Turn observability on; optionally resize the ring buffers and/or
    mirror spans into XLA profiles via `jax.profiler.TraceAnnotation`."""
    global _ENABLED, _EVENTS, _DISPATCH, _XLA_ANNOTATIONS
    with _LOCK:
        if capacity is not None and capacity != _EVENTS.maxlen:
            _EVENTS = deque(_EVENTS, maxlen=capacity)
            _DISPATCH = deque(_DISPATCH, maxlen=capacity)
        if xla_annotations is not None:
            _XLA_ANNOTATIONS = xla_annotations
        _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def reset() -> None:
    """Drop all recorded events, dispatch entries, and generic counters
    (op counters live in `repro.obs.counters` — `repro.obs.reset()`
    clears both)."""
    with _LOCK:
        _EVENTS.clear()
        _DISPATCH.clear()
        _COUNTERS.clear()


@contextmanager
def enabled_scope(xla_annotations: Optional[bool] = None):
    """Force-enable observability inside the block, restoring the prior
    state on exit — how benchmarks take counter readings without
    requiring ``REPRO_OBS`` in the environment."""
    global _ENABLED
    prev = _ENABLED
    enable(xla_annotations=xla_annotations)
    try:
        yield
    finally:
        _ENABLED = prev


# ------------------------------------------------------------------ spans ---

class Span:
    """One timed region. ``with span("qdot", cat="kernel", w_bits=4):``
    records an "X" (complete) trace event on exit carrying the attrs as
    ``args``. `set()` adds attrs mid-span; `sync(value)` blocks on a jax
    value so device time lands inside the span, and returns it."""

    __slots__ = ("name", "cat", "attrs", "_t0", "_ann")

    def __init__(self, name: str, cat: str, attrs: Dict[str, Any]):
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self._t0 = 0.0
        self._ann = None

    def __enter__(self) -> "Span":
        if _XLA_ANNOTATIONS:
            try:
                import jax
                self._ann = jax.profiler.TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:
                self._ann = None
        self._t0 = _now_us()
        return self

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def sync(self, value):
        try:
            import jax
            jax.block_until_ready(value)
        except Exception:
            pass
        return value

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur = _now_us() - self._t0
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        if _ENABLED:
            with _LOCK:
                _EVENTS.append({
                    "name": self.name, "cat": self.cat, "ph": "X",
                    "ts": round(self._t0, 3), "dur": round(dur, 3),
                    "pid": 0, "tid": _tid(),
                    "args": dict(self.attrs)})
        return False


class _NullSpan:
    """Shared do-nothing span returned while observability is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def sync(self, value):
        return value


_NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "span", **attrs):
    """A context manager timing the enclosed block (no-op singleton when
    disabled). Extra keyword attrs land in the event's ``args``."""
    if not _ENABLED:
        return _NULL_SPAN
    return Span(name, cat, attrs)


# --------------------------------------------------------------- counters ---

class Counter:
    """A named monotonically-accumulating value; `add` is a no-op while
    observability is off so handles can be cached across enable state."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def add(self, v=1) -> "Counter":
        if _ENABLED:
            with _LOCK:
                self.value += v
        return self


class _NullCounter(Counter):
    __slots__ = ()

    def add(self, v=1):
        return self


_NULL_COUNTER = _NullCounter("<disabled>")


def counter(name: str) -> Counter:
    """The named counter (created on first use); a shared no-op when
    observability is off, so the registry holds no disabled-mode state."""
    if not _ENABLED:
        return _NULL_COUNTER
    with _LOCK:
        c = _COUNTERS.get(name)
        if c is None:
            c = _COUNTERS[name] = Counter(name)
        return c


def counter_values() -> Dict[str, float]:
    with _LOCK:
        return {name: c.value for name, c in _COUNTERS.items()}


# ----------------------------------------------------------- dispatch log ---

def dispatch_event(**fields) -> None:
    """Record one structured backend/pipeline dispatch decision
    (`kernels/api.py` calls this once per resolution). Also mirrored
    into the span stream as an instant event so trace viewers show the
    decision inline with the kernel spans."""
    if not _ENABLED:
        return
    ts = _now_us()
    with _LOCK:
        _DISPATCH.append(dict(fields, ts=round(ts, 3)))
        _EVENTS.append({
            "name": f"dispatch:{fields.get('op', '?')}",
            "cat": "dispatch", "ph": "i", "s": "t",
            "ts": round(ts, 3), "pid": 0, "tid": _tid(),
            "args": dict(fields)})


def dispatch_log() -> List[Dict[str, Any]]:
    with _LOCK:
        return list(_DISPATCH)


# -------------------------------------------------------------- rendering ---

def events() -> List[Dict[str, Any]]:
    with _LOCK:
        return list(_EVENTS)


def spans(name: Optional[str] = None,
          cat: Optional[str] = None) -> List[Dict[str, Any]]:
    return [e for e in events()
            if e["ph"] == "X"
            and (name is None or e["name"] == name)
            and (cat is None or e["cat"] == cat)]


def chrome_trace() -> Dict[str, Any]:
    """The full buffer as a Chrome trace-event JSON object. Repo payloads
    (counters, op counters, dispatch log) ride under ``"repro"`` — extra
    top-level keys are explicitly allowed by the object form."""
    from repro.obs import counters as _opcounters
    return {
        "traceEvents": events(),
        "displayTimeUnit": "ms",
        "repro": {
            "version": TRACE_SCHEMA_VERSION,
            "counters": counter_values(),
            "op_counters": _opcounters.snapshot(),
            "dispatch": dispatch_log(),
        },
    }


def export_chrome_trace(path: str) -> str:
    with open(path, "w") as fh:
        json.dump(chrome_trace(), fh, indent=1, default=str)
    return path


def export_if_configured(default_path: Optional[str] = None) -> Optional[str]:
    """Export the trace when observability is on: to ``REPRO_OBS_TRACE``
    if set, else to ``default_path`` (no-op when neither). CLIs call
    this on exit so `REPRO_OBS=1 REPRO_OBS_TRACE=t.json <cli>` is the
    whole recipe."""
    if not _ENABLED:
        return None
    path = obsenv.get("REPRO_OBS_TRACE") or default_path
    if not path:
        return None
    return export_chrome_trace(path)


def summary() -> Dict[str, Any]:
    """Aggregate view: per-span-name {count, total_us, mean_us, max_us},
    generic counters, dispatch-event count."""
    agg: Dict[str, Dict[str, float]] = {}
    for e in spans():
        s = agg.setdefault(e["name"], {"count": 0, "total_us": 0.0,
                                       "max_us": 0.0})
        s["count"] += 1
        s["total_us"] += e["dur"]
        s["max_us"] = max(s["max_us"], e["dur"])
    for s in agg.values():
        s["mean_us"] = s["total_us"] / s["count"]
    return {"spans": agg, "counters": counter_values(),
            "dispatch_events": len(dispatch_log())}


# ------------------------------------------------------------ shared timer ---

def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Mean wall-clock µs per call of ``fn(*args)``.

    The one timing implementation behind `kernels.tune._time` and
    `benchmarks.common.time_call` (previously two divergent copies):
    ``warmup`` synced calls to amortise compilation, then ``iters``
    back-to-back calls with one `block_until_ready` on the last result —
    async dispatch overlaps inside the loop, the sync charges all device
    work to the measured window.
    """
    import jax

    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
