"""repro.obs — the observability layer: spans, software performance
counters, dispatch decision log, Chrome-trace export.

The software analogue of the paper's hardware performance-counter
methodology (Sec. V). Disabled by default; ``REPRO_OBS=1`` (or
`enable()`) turns recording on, ``REPRO_OBS_TRACE=path.json`` makes the
instrumented CLIs/benchmarks export a Chrome trace-event artifact that
``python -m repro.obs.report`` renders as MAC/µs-per-bit-width,
dispatch-summary, and top-span tables.

This package stays import-light: neither this module, `obs.env`, nor
`obs.trace` imports jax at module level, so `launch/dryrun.py` can read
env knobs before jax initialises.
"""
from repro.obs import env  # noqa: F401
from repro.obs.trace import (TRACE_SCHEMA_VERSION, chrome_trace,  # noqa: F401
                             counter, counter_values, disable,
                             dispatch_event, dispatch_log, enable, enabled,
                             enabled_scope, events, export_chrome_trace,
                             export_if_configured, span, spans, summary,
                             time_call)


def reset() -> None:
    """Drop every recorded event, generic counter, and op counter."""
    from repro.obs import counters, trace
    trace.reset()
    counters.reset()
