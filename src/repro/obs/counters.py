"""Software performance counters: MACs and bytes per quantized op call.

The paper reports MAC/cycle per bit-width from RI5CY hardware counters
(Sec. V); this is the software analogue. `repro.kernels.api` calls
:func:`record` at every `qdot`/`qconv` entry so effective MAC/µs and
arithmetic intensity per bit-width fall out of any instrumented run.

Accounting is keyed by ``(op, w_bits, a_bits, backend, pipeline)`` —
rendered as ``"{op}|w{w}a{a}|{backend}|{pipeline}"`` — and each bucket
accumulates

    calls           number of recorded entry-point calls
    macs            multiply-accumulates: m*k*n (qdot, padded K as the
                    kernel sees it), n*ho*wo*fh*fw*(cin/groups)*cout (qconv)
    logical_bytes   one byte per logical int8 element moved (activations
                    + weights + output) — the unpacked traffic a W8A8
                    kernel would move
    packed_bytes    the same traffic in packed containers: sub-byte
                    operands shrink by 8/bits — the memory-roofline term
                    the paper's sub-byte speedup comes from

``logical/packed`` per bucket is the measured container-compression
ratio; ``macs/packed_bytes`` is the arithmetic intensity the fig8
roofline plots. Recording is a no-op unless `repro.obs.trace` is
enabled. Under `jax.jit` the entry points run once per *trace*, so
counters record per compilation there — the instrumented benchmarks and
the serve engines call the registry un-jitted, where counts are
per-call.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from repro.obs import trace

_LOCK = threading.Lock()
_OPS: Dict[str, Dict[str, int]] = {}

_FIELDS = ("calls", "macs", "logical_bytes", "packed_bytes")


def _pack_factor(bits: int) -> int:
    return 8 // int(bits)


def key(op: str, w_bits: int, a_bits: int, backend: str,
        pipeline: str) -> str:
    return f"{op}|w{int(w_bits)}a{int(a_bits)}|{backend}|{pipeline}"


def parse_key(k: str) -> Dict[str, object]:
    op, bits, backend, pipeline = k.split("|")
    w, a = bits[1:].split("a")
    return {"op": op, "w_bits": int(w), "a_bits": int(a),
            "backend": backend, "pipeline": pipeline}


def conv_out_hw(h, w, fh, fw, stride, padding):
    ho = (h + 2 * padding - fh) // stride + 1
    wo = (w + 2 * padding - fw) // stride + 1
    return ho, wo


def qdot_costs(shape, a_bits: int, w_bits: int) -> Dict[str, int]:
    """(m, k, n) GEMM cost model; k is the padded K the kernel contracts."""
    m, k, n = (int(s) for s in shape[:3])
    macs = m * k * n
    logical = m * k + k * n + m * n
    packed = (m * k // _pack_factor(a_bits)
              + k * n // _pack_factor(w_bits) + m * n)
    return {"calls": 1, "macs": macs, "logical_bytes": logical,
            "packed_bytes": packed}


def qconv_costs(shape, a_bits: int, w_bits: int) -> Dict[str, int]:
    """Registry conv shape key -> costs. ``shape`` is the 9/10-tuple
    (n, h, w, cin, fh, fw, stride, padding, cout[, groups])."""
    n, h, w, cin, fh, fw, stride, padding, cout = (
        int(s) for s in shape[:9])
    groups = int(shape[9]) if len(shape) > 9 else 1
    ho, wo = conv_out_hw(h, w, fh, fw, stride, padding)
    k = fh * fw * (cin // groups)          # contraction depth per out pixel
    macs = n * ho * wo * k * cout
    logical = n * h * w * cin + k * cout + n * ho * wo * cout
    packed = (n * h * w * cin // _pack_factor(a_bits)
              + k * cout // _pack_factor(w_bits) + n * ho * wo * cout)
    return {"calls": 1, "macs": macs, "logical_bytes": logical,
            "packed_bytes": packed}


def record(op: str, shape, a_bits: int, w_bits: int, *, backend: str,
           pipeline: str,
           w_packed_bytes: Optional[int] = None) -> Optional[Dict[str, int]]:
    """Bump the (op, bits, backend, pipeline) bucket for one call; returns
    the per-call deltas (None when observability is off).

    GEMM-shaped ops ("qdot", "qdot_mixed") share the (m, k, n) cost
    model; everything else is the conv key. ``w_packed_bytes`` replaces
    the uniform-container weight term of ``packed_bytes`` — segmented
    containers stream exactly their per-run byte count, not k*n/pf at
    one width."""
    if not trace.enabled():
        return None
    costs = (qdot_costs if op.startswith("qdot") else qconv_costs)(
        shape, a_bits, w_bits)
    if w_packed_bytes is not None:
        m, kdim, n = (int(s) for s in shape[:3])
        costs["packed_bytes"] = (m * kdim // _pack_factor(a_bits)
                                 + int(w_packed_bytes) + m * n)
    k = key(op, w_bits, a_bits, backend, pipeline)
    with _LOCK:
        bucket = _OPS.setdefault(k, dict.fromkeys(_FIELDS, 0))
        for f in _FIELDS:
            bucket[f] += costs[f]
    return costs


def snapshot() -> Dict[str, Dict[str, int]]:
    with _LOCK:
        return {k: dict(v) for k, v in _OPS.items()}


def reset() -> None:
    with _LOCK:
        _OPS.clear()


def delta(after: Dict[str, Dict[str, int]],
          before: Dict[str, Dict[str, int]]) -> Dict[str, Dict[str, int]]:
    """Per-bucket ``after - before`` (buckets with no change dropped) —
    how benchmarks attribute counts to one timed region."""
    out: Dict[str, Dict[str, int]] = {}
    for k, av in after.items():
        bv = before.get(k, {})
        d = {f: av[f] - bv.get(f, 0) for f in _FIELDS}
        if any(d.values()):
            out[k] = d
    return out
