"""Render a trace artifact: MAC/µs per bit-width, dispatch summary, top
spans.

    python -m repro.obs.report [trace.json]

Reads the Chrome trace-event JSON written by `obs.export_chrome_trace`
(any instrumented CLI/benchmark run with ``REPRO_OBS=1
REPRO_OBS_TRACE=trace.json``) and prints

* **MAC/µs per bit-width** — kernel spans carry their MAC count and the
  resolved (backend, pipeline), so the table is measured throughput per
  (op, W, A, backend, pipeline) bucket, the software analogue of the
  paper's MAC/cycle-per-precision tables; packed-bytes and arithmetic
  intensity come from the op counters.
* **Dispatch summary** — how every resolution layer decided (explicit /
  plan / env / tuned / default), tune-cache hit rate, final
  backend×pipeline histogram.
* **Top spans** — where the wall-clock went, by total span duration.
* **Serving runtime** — the scheduler's admission/eviction/page counters
  and `serve.step` span aggregate when the trace contains serving work,
  plus a policy-comparison table from ``BENCH_serving.json``
  (benchmarks/loadgen) when that artifact sits next to the trace.

The path defaults to ``REPRO_OBS_TRACE`` then ``BENCH_trace.json``.
Dependency-free (stdlib only): runs anywhere the JSON artifact lands,
no jax required.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict
from typing import Any, Dict, List


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as fh:
        doc = json.load(fh)
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace-event object "
                         "(no 'traceEvents' key)")
    return doc


def _fmt_table(headers: List[str], rows: List[List[str]]) -> str:
    rows = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    out = [line(headers), line(["-" * w for w in widths])]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


def kernel_spans(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    return [e for e in doc.get("traceEvents", [])
            if e.get("ph") == "X" and e.get("cat") == "kernel"]


def mac_table(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Measured MAC/µs per (op, w_bits, a_bits, backend, pipeline), from
    kernel spans; packed bytes joined in from the op counters."""
    agg: Dict[tuple, Dict[str, float]] = defaultdict(
        lambda: {"calls": 0, "macs": 0.0, "us": 0.0})
    for e in kernel_spans(doc):
        a = e.get("args", {})
        k = (a.get("op") or e.get("name"), a.get("w_bits"),
             a.get("a_bits"), a.get("backend"), a.get("pipeline"))
        agg[k]["calls"] += 1
        agg[k]["macs"] += a.get("macs") or 0
        agg[k]["us"] += e.get("dur", 0.0)
    packed = {}
    for key, c in doc.get("repro", {}).get("op_counters", {}).items():
        op, bits, backend, pipeline = key.split("|")
        w, a = bits[1:].split("a")
        packed[(op, int(w), int(a), backend, pipeline)] = c
    rows = []
    for k in sorted(agg, key=lambda t: tuple(str(v) for v in t)):
        op, w, a, backend, pipeline = k
        v = agg[k]
        c = packed.get(k, {})
        pb = c.get("packed_bytes")
        rows.append({
            "op": op, "w_bits": w, "a_bits": a, "backend": backend,
            "pipeline": pipeline, "calls": v["calls"],
            "macs": int(v["macs"]), "us": v["us"],
            "macs_per_us": v["macs"] / v["us"] if v["us"] else 0.0,
            "packed_bytes": pb,
            "intensity": (int(v["macs"]) / pb if pb else None)})
    return rows


def dispatch_summary(doc: Dict[str, Any]) -> Dict[str, Any]:
    log = doc.get("repro", {}).get("dispatch", [])
    by_choice: Dict[str, int] = defaultdict(int)
    by_source: Dict[str, int] = defaultdict(int)
    hits = 0
    for d in log:
        by_choice[f"{d.get('op')}:{d.get('backend')}"
                  f"/{d.get('pipeline')}"] += 1
        by_source[f"backend<-{d.get('backend_source')}"] += 1
        by_source[f"pipeline<-{d.get('pipeline_source')}"] += 1
        hits += bool(d.get("tune_cache_hit"))
    return {"events": len(log), "tune_cache_hits": hits,
            "by_choice": dict(by_choice), "by_source": dict(by_source)}


def top_spans(doc: Dict[str, Any], n: int = 10) -> List[Dict[str, Any]]:
    agg: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "max_us": 0.0})
    for e in doc.get("traceEvents", []):
        if e.get("ph") != "X":
            continue
        s = agg[e["name"]]
        s["count"] += 1
        s["total_us"] += e.get("dur", 0.0)
        s["max_us"] = max(s["max_us"], e.get("dur", 0.0))
    ranked = sorted(agg.items(), key=lambda kv: -kv[1]["total_us"])[:n]
    return [dict(name=k, **v) for k, v in ranked]


def serving_summary(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Serving-runtime activity in a trace: the scheduler's admission/
    eviction/page counters (`repro.serve.runtime.slots`) and the
    aggregate of its per-step `serve.step` spans."""
    counters = doc.get("repro", {}).get("counters", {})
    serve = {k: counters[k] for k in sorted(counters)
             if k.startswith(("serve.", "engine."))}
    steps = [e for e in doc.get("traceEvents", [])
             if e.get("ph") == "X" and e.get("name") == "serve.step"]
    span = None
    if steps:
        active = [e.get("args", {}).get("active", 0) for e in steps]
        depth = [e.get("args", {}).get("queue_depth", 0) for e in steps]
        span = {"steps": len(steps),
                "total_us": sum(e.get("dur", 0.0) for e in steps),
                "mean_active": sum(active) / len(steps),
                "max_queue_depth": max(depth)}
    return {"counters": serve, "steps": span}


def render_serving_bench(payload: Dict[str, Any]) -> str:
    """Render a BENCH_serving.json (benchmarks/loadgen) policy table."""
    out = ["== serving benchmark (BENCH_serving.json) =="]
    w = payload.get("workload", {})
    out.append(f"  workload: {w.get('requests')} requests @ "
               f"{w.get('qps')} req/s, {w.get('slots')} slots, "
               f"seed {w.get('seed')}")
    out.append(_fmt_table(
        ["policy", "req/s", "tok/s", "p50_s", "p99_s", "steps",
         "occupancy", "max_queue"],
        [[r["policy"], f"{r['throughput_rps']:.3f}",
          f"{r['throughput_tps']:.3f}", f"{r['latency_s']['p50']:.1f}",
          f"{r['latency_s']['p99']:.1f}", str(r["steps"]),
          f"{r['occupancy']['mean']:.0%}",
          str(r["queue_depth"]["max"])]
         for r in payload.get("rows", [])]))
    acc = payload.get("acceptance", {})
    if acc:
        out.append(f"  continuous vs wave: "
                   f"{acc.get('throughput_gain'):.2f}x throughput, "
                   f"{acc.get('p99_ratio'):.2f}x p99 latency")
    return "\n".join(out)


def render(doc: Dict[str, Any]) -> str:
    out = []
    rows = mac_table(doc)
    out.append("== MAC/us per bit-width (measured, from kernel spans) ==")
    if rows:
        out.append(_fmt_table(
            ["op", "W", "A", "backend", "pipeline", "calls", "MMACs",
             "us", "MAC/us", "packed_KiB", "MAC/byte"],
            [[r["op"], str(r["w_bits"]), str(r["a_bits"]), r["backend"],
              r["pipeline"], str(r["calls"]), f"{r['macs'] / 1e6:.2f}",
              f"{r['us']:.1f}", f"{r['macs_per_us']:.1f}",
              "-" if r["packed_bytes"] is None
              else f"{r['packed_bytes'] / 1024:.1f}",
              "-" if r["intensity"] is None else f"{r['intensity']:.2f}"]
             for r in rows]))
    else:
        out.append("(no kernel spans in trace)")
    ds = dispatch_summary(doc)
    out.append("")
    out.append(f"== dispatch decisions ({ds['events']} events, "
               f"{ds['tune_cache_hits']} tune-cache hits) ==")
    for k in sorted(ds["by_choice"]):
        out.append(f"  {k:<40s} x{ds['by_choice'][k]}")
    for k in sorted(ds["by_source"]):
        out.append(f"  {k:<40s} x{ds['by_source'][k]}")
    out.append("")
    out.append("== top spans by total duration ==")
    ts = top_spans(doc)
    if ts:
        out.append(_fmt_table(
            ["span", "count", "total_us", "max_us"],
            [[s["name"], str(s["count"]), f"{s['total_us']:.1f}",
              f"{s['max_us']:.1f}"] for s in ts]))
    else:
        out.append("(no spans in trace)")
    sv = serving_summary(doc)
    if sv["counters"] or sv["steps"]:
        out.append("")
        out.append("== serving runtime ==")
        for k, v in sv["counters"].items():
            out.append(f"  {k:<28s} {v}")
        if sv["steps"]:
            s = sv["steps"]
            out.append(f"  serve.step: {s['steps']} steps, "
                       f"{s['total_us']:.0f}us total, mean active "
                       f"{s['mean_active']:.2f} slots, max queue "
                       f"{s['max_queue_depth']}")
    return "\n".join(out)


def main(argv=None) -> int:
    import argparse

    from repro.obs import env as obsenv

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__)
    ap.add_argument("trace", nargs="?",
                    default=obsenv.get("REPRO_OBS_TRACE")
                    or "BENCH_trace.json",
                    help="trace artifact path (default: $REPRO_OBS_TRACE "
                         "or BENCH_trace.json)")
    ap.add_argument("--top", type=int, default=10,
                    help="span rows to show")
    ap.add_argument("--serving", default="BENCH_serving.json",
                    help="serving benchmark artifact to summarize when "
                         "present (benchmarks/loadgen)")
    args = ap.parse_args(argv)
    try:
        doc = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    print(f"trace: {args.trace} "
          f"({len(doc.get('traceEvents', []))} events)")
    print(render(doc))
    try:
        with open(args.serving) as fh:
            print()
            print(render_serving_bench(json.load(fh)))
    except OSError:
        pass  # no serving artifact around — trace-only report
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
