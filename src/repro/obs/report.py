"""Render a trace artifact: MAC/µs per bit-width, dispatch summary, top
spans.

    python -m repro.obs.report [trace.json]

Reads the Chrome trace-event JSON written by `obs.export_chrome_trace`
(any instrumented CLI/benchmark run with ``REPRO_OBS=1
REPRO_OBS_TRACE=trace.json``) and prints

* **MAC/µs per bit-width** — kernel spans carry their MAC count and the
  resolved (backend, pipeline), so the table is measured throughput per
  (op, W, A, backend, pipeline) bucket, the software analogue of the
  paper's MAC/cycle-per-precision tables; packed-bytes and arithmetic
  intensity come from the op counters.
* **Dispatch summary** — how every resolution layer decided (explicit /
  plan / env / tuned / default), tune-cache hit rate, final
  backend×pipeline histogram.
* **Top spans** — where the wall-clock went, by total span duration.

The path defaults to ``REPRO_OBS_TRACE`` then ``BENCH_trace.json``.
Dependency-free (stdlib only): runs anywhere the JSON artifact lands,
no jax required.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict
from typing import Any, Dict, List


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as fh:
        doc = json.load(fh)
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace-event object "
                         "(no 'traceEvents' key)")
    return doc


def _fmt_table(headers: List[str], rows: List[List[str]]) -> str:
    rows = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    out = [line(headers), line(["-" * w for w in widths])]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


def kernel_spans(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    return [e for e in doc.get("traceEvents", [])
            if e.get("ph") == "X" and e.get("cat") == "kernel"]


def mac_table(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Measured MAC/µs per (op, w_bits, a_bits, backend, pipeline), from
    kernel spans; packed bytes joined in from the op counters."""
    agg: Dict[tuple, Dict[str, float]] = defaultdict(
        lambda: {"calls": 0, "macs": 0.0, "us": 0.0})
    for e in kernel_spans(doc):
        a = e.get("args", {})
        k = (a.get("op") or e.get("name"), a.get("w_bits"),
             a.get("a_bits"), a.get("backend"), a.get("pipeline"))
        agg[k]["calls"] += 1
        agg[k]["macs"] += a.get("macs") or 0
        agg[k]["us"] += e.get("dur", 0.0)
    packed = {}
    for key, c in doc.get("repro", {}).get("op_counters", {}).items():
        op, bits, backend, pipeline = key.split("|")
        w, a = bits[1:].split("a")
        packed[(op, int(w), int(a), backend, pipeline)] = c
    rows = []
    for k in sorted(agg, key=lambda t: tuple(str(v) for v in t)):
        op, w, a, backend, pipeline = k
        v = agg[k]
        c = packed.get(k, {})
        pb = c.get("packed_bytes")
        rows.append({
            "op": op, "w_bits": w, "a_bits": a, "backend": backend,
            "pipeline": pipeline, "calls": v["calls"],
            "macs": int(v["macs"]), "us": v["us"],
            "macs_per_us": v["macs"] / v["us"] if v["us"] else 0.0,
            "packed_bytes": pb,
            "intensity": (int(v["macs"]) / pb if pb else None)})
    return rows


def dispatch_summary(doc: Dict[str, Any]) -> Dict[str, Any]:
    log = doc.get("repro", {}).get("dispatch", [])
    by_choice: Dict[str, int] = defaultdict(int)
    by_source: Dict[str, int] = defaultdict(int)
    hits = 0
    for d in log:
        by_choice[f"{d.get('op')}:{d.get('backend')}"
                  f"/{d.get('pipeline')}"] += 1
        by_source[f"backend<-{d.get('backend_source')}"] += 1
        by_source[f"pipeline<-{d.get('pipeline_source')}"] += 1
        hits += bool(d.get("tune_cache_hit"))
    return {"events": len(log), "tune_cache_hits": hits,
            "by_choice": dict(by_choice), "by_source": dict(by_source)}


def top_spans(doc: Dict[str, Any], n: int = 10) -> List[Dict[str, Any]]:
    agg: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "max_us": 0.0})
    for e in doc.get("traceEvents", []):
        if e.get("ph") != "X":
            continue
        s = agg[e["name"]]
        s["count"] += 1
        s["total_us"] += e.get("dur", 0.0)
        s["max_us"] = max(s["max_us"], e.get("dur", 0.0))
    ranked = sorted(agg.items(), key=lambda kv: -kv[1]["total_us"])[:n]
    return [dict(name=k, **v) for k, v in ranked]


def render(doc: Dict[str, Any]) -> str:
    out = []
    rows = mac_table(doc)
    out.append("== MAC/us per bit-width (measured, from kernel spans) ==")
    if rows:
        out.append(_fmt_table(
            ["op", "W", "A", "backend", "pipeline", "calls", "MMACs",
             "us", "MAC/us", "packed_KiB", "MAC/byte"],
            [[r["op"], str(r["w_bits"]), str(r["a_bits"]), r["backend"],
              r["pipeline"], str(r["calls"]), f"{r['macs'] / 1e6:.2f}",
              f"{r['us']:.1f}", f"{r['macs_per_us']:.1f}",
              "-" if r["packed_bytes"] is None
              else f"{r['packed_bytes'] / 1024:.1f}",
              "-" if r["intensity"] is None else f"{r['intensity']:.2f}"]
             for r in rows]))
    else:
        out.append("(no kernel spans in trace)")
    ds = dispatch_summary(doc)
    out.append("")
    out.append(f"== dispatch decisions ({ds['events']} events, "
               f"{ds['tune_cache_hits']} tune-cache hits) ==")
    for k in sorted(ds["by_choice"]):
        out.append(f"  {k:<40s} x{ds['by_choice'][k]}")
    for k in sorted(ds["by_source"]):
        out.append(f"  {k:<40s} x{ds['by_source'][k]}")
    out.append("")
    out.append("== top spans by total duration ==")
    ts = top_spans(doc)
    if ts:
        out.append(_fmt_table(
            ["span", "count", "total_us", "max_us"],
            [[s["name"], str(s["count"]), f"{s['total_us']:.1f}",
              f"{s['max_us']:.1f}"] for s in ts]))
    else:
        out.append("(no spans in trace)")
    return "\n".join(out)


def main(argv=None) -> int:
    import argparse

    from repro.obs import env as obsenv

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__)
    ap.add_argument("trace", nargs="?",
                    default=obsenv.get("REPRO_OBS_TRACE")
                    or "BENCH_trace.json",
                    help="trace artifact path (default: $REPRO_OBS_TRACE "
                         "or BENCH_trace.json)")
    ap.add_argument("--top", type=int, default=10,
                    help="span rows to show")
    args = ap.parse_args(argv)
    try:
        doc = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    print(f"trace: {args.trace} "
          f"({len(doc.get('traceEvents', []))} events)")
    print(render(doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
