"""Shared benchmark helpers: wall-clock on CPU (structure-comparative only)
+ analytic TPU-v5e projections from the dry-run cost model.

CPU wall times do NOT predict TPU throughput; each benchmark therefore also
derives the v5e roofline projection (the graded quantity) from byte/flop
counts, and CSV rows carry both.
"""
import time

import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9

# every emit() also lands here so benchmarks/run.py can dump the whole
# session as machine-readable JSON (perf-trajectory tracking in CI)
ROWS = []


def time_call(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name, us, derived="", backend="", pipeline="", frac_of_peak=None):
    """`backend` names the kernel backend (repro.kernels.api) the row
    measured, so the perf trajectory can compare backends per row.
    `pipeline` names the kernel software-pipeline mode the row ran
    (kernels/common.PIPELINE_MODES) and `frac_of_peak` is the v5e
    roofline fraction-of-peak-MACs column — both optional; rows that
    carry them are the pipelined-vs-not roofline ladder (fig8)."""
    ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                 "derived": str(derived), "backend": str(backend),
                 "pipeline": str(pipeline),
                 "frac_of_peak": (None if frac_of_peak is None
                                  else round(float(frac_of_peak), 4))})
    print(f"{name},{us:.1f},{derived},{backend},{pipeline},"
          f"{'' if frac_of_peak is None else f'{frac_of_peak:.4f}'}")
