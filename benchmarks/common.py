"""Shared benchmark helpers: wall-clock on CPU (structure-comparative only)
+ analytic TPU-v5e projections from the dry-run cost model.

CPU wall times do NOT predict TPU throughput; each benchmark therefore also
derives the v5e roofline projection (the graded quantity) from byte/flop
counts, and CSV rows carry both. Timing goes through the one shared timer
(`repro.obs.time_call`); `counted_time_call` additionally reads the
software performance counters (`repro.obs.counters`) around the timed
loop so rows can carry *measured* MAC/µs and packed-bytes columns next to
the analytic projections.
"""
from repro.obs import counters as obs_counters
from repro.obs import trace as obs

PEAK_FLOPS = 197e12
HBM_BW = 819e9

# every emit() also lands here so benchmarks/run.py can dump the whole
# session as machine-readable JSON (perf-trajectory tracking in CI)
ROWS = []


def time_call(fn, *args, warmup=1, iters=3):
    """Mean µs per call — thin alias over the shared timer."""
    return obs.time_call(fn, *args, warmup=warmup, iters=iters)


def counted_time_call(fn, *args, warmup=1, iters=3):
    """Time ``fn`` and read the op counters around the loop.

    Returns ``(us_per_call, per_call_counts)`` where the counts dict has
    the mean per-call ``macs`` / ``packed_bytes`` / ``logical_bytes``
    attributed by `repro.kernels.api` (force-enabled for the duration,
    so the columns exist without ``REPRO_OBS`` in the environment).
    ``fn`` must reach the registry un-jitted — under `jax.jit` counters
    record once per compilation, not per call.
    """
    with obs.enabled_scope():
        before = obs_counters.snapshot()
        us = obs.time_call(fn, *args, warmup=warmup, iters=iters)
        after = obs_counters.snapshot()
    calls = warmup + iters
    per_call = {"macs": 0.0, "packed_bytes": 0.0, "logical_bytes": 0.0}
    for d in obs_counters.delta(after, before).values():
        for f in per_call:
            per_call[f] += d[f] / calls
    return us, per_call


def emit(name, us, derived="", backend="", pipeline="", frac_of_peak=None,
         macs_per_us=None, packed_bytes=None, segment_bits=None):
    """`backend` names the kernel backend (repro.kernels.api) the row
    measured, so the perf trajectory can compare backends per row.
    `pipeline` names the kernel software-pipeline mode the row ran
    (kernels/common.PIPELINE_MODES) and `frac_of_peak` is the v5e
    roofline fraction-of-peak-MACs column — both optional; rows that
    carry them are the pipelined-vs-not roofline ladder (fig8).
    `macs_per_us`/`packed_bytes` are the counter-measured throughput and
    per-call packed traffic (`counted_time_call`) — measured, not
    model-derived, so the roofline columns are auditable.
    `segment_bits` names the weight container widths the row's kernel
    consumed, widest first and "|"-joined (e.g. "8" uniform, "8|2"
    mixed-operand segmented) — rows that carry it are the fine-grain
    mixed-precision ladder."""
    ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                 "derived": str(derived), "backend": str(backend),
                 "pipeline": str(pipeline),
                 "frac_of_peak": (None if frac_of_peak is None
                                  else round(float(frac_of_peak), 4)),
                 "macs_per_us": (None if macs_per_us is None
                                 else round(float(macs_per_us), 2)),
                 "packed_bytes": (None if packed_bytes is None
                                  else int(packed_bytes)),
                 "segment_bits": (None if segment_bits is None
                                  else str(segment_bits))})
    print(f"{name},{us:.1f},{derived},{backend},{pipeline},"
          f"{'' if frac_of_peak is None else f'{frac_of_peak:.4f}'}")
