"""Fig. 8 analogue — measured roofline ladder of the packed GEMM kernel.

Paper: cycles per SIMD MAC for {sdotp, C&U mac&load, nn_sdotp, nn_sdotp+4x4}
at 8/4/2-bit — i.e. how close each ISA step gets the MAC unit to one useful
MAC per issue slot. TPU adaptation: for each bit-width we *measure*
`api.qdot_packed` in both pipeline modes (``off`` = grid pipeliner,
``double_buffer`` = the explicit Mac&Load analogue with manual HBM->VMEM
prefetch) and emit roofline columns:

  frac_of_peak   the v5e fraction-of-peak-MACs the mode can achieve for
                 this (shape, bits):
                   pipelined      t_roof = max(t_cmp, t_mem)  (DMA hidden)
                   not pipelined  t_serial = t_cmp + t_mem    (DMA exposed)
                 so frac = t_cmp / t_roof (resp. t_serial). The gap between
                 the two rows per bit-width is the paper's OPEF headroom —
                 what mac&load buys. On the MXU the MAC term is constant
                 across bit-widths while the packed memory term falls
                 ~linearly in bit-width, so the exposed-DMA penalty (and
                 hence the pipelining win) is *largest at 8-bit* and the
                 sub-byte modes ride closer to peak even unpipelined — the
                 memory-side dual of the paper's compute-side ladder, where
                 packing raises MACs per issue slot instead.

CPU wall time (interpret mode) rides along as us_per_call — structure-
comparative only, never TPU-predictive (see benchmarks/common.py).
"""
import numpy as np

from repro.core import packing
from repro.kernels import api, tune
from repro.obs import trace as obs
from benchmarks.common import counted_time_call, emit, PEAK_FLOPS, HBM_BW

# the kernel-family backend CI/CPU runs can execute (the real `pallas`
# backend asserts a TPU platform); rows carry it so trajectories are
# comparable per backend
BACKEND = "pallas_interpret"

# paper-class dense layer as GEMM; K a multiple of the default bk so both
# pipeline modes run the analytic tile unmodified
M, K, N = 256, 2048, 256


def roofline(bits: int, pipelined: bool, w_bytes=None):
    """(frac_of_peak, t_v5e_seconds) for the packed GEMM at ``bits``
    (activation width). ``w_bytes`` overrides the uniform-container
    weight term — segmented containers stream their exact per-run byte
    count (fine-grain mixed precision)."""
    macs = M * K * N
    t_cmp = 2 * macs / PEAK_FLOPS
    pf = packing.pack_factor(bits)
    if w_bytes is None:
        w_bytes = K * N // pf
    bytes_hbm = M * K // pf + w_bytes + M * N      # packed x + w, int8 out
    t_mem = bytes_hbm / HBM_BW
    t = max(t_cmp, t_mem) if pipelined else t_cmp + t_mem
    return t_cmp / t, t


def _mk_mixed_artifact(rng):
    """Half-W8 / half-W2 segmented weights at the fig8 GEMM shape — the
    mixed-operand kernel point of the ladder."""
    from repro.core.packing import SegmentMap
    from repro.core.quantize import quantize_linear_segmented

    segmap = SegmentMap(((0, N // 2, 8), (N // 2, N, 2)))
    w_hat = np.zeros((K, N), np.int8)
    for s, e, b in segmap.runs:
        lo, hi = packing.int_range(b, True)
        w_hat[:, s:e] = rng.integers(lo, hi + 1, size=(K, e - s))
    params = quantize_linear_segmented(
        w_hat, segmap,
        rng.integers(-127, 128, size=(N,)).astype(np.int32),
        rng.integers(-2**18, 2**18, size=(N,)).astype(np.int32),
        rng.integers(0, 2**15, size=(N,)).astype(np.int32),
        a_bits=8, a_signed=True, d=18, out_bits=8)
    x = rng.integers(-128, 128, size=(M, K)).astype(np.int8)
    return params, packing.pack(x, 8, axis=-1)


def main():
    rng = np.random.default_rng(0)
    for bits in (8, 4, 2):
        params, xp = tune._mk_qdot_artifact(rng, M, K, N, bits, bits)
        for pipe in ("off", "double_buffer"):
            us, counts = counted_time_call(
                lambda p=params, x=xp, pl=pipe: api.qdot_packed(
                    p, x, backend=BACKEND, pipeline=pl),
                warmup=1, iters=2)
            frac, t_v5e = roofline(bits, pipelined=(pipe == "double_buffer"))
            emit(f"fig8_{bits}bit_{pipe}", us,
                 f"v5e_us={t_v5e * 1e6:.3f};macs={M * K * N}",
                 backend=BACKEND, pipeline=pipe, frac_of_peak=frac,
                 macs_per_us=counts["macs"] / us,
                 packed_bytes=counts["packed_bytes"],
                 segment_bits=str(bits))
    # mixed-operand point: same shape, weights half W8 / half W2 — the
    # per-N-tile unpack-width switch rides the same roofline with the
    # weight term at the segmented containers' exact byte count
    params, xp = _mk_mixed_artifact(rng)
    w_bytes = params.segmap.packed_bytes(params.k_logical)
    for pipe in ("off", "double_buffer"):
        us, counts = counted_time_call(
            lambda p=params, x=xp, pl=pipe: api.qdot_packed(
                p, x, backend=BACKEND, pipeline=pl),
            warmup=1, iters=2)
        frac, t_v5e = roofline(8, pipelined=(pipe == "double_buffer"),
                               w_bytes=w_bytes)
        emit(f"fig8_w8w2_{pipe}", us,
             f"v5e_us={t_v5e * 1e6:.3f};macs={M * K * N};"
             f"w_bytes={w_bytes}",
             backend=BACKEND, pipeline=pipe, frac_of_peak=frac,
             macs_per_us=counts["macs"] / us,
             packed_bytes=counts["packed_bytes"], segment_bits="8|2")


if __name__ == "__main__":
    main()
    obs.export_if_configured("BENCH_trace.json")
