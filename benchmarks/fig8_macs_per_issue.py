"""Fig. 8 analogue — inverse efficiency ladder of the MatMul kernel.

Paper: cycles per SIMD MAC for {sdotp, C&U mac&load, nn_sdotp, nn_sdotp+4x4}
at 8/4/2-bit. TPU adaptation: effective int8-MACs per byte of HBM traffic
(arithmetic intensity) and VMEM working set for the packed GEMM across the
same ladder:
  baseline   — unpack weights in HBM first (no ISA support: the XpulpV2
               8-bit core emulating sub-byte, paper's baseline)
  packed     — unpack-in-kernel (XpulpNN sdotp)
  fused      — + fused BN/requant epilogue (removes the separate
               quantization pass = mac&load removing non-MAC issue slots)
  big-tile   — + larger (bm,bn) accumulator tile (the 4x2 -> 4x4 layout)
"""
import numpy as np
import jax.numpy as jnp

from repro.core import packing
from benchmarks.common import emit, time_call, HBM_BW


def hbm_bytes(M, K, N, w_bits, a_bits, fused, out_bits):
    """HBM traffic model for one GEMM tile pass (weights dominate)."""
    pf_w, pf_a = 8 // w_bits, 8 // a_bits
    w = K * N // pf_w
    x = M * K // pf_a
    inter = 0 if fused else M * N * 4 * 2  # acc out + back in for quant pass
    y = M * N // (8 // out_bits)
    return w + x + inter + y


def main():
    M, K, N = 256, 4608, 256  # the paper's 32x32 layer as GEMM
    macs = M * K * N
    for bits in (8, 4, 2):
        b0 = hbm_bytes(M, K, N, 8, 8, False, 8)      # unpacked emulation
        b1 = hbm_bytes(M, K, N, bits, bits, False, 8)
        b2 = hbm_bytes(M, K, N, bits, bits, True, bits)
        # big-tile: halves activation re-reads when N tiles > 1; model as
        # x read once instead of N/bn times (bn 128 -> 512)
        reread = (N // 128 - 1) * (M * K // (8 // bits))
        b3 = b2  # big tile already counted once; baseline variants re-read
        b1 += reread
        b2 += reread
        for name, b in (("baseline_unpacked", b0 + reread),
                        ("packed_sdotp", b1), ("fused_epilogue", b2),
                        ("big_tile_4x4", b3)):
            ai = macs / b  # int-MACs per HBM byte (higher is better)
            t_us = b / HBM_BW * 1e6
            emit(f"fig8_{bits}bit_{name}", t_us, f"macs_per_byte={ai:.1f}")


if __name__ == "__main__":
    main()
