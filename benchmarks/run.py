"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived,backend,pipeline,frac_of_peak`` CSV rows
and writes the same data as machine-readable JSON (``--json``, default
``BENCH_kernels.json``: name -> us_per_call, plus the derived annotations
under "derived", the kernel backend measured under "backend", the kernel
pipeline mode under "pipeline", the v5e roofline fraction-of-peak
column under "frac_of_peak", and the counter-measured "macs_per_us" /
"packed_bytes" columns from `benchmarks.common.counted_time_call`) so CI
can archive the perf trajectory run over run and compare
backends/pipeline modes per row. (Block-shape autotuning has its own
CLI: ``python -m repro.kernels.tune``.)

With ``REPRO_OBS=1`` the session additionally exports a Chrome
trace-event artifact (``REPRO_OBS_TRACE`` path, default
``BENCH_trace.json``) carrying kernel spans, per-bit-width MAC counters,
and the dispatch decision log — render it with
``python -m repro.obs.report``.
"""
import argparse
import json

from benchmarks import (common, fig8_macs_per_issue, fig9_cluster_scaling,
                        fig11_conv_layers, fig13_sota_comparison,
                        table1_envelope)
from repro.obs import trace as obs


def payload_from_rows(rows) -> dict:
    """The BENCH_kernels.json shape (pinned by benchmarks/schema.py)."""
    return {
        "us_per_call": {r["name"]: r["us_per_call"] for r in rows},
        "derived": {r["name"]: r["derived"] for r in rows
                    if r["derived"]},
        "backend": {r["name"]: r["backend"] for r in rows
                    if r.get("backend")},
        "pipeline": {r["name"]: r["pipeline"] for r in rows
                     if r.get("pipeline")},
        "frac_of_peak": {r["name"]: r["frac_of_peak"] for r in rows
                         if r.get("frac_of_peak") is not None},
        "macs_per_us": {r["name"]: r["macs_per_us"] for r in rows
                        if r.get("macs_per_us") is not None},
        "packed_bytes": {r["name"]: r["packed_bytes"] for r in rows
                         if r.get("packed_bytes") is not None},
        "segment_bits": {r["name"]: r["segment_bits"] for r in rows
                         if r.get("segment_bits") is not None},
    }


def main(json_path: str = "BENCH_kernels.json") -> None:
    print("name,us_per_call,derived,backend,pipeline,frac_of_peak")
    fig8_macs_per_issue.main()
    fig9_cluster_scaling.main()
    fig11_conv_layers.main()
    fig13_sota_comparison.main()
    table1_envelope.main()
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload_from_rows(common.ROWS), f, indent=2,
                      sort_keys=True)
        print(f"# wrote {len(common.ROWS)} rows -> {json_path}")
    trace_path = obs.export_if_configured("BENCH_trace.json")
    if trace_path:
        print(f"# wrote trace -> {trace_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="output path for the JSON rows ('' disables)")
    args = ap.parse_args()
    main(args.json)
