"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from benchmarks import (fig8_macs_per_issue, fig9_cluster_scaling,
                        fig11_conv_layers, fig13_sota_comparison,
                        table1_envelope)


def main() -> None:
    print("name,us_per_call,derived")
    fig8_macs_per_issue.main()
    fig9_cluster_scaling.main()
    fig11_conv_layers.main()
    fig13_sota_comparison.main()
    table1_envelope.main()


if __name__ == "__main__":
    main()
