"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived,backend`` CSV rows and writes the same
data as machine-readable JSON (``--json``, default ``BENCH_kernels.json``:
name -> us_per_call, plus the derived annotations under "derived" and the
kernel backend measured under "backend") so CI can archive the perf
trajectory run over run and compare backends per row. (Block-shape
autotuning has its own CLI: ``python -m repro.kernels.tune``.)
"""
import argparse
import json

from benchmarks import (common, fig8_macs_per_issue, fig9_cluster_scaling,
                        fig11_conv_layers, fig13_sota_comparison,
                        table1_envelope)


def main(json_path: str = "BENCH_kernels.json") -> None:
    print("name,us_per_call,derived,backend")
    fig8_macs_per_issue.main()
    fig9_cluster_scaling.main()
    fig11_conv_layers.main()
    fig13_sota_comparison.main()
    table1_envelope.main()
    if json_path:
        payload = {
            "us_per_call": {r["name"]: r["us_per_call"]
                            for r in common.ROWS},
            "derived": {r["name"]: r["derived"] for r in common.ROWS
                        if r["derived"]},
            "backend": {r["name"]: r["backend"] for r in common.ROWS
                        if r.get("backend")},
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {len(common.ROWS)} rows -> {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="output path for the JSON rows ('' disables)")
    args = ap.parse_args()
    main(args.json)
