"""Table I analogue — throughput/efficiency envelope of this framework on
v5e for the paper-shaped workloads (GOP/s per chip at the roofline bound).
"""
from benchmarks.common import emit, HBM_BW, PEAK_FLOPS


def main():
    # two operating points like Table I: compute-bound (the paper conv
    # layer: quantization does NOT speed up compute-bound work on an
    # int8-fixed MXU — an honest difference from the issue-bound MCU) and
    # memory-bound (per-chip decode GEMM: sub-byte pays off fully)
    for regime, (M, K, N) in (("conv_computebound", (256, 4608, 256)),
                              ("decode_membound", (32, 4096, 1024))):
        ops = 2 * M * K * N
        for bits in (8, 4, 2):
            b = (K * N + M * K) * bits // 8 + M * N
            t = max(ops / PEAK_FLOPS, b / HBM_BW)
            emit(f"table1_{regime}_{bits}bit", t * 1e6,
                 f"gops_per_chip={ops/t/1e9:.0f}")


if __name__ == "__main__":
    main()
