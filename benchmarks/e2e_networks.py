"""Network-level QNN benchmark — the paper's fig. 11 story composed into
whole CNNs (BENCH_e2e.json).

The paper's headline is network-level: conv layers at W{8,4,2} composed
into full QNNs running on the parallel cluster. This benchmark runs the
two paper-class networks of `repro.vision` (MobileNetV1-style
depthwise-separable, MLPerf-Tiny-style ResNet-8) end to end as integer
images — per-layer wall time at one device, whole-network wall time
across 1..8-device meshes (images data-parallel, the serving analogue of
fig. 9), at uniform W8/W4/W2 plus the planner-produced mixed plan, per
kernel backend. Mesh results are asserted bit-exact against the
single-device forward before timing (the registry's psum-free
construction). CPU wall time is structure-comparative only; total rows
carry the analytic v5e roofline projection alongside (benchmarks/common).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m benchmarks.e2e_networks --json BENCH_e2e.json
"""
import argparse
import json
import os
import sys

# must precede the first jax import to materialize host-platform devices
if "jax" not in sys.modules and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, PEAK_FLOPS, emit, time_call
from repro.deploy.calibrate import calibrate_vision
from repro.deploy.planner import auto_budget, plan_mixed_precision
from repro.vision.configs import get_vision_config
from repro.vision.models import (forward_int, init_fp, quantize_input,
                                 quantize_net, streamed_weight_bytes,
                                 trace_shapes)

BATCH = 8


def _layer_macs(t) -> int:
    """MACs per image for one traced layer (0 for pool/add)."""
    L, (h, w, c), (oh, ow, oc) = t["layer"], t["in"], t["out"]
    if L.kind == "conv":
        return oh * ow * oc * L.fh * L.fw * c
    if L.kind == "dwconv":
        return oh * ow * c * L.fh * L.fw
    if L.kind == "linear":
        return c * L.cout
    return 0


def _quantized_nets(cfg, fp_params, bits_sweep, rng, backend):
    """(tag, qnet) per sweep point: uniform W{b} plus the planner plan."""
    stats, absmax = calibrate_vision(
        cfg, fp_params,
        [rng.uniform(0, 1, (4, *cfg.in_hw, cfg.in_ch)).astype(np.float32)])
    out = [(str(b), quantize_net(cfg, fp_params, absmax, default_w_bits=b,
                                 backend=backend))
           for b in bits_sweep]
    plan = plan_mixed_precision(stats, auto_budget(stats), backend=backend)
    out.append(("mixed", quantize_net(cfg, fp_params, absmax, plan=plan,
                                      backend=backend)))
    return out


def _per_layer_rows(net, tag, qnet, x_hat, backend, rows):
    """Time each layer on its real intermediate input (1 device)."""
    trace = {t["layer"].path: t for t in trace_shapes(qnet.cfg)}
    stream, edges = x_hat, {}
    for L, q in qnet.qlayers:
        xin = edges[L.input_from] if L.input_from else stream
        if L.kind in ("conv", "dwconv", "linear"):
            fn = jax.jit(lambda v, q=q: q.apply(v, backend=backend))
            args = (xin,)
        elif L.kind == "add":
            fn = jax.jit(lambda a, b, q=q: q.apply(a, b))
            args = (xin, edges[L.skip_from])
        else:
            fn = jax.jit(lambda v, q=q: q.apply(v))
            args = (xin,)
        us = time_call(fn, *args)
        macs = _layer_macs(trace[L.path])
        rows.append({"name": f"e2e_{net}_{tag}_{L.path}_dev1",
                     "net": net, "layer": L.path, "bits": tag,
                     "devices": 1, "us_per_call": round(float(us), 1),
                     "macs_per_image": macs})
        emit(f"e2e_{net}_{tag}_{L.path}_dev1", us,
             f"macs={macs}", backend or "default")
        y = fn(*args)
        if L.save_as:
            edges[L.save_as] = y
        if not L.branch:
            stream = y


def _lm_planner_rows(rows, rng, backend):
    """Fine-grain vs per-layer planner rows on the LM dense path.

    The vision nets quantize per tensor (no segment support), so the
    fine-grain comparison runs on the transformer zoo's smoke LM — the
    one forward whose dense path consumes `PlanRule.segments` end to
    end. The smoke config is widened to d_ff=384 so the MLP projections
    span 3 channel groups (d_out=128 would degenerate to one group and
    the best-of-both planner would return the layer plan verbatim).
    Both plans run at the SAME auto budget; the row pair's
    bytes_streamed delta is the fine-grain packing win."""
    import dataclasses

    from repro.configs.qwen2p5_3b import smoke_config
    from repro.deploy.apply import (apply_plan, dense_inventory,
                                    quantized_dense_paths)
    from repro.deploy.calibrate import calibrate
    from repro.models.api import Model
    from repro.nn.layers import QuantConfig

    cfg = dataclasses.replace(smoke_config(), d_model=128, d_ff=384)
    fp = Model(cfg)
    fp_params = fp.init(jax.random.PRNGKey(0))
    seq = 16
    batches = [rng.integers(2, cfg.vocab, size=(2, seq)).astype(np.int32)]
    stats = calibrate(fp, fp_params, batches)
    # a tight budget is where granularity pays: whole-layer demotions bust
    # it, channel-group demotions fit (frac=0.5 admits every whole-layer
    # move and the plans converge)
    budget = auto_budget(stats, frac=0.12)
    plans = [("planner-layer",
              plan_mixed_precision(stats, budget, backend=backend,
                                   granularity="layer")),
             ("planner-fine",
              plan_mixed_precision(stats, budget, backend=backend,
                                   granularity="channel_group"))]
    qint = QuantConfig(mode="int", w_bits=8, a_bits=8)
    q0 = Model(dataclasses.replace(cfg, quant=qint))
    inv = dense_inventory(fp_params, quantized_dense_paths(q0.defs()))
    macs = sum(L * k * n for (L, k, n) in inv.values()) * seq
    toks = jnp.asarray(batches[0])
    for tag, plan in plans:
        q = Model(dataclasses.replace(cfg, quant=qint, quant_plan=plan))
        q_params = apply_plan(q.init(jax.random.PRNGKey(0)), fp_params, plan)
        fn = jax.jit(lambda p, t, q=q: q.forward(p, {"tokens": t})[0])
        us = time_call(fn, q_params, toks)
        packed_b = plan.meta["packed_weight_bytes"]
        n_seg = sum(1 for r in plan.rules if r.segments is not None)
        rows.append({"name": f"e2e_qwen-smoke_{tag}_total_dev1",
                     "net": "qwen-smoke", "layer": "total", "bits": tag,
                     "devices": 1, "us_per_call": round(float(us), 1),
                     "macs_per_image": macs, "bytes_streamed": packed_b})
        emit(f"e2e_qwen-smoke_{tag}_total_dev1", us,
             f"bytes={packed_b};segmented_rules={n_seg};macs={macs}",
             backend or "default")


def main(nets=("mobilenet-tiny", "resnet8"), bits_sweep=(8, 4, 2),
         devices=None, backend=None, json_path="BENCH_e2e.json",
         smoke=False, per_layer=True, lm_planner=True):
    avail = len(jax.devices())
    if devices is None:
        devices = [d for d in (1, 2, 4, 8) if d <= avail]
    rng = np.random.default_rng(0)
    rows = []
    for net in nets:
        cfg = get_vision_config(net, smoke=smoke)
        fp_params = init_fp(cfg, seed=0)
        total_macs = sum(_layer_macs(t) for t in trace_shapes(cfg))
        images = rng.uniform(0, 1, (BATCH, *cfg.in_hw, cfg.in_ch)
                             ).astype(np.float32)
        for tag, qnet in _quantized_nets(cfg, fp_params, bits_sweep, rng,
                                         backend):
            x_hat = quantize_input(qnet, images)
            if per_layer:
                _per_layer_rows(net, tag, qnet, x_hat, backend, rows)
            ref = np.asarray(forward_int(qnet, x_hat, backend=backend))
            # memory-roofline term: bytes one forward streams (the qdot
            # route's packed weights + epilogue vectors), NOT the full
            # artifact — which materializes both depthwise lowerings
            packed_b = streamed_weight_bytes(qnet)
            measured = []
            for n_dev in devices:
                if n_dev > avail:
                    print(f"# e2e: skipping {n_dev} devices "
                          f"(only {avail} available)")
                    continue
                mesh = (None if n_dev == 1 else jax.make_mesh(
                    (n_dev, 1), ("data", "model"),
                    devices=jax.devices()[:n_dev]))
                fn = jax.jit(lambda xh, q=qnet, m=mesh: forward_int(
                    q, xh, backend=backend, mesh=m))
                got = np.asarray(fn(x_hat))
                assert np.array_equal(got, ref), \
                    f"{net} {tag}: mesh result diverged at {n_dev} devices"
                measured.append((n_dev, time_call(fn, x_hat)))
            if not measured:
                continue
            base_us = min(measured)[1]
            for n_dev, us in measured:
                speedup = base_us / us if us > 0 else float("nan")
                flops = 2 * total_macs * BATCH / n_dev
                t_proj = max(flops / PEAK_FLOPS, packed_b / HBM_BW)
                rows.append({
                    "name": f"e2e_{net}_{tag}_total_dev{n_dev}",
                    "net": net, "layer": "total", "bits": tag,
                    "devices": n_dev,
                    "us_per_call": round(float(us), 1),
                    "speedup": round(float(speedup), 3),
                    "efficiency": round(float(speedup) / n_dev, 3),
                    "macs_per_image": total_macs,
                    "bytes_streamed": packed_b,
                    "proj_us_v5e": round(t_proj * 1e6, 3)})
                emit(f"e2e_{net}_{tag}_total_dev{n_dev}", us,
                     f"speedup={speedup:.2f};bytes={packed_b};"
                     f"proj_us_v5e={t_proj * 1e6:.3f}",
                     backend or "default")
    if lm_planner:
        _lm_planner_rows(rows, rng, backend)
    if json_path and rows:
        payload = {"version": 1, "batch": BATCH,
                   "path": "repro.vision.models.forward_int",
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(rows)} rows -> {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--nets", default="mobilenet-tiny,resnet8")
    ap.add_argument("--bits", default="8,4,2",
                    help="uniform w_bits sweep (the planner-mixed point "
                         "always runs)")
    ap.add_argument("--devices", default=None,
                    help="comma-separated mesh sizes (default: 1,2,4,8 "
                         "capped at available)")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--json", default="BENCH_e2e.json")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-size nets (CI/laptop)")
    ap.add_argument("--no-per-layer", action="store_true")
    ap.add_argument("--no-lm-planner", action="store_true",
                    help="skip the transformer fine-grain vs per-layer "
                         "planner rows")
    args = ap.parse_args()
    main(nets=tuple(args.nets.split(",")),
         bits_sweep=tuple(int(b) for b in args.bits.split(",")),
         devices=(None if args.devices is None else
                  [int(v) for v in args.devices.split(",")]),
         backend=args.backend, json_path=args.json, smoke=args.smoke,
         per_layer=not args.no_per_layer,
         lm_planner=not args.no_lm_planner)
