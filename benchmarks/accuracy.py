"""Accuracy-vs-packed-bytes Pareto benchmark (BENCH_accuracy.json).

The perf benchmarks (fig11/e2e) price W{8,4,2} in cycles and bytes; this
one prices them in task accuracy — the axis that decides whether a
narrow deployment is *usable*. Every row is an end-to-end artifact:
trained (float or fake-quant QAT, `repro.qat`), calibrated, folded by
`vision.models.quantize_net`, and evaluated on the **integer path**
(`forward_int` — the same eq. 1-4 arithmetic the kernels execute), never
on a float proxy. Bytes are `streamed_weight_bytes` of the deployed
artifact (what one forward actually reads).

The grid, on the hermetic seeded digits (`repro.qat.data`):

  float            fp32 reference (forward_fp accuracy, 4-byte weights)
  ptq  uniform     post-training quantization of the float model, W8/4/2
  qat  uniform     fake-quant fine-tune at W8/4/2, then fold
  ptq/qat layer    task-loss-calibrated per-layer mixed plan
  ptq/qat channel_group   same budget, CHUNK-wide channel-group segments

The plans come from `calibrate_vision(sensitivity="task_loss")` (per-
layer and per-group cross-entropy degradation on labeled batches) fed to
the unchanged `plan_mixed_precision` knapsack at one shared budget — so
the layer/fine comparison isolates granularity, nothing else.

Acceptance (full mode; reproduced claims, recomputed by the schema
validator from the rows):
  * QAT accuracy >= PTQ accuracy at W4 and at W2 (uniform rows);
  * every plan row sits on the Pareto frontier of its mode's uniform
    rows (no uniform row with <= bytes and >= accuracy, one strict);
  * the channel-group plan dominates-or-matches the per-layer plan at
    the same budget: <= bytes AND >= accuracy.

    PYTHONPATH=src python -m benchmarks.accuracy --json BENCH_accuracy.json
"""
import argparse
import json

import jax
import numpy as np

from repro.deploy.calibrate import calibrate_vision
from repro.deploy.planner import auto_budget, plan_mixed_precision
from repro.qat.data import SyntheticDigits
from repro.qat.evaluate import deploy, evaluate_int
from repro.qat.train import QATConfig, train_qat
from repro.vision.configs import get_vision_config
from repro.vision.models import forward_fp, streamed_weight_bytes

CANDIDATES = (8, 4, 2)
BUDGET_FRAC = 0.35      # admits partial demotion: the granularity story
NOISE, JITTER = 0.45, 3  # hard enough that W4 PTQ measurably degrades

# per-width fine-tune recipes (from the float init; ternary needs the
# long schedule — the W2 loss landscape is a code-flipping search)
FT = {8: dict(steps=250, lr=5e-3, warmup=10),
      4: dict(steps=250, lr=5e-3, warmup=10),
      2: dict(steps=600, lr=1e-2, warmup=30)}
FT_PLAN = dict(steps=400, lr=5e-3, warmup=20)
FLOAT_STEPS = 600
SMOKE_SCALE = 6          # smoke mode divides every step count by this


def _evaluate_float(cfg, params, batches):
    correct = n = 0
    for x, y in batches:
        logits = forward_fp(cfg, params, np.asarray(x, np.float32))
        pred = np.asarray(np.argmax(np.asarray(logits), axis=-1))
        correct += int((pred == np.asarray(y)).sum())
        n += len(y)
    return {"accuracy": correct / max(n, 1), "correct": correct, "n": n}


def _row(name, mode, plan, w_bits, ev, bytes_, steps, segmented):
    print(f"# {name}: acc={ev['accuracy']:.4f} bytes={bytes_} "
          f"({ev['correct']}/{ev['n']})")
    return {"name": name, "mode": mode, "plan": plan, "w_bits": w_bits,
            "accuracy": round(float(ev["accuracy"]), 6),
            "correct": int(ev["correct"]), "n": int(ev["n"]),
            "packed_weight_bytes": int(bytes_),
            "train_steps": int(steps), "segmented_rules": int(segmented)}


def _n_segmented(plan):
    return sum(1 for r in plan.rules if r.segments is not None)


def _frontier_ok(rows, mode):
    """Plan rows not strictly dominated by same-mode uniform rows."""
    uni = [r for r in rows if r["mode"] == mode and r["plan"] == "uniform"]
    ok = True
    for r in rows:
        if r["mode"] != mode or r["plan"] == "uniform":
            continue
        for u in uni:
            le_b = u["packed_weight_bytes"] <= r["packed_weight_bytes"]
            ge_a = u["accuracy"] >= r["accuracy"]
            strict = (u["packed_weight_bytes"] < r["packed_weight_bytes"]
                      or u["accuracy"] > r["accuracy"])
            if le_b and ge_a and strict:
                print(f"# FRONTIER FAIL: {u['name']} dominates {r['name']}")
                ok = False
    return ok


def compute_acceptance(rows):
    """The reproduced claims, from the rows alone (the schema validator
    runs this same reduction — the JSON can't assert what its rows
    don't show)."""
    def one(pred):
        got = [r for r in rows if pred(r)]
        return got[0] if got else None

    acc = {}
    for b in (4, 2):
        q = one(lambda r, b=b: r["mode"] == "qat"
                and r["plan"] == "uniform" and r["w_bits"] == b)
        p = one(lambda r, b=b: r["mode"] == "ptq"
                and r["plan"] == "uniform" and r["w_bits"] == b)
        acc[f"qat_ge_ptq_w{b}"] = bool(
            q and p and q["accuracy"] >= p["accuracy"])
    acc["plans_on_frontier"] = bool(
        _frontier_ok(rows, "ptq") and _frontier_ok(rows, "qat"))
    fine = one(lambda r: r["mode"] == "qat" and r["plan"] == "channel_group")
    layer = one(lambda r: r["mode"] == "qat" and r["plan"] == "layer")
    acc["fine_dominates_layer"] = bool(
        fine and layer
        and fine["packed_weight_bytes"] <= layer["packed_weight_bytes"]
        and fine["accuracy"] >= layer["accuracy"])
    acc["all"] = all(acc.values())
    return acc


def main(json_path="BENCH_accuracy.json", smoke=False, backend=None):
    div = SMOKE_SCALE if smoke else 1
    cfg = get_vision_config("qat-cnn", smoke=smoke)
    data = SyntheticDigits(split="train", seed=0, noise=NOISE, jitter=JITTER)
    test = SyntheticDigits(split="test", seed=0, noise=NOISE, jitter=JITTER)
    eval_batches = lambda: test.batches(100, 10)
    rows = []

    # ---- float reference (also the PTQ source and every QAT init) ----
    qc_f = QATConfig(steps=FLOAT_STEPS // div, batch=64, w_bits=None,
                     log_every=max(FLOAT_STEPS // div // 4, 1), seed=0)
    res_f = train_qat(cfg, data, qc_f)
    fp32_bytes = 4 * sum(
        int(np.prod(np.asarray(l).shape))
        for l in jax.tree.leaves(res_f.model_params()))
    rows.append(_row("float", "float", "none", 32,
                     _evaluate_float(cfg, res_f.model_params(),
                                     eval_batches()),
                     fp32_bytes, qc_f.steps, 0))

    # ---- uniform rows: PTQ fold vs QAT fine-tune, per width ----
    for b in CANDIDATES:
        qn = deploy(res_f, default_w_bits=b, backend=backend)
        rows.append(_row(f"ptq_w{b}", "ptq", "uniform", b,
                         evaluate_int(qn, eval_batches(), backend=backend),
                         streamed_weight_bytes(qn), qc_f.steps, 0))
        ft = FT[b]
        qc = QATConfig(steps=ft["steps"] // div, batch=64, lr=ft["lr"],
                       warmup=max(ft["warmup"] // div, 1), w_bits=b,
                       log_every=max(ft["steps"] // div // 2, 1), seed=0)
        res = train_qat(cfg, data, qc, init_params=res_f.params)
        qn = deploy(res, backend=backend)
        rows.append(_row(f"qat_w{b}", "qat", "uniform", b,
                         evaluate_int(qn, eval_batches(), backend=backend),
                         streamed_weight_bytes(qn), qc.steps, 0))

    # ---- task-loss plans at one shared budget ----
    xs, ys = [], []
    for x, y in data.batches(64, 4):
        xs.append(np.asarray(x))
        ys.append(np.asarray(y))
    stats, _ = calibrate_vision(cfg, res_f.model_params(), xs,
                                sensitivity="task_loss", labels=ys)
    budget = auto_budget(stats, CANDIDATES, frac=BUDGET_FRAC)
    print(f"# task-loss budget (frac={BUDGET_FRAC}): {budget:.4f}")
    for gran in ("layer", "channel_group"):
        plan = plan_mixed_precision(
            stats, budget, candidates=CANDIDATES, a_bits=cfg.a_bits,
            backend=backend, meta={"source": "task_loss"},
            granularity=gran)
        widths = {r.pattern: r.w_bits for r in plan.rules}
        print(f"# plan[{gran}]: {widths} "
              f"segmented_rules={_n_segmented(plan)}")
        qn = deploy(res_f, plan=plan, backend=backend)
        rows.append(_row(f"ptq_plan_{gran}", "ptq", gran, 0,
                         evaluate_int(qn, eval_batches(), backend=backend),
                         streamed_weight_bytes(qn), qc_f.steps,
                         _n_segmented(plan)))
        qc = QATConfig(steps=FT_PLAN["steps"] // div, batch=64,
                       lr=FT_PLAN["lr"],
                       warmup=max(FT_PLAN["warmup"] // div, 1),
                       log_every=max(FT_PLAN["steps"] // div // 2, 1),
                       seed=0)
        res = train_qat(cfg, data, qc, init_params=res_f.params, plan=plan)
        qn = deploy(res, backend=backend)
        rows.append(_row(f"qat_plan_{gran}", "qat", gran, 0,
                         evaluate_int(qn, eval_batches(), backend=backend),
                         streamed_weight_bytes(qn), qc.steps,
                         _n_segmented(plan)))

    accept = compute_acceptance(rows)
    print(f"# acceptance: {accept}")
    payload = {"version": 1, "net": cfg.name,
               "mode": "smoke" if smoke else "full",
               "dataset": {"name": "synthetic-digits", "noise": NOISE,
                           "jitter": JITTER, "seed": 0,
                           "eval_images": rows[0]["n"]},
               "budget_frac": BUDGET_FRAC,
               "path": "repro.vision.models.forward_int",
               "rows": rows, "acceptance": accept}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(rows)} rows -> {json_path}")
    if not smoke and not accept["all"]:
        raise SystemExit("# acceptance FAILED")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_accuracy.json")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-size net + 1/6 step counts; acceptance "
                         "reported but not enforced")
    ap.add_argument("--backend", default=None)
    args = ap.parse_args()
    main(json_path=args.json, smoke=args.smoke, backend=args.backend)
