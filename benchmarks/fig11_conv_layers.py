"""Fig. 11/12 analogue — the paper's two conv layers at 8/4/2-bit,
MatMul-only vs full conv (+BN/QNT), fused-vs-explicit im2col path.

Paper layers: 16x16x32 and 32x32x32 inputs, 64x3x3x32 filters. The `_full`
rows run the fused implicit-GEMM Pallas kernel (qconv2d_fused: in-kernel
receptive-field gather, no HBM im2col tensor — the PULP-NN/Mac&Load
execution model); the `_matmul_only` rows time the packed GEMM alone on a
pre-materialized XLA im2col, isolating the gather+epilogue cost. Interpret
mode: correctness + structure; wall time on CPU is not TPU-predictive — we
report the v5e roofline projection alongside
— the projection carries the paper's headline structure: sub-byte cuts the
memory term ~linearly in bitwidth, and the fused epilogue removes the
separate quantization pass whose relative cost GROWS as bits shrink
(paper §VI-B observes exactly this).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (QuantSpec, quantize, calibrate_weight,
                        calibrate_activation)
from repro.kernels.api import qconv, qdot
from repro.kernels.qconv import quantize_conv, im2col_hwc
from repro.obs import trace as obs
from benchmarks.common import counted_time_call, emit, PEAK_FLOPS, HBM_BW

# the kernel-family backend CI/CPU runs can execute (the real `pallas`
# backend asserts a TPU platform); rows carry it so trajectories are
# comparable per backend
BACKEND = "pallas_interpret"


def run_layer(H, W, rng):
    N, Cin, Cout, F = 1, 32, 64, 3
    w = rng.normal(size=(F, F, Cin, Cout)).astype(np.float32) * 0.08
    x = np.maximum(rng.normal(size=(N, H, W, Cin)), 0).astype(np.float32)
    bn_s = rng.normal(size=(Cout,)).astype(np.float32) * 0.05 + 0.3
    bn_b = np.zeros((Cout,), np.float32)
    macs = H * W * Cout * F * F * Cin
    for bits in (8, 4, 2):
        sw = calibrate_weight(jnp.asarray(w), bits)
        sx = calibrate_activation(x, bits, 100.0)
        sy = QuantSpec.activation(bits, 8.0)
        qp = quantize_conv(jnp.asarray(w), sw, bn_s, bn_b, sx, sy, 1, 1)
        xq = quantize(jnp.asarray(x), sx)

        us_full, counts_full = counted_time_call(
            lambda xq=xq, qp=qp: qconv(qp, xq, backend=BACKEND))
        cols, ho, wo = im2col_hwc(xq, 3, 3, 1, 1)
        us_mm, counts_mm = counted_time_call(
            lambda c=cols, qp=qp: qdot(qp.gemm, c.reshape(-1, 288),
                                       backend=BACKEND))
        # v5e projection: memory-bound at these sizes
        k_pad = 384
        bytes_hbm = (k_pad * Cout * bits // 8 + H * W * k_pad * bits // 8
                     + H * W * Cout * bits // 8)
        t_mem = bytes_hbm / HBM_BW
        t_cmp = 2 * macs / PEAK_FLOPS
        emit(f"fig11_conv{H}x{W}_{bits}bit_full", us_full,
             f"v5e_us={max(t_mem,t_cmp)*1e6:.3f};macs={macs}",
             backend=BACKEND, macs_per_us=counts_full["macs"] / us_full,
             packed_bytes=counts_full["packed_bytes"])
        emit(f"fig11_conv{H}x{W}_{bits}bit_matmul_only", us_mm,
             f"v5e_mem_term_us={t_mem*1e6:.3f}", backend=BACKEND,
             macs_per_us=counts_mm["macs"] / us_mm,
             packed_bytes=counts_mm["packed_bytes"])


def main():
    rng = np.random.default_rng(0)
    run_layer(16, 16, rng)
    run_layer(32, 32, rng)


if __name__ == "__main__":
    main()
    obs.export_if_configured("BENCH_trace.json")
