"""Fig. 9 analogue — cluster scaling of the packed GEMM on a real mesh.

Paper: MAC/cycle of the 8-core PULP cluster vs single core — near-linear
1->8 speedup because each core MACs a disjoint output-channel group with
operands resident (no inter-core reduction). TPU adaptation: the **same
quantized GEMM artifact** runs through `repro.kernels.api.qdot_sharded`
on a 1..8-device mesh (one JAX device ↔ one cluster core): packed weights
tensor-parallel over the output-feature axis, int32 accumulation local
per shard, psum-free epilogue — then wall-clock per mesh size plus the
analytic per-device roofline are emitted. On CPU the devices are
host-platform slices (``--xla_force_host_platform_device_count``), so
measured wall-clock is structure-comparative; the per-device flop/byte
column carries the paper's scaling argument either way. Results are
asserted bit-exact against the single-device reference before timing.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m benchmarks.fig9_cluster_scaling \
        --devices 1,2,4,8 --json BENCH_cluster.json
"""
import argparse
import json
import os
import sys

# must precede the first jax import to materialize host-platform devices;
# a no-op when jax is already loaded (e.g. under benchmarks.run)
if "jax" not in sys.modules and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call, PEAK_FLOPS, HBM_BW
from repro.core import packing
from repro.core.quantize import QuantizedLinearParams
from repro.kernels import api
from repro.parallel.sharding import shard_packed_linear

M, K, N = 256, 4608, 512


def _artifact(bits, rng):
    """One packed GEMM deployment artifact + activation batch at `bits`."""
    lo, hi = packing.int_range(bits, True)
    w = rng.integers(lo, hi + 1, size=(K, N)).astype(np.int8)
    wp = packing.pack(jnp.asarray(w), bits, axis=0)
    params = QuantizedLinearParams(
        w_packed=wp, w_bits=bits, a_bits=bits, a_signed=False,
        kappa=jnp.asarray(rng.integers(-64, 64, (N,)).astype(np.int32)),
        lam=jnp.asarray(rng.integers(-2**12, 2**12, (N,)).astype(np.int32)),
        m=jnp.asarray(rng.integers(0, 2**15, (N,)).astype(np.int32)),
        d=18, out_bits=8, k_logical=K)
    alo, ahi = packing.int_range(bits, False)
    x = jnp.asarray(rng.integers(alo, ahi + 1, (M, K)).astype(np.int8))
    return params, x


def main(devices=None, json_path="BENCH_cluster.json", backend=None,
         bits_sweep=(8, 4, 2)):
    avail = len(jax.devices())
    if devices is None:
        devices = [d for d in (1, 2, 4, 8) if d <= avail]
    rng = np.random.default_rng(0)
    rows = []
    for bits in bits_sweep:
        params, x = _artifact(bits, rng)
        ref = np.asarray(api.qdot(params, x, backend=backend))
        measured = []
        for n_dev in devices:
            if n_dev > avail:
                print(f"# fig9: skipping {n_dev} devices "
                      f"(only {avail} available; set XLA_FLAGS="
                      f"--xla_force_host_platform_device_count={n_dev})")
                continue
            mesh = jax.make_mesh((1, n_dev), ("data", "model"),
                                 devices=jax.devices()[:n_dev])
            sharded = shard_packed_linear(params, mesh)
            # jit so timing measures the compiled sharded GEMM, not
            # per-call shard_map retracing
            fn = jax.jit(lambda xx: api.qdot(sharded, xx, mesh=mesh,
                                             backend=backend))
            assert np.array_equal(np.asarray(fn(x)), ref), \
                f"sharded result diverged at {bits}-bit x {n_dev} devices"
            measured.append((n_dev, time_call(fn, x)))
        if not measured:
            continue
        # speedup is vs the smallest measured cluster (ideally 1 device),
        # regardless of --devices ordering or skipped sizes
        base_us = min(measured)[1]
        for n_dev, us in measured:
            speedup = base_us / us if us > 0 else float("nan")
            # per-device roofline terms: weights + epilogue vectors are
            # TP-sharded (1/n), activations replicated, no collective
            flops = 2 * M * K * N / n_dev
            w_bytes = K * N * bits // 8 // n_dev
            x_bytes = M * K * bits // 8
            t_proj = max(flops / PEAK_FLOPS, (w_bytes + x_bytes) / HBM_BW)
            rows.append({
                "name": f"fig9_{bits}bit_dev{n_dev}", "bits": bits,
                "devices": n_dev, "us_per_call": round(float(us), 1),
                "speedup": round(float(speedup), 3),
                "efficiency": round(float(speedup) / n_dev, 3),
                "per_dev_flops": flops, "coll_bytes": 0,
                "proj_us_v5e": round(t_proj * 1e6, 3)})
            emit(f"fig9_{bits}bit_dev{n_dev}", us,
                 f"speedup={speedup:.2f};per_dev_flops={flops:.2e};"
                 f"coll_bytes=0;proj_us_v5e={t_proj * 1e6:.3f}",
                 backend or "default")
    if json_path and rows:
        payload = {"version": 1, "gemm": {"M": M, "K": K, "N": N},
                   "path": "repro.kernels.api.qdot_sharded",
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(rows)} rows -> {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default="1,2,4,8",
                    help="comma-separated mesh sizes to sweep")
    ap.add_argument("--json", default="BENCH_cluster.json",
                    help="output path for the JSON rows ('' disables)")
    ap.add_argument("--backend", default=None,
                    help="force a kernel backend (default: registry "
                         "resolution per local shard shape)")
    ap.add_argument("--bits", default="8,4,2",
                    help="bit-widths to sweep (SPMD compile per "
                         "(bits, devices) point dominates on CPU — "
                         "narrow this for smokes)")
    args = ap.parse_args()
    main([int(v) for v in args.devices.split(",")], args.json, args.backend,
         tuple(int(v) for v in args.bits.split(",")))
