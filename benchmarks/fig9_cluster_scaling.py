"""Fig. 9 analogue — multicore (mesh) scaling of the MatMul.

Paper: MAC/cycle efficiency of the 8-core cluster vs single core (and the
TCDM banking-factor effect). TPU adaptation: per-device FLOPs and bytes of
the packed GEMM sharded over 1..16 'model' shards (weights stationary,
activations replicated) — near-linear scaling == per-device work ~ 1/n with
bounded collective bytes. Derived from analytic partitioning of the same
GEMM the dry-run exercises.
"""
from benchmarks.common import emit, PEAK_FLOPS, HBM_BW


def main():
    M, K, N = 256, 4608, 256
    for bits in (8, 4, 2):
        for n_dev in (1, 2, 4, 8, 16):
            flops = 2 * M * K * N / n_dev
            w_bytes = K * N * bits // 8 // n_dev   # weight-stationary
            x_bytes = M * K * bits // 8            # activations replicated
            psum = 0 if n_dev == 1 else M * N * 4  # partial-sum reduce
            t = max(flops / PEAK_FLOPS, (w_bytes + x_bytes) / HBM_BW)
            emit(f"fig9_{bits}bit_dev{n_dev}", t * 1e6,
                 f"per_dev_flops={flops:.2e};coll_bytes={psum}")


if __name__ == "__main__":
    main()
