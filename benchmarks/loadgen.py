"""Open-loop serving load generator (BENCH_serving.json).

Drives the continuous-batching runtime (`repro.serve.runtime`) and the
legacy synchronous-wave policy with the *same* seeded open-loop workload
— Poisson arrivals at a configured offered QPS, mixed prompt/generation
lengths — and reports per-policy p50/p95/p99 request latency, request
and token throughput, queue depth, and slot occupancy. The comparison is
the PR's acceptance artifact: continuous batching must beat the wave
baseline on throughput *and* tail latency at the same offered load,
because a freed slot is re-admitted at the next step instead of idling
behind the wave's straggler (the paper's idle-core argument at request
granularity).

Time is **virtual**: one engine step costs ``--step-cost`` seconds and
arrivals are pre-drawn from the seed, so the whole simulation — arrival
times, admission order, per-request latencies, every derived stat — is
bit-reproducible run over run (CI asserts replay determinism). Wall
time on CPU would only measure XLA jitter; the queueing behaviour under
load is what the benchmark isolates. Per-request *outputs* are identical
across policies by the runtime's bit-exactness invariant, so the two
rows differ only in scheduling.

    PYTHONPATH=src python -m benchmarks.loadgen --json BENCH_serving.json
"""
import argparse
import collections
import json
import os
import sys

# must precede the first jax import to materialize host-platform devices
if "jax" not in sys.modules and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

POLICIES = ("wave", "continuous")


def build_workload(cfg, args):
    """Seeded open-loop workload: (arrival_time, prompt, max_new) rows.

    Inter-arrival gaps are Exp(1/qps) (Poisson process); prompt lengths
    and generation budgets are uniform over the configured ranges — the
    mixed-length mix that makes synchronous waves straggle."""
    rng = np.random.default_rng(args.seed)
    gaps = rng.exponential(1.0 / args.qps, size=args.requests)
    arrivals = np.cumsum(gaps)
    rows = []
    for t in arrivals:
        plen = int(rng.integers(args.prompt_min, args.prompt_max + 1))
        mnew = int(rng.integers(args.new_min, args.new_max + 1))
        prompt = rng.integers(2, cfg.vocab, size=(plen,)).astype(np.int32)
        rows.append((float(t), prompt, mnew))
    return rows


def run_policy(policy, model, params, workload, args, mesh=None):
    """Simulate one policy over the workload on a virtual clock."""
    from repro.serve.runtime import LMDecodeAdapter, Request, Scheduler

    adapter = LMDecodeAdapter(model, params, max_len=args.max_len,
                              mesh=mesh)
    sched = Scheduler(adapter, args.slots, mesh=mesh, policy=policy)
    pending = collections.deque(
        (t, Request(prompt=p, max_new_tokens=m)) for t, p, m in workload)
    now, t0 = 0.0, pending[0][0]
    while pending or not sched.idle:
        while pending and pending[0][0] <= now:
            t, req = pending.popleft()
            sched.submit(req, now=t)     # latency includes queueing delay
        if sched.idle and pending:       # idle gap: jump to next arrival
            now = pending[0][0]
            continue
        sched.step(now=now)
        now += args.step_cost
    rep = sched.serving_report()
    makespan = max(r["finish_t"] for r in sched.request_log) - t0
    return {
        "policy": policy,
        "requests": rep["requests"],
        "steps": rep["steps"],
        "tokens_out": rep["tokens_out"],
        "makespan_s": round(makespan, 6),
        "throughput_rps": round(rep["requests"] / makespan, 6),
        "throughput_tps": round(rep["tokens_out"] / makespan, 6),
        "latency_s": {k: round(v, 6) for k, v in rep["latency"].items()},
        "queue_depth": rep["queue_depth"],
        "occupancy": {k: round(v, 6) for k, v in rep["occupancy"].items()},
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--qps", type=float, default=0.6,
                    help="offered load, arrivals per virtual second")
    ap.add_argument("--step-cost", type=float, default=1.0,
                    help="virtual seconds per engine step")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--prompt-min", type=int, default=2)
    ap.add_argument("--prompt-max", type=int, default=6)
    ap.add_argument("--new-min", type=int, default=1)
    ap.add_argument("--new-max", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", action="store_true",
                    help="shard slots data-parallel over a (dp, tp) mesh")
    ap.add_argument("--json", default=None, help="write BENCH_serving.json")
    args = ap.parse_args(argv)

    import jax
    from repro.configs.qwen2p5_3b import smoke_config
    from repro.models.api import build

    cfg = smoke_config()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = None
    if args.mesh:
        dp = min(4, len(jax.devices()))
        tp = len(jax.devices()) // dp
        mesh = jax.make_mesh((dp, tp), ("data", "model"),
                             devices=jax.devices()[: dp * tp])

    workload = build_workload(cfg, args)
    print(f"workload: {args.requests} requests, qps={args.qps}, "
          f"prompts [{args.prompt_min},{args.prompt_max}], max_new "
          f"[{args.new_min},{args.new_max}], slots={args.slots}, "
          f"seed={args.seed}" + (f", dp={mesh.shape['data']}" if mesh
                                 else ""))
    rows = []
    for policy in POLICIES:
        row = run_policy(policy, model, params, workload, args,
                         mesh=mesh)
        rows.append(row)
        lat = row["latency_s"]
        print(f"{policy:>10}: {row['throughput_rps']:.3f} req/s "
              f"{row['throughput_tps']:.3f} tok/s over {row['steps']} "
              f"steps; latency p50={lat['p50']:.1f}s p99={lat['p99']:.1f}s"
              f"; occupancy {row['occupancy']['mean']:.0%}")

    wave = next(r for r in rows if r["policy"] == "wave")
    cont = next(r for r in rows if r["policy"] == "continuous")
    payload = {
        "version": 1,
        "workload": {
            "model": cfg.name, "requests": args.requests,
            "qps": args.qps, "step_cost_s": args.step_cost,
            "slots": args.slots, "max_len": args.max_len,
            "prompt_lens": [args.prompt_min, args.prompt_max],
            "max_new": [args.new_min, args.new_max],
            "seed": args.seed,
            "devices": (1 if mesh is None
                        else int(mesh.shape["data"])),
        },
        "rows": rows,
        "acceptance": {
            "throughput_gain": round(
                cont["throughput_tps"] / wave["throughput_tps"], 4),
            "p99_ratio": round(
                cont["latency_s"]["p99"] / wave["latency_s"]["p99"], 4),
        },
    }
    gain, p99 = (payload["acceptance"]["throughput_gain"],
                 payload["acceptance"]["p99_ratio"])
    print(f"continuous vs wave: {gain:.2f}x throughput, "
          f"{p99:.2f}x p99 latency")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}")
    return payload


if __name__ == "__main__":
    main()
