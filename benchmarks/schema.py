"""Checked-in schemas for the benchmark JSON artifacts.

CI archives three machine-readable artifacts per run and diffs them run
over run (the perf trajectory). Their shapes are load-bearing — a renamed
column silently breaks the trajectory tooling — so each writer's schema
is pinned here and validated by tests/test_bench_schema.py:

  BENCH_kernels.json  benchmarks/run.py    column dicts keyed by row name
                      (us_per_call, derived, backend, pipeline,
                      frac_of_peak — the fig8 roofline ladder columns —
                      plus the counter-measured macs_per_us /
                      packed_bytes columns)
  BENCH_cluster.json  fig9_cluster_scaling  {version, gemm, path, rows}
  BENCH_e2e.json      e2e_networks          {version, batch, rows}
  BENCH_serving.json  benchmarks/loadgen    {version, workload, rows,
                      acceptance} — per-policy serving stats; the
                      validator enforces continuous > wave on both
                      token throughput and p99 latency
  BENCH_trace.json    repro.obs             Chrome trace-event object +
                      the "repro" payload (counters, op counters,
                      dispatch log) — `check_trace`

Validation is dependency-free (no jsonschema): `SchemaError` carries the
JSON-path of the first offending field.
"""
import json
import numbers
import pathlib

from repro.kernels.common import PIPELINE_MODES


class SchemaError(ValueError):
    """An artifact field is missing, mistyped, or out of range."""


def _fail(path, msg):
    raise SchemaError(f"{path}: {msg}")


def _need(d, key, types, path, check=None):
    if key not in d:
        _fail(path, f"missing required field {key!r}")
    return _typed(d[key], types, f"{path}.{key}", check)


def _typed(v, types, path, check=None):
    # bool is an int subclass; never accept it where a number is expected
    if isinstance(v, bool) and bool not in (types if isinstance(
            types, tuple) else (types,)):
        _fail(path, f"expected {types}, got bool")
    if not isinstance(v, types):
        _fail(path, f"expected {types}, got {type(v).__name__}")
    if check is not None and not check(v):
        _fail(path, f"value {v!r} out of range")
    return v


_NUM = numbers.Real


# ------------------------------------------------------ BENCH_kernels ---

def _segment_bits_ok(v) -> bool:
    """Widest-first "|"-joined container widths, e.g. "8", "8|2", "8|4|2"."""
    parts = v.split("|")
    widths = [int(p) for p in parts if p in ("8", "4", "2")]
    return (len(widths) == len(parts) and len(parts) >= 1
            and widths == sorted(widths, reverse=True)
            and len(set(widths)) == len(widths))


def validate_kernels(payload) -> None:
    """benchmarks/run.py payload: per-column dicts keyed by row name."""
    us = _need(payload, "us_per_call", dict, "$")
    for name, v in us.items():
        _typed(v, _NUM, f"$.us_per_call.{name}", lambda x: x >= 0)
    for col, types, check in (
            ("derived", str, None),
            ("backend", str, None),
            ("pipeline", str, lambda v: v in PIPELINE_MODES),
            ("frac_of_peak", _NUM, lambda v: 0.0 <= v <= 1.0),
            ("macs_per_us", _NUM, lambda v: v >= 0),
            ("packed_bytes", int, lambda v: v >= 0),
            ("segment_bits", str, _segment_bits_ok)):
        d = _need(payload, col, dict, "$")
        for name, v in d.items():
            if name not in us:
                _fail(f"$.{col}.{name}", "row name not in us_per_call")
            _typed(v, types, f"$.{col}.{name}", check)


def validate_fig8_roofline(payload, bits=(8, 4, 2)) -> None:
    """The fig8 acceptance shape: per bit-width, a pipelined and a
    non-pipelined row, each carrying a frac_of_peak roofline column, with
    the pipelined fraction >= the exposed-DMA one."""
    validate_kernels(payload)
    frac, pipe = payload["frac_of_peak"], payload["pipeline"]
    for b in bits:
        off, db = f"fig8_{b}bit_off", f"fig8_{b}bit_double_buffer"
        for name, mode in ((off, "off"), (db, "double_buffer")):
            if name not in payload["us_per_call"]:
                _fail(f"$.us_per_call.{name}", "missing fig8 roofline row")
            if pipe.get(name) != mode:
                _fail(f"$.pipeline.{name}", f"expected {mode!r}")
            if name not in frac:
                _fail(f"$.frac_of_peak.{name}", "missing roofline column")
            for col in ("macs_per_us", "packed_bytes"):
                if name not in payload[col]:
                    _fail(f"$.{col}.{name}",
                          "missing counter-measured column")
        if frac[db] < frac[off]:
            _fail(f"$.frac_of_peak.{db}",
                  "pipelined roofline below the exposed-DMA one")


# ------------------------------------------------------ BENCH_cluster ---

def _rows(payload, path):
    rows = _need(payload, "rows", list, path)
    if not rows:
        _fail(f"{path}.rows", "empty rows")
    return rows


def validate_cluster(payload) -> None:
    """fig9_cluster_scaling payload."""
    _need(payload, "version", int, "$", lambda v: v == 1)
    gemm = _need(payload, "gemm", dict, "$")
    for k in ("M", "K", "N"):
        _need(gemm, k, int, "$.gemm", lambda v: v > 0)
    _need(payload, "path", str, "$")
    for i, r in enumerate(_rows(payload, "$")):
        p = f"$.rows[{i}]"
        _typed(r, dict, p)
        _need(r, "name", str, p)
        _need(r, "bits", int, p, lambda v: v in (8, 4, 2))
        _need(r, "devices", int, p, lambda v: v >= 1)
        _need(r, "us_per_call", _NUM, p, lambda v: v >= 0)
        _need(r, "speedup", _NUM, p, lambda v: v > 0)
        _need(r, "efficiency", _NUM, p, lambda v: v > 0)
        _need(r, "per_dev_flops", _NUM, p, lambda v: v > 0)
        _need(r, "coll_bytes", int, p, lambda v: v >= 0)
        _need(r, "proj_us_v5e", _NUM, p, lambda v: v > 0)


# ---------------------------------------------------------- BENCH_e2e ---

def validate_e2e(payload) -> None:
    """e2e_networks payload; per-layer rows omit the scaling columns."""
    _need(payload, "version", int, "$", lambda v: v == 1)
    _need(payload, "batch", int, "$", lambda v: v >= 1)
    for i, r in enumerate(_rows(payload, "$")):
        p = f"$.rows[{i}]"
        _typed(r, dict, p)
        _need(r, "name", str, p)
        _need(r, "net", str, p)
        _need(r, "layer", str, p)
        _need(r, "bits", (str, int), p)
        _need(r, "devices", int, p, lambda v: v >= 1)
        _need(r, "us_per_call", _NUM, p, lambda v: v >= 0)
        _need(r, "macs_per_image", int, p, lambda v: v > 0)
        for opt, types, check in (
                ("speedup", _NUM, lambda v: v > 0),
                ("efficiency", _NUM, lambda v: v > 0),
                ("bytes_streamed", int, lambda v: v > 0),
                ("proj_us_v5e", _NUM, lambda v: v > 0)):
            if opt in r:
                _typed(r[opt], types, f"{p}.{opt}", check)


# ------------------------------------------------------ BENCH_serving ---

def _serving_stats(r, p):
    lat = _need(r, "latency_s", dict, p)
    for k in ("p50", "p95", "p99", "mean", "max"):
        _need(lat, k, _NUM, f"{p}.latency_s", lambda v: v >= 0)
    qd = _need(r, "queue_depth", dict, p)
    _need(qd, "mean", _NUM, f"{p}.queue_depth", lambda v: v >= 0)
    _need(qd, "max", int, f"{p}.queue_depth", lambda v: v >= 0)
    occ = _need(r, "occupancy", dict, p)
    _need(occ, "mean", _NUM, f"{p}.occupancy", lambda v: 0 <= v <= 1)
    _need(occ, "min", _NUM, f"{p}.occupancy", lambda v: 0 <= v <= 1)


def validate_serving(payload) -> None:
    """benchmarks/loadgen payload: one row per scheduling policy on the
    same seeded open-loop workload, plus the acceptance comparison —
    continuous batching must be strictly better than the synchronous
    wave baseline on token throughput AND p99 latency at the same
    offered load (the PR-8 acceptance shape, enforced like the fig8
    pipelined-roofline ordering)."""
    _need(payload, "version", int, "$", lambda v: v == 1)
    w = _need(payload, "workload", dict, "$")
    _need(w, "model", str, "$.workload")
    _need(w, "requests", int, "$.workload", lambda v: v >= 1)
    _need(w, "qps", _NUM, "$.workload", lambda v: v > 0)
    _need(w, "step_cost_s", _NUM, "$.workload", lambda v: v > 0)
    _need(w, "slots", int, "$.workload", lambda v: v >= 1)
    _need(w, "seed", int, "$.workload")
    _need(w, "devices", int, "$.workload", lambda v: v >= 1)
    rows = _rows(payload, "$")
    by_policy = {}
    for i, r in enumerate(rows):
        p = f"$.rows[{i}]"
        _typed(r, dict, p)
        pol = _need(r, "policy", str, p,
                    lambda v: v in ("wave", "continuous"))
        by_policy[pol] = r
        _need(r, "requests", int, p, lambda v: v >= 1)
        _need(r, "steps", int, p, lambda v: v >= 1)
        _need(r, "tokens_out", int, p, lambda v: v >= 0)
        _need(r, "makespan_s", _NUM, p, lambda v: v > 0)
        _need(r, "throughput_rps", _NUM, p, lambda v: v > 0)
        _need(r, "throughput_tps", _NUM, p, lambda v: v > 0)
        _serving_stats(r, p)
    for pol in ("wave", "continuous"):
        if pol not in by_policy:
            _fail("$.rows", f"missing policy row {pol!r}")
    acc = _need(payload, "acceptance", dict, "$")
    gain = _need(acc, "throughput_gain", _NUM, "$.acceptance")
    p99 = _need(acc, "p99_ratio", _NUM, "$.acceptance")
    wave, cont = by_policy["wave"], by_policy["continuous"]
    if cont["throughput_tps"] <= wave["throughput_tps"] or gain <= 1.0:
        _fail("$.acceptance.throughput_gain",
              "continuous batching does not beat the wave baseline "
              "on token throughput")
    if cont["latency_s"]["p99"] >= wave["latency_s"]["p99"] or p99 >= 1.0:
        _fail("$.acceptance.p99_ratio",
              "continuous batching does not beat the wave baseline "
              "on p99 latency")


# ----------------------------------------------------- BENCH_accuracy ---

_ACC_MODES = ("float", "ptq", "qat")
_ACC_PLANS = ("none", "uniform", "layer", "channel_group")


def _accuracy_row(r, p):
    _typed(r, dict, p)
    _need(r, "name", str, p)
    _need(r, "mode", str, p, lambda v: v in _ACC_MODES)
    _need(r, "plan", str, p, lambda v: v in _ACC_PLANS)
    _need(r, "w_bits", int, p, lambda v: v in (0, 2, 4, 8, 32))
    _need(r, "accuracy", _NUM, p, lambda v: 0 <= v <= 1)
    _need(r, "correct", int, p, lambda v: v >= 0)
    _need(r, "n", int, p, lambda v: v >= 1)
    if r["correct"] > r["n"]:
        _fail(p, f"correct {r['correct']} > n {r['n']}")
    _need(r, "packed_weight_bytes", int, p, lambda v: v >= 1)
    _need(r, "train_steps", int, p, lambda v: v >= 1)
    _need(r, "segmented_rules", int, p, lambda v: v >= 0)


def validate_accuracy(payload) -> None:
    """benchmarks/accuracy payload: accuracy-vs-packed-bytes Pareto rows
    (every accuracy an integer-path `forward_int` measurement), plus the
    acceptance gates. The gates are RECOMPUTED from the rows here — the
    stored booleans can't claim what the rows don't show:
      * uniform QAT >= uniform PTQ at W4 and W2,
      * no plan row strictly dominated by a same-mode uniform row,
      * the channel-group QAT plan has <= bytes and >= accuracy vs the
        per-layer QAT plan (same budget; granularity is the only delta).
    Smoke payloads keep the row schema but skip gate enforcement."""
    _need(payload, "version", int, "$", lambda v: v == 1)
    _need(payload, "net", str, "$")
    mode = _need(payload, "mode", str, "$",
                 lambda v: v in ("full", "smoke"))
    ds = _need(payload, "dataset", dict, "$")
    _need(ds, "name", str, "$.dataset")
    _need(ds, "seed", int, "$.dataset")
    _need(ds, "eval_images", int, "$.dataset", lambda v: v >= 1)
    _need(payload, "budget_frac", _NUM, "$", lambda v: 0 < v < 1)
    rows = _rows(payload, "$")
    for i, r in enumerate(rows):
        _accuracy_row(r, f"$.rows[{i}]")

    def pick(m, plan, bits=None):
        got = [r for r in rows if r["mode"] == m and r["plan"] == plan
               and (bits is None or r["w_bits"] == bits)]
        return got[0] if got else None

    for m in ("ptq", "qat"):
        for b in (8, 4, 2):
            if pick(m, "uniform", b) is None:
                _fail("$.rows", f"missing uniform row mode={m} w_bits={b}")
    acc = _need(payload, "acceptance", dict, "$")
    for key in ("qat_ge_ptq_w4", "qat_ge_ptq_w2", "plans_on_frontier",
                "fine_dominates_layer", "all"):
        _need(acc, key, bool, "$.acceptance")
    if mode == "smoke":
        return
    for b in (4, 2):
        q, p = pick("qat", "uniform", b), pick("ptq", "uniform", b)
        if q["accuracy"] < p["accuracy"]:
            _fail(f"$.acceptance.qat_ge_ptq_w{b}",
                  f"QAT ({q['accuracy']}) below PTQ ({p['accuracy']}) "
                  f"at W{b}")
    for m in ("ptq", "qat"):
        uni = [r for r in rows if r["mode"] == m and r["plan"] == "uniform"]
        for r in rows:
            if r["mode"] != m or r["plan"] not in ("layer",
                                                   "channel_group"):
                continue
            for u in uni:
                if (u["packed_weight_bytes"] <= r["packed_weight_bytes"]
                        and u["accuracy"] >= r["accuracy"]
                        and (u["packed_weight_bytes"]
                             < r["packed_weight_bytes"]
                             or u["accuracy"] > r["accuracy"])):
                    _fail("$.acceptance.plans_on_frontier",
                          f"{u['name']} dominates {r['name']}")
    fine, layer = pick("qat", "channel_group"), pick("qat", "layer")
    if fine is None or layer is None:
        _fail("$.rows", "missing qat plan rows (layer/channel_group)")
    if (fine["packed_weight_bytes"] > layer["packed_weight_bytes"]
            or fine["accuracy"] < layer["accuracy"]):
        _fail("$.acceptance.fine_dominates_layer",
              "channel-group plan does not dominate-or-match the "
              "per-layer plan")
    if not acc["all"]:
        _fail("$.acceptance.all", "gates hold but 'all' is false")


# -------------------------------------------------------- BENCH_trace ---

_TRACE_PHASES = ("X", "i", "B", "E", "M", "C")
_COUNTER_FIELDS = ("calls", "macs", "logical_bytes", "packed_bytes")


def check_trace(payload) -> None:
    """A `repro.obs` Chrome trace-event artifact: the trace-event object
    form (every event carries name/ph/ts; complete events a dur) plus
    the repo payload under "repro" (generic counters, per-(op, bits,
    backend, pipeline) op counters, the dispatch decision log)."""
    events = _need(payload, "traceEvents", list, "$")
    for i, e in enumerate(events):
        p = f"$.traceEvents[{i}]"
        _typed(e, dict, p)
        _need(e, "name", str, p)
        _need(e, "ph", str, p, lambda v: v in _TRACE_PHASES)
        _need(e, "ts", _NUM, p, lambda v: v >= 0)
        if e["ph"] == "X":
            _need(e, "dur", _NUM, p, lambda v: v >= 0)
        if "args" in e:
            _typed(e["args"], dict, f"{p}.args")
    repro = _need(payload, "repro", dict, "$")
    _need(repro, "version", int, "$.repro", lambda v: v == 1)
    counters = _need(repro, "counters", dict, "$.repro")
    for name, v in counters.items():
        _typed(v, _NUM, f"$.repro.counters.{name}")
    ops = _need(repro, "op_counters", dict, "$.repro")
    for key, bucket in ops.items():
        p = f"$.repro.op_counters.{key}"
        if len(key.split("|")) != 4:
            _fail(p, "key is not op|w{W}a{A}|backend|pipeline")
        _typed(bucket, dict, p)
        for f in _COUNTER_FIELDS:
            _need(bucket, f, int, p, lambda v: v >= 0)
    dispatch = _need(repro, "dispatch", list, "$.repro")
    for i, d in enumerate(dispatch):
        p = f"$.repro.dispatch[{i}]"
        _typed(d, dict, p)
        _need(d, "op", str, p)
        _need(d, "backend", str, p)
        _need(d, "backend_source", str, p)
        _need(d, "pipeline", str, p, lambda v: v in PIPELINE_MODES)
        _need(d, "pipeline_source", str, p)
        _need(d, "ts", _NUM, p, lambda v: v >= 0)


# ------------------------------------------------------------ dispatch ---

VALIDATORS = {
    "BENCH_kernels.json": validate_kernels,
    "BENCH_cluster.json": validate_cluster,
    "BENCH_e2e.json": validate_e2e,
    "BENCH_serving.json": validate_serving,
    "BENCH_accuracy.json": validate_accuracy,
    "BENCH_trace.json": check_trace,
}


def validate_file(path) -> None:
    """Validate an artifact file, dispatching on its basename."""
    p = pathlib.Path(path)
    try:
        fn = VALIDATORS[p.name]
    except KeyError:
        raise SchemaError(
            f"{p.name}: no schema registered (known: "
            f"{sorted(VALIDATORS)})") from None
    fn(json.loads(p.read_text()))
