"""Fig. 13/14 analogue — baseline (8-bit-only substrate) vs XpulpNN
(native sub-byte) at the framework level.

Paper: XpulpNN cluster vs RI5CY cluster (and STM32 MCUs): 6x (4-bit) and
8.7x (2-bit) conv speedups. TPU adaptation: W8A8 path (the '8-bit-only
baseline ISA': sub-byte data must be unpacked to bytes in HBM, gaining
nothing) vs packed W4A4/W2A2 path. The gain appears in the memory roofline
term of the serving-shaped GEMM; silicon wall-clock is out of scope (see
DESIGN.md §7).
"""
import numpy as np

from benchmarks.common import emit, HBM_BW, PEAK_FLOPS


def main():
    # decode-shaped GEMM per chip: 32 tokens/chip, d_model 4096, output
    # shard 16384/16 — the memory-bound serving regime the paper targets
    M, K, N = 32, 4096, 1024
    base = None
    for bits, name in ((16, "bf16_fp_baseline"), (8, "w8_baseline_isa"),
                       (4, "xpulpnn_w4"), (2, "xpulpnn_w2")):
        w_bytes = K * N * bits // 8
        x_bytes = M * K            # int8/bf16 activations
        t_mem = (w_bytes + x_bytes) / HBM_BW
        t_cmp = 2 * M * K * N / PEAK_FLOPS
        t = max(t_mem, t_cmp)
        if base is None:
            base = t
        bound = "mem" if t_mem > t_cmp else "compute"
        emit(f"fig13_decode_gemm_{name}", t * 1e6,
             f"speedup_vs_bf16={base/t:.2f}x;bound={bound}")


if __name__ == "__main__":
    main()
